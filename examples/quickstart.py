"""Quickstart: HaS speculative retrieval vs full-database retrieval.

    PYTHONPATH=src python examples/quickstart.py

Builds a synthetic entity-attribute world (the paper's Granola-EQ* analogue),
serves a Zipf query stream through HaS and through plain full-database
retrieval, and prints the paper's headline metrics side by side.
"""
import sys

from repro.core.has import HasConfig
from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
from repro.serving.engine import FullRetrievalEngine, HasEngine, RetrievalService
from repro.serving.latency import LatencyModel


def main():
    n_queries = int(sys.argv[1]) if len(sys.argv) > 1 else 1500
    print("== building world (8k entities, 40k passages) ==")
    world = SyntheticWorld(WorldConfig(n_entities=8000, seed=0))
    service = RetrievalService(world, LatencyModel(), k=10)
    ds = DATASETS["granola"]
    queries = world.sample_queries(n_queries, pattern=ds["pattern"],
                                   zipf_a=ds["zipf_a"],
                                   p_uncovered=ds["p_uncovered"], seed=1)

    print("== full-database retrieval (cloud ENNS, 49.2M-passage scale) ==")
    full = FullRetrievalEngine(service).serve(queries[:400]).summary()
    for k in ("avg_latency_s", "doc_hit_rate", "ra_qwen3-8b"):
        print(f"  {k:16s} {full[k]:.4f}")

    print("== HaS (two-channel speculation + homology validation) ==")
    has = HasEngine(service, HasConfig(k=10, tau=0.2, h_max=5000,
                                       nprobe=8, n_buckets=1024, d=64))
    s = has.serve(queries).summary()
    for k in ("avg_latency_s", "dar", "car", "l_at_da", "l_at_dr",
              "doc_hit_rate", "ra_qwen3-8b"):
        print(f"  {k:16s} {s[k]:.4f}")
    cut = (s["avg_latency_s"] - full["avg_latency_s"]) / full["avg_latency_s"]
    print(f"\n  retrieval latency change vs full DB: {cut:+.2%} "
          f"(paper: -23.74% Granola / -36.99% PopQA)")


if __name__ == "__main__":
    main()
