"""HaS inside an Auto-RAG-style agentic pipeline (paper §IV-E II, Fig 13/14).

    PYTHONPATH=src python examples/agentic_multihop.py [n_complex_queries]

Complex 2-hop queries are decomposed into sub-queries; every sub-query is
intercepted by HaS with zero pipeline modification.  Decomposed sub-queries
concentrate on hub entities, so the draft acceptance rate — and the latency
cut — exceed the single-hop setting (the paper reports -69.4%).
"""
import sys

from repro.core.has import HasConfig
from repro.data.synthetic import SyntheticWorld, WorldConfig
from repro.serving.agentic import AutoRagPipeline, TwoHopDataset
from repro.serving.engine import HasEngine, RetrievalService
from repro.serving.latency import LatencyModel


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 600
    world = SyntheticWorld(WorldConfig(n_entities=8000, seed=0))
    service = RetrievalService(world, LatencyModel(), k=10)
    ds = TwoHopDataset(world, seed=0)
    complex_qs = ds.sample(n, seed=2)

    print("== Auto-RAG with full-database retrieval ==")
    base = AutoRagPipeline(ds, None, service).run(complex_qs)
    for k, v in base.items():
        print(f"  {k:20s} {v:.4f}")

    print("== Auto-RAG + HaS (plug-in, no pipeline changes) ==")
    engine = HasEngine(service, HasConfig(k=10, tau=0.2, h_max=5000,
                                          nprobe=8, n_buckets=1024, d=64))
    plug = AutoRagPipeline(ds, engine, service).run(complex_qs)
    for k, v in plug.items():
        print(f"  {k:20s} {v:.4f}")

    cut = (plug["retrieval_latency"] - base["retrieval_latency"]) \
        / base["retrieval_latency"]
    dacc = plug["accuracy"] - base["accuracy"]
    print(f"\nretrieval latency: {cut:+.1%} (paper: -69.4%), "
          f"accuracy delta: {dacc:+.4f} (paper: -3.72%)")


if __name__ == "__main__":
    main()
