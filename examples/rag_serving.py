"""End-to-end RAG serving driver: HaS retrieval + a real LM decoding answers.

    PYTHONPATH=src python examples/rag_serving.py [n_requests]

The full request path of the paper's Fig 1, with every stage real:
  1. the query hits HaS (two-channel speculation + homology validation);
  2. retrieved doc ids become context tokens for a transformer generator
     (our LM substrate with a KV cache — the same decode_step that the
     dry-run lowers at 32k/500k context on the production mesh);
  3. the response streams out token by token (TTFT + decode throughput
     are measured per request, batched).
"""
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.has import HasConfig
from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
from repro.models import transformer as tf
from repro.serving.engine import HasEngine, RetrievalService
from repro.serving.latency import LatencyModel


def main():
    n_requests = int(sys.argv[1]) if len(sys.argv) > 1 else 200
    batch = 8
    gen_cfg = tf.TransformerConfig(
        name="rag-lm", n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
        d_ff=1024, vocab_size=4096, d_head=32, remat=False)
    print(f"generator: {gen_cfg.param_count() / 1e6:.1f}M params")
    params = tf.init_params(gen_cfg, jax.random.key(0))

    world = SyntheticWorld(WorldConfig(n_entities=5000, seed=0))
    service = RetrievalService(world, LatencyModel(), k=10)
    engine = HasEngine(service, HasConfig(k=10, tau=0.2, h_max=4000,
                                          nprobe=8, n_buckets=512, d=64))
    ds = DATASETS["granola"]
    queries = world.sample_queries(n_requests, pattern=ds["pattern"],
                                   zipf_a=ds["zipf_a"],
                                   p_uncovered=ds["p_uncovered"], seed=1)

    prompt_len, gen_len = 64, 16
    prefill = jax.jit(lambda p, t: tf.prefill(p, t, gen_cfg, None))
    decode = jax.jit(lambda p, c, t, i: tf.decode_step(p, c, t, i, gen_cfg,
                                                       None))
    # warmup
    toks = jnp.zeros((batch, prompt_len), jnp.int32)
    prefill(params, toks).block_until_ready()
    cache = tf.init_kv_cache(gen_cfg, batch, prompt_len + gen_len)
    decode(params, cache, jnp.zeros((batch,), jnp.int32), jnp.int32(0))

    stats = {"retrieval": [], "ttft": [], "decode_tps": [], "accept": []}
    for start in range(0, n_requests, batch):
        group = queries[start:start + batch]
        if len(group) < batch:
            break
        # 1) retrieval through HaS (sequential; cache mutates per query)
        doc_ids = []
        for q in group:
            ids, accept, lat, _ = engine.step(q["emb"])
            stats["retrieval"].append(lat)
            stats["accept"].append(accept)
            doc_ids.append(ids[:10])
        # 2) build prompts: [doc tokens..., query tokens...]
        prompt = np.zeros((batch, prompt_len), np.int64)
        for i, (q, ids) in enumerate(zip(group, doc_ids)):
            ctx = (np.abs(ids) % 4000).repeat(5)[:prompt_len - 8]
            prompt[i, :len(ctx)] = ctx
            prompt[i, -8:] = (q["tokens"] % 4000)[:8].repeat(2)[:8]
        prompt = jnp.asarray(prompt, jnp.int32)
        # 3) prefill (TTFT) + decode loop
        t0 = time.perf_counter()
        logits = prefill(params, prompt)
        logits.block_until_ready()
        ttft = time.perf_counter() - t0
        cache = tf.init_kv_cache(gen_cfg, batch, prompt_len + gen_len)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        t0 = time.perf_counter()
        for j in range(gen_len):
            lg, cache = decode(params, cache, tok, jnp.int32(prompt_len + j))
            tok = jnp.argmax(lg, -1).astype(jnp.int32)
        tok.block_until_ready()
        dt = time.perf_counter() - t0
        stats["ttft"].append(ttft)
        stats["decode_tps"].append(batch * gen_len / dt)

    print(f"requests served        {len(stats['retrieval'])}")
    print(f"retrieval avg latency  {np.mean(stats['retrieval']):.4f} s "
          f"(draft acceptance {np.mean(stats['accept']):.1%})")
    print(f"prefill TTFT (batch)   {np.mean(stats['ttft']) * 1e3:.1f} ms")
    print(f"decode throughput      {np.mean(stats['decode_tps']):.1f} tok/s")
    print("\nFig-1 takeaway: full-DB retrieval would add "
          f"{service.latency.full_scan_time():.2f} s/query on top of a "
          f"{np.mean(stats['ttft']) * 1e3:.0f} ms TTFT; HaS cuts the "
          "retrieval term for every accepted draft.")


if __name__ == "__main__":
    main()
