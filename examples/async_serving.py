"""Continuous-batching async serving demo: open-loop load on the scheduler.

    PYTHONPATH=src python examples/async_serving.py [n_requests] [qps] [backend]

Requests arrive as a Poisson process; the event-driven scheduler
(serving/scheduler.py) coalesces admissions into speculation batches on the
edge, returns accepted drafts immediately, collapses homologous rejects
into shared full retrievals (single-flight), late-revalidates queued
rejects against the freshly ingested cache, and overlaps the cloud
full-retrieval pipeline with ongoing edge speculation.

The cloud stage is a WORKER POOL over the pluggable retrieval backend
(retrieval/service.py) — ``backend.n_workers`` concurrent full-retrieval
dispatches, not the old serialized ``max_inflight_full=1`` scalar (that
config knob is deprecated; the backend sizes the pool).  Pass ``sharded``
as the third argument to back the pool with 4 mesh-sharded workers and
watch p95/p99 drop as full batches overlap.  Compare against
``examples/rag_serving.py`` which serves the same world strictly
sequentially.
"""
import sys

import numpy as np

from repro.core.has import HasConfig
from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
from repro.retrieval.service import ShardedMeshBackend
from repro.serving.engine import HasEngine, RetrievalService
from repro.serving.latency import LatencyModel
from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                     SchedulerConfig, poisson_arrivals)


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 600
    qps = float(sys.argv[2]) if len(sys.argv) > 2 else 25.0
    backend_name = sys.argv[3] if len(sys.argv) > 3 else "flat"

    world = SyntheticWorld(WorldConfig(n_entities=5000, seed=0))
    latency = LatencyModel()
    backend = None                                  # default: flat, 1 worker
    if backend_name == "sharded":
        import jax.numpy as jnp
        backend = ShardedMeshBackend(jnp.asarray(world.doc_emb), 10, latency,
                                     n_shards=4, n_workers=4)
    elif backend_name != "flat":
        raise SystemExit(f"unknown backend {backend_name!r} "
                         "(choices: flat, sharded)")
    service = RetrievalService(world, latency, k=10, backend=backend)
    cfg = HasConfig(k=10, tau=0.2, h_max=4000, nprobe=8, n_buckets=512, d=64)
    ds = DATASETS["granola"]
    queries = world.sample_queries(n, pattern=ds["pattern"],
                                   zipf_a=ds["zipf_a"],
                                   p_uncovered=ds["p_uncovered"], seed=1)

    sched = ContinuousBatchingScheduler(
        service, cfg,
        SchedulerConfig(max_spec_batch=32, full_batch=16,
                        full_max_wait_s=0.05))
    res = sched.serve(queries, poisson_arrivals(n, qps=qps, seed=7), seed=0)
    s = res.summary()

    print(f"open-loop load          {qps:.1f} qps Poisson, {n} requests")
    print(f"cloud worker pool       {backend_name} backend, "
          f"{sched.n_full_workers} worker(s), peak concurrency "
          f"{s['max_inflight_full_batches']:.0f}")
    print(f"completed throughput    {s['throughput_qps']:.2f} qps "
          f"(makespan {s['makespan_s']:.1f} s)")
    print(f"latency p50/p95/p99     {s['p50_latency_s'] * 1e3:.0f} / "
          f"{s['p95_latency_s'] * 1e3:.0f} / "
          f"{s['p99_latency_s'] * 1e3:.0f} ms")
    print(f"draft acceptance (DAR)  {s['dar']:.1%}   doc-hit "
          f"{s['doc_hit_rate']:.1%}")
    for ch in ("draft", "reval", "shared", "full"):
        cnt = int(np.sum(res.channels == ch))
        lat_ch = res.latencies[res.channels == ch]
        med = np.median(lat_ch) * 1e3 if cnt else 0.0
        print(f"  channel {ch:<7} {cnt:>5} requests   median latency "
              f"{med:7.1f} ms")
    print(f"full retrievals paid    {s['full_retrievals']} "
          f"({s['shared_accepts']} homologous rejects shared one)")

    # closed-loop sequential reference on a prefix of the same stream
    seq = HasEngine(service, cfg).serve(queries[:200]).summary()
    print(f"\nsequential HasEngine    {1.0 / seq['avg_latency_s']:.2f} qps "
          f"(AvgL {seq['avg_latency_s']:.3f} s) — the scheduler overlaps "
          "cloud retrieval with edge speculation instead of serializing")


if __name__ == "__main__":
    main()
