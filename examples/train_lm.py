"""Train an LM end-to-end with the production loop (checkpoint + watchdog).

    PYTHONPATH=src python examples/train_lm.py            # ~20M, 200 steps
    PYTHONPATH=src python examples/train_lm.py --full     # ~100M preset

Demonstrates the full training control plane at local scale: AdamW, Markov
LM data pipeline, async atomic checkpoints every 50 steps, straggler
watchdog, resumable restarts (re-run the command — it resumes).
"""
import argparse

from repro.launch.train import make_lm100m, train_lm
from repro.models.transformer import TransformerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params (slow on 1 CPU core)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    if args.full:
        cfg = make_lm100m()
        batch, seq = 4, 256
    else:
        cfg = TransformerConfig(
            name="lm20m", n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
            d_ff=1024, vocab_size=4096, d_head=32, remat=False)
        batch, seq = 8, 128
    print(f"training {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"{args.steps} steps @ batch={batch} seq={seq}")
    losses = train_lm(cfg, steps=args.steps, batch=batch, seq=seq,
                      ckpt_dir=args.ckpt_dir, log_every=20)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'improved' if losses[-1] < losses[0] else 'check data'})")


if __name__ == "__main__":
    main()
