"""Fig 13: HaS plugged into the Auto-RAG 2-hop agentic pipeline."""
from __future__ import annotations

from benchmarks.common import FAST, get_service, has_config, row
from repro.serving.agentic import AutoRagPipeline, TwoHopDataset
from repro.serving.engine import HasEngine


def run():
    rows = []
    svc = get_service()
    ds = TwoHopDataset(svc.world, seed=0)
    n = 300 if FAST else 1200
    complex_qs = ds.sample(n, seed=2)

    base = AutoRagPipeline(ds, None, svc).run(complex_qs)
    rows.append(row("fig13/auto-rag/full", base["retrieval_latency"],
                    f"acc={base['accuracy']:.4f};"
                    f"e2e={base['e2e_latency']:.3f}s"))

    has = HasEngine(svc, has_config())
    plug = AutoRagPipeline(ds, has, svc).run(complex_qs)
    dlat = (plug["retrieval_latency"] - base["retrieval_latency"]) \
        / base["retrieval_latency"]
    rows.append(row("fig13/auto-rag/HaS", plug["retrieval_latency"],
                    f"acc={plug['accuracy']:.4f};dar={plug['dar']:.4f};"
                    f"dLat={dlat:+.2%};e2e={plug['e2e_latency']:.3f}s"))
    return rows
