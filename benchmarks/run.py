"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one row per table entry) and writes
results/benchmarks.json.  BENCH_FAST=1 shrinks the world ~4x.
"""
from __future__ import annotations

import json
import os
import time


def main() -> None:
    from benchmarks import (fig11_k_sweep, fig13_agentic, retrieval_roofline,
                            sched_agentic, sched_throughput, table2_anns,
                            table3_reuse, table5_scattered,
                            table6_fuzzy_ablation, table7_compression,
                            table8_tau_encoders, table9_cache_size)
    from benchmarks.common import fmt_rows

    modules = [
        ("table3_reuse (Tables III+IV)", table3_reuse),
        ("table2_anns (Table II)", table2_anns),
        ("table5_scattered (Table V)", table5_scattered),
        ("table6_fuzzy_ablation (Table VI)", table6_fuzzy_ablation),
        ("table7_compression (Table VII)", table7_compression),
        ("table8_tau_encoders (Table VIII)", table8_tau_encoders),
        ("table9_cache_size (Table IX)", table9_cache_size),
        ("fig11_k_sweep (Fig 11)", fig11_k_sweep),
        ("fig13_agentic (Fig 13)", fig13_agentic),
        ("retrieval_roofline (Fig 1)", retrieval_roofline),
        ("sched_throughput (serving scheduler)", sched_throughput),
        ("sched_agentic (agentic multi-hop serving)", sched_agentic),
    ]
    all_rows = []
    for name, mod in modules:
        t0 = time.time()
        rows = mod.run()
        all_rows.extend(rows)
        print(f"# {name}: {len(rows)} rows in {time.time() - t0:.1f}s",
              flush=True)
        print(fmt_rows(rows), flush=True)
        print()

    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.json", "w") as f:
        json.dump(all_rows, f, indent=1)
    print(f"# total rows: {len(all_rows)} -> results/benchmarks.json")

    # automatic paper-vs-repro validation table
    from benchmarks.paper_compare import compare
    print("\n# paper-claim checks")
    results = compare(all_rows)
    for r in results:
        ours = f"{r['ours']:.4f}" if isinstance(r["ours"], float) else "-"
        print(f"{r['check']:42s} paper={r['paper']:10.4f} ours={ours:>10s} "
              f"{r['status']}")
    n_ok = sum(r["status"] == "OK" for r in results)
    print(f"# {n_ok}/{len(results)} paper checks OK")


if __name__ == "__main__":
    main()
