"""Shared benchmark context: one world/service per encoder, cached engines.

Sizes follow the paper where feasible on CPU: k=10, tau=0.2, H_max=5000,
fuzzy 16/2048 buckets (the paper's 64/8192 scope ratio), 100k-passage
synthetic corpus extrapolated to the 49.2M target by the calibrated latency
model (serving/latency.py).  BENCH_FAST=1 shrinks everything ~4x for CI.
"""
from __future__ import annotations

import functools
import os

from repro.core.has import HasConfig
from repro.data.synthetic import DATASETS, ENCODERS, SyntheticWorld, WorldConfig
from repro.serving.engine import RetrievalService
from repro.serving.latency import LatencyModel

FAST = bool(int(os.environ.get("BENCH_FAST", "0")))

N_ENTITIES = 4000 if FAST else 20000
N_QUERIES = 1200 if FAST else 5000
K = 10
TAU = 0.2
H_MAX = 1200 if FAST else 5000
N_BUCKETS = 512 if FAST else 2048
NPROBE = 4 if FAST else 16          # == the paper's 64/8192 scope ratio


@functools.lru_cache(maxsize=3)
def get_service(encoder: str = "contriever") -> RetrievalService:
    world = SyntheticWorld(WorldConfig(n_entities=N_ENTITIES, seed=0,
                                       **ENCODERS[encoder]))
    return RetrievalService(world, LatencyModel(), k=K,
                            chunk=min(32768, world.cfg.n_docs))


@functools.lru_cache(maxsize=16)
def get_queries(dataset: str = "granola", n: int = N_QUERIES,
                encoder: str = "contriever", seed: int = 1):
    ds = DATASETS[dataset]
    svc = get_service(encoder)
    return tuple(svc.world.sample_queries(
        n, pattern=ds["pattern"], zipf_a=ds["zipf_a"],
        p_uncovered=ds["p_uncovered"], seed=seed))


def has_config(**kw) -> HasConfig:
    base = dict(k=K, tau=TAU, h_max=H_MAX, nprobe=NPROBE,
                n_buckets=N_BUCKETS, d=64)
    base.update(kw)
    return HasConfig(**base)


def row(name: str, latency_s: float, derived) -> dict:
    """One CSV row: name, us_per_call, derived metric."""
    return {"name": name, "us_per_call": latency_s * 1e6, "derived": derived}


def fmt_rows(rows) -> str:
    out = ["name,us_per_call,derived"]
    for r in rows:
        out.append(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    return "\n".join(out)
