"""Table VI: fuzzy channel ablation — validation (V) and enhancement (E)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import get_queries, get_service, has_config, row
from repro.core.has import cache_update, init_has_state
from repro.serving.engine import HasEngine


def _prefill_cache(engine, svc, n=200, seed=99):
    """Paper footnote 7: pre-fill the cache with random queries so the
    no-fuzzy-validation setting doesn't trivially fail on cold start."""
    import jax.numpy as jnp
    qs = svc.world.sample_queries(n, pattern="zipf", seed=seed)
    for q in qs:
        ids, vecs, _ = svc.full_search(q["emb"])
        engine.state = cache_update(
            engine.cfg, engine.state, jnp.asarray(q["emb"]),
            jnp.asarray(ids.astype(np.int32)), jnp.asarray(vecs))


def run():
    rows = []
    svc = get_service()
    qs = list(get_queries("granola"))
    for v, e in ((False, False), (False, True), (True, False), (True, True)):
        eng = HasEngine(svc, has_config(use_fuzzy_validation=v,
                                        use_fuzzy_enhancement=e))
        if not v:
            _prefill_cache(eng, svc)
        s = eng.serve(qs, dataset="granola").summary()
        rows.append(row(
            f"t6/V={int(v)}E={int(e)}", s["avg_latency_s"],
            f"ra={s['ra_qwen3-8b']:.4f};dar={s['dar']:.4f};"
            f"car={s['car']:.4f};ra@da={s['ra_at_da']:.4f}"))
    return rows
