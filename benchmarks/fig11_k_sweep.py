"""Fig 11: U-shaped influence of k (draft size) on accuracy/validation."""
from __future__ import annotations

from repro.core.has import HasConfig
from repro.serving.engine import HasEngine

from benchmarks.common import (H_MAX, N_BUCKETS, NPROBE, get_queries,
                               get_service, row)


def run():
    rows = []
    svc = get_service()
    qs = list(get_queries("granola"))
    for k in (3, 5, 10, 20, 40):
        svc_k = svc if k == svc.k else None
        # the service is k-specific (full search returns k docs)
        from repro.serving.engine import RetrievalService
        if svc_k is None:
            svc_k = RetrievalService(svc.world, svc.latency, k=k,
                                     chunk=svc.chunk)
        cfg = HasConfig(k=k, tau=0.2, h_max=H_MAX, nprobe=NPROBE,
                        n_buckets=N_BUCKETS, d=64)
        s = HasEngine(svc_k, cfg).serve(qs, dataset="granola").summary()
        rows.append(row(f"fig11/k={k}", s["avg_latency_s"],
                        f"ra={s['ra_qwen3-8b']:.4f};car={s['car']:.4f};"
                        f"dar={s['dar']:.4f}"))
    return rows
