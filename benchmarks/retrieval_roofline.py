"""Fig 1 analogue + kernel accounting: retrieval cost vs generation TTFT.

Measures the real (CPU) wall time of the jitted retrieval substrate at
several corpus scales, derives the paper-scale latency via the calibrated
bandwidth model, and reports HLO flops/bytes of the retrieval step (the
per-kernel roofline terms used in EXPERIMENTS.md §Roofline).

``--sweep-backend`` (also folded into ``run()``) additionally sweeps the
batch-native speculation pipeline over backend × batch size — the XLA
reference vs the Pallas kernel path (interpret mode off-TPU) — records
p50/p95 step latency, host→device dispatch counts from the
:mod:`repro.core.dispatch` probe, and the analytic bytes-moved model
(:func:`repro.core.has.speculation_bytes_moved`), and writes the
``BENCH_speculate.json`` artifact so the perf trajectory has a recorded
baseline.  The sweep asserts the dispatch model: one ``speculate_batch``
call is ONE dispatch regardless of B, vs the O(B) launches of per-query
serving.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FAST, get_service, row
from repro.retrieval.flat import chunked_flat_search


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _sweep_state(cfg, n_corpus, rng):
    """A fully-warmed HasState + IVF index over a random unit corpus."""
    from repro.core.has import HasState
    from repro.retrieval.ivf import build_ivf

    corpus = rng.normal(size=(n_corpus, cfg.d)).astype(np.float32)
    corpus /= np.linalg.norm(corpus, axis=1, keepdims=True)
    corpus = jnp.asarray(corpus)
    index = build_ivf(corpus, cfg.n_buckets, seed=0)
    # saturated cache: every doc slot and query row live
    doc_ids = rng.permutation(n_corpus)[:cfg.doc_cap].astype(np.int32)
    qids = rng.integers(0, n_corpus, (cfg.h_max, cfg.k)).astype(np.int32)
    state = HasState(
        query_emb=jnp.asarray(
            rng.normal(size=(cfg.h_max, cfg.d)).astype(np.float32)),
        query_doc_ids=jnp.asarray(qids),
        query_valid=jnp.ones((cfg.h_max,), bool),
        q_ptr=jnp.asarray(cfg.h_max, jnp.int32),
        doc_emb=corpus[jnp.asarray(doc_ids)],
        doc_ids=jnp.asarray(doc_ids),
        d_ptr=jnp.asarray(cfg.doc_cap, jnp.int32))
    return state, index, corpus


def sweep_backends(out_path: str = "BENCH_speculate.json",
                   batches=(1, 8, 32), reps: int = 5):
    """Backend × batch-size sweep of ``speculate_batch`` -> CSV rows + JSON.

    Asserts the acceptance dispatch model: for B >= 32 the batch-native
    path issues <= 3 device dispatches per speculation batch (it issues
    exactly 1), where the legacy per-query loop issues B.
    """
    from repro.core import dispatch
    from repro.core.has import (HasConfig, speculate, speculate_batch,
                                speculation_bytes_moved)

    rng = np.random.default_rng(0)
    n_corpus = 20_000 if FAST else 50_000
    cfg = HasConfig(k=10, tau=0.2, h_max=1024 if FAST else 2048,
                    doc_capacity=4096 if FAST else 8192,
                    nprobe=4, n_buckets=128 if FAST else 256, d=64)
    state, index, _ = _sweep_state(cfg, n_corpus, rng)
    interpret = jax.default_backend() != "tpu"
    backends = ["xla", "pallas"]

    # legacy per-query path: O(B) dispatches under host iteration —
    # backend-independent, so measured once per batch size
    legacy = {}
    for b in batches:
        q = jnp.asarray(rng.normal(size=(b, cfg.d)), jnp.float32)
        jax.block_until_ready(speculate(cfg, state, index, q[0]))  # compile
        with dispatch.capture() as legacy_probe:
            for i in range(b):
                jax.block_until_ready(speculate(cfg, state, index, q[i]))
        legacy[b] = legacy_probe.total()

    rows, records = [], []
    verdict_ok = True
    for backend in backends:
        for b in batches:
            q = jnp.asarray(rng.normal(size=(b, cfg.d)), jnp.float32)
            # compile, then measure; one capture verifies the dispatch count
            jax.block_until_ready(
                speculate_batch(cfg, state, index, q, backend=backend))
            with dispatch.capture() as probe:
                jax.block_until_ready(
                    speculate_batch(cfg, state, index, q, backend=backend))
            dispatches = probe.total()
            times = []
            for _ in range(reps):
                t0 = time.perf_counter()
                out = speculate_batch(cfg, state, index, q, backend=backend)
                jax.block_until_ready(out)
                times.append(time.perf_counter() - t0)
            p50 = float(np.percentile(times, 50))
            p95 = float(np.percentile(times, 95))
            legacy_dispatches = legacy[b]
            rec = {
                "backend": backend, "batch": b, "interpret": bool(interpret),
                "p50_step_s": p50, "p95_step_s": p95,
                "dispatches_per_batch": dispatches,
                "legacy_dispatches_per_batch": legacy_dispatches,
                "bytes_moved_est": speculation_bytes_moved(
                    cfg, index.n_buckets, index.capacity, b, backend),
            }
            records.append(rec)
            rows.append(row(
                f"roofline/speculate_batch/{backend}/B={b}", p50,
                f"p95={p95 * 1e6:.1f}us;dispatches={dispatches};"
                f"legacy_dispatches={legacy_dispatches};"
                f"bytes={rec['bytes_moved_est']:.3e}"))
            if b >= 32 and dispatches > 3:
                verdict_ok = False

    rows.append(row(
        "roofline/speculate_dispatch_verdict", 0.0,
        f"{'PASS' if verdict_ok else 'FAIL'}"
        f"(batch-native<=3 dispatches at B>=32, legacy=O(B))"))
    # persist the artifact BEFORE asserting, so a failing verdict still
    # leaves the sweep data on disk to diagnose
    with open(out_path, "w") as f:
        json.dump({"config": {"n_corpus": n_corpus, "k": cfg.k,
                              "h_max": cfg.h_max, "doc_cap": cfg.doc_cap,
                              "nprobe": cfg.nprobe,
                              "n_buckets": index.n_buckets,
                              "backend_default_interpret": bool(interpret)},
                   "sweep": records}, f, indent=1)
    print(f"# wrote {out_path} ({len(records)} sweep points)")
    assert verdict_ok, "batch-native speculation exceeded 3 dispatches/batch"
    return rows


def run():
    rows = []
    svc = get_service()
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 64)), jnp.float32)

    for n in (10_000, 50_000, svc.world.cfg.n_docs):
        corpus = svc.corpus[:n]
        fn = jax.jit(lambda c, qq: chunked_flat_search(c, qq, 10, 8192))
        t = _time(fn, corpus, q)
        lowered = fn.lower(corpus, q)
        cost = lowered.compile().cost_analysis()
        rows.append(row(
            f"roofline/flat_scan/n={n}", t,
            f"flops={cost.get('flops', 0):.3e};"
            f"bytes={cost.get('bytes accessed', 0):.3e};"
            f"GB/s={n * 64 * 4 / t / 1e9:.2f}"))

    # paper-scale extrapolation (Fig 1's point: retrieval >> bare-LLM TTFT)
    full_t = svc.latency.full_scan_time()
    rows.append(row("roofline/full_db_49.2M_extrapolated", full_t,
                    f"vs_bare_llm_ttft~0.1s_x{full_t / 0.1:.1f}"))

    # HaS fast path budget: cache scan + validation at paper scale
    from repro.core.has import HasConfig, init_has_state, speculate
    from repro.retrieval.ivf import build_ivf
    cfg = HasConfig(k=10, tau=0.2, h_max=5000, nprobe=16, n_buckets=512,
                    d=64)
    state = init_has_state(cfg)
    index = build_ivf(svc.corpus[:50_000], 512, seed=0)
    qv = q[0]
    speculate(cfg, state, index, qv)  # compile
    t0 = time.perf_counter()
    for _ in range(10):
        out = speculate(cfg, state, index, qv)
    jax.block_until_ready(out)
    t_spec = (time.perf_counter() - t0) / 10
    rows.append(row("roofline/has_fast_path", t_spec,
                    f"doc_store={cfg.doc_cap};H={cfg.h_max}"))

    # backend × batch-size sweep of the batch-native speculation pipeline
    rows.extend(sweep_backends())
    return rows


if __name__ == "__main__":
    import sys

    from benchmarks.common import fmt_rows
    if "--sweep-backend" in sys.argv:
        print(fmt_rows(sweep_backends()))
    else:
        print(fmt_rows(run()))
