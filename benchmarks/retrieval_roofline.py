"""Fig 1 analogue + kernel accounting: retrieval cost vs generation TTFT.

Measures the real (CPU) wall time of the jitted retrieval substrate at
several corpus scales, derives the paper-scale latency via the calibrated
bandwidth model, and reports HLO flops/bytes of the retrieval step (the
per-kernel roofline terms used in EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FAST, get_service, row
from repro.retrieval.flat import chunked_flat_search


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run():
    rows = []
    svc = get_service()
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 64)), jnp.float32)

    for n in (10_000, 50_000, svc.world.cfg.n_docs):
        corpus = svc.corpus[:n]
        fn = jax.jit(lambda c, qq: chunked_flat_search(c, qq, 10, 8192))
        t = _time(fn, corpus, q)
        lowered = fn.lower(corpus, q)
        cost = lowered.compile().cost_analysis()
        rows.append(row(
            f"roofline/flat_scan/n={n}", t,
            f"flops={cost.get('flops', 0):.3e};"
            f"bytes={cost.get('bytes accessed', 0):.3e};"
            f"GB/s={n * 64 * 4 / t / 1e9:.2f}"))

    # paper-scale extrapolation (Fig 1's point: retrieval >> bare-LLM TTFT)
    full_t = svc.latency.full_scan_time()
    rows.append(row("roofline/full_db_49.2M_extrapolated", full_t,
                    f"vs_bare_llm_ttft~0.1s_x{full_t / 0.1:.1f}"))

    # HaS fast path budget: cache scan + validation at paper scale
    from repro.core.has import HasConfig, init_has_state, speculate
    from repro.retrieval.ivf import build_ivf
    cfg = HasConfig(k=10, tau=0.2, h_max=5000, nprobe=16, n_buckets=512,
                    d=64)
    state = init_has_state(cfg)
    index = build_ivf(svc.corpus[:50_000], 512, seed=0)
    qv = q[0]
    speculate(cfg, state, index, qv)  # compile
    t0 = time.perf_counter()
    for _ in range(10):
        out = speculate(cfg, state, index, qv)
    jax.block_until_ready(out)
    t_spec = (time.perf_counter() - t0) / 10
    rows.append(row("roofline/has_fast_path", t_spec,
                    f"doc_store={cfg.doc_cap};H={cfg.h_max}"))
    return rows
