"""Table II: HaS vs ANNS methods at edge (♠) and cloud (♦) scopes."""
from __future__ import annotations

from benchmarks.common import (N_BUCKETS, get_queries, get_service,
                               has_config, row)
from repro.serving.engine import ANNSEngine, FullRetrievalEngine, HasEngine


def run():
    rows = []
    for dataset in ("granola", "popqa"):
        svc = get_service()
        qs = list(get_queries(dataset))

        # ♠: tiny-scope ANNS on the edge, replacing HaS (no validation)
        for method in ("ivf", "scann"):
            eng = ANNSEngine(svc, method, n_buckets=N_BUCKETS,
                             nprobe=max(2, N_BUCKETS // 16), on_edge=True)
            s = eng.serve(qs, dataset=dataset).summary()
            rows.append(row(f"t2/{dataset}/{method}_edge",
                            s["avg_latency_s"], round(s["ra_qwen3-8b"], 4)))

        has = HasEngine(svc, has_config())
        s_has = has.serve(qs, dataset=dataset).summary()
        rows.append(row(f"t2/{dataset}/HaS", s_has["avg_latency_s"],
                        round(s_has["ra_qwen3-8b"], 4)))

        # ♦: optimized-scope ANNS replacing the cloud full retrieval,
        # alone and as HaS's fallback
        for method in ("ivf", "scann"):
            nprobe_c = max(8, N_BUCKETS // 3)
            cloud = ANNSEngine(svc, method, n_buckets=N_BUCKETS,
                               nprobe=nprobe_c, on_edge=False)
            s = cloud.serve(qs, dataset=dataset).summary()
            rows.append(row(f"t2/{dataset}/{method}_cloud",
                            s["avg_latency_s"], round(s["ra_qwen3-8b"], 4)))
            combo = HasEngine(svc, has_config(), fallback=ANNSEngine(
                svc, method, n_buckets=N_BUCKETS,
                nprobe=nprobe_c, on_edge=False))
            sc = combo.serve(qs, dataset=dataset).summary()
            delta = (sc["avg_latency_s"] - s["avg_latency_s"]) \
                / s["avg_latency_s"]
            rows.append(row(f"t2/{dataset}/HaS+{method}_cloud",
                            sc["avg_latency_s"],
                            f"ra={sc['ra_qwen3-8b']:.4f};dLat={delta:+.2%}"))
    return rows
