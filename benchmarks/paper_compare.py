"""Automatic paper-vs-reproduction check over results/benchmarks.json.

Each entry pins a number from the paper (table, metric) against the
benchmark row that reproduces it, with a tolerance band and a direction
('sign' entries only check the direction of the effect — the synthetic
world reproduces mechanisms, not third-digit point estimates).

  PYTHONPATH=src python -m benchmarks.paper_compare
"""
from __future__ import annotations

import json
import re
import sys

# (row name, field-in-derived|None=us_per_call, paper value, mode, tolerance)
# mode: 'abs' |value-paper|<=tol ; 'rel' within tol fraction; 'sign' same sign
CHECKS = [
    # Table III / IV — the headline numbers
    ("t3/granola/full", None, 1.3845e6, "rel", 0.05),
    ("t3/granola/HaS", "dLat", -0.2374, "sign", None),
    ("t3/popqa/HaS", "dLat", -0.3699, "sign", None),
    ("t4/granola/HaS", "car", 0.8877, "abs", 0.06),
    ("t4/granola/HaS", "l@da", 0.0555, "abs", 0.03),
    ("t4/granola/HaS", "l@dr", 1.4896, "abs", 0.15),
    ("t4/granola/crag", "dar", 0.422, "abs", 0.10),
    ("t3/granola/crag", "dLat", +0.0976, "sign", None),
    ("t3/popqa/crag", "dLat", +0.3133, "sign", None),
    ("t4/granola/crag", "l@da", 0.7006, "abs", 0.08),
    ("t4/granola/crag", "l@dr", 2.1168, "abs", 0.10),
    # reuse methods: modest negative deltas (sign + loose band)
    ("t3/granola/proximity", "dLat", -0.0476, "abs", 0.06),
    ("t3/granola/saferadius", "dLat", -0.0705, "abs", 0.06),
    ("t3/granola/mincache", "dLat", -0.0578, "abs", 0.12),
    # Table II: HaS on top of cloud ANNS keeps improving latency
    ("t2/granola/HaS+ivf_cloud", "dLat", -0.1524, "sign", None),
    ("t2/popqa/HaS+ivf_cloud", "dLat", -0.2873, "sign", None),
    ("t2/granola/HaS+scann_cloud", "dLat", -0.0755, "sign", None),
    # Table VII: compression collapse at tau=0.2 and recovery at tau=0.6
    ("t7/frac=0.01/tau=0.2", "dar", 0.6738, "sign-high", 0.5),
    ("t7/frac=0.01/tau=0.6", "dar", 0.2571, "sign-low", 0.5),
    # Fig 13: agentic latency cut
    ("fig13/auto-rag/HaS", "dLat", -0.694, "sign", None),
]


def _field(row, field):
    if field is None:
        return row["us_per_call"]
    d = str(row["derived"])
    m = re.search(rf"{re.escape(field)}=([+-]?[0-9.]+)%?", d)
    if not m:
        return None
    v = float(m.group(1))
    if f"{field}=" in d and "%" in d.split(f"{field}=")[1][:12]:
        v /= 100.0
    return v


def compare(rows) -> list[dict]:
    by_name = {}
    for r in rows:
        by_name[r["name"]] = r
    out = []
    for name, field, paper, mode, tol in CHECKS:
        row = by_name.get(name)
        rec = {"check": f"{name}:{field or 'latency'}", "paper": paper,
               "ours": None, "status": "MISSING"}
        if row is not None:
            v = _field(row, field)
            rec["ours"] = v
            if v is None:
                rec["status"] = "NOFIELD"
            elif mode == "abs":
                rec["status"] = "OK" if abs(v - paper) <= tol else "DELTA"
            elif mode == "rel":
                rec["status"] = "OK" if abs(v - paper) <= tol * abs(paper) \
                    else "DELTA"
            elif mode == "sign":
                rec["status"] = "OK" if (v < 0) == (paper < 0) else "FLIP"
            elif mode == "sign-high":   # reproduces 'degenerately high'
                rec["status"] = "OK" if v >= paper - tol else "DELTA"
            elif mode == "sign-low":    # reproduces 'restored low'
                rec["status"] = "OK" if v <= paper + tol else "DELTA"
        out.append(rec)
    return out


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "results/benchmarks.json"
    rows = json.load(open(path))
    results = compare(rows)
    n_ok = sum(r["status"] == "OK" for r in results)
    print(f"{'check':42s} {'paper':>10s} {'ours':>10s}  status")
    for r in results:
        ours = f"{r['ours']:.4f}" if isinstance(r["ours"], float) else "-"
        print(f"{r['check']:42s} {r['paper']:10.4f} {ours:>10s}  "
              f"{r['status']}")
    print(f"\n{n_ok}/{len(results)} paper checks OK")


if __name__ == "__main__":
    main()
