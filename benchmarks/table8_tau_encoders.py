"""Table VIII: tau sweep x encoder robustness."""
from __future__ import annotations

from benchmarks.common import get_queries, get_service, has_config, row
from repro.serving.engine import FullRetrievalEngine, HasEngine


def run():
    rows = []
    for encoder in ("contriever", "bge-large", "e5-base"):
        svc = get_service(encoder)
        qs = list(get_queries("granola", encoder=encoder))
        base = FullRetrievalEngine(svc).serve(qs[:1000]).summary()
        rows.append(row(f"t8/{encoder}/full", base["avg_latency_s"],
                        round(base["ra_qwen3-8b"], 4)))
        for tau in (0.1, 0.2, 0.3):
            eng = HasEngine(svc, has_config(tau=tau))
            s = eng.serve(qs, dataset="granola").summary()
            rows.append(row(f"t8/{encoder}/tau={tau}", s["avg_latency_s"],
                            round(s["ra_qwen3-8b"], 4)))
    return rows
