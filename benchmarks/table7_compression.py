"""Table VII: fuzzy-channel compression (subset %) x threshold tau."""
from __future__ import annotations

from benchmarks.common import get_queries, get_service, has_config, row
from repro.serving.engine import HasEngine


def run():
    rows = []
    svc = get_service()
    qs = list(get_queries("granola"))
    # fixed tau across compression levels
    for frac in (0.01, 0.1, 0.5, 1.0):
        eng = HasEngine(svc, has_config(), fuzzy_fraction=frac)
        s = eng.serve(qs, dataset="granola").summary()
        rows.append(row(f"t7/frac={frac}/tau=0.2", s["avg_latency_s"],
                        f"ra={s['ra_qwen3-8b']:.4f};dar={s['dar']:.4f};"
                        f"ra@da={s['ra_at_da']:.4f}"))
    # tuned tau restores accuracy under compression
    for frac, tau in ((0.01, 0.6), (0.1, 0.4), (0.5, 0.3), (1.0, 0.2)):
        eng = HasEngine(svc, has_config(tau=tau), fuzzy_fraction=frac)
        s = eng.serve(qs, dataset="granola").summary()
        rows.append(row(f"t7/frac={frac}/tau={tau}", s["avg_latency_s"],
                        f"ra={s['ra_qwen3-8b']:.4f};dar={s['dar']:.4f};"
                        f"ra@da={s['ra_at_da']:.4f}"))
    return rows
