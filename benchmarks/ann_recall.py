"""ANN cloud-stage calibration: recall/latency grid + end-to-end doc-hit.

The IVF backend (retrieval/service.py::IVFBackend) trades exactness for a
~nprobe/n_clusters fraction of the scan.  This benchmark calibrates that
trade on two axes and writes ``BENCH_ann.json``:

1. **Kernel grid** — recall@k vs the exact flat scan over an
   nprobe x corpus-size grid on a TOPIC-CLUSTERED synthetic corpus
   (docs = unit prototype + Gaussian noise, queries = perturbed docs —
   the regime IVF partitions are built for, and representative of real
   embedding corpora; the generator parameters are recorded in the JSON).
   The *calibrated default nprobe* is the smallest grid value whose f32
   recall@k >= ``RECALL_FLOOR`` at the largest corpus.
2. **End-to-end** — the continuous-batching scheduler served twice on the
   REAL SyntheticWorld trace (flat cloud stage vs IVF cloud stage): the
   verdict metric is doc-hit, because approximate cloud results feed the
   HaS cache and recall loss COMPOUNDS through later accepts (the
   scheduler docstring caveat).  The e2e nprobe starts at the kernel
   default and doubles until doc-hit is within ``E2E_DOCHIT_TOL``.

Verdicts (written to ``BENCH_ann.json``):

``speedup_at_recall``
    At the >= 1M-doc corpus (262k under BENCH_FAST), the IVF backend's
    measured per-dispatch search latency is >= ``SPEEDUP_FLOOR`` x faster
    than the flat scan while f32 recall@k >= ``RECALL_FLOOR`` at the
    calibrated default nprobe.
``e2e_dochit``
    Scheduler doc-hit with the IVF cloud stage is within
    ``E2E_DOCHIT_TOL`` of the flat backend on the same trace.
``int8_residency``
    The compressed bucket store (int8 centroid-residual codes + two
    per-half scales) fits >= ``RESIDENCY_FLOOR`` x the f32 store's vectors
    at fixed host bytes (measured from actual array nbytes: 4d/(d+8) =
    3.56x at d=64), with recall drop vs the f32 index <=
    ``INT8_RECALL_DROP`` at the calibrated default nprobe.

Run standalone:  PYTHONPATH=src python -m benchmarks.ann_recall
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FAST, get_queries, get_service, has_config, row
from repro.retrieval.flat import chunked_flat_search
from repro.retrieval.ivf import build_ivf_streaming, ivf_search
from repro.retrieval.service import IVFBackend
from repro.serving.engine import RetrievalService
from repro.serving.latency import LatencyModel
from repro.serving.scheduler import ContinuousBatchingScheduler, SchedulerConfig

RECALL_FLOOR = 0.95        # kernel recall@k at the calibrated default nprobe
SPEEDUP_FLOOR = 5.0        # IVF vs flat measured per-dispatch latency
E2E_DOCHIT_TOL = 0.02      # scheduler doc-hit gap vs the flat backend
RESIDENCY_FLOOR = 3.0      # int8 store packs >= 3x the vectors per byte
INT8_RECALL_DROP = 0.01    # int8 vs f32 recall at the default nprobe

D = 64
K = 10
N_EVAL_Q = 64 if FAST else 128
CORPUS_SIZES = [32_768, 262_144] if FAST else [262_144, 1_048_576]
N_CLUSTERS = {32_768: 256, 262_144: 1024, 1_048_576: 1024}
NPROBES = [4, 8, 16, 32, 64]
#: clustered-corpus generator (recorded in the JSON): docs = prototype +
#: CLUSTER_NOISE * N(0,1) per coordinate, renormalized; queries = doc +
#: QUERY_NOISE * N(0,1).  At d=64 the relative perturbation norms are
#: ~sqrt(d) x these (1.2 / 0.48) — tuned so recall@k varies across the
#: nprobe grid instead of saturating at either end.  PROTO_FRACTION keeps
#: topic clusters SMALLER than an IVF bucket at the default 1024-centroid
#: build (1M docs -> 512 prototypes, ~2 centroids per cluster): with
#: clusters larger than buckets, whole-cluster assignment overflows the
#: 2x capacity and TRUNCATES docs that no nprobe can then recover.
PROTO_FRACTION = 1 / 2048  # prototypes per corpus row
CLUSTER_NOISE = 0.15
QUERY_NOISE = 0.06
E2E_QUERIES = 600 if FAST else 1200
E2E_CLUSTERS = 256 if FAST else 512


def _clustered_corpus(n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    n_protos = max(64, int(n * PROTO_FRACTION))
    protos = rng.normal(size=(n_protos, D)).astype(np.float32)
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)
    out = np.empty((n, D), np.float32)
    for lo in range(0, n, 131072):
        hi = min(n, lo + 131072)
        x = protos[rng.integers(0, n_protos, hi - lo)] \
            + CLUSTER_NOISE * rng.normal(size=(hi - lo, D)).astype(np.float32)
        out[lo:hi] = x / np.linalg.norm(x, axis=1, keepdims=True)
    return out


def _eval_queries(corpus: np.ndarray, n_q: int, seed: int) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    q = corpus[rng.integers(0, len(corpus), n_q)] \
        + QUERY_NOISE * rng.normal(size=(n_q, D)).astype(np.float32)
    return jnp.asarray(q / np.linalg.norm(q, axis=1, keepdims=True))


def _recall(index, queries, exact_ids, nprobe: int) -> float:
    """Mean |ivf top-k ∩ exact top-k| / k, one query (one dispatch) at a
    time — the [1, nprobe, cap, d] gather stays small, matching the
    backend's per-dispatch shape."""
    hits = 0
    for i in range(queries.shape[0]):
        ids = np.asarray(ivf_search(index, queries[i:i + 1],
                                    nprobe=nprobe, k=K)[1])[0]
        hits += len(set(ids.tolist()) & set(exact_ids[i].tolist()))
    return hits / (queries.shape[0] * K)


def _median_time(fn, reps: int = 7) -> float:
    fn()                                      # warm the jit cache
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.tree.map(lambda a: a.block_until_ready(), fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(out_path: str = "BENCH_ann.json"):
    rows = []
    flat = jax.jit(chunked_flat_search, static_argnames=("k", "chunk"))

    # ---- kernel grid: recall@k over nprobe x corpus size -----------------
    grid = {}
    timing = {}
    indexes = {}
    for n in CORPUS_SIZES:
        corpus = _clustered_corpus(n, seed=0)
        cj = jnp.asarray(corpus)
        q = _eval_queries(corpus, N_EVAL_Q, seed=1)
        exact_ids = np.asarray(flat(cj, q, K, 131072)[1])
        c = N_CLUSTERS[n]
        t0 = time.time()
        f32 = build_ivf_streaming(corpus, c, seed=0)
        t_build = time.time() - t0
        i8 = build_ivf_streaming(corpus, c, seed=0, compressed=True)
        indexes[n] = (f32, i8, cj, q, exact_ids)
        for nprobe in NPROBES:
            if nprobe > f32.n_buckets:
                continue
            r32 = _recall(f32, q, exact_ids, nprobe)
            r8 = _recall(i8, q, exact_ids, nprobe)
            grid[(n, nprobe)] = (r32, r8)
            rows.append(row(
                f"ann/recall_n{n}_np{nprobe}", 0.0,
                f"f32={r32:.4f};int8={r8:.4f};clusters={c}"))
        rows.append(row(f"ann/build_n{n}", t_build * 1e6 / n,
                        f"build={t_build:.1f}s;cap={f32.capacity}"))

    # ---- calibrate: smallest nprobe clearing the recall floor ------------
    n_big = CORPUS_SIZES[-1]
    default_nprobe = None
    for nprobe in NPROBES:
        if grid.get((n_big, nprobe), (0, 0))[0] >= RECALL_FLOOR:
            default_nprobe = nprobe
            break
    if default_nprobe is None:            # never expected; report honestly
        default_nprobe = NPROBES[-1]
    r32_def, r8_def = grid[(n_big, default_nprobe)]
    rows.append(row("ann/calibrated_nprobe", 0.0,
                    f"nprobe={default_nprobe};recall={r32_def:.4f}"))

    # ---- measured per-dispatch latency at the largest corpus -------------
    f32, i8, cj, q, _ = indexes[n_big]
    q1 = q[:1]
    t_flat = _median_time(lambda: flat(cj, q1, K, 131072))
    t_ivf = _median_time(
        lambda: ivf_search(f32, q1, nprobe=default_nprobe, k=K))
    t_ivf8 = _median_time(
        lambda: ivf_search(i8, q1, nprobe=default_nprobe, k=K))
    speedup = t_flat / t_ivf
    # the analytic model the scheduler charges (at the paper's 49.2M scale)
    lat = LatencyModel()
    model_f32 = 1.0 / lat.ann_scale(N_CLUSTERS[n_big], default_nprobe)
    model_i8 = 1.0 / lat.ann_scale(N_CLUSTERS[n_big], default_nprobe,
                                   bytes_per_dim=1)
    timing = {"flat_ms": t_flat * 1e3, "ivf_f32_ms": t_ivf * 1e3,
              "ivf_int8_ms": t_ivf8 * 1e3, "measured_speedup": speedup,
              "modeled_speedup_f32": model_f32,
              "modeled_speedup_int8": model_i8}
    rows.append(row("ann/search_flat", t_flat, f"n={n_big}"))
    rows.append(row(
        "ann/search_ivf", t_ivf,
        f"np={default_nprobe};speedup={speedup:.1f}x;"
        f"modeled={model_f32:.1f}x"))

    # (a) speedup at the recall floor
    sp_ok = speedup >= SPEEDUP_FLOOR and r32_def >= RECALL_FLOOR
    rows.append(row(
        "ann/verdict_speedup_at_recall", 0.0,
        f"{'PASS' if sp_ok else 'FAIL'}"
        f"(speedup={speedup:.1f}x;floor={SPEEDUP_FLOOR}x;"
        f"recall={r32_def:.4f};n={n_big};np={default_nprobe})"))

    # (c) int8 residency: byte ratio + bounded recall drop
    f32_bytes = int(f32.bucket_vecs.nbytes)
    i8_bytes = int(i8.bucket_vecs.nbytes) + int(i8.bucket_scales.nbytes)
    ratio = f32_bytes / i8_bytes
    drop = r32_def - r8_def
    res_ok = ratio >= RESIDENCY_FLOOR and drop <= INT8_RECALL_DROP
    rows.append(row(
        "ann/verdict_int8_residency", 0.0,
        f"{'PASS' if res_ok else 'FAIL'}"
        f"(fit={ratio:.2f}x;floor={RESIDENCY_FLOOR}x;"
        f"recall_drop={drop:.4f};cap={INT8_RECALL_DROP})"))
    del indexes

    # ---- end-to-end: scheduler doc-hit, flat vs IVF cloud stage ----------
    base_svc = get_service()
    world = base_svc.world
    lat = LatencyModel()
    qs = list(get_queries("granola", n=E2E_QUERIES))
    cfg = has_config(h_max=min(600, E2E_QUERIES))
    kw = dict(max_spec_batch=32, full_batch=16, full_max_wait_s=0.05)
    s_flat = ContinuousBatchingScheduler(
        base_svc, cfg, SchedulerConfig(**kw)).serve(qs, None, seed=0).summary()
    corpus = jnp.asarray(world.doc_emb)
    e2e_nprobe, s_ann = default_nprobe, None
    while True:
        e2e_nprobe = min(e2e_nprobe, E2E_CLUSTERS)
        svc = RetrievalService(
            world, lat, k=base_svc.k, chunk=base_svc.chunk,
            backend=IVFBackend(corpus, base_svc.k, lat,
                               n_clusters=E2E_CLUSTERS, nprobe=e2e_nprobe,
                               compressed=True, seed=0))
        s_ann = ContinuousBatchingScheduler(
            svc, cfg, SchedulerConfig(**kw)).serve(qs, None, seed=0).summary()
        gap = s_flat["doc_hit_rate"] - s_ann["doc_hit_rate"]
        rows.append(row(
            f"ann/e2e_np{e2e_nprobe}", s_ann["avg_latency_s"],
            f"doc_hit={s_ann['doc_hit_rate']:.4f};"
            f"flat={s_flat['doc_hit_rate']:.4f};gap={gap:.4f};"
            f"dar={s_ann['dar']:.4f};qps={s_ann['throughput_qps']:.1f}"))
        if gap <= E2E_DOCHIT_TOL or e2e_nprobe >= E2E_CLUSTERS:
            break
        e2e_nprobe *= 2

    # (b) e2e doc-hit within tolerance of flat
    gap = s_flat["doc_hit_rate"] - s_ann["doc_hit_rate"]
    e2e_ok = gap <= E2E_DOCHIT_TOL
    rows.append(row(
        "ann/verdict_e2e_dochit", 0.0,
        f"{'PASS' if e2e_ok else 'FAIL'}"
        f"(gap={gap:.4f};tol={E2E_DOCHIT_TOL};np={e2e_nprobe};"
        f"clusters={E2E_CLUSTERS})"))

    with open(out_path, "w") as f:
        json.dump({
            "fast": FAST,
            "generator": {"proto_fraction": PROTO_FRACTION,
                          "cluster_noise": CLUSTER_NOISE,
                          "query_noise": QUERY_NOISE, "d": D, "k": K,
                          "n_eval_queries": N_EVAL_Q},
            "grid": [{"n": n, "nprobe": p, "clusters": N_CLUSTERS[n],
                      "recall_f32": r32, "recall_int8": r8}
                     for (n, p), (r32, r8) in sorted(grid.items())],
            "calibrated": {"default_nprobe": default_nprobe,
                           "recall_f32": r32_def, "recall_int8": r8_def,
                           "e2e_nprobe": e2e_nprobe},
            "timing": timing,
            "residency": {"f32_bucket_bytes": f32_bytes,
                          "int8_bucket_bytes": i8_bytes, "fit": ratio},
            "e2e": {"queries": E2E_QUERIES, "clusters": E2E_CLUSTERS,
                    "flat": s_flat, "ann": s_ann},
            "verdicts": {"speedup_at_recall": bool(sp_ok),
                         "e2e_dochit": bool(e2e_ok),
                         "int8_residency": bool(res_ok)},
        }, f, indent=2)
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    ap = argparse.ArgumentParser(
        description="ANN (IVF) backend recall/latency calibration; writes "
                    "BENCH_ann.json")
    ap.add_argument("--out", default="BENCH_ann.json")
    args = ap.parse_args()
    print(fmt_rows(run(out_path=args.out)))
