"""Chaos serving: deterministic fault injection + self-healing verdicts.

Drives the continuous-batching scheduler (serving/scheduler.py) through a
fault plan covering every kind in serving/faults.py — a straggling cloud
worker, a worker crash with downtime, a transient search-failure window,
an edge-replica crash mid-speculation, and dropped + duplicated
replication appends — all pinned to the virtual clock, so the whole chaos
run is a pure function of ``(seed, plan, arrivals, queries)`` and every
verdict is reproducible bit-for-bit.  Fault times scale with the
fault-free run's makespan, so the same scenario shape runs under
``BENCH_FAST=1``.

Verdicts (written to ``BENCH_chaos.json``):

``bounded_p99``
    Self-healing keeps the tail bounded: every request completes (zero
    ``failed``), and chaos p99 stays within ``P99_INFLATION_BOUND`` x the
    fault-free p99 — deadlines + hedging + bounded retry + requeue turn
    faults into a bounded latency tax instead of an unbounded stall.
``mttr_dar``
    Mid-stream replica recovery: after the edge-replica crash, the
    windowed draft-acceptance rate returns to the fault-free level (within
    ``DAR_TOL``) in at most ``MTTR_FRAC`` of the makespan — the crashed
    slot's in-flight batch reroutes to the full channel and the slot is
    rebuilt in the background, so acceptance degrades only transiently.
``no_dup_fold``
    Idempotent ingest: a dup-only fault plan (replication appends
    delivered twice) is BIT-IDENTICAL to the fault-free run — channels,
    completion times, served ids, and every edge-replica cache state —
    because ``ingest_key`` dedup drops the duplicate before it can fold.
``zero_cost_off``
    An EMPTY fault plan is free: on the pinned golden fixture
    (tests/test_edge_pool.py), the scheduler with ``FaultPlan()``
    reproduces the pre-PR golden trace hashes bit-exactly, Poisson and
    saturated — the fault machinery adds no heap events, draws no rng,
    and shifts no completion when it has nothing to inject.

Run standalone:  PYTHONPATH=src python -m benchmarks.sched_chaos
"""
from __future__ import annotations

import argparse
import hashlib
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (N_QUERIES, get_queries, get_service,
                               has_config, row)
from repro.core.has import HasConfig
from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
from repro.retrieval.service import ShardedMeshBackend
from repro.serving.engine import RetrievalService
from repro.serving.faults import FaultEvent, FaultPlan
from repro.serving.latency import LatencyModel
from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                     SchedulerConfig, poisson_arrivals)

#: chaos p99 may inflate at most this factor over fault-free p99
P99_INFLATION_BOUND = 4.0
#: windowed DAR must return within this tolerance of the fault-free level
DAR_TOL = 0.15
#: ... in at most this fraction of the fault-free makespan after the crash
MTTR_FRAC = 0.25


def _hashes(r):
    return (hashlib.md5(",".join(r.channels).encode()).hexdigest(),
            hashlib.md5(np.round(r.t_done, 9).tobytes()).hexdigest(),
            hashlib.md5(r.served_ids.tobytes()).hexdigest())


def _windowed_dar(r, t0: float, t1: float) -> float:
    """Acceptance rate over requests COMPLETING in [t0, t1) (NaN-safe)."""
    m = (r.t_done >= t0) & (r.t_done < t1)
    return float(r.accepts[m].mean()) if m.any() else float("nan")


def _pool_states_equal(a, b) -> bool:
    for la, lb in zip(jax.tree.leaves([p.states for p in (a,)]),
                      jax.tree.leaves([p.states for p in (b,)])):
        if not np.array_equal(np.asarray(la), np.asarray(lb)):
            return False
    return True


def run(out_path: str = "BENCH_chaos.json"):
    rows = []
    base_svc = get_service()
    world = base_svc.world
    lat = LatencyModel()
    corpus = jnp.asarray(world.doc_emb)
    svc = RetrievalService(
        world, lat, k=base_svc.k, chunk=base_svc.chunk,
        backend=ShardedMeshBackend(corpus, base_svc.k, lat, n_shards=4,
                                   n_workers=4))
    n = min(N_QUERIES, 1200)
    qs = list(get_queries("granola", n=n))
    cfg = has_config()
    # retry budget provisioned to ride out the search-failure window: 3
    # retries at 0.3s exponential backoff span ~2.1s of cumulative wait,
    # so the last attempt of a batch that first failed early in the
    # 0.10 x makespan window lands after it closes (the knobs launch/
    # serve.py exposes as --retry-max / backoff)
    kw = dict(max_spec_batch=32, full_batch=16, full_max_wait_s=0.05,
              edge_replicas=3, retry_max=3, retry_backoff_s=0.3)
    # moderate open-loop load: busy enough that faults queue work behind
    # them, below saturation so recovery is visible in the window
    base = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(**kw))
    edge_rate = base.sched.max_spec_batch / base._spec_time(
        base.sched.max_spec_batch)
    arrivals = poisson_arrivals(n, qps=0.8 * edge_rate, seed=11)

    def sched_for(plan=None):
        return ContinuousBatchingScheduler(
            svc, cfg, SchedulerConfig(
                **kw, **({} if plan is None else {"fault_plan": plan})),
            index=base.index)

    # ---- fault-free reference --------------------------------------------
    r_ff = base.serve(qs, arrivals, seed=0)
    s_ff = r_ff.summary()
    M = s_ff["makespan_s"]
    rows.append(row(
        "chaos/fault_free", s_ff["avg_latency_s"],
        f"p99={s_ff['p99_latency_s']:.3f}s;dar={s_ff['dar']:.4f};"
        f"makespan={M:.1f}s"))

    # ---- the chaos plan: every fault kind, timed off the makespan --------
    t_crash = round(0.50 * M, 6)             # edge-replica crash
    plan = FaultPlan(events=(
        FaultEvent(t=round(0.15 * M, 6), kind="straggler", target=1,
                   duration_s=round(0.20 * M, 6), factor=6.0),
        FaultEvent(t=round(0.25 * M, 6), kind="worker_crash", target=0,
                   down_s=round(0.15 * M, 6)),
        FaultEvent(t=round(0.30 * M, 6), kind="delta_drop", count=2),
        FaultEvent(t=round(0.40 * M, 6), kind="search_fail", target=2,
                   duration_s=round(0.10 * M, 6)),
        FaultEvent(t=t_crash, kind="replica_crash", target=1),
        FaultEvent(t=round(0.60 * M, 6), kind="delta_dup", count=2),
    ))
    r_ch = sched_for(plan).serve(qs, arrivals, seed=0)
    s_ch = r_ch.summary()
    tr = r_ch.trace
    rows.append(row(
        "chaos/full_plan", s_ch["avg_latency_s"],
        f"p99={s_ch['p99_latency_s']:.3f}s;dar={s_ch['dar']:.4f};"
        f"retries={s_ch['retries']};hedges={s_ch['hedges']};"
        f"deaths={s_ch['worker_deaths']};"
        f"rebuilds={s_ch['replica_rebuilds']};failed={s_ch['failed']};"
        f"lost={tr.spans['lost'].sum():.2f}s;"
        f"backoff={tr.spans['retry_backoff'].sum():.2f}s"))

    # every recovery path conserves spans exactly (hard invariant — a
    # violated conservation residual means the accounting lost time)
    res = float(np.abs(tr.conservation_residual()).max())
    assert res < 1e-9, f"span conservation violated under chaos: {res}"

    # (a) bounded p99 inflation + nothing permanently failed
    p99_bound = P99_INFLATION_BOUND * s_ff["p99_latency_s"]
    p99_ok = (s_ch["failed"] == 0
              and len(r_ch.t_done) == n
              and s_ch["p99_latency_s"] <= p99_bound
              and s_ch["worker_deaths"] == 1
              and s_ch["replica_rebuilds"] >= 1)
    rows.append(row(
        "chaos/verdict_bounded_p99", 0.0,
        f"{'PASS' if p99_ok else 'FAIL'}"
        f"(p99={s_ch['p99_latency_s']:.3f}s;bound={p99_bound:.3f}s;"
        f"failed={s_ch['failed']})"))

    # (b) MTTR: windowed DAR back at the fault-free level within the bound
    w = max(0.10 * M, 1e-6)
    mttr_bound = MTTR_FRAC * M
    dar_ref = _windowed_dar(r_ff, t_crash, M + 1.0)
    mttr = float("inf")
    t = t_crash
    while t < float(r_ch.t_done.max()):
        d = _windowed_dar(r_ch, t, t + w)
        if np.isfinite(d) and d >= dar_ref - DAR_TOL:
            mttr = t - t_crash
            break
        t += w / 4
    mttr_ok = mttr <= mttr_bound
    rows.append(row(
        "chaos/verdict_mttr_dar", 0.0,
        f"{'PASS' if mttr_ok else 'FAIL'}"
        f"(mttr={mttr:.2f}s;bound={mttr_bound:.2f}s;"
        f"dar_ref={dar_ref:.4f};window={w:.2f}s)"))

    # (c) duplicated replication appends fold exactly once: the dup-only
    # run IS the fault-free run, bit-exactly, down to the replica caches
    dup_plan = FaultPlan(events=(
        FaultEvent(t=round(0.2 * M, 6), kind="delta_dup", count=3),))
    base2 = sched_for()                      # fresh pool for the reference
    r_ref = base2.serve(qs, arrivals, seed=0)
    dup = sched_for(dup_plan)
    r_dup = dup.serve(qs, arrivals, seed=0)
    dup_ok = (_hashes(r_dup) == _hashes(r_ref)
              and _pool_states_equal(dup.edge_pool, base2.edge_pool))
    rows.append(row(
        "chaos/verdict_no_dup_fold", 0.0,
        f"{'PASS' if dup_ok else 'FAIL'}"
        f"(schedule={'==' if _hashes(r_dup) == _hashes(r_ref) else '!='};"
        f"states={'==' if dup_ok else '?'})"))

    # (d) zero-cost when off: empty plan == pre-PR goldens on the pinned
    # fixture (small and FIXED — independent of BENCH_FAST, matching
    # tests/test_edge_pool.py::_GOLDEN_*_CHARGED)
    golden_poisson = ("ee529472ed19175fb3b357b75a2348a1",
                      "ce77d205b924b6639b8b0e61f3e6f769",
                      "bde019df4c7b6738d1b80507a91574ce")
    golden_saturated = ("818904a0aba858b52dc05f954ac76e94",
                        "58946f966a201cd50552d6eb2613e47d",
                        "3806ef068db5ea2db34da56effc252bd")
    gworld = SyntheticWorld(WorldConfig(n_entities=400, seed=0))
    gsvc = RetrievalService(gworld, LatencyModel(), k=10, chunk=2048)
    ds = DATASETS["granola"]
    gqs = gworld.sample_queries(160, pattern=ds["pattern"],
                                zipf_a=ds["zipf_a"],
                                p_uncovered=ds["p_uncovered"], seed=1)
    gcfg = HasConfig(k=10, tau=0.2, h_max=400, nprobe=4, n_buckets=256,
                     d=64)
    gsched = ContinuousBatchingScheduler(gsvc, gcfg, SchedulerConfig(
        max_spec_batch=16, full_batch=8, full_max_wait_s=0.1,
        fault_plan=FaultPlan()))
    garr = poisson_arrivals(160, qps=30.0, seed=5)
    h_poi = _hashes(gsched.serve(gqs, garr, seed=3))
    h_sat = _hashes(gsched.serve(gqs, None, seed=3))
    zero_ok = h_poi == golden_poisson and h_sat == golden_saturated
    rows.append(row(
        "chaos/verdict_zero_cost_off", 0.0,
        f"{'PASS' if zero_ok else 'FAIL'}"
        f"(poisson={'==' if h_poi == golden_poisson else '!='}golden;"
        f"saturated={'==' if h_sat == golden_saturated else '!='}golden)"))

    with open(out_path, "w") as f:
        json.dump({
            "n_queries": n,
            "arrival_qps": 0.8 * edge_rate,
            "fault_free": s_ff,
            "chaos": s_ch,
            "plan": [vars(e) | {"kind": e.kind} for e in plan.events],
            "p99_bound_s": p99_bound,
            "mttr_s": None if not np.isfinite(mttr) else mttr,
            "mttr_bound_s": mttr_bound,
            "lost_s": float(tr.spans["lost"].sum()),
            "retry_backoff_s": float(tr.spans["retry_backoff"].sum()),
            "verdicts": {"bounded_p99": bool(p99_ok),
                         "mttr_dar": bool(mttr_ok),
                         "no_dup_fold": bool(dup_ok),
                         "zero_cost_off": bool(zero_ok)},
        }, f, indent=2)
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    ap = argparse.ArgumentParser(
        description="Deterministic chaos serving benchmark: fault "
                    "injection + self-healing verdicts; writes "
                    "BENCH_chaos.json")
    ap.add_argument("--out", default="BENCH_chaos.json")
    args = ap.parse_args()
    print(fmt_rows(run(out_path=args.out)))
