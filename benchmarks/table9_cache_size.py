"""Table IX: cache size H_max vs efficiency (+ memory footprint)."""
from __future__ import annotations

from benchmarks.common import FAST, get_queries, get_service, has_config, row
from repro.core.has import cache_memory_bytes
from repro.serving.engine import HasEngine


def run():
    rows = []
    svc = get_service()
    qs = list(get_queries("granola"))
    sizes = (400, 600, 800, 1200) if FAST else (2000, 3000, 4000, 5000)
    for h in sizes:
        cfg = has_config(h_max=h)
        s = HasEngine(svc, cfg).serve(qs, dataset="granola").summary()
        rows.append(row(
            f"t9/H={h}", s["avg_latency_s"],
            f"dar={s['dar']:.4f};l@da={s['l_at_da']:.4f};"
            f"l@dr={s['l_at_dr']:.4f};"
            f"mem={cache_memory_bytes(cfg) / 1e6:.1f}MB"))
    return rows
