"""Table V: scattered-query datasets (TriviaQA/SQuAD-like)."""
from __future__ import annotations

from benchmarks.common import get_queries, get_service, has_config, row
from repro.serving.engine import FullRetrievalEngine, HasEngine, ReuseEngine


def run():
    rows = []
    for dataset in ("triviaqa", "squad"):
        svc = get_service()
        qs = list(get_queries(dataset))
        base = FullRetrievalEngine(svc).serve(qs, dataset=dataset).summary()
        rows.append(row(f"t5/{dataset}/full", base["avg_latency_s"],
                        round(base["ra_qwen3-8b"], 4)))
        engines = {
            "proximity": ReuseEngine(svc, "proximity", theta=0.65),
            "mincache": ReuseEngine(svc, "mincache", t_lex=0.95, t_sem=0.645),
            "saferadius": ReuseEngine(svc, "saferadius", alpha=4.0),
            "HaS": HasEngine(svc, has_config()),
        }
        for name, eng in engines.items():
            s = eng.serve(qs, dataset=dataset).summary()
            dlat = (s["avg_latency_s"] - base["avg_latency_s"]) \
                / base["avg_latency_s"]
            rows.append(row(f"t5/{dataset}/{name}", s["avg_latency_s"],
                            f"ra={s['ra_qwen3-8b']:.4f};dLat={dlat:+.2%}"))
    return rows
