"""Continuous-batching scheduler under open-loop Poisson load.

Compares the event-driven scheduler (serving/scheduler.py) against the
sequential ``HasEngine`` (closed loop: effective throughput = 1/AvgL) and
the snapshot ``BatchedHasEngine`` on the same zipf (homology-heavy) stream:

  * throughput (completed qps) and p50/p95/p99 latency across a QPS sweep
    up to batch saturation (arrival rate >= the edge's speculation service
    rate, i.e. the admission queue never drains);
  * DAR parity with the micro-batch engine (sharing + late re-validation
    can only add accepts);
  * the single-flight sharing ablation: full-retrieval count with the
    intra-batch homology election on vs. off;
  * the dispatch model of the batch-native refactor: one fused
    ``speculate_batch`` program per speculation batch and one fused
    ``cache_update_batched`` scan per ingest chunk (counted by the
    ``repro.core.dispatch`` probe during the saturated run), swept over
    backend × speculation batch size (the Pallas backend joins the sweep
    on TPU; on CPU it runs in interpret mode and is benchmarked by
    ``retrieval_roofline.sweep_backends`` instead).

Run standalone:  PYTHONPATH=src python -m benchmarks.sched_throughput
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import N_QUERIES, get_queries, get_service, has_config, row
from repro.core import dispatch
from repro.core.has import default_backend
from repro.serving.batched import BatchedHasEngine
from repro.serving.engine import HasEngine
from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                     SchedulerConfig, poisson_arrivals)


def _fmt(s: dict) -> str:
    return (f"thr={s['throughput_qps']:.2f}qps;dar={s['dar']:.4f};"
            f"p50={s['p50_latency_s'] * 1e3:.0f}ms;"
            f"p95={s['p95_latency_s'] * 1e3:.0f}ms;"
            f"p99={s['p99_latency_s'] * 1e3:.0f}ms;"
            f"shared={s['shared_accepts']};reval={s['reval_accepts']};"
            f"full={s['full_retrievals']}")


def run():
    rows = []
    svc = get_service()
    n = min(N_QUERIES, 2000)
    qs = list(get_queries("granola", n=n))
    cfg = has_config()
    sc = SchedulerConfig(max_spec_batch=32, full_batch=16,
                         full_max_wait_s=0.05)
    sched = ContinuousBatchingScheduler(svc, cfg, sc)

    # closed-loop sequential baseline: one query in flight at a time
    seq = HasEngine(svc, cfg).serve(qs[:min(n, 800)]).summary()
    seq_thr = 1.0 / seq["avg_latency_s"]
    rows.append(row("sched/sequential_has", seq["avg_latency_s"],
                    f"thr={seq_thr:.2f}qps;dar={seq['dar']:.4f}"))

    bat = BatchedHasEngine(svc, cfg, batch_size=sc.max_spec_batch
                           ).serve(qs).summary()
    rows.append(row("sched/batched_has", bat["avg_latency_s"],
                    f"dar={bat['dar']:.4f}"))

    # QPS sweep up to saturation of the edge speculation service rate
    edge_rate = sc.max_spec_batch / sched._spec_time(sc.max_spec_batch)
    sat = None
    for frac, label in ((0.25, "qps_low"), (1.0, "qps_saturating"),
                        (None, "qps_inf")):
        if frac is None:
            arrivals, qps_str = None, "inf"
        else:
            qps = frac * edge_rate
            arrivals = poisson_arrivals(n, qps=qps, seed=7)
            qps_str = f"{qps:.1f}"
        with dispatch.capture() as probe:
            s = sched.serve(qs, arrivals, seed=0).summary()
        if label != "qps_low":
            sat = s                               # saturated reference
        rows.append(row(f"sched/{label}={qps_str}",
                        s["avg_latency_s"], _fmt(s)))
        if label == "qps_inf":
            # dispatch model of the batch-native hot path: 1 fused program
            # per speculation batch, 1 fused ingest scan per chunk
            c = probe.counts()
            spec_per_batch = c.get("speculate_batch", 0) / max(
                s["spec_batches"], 1)
            ingest_per_full = c.get("cache_update_batched", 0) / max(
                s["full_batches"], 1)
            rows.append(row(
                "sched/dispatches", 0.0,
                f"spec_per_batch={spec_per_batch:.2f};"
                f"ingest_per_full_batch={ingest_per_full:.2f};"
                f"total={sum(c.values())}"))

    # single-flight sharing ablation at full saturation
    no_share = ContinuousBatchingScheduler(
        svc, cfg, SchedulerConfig(max_spec_batch=32, full_batch=16,
                                  full_max_wait_s=0.05, share=False),
        index=sched.index)
    s0 = no_share.serve(qs, None, seed=0).summary()
    rows.append(row("sched/qps_inf_no_share", s0["avg_latency_s"], _fmt(s0)))

    # backend × speculation-batch-size sweep at saturation (the Pallas
    # backend joins on TPU; on CPU it would run the kernels in interpret
    # mode, which retrieval_roofline.sweep_backends measures instead)
    backends = ["xla"] + (["pallas"] if jax.default_backend() == "tpu"
                          else [])
    for backend in backends:
        for b in (8, 32):
            if backend == default_backend() and b == sc.max_spec_batch:
                s_b = sat        # already measured above (backend=None ->
                                 # default_backend(), same compiled path)
            else:
                swp = ContinuousBatchingScheduler(
                    svc, cfg, SchedulerConfig(
                        max_spec_batch=b, full_batch=16,
                        full_max_wait_s=0.05, backend=backend),
                    index=sched.index)
                s_b = swp.serve(qs, None, seed=0).summary()
            rows.append(row(f"sched/backend={backend}/B={b}",
                            s_b["avg_latency_s"], _fmt(s_b)))

    # acceptance verdicts (issue: scheduler beats sequential throughput at
    # saturating QPS, DAR within 2 points of the micro-batch engine, and
    # sharing measurably cuts full retrievals on a homology-heavy stream)
    rows.append(row(
        "sched/verdict_throughput", 0.0,
        f"{'PASS' if sat['throughput_qps'] > seq_thr else 'FAIL'}"
        f"(sched={sat['throughput_qps']:.2f}qps,seq={seq_thr:.2f}qps)"))
    rows.append(row(
        "sched/verdict_dar_parity", 0.0,
        f"{'PASS' if sat['dar'] >= bat['dar'] - 0.02 else 'FAIL'}"
        f"(sched={sat['dar']:.4f},batched={bat['dar']:.4f})"))
    rows.append(row(
        "sched/verdict_sharing", 0.0,
        f"{'PASS' if sat['full_retrievals'] < s0['full_retrievals'] else 'FAIL'}"
        f"(shared_on={sat['full_retrievals']},off={s0['full_retrievals']})"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    print(fmt_rows(run()))
