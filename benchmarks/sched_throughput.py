"""Continuous-batching scheduler under open-loop Poisson load.

Compares the event-driven scheduler (serving/scheduler.py) against the
sequential ``HasEngine`` (closed loop: effective throughput = 1/AvgL) and
the snapshot ``BatchedHasEngine`` on the same zipf (homology-heavy) stream:

  * throughput (completed qps) and p50/p95/p99 latency across a QPS sweep
    up to batch saturation (arrival rate >= the edge's speculation service
    rate, i.e. the admission queue never drains);
  * DAR parity with the micro-batch engine (sharing + late re-validation
    can only add accepts);
  * the single-flight sharing ablation: full-retrieval count with the
    intra-batch homology election on vs. off;
  * the dispatch model of the batch-native refactor: one fused
    ``speculate_batch`` program per speculation batch and one fused
    ``cache_update_batched`` scan per ingest chunk (counted by the
    ``repro.core.dispatch`` probe during the saturated run), swept over
    backend × speculation batch size (the Pallas backend joins the sweep
    on TPU; on CPU it runs in interpret mode and is benchmarked by
    ``retrieval_roofline.sweep_backends`` instead).

Three opt-in sweeps ride along (see --help):

  * ``--sweep-backend-shards`` — the cloud stage as a WORKER POOL over the
    pluggable retrieval backend (retrieval/service.py): full-retrieval
    throughput vs ``backend.n_workers`` (1→4 mesh-sharded workers at fixed
    DAR, on a scattered low-homology stream where the full stage is the
    bottleneck).  The pool replaces the deprecated serialized
    ``SchedulerConfig.max_inflight_full`` scalar.
  * ``--sweep-share-tau`` — calibration of the sharing threshold
    (``share_tau``) across multipliers of the validation tau: follower
    doc-hit degradation vs latency/full-retrieval savings; the sweep sets
    ``repro.serving.scheduler.DEFAULT_SHARE_TAU_MULT``.
  * ``--sweep-tenants`` — the tenant-partitioned cache under mixed
    Zipf-per-tenant traffic (each tenant a distinct hot set over a
    disjoint entity range): per-tenant doc-hit vs a DEDICATED
    single-tenant scheduler of the same per-tenant capacity (isolation
    verdict), and a cross-tenant leakage audit of every served draft on a
    fuzzy-disabled run where drafts can only come from the tenant's own
    cache partition (no doc id ever served to a tenant that did not pay a
    full retrieval for it; no shared follower attached to a cross-tenant
    leader).  Writes ``BENCH_sched_tenants.json``.
  * ``--sweep-edge-replicas`` — the edge speculation replica pool
    (serving/edge_pool.py): speculation-stage throughput R = 1→4 cache
    replicas at a FIXED arrival rate that saturates the single-edge
    scheduler (the homology-heavy granola stream, where the edge is the
    bottleneck; the cloud stage gets a 4-worker sharded pool so it never
    is), plus DAR vs replica staleness across ``edge_sync_every`` at
    R = 4.  Verdicts: throughput scales monotonically with R, and DAR at
    the default sync cadence stays within 2 points of the zero-lag
    R = 1 path.  Writes ``BENCH_edge_replicas.json``.
  * ``--sweep-overload`` — SLO-aware overload control at 4x edge
    saturation: admitted-request p99 and goodput under
    ``overload_policy`` shed / degrade vs the uncontrolled baseline,
    plus the tracing zero-cost verdict (compat accounting with tracing
    off reproduces the pre-PR golden traces bit-exactly).  Writes
    ``BENCH_overload.json``.
  * ``--sweep-fusion`` — hybrid lexical+dense retrieval with fused RRF
    reranking (retrieval/fusion.py): doc-hit lift over the dense-only
    scan on a corpus whose dense embeddings are corrupted for a third of
    the entities (lexical postings intact) at a matched latency budget,
    the single-dispatch probe at B=32 on both scan backends, and the
    near-duplicate diversification ablation.  Writes
    ``BENCH_fusion.json``.

Run standalone:  PYTHONPATH=src python -m benchmarks.sched_throughput
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (FAST, K, N_QUERIES, get_queries, get_service,
                               has_config, row)
from repro.core import dispatch
from repro.core.has import default_backend
from repro.retrieval.service import RetrievalService, ShardedMeshBackend
from repro.serving.batched import BatchedHasEngine
from repro.serving.engine import HasEngine
from repro.serving.latency import LatencyModel
from repro.serving.scheduler import (DEFAULT_SHARE_TAU_MULT,
                                     ContinuousBatchingScheduler,
                                     SchedulerConfig, poisson_arrivals)


def _fmt(s: dict) -> str:
    return (f"thr={s['throughput_qps']:.2f}qps;dar={s['dar']:.4f};"
            f"p50={s['p50_latency_s'] * 1e3:.0f}ms;"
            f"p95={s['p95_latency_s'] * 1e3:.0f}ms;"
            f"p99={s['p99_latency_s'] * 1e3:.0f}ms;"
            f"shared={s['shared_accepts']};reval={s['reval_accepts']};"
            f"full={s['full_retrievals']}")


def run():
    rows = []
    svc = get_service()
    n = min(N_QUERIES, 2000)
    qs = list(get_queries("granola", n=n))
    cfg = has_config()
    sc = SchedulerConfig(max_spec_batch=32, full_batch=16,
                         full_max_wait_s=0.05)
    sched = ContinuousBatchingScheduler(svc, cfg, sc)

    # closed-loop sequential baseline: one query in flight at a time
    seq = HasEngine(svc, cfg).serve(qs[:min(n, 800)]).summary()
    seq_thr = 1.0 / seq["avg_latency_s"]
    rows.append(row("sched/sequential_has", seq["avg_latency_s"],
                    f"thr={seq_thr:.2f}qps;dar={seq['dar']:.4f}"))

    bat = BatchedHasEngine(svc, cfg, batch_size=sc.max_spec_batch
                           ).serve(qs).summary()
    rows.append(row("sched/batched_has", bat["avg_latency_s"],
                    f"dar={bat['dar']:.4f}"))

    # QPS sweep up to saturation of the edge speculation service rate
    edge_rate = sc.max_spec_batch / sched._spec_time(sc.max_spec_batch)
    sat = None
    for frac, label in ((0.25, "qps_low"), (1.0, "qps_saturating"),
                        (None, "qps_inf")):
        if frac is None:
            arrivals, qps_str = None, "inf"
        else:
            qps = frac * edge_rate
            arrivals = poisson_arrivals(n, qps=qps, seed=7)
            qps_str = f"{qps:.1f}"
        with dispatch.capture() as probe:
            s = sched.serve(qs, arrivals, seed=0).summary()
        if label != "qps_low":
            sat = s                               # saturated reference
        rows.append(row(f"sched/{label}={qps_str}",
                        s["avg_latency_s"], _fmt(s)))
        if label == "qps_inf":
            # dispatch model of the batch-native hot path: 1 fused program
            # per speculation batch, 1 fused ingest scan per chunk
            c = probe.counts()
            spec_per_batch = c.get("speculate_batch", 0) / max(
                s["spec_batches"], 1)
            ingest_per_full = c.get("cache_update_batched", 0) / max(
                s["full_batches"], 1)
            rows.append(row(
                "sched/dispatches", 0.0,
                f"spec_per_batch={spec_per_batch:.2f};"
                f"ingest_per_full_batch={ingest_per_full:.2f};"
                f"total={sum(c.values())}"))

    # single-flight sharing ablation at full saturation
    no_share = ContinuousBatchingScheduler(
        svc, cfg, SchedulerConfig(max_spec_batch=32, full_batch=16,
                                  full_max_wait_s=0.05, share=False),
        index=sched.index)
    s0 = no_share.serve(qs, None, seed=0).summary()
    rows.append(row("sched/qps_inf_no_share", s0["avg_latency_s"], _fmt(s0)))

    # backend × speculation-batch-size sweep at saturation (the Pallas
    # backend joins on TPU; on CPU it would run the kernels in interpret
    # mode, which retrieval_roofline.sweep_backends measures instead)
    backends = ["xla"] + (["pallas"] if jax.default_backend() == "tpu"
                          else [])
    for backend in backends:
        for b in (8, 32):
            if backend == default_backend() and b == sc.max_spec_batch:
                s_b = sat        # already measured above (backend=None ->
                                 # default_backend(), same compiled path)
            else:
                swp = ContinuousBatchingScheduler(
                    svc, cfg, SchedulerConfig(
                        max_spec_batch=b, full_batch=16,
                        full_max_wait_s=0.05, backend=backend),
                    index=sched.index)
                s_b = swp.serve(qs, None, seed=0).summary()
            rows.append(row(f"sched/backend={backend}/B={b}",
                            s_b["avg_latency_s"], _fmt(s_b)))

    # acceptance verdicts (issue: scheduler beats sequential throughput at
    # saturating QPS, DAR within 2 points of the micro-batch engine, and
    # sharing measurably cuts full retrievals on a homology-heavy stream)
    rows.append(row(
        "sched/verdict_throughput", 0.0,
        f"{'PASS' if sat['throughput_qps'] > seq_thr else 'FAIL'}"
        f"(sched={sat['throughput_qps']:.2f}qps,seq={seq_thr:.2f}qps)"))
    rows.append(row(
        "sched/verdict_dar_parity", 0.0,
        f"{'PASS' if sat['dar'] >= bat['dar'] - 0.02 else 'FAIL'}"
        f"(sched={sat['dar']:.4f},batched={bat['dar']:.4f})"))
    rows.append(row(
        "sched/verdict_sharing", 0.0,
        f"{'PASS' if sat['full_retrievals'] < s0['full_retrievals'] else 'FAIL'}"
        f"(shared_on={sat['full_retrievals']},off={s0['full_retrievals']})"))
    return rows


def sweep_backend_shards():
    """Cloud-stage worker pool: full-retrieval throughput vs backend workers.

    Saturated load on a scattered (squad-like) stream — near-zero homology,
    so nearly every query pays a full retrieval and the cloud stage is the
    bottleneck whose scaling the sweep isolates.  The flat backend is the
    serialized baseline (1 worker, the old ``max_inflight_full=1``
    behavior); the sharded backend adds mesh workers 1→4 at 4 corpus
    shards.  Full-stage throughput = paid full retrievals / makespan.
    """
    rows = []
    base = get_service()
    world = base.world
    n = min(N_QUERIES, 1500)
    # entity-unique scattered stream: no query re-encounters an earlier
    # query's entity, so acceptance cannot depend on WHEN full results
    # ingest -> DAR is pinned across worker counts and nearly every query
    # pays a full retrieval (the stage whose scaling the sweep isolates)
    pool = world.sample_queries(4 * n, pattern="scattered",
                                p_uncovered=0.9, seed=2)
    seen, qs = set(), []
    for q in pool:
        if q["entity"] not in seen:
            seen.add(q["entity"])
            qs.append(q)
        if len(qs) == n:
            break
    n = len(qs)
    cfg = has_config(nprobe=1)          # thin edge: cloud stage dominates
    corpus = jnp.asarray(world.doc_emb)

    def one(label, backend_fn):
        lat = LatencyModel()
        svc = RetrievalService(world, lat, k=base.k, chunk=base.chunk,
                               backend=backend_fn(lat))
        # sharing/revalidation off: the sweep isolates the full stage (its
        # work is then identical across worker counts; only overlap varies)
        sched = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
            max_spec_batch=32, full_batch=8, full_max_wait_s=0.05,
            share=False, revalidate=False))
        r = sched.serve(qs, None, seed=0)
        s = r.summary()
        thr_full = s["full_retrievals"] / max(s["makespan_s"], 1e-9)
        rows.append(row(
            f"backshards/{label}", s["avg_latency_s"],
            f"full_thr={thr_full:.2f}qps;dar={s['dar']:.4f};"
            f"max_inflight={s['max_inflight_full_batches']};"
            f"full={s['full_retrievals']};"
            f"makespan={s['makespan_s']:.1f}s"))
        return thr_full, s

    one("flat/w=1", lambda lat: None)
    thr, dar, infl = [], [], []
    for w in (1, 2, 3, 4):
        t, s = one(f"sharded4/w={w}",
                   lambda lat, w=w: ShardedMeshBackend(
                       corpus, base.k, lat, n_shards=4, n_workers=w))
        thr.append(t)
        dar.append(s["dar"])
        infl.append(s["max_inflight_full_batches"])

    # verdicts: the pool sustains >=2 concurrent full batches, full-stage
    # throughput rises monotonically 1->4 workers, DAR stays unchanged
    mono = all(b > a for a, b in zip(thr, thr[1:]))
    rows.append(row(
        "backshards/verdict_concurrency", 0.0,
        f"{'PASS' if max(infl[1:]) >= 2 else 'FAIL'}"
        f"(max_inflight@w2..4={infl[1:]})"))
    rows.append(row(
        "backshards/verdict_scaling", 0.0,
        f"{'PASS' if mono and thr[-1] > 1.5 * thr[0] else 'FAIL'}"
        f"(full_thr_w1..4={','.join(f'{t:.2f}' for t in thr)})"))
    rows.append(row(
        "backshards/verdict_dar_fixed", 0.0,
        f"{'PASS' if max(dar) - min(dar) <= 0.02 else 'FAIL'}"
        f"(dar_w1..4={','.join(f'{d:.4f}' for d in dar)})"))
    return rows


def sweep_share_tau():
    """Sharing-threshold calibration: follower doc-hit degradation vs the
    latency / full-retrieval savings across share_tau = mult * cfg.tau on
    the homology-heavy granola stream at saturation.  The chosen default
    (``DEFAULT_SHARE_TAU_MULT``) is the most aggressive (lowest, i.e.
    cheapest-latency) multiplier whose follower channel stays within 10
    doc-hit points of the full channel."""
    rows = []
    svc = get_service()
    n = min(N_QUERIES, 1500)
    qs = list(get_queries("granola", n=n))
    cfg = has_config()
    picked = None
    for mult in (0.25, 0.5, 0.75, 1.0):
        sched = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
            max_spec_batch=32, full_batch=16, full_max_wait_s=0.05,
            share_tau=mult * cfg.tau))
        r = sched.serve(qs, None, seed=0)
        s = r.summary()
        shared = r.channels == "shared"
        full = r.channels == "full"
        hit_sh = float(r.doc_hits[shared].mean()) if shared.any() else 1.0
        hit_fl = float(r.doc_hits[full].mean()) if full.any() else 1.0
        degr = hit_fl - hit_sh
        rows.append(row(
            f"sharetau/mult={mult}", s["avg_latency_s"],
            f"shared={int(shared.sum())};follower_hit={hit_sh:.4f};"
            f"full_hit={hit_fl:.4f};degr={degr:+.4f};"
            f"full_retrievals={s['full_retrievals']};dar={s['dar']:.4f}"))
        # multipliers sweep ascending: the first within the degradation
        # bound is the most aggressive acceptable one (lower mult = more
        # sharing = lower latency)
        if picked is None and degr <= 0.10:
            picked = mult
    rows.append(row(
        "sharetau/verdict_default", 0.0,
        f"{'PASS' if picked == DEFAULT_SHARE_TAU_MULT else 'FAIL'}"
        f"(sweep_pick={picked},default={DEFAULT_SHARE_TAU_MULT})"))
    return rows


def sweep_tenants(n_tenants: int = 4, out_path: str =
                  "BENCH_sched_tenants.json"):
    """Tenant-partitioned cache under mixed Zipf-per-tenant traffic.

    Each tenant gets its own zipf (homology-heavy) stream over a DISJOINT
    entity range (entity % T == t), so the tenants' hot sets never overlap
    and leakage is detectable from doc ids.  Verdicts:

    (a) isolation — per-tenant doc-hit in the shared multi-tenant
        scheduler is no worse than a dedicated single-tenant scheduler of
        the same per-tenant capacity run on that tenant's stream alone;
    (b) no leakage — on a fuzzy-disabled run (drafts can only come from
        the tenant's own cache partition) no served draft contains a doc
        id the tenant never paid a full retrieval for, and no shared
        follower is attached to a cross-tenant leader.
    """
    rows = []
    svc = get_service()
    world = svc.world
    from repro.data.synthetic import DATASETS
    ds = DATASETS["granola"]
    n_per = min(N_QUERIES, 1600) // n_tenants
    streams = []
    for t in range(n_tenants):
        pool = world.sample_queries(
            8 * n_per, pattern=ds["pattern"], zipf_a=ds["zipf_a"],
            p_uncovered=ds["p_uncovered"], seed=100 + t)
        qs_t = [q for q in pool if q["entity"] % n_tenants == t][:n_per]
        streams.append(qs_t)
    n_per = min(len(s) for s in streams)
    streams = [s[:n_per] for s in streams]
    # round-robin interleave: the mixed open stream the scheduler sees
    mixed = [streams[t][i] for i in range(n_per) for t in range(n_tenants)]
    tids = np.array([t for _ in range(n_per) for t in range(n_tenants)],
                    np.int32)
    cfg = has_config()
    sc_kw = dict(max_spec_batch=32, full_batch=16, full_max_wait_s=0.05)

    multi = ContinuousBatchingScheduler(
        svc, cfg, SchedulerConfig(n_tenants=n_tenants, **sc_kw))
    r = multi.serve(mixed, None, seed=0, tenant_ids=tids)
    per = r.per_tenant()
    s = r.summary()
    rows.append(row("tenants/multi", s["avg_latency_s"], _fmt(s)))

    # dedicated baselines: one single-tenant scheduler per stream, same
    # per-tenant capacity (cfg.h_max / cfg.doc_cap are PER TENANT in the
    # stacked store), sharing the prebuilt fuzzy index
    # isolation: every tenant within a small band of its dedicated baseline
    # (batching patterns differ, so individual tenants jitter a few points
    # either way) AND the aggregate no worse — a broken partition (one
    # tenant churning another's window) fails both by a wide margin
    iso_ok, detail, hits_m, hits_d = True, [], [], []
    for t in range(n_tenants):
        ded = ContinuousBatchingScheduler(
            svc, cfg, SchedulerConfig(**sc_kw), index=multi.index)
        rd = ded.serve(streams[t], None, seed=0)
        hit_m = per[t]["doc_hit_rate"]
        hit_d = float(rd.doc_hits.mean())
        hits_m.append(hit_m)
        hits_d.append(hit_d)
        iso_ok &= hit_m >= hit_d - 0.05
        detail.append(f"t{t}:{hit_m:.4f}/{hit_d:.4f}")
        rows.append(row(
            f"tenants/t={t}", per[t]["avg_latency_s"],
            f"multi_hit={hit_m:.4f};dedicated_hit={hit_d:.4f};"
            f"dar={per[t]['dar']:.4f};full={per[t]['full_retrievals']};"
            f"shared={per[t]['shared_accepts']}"))
    iso_ok &= float(np.mean(hits_m)) >= float(np.mean(hits_d)) - 0.01
    rows.append(row(
        "tenants/verdict_isolation", 0.0,
        f"{'PASS' if iso_ok else 'FAIL'}"
        f"(mean={np.mean(hits_m):.4f}/{np.mean(hits_d):.4f};"
        f"{';'.join(detail)})"))

    # leakage audit on a fuzzy-disabled run: every draft id must be a doc
    # the tenant itself ingested via a full retrieval (the fuzzy channel is
    # corpus-shared by design, so it is switched off to expose the cache
    # partition alone)
    cfg_nf = dataclasses.replace(cfg, use_fuzzy_validation=False,
                                 use_fuzzy_enhancement=False)
    leak_sched = ContinuousBatchingScheduler(
        svc, cfg_nf, SchedulerConfig(n_tenants=n_tenants, **sc_kw),
        index=multi.index)
    rl = leak_sched.serve(mixed, None, seed=0, tenant_ids=tids)
    own_docs = [set() for _ in range(n_tenants)]
    for i in np.flatnonzero(rl.channels == "full"):
        own_docs[int(tids[i])].update(
            int(x) for x in rl.served_ids[i] if x >= 0)
    leaked = 0
    accepted = np.isin(rl.channels, ("draft", "reval", "shared"))
    for i in np.flatnonzero(accepted):
        t = int(tids[i])
        leaked += sum(1 for x in rl.served_ids[i]
                      if x >= 0 and int(x) not in own_docs[t])
    sh = np.flatnonzero(rl.channels == "shared")
    cross_followers = int(np.sum(
        rl.tenant_ids[rl.leader_idx[sh]] != rl.tenant_ids[sh])) \
        if len(sh) else 0
    rows.append(row(
        "tenants/verdict_no_leakage", 0.0,
        f"{'PASS' if leaked == 0 and cross_followers == 0 else 'FAIL'}"
        f"(leaked_ids={leaked};cross_followers={cross_followers};"
        f"audited={int(accepted.sum())})"))

    with open(out_path, "w") as f:
        json.dump({
            "n_tenants": n_tenants,
            "n_queries": len(mixed),
            "multi": {k: v for k, v in s.items()},
            "per_tenant": per,
            "verdicts": {"isolation": bool(iso_ok),
                         "no_leakage": leaked == 0 and cross_followers == 0},
        }, f, indent=2)
    return rows


def sweep_edge_replicas(out_path: str = "BENCH_edge_replicas.json"):
    """Edge speculation replica pool: throughput vs R, DAR vs staleness.

    Fixed arrival rate 2.5x the single-edge speculation service rate on
    the homology-heavy granola stream: R = 1 saturates (makespan ~
    n / edge_rate), R = 4 has the capacity to track arrivals — completed
    throughput scales with the replica count while every batch's
    acceptance is decided against its serving replica's own (bounded-lag)
    cache version.  The cloud stage runs a 4-worker sharded pool so full
    retrievals never serialize the comparison.  The staleness half holds
    R = 4 and sweeps ``edge_sync_every``: the default cadence must keep
    DAR within 2 points of the zero-lag R = 1 path, while an effectively
    never-syncing pool shows the acceptance cost of cold replicas.
    """
    from repro.serving.edge_pool import DEFAULT_EDGE_SYNC_EVERY
    rows = []
    base = get_service()
    world = base.world
    n = min(N_QUERIES, 1500)
    qs = list(get_queries("granola", n=n))
    cfg = has_config()
    corpus = jnp.asarray(world.doc_emb)

    def sched_for(r_replicas, sync_every, index=None):
        lat = LatencyModel()
        svc = RetrievalService(world, lat, k=base.k, chunk=base.chunk,
                               backend=ShardedMeshBackend(
                                   corpus, base.k, lat, n_shards=4,
                                   n_workers=4))
        return ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
            max_spec_batch=32, full_batch=16, full_max_wait_s=0.05,
            edge_replicas=r_replicas, edge_sync_every=sync_every),
            index=index)

    s1 = sched_for(1, DEFAULT_EDGE_SYNC_EVERY)
    edge_rate = 32 / s1._spec_time(32)
    qps = 2.5 * edge_rate
    arrivals = poisson_arrivals(n, qps=qps, seed=9)

    thr, dar, infl = [], [], []
    for r_replicas in (1, 2, 3, 4):
        sched = s1 if r_replicas == 1 else sched_for(
            r_replicas, DEFAULT_EDGE_SYNC_EVERY, index=s1.index)
        s = sched.serve(qs, arrivals, seed=0).summary()
        thr.append(s["throughput_qps"])
        dar.append(s["dar"])
        infl.append(s["max_inflight_spec_batches"])
        rows.append(row(
            f"edgepool/R={r_replicas}", s["avg_latency_s"],
            f"thr={s['throughput_qps']:.2f}qps;dar={s['dar']:.4f};"
            f"max_spec_inflight={s['max_inflight_spec_batches']};"
            f"replays={s['edge_replays']};"
            f"p95={s['p95_latency_s'] * 1e3:.0f}ms;"
            f"makespan={s['makespan_s']:.1f}s"))

    # DAR vs staleness at R = 4 (same arrival trace): the admission /
    # acceptance cost of serving from ever-staler replica cache versions
    stale = {}
    for sync_every in (8, DEFAULT_EDGE_SYNC_EVERY, 128, 10**9):
        if sync_every == DEFAULT_EDGE_SYNC_EVERY:
            s = None          # measured above at R=4
            d4 = dar[-1]
            replays = None
        else:
            s = sched_for(4, sync_every, index=s1.index).serve(
                qs, arrivals, seed=0).summary()
            d4 = s["dar"]
            replays = s["edge_replays"]
        stale[sync_every] = d4
        label = ("inf" if sync_every >= 10**9 else str(sync_every)) + \
            ("*" if sync_every == DEFAULT_EDGE_SYNC_EVERY else "")
        rows.append(row(
            f"edgepool/R=4/sync={label}", 0.0,
            f"dar={d4:.4f};degr_vs_R1={dar[0] - d4:+.4f}"
            + (f";replays={replays}" if replays is not None else "")))

    # verdicts: (a) speculation-stage throughput scales with the replica
    # count at the fixed arrival rate (monotone non-decreasing, >= 1.8x by
    # R=4, and the pool genuinely overlaps batches); (b) bounded-lag
    # replay at the default cadence costs <= 2 DAR points vs zero lag
    mono = all(b >= a * 0.98 for a, b in zip(thr, thr[1:]))
    scal_ok = mono and thr[-1] >= 1.8 * thr[0] and max(infl[1:]) >= 2
    rows.append(row(
        "edgepool/verdict_spec_scaling", 0.0,
        f"{'PASS' if scal_ok else 'FAIL'}"
        f"(thr_R1..4={','.join(f'{t:.2f}' for t in thr)};"
        f"max_spec_inflight={infl})"))
    dar_ok = stale[DEFAULT_EDGE_SYNC_EVERY] >= dar[0] - 0.02
    rows.append(row(
        "edgepool/verdict_dar_staleness", 0.0,
        f"{'PASS' if dar_ok else 'FAIL'}"
        f"(dar_R1={dar[0]:.4f},dar_R4@default={stale[DEFAULT_EDGE_SYNC_EVERY]:.4f},"
        f"dar_R4@inf={stale[10**9]:.4f})"))

    with open(out_path, "w") as f:
        json.dump({
            "n_queries": n,
            "arrival_qps": qps,
            "edge_rate_qps": edge_rate,
            "default_sync_every": DEFAULT_EDGE_SYNC_EVERY,
            "throughput_qps_by_R": dict(zip((1, 2, 3, 4), thr)),
            "dar_by_R": dict(zip((1, 2, 3, 4), dar)),
            "max_spec_inflight_by_R": dict(zip((1, 2, 3, 4), infl)),
            "dar_by_sync_every_at_R4": {str(k): v for k, v in stale.items()},
            "verdicts": {"spec_scaling": bool(scal_ok),
                         "dar_staleness": bool(dar_ok)},
        }, f, indent=2)
    return rows


def sweep_overload(out_path: str = "BENCH_overload.json"):
    """SLO-aware overload control at 4x saturation + tracing zero-cost.

    Drives a Poisson arrival stream at 4x the edge speculation service
    rate (open loop: without control the admission queue grows without
    bound and p99 is meaningless) and compares overload_policy
    none / shed / degrade at an SLO of 2.5x the unloaded reject-path
    latency.  Verdicts:

    (a) bounded p99 — admitted-request p99 under ``shed`` stays within
        SLO + one unloaded reject-path service pass, while the
        uncontrolled run blows far past it;
    (b) goodput — ``shed`` completes at least as many within-SLO results
        per second as the uncontrolled run (it stops burning the cloud
        stage on requests that are already doomed);
    (c) tracing zero-cost — on the pinned golden fixture
        (tests/test_edge_pool.py), the compat accounting point
        (free_ingest_replay=True, follower_score_weighted=False) with
        tracing DISABLED reproduces the pre-PR golden trace hashes
        bit-exactly, and enabling tracing changes nothing — the span
        bookkeeping never advances the virtual clock.
    """
    import hashlib

    from repro.core.has import HasConfig
    from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
    rows = []
    svc = get_service()
    n = min(N_QUERIES, 1500)
    qs = list(get_queries("granola", n=n))
    cfg = has_config()
    base_kw = dict(max_spec_batch=32, full_batch=16, full_max_wait_s=0.05)
    base = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(**base_kw))

    # 4x saturation of the edge stage; SLO = 2.5x the unloaded reject path
    # (one speculation pass + one cloud pass, mean RTTs)
    lat = svc.latency
    spec_svc = base._spec_time(base.sched.max_spec_batch)
    full_svc = base._full_time(base.sched.full_batch)
    reject_path = (spec_svc + 0.5 * (lat.edge_rtt[0] + lat.edge_rtt[1])
                   + full_svc + 0.5 * (lat.cloud_rtt[0] + lat.cloud_rtt[1]))
    slo = 2.5 * reject_path
    edge_rate = base.sched.max_spec_batch / spec_svc
    qps = 4.0 * edge_rate
    arrivals = poisson_arrivals(n, qps=qps, seed=11)

    summaries = {}
    for policy in ("none", "shed", "degrade"):
        sched = base if policy == "none" else ContinuousBatchingScheduler(
            svc, cfg, SchedulerConfig(
                **base_kw, slo_deadline_s=slo, overload_policy=policy),
            index=base.index)
        if policy == "none":
            # the uncontrolled baseline still reports goodput vs the SLO
            r = sched.serve(qs, arrivals, seed=0)
            r.slo_deadline_s = slo
        else:
            r = sched.serve(qs, arrivals, seed=0)
        s = r.summary()
        summaries[policy] = s
        if policy == "shed":
            shed_breakdown = r.trace.stage_breakdown()
        rows.append(row(
            f"overload/{policy}", s["avg_latency_s"],
            f"p99={s['p99_latency_s']:.2f}s;"
            f"p99_adm={s['p99_admitted_latency_s']:.2f}s;"
            f"goodput={s['goodput_qps']:.2f}qps;shed={s['shed']};"
            f"degraded={s['degraded']};dar={s['dar']:.4f};"
            f"makespan={s['makespan_s']:.1f}s"))

    # (c) tracing zero-cost on the pinned golden fixture (small and FIXED —
    # independent of BENCH_FAST, matching tests/test_edge_pool.py)
    gworld = SyntheticWorld(WorldConfig(n_entities=400, seed=0))
    gsvc = RetrievalService(gworld, LatencyModel(), k=10, chunk=2048)
    ds = DATASETS["granola"]
    gqs = gworld.sample_queries(160, pattern=ds["pattern"],
                                zipf_a=ds["zipf_a"],
                                p_uncovered=ds["p_uncovered"], seed=1)
    gcfg = HasConfig(k=10, tau=0.2, h_max=400, nprobe=4, n_buckets=256,
                     d=64)
    garr = poisson_arrivals(160, qps=30.0, seed=5)
    compat_kw = dict(max_spec_batch=16, full_batch=8, full_max_wait_s=0.1,
                     free_ingest_replay=True, follower_score_weighted=False)

    def hashes(r):
        return (hashlib.md5(",".join(r.channels).encode()).hexdigest(),
                hashlib.md5(np.round(r.t_done, 9).tobytes()).hexdigest(),
                hashlib.md5(r.served_ids.tobytes()).hexdigest())

    # pre-PR golden trace hashes (tests/test_edge_pool.py::_GOLDEN_POISSON,
    # generated from the historical scheduler before tracing existed)
    golden = ("ee529472ed19175fb3b357b75a2348a1",
              "5acffd0fe97094942a39198f7ebbfb7f",
              "9e600796f5efd958709178a8aaf970cf")
    off = ContinuousBatchingScheduler(
        gsvc, gcfg, SchedulerConfig(**compat_kw, trace=False))
    r_off = off.serve(gqs, garr, seed=3)
    on = ContinuousBatchingScheduler(
        gsvc, gcfg, SchedulerConfig(**compat_kw, trace=True),
        index=off.index)
    r_on = on.serve(gqs, garr, seed=3)
    zero_ok = (hashes(r_off) == golden and hashes(r_on) == golden
               and r_off.trace is None and r_on.trace is not None)
    rows.append(row(
        "overload/verdict_tracing_zero_cost", 0.0,
        f"{'PASS' if zero_ok else 'FAIL'}"
        f"(compat_off={'==' if hashes(r_off) == golden else '!='}golden;"
        f"compat_on={'==' if hashes(r_on) == golden else '!='}golden)"))

    # (a) bounded p99 for admitted requests under shed
    p99_bound = slo + reject_path
    s_none, s_shed = summaries["none"], summaries["shed"]
    p99_ok = (s_shed["shed"] > 0
              and s_shed["p99_admitted_latency_s"] <= p99_bound
              and s_none["p99_latency_s"] > p99_bound)
    rows.append(row(
        "overload/verdict_shed_p99", 0.0,
        f"{'PASS' if p99_ok else 'FAIL'}"
        f"(p99_adm_shed={s_shed['p99_admitted_latency_s']:.2f}s;"
        f"bound={p99_bound:.2f}s;p99_none={s_none['p99_latency_s']:.2f}s)"))
    # (b) goodput no worse than the uncontrolled baseline
    good_ok = s_shed["goodput_qps"] >= s_none["goodput_qps"]
    rows.append(row(
        "overload/verdict_goodput", 0.0,
        f"{'PASS' if good_ok else 'FAIL'}"
        f"(shed={s_shed['goodput_qps']:.2f}qps;"
        f"none={s_none['goodput_qps']:.2f}qps;"
        f"degrade={summaries['degrade']['goodput_qps']:.2f}qps)"))

    with open(out_path, "w") as f:
        json.dump({
            "n_queries": n,
            "arrival_qps": qps,
            "edge_rate_qps": edge_rate,
            "slo_deadline_s": slo,
            "p99_bound_s": p99_bound,
            "policies": summaries,
            "shed_stage_breakdown": shed_breakdown,
            "verdicts": {"shed_p99": bool(p99_ok),
                         "goodput": bool(good_ok),
                         "tracing_zero_cost": bool(zero_ok)},
        }, f, indent=2)
    return rows


def sweep_fusion(out_path: str = "BENCH_fusion.json"):
    """Hybrid lexical+dense retrieval with single-dispatch fused reranking.

    Verdicts (written to ``BENCH_fusion.json``):

    (a) fused doc-hit — on a corpus where the dense embeddings of a third
        of the entities are replaced by unit noise while their lexical
        postings stay intact (the 'embedding blind spot' the second channel
        exists for), the hybrid backend's doc-hit must be >= the dense-only
        flat scan's at a matched latency budget (hybrid modeled per-query
        latency <= 1.25x dense);
    (b) single dispatch — exactly ONE host dispatch per hybrid search
        batch at B=32 on both scan backends (``repro.core.dispatch``
        probe over the warm program);
    (c) diversification — on a corpus doubled with near-duplicate rows,
        ``diversify_sim=0.98`` lowers the served top-k's mean max pairwise
        cosine similarity vs the ablated (``None``) arm while doc-hit
        gives up at most 2 points.
    """
    from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
    from repro.retrieval.service import HybridBackend, LocalFlatBackend
    rows = []
    n_ent = 400 if FAST else 1200
    nq = 256 if FAST else 512
    world = SyntheticWorld(WorldConfig(n_entities=n_ent, seed=0))
    lat = LatencyModel()
    ds = DATASETS["granola"]
    qs = world.sample_queries(nq, pattern=ds["pattern"],
                              zipf_a=ds["zipf_a"],
                              p_uncovered=ds["p_uncovered"], seed=7)
    embs = jnp.asarray(np.stack([q["emb"] for q in qs]))
    tw_w = max(len(q["terms"]) for q in qs)
    terms = np.full((nq, tw_w), -1, np.int32)
    tws = np.zeros((nq, tw_w), np.float32)
    for j, q in enumerate(qs):
        qt = np.asarray(q["terms"], np.int32)
        qw = np.asarray(q["term_weights"], np.float32)
        terms[j, :qt.shape[0]], tws[j, :qw.shape[0]] = qt, qw
    terms_j, tws_j = jnp.asarray(terms), jnp.asarray(tws)

    # (a) corrupt the dense rows of 1/3 of the entities; postings intact
    rng = np.random.default_rng(123)
    bad_entities = rng.choice(n_ent, size=n_ent // 3, replace=False)
    bad = np.isin(world.doc_entity, bad_entities)
    noise = rng.normal(size=(int(bad.sum()), world.cfg.d)).astype(np.float32)
    noise /= np.maximum(np.linalg.norm(noise, axis=1, keepdims=True), 1e-8)
    corrupted = world.doc_emb.copy()
    corrupted[bad] = noise
    corrupted = jnp.asarray(corrupted)

    def dochit(ids, n_docs=None):
        ids = np.asarray(ids)
        if n_docs is not None:        # doubled corpus: map dup row -> doc
            ids = np.where(ids >= 0, ids % n_docs, -1)
        return float(np.mean([
            world.golden_mask(q["entity"], q["attr"], ids[j]).any()
            for j, q in enumerate(qs)]))

    dense_be = LocalFlatBackend(corrupted, K, lat)
    hyb = HybridBackend(corrupted, K, lat, world.doc_terms,
                        world.doc_term_weights)
    _, ids_d = dense_be.search(embs)
    _, ids_h = hyb.search(embs, q_terms=terms_j, q_term_weights=tws_j)
    hit_d, hit_h = dochit(ids_d), dochit(ids_h)
    lat_d, lat_h = dense_be.latency(1), hyb.latency(1)
    ratio = lat_h / lat_d
    rows.append(row("fusion/dense_only", lat_d, f"doc_hit={hit_d:.4f}"))
    rows.append(row("fusion/hybrid", lat_h, f"doc_hit={hit_h:.4f}"))
    hit_ok = hit_h >= hit_d and ratio <= 1.25
    rows.append(row(
        "fusion/verdict_fused_dochit", 0.0,
        f"{'PASS' if hit_ok else 'FAIL'}"
        f"(hybrid={hit_h:.4f};dense={hit_d:.4f};"
        f"lat_ratio={ratio:.3f};budget=1.25x)"))

    # (b) one host dispatch per warm hybrid batch, both scan backends
    probe = {}
    e32, t32, w32 = embs[:32], terms_j[:32], tws_j[:32]
    for be in ("pallas", "xla"):
        b = HybridBackend(corrupted, K, lat, world.doc_terms,
                          world.doc_term_weights, backend=be)
        b.search(e32, q_terms=t32,
                 q_term_weights=w32)[1].block_until_ready()      # warm jit
        with dispatch.capture() as cpt:
            b.search(e32, q_terms=t32,
                     q_term_weights=w32)[1].block_until_ready()
        probe[be] = cpt.total()
        rows.append(row(f"fusion/dispatch_{be}", 0.0,
                        f"dispatches_per_batch={probe[be]}"))
    disp_ok = all(v == 1 for v in probe.values())
    rows.append(row(
        "fusion/verdict_single_dispatch", 0.0,
        f"{'PASS' if disp_ok else 'FAIL'}"
        f"(pallas={probe['pallas']};xla={probe['xla']};B=32)"))

    # (c) diversification ablation on a near-duplicate-doubled corpus
    n_docs = world.doc_emb.shape[0]
    dup = world.doc_emb + 1e-3 * rng.normal(
        size=world.doc_emb.shape).astype(np.float32)
    dup /= np.maximum(np.linalg.norm(dup, axis=1, keepdims=True), 1e-8)
    corpus2 = jnp.asarray(np.concatenate([world.doc_emb,
                                          dup.astype(np.float32)]))
    terms2 = np.concatenate([world.doc_terms, world.doc_terms])
    tws2 = np.concatenate([world.doc_term_weights, world.doc_term_weights])
    arms = {}
    for name, dsim in (("on", 0.98), ("off", None)):
        b = HybridBackend(corpus2, K, lat, terms2, tws2, diversify_sim=dsim)
        _, ids = b.search(embs, q_terms=terms_j, q_term_weights=tws_j)
        ids = np.asarray(ids)
        vecs = np.asarray(corpus2)[np.maximum(ids, 0)]
        valid = ids >= 0
        sims = []
        for j in range(nq):
            v = vecs[j][valid[j]]
            if v.shape[0] >= 2:
                g = v @ v.T
                np.fill_diagonal(g, -np.inf)
                sims.append(float(g.max(axis=1).mean()))
        arms[name] = {"maxsim": float(np.mean(sims)),
                      "doc_hit": dochit(ids, n_docs=n_docs)}
        rows.append(row(f"fusion/diversify_{name}", 0.0,
                        f"maxsim={arms[name]['maxsim']:.4f};"
                        f"doc_hit={arms[name]['doc_hit']:.4f}"))
    div_ok = (arms["on"]["maxsim"] < arms["off"]["maxsim"]
              and arms["on"]["doc_hit"] >= arms["off"]["doc_hit"] - 0.02)
    rows.append(row(
        "fusion/verdict_diversify", 0.0,
        f"{'PASS' if div_ok else 'FAIL'}"
        f"(maxsim_on={arms['on']['maxsim']:.4f};"
        f"maxsim_off={arms['off']['maxsim']:.4f};"
        f"hit_on={arms['on']['doc_hit']:.4f};"
        f"hit_off={arms['off']['doc_hit']:.4f})"))

    with open(out_path, "w") as f:
        json.dump({
            "n_entities": n_ent,
            "n_queries": nq,
            "corrupted_entity_frac": round(len(bad_entities) / n_ent, 4),
            "doc_hit": {"dense_only": hit_d, "hybrid": hit_h},
            "latency_s": {"dense_only": lat_d, "hybrid": lat_h,
                          "ratio": ratio},
            "dispatches_per_batch": probe,
            "diversify": arms,
            "verdicts": {"fused_dochit": bool(hit_ok),
                         "single_dispatch": bool(disp_ok),
                         "diversify": bool(div_ok)},
        }, f, indent=2)
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    ap = argparse.ArgumentParser(
        description="Continuous-batching scheduler benchmarks.  The cloud "
                    "stage is a worker pool sized by the retrieval "
                    "backend's n_workers (retrieval/service.py); the old "
                    "SchedulerConfig.max_inflight_full scalar is "
                    "deprecated.")
    ap.add_argument("--sweep-backend-shards", action="store_true",
                    help="backend × worker sweep: full-retrieval throughput "
                         "scaling with the cloud worker pool (1→4 "
                         "mesh-sharded workers at fixed DAR)")
    ap.add_argument("--sweep-share-tau", action="store_true",
                    help="share_tau calibration: follower doc-hit "
                         "degradation vs latency across tau multipliers; "
                         "sets DEFAULT_SHARE_TAU_MULT")
    ap.add_argument("--sweep-tenants", action="store_true",
                    help="tenant-partitioned cache under mixed "
                         "Zipf-per-tenant traffic: per-tenant doc-hit vs "
                         "dedicated single-tenant baselines + cross-tenant "
                         "leakage audit; writes BENCH_sched_tenants.json")
    ap.add_argument("--sweep-edge-replicas", action="store_true",
                    help="edge speculation replica pool: speculation-stage "
                         "throughput R=1→4 at fixed arrival rate + DAR vs "
                         "edge_sync_every staleness at R=4; writes "
                         "BENCH_edge_replicas.json")
    ap.add_argument("--sweep-overload", action="store_true",
                    help="SLO-aware overload control at 4x saturation: "
                         "shed/degrade vs uncontrolled p99 + goodput, and "
                         "the tracing zero-cost golden-trace verdict; "
                         "writes BENCH_overload.json")
    ap.add_argument("--sweep-fusion", action="store_true",
                    help="hybrid lexical+dense fused reranking: doc-hit "
                         "lift on a corrupted-embedding corpus at a "
                         "matched latency budget, the single-dispatch "
                         "probe on both scan backends, and the "
                         "diversification ablation; writes "
                         "BENCH_fusion.json")
    ap.add_argument("--skip-base", action="store_true",
                    help="run only the requested sweeps, not the base "
                         "throughput/DAR/sharing verdicts")
    args = ap.parse_args()
    rows = []
    if not args.skip_base:
        rows += run()
    if args.sweep_backend_shards:
        rows += sweep_backend_shards()
    if args.sweep_share_tau:
        rows += sweep_share_tau()
    if args.sweep_tenants:
        rows += sweep_tenants()
    if args.sweep_edge_replicas:
        rows += sweep_edge_replicas()
    if args.sweep_overload:
        rows += sweep_overload()
    if args.sweep_fusion:
        rows += sweep_fusion()
    print(fmt_rows(rows))
