"""Tables III + IV: HaS vs reuse-based methods + CRAG; DAR/L@DA/L@DR."""
from __future__ import annotations

from benchmarks.common import get_queries, get_service, has_config, row
from repro.serving.engine import (CRAGEngine, FullRetrievalEngine, HasEngine,
                                  ReuseEngine)

RESULTS = {}


def run():
    rows = []
    for dataset in ("granola", "popqa"):
        svc = get_service()
        qs = list(get_queries(dataset))
        base = FullRetrievalEngine(svc).serve(qs, dataset=dataset).summary()
        rows.append(row(f"t3/{dataset}/full", base["avg_latency_s"],
                        round(base["ra_qwen3-8b"], 4)))

        engines = {
            "proximity": ReuseEngine(svc, "proximity", theta=0.65),
            "mincache": ReuseEngine(svc, "mincache", t_lex=0.95, t_sem=0.645),
            "saferadius": ReuseEngine(svc, "saferadius", alpha=4.0),
            "crag": CRAGEngine(svc, has_config()),
            "HaS": HasEngine(svc, has_config()),
        }
        for name, eng in engines.items():
            s = eng.serve(qs, dataset=dataset).summary()
            RESULTS[(dataset, name)] = s
            dlat = (s["avg_latency_s"] - base["avg_latency_s"]) \
                / base["avg_latency_s"]
            rows.append(row(
                f"t3/{dataset}/{name}", s["avg_latency_s"],
                f"ra={s['ra_qwen3-8b']:.4f};hit={s['doc_hit_rate']:.4f};"
                f"dLat={dlat:+.2%}"))
        # Table IV extras
        for name in ("crag", "HaS"):
            s = RESULTS[(dataset, name)]
            rows.append(row(
                f"t4/{dataset}/{name}", s["avg_latency_s"],
                f"dar={s['dar']:.4f};l@da={s['l_at_da']:.4f};"
                f"l@dr={s['l_at_dr']:.4f};car={s['car']:.4f}"))
    return rows
