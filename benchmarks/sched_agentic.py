"""Agentic multi-hop serving: hop graphs inside the scheduler, with verdicts.

Four arms over the same complex-query population (serving/agentic.py):

``seq/full``
    The paper's Auto-RAG baseline — every hop a sequential full (cloud)
    retrieval, reasoning charged per hop from
    ``LatencyModel.reason_scale``.
``seq/has``
    HaS plugged into the same sequential pipeline (the paper's Fig-13
    arm): per-hop speculation against the cache, full retrieval only on
    rejects.  Measured at steady state — the engine first serves a
    DISJOINT complex-query sample to warm the cache, as the paper's
    deployed edge cache is warm when agentic traffic arrives; the
    cold-start pass is reported as its own row.
``sched/sequential``
    The complex queries served through the continuous-batching scheduler
    with cross-hop pre-speculation OFF (``speculate_hops=False``): hop
    graphs resolve strictly serially on the virtual clock, but hops of
    DIFFERENT complex queries still batch and share.
``sched/pipelined``
    Pre-speculation ON: hop h+1 launches from hop h's rejected draft's
    bridge entity, racing hop h's validation / full retrieval;
    mis-speculations cancel deterministically and re-enqueue corrected.

Verdicts (written to ``BENCH_agentic.json``):

``sequential_cut``
    ``seq/has`` reproduces the paper's Fig-13 sequential cut over
    ``seq/full``.  The magnitude tracks the workload's sub-query
    redundancy, which a zipf draw over a synthetic entity set only
    brackets: the disjoint-warm arm must cut at least ``SEQ_CUT_BOUND``
    (same sign-level convention ``benchmarks/paper_compare.py`` applies
    to the fig13 row), and the high-redundancy steady-state arm
    (``seq/has_steady``, every sub-query seen before — the regime of
    the paper's −69.4%) must cut PAST the paper's number, so the two
    arms bracket it.
``pipelining``
    ``sched/pipelined`` complex-query e2e latency is STRICTLY below
    ``sched/sequential`` at equal DAR/accuracy (within ``DAR_TOL`` /
    ``ACC_TOL``) — the cross-hop head start is a real win, not a
    quality trade — with the pre-speculation hit rate reported.
``empty_trace``
    A trace with no agentic requests is BIT-IDENTICAL to the pre-PR
    golden hashes (tests/test_edge_pool.py fixture): the hop-graph
    machinery adds zero rng draws, heap events and span charges when
    nothing carries a ``hop_plan``.
``conservation``
    Per-stage span conservation stays exact (residual <= 1e-9) through
    the new ``reason`` and ``cancelled`` paths of the pipelined run.

Run standalone:  PYTHONPATH=src python -m benchmarks.sched_agentic
"""
from __future__ import annotations

import argparse
import hashlib
import json

import numpy as np

from benchmarks.common import FAST, get_service, has_config, row
from repro.core.has import HasConfig
from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
from repro.serving.agentic import AutoRagPipeline, TwoHopDataset
from repro.serving.engine import HasEngine, RetrievalService
from repro.serving.latency import LatencyModel
from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                     SchedulerConfig, poisson_arrivals)

#: disjoint-warm seq/has must cut sequential retrieval latency at least
#: this much (sign-level, matching paper_compare's fig13 convention);
#: the steady-state arm must reach the paper's cut
SEQ_CUT_BOUND = -0.2
PAPER_FIG13_CUT = -0.694
#: pipelined vs sequential DAR / answer-accuracy equality tolerances
DAR_TOL = 0.08
ACC_TOL = 0.08
#: pre-PR golden trace hashes (tests/test_edge_pool.py, charged
#: accounting) the empty-agentic run must reproduce bit-exactly
GOLDEN_POISSON = ("ee529472ed19175fb3b357b75a2348a1",
                  "ce77d205b924b6639b8b0e61f3e6f769",
                  "bde019df4c7b6738d1b80507a91574ce")
GOLDEN_SATURATED = ("818904a0aba858b52dc05f954ac76e94",
                    "58946f966a201cd50552d6eb2613e47d",
                    "3806ef068db5ea2db34da56effc252bd")


def _hashes(r):
    return (hashlib.md5(",".join(r.channels).encode()).hexdigest(),
            hashlib.md5(np.round(r.t_done, 9).tobytes()).hexdigest(),
            hashlib.md5(r.served_ids.tobytes()).hexdigest())


def run(out_path: str = "BENCH_agentic.json"):
    rows = []
    svc = get_service()
    ds = TwoHopDataset(svc.world, seed=0)
    n = 300 if FAST else 900
    cqs = ds.sample(n, seed=2)
    cfg = has_config()

    # ---- sequential arms (the paper's Fig-13 shape) ----------------------
    base = AutoRagPipeline(ds, None, svc).run(cqs)
    rows.append(row("agentic/seq/full", base["retrieval_latency"],
                    f"acc={base['accuracy']:.4f};"
                    f"e2e={base['e2e_latency']:.3f}s"))
    has_pipe = AutoRagPipeline(ds, HasEngine(svc, cfg), svc)
    cold = has_pipe.run(ds.sample(n, seed=9))  # disjoint warm-up sample
    rows.append(row("agentic/seq/has_coldstart", cold["retrieval_latency"],
                    f"acc={cold['accuracy']:.4f};dar={cold['dar']:.4f}"))
    plug = has_pipe.run(cqs)
    cut = (plug["retrieval_latency"] - base["retrieval_latency"]) \
        / base["retrieval_latency"]
    rows.append(row("agentic/seq/has", plug["retrieval_latency"],
                    f"acc={plug['accuracy']:.4f};dar={plug['dar']:.4f};"
                    f"dLat={cut:+.2%};e2e={plug['e2e_latency']:.3f}s"))
    steady = has_pipe.run(cqs)
    steady_cut = (steady["retrieval_latency"] - base["retrieval_latency"]) \
        / base["retrieval_latency"]
    rows.append(row("agentic/seq/has_steady", steady["retrieval_latency"],
                    f"dar={steady['dar']:.4f};dLat={steady_cut:+.2%}"))
    cut_ok = cut <= SEQ_CUT_BOUND and steady_cut <= PAPER_FIG13_CUT
    rows.append(row(
        "agentic/verdict_sequential_cut", 0.0,
        f"{'PASS' if cut_ok else 'FAIL'}"
        f"(dLat={cut:+.2%};bound={SEQ_CUT_BOUND:+.0%};"
        f"steady={steady_cut:+.2%};paper={PAPER_FIG13_CUT:+.1%})"))

    # ---- scheduler arms: same plans, open-loop arrivals ------------------
    # moderate load relative to the edge's drain rate — every complex
    # query spawns ~hops sub-queries, so the admitted rate is about
    # hops x the hop-1 rate
    probe = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig())
    edge_rate = probe.sched.max_spec_batch / probe._spec_time(
        probe.sched.max_spec_batch)
    qps = 0.35 * edge_rate
    arrivals = poisson_arrivals(n, qps=qps, seed=11)

    def sched_arm(speculate: bool):
        eng = ContinuousBatchingScheduler(
            svc, cfg, SchedulerConfig(speculate_hops=speculate),
            index=probe.index)
        return AutoRagPipeline(ds, eng, svc).run(cqs, arrivals=arrivals)

    seq = sched_arm(False)
    pip = sched_arm(True)
    s_seq = seq["sched_result"].summary()
    s_pip = pip["sched_result"].summary()
    rows.append(row(
        "agentic/sched/sequential", seq["e2e_latency"],
        f"acc={seq['accuracy']:.4f};dar={seq['dar']:.4f};"
        f"retr={seq['retrieval_latency']:.3f}s;"
        f"p95={s_seq['complex_e2e_p95_s']:.3f}s"))
    rows.append(row(
        "agentic/sched/pipelined", pip["e2e_latency"],
        f"acc={pip['accuracy']:.4f};dar={pip['dar']:.4f};"
        f"retr={pip['retrieval_latency']:.3f}s;"
        f"p95={s_pip['complex_e2e_p95_s']:.3f}s;"
        f"prespec={pip['hop2_prespec_rate']:.3f};"
        f"prespec_hit={pip['hop2_prespec_hit_rate']:.3f};"
        f"cancelled={s_pip['cancelled']}"))

    # (b) pipelining: strictly faster at equal DAR/accuracy
    speedup = 1.0 - pip["e2e_latency"] / seq["e2e_latency"]
    pipe_ok = (pip["e2e_latency"] < seq["e2e_latency"]
               and abs(pip["dar"] - seq["dar"]) <= DAR_TOL
               and abs(pip["accuracy"] - seq["accuracy"]) <= ACC_TOL)
    rows.append(row(
        "agentic/verdict_pipelining", 0.0,
        f"{'PASS' if pipe_ok else 'FAIL'}"
        f"(e2e={pip['e2e_latency']:.3f}s<{seq['e2e_latency']:.3f}s;"
        f"speedup={speedup:+.2%};"
        f"dDAR={pip['dar'] - seq['dar']:+.4f};"
        f"dAcc={pip['accuracy'] - seq['accuracy']:+.4f};"
        f"prespec_hit={pip['hop2_prespec_hit_rate']:.3f})"))

    # (d) conservation through reason + cancelled paths (hard invariant)
    tr = pip["sched_result"].trace
    resid = float(np.abs(tr.conservation_residual()).max())
    cons_ok = resid <= 1e-9
    assert cons_ok, f"span conservation violated on the agentic path: {resid}"
    rows.append(row(
        "agentic/verdict_conservation", 0.0,
        f"{'PASS' if cons_ok else 'FAIL'}(residual={resid:.2e};"
        f"reason={tr.spans['reason'].sum():.2f}s;"
        f"cancelled={int(np.sum(pip['sched_result'].channels == 'cancelled'))})"))

    # (c) zero-cost when unused: a plain trace reproduces the pre-PR
    # golden hashes on the pinned fixture (small and FIXED — independent
    # of BENCH_FAST, matching tests/test_edge_pool.py)
    gworld = SyntheticWorld(WorldConfig(n_entities=400, seed=0))
    gsvc = RetrievalService(gworld, LatencyModel(), k=10, chunk=2048)
    gds = DATASETS["granola"]
    gqs = gworld.sample_queries(160, pattern=gds["pattern"],
                                zipf_a=gds["zipf_a"],
                                p_uncovered=gds["p_uncovered"], seed=1)
    gcfg = HasConfig(k=10, tau=0.2, h_max=400, nprobe=4, n_buckets=256,
                     d=64)
    gsched = ContinuousBatchingScheduler(gsvc, gcfg, SchedulerConfig(
        max_spec_batch=16, full_batch=8, full_max_wait_s=0.1))
    garr = poisson_arrivals(160, qps=30.0, seed=5)
    h_poi = _hashes(gsched.serve(gqs, garr, seed=3))
    h_sat = _hashes(gsched.serve(gqs, None, seed=3))
    empty_ok = h_poi == GOLDEN_POISSON and h_sat == GOLDEN_SATURATED
    rows.append(row(
        "agentic/verdict_empty_trace", 0.0,
        f"{'PASS' if empty_ok else 'FAIL'}"
        f"(poisson={'==' if h_poi == GOLDEN_POISSON else '!='}golden;"
        f"saturated={'==' if h_sat == GOLDEN_SATURATED else '!='}golden)"))

    with open(out_path, "w") as f:
        json.dump({
            "n_complex": n,
            "hops": 2,
            "arrival_qps": qps,
            "seq_full": base,
            "seq_has_coldstart": cold,
            "seq_has": plug,
            "seq_has_steady": steady,
            "seq_cut": cut,
            "seq_cut_steady": steady_cut,
            "sched_sequential": {k: v for k, v in seq.items()
                                 if k != "sched_result"},
            "sched_pipelined": {k: v for k, v in pip.items()
                                if k != "sched_result"},
            "pipelined_summary": {
                k: (None if isinstance(v, float) and not np.isfinite(v)
                    else v)
                for k, v in s_pip.items()},
            "speedup": speedup,
            "conservation_residual": resid,
            "verdicts": {"sequential_cut": bool(cut_ok),
                         "pipelining": bool(pipe_ok),
                         "empty_trace": bool(empty_ok),
                         "conservation": bool(cons_ok)},
        }, f, indent=2)
    return rows


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    ap = argparse.ArgumentParser(
        description="Agentic multi-hop serving benchmark: sequential "
                    "Fig-13 arms vs scheduler hop graphs with cross-hop "
                    "pre-speculation; writes BENCH_agentic.json")
    ap.add_argument("--out", default="BENCH_agentic.json")
    args = ap.parse_args()
    print(fmt_rows(run(out_path=args.out)))
