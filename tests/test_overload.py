"""SLO-aware overload control + per-stage virtual-clock tracing.

Covers the PR's tentpole contracts:

  * conservation: every request's recorded stage spans sum EXACTLY to its
    end-to-end latency (all channels, R > 1, T > 1, every overload policy);
  * the accounting fixes: cache ingest is charged on the cloud-done path,
    bounded-lag replay is charged to the dispatching edge slot
    (``edge_replays > 0`` implies nonzero charged replay time), and the
    compat flag restores the historical free accounting bit-exactly;
  * tracing is bookkeeping only: trace on / trace off produce identical
    schedules;
  * ``shed`` bounds admitted-request p99 under 4x-saturation arrivals and
    stays deterministic; ``degrade`` returns unvalidated drafts instead of
    queueing for the cloud;
  * NaN-safe empty-stream metrics (``serve([])`` regression);
  * SchedulerConfig knob validation.
"""
import numpy as np
import pytest

from repro.core.has import HasConfig
from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
from repro.serving.engine import RetrievalService
from repro.serving.latency import LatencyModel
from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                     SchedulerConfig, poisson_arrivals)
from repro.serving.tracing import STAGES, Trace

BASE = dict(max_spec_batch=16, full_batch=8, full_max_wait_s=0.1)


@pytest.fixture(scope="module")
def setup():
    world = SyntheticWorld(WorldConfig(n_entities=400, seed=0))
    svc = RetrievalService(world, LatencyModel(), k=10, chunk=2048)
    ds = DATASETS["granola"]
    qs = world.sample_queries(160, pattern=ds["pattern"],
                              zipf_a=ds["zipf_a"],
                              p_uncovered=ds["p_uncovered"], seed=1)
    cfg = HasConfig(k=10, tau=0.2, h_max=400, nprobe=4, n_buckets=256, d=64)
    sched = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(**BASE))
    return svc, qs, cfg, sched


def _assert_conserved(r):
    assert r.trace is not None
    res = r.trace.conservation_residual()
    np.testing.assert_allclose(res, 0.0, atol=1e-9)
    # spans are never negative
    for s in STAGES:
        assert (r.trace.spans[s] >= 0).all(), s


# ---------------------------------------------------------------------------
# Conservation property
# ---------------------------------------------------------------------------

def test_conservation_r1(setup):
    _, qs, _, sched = setup
    r = sched.serve(qs, poisson_arrivals(len(qs), qps=30.0, seed=5), seed=3)
    _assert_conserved(r)
    assert set(np.unique(r.channels)) >= {"draft", "full"}
    # saturated stream exercises deep queues
    _assert_conserved(sched.serve(qs, None, seed=3))


def test_conservation_pooled_multi_tenant(setup):
    """R > 1 and T > 1: replay + tenant-fair queueing all stay conserved."""
    svc, qs, cfg, sched = setup
    pooled = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        **BASE, edge_replicas=3, edge_sync_every=16, n_tenants=2),
        index=sched.index)
    tids = np.array([i % 2 for i in range(len(qs))], np.int32)
    r = pooled.serve(qs, poisson_arrivals(len(qs), qps=60.0, seed=5),
                     seed=3, tenant_ids=tids)
    _assert_conserved(r)
    # the accounting fix: replay events imply charged replay time
    assert r.summary()["edge_replays"] > 0
    assert r.trace.spans["replay"].sum() > 0


def test_conservation_under_policies(setup):
    """shed and degrade channels conserve too (shed: all-zero spans)."""
    svc, qs, cfg, sched = setup
    arr = poisson_arrivals(len(qs), qps=400.0, seed=5)   # way past saturation
    for policy in ("shed", "degrade"):
        s = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
            **BASE, slo_deadline_s=3.0, overload_policy=policy),
            index=sched.index)
        r = s.serve(qs, arr, seed=3)
        _assert_conserved(r)
        extra = "shed" if policy == "shed" else "degraded"
        assert (r.channels == extra).sum() > 0
        if policy == "shed":
            m = r.channels == "shed"
            assert np.all(r.t_done[m] == r.t_arrive[m])
            assert np.all(r.trace.total()[m] == 0)


def test_charged_ingest_delays_cloud_path(setup):
    """Cloud-path completions are strictly later than under the compat
    (free-ingest) accounting — the bug the PR fixes."""
    svc, qs, cfg, sched = setup
    arr = poisson_arrivals(len(qs), qps=30.0, seed=5)
    r = sched.serve(qs, arr, seed=3)
    compat = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        **BASE, free_ingest_replay=True, follower_score_weighted=False),
        index=sched.index)
    rc = compat.serve(qs, arr, seed=3)
    # identical schedule shape at R == 1 (ingest only shifts completions)
    assert np.array_equal(r.channels, rc.channels)
    cloudy = np.isin(r.channels, ("full", "shared"))
    assert cloudy.any()
    assert np.all(r.t_done[cloudy] > rc.t_done[cloudy])
    assert np.all(r.trace.spans["ingest"][cloudy] > 0)
    # compat records zero ingest/replay spans
    assert rc.trace.spans["ingest"].sum() == 0
    assert rc.trace.spans["replay"].sum() == 0


def test_trace_off_identical_schedule(setup):
    """Tracing is bookkeeping only: trace=False produces the same stream
    (and no Trace object)."""
    svc, qs, cfg, sched = setup
    arr = poisson_arrivals(len(qs), qps=30.0, seed=5)
    r_on = sched.serve(qs, arr, seed=3)
    off = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        **BASE, trace=False), index=sched.index)
    r_off = off.serve(qs, arr, seed=3)
    assert r_off.trace is None
    assert np.array_equal(r_on.t_done, r_off.t_done)
    assert np.array_equal(r_on.channels, r_off.channels)
    assert np.array_equal(r_on.served_ids, r_off.served_ids)


def test_stage_breakdown_and_timeline(setup):
    _, qs, _, sched = setup
    r = sched.serve(qs, poisson_arrivals(len(qs), qps=30.0, seed=5), seed=3)
    bd = r.trace.stage_breakdown()
    assert abs(sum(v["frac"] for v in bd.values()) - 1.0) < 1e-9
    # draft channel never touches the cloud stages
    bd_draft = r.trace.stage_breakdown(channels=["draft"])
    for s in ("reval_wait", "cloud_queue", "cloud", "ingest"):
        assert bd_draft[s]["total_s"] == 0.0
    tl = r.trace.timeline(bucket_s=1.0)
    assert tl["n"].sum() == len(qs)
    for s in STAGES:
        np.testing.assert_allclose(tl[s].sum(), r.trace.spans[s].sum())
    with pytest.raises(ValueError):
        r.trace.timeline(bucket_s=0.0)


# ---------------------------------------------------------------------------
# Overload policies
# ---------------------------------------------------------------------------

def test_shed_bounds_admitted_p99(setup):
    """4x-saturation arrivals: no policy lets p99 grow with queue depth;
    shed keeps admitted-request p99 bounded and is deterministic."""
    svc, qs, cfg, sched = setup
    arr = poisson_arrivals(len(qs), qps=400.0, seed=5)
    slo = 3.0
    r_none = sched.serve(qs, arr, seed=3)
    shed = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        **BASE, slo_deadline_s=slo, overload_policy="shed"),
        index=sched.index)
    r_shed = shed.serve(qs, arr, seed=3)
    s_none, s_shed = r_none.summary(), r_shed.summary()
    assert s_shed["shed"] > 0
    assert s_shed["p99_admitted_latency_s"] < s_none["p99_latency_s"]
    # every non-shed request still completes on a real channel
    adm = r_shed.channels != "shed"
    assert np.all(np.isin(r_shed.channels[adm],
                          ("draft", "reval", "shared", "full")))
    r2 = shed.serve(qs, arr, seed=3)
    assert np.array_equal(r_shed.t_done, r2.t_done)
    assert np.array_equal(r_shed.channels, r2.channels)


def test_degrade_serves_drafts_without_cloud(setup):
    svc, qs, cfg, sched = setup
    arr = poisson_arrivals(len(qs), qps=400.0, seed=5)
    deg = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        **BASE, slo_deadline_s=3.0, overload_policy="degrade"),
        index=sched.index)
    r = deg.serve(qs, arr, seed=3)
    m = r.channels == "degraded"
    assert m.sum() > 0
    # degraded = unvalidated draft: not an accept, no cloud time, served
    # ids are the speculation drafts
    assert not r.accepts[m].any()
    assert np.all(r.cloud_s[m] == 0)
    assert np.all(r.trace.spans["cloud"][m] == 0)
    # goodput accounting excludes degraded results
    s = r.summary()
    assert s["degraded"] == int(m.sum())
    assert "goodput_qps" in s


# ---------------------------------------------------------------------------
# Empty-stream + validation satellites
# ---------------------------------------------------------------------------

def test_empty_stream_summary_is_nan_safe(setup):
    _, _, _, sched = setup
    r = sched.serve([])
    s = r.summary()
    assert np.isnan(s["p99_latency_s"]) and np.isnan(s["avg_latency_s"])
    assert s["throughput_qps"] == 0.0
    assert r.per_tenant()[0]["n"] == 0 if r.per_tenant() else True
    assert r.trace is not None and r.trace.n == 0
    assert r.trace.stage_breakdown()["spec"]["total_s"] == 0.0


@pytest.mark.parametrize("bad", [
    dict(max_spec_batch=0),
    dict(full_batch=0),
    dict(full_max_wait_s=-0.1),
    dict(ingest_batch=0),
    dict(overload_policy="panic", slo_deadline_s=1.0),
    dict(overload_policy="shed"),            # needs slo_deadline_s
    dict(slo_deadline_s=0.0),
    dict(slo_deadline_s=1.0, overload_policy="shed", overload_exit_frac=0.0),
])
def test_scheduler_config_validation(setup, bad):
    svc, _, cfg, _ = setup
    with pytest.raises(ValueError):
        ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(**bad))


def test_trace_container_nan_safety():
    t = Trace(t_arrive=np.zeros(0), t_done=np.zeros(0),
              channels=np.array([], dtype="U16"),
              spans={s: np.zeros(0) for s in STAGES})
    bd = t.stage_breakdown()
    assert np.isnan(bd["spec"]["mean_s"]) and np.isnan(bd["spec"]["frac"])
    tl = t.timeline(1.0)
    assert tl["n"].size == 0
