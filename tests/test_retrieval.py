"""Retrieval substrate: chunked==flat, IVF recall, int8 store, distributed."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# real hypothesis when installed, skip-stubs otherwise (see conftest.py)
from conftest import given, settings, st

from repro.retrieval.flat import (chunked_flat_search, flat_search,
                                  quantize_store, quantized_search)
from repro.retrieval.ivf import build_ivf, ivf_search, subset_index


def _unit(rng, n, d):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def test_chunked_equals_flat(rng):
    corpus = jnp.asarray(_unit(rng, 1000, 32))
    q = jnp.asarray(_unit(rng, 5, 32))
    s1, i1 = flat_search(corpus, q, 10)
    s2, i2 = chunked_flat_search(corpus, q, 10, chunk=128)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 7), st.sampled_from([64, 100, 257]))
def test_chunked_property(seed, k, n):
    rng = np.random.default_rng(seed)
    corpus = jnp.asarray(_unit(rng, n, 16))
    q = jnp.asarray(_unit(rng, 2, 16))
    s1, i1 = flat_search(corpus, q, k)
    s2, i2 = chunked_flat_search(corpus, q, k, chunk=50)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-6)


def test_ivf_recall(rng):
    corpus = jnp.asarray(_unit(rng, 2000, 32))
    index = build_ivf(corpus, 16, seed=0)
    q = jnp.asarray(_unit(rng, 20, 32))
    _, exact = flat_search(corpus, q, 10)
    _, approx = ivf_search(index, q, nprobe=8, k=10)
    recall = np.mean([len(set(a) & set(e)) / 10
                      for a, e in zip(np.asarray(approx), np.asarray(exact))])
    assert recall > 0.6   # half the buckets probed -> decent recall
    # more probes -> recall must not decrease (on average)
    _, approx_all = ivf_search(index, q, nprobe=16, k=10)
    recall_all = np.mean([len(set(a) & set(e)) / 10 for a, e in
                          zip(np.asarray(approx_all), np.asarray(exact))])
    assert recall_all >= recall - 1e-9


def test_ivf_all_vectors_indexed_once(rng):
    corpus = jnp.asarray(_unit(rng, 512, 16))
    index = build_ivf(corpus, 8, capacity_factor=8.0, seed=0)
    ids = np.asarray(index.bucket_ids)
    live = ids[ids >= 0]
    assert len(live) == 512 and len(set(live.tolist())) == 512


def test_subset_index_compression(rng):
    corpus = jnp.asarray(_unit(rng, 512, 16))
    index = build_ivf(corpus, 8, seed=0)
    sub = subset_index(index, 0.25)
    assert sub.capacity == max(1, index.capacity // 4)


def test_quantized_store_error_bound(rng):
    corpus = jnp.asarray(_unit(rng, 300, 32))
    store = quantize_store(corpus)
    deq = store["q"].astype(jnp.float32) * store["scale"][:, None]
    err = float(jnp.max(jnp.abs(deq - corpus)))
    assert err <= float(jnp.max(store["scale"])) * 0.5 + 1e-6


def test_quantized_search_with_rescore(rng):
    corpus = jnp.asarray(_unit(rng, 500, 32))
    q = jnp.asarray(_unit(rng, 4, 32))
    store = quantize_store(corpus)
    _, exact = flat_search(corpus, q, 5)
    _, approx = quantized_search(store, q, 5, rescore=corpus)
    recall = np.mean([len(set(a) & set(e)) / 5
                      for a, e in zip(np.asarray(approx), np.asarray(exact))])
    assert recall > 0.9


def test_distributed_topk_single_device():
    """shard_map distributed top-k on a 1x1 mesh == flat search."""
    from repro.launch.mesh import make_local_mesh
    from repro.retrieval.distributed import distributed_flat_search
    rng = np.random.default_rng(0)
    corpus = jnp.asarray(_unit(rng, 256, 16))
    q = jnp.asarray(_unit(rng, 3, 16))
    mesh = make_local_mesh()
    search = distributed_flat_search(mesh, ("data", "model"))
    s, i = jax.jit(lambda c, qq: search(c, qq, 7))(corpus, q)
    se, ie = flat_search(corpus, q, 7)
    np.testing.assert_allclose(np.asarray(s), np.asarray(se), rtol=1e-5)
    assert np.array_equal(np.asarray(i), np.asarray(ie))
