"""Agentic multi-hop serving inside the scheduler (serving/agentic.py +
the hop-graph machinery of serving/scheduler.py).

Covers the PR's tentpole contracts:

  * decomposition determinism: datasets, hop plans and their per-(uid,
    hop) rng substreams are pure functions of their seeds — the drafted
    and validated bridges agree whenever their doc-hits agree;
  * the terms-forwarding regression: sequential hops thread lexical
    terms through BOTH the plug-in engine and the full path (a hybrid
    cloud stage must never silently degrade to dense-only);
  * reasoning time comes from ``LatencyModel.reason_scale`` and is
    charged identically to the sequential baseline and the scheduler's
    ``reason`` trace stage;
  * hop graphs complete through the scheduler with span conservation
    exact through the new reason/cancelled paths;
  * cross-hop pre-speculation pipelines hop-2 under hop-1 (strictly
    lower complex e2e than ``speculate_hops=False``) and mis-speculated
    hops cancel deterministically without ever ingesting;
  * a trace with NO agentic requests is bit-identical to the pre-PR
    golden hashes — the hop-graph machinery is zero-cost when unused;
  * chaos: a mixed agentic+plain trace under the full fault cocktail
    replays bit-exactly and still conserves spans;
  * CLI validation: ``launch/serve.py`` rejects bad ``--agentic-frac``
    / ``--hops`` combinations with exit code 2.
"""
import hashlib
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.has import HasConfig
from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
from repro.serving.agentic import (AutoRagPipeline, HopPlan, TwoHopDataset,
                                   build_hop_trace, decompose)
from repro.serving.engine import HasEngine, RetrievalService
from repro.serving.faults import FaultEvent, FaultPlan
from repro.serving.latency import LatencyModel
from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                     SchedulerConfig, poisson_arrivals)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def setup():
    world = SyntheticWorld(WorldConfig(n_entities=400, seed=0))
    svc = RetrievalService(world, LatencyModel(), k=10, chunk=2048)
    cfg = HasConfig(k=10, tau=0.2, h_max=400, nprobe=4, n_buckets=256, d=64)
    sched = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        max_spec_batch=16, full_batch=8, full_max_wait_s=0.1))
    ds = TwoHopDataset(world, seed=0)
    return world, svc, cfg, sched, ds


# ---------------------------------------------------------------------------
# Decomposition layer
# ---------------------------------------------------------------------------

def test_dataset_deterministic_and_chain_consistent(setup):
    world, _, _, _, ds = setup
    a = ds.sample(40, seed=7, hops=3)
    b = TwoHopDataset(world, seed=0).sample(40, seed=7, hops=3)
    assert a == b
    for cq in a:
        assert len(cq["entities"]) == 3 and len(cq["rels"]) == 2
        for h, r in enumerate(cq["rels"]):
            # each chain link follows the dataset's relation map
            assert cq["entities"][h + 1] == int(
                ds.relations[r][cq["entities"][h]])
    # legacy 2-hop keys preserved
    two = ds.sample(5, seed=7)
    assert all(q["e2"] == q["entities"][1] for q in two)
    with pytest.raises(ValueError, match="hops"):
        ds.sample(3, hops=0)


def test_hop_plan_bridge_frozen_and_hit_grounded(setup):
    world, _, _, _, ds = setup
    plan = decompose(ds, ds.sample(1, seed=3, hops=3), seed=5)[0]
    # grounded hop -> true next entity, every call
    assert plan.bridge(1, True) == plan.entities[1]
    assert plan.bridge(1, True) == plan.entities[1]
    # the lucky/guess draws are FROZEN per hop: a draft-derived and a
    # validated bridge with the same hit agree (pre-speculation's
    # confirmability), and an independent copy of the plan agrees too
    copy = HopPlan(world, ds.rel_attr, plan.entities, plan.rels, plan.attr,
                   uid=plan.uid, seed=5)
    for h in (1, 2):
        assert plan.bridge(h, False) == plan.bridge(h, False)
        assert plan.bridge(h, False) == copy.bridge(h, False)
    # sub-query encodings are pure functions of (uid, hop, entity)
    q1, q2 = plan.query(2, 17), copy.query(2, 17)
    np.testing.assert_array_equal(q1["emb"], q2["emb"])
    np.testing.assert_array_equal(q1["terms"], q2["terms"])
    with pytest.raises(ValueError, match="relations"):
        HopPlan(world, ds.rel_attr, [1, 2, 3], [0], 0, uid=0)


def test_sequential_hops_forward_lexical_terms(setup, monkeypatch):
    """Regression (satellite): ``AutoRagPipeline._retrieve`` must thread
    query terms into BOTH the full path and the plug-in engine — it used
    to drop them on the floor for ``full_search``."""
    _, svc, cfg, _, ds = setup
    cqs = ds.sample(3, seed=2)

    seen_full, seen_step = [], []
    real_full = svc.full_search

    def spy_full(emb, terms=None, weights=None, **kw):
        seen_full.append(terms)
        return real_full(emb, terms, weights, **kw)

    monkeypatch.setattr(svc, "full_search", spy_full)
    AutoRagPipeline(ds, None, svc).run(cqs)
    assert seen_full and all(t is not None and len(t) for t in seen_full)

    eng = HasEngine(svc, cfg)
    real_step = eng.step

    def spy_step(emb, **kw):
        seen_step.append(kw.get("q_terms"))
        return real_step(emb, **kw)

    monkeypatch.setattr(eng, "step", spy_step)
    AutoRagPipeline(ds, eng, svc).run(cqs)
    assert seen_step and all(t is not None and len(t) for t in seen_step)


def test_reasoning_time_comes_from_latency_model(setup):
    _, svc, _, _, ds = setup
    assert svc.latency.reason_time() == svc.latency.reason_scale
    p = AutoRagPipeline(ds, None, svc)
    assert p.reasoning_latency == svc.latency.reason_scale
    assert AutoRagPipeline(ds, None, svc,
                           reasoning_latency=0.7).reasoning_latency == 0.7
    r = p.run(ds.sample(8, seed=2))
    # e2e == retrieval + hops x reason, exactly, on the sequential arm
    assert r["e2e_latency"] == pytest.approx(
        r["retrieval_latency"] + 2 * svc.latency.reason_scale)


# ---------------------------------------------------------------------------
# Scheduler substrate
# ---------------------------------------------------------------------------

def _agentic_serve(svc, cfg, index, ds, n=48, hops=2, speculate=True,
                   qps=20.0, **sched_kw):
    sched = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        max_spec_batch=16, full_batch=8, full_max_wait_s=0.1,
        speculate_hops=speculate, **sched_kw), index=index)
    qs = build_hop_trace(ds, ds.sample(n, seed=2, hops=hops), seed=0)
    arr = poisson_arrivals(n, qps=qps, seed=5)
    return sched.serve(qs, arr, seed=3)


def test_hop_graphs_complete_and_conserve(setup):
    _, svc, cfg, sched, ds = setup
    r = _agentic_serve(svc, cfg, sched.index, ds, n=48, hops=3)
    recs = r.complex_records
    assert recs is not None and len(recs) == 48
    assert all(np.isfinite(c["e2e_s"]) for c in recs)
    # every complex query charged exactly hops x reason_s of thinking
    reason = svc.latency.reason_scale
    assert all(c["reason_s"] == pytest.approx(3 * reason) for c in recs)
    assert all(c["e2e_s"] > c["reason_s"] for c in recs)
    # span conservation exact through reason + cancelled paths
    assert np.abs(r.trace.conservation_residual()).max() <= 1e-9
    assert r.trace.spans["reason"].sum() > 0
    # per-hop identity threaded into the result arrays: each chain
    # resolves exactly one NON-speculative request per hop (mis-spec
    # orphans that outran their parent stay live but flagged
    # speculative; mis-specs caught in flight land on ``cancelled``)
    assert r.hop.max() == 3 and (r.hop >= 1).all()
    resolved = (r.channels != "cancelled") & ~r.speculative
    for h in (1, 2, 3):
        assert np.sum((r.hop == h) & resolved) == 48
    s = r.summary()
    for k in ("complex_n", "complex_e2e_avg_s", "complex_dar",
              "complex_accuracy", "hop_prespec_rate",
              "hop_prespec_hit_rate", "cancelled", "hop1_n", "hop3_dar"):
        assert k in s, k
    assert s["complex_n"] == 48 and s["hop1_n"] == 48


def test_prespec_pipelines_and_cancels_cleanly(setup):
    # moderate load: saturation would queue the pre-speculated hops
    # behind everything else and drown the head start they buy
    _, svc, cfg, sched, ds = setup
    r_on = _agentic_serve(svc, cfg, sched.index, ds, speculate=True,
                          qps=10.0)
    r_off = _agentic_serve(svc, cfg, sched.index, ds, speculate=False,
                           qps=10.0)
    s_on, s_off = r_on.summary(), r_off.summary()
    # same work, equal quality, strictly faster with the head start
    assert s_on["complex_n"] == s_off["complex_n"] == 48
    assert s_on["complex_e2e_avg_s"] < s_off["complex_e2e_avg_s"]
    assert s_on["hop_prespec_rate"] > 0
    assert s_off["hop_prespec_rate"] == 0 and s_off["cancelled"] == 0
    assert (r_off.channels != "cancelled").all()
    # mis-speculations happen and settle on the cancelled channel
    cancelled = r_on.channels == "cancelled"
    assert s_on["cancelled"] == cancelled.sum() > 0
    assert r_on.speculative is not None
    # cancelled rows never ingest and carry sentinel ids
    assert not r_on.trace.spans["ingest"][cancelled].any()
    assert (r_on.served_ids[cancelled] == -1).all()
    # every cancelled row is a pre-speculated follow-up hop, never hop 1
    assert (r_on.hop[cancelled] > 1).all()
    # conservation holds on both arms
    for r in (r_on, r_off):
        assert np.abs(r.trace.conservation_residual()).max() <= 1e-9


def test_agentic_trace_replays_bit_exactly(setup):
    _, svc, cfg, sched, ds = setup
    a = _agentic_serve(svc, cfg, sched.index, ds)
    b = _agentic_serve(svc, cfg, sched.index, ds)
    assert list(a.channels) == list(b.channels)
    assert np.array_equal(a.t_done, b.t_done)
    assert np.array_equal(a.served_ids, b.served_ids)
    assert np.array_equal(a.hop, b.hop)


# golden hashes shared with tests/test_edge_pool.py (charged accounting):
# the agentic machinery must not move a single bit of a plain trace
_GOLDEN_POISSON = ("ee529472ed19175fb3b357b75a2348a1",
                   "ce77d205b924b6639b8b0e61f3e6f769",
                   "bde019df4c7b6738d1b80507a91574ce")
_GOLDEN_SATURATED = ("818904a0aba858b52dc05f954ac76e94",
                     "58946f966a201cd50552d6eb2613e47d",
                     "3806ef068db5ea2db34da56effc252bd")


def _trace_hashes(r):
    return (hashlib.md5(",".join(r.channels).encode()).hexdigest(),
            hashlib.md5(np.round(r.t_done, 9).tobytes()).hexdigest(),
            hashlib.md5(r.served_ids.tobytes()).hexdigest())


def test_plain_trace_bit_identical_to_pre_pr_goldens(setup):
    world, svc, cfg, sched, _ = setup
    gds = DATASETS["granola"]
    qs = world.sample_queries(160, pattern=gds["pattern"],
                              zipf_a=gds["zipf_a"],
                              p_uncovered=gds["p_uncovered"], seed=1)
    arr = poisson_arrivals(160, qps=30.0, seed=5)
    r = sched.serve(qs, arr, seed=3)
    assert _trace_hashes(r) == _GOLDEN_POISSON
    assert _trace_hashes(sched.serve(qs, None, seed=3)) == _GOLDEN_SATURATED
    # and the agentic surfaces stay inert: no hop identity, no records
    assert r.complex_records is None
    assert not r.trace.spans["reason"].any()


# ---------------------------------------------------------------------------
# Chaos smoke: hop graphs under the full fault cocktail
# ---------------------------------------------------------------------------

def test_chaos_smoke_mixed_agentic_trace():
    import jax.numpy as jnp

    from repro.retrieval.service import ShardedMeshBackend
    world = SyntheticWorld(WorldConfig(n_entities=400, seed=0))
    lat = LatencyModel()
    backend = ShardedMeshBackend(jnp.asarray(world.doc_emb), 10, lat,
                                 n_shards=4, n_workers=4)
    svc = RetrievalService(world, lat, k=10, chunk=2048, backend=backend)
    cfg = HasConfig(k=10, tau=0.2, h_max=400, nprobe=4, n_buckets=256, d=64)
    gds = DATASETS["granola"]
    qs = world.sample_queries(96, pattern=gds["pattern"],
                              zipf_a=gds["zipf_a"],
                              p_uncovered=gds["p_uncovered"], seed=1)
    ds = TwoHopDataset(world, seed=0)
    hop1 = build_hop_trace(ds, ds.sample(24, seed=2), seed=0)
    slots = np.sort(np.random.default_rng(8).choice(96, 24, replace=False))
    for i, q in zip(slots, hop1):
        qs[int(i)] = q
    plan = FaultPlan(events=(
        FaultEvent(t=0.3, kind="straggler", target=1, duration_s=2.0,
                   factor=6.0),
        FaultEvent(t=0.5, kind="worker_crash", target=0, down_s=1.0),
        FaultEvent(t=0.8, kind="search_fail", target=2, duration_s=1.0),
        FaultEvent(t=0.6, kind="delta_drop", count=2),
    ))

    def serve():
        sched = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
            max_spec_batch=16, full_batch=8, full_max_wait_s=0.1,
            edge_replicas=2, fault_plan=plan), seed=0)
        return sched.serve(qs, poisson_arrivals(96, qps=40.0, seed=5),
                           seed=3)

    a, b = serve(), serve()
    assert list(a.channels) == list(b.channels)
    assert np.array_equal(a.t_done, b.t_done)
    assert np.array_equal(a.served_ids, b.served_ids)
    # every request reached a terminal channel and spans conserve
    assert (a.t_done >= 0).all()
    assert np.abs(a.trace.conservation_residual()).max() <= 1e-9
    # the agentic slice actually exercised the fault window
    assert a.complex_records is not None and len(a.complex_records) == 24
    done = [c for c in a.complex_records if np.isfinite(c["e2e_s"])]
    assert len(done) > 0


# ---------------------------------------------------------------------------
# CLI validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("flags", [
    ["--engine", "sched", "--agentic-frac", "1.5"],
    ["--engine", "sched", "--agentic-frac", "0.3", "--hops", "0"],
    ["--engine", "has", "--agentic-frac", "0.3"],
])
def test_serve_cli_rejects_bad_agentic_flags(flags):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--queries", "8"]
        + flags,
        capture_output=True, text=True, env=env, timeout=120, cwd=REPO)
    assert p.returncode == 2, p.stderr
    assert "agentic" in p.stderr or "hops" in p.stderr
