"""Edge speculation replica pool (serving/edge_pool.py + scheduler slots).

Covers the PR's tentpole contracts:

  * R == 1 stays bit-exact with the PRE-PR scheduler (golden trace
    hashes generated from the historical code), on both speculation
    backends;
  * the delta-log substrate: sequence numbering, clear-on-snapshot vs
    delta-cursor consumption, maxlen eviction detection, compaction;
  * bounded-lag replay parity: a replica synced to version s is
    bit-identical to the primary's state after its first s ingest rows;
  * stale-accept audit: no accepted draft references a doc absent from
    the serving replica's cache version (fuzzy channel disabled so drafts
    can only come from the replica's own cache);
  * failover mid-stream: promoting a replica continues the ingest trace
    bit-exactly;
  * ReplicaBackend unification: cloud standbys and the edge pool
    reconcile off one ``on_ingest`` fan-out.
"""
import hashlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.has import (HasConfig, cache_update_chunked, init_has_state,
                            init_tenant_states)
from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
from repro.serving.edge_pool import DEFAULT_EDGE_SYNC_EVERY, EdgeReplicaPool
from repro.serving.engine import RetrievalService
from repro.serving.latency import LatencyModel
from repro.serving.replication import DeltaLog
from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                     SchedulerConfig, poisson_arrivals)


# ---------------------------------------------------------------------------
# DeltaLog substrate
# ---------------------------------------------------------------------------

def test_delta_log_sequence_and_cursors():
    log = DeltaLog()
    for i in range(5):
        log.append(i)
    assert (log.base, log.head, len(log)) == (0, 5, 5)
    assert log.since(0) == [0, 1, 2, 3, 4]
    assert log.since(3) == [3, 4]
    assert log.since(5) == []
    log.compact_below(3)                     # min cursor over consumers
    assert (log.base, log.head, len(log)) == (3, 5, 2)
    assert log.since(3) == [3, 4]
    with pytest.raises(LookupError):         # evicted rows are detectable
        log.since(1)
    log.clear()                              # clear-on-snapshot style
    assert (log.base, log.head, len(log)) == (5, 5, 0)
    log.append(9)
    assert log.since(5) == [9]


def test_delta_log_maxlen_eviction_advances_base():
    log = DeltaLog(maxlen=3)
    for i in range(5):
        log.append(i)
    assert (log.base, log.head, len(log)) == (2, 5, 3)
    assert list(log) == [2, 3, 4]
    with pytest.raises(LookupError):
        log.since(0)                         # fell behind: must full-resync


# ---------------------------------------------------------------------------
# Pool-level replay parity + failover
# ---------------------------------------------------------------------------

def _rows(rng, n, cfg, hi=200):
    qs = rng.normal(size=(n, cfg.d)).astype(np.float32)
    ids = rng.integers(0, hi, size=(n, cfg.k)).astype(np.int32)
    vecs = rng.normal(size=(n, cfg.k, cfg.d)).astype(np.float32)
    return qs, ids, vecs


def _fold(cfg, qs, ids, vecs, n_tenants=1, tids=None):
    state = (init_has_state(cfg) if n_tenants == 1
             else init_tenant_states(cfg, n_tenants))
    if len(qs) == 0:
        return state
    return cache_update_chunked(cfg, state, qs, ids, vecs, chunk=16,
                                tenant_ids=tids)


def _assert_states_equal(a, b, msg=""):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=msg)


def test_bounded_lag_replay_parity():
    """After every record_batch, lag stays < sync_every; after a sync, the
    replica equals the primary PREFIX fold at its cursor, bit-exactly."""
    cfg = HasConfig(k=4, h_max=16, doc_capacity=64, d=8)
    pool = EdgeReplicaPool(cfg, n_replicas=3, sync_every=5, compact=False)
    rng = np.random.default_rng(0)
    qs, ids, vecs = _rows(rng, 23, cfg)
    for i0 in range(0, 23, 3):
        pool.record_batch(qs[i0:i0 + 3], ids[i0:i0 + 3], vecs[i0:i0 + 3])
        for r in range(3):
            assert pool.lag(r) < pool.sync_every
    for r in range(3):
        v = pool.version(r)
        _assert_states_equal(
            pool.states[r], _fold(cfg, qs[:v], ids[:v], vecs[:v]),
            msg=f"replica {r} at version {v}")
    pool.sync_all()
    for r in range(3):
        assert pool.version(r) == 23
        _assert_states_equal(pool.states[r], _fold(cfg, qs, ids, vecs))


def test_pool_compaction_drops_fully_replayed_rows():
    cfg = HasConfig(k=4, h_max=16, doc_capacity=64, d=8)
    pool = EdgeReplicaPool(cfg, n_replicas=2, sync_every=4)   # compact=True
    rng = np.random.default_rng(1)
    qs, ids, vecs = _rows(rng, 12, cfg)
    pool.record_batch(qs, ids, vecs)
    # one 12-row batch trips the cadence for both replicas -> cursors at
    # head -> everything compacted away
    assert pool.version(0) == pool.version(1) == 12
    assert len(pool.log) == 0 and pool.log.base == 12
    # the NEXT delta still replays correctly from the compacted log
    qs2, ids2, vecs2 = _rows(rng, 2, cfg)
    pool.record_batch(qs2, ids2, vecs2)
    pool.sync_all()
    full = (np.concatenate([qs, qs2]), np.concatenate([ids, ids2]),
            np.concatenate([vecs, vecs2]))
    _assert_states_equal(pool.states[0], _fold(cfg, *full))


def test_failover_midstream_continues_trace_bit_exactly():
    """Primary dies mid-stream: promote() must hand over exactly the cache
    the primary had, and continuing the ingest trace on the promoted state
    matches an uninterrupted run."""
    cfg = HasConfig(k=4, h_max=16, doc_capacity=64, d=8)
    pool = EdgeReplicaPool(cfg, n_replicas=2, sync_every=7, compact=False)
    rng = np.random.default_rng(2)
    qs, ids, vecs = _rows(rng, 20, cfg)
    m = 11                                   # rows ingested before the loss
    for i in range(m):
        pool.record_batch(qs[i:i + 1], ids[i:i + 1], vecs[i:i + 1])
    assert pool.lag(1) > 0                   # genuinely stale at failover
    promoted = pool.promote(1)
    _assert_states_equal(promoted, _fold(cfg, qs[:m], ids[:m], vecs[:m]),
                         msg="promoted replica != primary at failover")
    # the trace continues on the promoted state
    cont = cache_update_chunked(cfg, promoted, qs[m:], ids[m:], vecs[m:],
                                chunk=16)
    _assert_states_equal(cont, _fold(cfg, qs, ids, vecs),
                         msg="continued trace diverged after failover")


def test_pool_multi_tenant_replay_routes_partitions():
    cfg = HasConfig(k=4, h_max=8, doc_capacity=32, d=8)
    pool = EdgeReplicaPool(cfg, n_replicas=2, sync_every=3, n_tenants=3,
                           compact=False)
    rng = np.random.default_rng(3)
    qs, ids, vecs = _rows(rng, 9, cfg, hi=60)
    tids = np.array([0, 2, 0, 2, 2, 1, 0, 1, 2], np.int32)
    pool.record_batch(qs, ids, vecs, tenant_ids=tids)
    pool.sync_all()
    _assert_states_equal(pool.states[0],
                         _fold(cfg, qs, ids, vecs, n_tenants=3, tids=tids))
    with pytest.raises(ValueError):          # tenant_ids required at T > 1
        pool.record_batch(qs[:1], ids[:1], vecs[:1])


def test_pool_validation():
    cfg = HasConfig(k=4, h_max=8, doc_capacity=32, d=8)
    with pytest.raises(ValueError):
        EdgeReplicaPool(cfg, n_replicas=0)
    with pytest.raises(ValueError):
        EdgeReplicaPool(cfg, n_replicas=2, sync_every=0)
    pool = EdgeReplicaPool(cfg, n_replicas=1)
    rng = np.random.default_rng(4)
    qs, ids, vecs = _rows(rng, 4, cfg)
    with pytest.raises(ValueError):          # zip-truncation guard
        pool.record_batch(qs, ids[:3], vecs)


# ---------------------------------------------------------------------------
# Scheduler integration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    world = SyntheticWorld(WorldConfig(n_entities=400, seed=0))
    svc = RetrievalService(world, LatencyModel(), k=10, chunk=2048)
    ds = DATASETS["granola"]
    qs = world.sample_queries(160, pattern=ds["pattern"],
                              zipf_a=ds["zipf_a"],
                              p_uncovered=ds["p_uncovered"], seed=1)
    cfg = HasConfig(k=10, tau=0.2, h_max=400, nprobe=4, n_buckets=256, d=64)
    sched = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        max_spec_batch=16, full_batch=8, full_max_wait_s=0.1))
    return svc, qs, cfg, sched


# Golden trace of the PRE-PR scheduler (generated from the historical code
# before the edge-pool generalization, same setup as the fixture above:
# poisson_arrivals(160, qps=30.0, seed=5), serve(seed=3) and the fully
# saturated serve(None, seed=3)).  The COMPAT accounting point
# (free_ingest_replay=True, follower_score_weighted=False) must keep
# producing EXACTLY these channels / completion times / served ids — the
# tracing machinery is bookkeeping only and never advances the clock.
_GOLDEN_POISSON = ("ee529472ed19175fb3b357b75a2348a1",
                   "5acffd0fe97094942a39198f7ebbfb7f",
                   "9e600796f5efd958709178a8aaf970cf")
_GOLDEN_SATURATED = ("818904a0aba858b52dc05f954ac76e94",
                     "b8f7083aa5617849da4d9f642d60d88d",
                     "161545ea8e39fc12bcb43e7987d6a07a")

# Golden trace of the DEFAULT (accounting-fixed) scheduler: ingest charged
# on the cloud-done path, replay charged to the dispatching edge slot,
# score-weighted follower ingest, min-heap slot allocator.  Pins the fixed
# accounting against accidental schedule drift the same way the compat
# goldens pin the historical one.
_GOLDEN_POISSON_CHARGED = ("ee529472ed19175fb3b357b75a2348a1",
                           "ce77d205b924b6639b8b0e61f3e6f769",
                           "bde019df4c7b6738d1b80507a91574ce")
_GOLDEN_SATURATED_CHARGED = ("818904a0aba858b52dc05f954ac76e94",
                             "58946f966a201cd50552d6eb2613e47d",
                             "3806ef068db5ea2db34da56effc252bd")


def _trace_hashes(r):
    return (hashlib.md5(",".join(r.channels).encode()).hexdigest(),
            hashlib.md5(np.round(r.t_done, 9).tobytes()).hexdigest(),
            hashlib.md5(r.served_ids.tobytes()).hexdigest())


def test_r1_bit_exact_vs_pre_pr_golden_trace(setup):
    svc, qs, cfg, sched = setup
    compat = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        max_spec_batch=16, full_batch=8, full_max_wait_s=0.1,
        free_ingest_replay=True, follower_score_weighted=False),
        index=sched.index)
    arr = poisson_arrivals(len(qs), qps=30.0, seed=5)
    assert _trace_hashes(compat.serve(qs, arr, seed=3)) == _GOLDEN_POISSON
    assert _trace_hashes(compat.serve(qs, None, seed=3)) == _GOLDEN_SATURATED


def test_r1_charged_accounting_golden_trace(setup):
    """Default accounting: same schedule SHAPE as the pre-PR goldens (the
    channel sequence is identical — charging ingest only shifts completion
    times at R == 1), different completion times and follower doc order."""
    _, qs, _, sched = setup
    arr = poisson_arrivals(len(qs), qps=30.0, seed=5)
    r = sched.serve(qs, arr, seed=3)
    assert _trace_hashes(r) == _GOLDEN_POISSON_CHARGED
    assert _trace_hashes(sched.serve(qs, None, seed=3)) == \
        _GOLDEN_SATURATED_CHARGED
    # charged ingest strictly delays cloud-path completions vs compat
    full = r.channels == "full"
    assert full.any() and np.all(
        r.trace.spans["ingest"][full] > 0)


def test_r1_inert_sync_knob_and_backends(setup):
    """At R == 1 the lone slot IS the primary: edge_sync_every is inert,
    and the xla / pallas(interpret) speculation backends stay bit-equal
    through the pool-generalized loop (their parity is kernel-level,
    tests/test_speculate_batch.py)."""
    svc, qs, cfg, sched = setup
    arr = poisson_arrivals(len(qs), qps=30.0, seed=5)
    base = sched.serve(qs[:64], arr[:64], seed=3)
    alt = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        max_spec_batch=16, full_batch=8, full_max_wait_s=0.1,
        edge_sync_every=1), index=sched.index)
    r_alt = alt.serve(qs[:64], arr[:64], seed=3)
    assert np.array_equal(base.t_done, r_alt.t_done)
    assert np.array_equal(base.channels, r_alt.channels)
    pal = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        max_spec_batch=16, full_batch=8, full_max_wait_s=0.1,
        backend="pallas"), index=sched.index)
    r_pal = pal.serve(qs[:64], arr[:64], seed=3)
    assert np.array_equal(base.channels, r_pal.channels)
    assert np.array_equal(base.served_ids, r_pal.served_ids)


def test_scheduler_edge_pool_overlaps_and_completes(setup):
    svc, qs, cfg, sched = setup
    arr = poisson_arrivals(len(qs), qps=60.0, seed=5)
    pooled = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        max_spec_batch=16, full_batch=8, full_max_wait_s=0.1,
        edge_replicas=3, edge_sync_every=16), index=sched.index)
    r = pooled.serve(qs, arr, seed=3)
    assert np.all(r.channels != "pending") and np.all(r.t_done >= 0)
    s = r.summary()
    assert s["max_inflight_spec_batches"] >= 2      # genuine overlap
    assert s["edge_replays"] > 0                    # bounded-lag replay ran
    assert set(r.replica_ids.tolist()) == {0, 1, 2}
    # versions are monotone along each replica's dispatch order
    assert r.cache_versions.min() >= 0
    # the pool beats the single-edge scheduler's saturated makespan
    r1 = sched.serve(qs, arr, seed=3)
    assert s["throughput_qps"] > r1.summary()["throughput_qps"]
    # staleness at a tight sync cadence costs at most a few DAR points
    assert s["dar"] >= r1.summary()["dar"] - 0.05
    # determinism of the pooled path
    r2 = pooled.serve(qs, arr, seed=3)
    assert np.array_equal(r.t_done, r2.t_done)
    assert np.array_equal(r.replica_ids, r2.replica_ids)


def test_stale_accept_audit_no_phantom_accepts(setup):
    """With the fuzzy channel off, a draft can only contain docs from the
    SERVING replica's cache — fold the delta-log prefix at each accept's
    recorded cache version and assert every served id was in it."""
    svc, qs, _, sched = setup
    cfg_nf = HasConfig(k=10, tau=0.2, h_max=400, nprobe=4, n_buckets=256,
                       d=64, use_fuzzy_validation=False,
                       use_fuzzy_enhancement=False)
    pooled = ContinuousBatchingScheduler(svc, cfg_nf, SchedulerConfig(
        max_spec_batch=16, full_batch=8, full_max_wait_s=0.1,
        edge_replicas=3, edge_sync_every=8), index=sched.index)
    pooled._keep_edge_log = True                  # retain rows for the audit
    # paced arrivals: the cache must warm (and replicas sync) while the
    # stream is still running, or nothing can accept with the fuzzy
    # channel off
    r = pooled.serve(qs, poisson_arrivals(len(qs), qps=15.0, seed=5),
                     seed=3)
    pool = pooled.edge_pool
    rows = pool.log.since(0)
    drafts = np.flatnonzero(r.channels == "draft")
    assert len(drafts) > 0
    audited = 0
    by_version = {}
    for i in drafts:
        by_version.setdefault(int(r.cache_versions[i]), []).append(i)
    for v, idxs in by_version.items():
        if v == 0:
            docs = set()
        else:
            st = _fold(cfg_nf,
                       np.stack([q for q, _, _, _ in rows[:v]]),
                       np.stack([d for _, d, _, _ in rows[:v]]),
                       np.stack([x for _, _, x, _ in rows[:v]]))
            docs = {int(x) for x in np.asarray(st.doc_ids) if x >= 0}
        for i in idxs:
            served = [int(x) for x in r.served_ids[i] if x >= 0]
            assert set(served) <= docs, (
                f"request {i} accepted on replica {r.replica_ids[i]} at "
                f"version {v} references docs outside that cache version")
            audited += 1
    assert audited == len(drafts)


def test_pool_as_replica_backend_member(setup):
    """Unification: one ReplicaBackend.on_ingest fan-out feeds a cloud
    WarmStandby AND an EdgeReplicaPool — failover/promote both rebuild the
    scheduler's final cache bit-exactly."""
    from repro.checkpoint import CheckpointManager
    from repro.retrieval.service import LocalFlatBackend, ReplicaBackend
    from repro.serving.replication import WarmStandby
    world = setup[0].world
    qs, cfg = setup[1], setup[2]
    standby = WarmStandby(cfg, CheckpointManager(tempfile.mkdtemp()),
                          snapshot_every=10**9, max_lag=10**6)
    pool = EdgeReplicaPool(cfg, n_replicas=2, sync_every=50, compact=False)
    lat = LatencyModel()
    corpus = jnp.asarray(world.doc_emb)
    svc = RetrievalService(world, lat, k=10, chunk=2048,
                           backend=ReplicaBackend(
                               LocalFlatBackend(corpus, 10, lat, chunk=2048),
                               [standby, pool], corpus))
    sch = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        max_spec_batch=16, full_batch=8, full_max_wait_s=0.1))
    sch.serve(qs[:120], None, seed=0)
    assert len(standby.log) > 0 and pool.log.head > 0
    _assert_states_equal(standby.failover(), sch.state,
                         msg="cloud standby diverged")
    _assert_states_equal(pool.promote(0), sch.state,
                         msg="edge replica diverged")


def test_edge_pool_composes_with_tenant_partitioning(setup):
    """R > 1 and T > 1 together: replica states are stacked per-tenant
    stores, delta rows carry tenant tags through replay, and the stream
    completes deterministically with no cross-tenant leakage in the
    sharing channel."""
    svc, qs, cfg, sched = setup
    T = 2
    tids = np.array([i % T for i in range(len(qs))], np.int32)
    pooled = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        max_spec_batch=16, full_batch=8, full_max_wait_s=0.1,
        n_tenants=T, edge_replicas=2, edge_sync_every=16),
        index=sched.index)
    pooled._keep_edge_log = True
    arr = poisson_arrivals(len(qs), qps=60.0, seed=5)
    r = pooled.serve(qs, arr, seed=3, tenant_ids=tids)
    assert np.all(r.channels != "pending")
    assert r.summary()["max_inflight_spec_batches"] >= 2
    # replica replay routed rows into the right partitions: a synced
    # replica equals the primary (stacked) state prefix at the log head
    pool = pooled.edge_pool
    rows = pool.log.since(0)
    pool.sync_all()
    _assert_states_equal(
        pool.states[0],
        _fold(cfg, np.stack([q for q, _, _, _ in rows]),
              np.stack([d for _, d, _, _ in rows]),
              np.stack([v for _, _, v, _ in rows]), n_tenants=T,
              tids=np.array([t for _, _, _, t in rows], np.int32)))
    _assert_states_equal(pool.states[0], pooled.state)
    # followers never cross tenants even when batches land on replicas
    sh = np.flatnonzero(r.channels == "shared")
    if len(sh):
        assert np.all(r.tenant_ids[r.leader_idx[sh]] == r.tenant_ids[sh])
    r2 = pooled.serve(qs, arr, seed=3, tenant_ids=tids)
    assert np.array_equal(r.t_done, r2.t_done)


def test_scheduler_config_validation(setup):
    svc, _, cfg, _ = setup
    with pytest.raises(ValueError):
        ContinuousBatchingScheduler(svc, cfg,
                                    SchedulerConfig(edge_replicas=0))
    with pytest.raises(ValueError):
        ContinuousBatchingScheduler(svc, cfg,
                                    SchedulerConfig(edge_sync_every=0))
    with pytest.raises(ValueError):       # quota 0 would livelock the loop
        ContinuousBatchingScheduler(svc, cfg,
                                    SchedulerConfig(tenant_quota=0))


@pytest.mark.parametrize("argv", [
    ["--edge-replicas", "0"],
    ["--edge-sync-every", "0", "--engine", "sched"],
    ["--edge-replicas", "2", "--engine", "has"],
    ["--edge-sync-every", "16", "--engine", "has"],
    ["--qps", "10", "--engine", "has"],
    ["--qps", "-1", "--engine", "sched"],
])
def test_serve_cli_rejects_invalid_edge_args(argv):
    from repro.launch.serve import main
    with pytest.raises(SystemExit):
        main(argv)
