"""Batch-native speculation + fused-ingest parity suite.

``speculate_batch(backend="pallas", interpret=True)`` must be bit-equal to
the XLA reference (``backend="xla"``) on random AND adversarial inputs —
all-invalid cache, duplicate ids across channels, tail tiles — and
``cache_update_batched`` must equal a sequential fold of ``cache_update``.
Also covers the two dedup satellite fixes (in-batch doc dedup, stale-id
normalization in ``_dedup_merge``) and the dispatch-count model.
"""
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch
from repro.core.has import (HasConfig, _dedup_merge, cache_update,
                            cache_update_batched, init_has_state, speculate,
                            speculate_batch, speculate_batched)
from repro.retrieval.ivf import build_ivf

RNG = np.random.default_rng(11)


def _world(cfg, n_corpus=256, seed=0, n_ingests=6):
    """Unit corpus + IVF index + a state warmed with real full results."""
    rng = np.random.default_rng(seed)
    corpus = rng.normal(size=(n_corpus, cfg.d)).astype(np.float32)
    corpus /= np.linalg.norm(corpus, axis=1, keepdims=True)
    index = build_ivf(jnp.asarray(corpus), cfg.n_buckets, seed=0)
    state = init_has_state(cfg)
    for _ in range(n_ingests):
        q = rng.normal(size=(cfg.d,)).astype(np.float32)
        ids = np.argsort(-(corpus @ q))[:cfg.k].astype(np.int32)
        state = cache_update(cfg, state, jnp.asarray(q), jnp.asarray(ids),
                             jnp.asarray(corpus[ids]))
    return corpus, index, state


def _assert_outputs_equal(a, b):
    for key in ("accept", "homology", "matched_slot", "val_ids",
                "draft_ids", "draft_scores"):
        x, y = np.asarray(a[key]), np.asarray(b[key])
        if np.issubdtype(x.dtype, np.floating):
            np.testing.assert_allclose(x, y, rtol=1e-6, atol=1e-6,
                                       err_msg=key)
        else:
            np.testing.assert_array_equal(x, y, err_msg=key)


@pytest.mark.parametrize("b,tile_c", [(1, 64), (5, 64), (8, 1024)])
def test_backend_parity_random(b, tile_c):
    cfg = HasConfig(k=5, tau=0.2, h_max=32, doc_capacity=96, nprobe=2,
                    n_buckets=8, d=16)
    corpus, index, state = _world(cfg)
    q = jnp.asarray(RNG.normal(size=(b, cfg.d)), jnp.float32)
    out_x = speculate_batch(cfg, state, index, q, backend="xla")
    out_p = speculate_batch(cfg, state, index, q, backend="pallas",
                            interpret=True, tile_c=tile_c)
    _assert_outputs_equal(out_x, out_p)


def test_backend_parity_all_invalid_cache():
    """Empty doc store + no valid cached queries: every channel must mask,
    nothing accepts, and no phantom ids leak into the drafts."""
    cfg = HasConfig(k=4, tau=0.2, h_max=16, doc_capacity=64, nprobe=2,
                    n_buckets=8, d=16)
    corpus, index, _ = _world(cfg, n_ingests=0)
    state = init_has_state(cfg)                      # all doc_ids == -1
    q = jnp.asarray(RNG.normal(size=(3, cfg.d)), jnp.float32)
    out_x = speculate_batch(cfg, state, index, q, backend="xla")
    out_p = speculate_batch(cfg, state, index, q, backend="pallas",
                            interpret=True, tile_c=64)
    _assert_outputs_equal(out_x, out_p)
    assert not np.asarray(out_p["accept"]).any()
    # cache-channel contribution fully masked: only fuzzy (corpus) ids
    # survive, every non-finite score carries id -1
    for out in (out_x, out_p):
        scores = np.asarray(out["draft_scores"])
        ids = np.asarray(out["draft_ids"])
        assert np.all(ids[~np.isfinite(scores)] == -1)


def test_backend_parity_duplicate_ids():
    """Doc store seeded from real full results so the fuzzy channel returns
    the same ids -> the dedup-merge path is exercised in both backends."""
    cfg = HasConfig(k=6, tau=0.1, h_max=16, doc_capacity=64, nprobe=4,
                    n_buckets=8, d=16)
    corpus, index, state = _world(cfg, n_ingests=8)
    # queries aimed at cached docs maximize cache/fuzzy overlap
    docs = np.asarray(state.doc_emb)[np.asarray(state.doc_ids) >= 0]
    q = jnp.asarray(docs[:4] + 0.01 * RNG.normal(size=(4, cfg.d)),
                    jnp.float32)
    out_x = speculate_batch(cfg, state, index, q, backend="xla")
    out_p = speculate_batch(cfg, state, index, q, backend="pallas",
                            interpret=True, tile_c=64)
    _assert_outputs_equal(out_x, out_p)
    # sanity: no draft row may contain a live duplicate id
    for row_ids in np.asarray(out_p["draft_ids"]):
        live = row_ids[row_ids >= 0]
        assert live.size == np.unique(live).size


def test_backend_parity_tail_tile():
    """doc_capacity not a multiple of tile_c: the kernel's padded tail tile
    must never contribute candidates."""
    cfg = HasConfig(k=4, tau=0.2, h_max=13, doc_capacity=100, nprobe=2,
                    n_buckets=8, d=16)
    corpus, index, state = _world(cfg, n_ingests=12)
    q = jnp.asarray(RNG.normal(size=(5, cfg.d)), jnp.float32)
    out_x = speculate_batch(cfg, state, index, q, backend="xla")
    out_p = speculate_batch(cfg, state, index, q, backend="pallas",
                            interpret=True, tile_c=64)    # 100 -> 64 + 36
    _assert_outputs_equal(out_x, out_p)


def test_xla_backend_matches_vmap_oracle():
    """The batch-first XLA program == the legacy vmap(speculate) lifting."""
    cfg = HasConfig(k=5, tau=0.2, h_max=32, doc_capacity=96, nprobe=2,
                    n_buckets=8, d=16)
    corpus, index, state = _world(cfg)
    q = jnp.asarray(RNG.normal(size=(6, cfg.d)), jnp.float32)
    out_x = speculate_batch(cfg, state, index, q, backend="xla")
    out_v = speculate_batched(cfg, state, index, q)
    _assert_outputs_equal(out_x, out_v)


def test_single_query_consistency():
    """speculate_batch on a batch of one == the sequential speculate."""
    cfg = HasConfig(k=5, tau=0.2, h_max=32, doc_capacity=96, nprobe=2,
                    n_buckets=8, d=16)
    corpus, index, state = _world(cfg)
    q = jnp.asarray(RNG.normal(size=(cfg.d,)), jnp.float32)
    out_b = speculate_batch(cfg, state, index, q[None], backend="xla")
    out_s = speculate(cfg, state, index, q)
    for key in ("accept", "homology", "val_ids", "draft_ids"):
        np.testing.assert_array_equal(np.asarray(out_b[key])[0],
                                      np.asarray(out_s[key]), err_msg=key)


# -- fused ingest ----------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 3, 9])
def test_cache_update_batched_equals_sequential_fold(seed):
    rng = np.random.default_rng(seed)
    cfg = HasConfig(k=4, h_max=5, doc_capacity=16, d=8)
    B = 11
    qe = rng.normal(size=(B, cfg.d)).astype(np.float32)
    fids = rng.integers(0, 30, size=(B, cfg.k)).astype(np.int32)
    fids[1, 2] = fids[1, 0]                      # in-batch duplicate
    fvecs = rng.normal(size=(B, cfg.k, cfg.d)).astype(np.float32)
    mask = rng.random(B) > 0.3
    mask[0] = True

    seq = init_has_state(cfg)
    for i in range(B):
        if mask[i]:
            seq = cache_update(cfg, seq, jnp.asarray(qe[i]),
                               jnp.asarray(fids[i]), jnp.asarray(fvecs[i]))
    bat = cache_update_batched(cfg, init_has_state(cfg), jnp.asarray(qe),
                               jnp.asarray(fids), jnp.asarray(fvecs),
                               jnp.asarray(mask))
    for f in ("query_emb", "query_doc_ids", "query_valid", "q_ptr",
              "doc_emb", "doc_ids", "d_ptr"):
        np.testing.assert_array_equal(np.asarray(getattr(seq, f)),
                                      np.asarray(getattr(bat, f)),
                                      err_msg=f)


def test_cache_update_batched_default_mask():
    cfg = HasConfig(k=3, h_max=4, doc_capacity=16, d=4)
    qe = jnp.asarray(RNG.normal(size=(2, 4)), jnp.float32)
    fids = jnp.asarray([[0, 1, 2], [3, 4, 5]], jnp.int32)
    fvecs = jnp.ones((2, 3, 4))
    out = cache_update_batched(cfg, init_has_state(cfg), qe, fids, fvecs)
    assert int(out.q_ptr) == 2 and int(out.d_ptr) == 6


# -- satellite dedup fixes -------------------------------------------------

def test_cache_update_dedups_within_incoming_batch():
    """Duplicate ids inside one full result must occupy ONE ring slot."""
    cfg = HasConfig(k=4, h_max=4, doc_capacity=16, d=4)
    state = init_has_state(cfg)
    ids = jnp.asarray([5, 5, 7, 5], jnp.int32)
    state = cache_update(cfg, state, jnp.ones((4,)), ids, jnp.ones((4, 4)))
    live = np.asarray(state.doc_ids)
    live = live[live >= 0]
    assert sorted(live.tolist()) == [5, 7]
    assert int(state.d_ptr) == 2                 # no wasted capacity


def test_dedup_merge_normalizes_stale_ids():
    """A dup-masked b-entry keeps -inf score AND id -1 in the merge."""
    s_a = jnp.asarray([1.0, -jnp.inf], jnp.float32)
    i_a = jnp.asarray([3, -1], jnp.int32)
    s_b = jnp.asarray([0.9, 0.8], jnp.float32)
    i_b = jnp.asarray([3, 3], jnp.int32)          # both duplicate id 3
    ts, ti = _dedup_merge(s_a, i_a, s_b, i_b, 3)
    ts, ti = np.asarray(ts), np.asarray(ti)
    assert ti[0] == 3 and np.isfinite(ts[0])
    # every non-finite merged score must carry id -1, never a stale 3
    assert np.all(ti[~np.isfinite(ts)] == -1)
    assert np.sum(ti == 3) == 1


# -- dispatch model --------------------------------------------------------

def test_batch_entry_points_are_single_dispatch():
    cfg = HasConfig(k=4, tau=0.2, h_max=16, doc_capacity=64, nprobe=2,
                    n_buckets=8, d=16)
    corpus, index, state = _world(cfg)
    q = jnp.asarray(RNG.normal(size=(4, cfg.d)), jnp.float32)
    with dispatch.capture() as probe:
        speculate_batch(cfg, state, index, q, backend="xla")
    assert probe.counts() == {"speculate_batch": 1}
    with dispatch.capture() as probe:
        cache_update_batched(
            cfg, init_has_state(cfg), q,
            jnp.zeros((4, cfg.k), jnp.int32), jnp.zeros((4, cfg.k, cfg.d)),
            jnp.zeros((4,), bool))
    assert probe.counts() == {"cache_update_batched": 1}
    with dispatch.capture() as probe:
        for i in range(4):
            speculate(cfg, state, index, q[i])   # legacy: O(B) dispatches
    assert probe.counts() == {"speculate": 4}


# -- benchmark smoke (slow: exercises the full sweep machinery) ------------

@pytest.mark.slow
def test_roofline_backend_sweep_smoke(tmp_path, monkeypatch):
    monkeypatch.setenv("BENCH_FAST", "1")
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    try:
        from benchmarks.retrieval_roofline import sweep_backends
        out = tmp_path / "BENCH_speculate.json"
        rows = sweep_backends(out_path=str(out), batches=(1, 4), reps=2)
    finally:
        sys.path.pop(0)
    assert out.exists()
    import json
    data = json.loads(out.read_text())
    assert len(data["sweep"]) == 4               # 2 backends x 2 batches
    assert all(r["dispatches_per_batch"] == 1 for r in data["sweep"])
    assert any("dispatch_verdict" in r["name"] and "PASS" in r["derived"]
               for r in rows)
