"""Tests for the §Perf optimized paths and launch utilities."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_moe_hierarchical_matches_flat():
    from repro.models import layers as L
    params = L.init_moe(jax.random.key(0), 16, 32, 4)
    x = jax.random.normal(jax.random.key(1), (2, 8, 16))
    o1, _ = L.moe(params, x, top_k=2, capacity_factor=16.0, dp_groups=1)
    o2, _ = L.moe(params, x, top_k=2, capacity_factor=16.0, dp_groups=4)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_flat_search_merge_chunks_exact(rng):
    from repro.retrieval.flat import flat_search
    corpus = jnp.asarray(rng.normal(size=(512, 16)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(3, 16)), jnp.float32)
    s1, i1 = flat_search(corpus, q, 7)
    s2, i2 = flat_search(corpus, q, 7, merge_chunks=8)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))


def test_has_rag_iterative_topk_exact(rng):
    from repro.configs.has_rag import _iterative_topk
    sc = jnp.asarray(rng.normal(size=(4, 8, 64)), jnp.float32)
    v, i = _iterative_topk(sc, 5)
    vr, ir = jax.lax.top_k(sc, 5)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), rtol=1e-6)
    assert np.array_equal(np.asarray(i), np.asarray(ir))


def test_prefill_last_position_matches_forward():
    from repro.models import transformer as tf
    cfg = tf.TransformerConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_ff=128, vocab_size=128,
                               d_head=16, remat=False)
    p = tf.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, 128)
    full, _ = tf.forward(p, toks, cfg, compute_dtype=jnp.float32)
    last = tf.prefill(p, toks, cfg, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(full[:, -1]), np.asarray(last),
                               rtol=1e-5, atol=1e-5)


def test_rules_for_mesh_drops_missing_axes():
    from repro.launch.dryrun import rules_for_mesh

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    rules = rules_for_mesh(FakeMesh())
    assert rules["batch"] == ("data",)
    assert rules["kv_seq_long"] == ("data", "model")
    assert rules["seq"] == "model"


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ag = f32[64,1024]{1,0} all-gather(f32[4,1024]{1,0} %p), replica_groups={}
  %ar = (bf16[8,8]{1,0}, bf16[8,8]{1,0}) all-reduce(%a, %b), to_apply=%sum
  %nothing = f32[2]{0} add(%x, %y)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 64 * 1024 * 4
    assert out["all-reduce"] == 2 * 8 * 8 * 2
    assert out["total"] == out["all-gather"] + out["all-reduce"]


def test_roofline_analyze_corrects_scan():
    from repro.launch.roofline import analyze
    base = {"arch": "chatglm3-6b", "shape": "train_4k", "n_devices": 256,
            "ok": True, "flops_per_device": 1e12, "bytes_per_device": 1e12,
            "collectives": {"total": 1e9}}
    u1 = dict(base, variant={"n_layers": 1, "unroll": True},
              flops_per_device=2e12, bytes_per_device=2e12,
              collectives={"total": 2e9})
    u2 = dict(base, variant={"n_layers": 2, "unroll": True},
              flops_per_device=3e12, bytes_per_device=3e12,
              collectives={"total": 3e9})
    rows = analyze([base, u1, u2])
    assert len(rows) == 1
    r = rows[0]
    # 28 layers: u1 + 27 * (u2 - u1) = 2e12 + 27e12 = 29e12
    assert abs(r["flops_per_chip"] - 29e12) < 1e9
    assert r["corrected"]


def test_compressed_allreduce_local_mesh(rng):
    from repro.launch.mesh import make_local_mesh
    from repro.training.compression import make_compressed_allreduce
    mesh = make_local_mesh()
    fn = make_compressed_allreduce(mesh, dp_axes=("data",))
    g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    e = {"w": jnp.zeros((64,), jnp.float32)}
    red, err = fn(g, e)
    # single device: reduction == dequantized value; error = quant residual
    np.testing.assert_allclose(np.asarray(red["w"] + err["w"]),
                               np.asarray(g["w"]), atol=1e-6)


def test_agentic_pipeline_runs():
    from repro.data.synthetic import SyntheticWorld, WorldConfig
    from repro.serving.agentic import AutoRagPipeline, TwoHopDataset
    from repro.serving.engine import RetrievalService
    from repro.serving.latency import LatencyModel
    world = SyntheticWorld(WorldConfig(n_entities=500, seed=0))
    svc = RetrievalService(world, LatencyModel(), k=10, chunk=1024)
    ds = TwoHopDataset(world, seed=0)
    out = AutoRagPipeline(ds, None, svc).run(ds.sample(20, seed=1))
    assert 0 <= out["accuracy"] <= 1
    assert out["retrieval_latency"] > 0
