"""Multi-tenant partitioned HaS cache: isolation + parity suite.

The tenancy contract (core/has.py::init_tenant_states + the tenant-batched
entry points):

  * T == 1 reduces BIT-EXACTLY to the single-tenant path on both backends;
  * a tenant-batched call equals running each query against its tenant's
    slice alone (per-slice oracle), still in ONE device dispatch;
  * partitions are independent: adversarial churn from one tenant leaves
    every other tenant's accepts / drafts / doc-hits bit-for-bit identical
    to a dedicated single-tenant run of its stream;
  * ``intra_batch_share`` never elects a cross-tenant follower;
  * the scheduler's weighted-fair admission + per-tenant quotas hold.

Also locks the ``cache_update_chunked`` tail-chunk contract: the final
partial chunk is padded+masked into the SAME compiled shape (no second jit
entry), asserted via the core/dispatch probe plus the jit cache size.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch
from repro.core.has import (HasConfig, _cache_update_batched_jit,
                            cache_update, cache_update_batched,
                            cache_update_chunked, init_has_state,
                            init_tenant_states, intra_batch_share,
                            speculate_batch, tenant_count, tenant_slice)
from repro.retrieval.ivf import build_ivf

RNG = np.random.default_rng(23)


def _world(cfg, n_corpus=192, seed=0):
    rng = np.random.default_rng(seed)
    corpus = rng.normal(size=(n_corpus, cfg.d)).astype(np.float32)
    corpus /= np.linalg.norm(corpus, axis=1, keepdims=True)
    index = build_ivf(jnp.asarray(corpus), cfg.n_buckets, seed=0)
    return corpus, index


def _full_ids(corpus, q, k):
    return np.argsort(-(corpus @ q))[:k].astype(np.int32)


def _warm_pair(cfg, corpus, n=6, seed=0):
    """An unstacked state and a T=1 stacked state warmed identically."""
    rng = np.random.default_rng(seed)
    s1, sT = init_has_state(cfg), init_tenant_states(cfg, 1)
    for _ in range(n):
        q = rng.normal(size=(cfg.d,)).astype(np.float32)
        ids = _full_ids(corpus, q, cfg.k)
        vecs = jnp.asarray(corpus[ids])
        s1 = cache_update(cfg, s1, jnp.asarray(q), jnp.asarray(ids), vecs)
        sT = cache_update(cfg, sT, jnp.asarray(q), jnp.asarray(ids), vecs,
                          tenant_id=0)
    return s1, sT


def _cfg(**kw):
    base = dict(k=5, tau=0.2, h_max=16, doc_capacity=48, nprobe=2,
                n_buckets=8, d=16)
    base.update(kw)
    return HasConfig(**base)


# -- core: shapes + T=1 reduction ------------------------------------------

def test_init_tenant_states_shapes():
    cfg = _cfg()
    st = init_tenant_states(cfg, 3)
    assert st.query_emb.shape == (3, cfg.h_max, cfg.d)
    assert st.query_doc_ids.shape == (3, cfg.h_max, cfg.k)
    assert st.doc_ids.shape == (3, cfg.doc_cap)
    assert st.q_ptr.shape == (3,) and st.d_ptr.shape == (3,)
    assert tenant_count(st) == 3
    assert tenant_count(init_has_state(cfg)) == 1
    sl = tenant_slice(st, 1)
    assert sl.query_emb.shape == (cfg.h_max, cfg.d)
    with pytest.raises(ValueError):
        init_tenant_states(cfg, 0)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_t1_reduces_bit_exact(backend):
    """speculate_batch on a [1, ...] stacked store with tenant_ids == 0 is
    bit-identical to the current single-tenant path (acceptance #4)."""
    cfg = _cfg()
    corpus, index = _world(cfg)
    s1, sT = _warm_pair(cfg, corpus)
    q = jnp.asarray(RNG.normal(size=(7, cfg.d)), jnp.float32)
    kw = dict(interpret=True, tile_c=32) if backend == "pallas" else {}
    o1 = speculate_batch(cfg, s1, index, q, backend=backend, **kw)
    oT = speculate_batch(cfg, sT, index, q, backend=backend,
                         tenant_ids=jnp.zeros((7,), jnp.int32), **kw)
    for key in ("accept", "homology", "matched_slot", "val_ids",
                "draft_ids", "draft_scores"):
        np.testing.assert_array_equal(np.asarray(o1[key]),
                                      np.asarray(oT[key]), err_msg=key)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_tenant_batch_matches_per_slice_oracle(backend):
    """A mixed-tenant batch == each query run against its slice alone."""
    cfg = _cfg()
    corpus, index = _world(cfg)
    T = 3
    stM = init_tenant_states(cfg, T)
    sts = [init_has_state(cfg) for _ in range(T)]
    rng = np.random.default_rng(5)
    for i in range(9):
        t = i % T
        q = rng.normal(size=(cfg.d,)).astype(np.float32)
        ids = _full_ids(corpus, q, cfg.k)
        vecs = jnp.asarray(corpus[ids])
        stM = cache_update(cfg, stM, jnp.asarray(q), jnp.asarray(ids), vecs,
                           tenant_id=t)
        sts[t] = cache_update(cfg, sts[t], jnp.asarray(q), jnp.asarray(ids),
                              vecs)
    q = jnp.asarray(rng.normal(size=(6, cfg.d)), jnp.float32)
    tids = jnp.asarray(np.array([0, 1, 2, 2, 1, 0], np.int32))
    kw = dict(interpret=True, tile_c=32) if backend == "pallas" else {}
    oM = speculate_batch(cfg, stM, index, q, backend=backend,
                         tenant_ids=tids, **kw)
    for i in range(6):
        o1 = speculate_batch(cfg, sts[int(tids[i])], index, q[i][None],
                             backend=backend, **kw)
        for key in ("accept", "homology", "val_ids", "draft_ids"):
            np.testing.assert_array_equal(
                np.asarray(oM[key])[i], np.asarray(o1[key])[0],
                err_msg=f"{key}[{i}]")
        # matched_slot is flat over [T*H]: tenant t's slot s at t*h_max + s
        # (only meaningful on a real match — an all-zero score row argmaxes
        # to global slot 0 in the flat layout, slot 0 in the sliced one)
        if float(np.asarray(oM["homology"])[i]) > 0:
            exp = int(tids[i]) * cfg.h_max \
                + int(np.asarray(o1["matched_slot"])[0])
            assert int(np.asarray(oM["matched_slot"])[i]) == exp


def test_tenant_entry_points_single_dispatch():
    """Acceptance #4: tenant-batched speculation and ingest stay ONE device
    dispatch per batch on both backends."""
    cfg = _cfg()
    corpus, index = _world(cfg)
    st = init_tenant_states(cfg, 4)
    q = jnp.asarray(RNG.normal(size=(8, cfg.d)), jnp.float32)
    tids = jnp.asarray(np.arange(8, dtype=np.int32) % 4)
    for backend, kw in (("xla", {}),
                        ("pallas", dict(interpret=True, tile_c=32))):
        with dispatch.capture() as probe:
            speculate_batch(cfg, st, index, q, backend=backend,
                            tenant_ids=tids, **kw)
        assert probe.counts() == {"speculate_batch": 1}, backend
    with dispatch.capture() as probe:
        cache_update_batched(
            cfg, st, q, jnp.zeros((8, cfg.k), jnp.int32),
            jnp.zeros((8, cfg.k, cfg.d)), jnp.zeros((8,), bool),
            tenant_ids=tids)
    assert probe.counts() == {"cache_update_batched": 1}


def test_cache_update_batched_tenant_scatter_equals_fold():
    cfg = _cfg(h_max=5, doc_capacity=16, d=8, k=4, n_buckets=4)
    rng = np.random.default_rng(3)
    T, B = 3, 13
    qe = rng.normal(size=(B, cfg.d)).astype(np.float32)
    fids = rng.integers(0, 30, size=(B, cfg.k)).astype(np.int32)
    fvecs = rng.normal(size=(B, cfg.k, cfg.d)).astype(np.float32)
    mask = rng.random(B) > 0.25
    tids = rng.integers(0, T, B).astype(np.int32)
    bat = cache_update_batched(cfg, init_tenant_states(cfg, T),
                               jnp.asarray(qe), jnp.asarray(fids),
                               jnp.asarray(fvecs), jnp.asarray(mask),
                               tenant_ids=jnp.asarray(tids))
    seq = [init_has_state(cfg) for _ in range(T)]
    for i in range(B):
        if mask[i]:
            t = int(tids[i])
            seq[t] = cache_update(cfg, seq[t], jnp.asarray(qe[i]),
                                  jnp.asarray(fids[i]), jnp.asarray(fvecs[i]))
    for t in range(T):
        sl = tenant_slice(bat, t)
        for f in ("query_emb", "query_doc_ids", "query_valid", "q_ptr",
                  "doc_emb", "doc_ids", "d_ptr"):
            np.testing.assert_array_equal(
                np.asarray(getattr(sl, f)), np.asarray(getattr(seq[t], f)),
                err_msg=f"t{t}:{f}")


def test_stacked_state_requires_tenant_ids():
    cfg = _cfg()
    corpus, index = _world(cfg)
    st = init_tenant_states(cfg, 2)
    q = jnp.zeros((2, cfg.d))
    with pytest.raises(ValueError):
        speculate_batch(cfg, st, index, q, backend="xla")
    with pytest.raises(ValueError):
        cache_update_batched(cfg, st, q, jnp.zeros((2, cfg.k), jnp.int32),
                             jnp.zeros((2, cfg.k, cfg.d)))
    with pytest.raises(ValueError):
        speculate_batch(cfg, init_has_state(cfg), index, q, backend="xla",
                        tenant_ids=jnp.zeros((2,), jnp.int32))
    # cache_update: same guards + range check (a silently-dropped scatter
    # would leave the tenant's cache forever cold)
    one = jnp.zeros((cfg.d,))
    ids1 = jnp.zeros((cfg.k,), jnp.int32)
    vecs1 = jnp.zeros((cfg.k, cfg.d))
    with pytest.raises(ValueError):
        cache_update(cfg, st, one, ids1, vecs1)           # stacked, no id
    with pytest.raises(ValueError):
        cache_update(cfg, init_has_state(cfg), one, ids1, vecs1,
                     tenant_id=0)                          # unstacked + id
    with pytest.raises(ValueError):
        cache_update(cfg, st, one, ids1, vecs1, tenant_id=2)  # range


def test_engines_reject_out_of_range_tenant_tags(sched_setup):
    from repro.serving.batched import BatchedHasEngine
    from repro.serving.engine import HasEngine
    svc, qs, cfg = sched_setup
    eng = HasEngine(svc, cfg, n_tenants=2)
    with pytest.raises(ValueError):
        eng.step(qs[0]["emb"], tenant=2)
    bad = [dict(q, tenant=3) for q in qs[:4]]
    bat = BatchedHasEngine(svc, cfg, batch_size=4, n_tenants=2)
    with pytest.raises(ValueError):
        bat.serve(bad)


def test_multi_tenant_standby_requires_tenant_ids(tmp_path):
    from repro.checkpoint import CheckpointManager
    from repro.serving.replication import WarmStandby
    cfg = _cfg(d=8, k=4, h_max=8, doc_capacity=32)
    sb = WarmStandby(cfg, CheckpointManager(str(tmp_path)), n_tenants=2)
    qs = np.zeros((2, cfg.d), np.float32)
    ids = np.zeros((2, cfg.k), np.int32)
    vecs = np.zeros((2, cfg.k, cfg.d), np.float32)
    st = init_tenant_states(cfg, 2)
    with pytest.raises(ValueError):
        sb.record_batch(qs, ids, vecs, st)                 # no tenant_ids
    with pytest.raises(ValueError):
        sb.record_batch(qs, ids, vecs, st,
                        tenant_ids=np.array([0, 5], np.int32))  # range
    sb.record_batch(qs, ids, vecs, st,
                    tenant_ids=np.array([0, 1], np.int32))
    assert [len(log) for log in sb.logs] == [1, 1]


# -- intra-batch sharing isolation -----------------------------------------

def test_intra_batch_share_never_crosses_tenants():
    """Perfectly homologous drafts in different tenants must NOT share; the
    same drafts in one tenant must."""
    k = 4
    ids = np.tile(np.array([3, 7, 11, 19], np.int32), (4, 1))  # identical
    rej = jnp.ones((4,), bool)
    tau = jnp.float32(0.5)
    # same tenant: one leader, three followers
    out_same = intra_batch_share(jnp.asarray(ids), rej, tau, None,
                                 jnp.zeros((4,), jnp.int32))
    assert int(np.asarray(out_same["is_leader"]).sum()) == 1
    assert np.all(np.asarray(out_same["leader"]) == 0)
    # alternating tenants: per-tenant leaders only, followers stay inside
    tids = np.array([0, 1, 0, 1], np.int32)
    out = intra_batch_share(jnp.asarray(ids), rej, tau, None,
                            jnp.asarray(tids))
    lead = np.asarray(out["leader"])
    assert np.all(tids[lead] == tids), "cross-tenant follower elected"
    assert np.asarray(out["is_leader"])[0] and np.asarray(out["is_leader"])[1]
    assert lead[2] == 0 and lead[3] == 1


@pytest.mark.parametrize("trial", range(3))
def test_intra_batch_share_random_never_crosses(trial):
    rng = np.random.default_rng(trial)
    b, k, T = 24, 5, 3
    ids = rng.integers(0, 12, size=(b, k)).astype(np.int32)  # heavy overlap
    rej = rng.random(b) > 0.3
    pend = (~rej) & (rng.random(b) > 0.5)
    tids = rng.integers(0, T, b).astype(np.int32)
    out = intra_batch_share(jnp.asarray(ids), jnp.asarray(rej),
                            jnp.float32(0.2), jnp.asarray(pend),
                            jnp.asarray(tids))
    lead = np.asarray(out["leader"])
    followers = rej & ~np.asarray(out["is_leader"])
    assert np.all(tids[lead[followers]] == tids[followers])


# -- the isolation property (acceptance #3), core level --------------------

def test_isolation_bit_for_bit_under_adversarial_churn():
    """T=4; tenant 0 churns adversarially (every query rejected + ingested,
    wrapping its FIFO rings many times).  Every victim tenant's accepts,
    drafts and cache trajectory are BIT-FOR-BIT what a dedicated
    single-tenant cache of the same capacity produces on its stream alone.

    Driver: round-robin interleave, one query per tenant per fused batch,
    rejects ingested (tenant-scattered) after each batch — the dedicated
    baselines see the identical per-tenant sequence at B=1.
    """
    cfg = _cfg(h_max=6, doc_capacity=12, tau=0.3)   # tiny rings: churn wraps
    corpus, index = _world(cfg, n_corpus=256)
    T, steps = 4, 18
    rng = np.random.default_rng(9)
    # victims revisit a small pool of queries (homology-heavy); the churn
    # tenant never repeats (every query ingests, evicting its own ring only)
    pools = [rng.normal(size=(3, cfg.d)).astype(np.float32)
             for _ in range(T - 1)]
    streams = [[] for _ in range(T)]
    for i in range(steps):
        streams[0].append(rng.normal(size=(cfg.d,)).astype(np.float32))
        for t in range(1, T):
            base = pools[t - 1][i % 3]
            streams[t].append(
                (base + 0.01 * rng.normal(size=(cfg.d,))).astype(np.float32))

    def drive_multi():
        st = init_tenant_states(cfg, T)
        acc = [[] for _ in range(T)]
        drafts = [[] for _ in range(T)]
        tids = jnp.asarray(np.arange(T, dtype=np.int32))
        for i in range(steps):
            q = np.stack([streams[t][i] for t in range(T)])
            out = speculate_batch(cfg, st, index, jnp.asarray(q),
                                  backend="xla", tenant_ids=tids)
            a = np.asarray(out["accept"])
            for t in range(T):
                acc[t].append(bool(a[t]))
                drafts[t].append(np.asarray(out["draft_ids"])[t])
            rej = np.flatnonzero(~a)
            if len(rej):
                fids = np.stack([_full_ids(corpus, q[j], cfg.k)
                                 for j in rej])
                st = cache_update_batched(
                    cfg, st, jnp.asarray(q[rej]), jnp.asarray(fids),
                    jnp.asarray(corpus[fids]),
                    tenant_ids=jnp.asarray(np.asarray(rej, np.int32)))
        return acc, drafts, st

    def drive_dedicated(t):
        st = init_has_state(cfg)
        acc, drafts = [], []
        for i in range(steps):
            q = streams[t][i]
            out = speculate_batch(cfg, st, index, jnp.asarray(q)[None],
                                  backend="xla")
            a = bool(np.asarray(out["accept"])[0])
            acc.append(a)
            drafts.append(np.asarray(out["draft_ids"])[0])
            if not a:
                fids = _full_ids(corpus, q, cfg.k)
                st = cache_update(cfg, st, jnp.asarray(q),
                                  jnp.asarray(fids),
                                  jnp.asarray(corpus[fids]))
        return acc, drafts, st

    accM, draftsM, stM = drive_multi()
    # churn actually wrapped tenant 0's rings (the adversarial condition)
    assert int(tenant_slice(stM, 0).d_ptr) > cfg.doc_cap
    for t in range(1, T):
        accD, draftsD, stD = drive_dedicated(t)
        assert accM[t] == accD, f"tenant {t} accept stream diverged"
        for i in range(steps):
            np.testing.assert_array_equal(draftsM[t][i], draftsD[i],
                                          err_msg=f"t{t} draft {i}")
        sl = tenant_slice(stM, t)
        for f in ("query_emb", "query_doc_ids", "query_valid", "q_ptr",
                  "doc_emb", "doc_ids", "d_ptr"):
            np.testing.assert_array_equal(
                np.asarray(getattr(sl, f)), np.asarray(getattr(stD, f)),
                err_msg=f"t{t}:{f}")
    # and at least one victim actually accepted something (the property is
    # not vacuous: victims keep their homology window under churn)
    assert any(any(accM[t]) for t in range(1, T))


# -- chunked tail: one compiled shape (satellite) --------------------------

def test_chunked_tail_chunk_reuses_compiled_shape():
    """The final partial chunk pads+masks into the SAME [chunk, ...] shape:
    no second jit entry, one dispatch per chunk."""
    cfg = _cfg(h_max=8, doc_capacity=32, d=8, k=4)
    chunk = 4
    rng = np.random.default_rng(1)

    def rows(n):
        return (rng.normal(size=(n, cfg.d)).astype(np.float32),
                rng.integers(0, 40, size=(n, cfg.k)).astype(np.int32),
                rng.normal(size=(n, cfg.k, cfg.d)).astype(np.float32))

    # warm the [chunk, ...] shape with a full chunk
    qe, fi, fv = rows(chunk)
    state = cache_update_chunked(cfg, init_has_state(cfg), qe, fi, fv,
                                 chunk=chunk)
    warm = _cache_update_batched_jit._cache_size()
    # 10 rows -> 2 full chunks + a 2-row tail: 3 dispatches, 0 recompiles
    qe, fi, fv = rows(10)
    with dispatch.capture() as probe:
        state = cache_update_chunked(cfg, state, qe, fi, fv, chunk=chunk)
    assert probe.counts() == {"cache_update_batched": 3}
    assert _cache_update_batched_jit._cache_size() == warm, \
        "tail chunk jitted a second shape"
    # parity: padded+masked tail == a plain sequential fold
    seq = cache_update_chunked(cfg, init_has_state(cfg), qe[:10], fi[:10],
                               fv[:10], chunk=10)
    ref = init_has_state(cfg)
    for i in range(10):
        ref = cache_update(cfg, ref, jnp.asarray(qe[i]), jnp.asarray(fi[i]),
                           jnp.asarray(fv[i]))
    np.testing.assert_array_equal(np.asarray(seq.doc_ids),
                                  np.asarray(ref.doc_ids))


# -- scheduler-level tenancy -----------------------------------------------

@pytest.fixture(scope="module")
def sched_setup():
    from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
    from repro.serving.engine import RetrievalService
    from repro.serving.latency import LatencyModel
    world = SyntheticWorld(WorldConfig(n_entities=600, seed=0))
    svc = RetrievalService(world, LatencyModel(), k=10, chunk=2048)
    ds = DATASETS["granola"]
    qs = world.sample_queries(240, pattern=ds["pattern"],
                              zipf_a=ds["zipf_a"],
                              p_uncovered=ds["p_uncovered"], seed=1)
    cfg = HasConfig(k=10, tau=0.2, h_max=300, nprobe=4, n_buckets=256, d=64)
    return svc, list(qs), cfg


def test_scheduler_multi_tenant_isolation_invariants(sched_setup):
    from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                         SchedulerConfig)
    svc, qs, cfg = sched_setup
    tids = np.arange(len(qs), dtype=np.int32) % 3
    sched = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        max_spec_batch=16, full_batch=8, full_max_wait_s=0.1, n_tenants=3))
    r = sched.serve(qs, None, seed=0, tenant_ids=tids)
    assert np.all(r.channels != "pending") and np.all(r.t_done >= 0)
    # every shared follower's leader belongs to the follower's tenant
    sh = np.flatnonzero(r.channels == "shared")
    assert len(sh) > 0
    assert np.all(r.leader_idx[sh] >= 0)
    assert np.all(r.tenant_ids[r.leader_idx[sh]] == r.tenant_ids[sh])
    # per-tenant slices partition the stream
    per = r.per_tenant()
    assert sorted(per) == [0, 1, 2]
    assert sum(p["n"] for p in per.values()) == len(qs)
    assert sum(p["full_retrievals"] for p in per.values()) \
        == r.full_retrievals
    # deterministic replay with tenants
    r2 = sched.serve(qs, None, seed=0, tenant_ids=tids)
    assert np.array_equal(r.latencies, r2.latencies)
    assert np.array_equal(r.channels, r2.channels)
    # out-of-range tenant ids are rejected
    with pytest.raises(ValueError):
        sched.serve(qs, None, seed=0,
                    tenant_ids=np.full(len(qs), 7, np.int32))


def test_scheduler_tenant_quota_caps_batch_share(sched_setup):
    """With tenant_quota=q, one tenant alone can fill at most q rows per
    speculation batch -> at least ceil(n/q) batches."""
    from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                         SchedulerConfig)
    svc, qs, cfg = sched_setup
    qs = qs[:64]
    sched = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        max_spec_batch=16, full_batch=8, full_max_wait_s=0.1, n_tenants=2,
        tenant_quota=4))
    r = sched.serve(qs, None, seed=0,
                    tenant_ids=np.zeros(len(qs), np.int32))
    assert r.spec_batches >= int(np.ceil(len(qs) / 4))
    free = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        max_spec_batch=16, full_batch=8, full_max_wait_s=0.1, n_tenants=2),
        index=sched.index)
    r0 = free.serve(qs, None, seed=0,
                    tenant_ids=np.zeros(len(qs), np.int32))
    assert r0.spec_batches < r.spec_batches


def test_scheduler_weighted_fair_protects_minority_tenant(sched_setup):
    """All requests arrive at t=0 with tenant 0's 64 ahead of tenant 1's 16
    in FIFO order.  Equal-weight fairness interleaves both tenants from
    the first batches; skewing the weights massively toward tenant 0
    (tenant 0 drains first, the old FIFO behavior) must make tenant 1
    measurably slower — i.e. fairness is real and weight-controlled."""
    from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                         SchedulerConfig)
    svc, qs, cfg = sched_setup
    qs = qs[:80]
    tids = np.zeros(len(qs), np.int32)
    tids[64:] = 1                       # minority tenant, admitted last
    kw = dict(max_spec_batch=16, full_batch=8, full_max_wait_s=0.1,
              n_tenants=2)
    fair = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(**kw))
    r_fair = fair.serve(qs, None, seed=0, tenant_ids=tids)
    skew = ContinuousBatchingScheduler(
        svc, cfg, SchedulerConfig(tenant_weights=(1e6, 1.0), **kw),
        index=fair.index)
    r_skew = skew.serve(qs, None, seed=0, tenant_ids=tids)
    wait_fair = (r_fair.t_done - r_fair.t_arrive)[tids == 1].mean()
    wait_skew = (r_skew.t_done - r_skew.t_arrive)[tids == 1].mean()
    assert wait_fair < wait_skew


def test_scheduler_t1_bit_identical_to_legacy_config(sched_setup):
    """n_tenants=1 (the default) and an explicit 1-entry weights tuple both
    take the historical single-tenant path, bit-identically."""
    from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                         SchedulerConfig)
    svc, qs, cfg = sched_setup
    a = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        max_spec_batch=16, full_batch=8, full_max_wait_s=0.1))
    b = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        max_spec_batch=16, full_batch=8, full_max_wait_s=0.1, n_tenants=1,
        tenant_weights=(2.0,)), index=a.index)
    ra = a.serve(qs, None, seed=0)
    rb = b.serve(qs, None, seed=0)
    assert np.array_equal(ra.latencies, rb.latencies)
    assert np.array_equal(ra.channels, rb.channels)
    assert ra.full_retrievals == rb.full_retrievals


# -- launch/serve.py argument validation (satellite) -----------------------

@pytest.mark.parametrize("argv", [
    ["--shards", "0", "--retrieval-backend", "sharded"],
    ["--workers", "0", "--retrieval-backend", "sharded"],
    ["--workers", "2"],                       # flat backend: no workers
    ["--workers", "2", "--retrieval-backend", "flat"],
    ["--tenants", "0"],
    ["--tenants", "-3"],
    ["--tenant-zipf", "-1", "--tenants", "2"],
    ["--tenants", "2", "--engine", "full"],
])
def test_serve_cli_rejects_invalid_args(argv):
    from repro.launch.serve import main
    with pytest.raises(SystemExit) as e:
        main(argv)
    assert e.value.code == 2                  # argparse usage error


def test_serve_cli_accepts_valid_combos():
    """Validation must not reject the documented combinations (parse-only:
    monkeypatching would be heavier than just checking no SystemExit(2)
    before the world is built — so use a tiny world)."""
    from repro.launch.serve import main
    main(["--queries", "24", "--entities", "120", "--h-max", "60",
          "--tenants", "2", "--tenant-zipf", "0"])
