"""Hybrid lexical+dense retrieval with single-dispatch fused RRF reranking.

Covers the lexical-score and fused-rerank kernels against their XLA oracles
(bit-parity, including adversarial empty postings rows, all-invalid pools
and cross-channel duplicate ids), the ``HybridBackend`` one-dispatch-per-
batch probe on both scan backends, the rank-domain monotone-invariance
property of RRF fusion and of the fused-list homology validation
(``HasConfig.fusion == "rrf"``), live ingest threading both channels,
``ReplicaBackend`` composition, the serve-CLI knob validation, and the
scheduler end-to-end doc-hit lift on a corrupted-dense-embedding corpus.

The CI `hybrid-fusion` job runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` alongside the
``benchmarks/sched_throughput.py --sweep-fusion`` verdicts.
"""
import functools

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import dispatch
from repro.core.has import HasConfig, _rrf_merge
from repro.core.homology import (homology_scores_weighted, rrf_draft_weights)
from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
from repro.kernels import ops, ref
from repro.retrieval.lexical import (attr_term, build_doc_terms, entity_term,
                                     lexical_topk, query_terms)
from repro.retrieval.service import (HybridBackend, LocalFlatBackend,
                                     ReplicaBackend, RetrievalService)
from repro.serving.latency import LatencyModel


@functools.lru_cache(maxsize=1)
def _world():
    return SyntheticWorld(WorldConfig(n_entities=240, seed=0))


def _query_batch(world, n, seed=3):
    qs = world.sample_queries(n, seed=seed)
    embs = jnp.asarray(np.stack([q["emb"] for q in qs]))
    terms = jnp.asarray(np.stack([q["terms"] for q in qs]).astype(np.int32))
    tws = jnp.asarray(np.stack([q["term_weights"]
                                for q in qs]).astype(np.float32))
    return qs, embs, terms, tws


# -- lexical-score kernel <-> oracle parity --------------------------------

def test_lexical_kernel_parity_adversarial():
    """Bit-parity on postings with empty (-1) rows, docs with no matching
    term, and an odd tail tile; no-match rows surface as -inf / -1."""
    rng = np.random.default_rng(0)
    n, l_w, b, t, k = 700, 3, 6, 2, 8       # n not a tile multiple
    doc_terms = rng.integers(0, 50, size=(n, l_w)).astype(np.int32)
    doc_w = rng.uniform(0.1, 1.0, size=(n, l_w)).astype(np.float32)
    doc_terms[::7] = -1                      # empty postings rows
    doc_w[doc_terms < 0] = 0.0
    q_terms = rng.integers(0, 50, size=(b, t)).astype(np.int32)
    q_w = rng.uniform(0.1, 1.0, size=(b, t)).astype(np.float32)
    q_terms[0] = -1                          # term-less query row
    vk, ik = ops.lexical_score(jnp.asarray(q_terms), jnp.asarray(q_w),
                               jnp.asarray(doc_terms), jnp.asarray(doc_w),
                               k, tile_n=256, interpret=True)
    vr, ir = ref.lexical_score_ref(jnp.asarray(q_terms), jnp.asarray(q_w),
                                   jnp.asarray(doc_terms),
                                   jnp.asarray(doc_w), k, tile_n=256)
    assert np.array_equal(np.asarray(vk), np.asarray(vr))
    assert np.array_equal(np.asarray(ik), np.asarray(ir))
    assert np.all(np.asarray(ik)[0] == -1)   # term-less query matches nothing
    assert not np.isin(np.arange(0, n, 7), np.asarray(ik)).any()


def test_lexical_channel_ranks_golden_docs_first():
    """A query's (entity, attr) terms rank that entity's attr-covering docs
    above its other docs — scores 1.49 vs 1.0 (module docstring)."""
    w = _world()
    e = int(w.doc_entity[0])
    attr = int(np.flatnonzero(w.entity_attrs[e])[0])
    qt, qw = query_terms(e, attr)
    vals, idx = lexical_topk(jnp.asarray(qt)[None], jnp.asarray(qw)[None],
                             jnp.asarray(w.doc_terms),
                             jnp.asarray(w.doc_term_weights), 5,
                             backend="xla")
    idx = np.asarray(idx)[0]
    assert (w.doc_entity[idx] == e).all()
    top = idx[np.asarray(vals)[0] >= 1.4]
    assert len(top) and w.doc_attr_mask[top, attr].all()


def test_lexical_hash_disperses():
    """Entity and pair terms must not collide trivially (same entity's
    attr terms differ from its entity term and from each other)."""
    e = np.arange(64)
    assert len(set(entity_term(e).tolist())) == 64
    a0, a1 = attr_term(e, 0), attr_term(e, 1)
    assert not np.any(a0 == a1)
    assert not np.any(entity_term(e) == a0)


# -- fused-rerank kernel <-> oracle parity ---------------------------------

@pytest.mark.parametrize("dsim", [None, 0.9])
def test_fused_rerank_parity_adversarial(dsim):
    """Bit-parity incl. an all-invalid pool and cross-channel dup ids."""
    rng = np.random.default_rng(1)
    b, d, kd, kl, k = 8, 16, 6, 6, 5
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    ids = rng.integers(0, 30, size=(b, kd + kl)).astype(np.int32)
    ids[0, :] = -1                           # nothing retrieved at all
    ids[1, kd:] = ids[1, :kl]                # lexical repeats dense exactly
    vecs = rng.normal(size=(b, kd + kl, d)).astype(np.float32)
    vecs[ids < 0] = 0.0
    vk, ik = ops.fused_rerank(q, jnp.asarray(ids), jnp.asarray(vecs),
                              kd=kd, k=k, rrf_k=60.0, diversify_sim=dsim,
                              interpret=True)
    vr, ir = ref.fused_rerank_ref(q, jnp.asarray(ids), jnp.asarray(vecs),
                                  kd=kd, k=k, rrf_k=60.0,
                                  diversify_sim=dsim)
    assert np.array_equal(np.asarray(vk), np.asarray(vr))
    assert np.array_equal(np.asarray(ik), np.asarray(ir))
    assert np.all(np.asarray(ik)[0] == -1)   # empty pool -> empty result
    out1 = np.asarray(ik)[1]
    ids1 = out1[out1 >= 0]
    assert len(ids1) == len(set(ids1.tolist()))   # dups served at most once


def test_fused_rerank_duplicate_mass_wins():
    """A doc in BOTH channels outranks same-rank single-channel docs: its
    RRF mass is the sum of both occurrences."""
    d, kd, kl = 8, 3, 3
    q = jnp.zeros((1, d))
    # dense [10, 11, 12], lexical [20, 10, 21]: doc 10 holds rank 0 dense +
    # rank 1 lexical -> mass 1/60 + 1/61, beating every single occurrence
    ids = jnp.asarray(np.array([[10, 11, 12, 20, 10, 21]], np.int32))
    vecs = jnp.asarray(np.eye(kd + kl, d, dtype=np.float32))[None]
    vals, out = ref.fused_rerank_ref(q, ids, vecs, kd=kd, k=4, rrf_k=60.0)
    out, vals = np.asarray(out)[0], np.asarray(vals)[0]
    assert out[0] == 10
    assert np.isclose(vals[0], 1 / 60.0 + 1 / 61.0)


def test_fused_rerank_mass_ordering_monotone_invariant():
    """The fused ordering is pure rank domain: replacing either channel's
    raw scores with any positive monotone transform leaves the channel
    top-k ids — and therefore the fused output — bit-identical."""
    rng = np.random.default_rng(2)
    n, d, k = 120, 12, 6
    dense_raw = rng.normal(size=n)
    lex_raw = rng.uniform(0.1, 5.0, size=n)
    corpus = rng.normal(size=(n, d)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(1, d)).astype(np.float32))

    def fused(ds, ls):
        i_d = np.argsort(-ds, kind="stable")[:k].astype(np.int32)
        i_l = np.argsort(-ls, kind="stable")[:k].astype(np.int32)
        ids = np.concatenate([i_d, i_l])[None]
        vecs = corpus[ids[0]][None]
        _, out = ref.fused_rerank_ref(q, jnp.asarray(ids),
                                      jnp.asarray(vecs), kd=k, k=k,
                                      rrf_k=60.0, diversify_sim=0.95)
        return np.asarray(out)

    base = fused(dense_raw, lex_raw)
    for f_d, f_l in ((np.exp, np.tanh),
                     (lambda x: 3.0 * x + 7.0, np.exp),
                     (np.tanh, lambda x: x ** 3)):
        assert np.array_equal(base, fused(f_d(dense_raw), f_l(lex_raw)))


# -- HybridBackend: parity, dispatch discipline, degradation ---------------

@pytest.mark.parametrize("dense", ["flat", "sharded", "ann"])
def test_hybrid_backend_pallas_xla_bit_parity(dense):
    w = _world()
    corpus = jnp.asarray(w.doc_emb)
    lat = LatencyModel()
    _, embs, terms, tws = _query_batch(w, 16)
    outs = {}
    for be in ("pallas", "xla"):
        hb = HybridBackend(corpus, 10, lat, w.doc_terms,
                           w.doc_term_weights, dense=dense, backend=be,
                           n_shards=2)
        s, i = hb.search(embs, q_terms=terms, q_term_weights=tws)
        outs[be] = (np.asarray(s), np.asarray(i))
    assert np.array_equal(outs["pallas"][0], outs["xla"][0])
    assert np.array_equal(outs["pallas"][1], outs["xla"][1])


@pytest.mark.parametrize("be", ["pallas", "xla"])
@pytest.mark.parametrize("dense", ["flat", "ann"])
def test_hybrid_single_dispatch_per_batch(be, dense):
    """Channel scans + RRF fusion + diversification + rerank cost exactly
    ONE host dispatch per warm [B, d] batch at B=32."""
    w = _world()
    lat = LatencyModel()
    hb = HybridBackend(jnp.asarray(w.doc_emb), 10, lat, w.doc_terms,
                       w.doc_term_weights, dense=dense, backend=be)
    _, embs, terms, tws = _query_batch(w, 32)
    hb.search(embs, q_terms=terms,
              q_term_weights=tws)[1].block_until_ready()        # warm jit
    with dispatch.capture() as cpt:
        hb.search(embs, q_terms=terms,
                  q_term_weights=tws)[1].block_until_ready()
    assert cpt.total() == 1, dict(cpt.counts())


def test_hybrid_termless_degrades_to_dense():
    """Queries without term arrays (warmup, embedding-only engines) run
    the same program with an inert lexical channel: with diversification
    off the fused list is exactly the dense top-k."""
    w = _world()
    lat = LatencyModel()
    corpus = jnp.asarray(w.doc_emb)
    _, embs, _, _ = _query_batch(w, 8)
    hb = HybridBackend(corpus, 10, lat, w.doc_terms, w.doc_term_weights,
                       diversify_sim=None, backend="xla")
    _, ids_h = hb.search(embs)
    _, ids_d = LocalFlatBackend(corpus, 10, lat).search(embs)
    assert np.array_equal(np.asarray(ids_h), np.asarray(ids_d))


def test_hybrid_latency_model_and_knob_validation():
    w = _world()
    lat = LatencyModel()
    corpus = jnp.asarray(w.doc_emb)
    hb = HybridBackend(corpus, 10, lat, w.doc_terms, w.doc_term_weights)
    # hybrid = dense channel + postings stream + fusion: strictly more
    # expensive than the flat dense-only scan, but within the bench budget
    flat = LocalFlatBackend(corpus, 10, lat)
    assert flat.latency(1) < hb.latency(1) <= 1.25 * flat.latency(1)
    # narrower postings cost less
    hb1 = HybridBackend(corpus, 10, lat, w.doc_terms, w.doc_term_weights,
                        lexical_terms=1)
    assert hb1.latency(1) < hb.latency(1)
    with pytest.raises(ValueError):
        HybridBackend(corpus, 10, lat, w.doc_terms, w.doc_term_weights,
                      rrf_k=0.5)
    with pytest.raises(ValueError):
        HybridBackend(corpus, 10, lat, w.doc_terms, w.doc_term_weights,
                      diversify_sim=1.5)
    with pytest.raises(ValueError):
        HybridBackend(corpus, 10, lat, w.doc_terms, w.doc_term_weights,
                      dense="faiss")
    with pytest.raises(ValueError):
        HybridBackend(corpus, 10, lat, w.doc_terms[:10],
                      w.doc_term_weights[:10])


# -- live ingest & composition ---------------------------------------------

def test_hybrid_ingest_threads_both_channels():
    w = _world()
    lat = LatencyModel()
    rng = np.random.default_rng(5)
    hb = HybridBackend(jnp.asarray(w.doc_emb), 10, lat, w.doc_terms,
                       w.doc_term_weights, backend="xla")
    n0 = hb._corpus_np.shape[0]
    new_vec = rng.normal(size=(1, w.cfg.d)).astype(np.float32)
    new_term = np.array([[999_983]], np.int32)     # unique hashed term
    got = hb.ingest_docs(new_vec, terms=new_term, ingest_key="k0")
    assert got.tolist() == [n0]
    # idempotent on the same ingest key
    assert hb.ingest_docs(new_vec, terms=new_term,
                          ingest_key="k0").tolist() == [n0]
    assert hb._corpus_np.shape[0] == hb._terms_np.shape[0] == n0 + 1
    # a query carrying ONLY the new term finds the new doc lexically
    q = jnp.asarray(rng.normal(size=(1, w.cfg.d)).astype(np.float32))
    _, ids = hb.search(q, q_terms=jnp.asarray(new_term))
    assert n0 in np.asarray(ids)[0].tolist()
    # non-sequential ids violate the postings-row == doc-id contract
    with pytest.raises(ValueError):
        hb.ingest_docs(new_vec, ids=np.array([n0 + 5], np.int32))


def test_replica_composition_and_service_forwarding():
    w = _world()
    lat = LatencyModel()
    corpus = jnp.asarray(w.doc_emb)
    hb = HybridBackend(corpus, 10, lat, w.doc_terms, w.doc_term_weights,
                       backend="xla")
    rb = ReplicaBackend(hb, [], corpus)
    assert rb.uses_lexical and rb.q_term_width == hb.q_term_width
    qs, embs, terms, tws = _query_batch(w, 4)
    _, want = hb.search(embs, q_terms=terms, q_term_weights=tws)
    _, got = rb.search(embs, q_terms=terms, q_term_weights=tws)
    assert np.array_equal(np.asarray(want), np.asarray(got))
    # RetrievalService forwards terms only to lexical-aware backends
    svc = RetrievalService(w, lat, k=10, backend=hb)
    ids, vecs, t = svc.full_search(qs[0]["emb"], qs[0]["terms"],
                                   qs[0]["term_weights"])
    assert np.array_equal(ids, np.asarray(want)[0])
    assert t == hb.latency(1)
    flat = RetrievalService(w, lat, k=10)
    ids_f, _, _ = flat.full_search(qs[0]["emb"], qs[0]["terms"],
                                   qs[0]["term_weights"])  # silently dropped
    assert ids_f.shape == (10,)


# -- fused-list speculation (HasConfig.fusion == "rrf") --------------------

def test_hasconfig_fusion_default_is_score():
    """The default keeps every pre-hybrid HaS program byte-identical."""
    cfg = HasConfig()
    assert cfg.fusion == "score" and cfg.rrf_k == 60.0


def test_rrf_merge_and_weighted_homology_monotone_invariant():
    """Fused-list speculation is rank-domain end to end: any positive
    monotone transform of either channel's raw scores leaves the merged
    draft ids AND the weighted homology accept decision unchanged."""
    rng = np.random.default_rng(7)
    n, k, h = 80, 8, 16
    dense_raw = rng.normal(size=n)
    lex_raw = rng.uniform(0.0, 3.0, size=n)
    cache = rng.integers(0, n, size=(h, k)).astype(np.int32)
    valid = jnp.asarray(np.ones(h, bool))

    def decide(ds, ls):
        i_a = jnp.asarray(np.argsort(-ds, kind="stable")[:k].astype(np.int32))
        i_b = jnp.asarray(np.argsort(-ls, kind="stable")[:k].astype(np.int32))
        _, ids = _rrf_merge(i_a, i_b, k, 60.0)
        s = homology_scores_weighted(ids, jnp.asarray(cache), valid,
                                     rrf_draft_weights(ids, 60.0))
        return np.asarray(ids), float(np.max(np.asarray(s)))

    ids0, best0 = decide(dense_raw, lex_raw)
    for f_d, f_l in ((np.exp, lambda x: 2.0 * x + 1.0),
                     (np.tanh, np.exp),
                     (lambda x: x ** 3, np.tanh)):
        ids1, best1 = decide(f_d(dense_raw), f_l(lex_raw))
        assert np.array_equal(ids0, ids1)
        assert best0 == best1


def test_rrf_merge_drops_nothing_and_dedups():
    """_rrf_merge: cross-list duplicates keep ONE slot (summed mass), -1
    padding stays inert, empty merge -> all -1."""
    i_a = jnp.asarray(np.array([3, 5, 9, -1], np.int32))
    i_b = jnp.asarray(np.array([5, 2, 3, 7], np.int32))
    vals, ids = _rrf_merge(i_a, i_b, 4, 60.0)
    ids = np.asarray(ids)
    assert len(set(ids.tolist())) == 4 and -1 not in ids
    assert ids[0] == 5 and ids[1] == 3      # double-mass docs lead
    assert np.all(np.diff(np.asarray(vals)) <= 0)
    _, empty = _rrf_merge(jnp.full((4,), -1, jnp.int32),
                          jnp.full((4,), -1, jnp.int32), 4, 60.0)
    assert np.all(np.asarray(empty) == -1)


@pytest.mark.parametrize("be", ["pallas", "xla"])
def test_speculate_batch_rrf_mode_backend_parity(be):
    from repro.core.has import (cache_update, init_has_state,
                                speculate_batch)
    from repro.retrieval.ivf import build_ivf
    w = _world()
    corpus = jnp.asarray(w.doc_emb)
    idx = build_ivf(corpus, 32, seed=0)
    cfg = HasConfig(k=10, h_max=64, doc_capacity=640, n_buckets=32,
                    nprobe=8, fusion="rrf")
    st = init_has_state(cfg)
    _, embs, _, _ = _query_batch(w, 12, seed=9)
    ids = jnp.asarray(np.arange(10, dtype=np.int32))
    st = cache_update(cfg, st, embs[0], ids, corpus[np.arange(10)])
    out = speculate_batch(cfg, st, idx, embs, backend=be)
    oracle = speculate_batch(cfg, st, idx, embs, backend="xla")
    assert np.array_equal(np.asarray(out["accept"]),
                          np.asarray(oracle["accept"]))
    assert np.allclose(np.asarray(out["homology"]),
                       np.asarray(oracle["homology"]), atol=1e-6)
    # weighted validation stays in [0, 1] so the score-mode tau applies
    assert float(np.max(np.asarray(out["homology"]))) <= 1.0 + 1e-6


# -- scheduler end-to-end: the reason the second channel exists ------------

def test_scheduler_hybrid_beats_dense_on_corrupted_corpus():
    """With a third of the entities' dense embeddings replaced by noise
    (postings intact), the scheduler serving through HybridBackend must
    recover doc-hit the dense-only backend cannot."""
    from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                         SchedulerConfig)
    w = _world()
    lat = LatencyModel()
    rng = np.random.default_rng(11)
    bad_entities = rng.choice(w.cfg.n_entities, size=w.cfg.n_entities // 3,
                              replace=False)
    bad = np.isin(w.doc_entity, bad_entities)
    corrupted = w.doc_emb.copy()
    noise = rng.normal(size=(int(bad.sum()), w.cfg.d)).astype(np.float32)
    corrupted[bad] = noise / np.maximum(
        np.linalg.norm(noise, axis=1, keepdims=True), 1e-8)
    corrupted = jnp.asarray(corrupted)
    ds = DATASETS["granola"]
    qs = w.sample_queries(96, pattern=ds["pattern"], zipf_a=ds["zipf_a"],
                          p_uncovered=ds["p_uncovered"], seed=13)
    cfg = HasConfig(k=10, tau=0.2, h_max=96, nprobe=4, n_buckets=64, d=64)
    hits = {}
    for name, be in (
            ("dense", LocalFlatBackend(corrupted, 10, lat)),
            ("hybrid", HybridBackend(corrupted, 10, lat, w.doc_terms,
                                     w.doc_term_weights, backend="xla"))):
        svc = RetrievalService(w, lat, k=10, backend=be)
        sched = ContinuousBatchingScheduler(
            svc, cfg, SchedulerConfig(max_spec_batch=16, full_batch=8,
                                      full_max_wait_s=0.05))
        r = sched.serve(qs, None, seed=0)
        hits[name] = float(np.mean(r.doc_hits))
    assert hits["hybrid"] >= hits["dense"] + 0.05, hits


# -- launch/serve.py knob validation (satellite) ---------------------------

@pytest.mark.parametrize("argv", [
    ["--retrieval-backend", "hybrid", "--rrf-k", "0.5"],
    ["--retrieval-backend", "hybrid", "--diversify-sim", "0"],
    ["--retrieval-backend", "hybrid", "--diversify-sim", "1.5"],
    ["--retrieval-backend", "hybrid", "--lexical-terms", "0"],
    ["--rrf-k", "60"],                                 # flat backend
    ["--diversify-sim", "0.9", "--retrieval-backend", "ann"],
    ["--lexical-terms", "2", "--retrieval-backend", "sharded"],
    ["--hybrid-dense", "ann"],                         # without hybrid
    ["--compressed-corpus", "--retrieval-backend", "hybrid"],  # flat dense
])
def test_serve_cli_rejects_invalid_hybrid_args(argv):
    from repro.launch.serve import main
    with pytest.raises(SystemExit) as e:
        main(argv)
    assert e.value.code == 2                  # argparse usage error


def test_serve_cli_accepts_hybrid_combo():
    """The documented hybrid invocation must run end-to-end on a tiny
    world (ANN dense channel + scheduler engine + all three knobs)."""
    from repro.launch.serve import main
    main(["--queries", "24", "--entities", "120", "--h-max", "60",
          "--engine", "sched", "--retrieval-backend", "hybrid",
          "--hybrid-dense", "ann", "--ann-clusters", "8", "--nprobe", "4",
          "--rrf-k", "30", "--diversify-sim", "0.95",
          "--lexical-terms", "2", "--workers", "2"])
