"""Training substrate: optimizers, accumulation, compression, fault logic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.compression import (compressed_psum, dequantize_int8,
                                        quantize_int8)
from repro.training.fault import (ElasticPlan, StragglerConfig,
                                  StragglerDetector, run_with_retries)
from repro.training.optimizer import (OptConfig, adafactor_init, adamw_init,
                                      clip_by_global_norm, global_norm,
                                      opt_init, opt_state_logical, opt_update)
from repro.training.train import make_train_step, make_train_step_accum


def _quadratic(params, batch):
    loss = sum(jnp.sum((x - 1.5) ** 2) for x in jax.tree.leaves(params))
    loss = loss + 0.0 * jnp.sum(batch["x"])
    return loss, {"l": loss}


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_converges(name):
    cfg = OptConfig(name=name, lr=0.05, weight_decay=0.0)
    params = {"a": jnp.zeros((4, 8)), "b": jnp.zeros((3,))}
    state = opt_init(cfg, params)
    step = jax.jit(make_train_step(_quadratic, cfg))
    batch = {"x": jnp.zeros((2,))}
    for _ in range(300):
        params, state, m = step(params, state, batch)
    assert float(m["loss"]) < 0.05


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 100.0


def test_accumulation_matches_full_batch():
    cfg = OptConfig(name="adamw", lr=0.1, weight_decay=0.0, grad_clip=0.0)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        l = jnp.mean((pred - batch["y"]) ** 2)
        return l, {}

    rng = np.random.default_rng(0)
    batch = {"x": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
             "y": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
    p0 = {"w": jnp.zeros((4,))}
    s0 = opt_init(cfg, p0)

    full = make_train_step(loss_fn, cfg)
    p1, _, _ = full(p0, s0, batch)
    accum = make_train_step_accum(loss_fn, cfg, n_micro=4)
    p2, _, _ = accum(p0, s0, batch)
    # MSE over microbatches averages to the full-batch loss -> same grads
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               rtol=1e-5, atol=1e-6)


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 128))}
    st = adafactor_init(params)
    assert st["v"]["w"]["vr"].shape == (64,)
    assert st["v"]["w"]["vc"].shape == (128,)
    # memory: factored states are O(n+m), not O(n*m)
    adam = adamw_init(params)
    factored = sum(x.size for x in jax.tree.leaves(st))
    full = sum(x.size for x in jax.tree.leaves(adam))
    assert factored < full / 20


def test_opt_state_logical_structure():
    cfg = OptConfig(name="adafactor")
    lg = opt_state_logical(cfg, {"w": ("fsdp", "d_ff"),
                                 "s": (None, "a", "b")})
    assert lg["v"]["w"] == {"vr": ("fsdp",), "vc": ("d_ff",)}
    assert lg["v"]["s"] == {"vr": (None, "a"), "vc": (None, "b")}


def test_int8_quantization_error_feedback():
    """Error feedback: accumulated quantization error stays bounded and the
    long-run mean of dequantized values converges to the true mean."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(50):
        q, scale = quantize_int8(g + err)
        deq = dequantize_int8(q, scale)
        err = (g + err) - deq
        total = total + deq
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g),
                               atol=float(scale) * 1.1)


def test_straggler_detector():
    det = StragglerDetector(StragglerConfig(window=10, deadline_factor=2.0,
                                            min_samples=3))
    for i in range(5):
        assert not det.observe(i, 1.0)
    assert det.observe(5, 5.0)          # 5x median
    assert det.flagged == [5]
    assert not det.observe(6, 1.1)


def test_run_with_retries_redispatches():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("device lost")
        return 42

    out, attempts = run_with_retries(flaky, max_retries=2)
    assert out == 42 and attempts == 1


def test_elastic_plan_keeps_global_batch():
    plan = ElasticPlan.plan(old_data=16, surviving_hosts=12)
    assert plan.new_data == 12
    assert plan.accum_steps * plan.new_data >= 16   # global batch preserved
