"""Deterministic fault injection + self-healing serving (serving/faults.py).

Covers the PR's tentpole contracts:

  * FaultPlan/FaultEvent validation and the CLI parse grammar;
  * the zero-cost contract: an EMPTY (or absent) fault plan leaves the
    scheduler bit-identical to one built without the module at all —
    pinned against the pre-PR golden trace hashes;
  * chaos runs are pure functions of (seed, plan, arrivals, queries):
    the same plan replays the same schedule bit-exactly;
  * span conservation stays EXACT through every recovery path (retried,
    hedged, requeued, rerouted requests), and tracing off matches the
    traced run's ids/times bit-exactly under a non-empty plan;
  * delta-channel loss surfaces as a LOUD replay gap error naming the
    replica and sequence (never silent divergence), and duplicated
    replication appends are absorbed by idempotent ingest keys — a
    dup-only chaos run is bit-identical to fault-free;
  * promote() retires the promoted replica so its stale cursor stops
    pinning log compaction (log memory stays bounded while serving
    continues on the remaining replicas);
  * scheduler knob/topology validation and the launch CLI's argument
    validation fail fast with actionable messages.
"""
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.has import HasConfig
from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
from repro.serving.edge_pool import EdgeReplicaPool
from repro.serving.engine import RetrievalService
from repro.serving.faults import (KINDS, FaultEvent, FaultInjector,
                                  FaultPlan)
from repro.serving.latency import LatencyModel
from repro.serving.replication import WarmStandby
from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                     SchedulerConfig, poisson_arrivals)
from repro.serving.tracing import STAGES


# ---------------------------------------------------------------------------
# FaultPlan / FaultEvent / parse grammar
# ---------------------------------------------------------------------------

def test_fault_plan_validation():
    ok = FaultPlan(events=(FaultEvent(t=1.0, kind="worker_crash"),))
    assert len(ok) == 1 and len(FaultPlan()) == 0
    with pytest.raises(TypeError, match="expected.*FaultEvent"):
        FaultPlan(events=("worker_crash",))
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan(events=(FaultEvent(t=1.0, kind="meteor"),))
    with pytest.raises(ValueError, match="t must be >= 0"):
        FaultPlan(events=(FaultEvent(t=-1.0, kind="worker_crash"),))
    with pytest.raises(ValueError, match="target must be >= 0"):
        FaultPlan(events=(FaultEvent(t=0.0, kind="worker_crash",
                                     target=-1),))
    with pytest.raises(ValueError, match="duration_s must be > 0"):
        FaultPlan(events=(FaultEvent(t=0.0, kind="straggler"),))
    with pytest.raises(ValueError, match="factor must be > 1"):
        FaultPlan(events=(FaultEvent(t=0.0, kind="straggler",
                                     duration_s=1.0, factor=1.0),))
    with pytest.raises(ValueError, match="down_s must be >= 0"):
        FaultPlan(events=(FaultEvent(t=0.0, kind="worker_crash",
                                     down_s=-1.0),))
    with pytest.raises(ValueError, match="count must be >= 1"):
        FaultPlan(events=(FaultEvent(t=0.0, kind="delta_drop", count=0),))


def test_fault_plan_parse_grammar():
    plan = FaultPlan.parse(
        "worker_crash@2.0,target=1,down=3.0;"
        "straggler@1.0,duration=5,factor=4;"
        "delta_drop@0.5,count=3")
    assert [e.kind for e in plan.events] == [
        "worker_crash", "straggler", "delta_drop"]
    wc, st, dd = plan.events
    assert (wc.t, wc.target, wc.down_s) == (2.0, 1, 3.0)
    assert (st.duration_s, st.factor) == (5.0, 4.0)
    assert dd.count == 3
    # sorted_events orders by time, stable
    assert [e.kind for e in plan.sorted_events()] == [
        "delta_drop", "straggler", "worker_crash"]
    assert len(FaultPlan.parse("")) == 0 and len(FaultPlan.parse(" ; ")) == 0
    with pytest.raises(ValueError, match="expected 'kind@t'"):
        FaultPlan.parse("worker_crash")
    with pytest.raises(ValueError, match="is not a number"):
        FaultPlan.parse("worker_crash@soon")
    with pytest.raises(ValueError, match="bad field"):
        FaultPlan.parse("worker_crash@1,fuzz=3")
    with pytest.raises(ValueError, match="not a valid int"):
        FaultPlan.parse("delta_drop@1,count=many")
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.parse("meteor@1")


def test_injector_windows_and_delta_counters():
    inj = FaultInjector(FaultPlan())
    for kind in ("straggler", "search_fail"):
        inj.activate(FaultEvent(t=1.0, kind=kind, target=0, duration_s=2.0,
                                factor=3.0))
    # windows are [t, t + duration): closed start, open end, per worker
    assert inj.latency_multiplier(0, 1.0) == 3.0
    assert inj.latency_multiplier(0, 3.0) == 1.0
    assert inj.latency_multiplier(1, 1.5) == 1.0
    assert inj.search_fails(0, 2.9) and not inj.search_fails(0, 3.0)
    # overlapping straggler windows compound
    inj.activate(FaultEvent(t=2.0, kind="straggler", target=0,
                            duration_s=2.0, factor=2.0))
    assert inj.latency_multiplier(0, 2.5) == 6.0
    # delta counters consume one per append; drop wins over dup
    inj.activate(FaultEvent(t=0.0, kind="delta_drop", count=1))
    inj.activate(FaultEvent(t=0.0, kind="delta_dup", count=1))
    assert [inj.delta_fault() for _ in range(3)] == ["drop", "dup", None]
    assert (inj.dropped_appends, inj.duplicated_appends) == (1, 1)


# ---------------------------------------------------------------------------
# Replication substrate: gap detection, promote retirement, idempotence
# ---------------------------------------------------------------------------

def _rows(rng, n, cfg, hi=200):
    qs = rng.normal(size=(n, cfg.d)).astype(np.float32)
    ids = rng.integers(0, hi, size=(n, cfg.k)).astype(np.int32)
    vecs = rng.normal(size=(n, cfg.k, cfg.d)).astype(np.float32)
    return qs, ids, vecs


def test_replay_gap_raises_naming_replica_and_seq():
    """Satellite regression: rows lost on the replication channel must
    surface as a LOUD per-replica error at the next replay — silently
    folding past the gap would diverge the replica from the primary."""
    cfg = HasConfig(k=4, h_max=16, doc_capacity=64, d=8)
    pool = EdgeReplicaPool(cfg, n_replicas=2, sync_every=100, compact=False)
    rng = np.random.default_rng(0)
    qs, ids, vecs = _rows(rng, 3, cfg)
    pool.record_batch(qs, ids, vecs)
    pool.sync(0)                             # replica 0 at seq 3
    pool.mark_lost(2)                        # seqs 3-4 lost in transit
    qs2, ids2, vecs2 = _rows(rng, 2, cfg)
    pool.record_batch(qs2, ids2, vecs2)      # seqs 5-6 arrive
    with pytest.raises(ValueError,
                       match=r"replica 0: expected seq 3, got 5"):
        pool.sync(0)
    # replica 1 (cursor 0) sees the same gap mid-log, named with ITS id
    with pytest.raises(ValueError, match=r"replica 1: expected seq 3"):
        pool.sync(1)


def test_replay_gap_trailing_and_total_loss():
    cfg = HasConfig(k=4, h_max=16, doc_capacity=64, d=8)
    pool = EdgeReplicaPool(cfg, n_replicas=1, sync_every=100, compact=False,
                           sync_on_record=False)
    rng = np.random.default_rng(1)
    pool.record_batch(*_rows(rng, 3, cfg))
    pool.mark_lost(1)                        # tail row lost, no rows after
    with pytest.raises(ValueError, match="replica 0.*full resync"):
        pool.sync(0)
    pool.resync_from(0, pool.states[0], pool.log.head)
    assert pool.sync(0) == 0                 # recovered, nothing to replay


def test_promote_retires_cursor_and_log_stays_bounded():
    """Satellite regression: promote() must retire the promoted replica's
    cursor — otherwise the stale cursor pins compaction forever and the
    delta log grows without bound while serving continues."""
    cfg = HasConfig(k=4, h_max=16, doc_capacity=64, d=8)
    pool = EdgeReplicaPool(cfg, n_replicas=2, sync_every=4)   # compact=True
    rng = np.random.default_rng(2)
    pool.record_batch(*_rows(rng, 8, cfg))
    promoted = pool.promote(1)
    assert 1 in pool.retired
    # serving continues on replica 0 only: every subsequent batch trips
    # replica 0's cadence, and with replica 1 retired the log compacts
    # down each time instead of accumulating behind its dead cursor
    for _ in range(6):
        pool.record_batch(*_rows(rng, 4, cfg))
        assert len(pool.log) < pool.sync_every + 4
    assert pool.log.base > 8                 # trimmed PAST the old cursor
    # replaying into the retired slot is refused (its buffers now back
    # the promoted primary; a donated-buffer fold would corrupt it)
    with pytest.raises(ValueError, match="retired by promote"):
        pool.sync(1)
    # rebuild un-retires with a DEEP copy: folding into the rebuilt slot
    # must not mutate the promoted primary's arrays
    import jax
    before = [np.asarray(l).copy() for l in jax.tree.leaves(promoted)]
    pool.resync_from(1, promoted, pool.log.head)
    assert 1 not in pool.retired
    pool.record_batch(*_rows(rng, 8, cfg))   # trips both replicas' replay
    for b, l in zip(before, jax.tree.leaves(promoted)):
        np.testing.assert_array_equal(b, np.asarray(l))


def test_ingest_key_idempotence(tmp_path):
    """The same ingest batch delivered twice (duplicated replication send
    or a retried cloud dispatch) folds exactly once, on every sink."""
    cfg = HasConfig(k=4, h_max=16, doc_capacity=64, d=8)
    rng = np.random.default_rng(3)
    qs, ids, vecs = _rows(rng, 3, cfg, hi=60)
    pool = EdgeReplicaPool(cfg, n_replicas=2, sync_every=100)
    pool.record_batch(qs, ids, vecs, ingest_key=7)
    pool.record_batch(qs, ids, vecs, ingest_key=7)    # dropped whole
    assert pool.log.head == 3
    pool.record_batch(qs, ids, vecs, ingest_key=8)    # new key folds
    assert pool.log.head == 6
    sb = WarmStandby(cfg, CheckpointManager(str(tmp_path)))
    from repro.core.has import init_has_state
    state = init_has_state(cfg)
    sb.record_batch(qs, ids, vecs, state, ingest_key=7)
    sb.record_batch(qs, ids, vecs, state, ingest_key=7)
    assert sb.log.head == 3
    # key=None skips dedup (the historical unkeyed path)
    sb.record_batch(qs, ids, vecs, state)
    sb.record_batch(qs, ids, vecs, state)
    assert sb.log.head == 9


# ---------------------------------------------------------------------------
# Scheduler chaos runs
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    import jax.numpy as jnp

    from repro.retrieval.service import ShardedMeshBackend
    world = SyntheticWorld(WorldConfig(n_entities=400, seed=0))
    lat = LatencyModel()
    backend = ShardedMeshBackend(jnp.asarray(world.doc_emb), 10, lat,
                                 n_shards=4, n_workers=4)
    svc = RetrievalService(world, lat, k=10, chunk=2048, backend=backend)
    ds = DATASETS["granola"]
    qs = world.sample_queries(160, pattern=ds["pattern"],
                              zipf_a=ds["zipf_a"],
                              p_uncovered=ds["p_uncovered"], seed=1)
    cfg = HasConfig(k=10, tau=0.2, h_max=400, nprobe=4, n_buckets=256, d=64)
    return svc, qs, cfg


BASE = dict(max_spec_batch=16, full_batch=8, full_max_wait_s=0.1,
            edge_replicas=3)

#: every fault kind at once — the benchmark's chaos plan in miniature
CHAOS = FaultPlan(events=(
    FaultEvent(t=0.3, kind="straggler", target=1, duration_s=2.0,
               factor=6.0),
    FaultEvent(t=0.5, kind="worker_crash", target=0, down_s=1.0),
    FaultEvent(t=0.8, kind="search_fail", target=2, duration_s=1.0),
    FaultEvent(t=1.0, kind="replica_crash", target=1),
    FaultEvent(t=0.6, kind="delta_drop", count=2),
    FaultEvent(t=1.2, kind="delta_dup", count=2),
))


def _serve(svc, qs, cfg, seed=0, arrivals="poisson", **kw):
    sched = ContinuousBatchingScheduler(
        svc, cfg, SchedulerConfig(**BASE, **kw), seed=seed)
    arr = (poisson_arrivals(len(qs), qps=40.0, seed=5)
           if isinstance(arrivals, str) else arrivals)
    return sched.serve(qs, arrivals=arr, seed=3)


def _same_schedule(a, b):
    return (np.array_equal(a.t_done, b.t_done)
            and np.array_equal(a.served_ids, b.served_ids)
            and list(a.channels) == list(b.channels))


def test_empty_plan_bit_identical_to_no_plan(setup):
    """The zero-cost contract: FaultPlan() == no fault machinery at all
    (same rng draw order, no extra heap events, same dispatch path)."""
    svc, qs, cfg = setup
    r_none = _serve(svc, qs, cfg)
    r_empty = _serve(svc, qs, cfg, fault_plan=FaultPlan())
    assert _same_schedule(r_none, r_empty)
    assert np.array_equal(r_none.t_arrive, r_empty.t_arrive)
    s = r_empty.summary()
    assert (s["retries"], s["hedges"], s["worker_deaths"],
            s["replica_rebuilds"], s["failed"]) == (0, 0, 0, 0, 0)
    # lost / retry_backoff spans stay identically zero fault-free
    assert not r_empty.trace.spans["lost"].any()
    assert not r_empty.trace.spans["retry_backoff"].any()


def test_dup_only_plan_bit_identical(setup):
    """Duplicated replication appends are fully absorbed by idempotent
    ingest keys: a dup-only chaos run IS the fault-free run, bit-exactly
    — the strongest form of the no-duplicate-fold verdict."""
    svc, qs, cfg = setup
    r0 = _serve(svc, qs, cfg)
    plan = FaultPlan(events=(FaultEvent(t=0.2, kind="delta_dup", count=3),))
    r1 = _serve(svc, qs, cfg, fault_plan=plan)
    assert _same_schedule(r0, r1)


def test_chaos_run_deterministic_conserved_and_healed(setup):
    """All six fault kinds at once: every request still completes (or is
    explicitly failed), the recovery machinery engages, span conservation
    stays exact through every retry/hedge/requeue/reroute path, and the
    whole run replays bit-exactly."""
    svc, qs, cfg = setup
    r = _serve(svc, qs, cfg, fault_plan=CHAOS)
    s = r.summary()
    assert s["worker_deaths"] == 1
    assert s["replica_rebuilds"] >= 1        # crash rebuild (+ gap resyncs)
    assert s["retries"] >= 1 and s["hedges"] >= 1
    assert s["failed"] == 0                  # bounded retries sufficed
    # conservation EXACT for every request, including the recovered ones
    res = r.trace.conservation_residual()
    assert np.abs(res).max() < 1e-9
    for st in STAGES:
        assert r.trace.spans[st].min() >= 0.0, st
    # faults actually cost something, and the cost is attributed
    assert r.trace.spans["lost"].sum() > 0
    assert r.trace.spans["retry_backoff"].sum() > 0
    # the retried/hedged/rerouted requests specifically conserve
    touched = (r.trace.spans["lost"] > 0) | (
        r.trace.spans["retry_backoff"] > 0)
    assert touched.any() and np.abs(res[touched]).max() < 1e-9
    # purity: same (seed, plan, arrivals, queries) -> same schedule
    assert _same_schedule(r, _serve(svc, qs, cfg, fault_plan=CHAOS))


def test_trace_off_matches_traced_under_faults(setup):
    """Tracing is bookkeeping only, also through every recovery path."""
    svc, qs, cfg = setup
    r_t = _serve(svc, qs, cfg, fault_plan=CHAOS)
    r_n = _serve(svc, qs, cfg, fault_plan=CHAOS, trace=False)
    assert r_n.trace is None
    assert _same_schedule(r_t, r_n)


def test_permanent_worker_crash_degrades_but_completes(setup):
    """down_s=0 removes the worker forever; the remaining pool absorbs
    the requeued batch and the stream still drains."""
    svc, qs, cfg = setup
    plan = FaultPlan(events=(
        FaultEvent(t=0.4, kind="worker_crash", target=0, down_s=0.0),))
    r = _serve(svc, qs[:96], cfg, fault_plan=plan,
               arrivals=poisson_arrivals(96, qps=40.0, seed=5))
    s = r.summary()
    assert s["worker_deaths"] == 1 and s["failed"] == 0
    assert len(r.t_done) == 96 and np.isfinite(r.t_done).all()
    assert np.abs(r.trace.conservation_residual()).max() < 1e-9


def test_scheduler_fault_knob_and_topology_validation(setup):
    svc, qs, cfg = setup

    def build(**kw):
        return ContinuousBatchingScheduler(
            svc, cfg, SchedulerConfig(**BASE, **kw), seed=0)

    with pytest.raises(ValueError, match="retry_max"):
        build(retry_max=-1)
    with pytest.raises(ValueError, match="retry_backoff_s"):
        build(retry_backoff_s=-0.1)
    with pytest.raises(ValueError, match="hedge_after"):
        build(hedge_after=1.0)
    with pytest.raises(TypeError, match="FaultPlan.parse"):
        build(fault_plan="worker_crash@1")
    with pytest.raises(ValueError, match="targets worker 9"):
        build(fault_plan=FaultPlan(events=(
            FaultEvent(t=1.0, kind="worker_crash", target=9),)))
    with pytest.raises(ValueError, match="targets replica 5"):
        build(fault_plan=FaultPlan(events=(
            FaultEvent(t=1.0, kind="replica_crash", target=5),)))
    with pytest.raises(ValueError, match="edge_replicas"):
        ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
            max_spec_batch=16, full_batch=8, full_max_wait_s=0.1,
            fault_plan=FaultPlan(events=(
                FaultEvent(t=1.0, kind="replica_crash", target=0),))),
            seed=0)
    with pytest.raises(ValueError, match="free_ingest_replay"):
        build(free_ingest_replay=True, fault_plan=FaultPlan(events=(
            FaultEvent(t=1.0, kind="delta_drop"),)))
    with pytest.raises(ValueError, match="permanently crashes all"):
        build(fault_plan=FaultPlan(events=tuple(
            FaultEvent(t=1.0, kind="worker_crash", target=i, down_s=0.0)
            for i in range(4))))


# ---------------------------------------------------------------------------
# Launch CLI argument validation (cheap paths only — they fail before the
# heavy imports)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("argv", [
    ["--engine", "sched", "--fault-plan", "meteor@1"],
    ["--engine", "sched", "--fault-plan", "worker_crash"],
    ["--engine", "has", "--fault-plan", "worker_crash@1"],
    ["--engine", "sched", "--retry-max", "2"],
    ["--engine", "sched", "--hedge-after", "2.5"],
    ["--engine", "sched", "--fault-plan", "worker_crash@1",
     "--retry-max", "-1"],
    ["--engine", "sched", "--fault-plan", "worker_crash@1",
     "--hedge-after", "1.0"],
])
def test_serve_cli_rejects_bad_fault_args(argv, capsys):
    from repro.launch.serve import main
    with pytest.raises(SystemExit) as ei:
        main(argv)
    assert ei.value.code == 2
    err = capsys.readouterr().err
    assert "--fault-plan" in err or "--retry-max" in err \
        or "--hedge-after" in err
