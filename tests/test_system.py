"""End-to-end system tests: dry-run lowering (subprocess), graph sampler,
LM training convergence, batched speculation."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """One real production-mesh cell: 512 virtual devices, lower+compile."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "dlrm-rm2",
         "--shape", "serve_p99", "--multi-pod"],
        capture_output=True, text=True, env=env, timeout=420)
    assert "OK" in out.stdout, out.stdout + out.stderr


def test_mesh_axes():
    # no XLA flag in-process: just validate shapes/axis names via subprocess
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=512")
    code = ("from repro.launch.mesh import make_production_mesh;"
            "m = make_production_mesh(multi_pod=True);"
            "assert m.shape == {'pod': 2, 'data': 16, 'model': 16}, m.shape;"
            "m2 = make_production_mesh();"
            "assert m2.shape == {'data': 16, 'model': 16};"
            "print('MESH_OK')")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=120)
    assert "MESH_OK" in out.stdout, out.stdout + out.stderr


def test_neighbor_sampler_block_shapes():
    from repro.data.graph import NeighborSampler, random_graph
    g = random_graph(500, 4000, 8, 3, seed=0)
    samp = NeighborSampler(g["edge_src"].astype(np.int64),
                           g["edge_dst"].astype(np.int64), 500, seed=0)
    seeds = np.arange(32)
    nodes, src, dst, mask = samp.sample_block(seeds, (5, 3), e_max=1024)
    assert src.shape == (1024,) and mask.dtype == bool
    assert mask.sum() > 0
    # all local ids within the node set
    assert src[mask].max() < len(nodes) and dst[mask].max() < len(nodes)


def test_lm_training_loss_decreases():
    """(b) deliverable sanity at test scale: loss goes down on Markov data."""
    from repro.launch.train import train_lm
    from repro.models.transformer import TransformerConfig
    cfg = TransformerConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                            n_kv_heads=2, d_ff=128, vocab_size=64, d_head=16,
                            remat=False)
    losses = train_lm(cfg, steps=30, batch=8, seq=32, ckpt_dir=None,
                      log_every=1000)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_speculate_batched_matches_single():
    from repro.core.has import (HasConfig, cache_update, init_has_state,
                                speculate, speculate_batched)
    from repro.retrieval.ivf import build_ivf
    rng = np.random.default_rng(0)
    cfg = HasConfig(k=4, tau=0.2, h_max=16, doc_capacity=64, nprobe=2,
                    n_buckets=4, d=8)
    corpus = jnp.asarray(rng.normal(size=(128, 8)), jnp.float32)
    index = build_ivf(corpus, 4, seed=0)
    state = init_has_state(cfg)
    state = cache_update(cfg, state, jnp.ones((8,)),
                         jnp.asarray([0, 1, 2, 3], jnp.int32), corpus[:4])
    qs = jnp.asarray(rng.normal(size=(6, 8)), jnp.float32)
    batched = speculate_batched(cfg, state, index, qs)
    for i in range(6):
        single = speculate(cfg, state, index, qs[i])
        for key in ("draft_ids", "accept", "homology"):
            np.testing.assert_array_equal(np.asarray(batched[key][i]),
                                          np.asarray(single[key]), err_msg=key)


def test_has_dryrun_step_semantics():
    """has-rag smoke: accepted queries return drafts, rejected the full ids."""
    from repro.configs import get_arch
    spec = get_arch("has-rag")
    cfg, fn, args = spec.make_smoke()
    ids, accept, best = jax.jit(fn)(*args)
    corpus = np.asarray(args[0])
    queries = np.asarray(args[-1])
    k = ids.shape[1]
    exact = np.argsort(-(queries @ corpus.T), axis=1)[:, :k]
    for i in range(queries.shape[0]):
        if not bool(accept[i]):
            assert set(np.asarray(ids[i]).tolist()) == set(exact[i].tolist())
