"""Synthetic world calibration: the paper's empirical observations hold."""
import numpy as np
import pytest

from repro.data.synthetic import (DATASETS, ENCODERS, SyntheticWorld,
                                  WorldConfig)


@pytest.fixture(scope="module")
def world():
    return SyntheticWorld(WorldConfig(n_entities=2000, seed=0))


def test_entity_alignment(world):
    """Obs. 1: ~2.35/5 of top-5 docs entity-aligned; ~64% top-1 aligned."""
    rng = np.random.default_rng(5)
    a5, top1 = [], []
    for _ in range(200):
        e = int(rng.integers(world.cfg.n_entities))
        avail = np.flatnonzero(world.entity_attrs[e])
        a = int(rng.choice(avail))
        q = world.encode_query(e, a, rng)
        top = np.argsort(-(world.doc_emb @ q))[:5]
        a5.append((world.doc_entity[top] == e).sum())
        top1.append(world.doc_entity[top[0]] == e)
    assert 1.5 < np.mean(a5) < 3.5          # paper: 2.35
    assert 0.5 < np.mean(top1) < 0.9        # paper: 0.643


def test_homologous_queries_share_golden_docs(world):
    """Insight 1: homologous queries are empirically quasi-homologous."""
    rng = np.random.default_rng(9)
    share = []
    for _ in range(100):
        e = int(rng.integers(world.cfg.n_entities))
        attrs = np.flatnonzero(world.entity_attrs[e])
        if len(attrs) < 2:
            continue
        a1, a2 = rng.choice(attrs, 2, replace=False)
        g1 = (world.doc_entity == e) & world.doc_attr_mask[:, a1]
        g2 = (world.doc_entity == e) & world.doc_attr_mask[:, a2]
        share.append((g1 & g2).any())
    # ~half of homologous pairs share a golden doc outright; combined with
    # entity-aligned result overlap (next test) this carries Insight 1
    assert np.mean(share) > 0.4


def test_homology_score_separates(world):
    """Fig. 6c: homologous pairs' result overlap >> random pairs'."""
    rng = np.random.default_rng(11)
    k = 10
    hom, rnd = [], []
    for _ in range(60):
        e = int(rng.integers(world.cfg.n_entities))
        attrs = np.flatnonzero(world.entity_attrs[e])
        if len(attrs) < 2:
            continue
        a1, a2 = rng.choice(attrs, 2, replace=False)
        q1 = world.encode_query(e, int(a1), rng)
        q2 = world.encode_query(e, int(a2), rng)
        e3 = int(rng.integers(world.cfg.n_entities))
        a3 = int(rng.choice(np.flatnonzero(world.entity_attrs[e3])))
        q3 = world.encode_query(e3, a3, rng)
        t1 = set(np.argsort(-(world.doc_emb @ q1))[:k].tolist())
        t2 = set(np.argsort(-(world.doc_emb @ q2))[:k].tolist())
        t3 = set(np.argsort(-(world.doc_emb @ q3))[:k].tolist())
        hom.append(len(t1 & t2) / k)
        rnd.append(len(t1 & t3) / k)
    assert np.mean(hom) > np.mean(rnd) + 0.15
    assert np.mean(rnd) < 0.05


def test_zipf_popularity(world):
    """Fig. 4: most queries share their entity with another query."""
    qs = world.sample_queries(1000, pattern="zipf", zipf_a=1.12, seed=1)
    ents = np.asarray([q["entity"] for q in qs])
    _, counts = np.unique(ents, return_counts=True)
    frac_repeat = (np.repeat(counts, counts) > 1).mean()
    assert frac_repeat > 0.6                # paper: >60% have counterparts

    scattered = world.sample_queries(1000, pattern="scattered", seed=1)
    ents_s = np.asarray([q["entity"] for q in scattered])
    _, cs = np.unique(ents_s, return_counts=True)
    assert (np.repeat(cs, cs) > 1).mean() < frac_repeat


def test_golden_mask_oracle(world):
    e = 5
    a = int(np.flatnonzero(world.entity_attrs[e])[0])
    docs = np.flatnonzero((world.doc_entity == e)
                          & world.doc_attr_mask[:, a])
    assert world.golden_mask(e, a, docs).all()
    other = np.flatnonzero(world.doc_entity != e)[:5]
    assert not world.golden_mask(e, a, other).any()
    assert not world.golden_mask(e, a, np.array([-1])).any()


def test_encoder_presets_all_work():
    for name, kw in ENCODERS.items():
        w = SyntheticWorld(WorldConfig(n_entities=200, seed=1, **kw))
        assert np.isfinite(w.doc_emb).all(), name
