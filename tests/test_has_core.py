"""HaS core invariants: FIFO cache, dedup, homology math, Algorithm 1
equivalence between the jitted fixed-shape engine and the faithful
hash-map reference (core/reference.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# real hypothesis when installed, skip-stubs otherwise (see conftest.py)
from conftest import given, settings, st

from repro.core.has import HasConfig, cache_update, init_has_state, speculate
from repro.core.homology import (homology_scores, pairwise_homology,
                                 reidentify)
from repro.core.reference import RefHas


def test_cache_fifo_eviction():
    cfg = HasConfig(k=4, h_max=3, doc_capacity=64, d=8)
    state = init_has_state(cfg)
    rng = np.random.default_rng(0)
    for i in range(7):
        ids = jnp.asarray(np.arange(i * 4, i * 4 + 4), jnp.int32)
        vecs = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
        state = cache_update(cfg, state, jnp.ones((8,)), ids, vecs)
    assert int(state.q_ptr) == 7
    # only the last h_max=3 queries survive, in ring order
    live = set(np.asarray(state.query_doc_ids).reshape(-1).tolist())
    expected = set(range(16, 28))   # queries 4,5,6 -> ids 16..27
    assert expected <= live


def test_doc_dedup_on_insert():
    cfg = HasConfig(k=4, h_max=8, doc_capacity=32, d=4)
    state = init_has_state(cfg)
    ids = jnp.asarray([1, 2, 3, 4], jnp.int32)
    vecs = jnp.ones((4, 4))
    state = cache_update(cfg, state, jnp.ones((4,)), ids, vecs)
    state = cache_update(cfg, state, jnp.ones((4,)), ids, vecs)  # same docs
    live = np.asarray(state.doc_ids)
    assert sorted(live[live >= 0].tolist()) == [1, 2, 3, 4]
    assert int(state.d_ptr) == 4     # no duplicate slots consumed


def test_homology_score_definition():
    # s(q1,q2) = |D1 ∩ D2| / k  (Definition 5)
    a = jnp.asarray([1, 2, 3, 4], jnp.int32)
    b = jnp.asarray([3, 4, 5, 6], jnp.int32)
    assert float(pairwise_homology(a, b)) == 0.5
    assert float(pairwise_homology(a, a)) == 1.0
    assert float(pairwise_homology(a, jnp.asarray([7, 8, 9, 10], jnp.int32))) == 0.0


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 2), st.integers(1, 10))
def test_homology_symmetric_and_bounded(seed, k):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(0, 20, k), jnp.int32)
    b = jnp.asarray(rng.integers(0, 20, k), jnp.int32)
    # NOTE: result sets contain distinct docs in practice; with duplicates
    # the overlap count is still bounded by k
    sab = float(pairwise_homology(a, b))
    assert 0.0 <= sab <= 1.0
    # identical sets always score 1
    assert float(pairwise_homology(a, a)) == 1.0


def test_reidentify_threshold_strict():
    cache = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    valid = jnp.asarray([True, True])
    draft = jnp.asarray([1, 2, 9, 10], jnp.int32)   # overlap 2/4 = 0.5
    acc, best, slot = reidentify(draft, cache, valid, jnp.float32(0.5))
    assert not bool(acc)            # strict >
    acc, _, slot = reidentify(draft, cache, valid, jnp.float32(0.49))
    assert bool(acc) and int(slot) == 0


def test_invalid_slots_score_zero():
    cache = jnp.asarray([[1, 2], [1, 2]], jnp.int32)
    valid = jnp.asarray([False, True])
    s = homology_scores(jnp.asarray([1, 2], jnp.int32), cache, valid)
    assert float(s[0]) == 0.0 and float(s[1]) == 1.0


def test_algorithm1_equivalence_with_reference():
    """Jitted fixed-shape HaS == faithful hash-map reference, per query."""
    k, h_max, doc_cap, d = 5, 16, 128, 16
    cfg = HasConfig(k=k, tau=0.3, h_max=h_max, doc_capacity=doc_cap,
                    nprobe=2, n_buckets=4, d=d,
                    use_fuzzy_validation=False, use_fuzzy_enhancement=False)
    refi = RefHas(k=k, tau=0.3, h_max=h_max, doc_cap=doc_cap)
    state = init_has_state(cfg)

    rng = np.random.default_rng(3)
    corpus = rng.normal(size=(256, d)).astype(np.float32)
    corpus /= np.linalg.norm(corpus, axis=1, keepdims=True)

    from repro.retrieval.ivf import build_ivf
    index = build_ivf(jnp.asarray(corpus), cfg.n_buckets, seed=0)

    for step in range(60):
        q = rng.normal(size=(d,)).astype(np.float32)
        q /= np.linalg.norm(q)
        out = speculate(cfg, state, index, jnp.asarray(q))
        # reference: cache-channel draft + inverted-index validation
        ref_ids, _ = refi.cache_channel(q)
        accept_ref, _ = refi.validate(ref_ids)
        got_ids = np.asarray(out["val_ids"])
        live_got = sorted(int(i) for i in got_ids if i >= 0)
        live_ref = sorted(int(i) for i in ref_ids if i >= 0)
        assert live_got == live_ref, (step, live_got, live_ref)
        assert bool(out["accept"]) == accept_ref, step
        if not accept_ref:
            full = np.argsort(-(corpus @ q))[:k].astype(np.int32)
            state = cache_update(cfg, state, jnp.asarray(q),
                                 jnp.asarray(full), jnp.asarray(corpus[full]))
            refi.update(q, full, corpus[full])


def test_fuzzy_ablation_flags():
    """Table VI flags: V/E control which channels feed validation/output."""
    cfg_full = HasConfig(k=4, tau=0.1, h_max=8, doc_capacity=32,
                         nprobe=2, n_buckets=4, d=8)
    cfg_noE = HasConfig(k=4, tau=0.1, h_max=8, doc_capacity=32,
                        nprobe=2, n_buckets=4, d=8,
                        use_fuzzy_enhancement=False)
    rng = np.random.default_rng(0)
    corpus = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    from repro.retrieval.ivf import build_ivf
    index = build_ivf(corpus, 4, seed=0)
    state = init_has_state(cfg_full)
    # insert one query so the cache channel is non-empty
    state = cache_update(cfg_full, state, jnp.ones((8,)),
                         jnp.asarray([0, 1, 2, 3], jnp.int32), corpus[:4])
    q = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    out_full = speculate(cfg_full, state, index, q)
    out_noE = speculate(cfg_noE, state, index, q)
    # without enhancement the returned draft only contains cached docs
    cached = {0, 1, 2, 3, -1}
    assert set(np.asarray(out_noE["draft_ids"]).tolist()) <= cached
    # validation drafts identical (V on in both)
    assert np.array_equal(np.asarray(out_full["val_ids"]),
                          np.asarray(out_noE["val_ids"]))
