"""ANN cloud backend (retrieval/service.py::IVFBackend): Pallas ivf_scan
<-> retrieval/ivf.py oracle parity (duplicate global ids, corpus < k,
fully padded buckets, tail capacities, int8-dequant), the one-dispatch-
per-batch probe, streaming/compressed index builds, live-ingest
reconciliation (bucket spill -> residual -> re-bucketing flush),
``ReplicaBackend(IVFBackend)`` composition, fault-plan retry/hedge paths
through an IVF dispatch, and the new serve-CLI knob validation.

The CI `ann-backend` job runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` alongside the
``benchmarks/ann_recall.py`` verdicts.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.core.has import HasConfig
from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
from repro.kernels import ops, ref
from repro.retrieval.flat import chunked_flat_search
from repro.retrieval.ivf import (CompressedIVFIndex, IVFIndex, build_ivf,
                                 build_ivf_streaming, ivf_probe_scan,
                                 ivf_search)
from repro.retrieval.service import (FullRetrievalBackend, IVFBackend,
                                     LocalFlatBackend, ReplicaBackend,
                                     RetrievalService)
from repro.serving.latency import LatencyModel
from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                     SchedulerConfig, poisson_arrivals)
from repro.training.compression import dequantize_int8, quantize_int8


def _unit(rng, n, d):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _clustered(rng, n, d, n_protos=64, spread=0.2):
    """Topic-clustered corpus (the regime IVF indexes are built for)."""
    protos = _unit(rng, n_protos, d)
    x = protos[rng.integers(0, n_protos, n)] + spread * rng.normal(size=(n, d))
    return (x / np.linalg.norm(x, axis=1, keepdims=True)).astype(np.float32)


def _ids_match(i_kernel, i_ref, s_kernel, s_ref, atol=1e-5):
    """Rank-order ids may swap within score ties; compare as score-sets."""
    assert np.allclose(np.asarray(s_kernel), np.asarray(s_ref), atol=atol)
    for rk, rr in zip(np.asarray(i_kernel), np.asarray(i_ref)):
        assert set(rk.tolist()) == set(rr.tolist())


# -- quantize_int8 regression (satellite) ----------------------------------

def test_quantize_int8_all_zero_vector_regression():
    """An all-zero vector (every IVF bucket pad slot) must quantize to a
    floored scale, not scale 0 -> 0/0 -> NaN."""
    z = jnp.zeros((3, 16)).at[1].set(jnp.linspace(-2.0, 2.0, 16))
    q, s = quantize_int8(z, axis=-1)
    d = dequantize_int8(q, s)
    assert bool(jnp.all(jnp.isfinite(d)))
    assert bool(jnp.all(d[0] == 0.0)) and bool(jnp.all(d[2] == 0.0))
    assert bool(jnp.all(s > 0.0))
    # the live row roundtrips within one quantization step
    step = float(s[1, 0])
    assert float(jnp.max(jnp.abs(d[1] - z[1]))) <= step
    # whole-tensor zero input through the scalar path too
    q0, s0 = quantize_int8(jnp.zeros((4, 4)))
    assert np.isfinite(float(s0)) and float(s0) > 0.0
    assert bool(jnp.all(dequantize_int8(q0, s0) == 0.0))


def test_quantize_int8_scalar_path_unchanged():
    """axis=None must stay the original per-tensor contract (the gradient
    compression path depends on a 0-d scale)."""
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)),
                    jnp.float32)
    q, s = quantize_int8(x)
    assert s.ndim == 0 and q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(dequantize_int8(q, s) - x))) <= float(s)


# -- Pallas ivf_scan <-> oracle parity suite (satellite) -------------------

def test_ivf_scan_parity_duplicate_global_ids():
    """The same global id in several probed buckets must not confuse the
    running top-k merge: scores equal the oracle's, id-sets match."""
    rng = np.random.default_rng(0)
    d, cap, k = 32, 8, 6
    vecs = rng.normal(size=(4, cap, d)).astype(np.float32)
    ids = rng.integers(0, 40, size=(4, cap)).astype(np.int32)
    ids[0, :4] = ids[1, :4] = np.arange(4)       # duplicates across buckets
    vecs[1, :4] = vecs[0, :4]                    # same doc, same vector
    q = jnp.asarray(_unit(rng, 3, d))
    probe = jnp.asarray(np.array([[0, 1], [1, 0], [2, 3]], np.int32))
    out = ops.ivf_scan(q, probe, jnp.asarray(vecs), jnp.asarray(ids), k,
                       interpret=True)
    want = ref.ivf_scan_ref(q, probe, jnp.asarray(vecs), jnp.asarray(ids), k)
    _ids_match(out[1], want[1], out[0], want[0])


def test_ivf_scan_parity_corpus_smaller_than_k():
    """Probed pool < k: the tail must pad with -inf scores and -1 ids on
    both the kernel and the oracle."""
    rng = np.random.default_rng(1)
    d, cap, k = 16, 3, 10
    vecs = rng.normal(size=(2, cap, d)).astype(np.float32)
    ids = np.array([[0, 1, -1], [2, -1, -1]], np.int32)
    q = jnp.asarray(_unit(rng, 2, d))
    probe = jnp.asarray(np.array([[0, 1], [0, 1]], np.int32))
    s_k, i_k = ops.ivf_scan(q, probe, jnp.asarray(vecs), jnp.asarray(ids),
                            k, interpret=True)
    s_r, i_r = ref.ivf_scan_ref(q, probe, jnp.asarray(vecs),
                                jnp.asarray(ids), k)
    live = np.asarray(i_r) >= 0
    assert np.array_equal(np.asarray(i_k) >= 0, live)
    assert np.allclose(np.asarray(s_k)[live], np.asarray(s_r)[live],
                       atol=1e-5)
    assert (np.asarray(i_k)[~live] == -1).all()
    assert np.isneginf(np.asarray(s_k)[~live]).all()
    assert live.sum(axis=1).tolist() == [3, 3]   # exactly the 3 real docs


def test_ivf_scan_parity_fully_padded_buckets():
    """A probe hitting only pad (-1) slots contributes nothing."""
    rng = np.random.default_rng(2)
    d, cap, k = 16, 4, 5
    vecs = rng.normal(size=(3, cap, d)).astype(np.float32)
    ids = np.full((3, cap), -1, np.int32)
    ids[0] = np.arange(4)                        # only bucket 0 is live
    q = jnp.asarray(_unit(rng, 2, d))
    probe = jnp.asarray(np.array([[1, 2], [0, 2]], np.int32))
    s_k, i_k = ops.ivf_scan(q, probe, jnp.asarray(vecs), jnp.asarray(ids),
                            k, interpret=True)
    s_r, i_r = ref.ivf_scan_ref(q, probe, jnp.asarray(vecs),
                                jnp.asarray(ids), k)
    # row 0 probes only padded buckets -> all -1 / -inf
    assert (np.asarray(i_k)[0] == -1).all()
    assert np.isneginf(np.asarray(s_k)[0]).all()
    _ids_match(i_k, i_r, s_k, s_r)


def test_ivf_scan_parity_tail_bucket_capacities():
    """Counts < capacity (the tail of every real build): pad slots masked
    identically on kernel and oracle, across ragged tails."""
    rng = np.random.default_rng(3)
    n, d, k = 700, 32, 10
    corpus = jnp.asarray(_clustered(rng, n, d))
    idx = build_ivf(corpus, 16, seed=1)
    counts = np.asarray(idx.bucket_counts)
    assert (counts < idx.capacity).any()         # genuine ragged tails
    q = jnp.asarray(_unit(rng, 5, d))
    cs = q @ idx.centroids.T
    probe = jax.lax.top_k(cs, 6)[1].astype(jnp.int32)
    s_k, i_k = ops.ivf_scan(q, probe, idx.bucket_vecs, idx.bucket_ids, k,
                            interpret=True)
    s_r, i_r = ref.ivf_scan_ref(q, probe, idx.bucket_vecs, idx.bucket_ids, k)
    _ids_match(i_k, i_r, s_k, s_r)
    # and the jnp search oracle agrees end-to-end
    s_o, i_o = ivf_search(idx, q, nprobe=6, k=k)
    _ids_match(i_k, i_o, s_k, s_o)


def test_ivf_scan_int8_dequant_parity():
    """Compressed residency: the kernel's fused residual dequant
    (bias + per-half (q . v8) * scale) must match the oracle bit-for-bit
    in id-sets and to fp tolerance in scores."""
    rng = np.random.default_rng(4)
    n, d, k = 900, 32, 10
    corpus = _clustered(rng, n, d)
    idx = build_ivf_streaming(corpus, 16, seed=1, compressed=True)
    assert isinstance(idx, CompressedIVFIndex)
    assert idx.bucket_vecs.dtype == jnp.int8
    assert idx.bucket_scales.shape == (*idx.bucket_ids.shape, 2)
    q = jnp.asarray(_unit(rng, 4, d))
    cs = q @ idx.centroids.T
    bias, probe = jax.lax.top_k(cs, 5)
    probe = probe.astype(jnp.int32)
    s_k, i_k = ops.ivf_scan(q, probe, idx.bucket_vecs, idx.bucket_ids, k,
                            interpret=True, bucket_scales=idx.bucket_scales,
                            probe_bias=bias)
    s_r, i_r = ref.ivf_scan_ref(q, probe, idx.bucket_vecs, idx.bucket_ids,
                                k, bucket_scales=idx.bucket_scales,
                                probe_bias=bias)
    _ids_match(i_k, i_r, s_k, s_r)
    # the fused path == probe-scan oracle on the compressed index
    s_o, i_o = ivf_probe_scan(idx, q, probe, k)
    _ids_match(i_k, i_o, s_k, s_o)
    # and close to the f32 index's scores (quantization noise only)
    f32 = build_ivf_streaming(corpus, 16, seed=1)
    s_f, _ = ivf_probe_scan(f32, q, probe, k)
    assert np.allclose(np.asarray(s_k), np.asarray(s_f), atol=0.02)


def test_ivf_backend_single_dispatch_per_batch():
    """O(1) dispatches: one [B,d] search = ONE host->device program launch
    regardless of B, nprobe, or compression."""
    rng = np.random.default_rng(5)
    lat = LatencyModel()
    corpus = jnp.asarray(_clustered(rng, 1200, 32))
    for compressed in (False, True):
        be = IVFBackend(corpus, 10, lat, n_clusters=16, nprobe=4,
                        compressed=compressed, backend="xla")
        for b in (1, 8, 32):
            q = jnp.asarray(_unit(rng, b, 32))
            be.search(q)                          # warm the jit cache
            with dispatch.capture() as cpt:
                be.search(q)[0].block_until_ready()
            assert cpt.total() == 1, (compressed, b, cpt.counts)


# -- streaming / compressed index builds -----------------------------------

def test_streaming_build_matches_build_ivf():
    """Chunked assignment must reproduce build_ivf's buckets exactly
    (same centroids, ids, vectors, counts) for any chunk size."""
    rng = np.random.default_rng(6)
    corpus = _clustered(rng, 1500, 32)
    a = build_ivf(jnp.asarray(corpus), 32, seed=2)
    for chunk in (64, 999, 10**6):
        b = build_ivf_streaming(corpus, 32, seed=2, chunk=chunk)
        assert isinstance(b, IVFIndex)
        assert np.array_equal(np.asarray(a.centroids), np.asarray(b.centroids))
        assert np.array_equal(np.asarray(a.bucket_ids), np.asarray(b.bucket_ids))
        assert np.array_equal(np.asarray(a.bucket_vecs), np.asarray(b.bucket_vecs))
        assert np.array_equal(np.asarray(a.bucket_counts),
                              np.asarray(b.bucket_counts))


def test_compressed_build_memory_and_recall():
    """int8 residency: bucket store >= 3x smaller than f32 at equal shape,
    and search results nearly identical at the same nprobe."""
    rng = np.random.default_rng(7)
    corpus = _clustered(rng, 4000, 64)
    f32 = build_ivf_streaming(corpus, 64, seed=3)
    i8 = build_ivf_streaming(corpus, 64, seed=3, compressed=True)
    f32_bytes = f32.bucket_vecs.nbytes
    i8_bytes = i8.bucket_vecs.nbytes + i8.bucket_scales.nbytes
    assert f32_bytes / i8_bytes >= 3.0
    q = jnp.asarray(_unit(rng, 32, 64))
    k = 10
    _, if32 = ivf_search(f32, q, nprobe=8, k=k)
    _, ii8 = ivf_search(i8, q, nprobe=8, k=k)
    overlap = np.mean([len(set(a.tolist()) & set(b.tolist())) / k
                       for a, b in zip(np.asarray(if32), np.asarray(ii8))])
    assert overlap >= 0.98


# -- IVFBackend: protocol, recall, latency model, ingest -------------------

def test_ivf_backend_protocol_recall_and_latency():
    rng = np.random.default_rng(8)
    n, d, k = 4000, 64, 10
    corpus = jnp.asarray(_clustered(rng, n, d))
    lat = LatencyModel(target_corpus=n, actual_corpus=n)
    flat = LocalFlatBackend(corpus, k, lat)
    for compressed in (False, True):
        be = IVFBackend(corpus, k, lat, n_clusters=64, nprobe=16,
                        compressed=compressed, backend="xla")
        assert isinstance(be, FullRetrievalBackend)
        # queries = lightly perturbed corpus docs (the ANN regime)
        qn = np.asarray(corpus)[rng.integers(0, n, 64)] \
            + 0.1 * rng.normal(size=(64, d)).astype(np.float32)
        q = jnp.asarray(qn / np.linalg.norm(qn, axis=1, keepdims=True),
                        dtype=jnp.float32)
        fs, fi = flat.search(q)
        s, i = be.search(q)
        rec = np.mean([len(set(a.tolist()) & set(b.tolist())) / k
                       for a, b in zip(np.asarray(fi), np.asarray(i))])
        assert rec >= 0.9, (compressed, rec)
        # the latency model charges centroids + probed buckets, not the
        # whole corpus: strictly faster than flat, int8 faster than f32
        assert be.latency(16) < flat.latency(16)
    f32_lat = IVFBackend(corpus, k, lat, n_clusters=64, nprobe=16,
                         backend="xla").latency(1)
    i8_lat = IVFBackend(corpus, k, lat, n_clusters=64, nprobe=16,
                        compressed=True, backend="xla").latency(1)
    assert i8_lat < f32_lat
    # ann_scale sanity: monotone in nprobe, degenerate == full scan cost+
    scales = [lat.ann_scale(64, p) for p in (1, 2, 4, 8)]
    assert all(a < b for a, b in zip(scales, scales[1:]))
    assert lat.ann_scale(64, 64, capacity_factor=1.0) > 1.0  # probe-all


def test_ivf_backend_pallas_matches_xla_oracle():
    rng = np.random.default_rng(9)
    corpus = jnp.asarray(_clustered(rng, 2000, 32))
    lat = LatencyModel()
    kw = dict(n_clusters=32, nprobe=8, seed=1)
    for compressed in (False, True):
        bx = IVFBackend(corpus, 10, lat, backend="xla", compressed=compressed,
                        **kw)
        bp = IVFBackend(corpus, 10, lat, backend="pallas",
                        compressed=compressed, **kw)
        q = jnp.asarray(_unit(rng, 6, 32))
        sx, ix = bx.search(q)
        sp, ip = bp.search(q)
        _ids_match(ip, ix, sp, sx)


def test_ivf_backend_ingest_reconciliation():
    """Live ingest: new docs searchable immediately (bucket or residual),
    idempotent on ingest_key, residual overflow flushes via re-bucketing,
    and nothing is lost across the flush."""
    rng = np.random.default_rng(10)
    d, k = 32, 10
    corpus = jnp.asarray(_clustered(rng, 1600, d))
    lat = LatencyModel()
    be = IVFBackend(corpus, k, lat, n_clusters=16, nprobe=4, backend="xla",
                    residual_cap=8, seed=2)
    v = _unit(rng, 1, d)
    ids = be.ingest_docs(v, ingest_key="batch-1")
    assert np.array_equal(be.ingest_docs(v, ingest_key="batch-1"), ids)
    assert be._corpus_np.shape[0] == 1601      # idempotent: grown ONCE
    s, i = be.search(jnp.asarray(v))
    assert int(np.asarray(i)[0, 0]) == int(ids[0])
    # aim a flood at one centroid: fills its bucket, spills to the
    # residual, then overflows -> re-bucketing flush
    c0 = np.asarray(be.index.centroids)[0]
    flood = c0[None] + 0.01 * rng.normal(size=(600, d)).astype(np.float32)
    flood = (flood / np.linalg.norm(flood, axis=1, keepdims=True)).astype(
        np.float32)
    flood_ids = be.ingest_docs(flood)
    assert be.rebuilds >= 1 and be.residual_count == 0
    # post-flush: ingested docs still retrievable by their own embedding
    s, i = be.search(jnp.asarray(flood[:16]))
    hit = np.mean([fid in set(row.tolist())
                   for fid, row in zip(flood_ids[:16], np.asarray(i))])
    assert hit >= 0.9
    # the residual path itself serves hits before any flush
    be2 = IVFBackend(corpus, k, lat, n_clusters=16, nprobe=4, backend="xla",
                     residual_cap=64, seed=2)
    cap = be2.index.capacity
    b0 = int(np.argmax(np.asarray(be2.index.bucket_counts)))
    cvec = np.asarray(be2.index.centroids)[b0]
    need = cap - int(np.asarray(be2.index.bucket_counts)[b0]) + 5
    spill = cvec[None] + 0.01 * rng.normal(size=(need, d)).astype(np.float32)
    spill = (spill / np.linalg.norm(spill, axis=1, keepdims=True)).astype(
        np.float32)
    sids = be2.ingest_docs(spill)
    assert be2.residual_count > 0 and be2.rebuilds == 0
    s, i = be2.search(jnp.asarray(spill[-3:]))
    assert all(sid in set(row.tolist())
               for sid, row in zip(sids[-3:], np.asarray(i)))


# -- scheduler / composition / fault paths ---------------------------------

@pytest.fixture(scope="module")
def world_setup():
    world = SyntheticWorld(WorldConfig(n_entities=600, seed=0))
    ds = DATASETS["granola"]
    qs = world.sample_queries(300, pattern=ds["pattern"], zipf_a=ds["zipf_a"],
                              p_uncovered=ds["p_uncovered"], seed=1)
    cfg = HasConfig(k=10, tau=0.2, h_max=600, nprobe=4, n_buckets=256, d=64)
    return world, qs, cfg


def _sched(world, cfg, backend=None, **sched_kw):
    lat = LatencyModel()
    if callable(backend):
        backend = backend(jnp.asarray(world.doc_emb), lat)
    svc = RetrievalService(world, lat, k=10, chunk=2048, backend=backend)
    kw = dict(max_spec_batch=16, full_batch=8, full_max_wait_s=0.1)
    kw.update(sched_kw)
    return ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(**kw))


def test_scheduler_ann_backend_e2e(world_setup):
    """The scheduler's cloud stage over an IVF pool: every request
    completes, doc-hit stays within a few points of flat (golden docs are
    entity-clustered, exactly what IVF probes catch), and the modeled ANN
    latency shows up as throughput."""
    world, qs, cfg = world_setup
    r0 = _sched(world, cfg).serve(qs, None, seed=0)
    ann = _sched(world, cfg, backend=lambda c, lat: IVFBackend(
        c, 10, lat, n_clusters=128, nprobe=32, backend="xla", n_workers=2))
    assert ann.n_full_workers == 2
    r1 = ann.serve(qs, None, seed=0)
    assert np.all(r1.t_done >= 0) and np.all(r1.channels != "pending")
    s0, s1 = r0.summary(), r1.summary()
    assert abs(s1["doc_hit_rate"] - s0["doc_hit_rate"]) < 0.03
    assert s1["throughput_qps"] > s0["throughput_qps"]


def test_replica_backend_over_ivf_composition(world_setup):
    """ReplicaBackend(IVFBackend): approximate search + standby cache
    reconciliation compose — the standby rebuilds EXACTLY the cache the
    scheduler ended with, fed by ANN results."""
    import tempfile

    from repro.checkpoint import CheckpointManager
    from repro.serving.replication import WarmStandby
    world, qs, cfg = world_setup
    standby = WarmStandby(cfg, CheckpointManager(tempfile.mkdtemp()),
                          snapshot_every=10**9, max_lag=10**6)
    sch = _sched(world, cfg, backend=lambda c, lat: ReplicaBackend(
        IVFBackend(c, 10, lat, n_clusters=128, nprobe=32, backend="xla"),
        [standby], c))
    sch.serve(qs, None, seed=0)
    assert len(standby.log) > 0
    recovered = standby.failover()
    for a, b in zip(jax.tree.leaves(recovered), jax.tree.leaves(sch.state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_fault_plan_retry_hedge_through_ivf_dispatch(world_setup):
    """An IVF dispatch is retryable/hedgeable like a flat one: transient
    search failures retry onto another pool slot, stragglers hedge, every
    request completes, and the chaos run replays bit-exactly."""
    from repro.serving.faults import FaultEvent, FaultPlan
    world, qs, cfg = world_setup
    plan = FaultPlan(events=(
        FaultEvent(t=0.3, kind="straggler", target=1, duration_s=3.0,
                   factor=8.0),
        FaultEvent(t=0.5, kind="search_fail", target=0, duration_s=1.5),
        FaultEvent(t=1.2, kind="worker_crash", target=2, down_s=1.0),
    ))
    mk = lambda: _sched(world, cfg, backend=lambda c, lat: IVFBackend(
        c, 10, lat, n_clusters=128, nprobe=32, backend="xla", n_workers=4),
        fault_plan=plan)
    arr = poisson_arrivals(len(qs), qps=25.0, seed=5)
    r = mk().serve(qs, arrivals=arr, seed=3)
    s = r.summary()
    assert np.all(r.t_done >= 0) and np.all(r.channels != "pending")
    assert s["failed"] == 0
    assert s["retries"] >= 1 and s["hedges"] >= 1
    assert s["worker_deaths"] == 1
    res = r.trace.conservation_residual()
    assert np.abs(res).max() < 1e-9
    r2 = mk().serve(qs, arrivals=arr, seed=3)
    assert np.array_equal(r.t_done, r2.t_done)
    assert list(r.channels) == list(r2.channels)


def test_service_reuses_ann_backend_corpus(world_setup):
    world, qs, cfg = world_setup
    lat = LatencyModel()
    be = IVFBackend(jnp.asarray(world.doc_emb), 10, lat, n_clusters=128,
                    nprobe=16, backend="xla")
    svc = RetrievalService(world, lat, k=10, backend=be)
    assert svc.corpus is be.corpus
    ids, vecs, t = svc.full_search(np.asarray(world.doc_emb[7]))
    assert 7 in set(ids.tolist())
    assert t == be.latency(1)


# -- launch/serve.py knob validation (satellite) ---------------------------

@pytest.mark.parametrize("argv", [
    ["--nprobe", "0"],
    ["--nprobe", "-4", "--retrieval-backend", "ann"],
    ["--ann-clusters", "0", "--retrieval-backend", "ann"],
    ["--nprobe", "64", "--ann-clusters", "32", "--retrieval-backend", "ann"],
    ["--compressed-corpus"],                               # flat backend
    ["--compressed-corpus", "--retrieval-backend", "sharded"],
    ["--compressed-corpus", "--retrieval-backend", "replica"],
])
def test_serve_cli_rejects_invalid_ann_args(argv):
    from repro.launch.serve import main
    with pytest.raises(SystemExit) as e:
        main(argv)
    assert e.value.code == 2                  # argparse usage error


def test_serve_cli_accepts_ann_combo():
    """The documented ANN invocation must run end-to-end on a tiny world
    (compressed residency + scheduler engine + worker pool)."""
    from repro.launch.serve import main
    main(["--queries", "24", "--entities", "120", "--h-max", "60",
          "--engine", "sched", "--retrieval-backend", "ann",
          "--ann-clusters", "8", "--nprobe", "4", "--compressed-corpus",
          "--workers", "2"])
