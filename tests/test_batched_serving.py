"""Batched (accept-mask compaction) serving vs the sequential engine."""
import numpy as np
import pytest

from repro.core.has import HasConfig
from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
from repro.serving.batched import BatchedHasEngine
from repro.serving.engine import HasEngine, RetrievalService
from repro.serving.latency import LatencyModel


@pytest.fixture(scope="module")
def setup():
    world = SyntheticWorld(WorldConfig(n_entities=600, seed=0))
    svc = RetrievalService(world, LatencyModel(), k=10, chunk=2048)
    ds = DATASETS["granola"]
    qs = world.sample_queries(400, pattern=ds["pattern"],
                              zipf_a=ds["zipf_a"],
                              p_uncovered=ds["p_uncovered"], seed=1)
    cfg = HasConfig(k=10, tau=0.2, h_max=600, nprobe=8, n_buckets=64, d=64)
    return svc, qs, cfg


def test_batched_matches_sequential_trends(setup):
    svc, qs, cfg = setup
    seq = HasEngine(svc, cfg).serve(qs).summary()
    bat = BatchedHasEngine(svc, cfg, batch_size=16).serve(qs).summary()
    # snapshot semantics: batched DAR is a lower bound of sequential DAR,
    # converging from below; hit rates comparable
    assert bat["dar"] <= seq["dar"] + 0.02
    assert bat["dar"] > seq["dar"] * 0.5
    assert abs(bat["doc_hit_rate"] - seq["doc_hit_rate"]) < 0.08


def test_batched_handles_tail_batch(setup):
    svc, qs, cfg = setup
    r = BatchedHasEngine(svc, cfg, batch_size=32).serve(qs[:33])
    assert len(r.latencies) == 33
    assert np.isfinite(r.latencies).all()
