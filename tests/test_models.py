"""Model substrate tests: per-arch smokes, decode/prefill consistency,
blocked & head-padded attention exactness, MoE dispatch math."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_arch
from repro.models import layers as L
from repro.models import transformer as tf

TINY = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=256, d_head=16, remat=False)


@pytest.mark.parametrize("arch", all_archs())
def test_arch_smoke(arch):
    """(f) deliverable: reduced config, one step, output shapes, no NaN."""
    spec = get_arch(arch)
    if arch == "has-rag":
        cfg, fn, args = spec.make_smoke()
        ids, accept, best = jax.jit(fn)(*args)
        assert ids.shape == (args[-1].shape[0], cfg.k)
        assert not bool(jnp.isnan(best).any())
        return
    cfg, params, opt_state, step, batch = spec.make_smoke()
    p2, o2, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), arch
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0, arch


def test_decode_matches_prefill():
    cfg = tf.TransformerConfig(name="t", **TINY)
    p = tf.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 6), 0, 256)
    full, _ = tf.forward(p, toks, cfg, compute_dtype=jnp.float32)
    cache = tf.init_kv_cache(cfg, 2, 8, jnp.float32)
    for i in range(6):
        lg, cache = tf.decode_step(p, cache, toks[:, i], jnp.int32(i), cfg,
                                   compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(full[:, -1]), np.asarray(lg),
                               rtol=2e-4, atol=2e-4)


def test_blocked_attention_exact():
    cfg = tf.TransformerConfig(name="t", **TINY)
    cfgb = tf.TransformerConfig(name="tb", attn_block_q=4, **TINY)
    p = tf.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 256)
    a, _ = tf.forward(p, toks, cfg, compute_dtype=jnp.float32)
    b, _ = tf.forward(p, toks, cfgb, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_head_padding_exact():
    base = dict(TINY, n_heads=6, n_kv_heads=2)
    cfg = tf.TransformerConfig(name="t", **base)
    cfgp = tf.TransformerConfig(name="tp", head_tp=False, head_pad_to=8,
                                **base)
    p = tf.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, 256)
    a, _ = tf.forward(p, toks, cfg, compute_dtype=jnp.float32)
    b, _ = tf.forward(p, toks, cfgp, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_moe_matches_dense_expert_loop():
    """Sort-based capacity dispatch == naive per-expert masked loop."""
    key = jax.random.key(0)
    d, f, e, topk = 16, 32, 4, 2
    params = L.init_moe(key, d, f, e)
    x = jax.random.normal(jax.random.key(1), (2, 8, d))
    out, _ = L.moe(params, x, top_k=topk, capacity_factor=8.0)  # no drops

    # naive: every token through its top-k experts
    xt = x.reshape(-1, d)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, topk)
    w = w / w.sum(-1, keepdims=True)
    naive = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        for j in range(topk):
            ee = int(idx[t, j])
            h = jax.nn.silu(xt[t] @ params["w_gate"][ee]) * (
                xt[t] @ params["w_in"][ee])
            naive = naive.at[t].add(w[t, j] * (h @ params["w_out"][ee]))
    np.testing.assert_allclose(np.asarray(out.reshape(-1, d)),
                               np.asarray(naive), rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    key = jax.random.key(0)
    params = L.init_moe(key, 8, 16, 2)
    x = jax.random.normal(jax.random.key(1), (1, 32, 8))
    out_lo, _ = L.moe(params, x, top_k=1, capacity_factor=0.25)
    out_hi, _ = L.moe(params, x, top_k=1, capacity_factor=8.0)
    # low capacity drops most tokens -> outputs differ and some are zero
    zeros = np.asarray(jnp.all(out_lo == 0, axis=-1)).sum()
    assert zeros > 0
    assert not np.allclose(np.asarray(out_lo), np.asarray(out_hi))


def test_rope_fraction_chatglm():
    x = jax.random.normal(jax.random.key(0), (1, 4, 2, 8))
    pos = jnp.arange(4)[None, :]
    full = L.apply_rope(x, pos, 10000.0, 1.0)
    half = L.apply_rope(x, pos, 10000.0, 0.5)
    # pass-through half is untouched
    np.testing.assert_allclose(np.asarray(half[..., 4:]),
                               np.asarray(x[..., 4:]))
    assert not np.allclose(np.asarray(half[..., :4]), np.asarray(x[..., :4]))
    # position 0 is identity everywhere
    np.testing.assert_allclose(np.asarray(full[:, 0]), np.asarray(x[:, 0]),
                               rtol=1e-6)


def test_param_counts_match_configs():
    for arch in ("arctic-480b", "dbrx-132b", "starcoder2-7b",
                 "phi3-medium-14b", "chatglm3-6b"):
        cfg = get_arch(arch).config
        n = cfg.param_count()
        # sanity: the advertised scale class
        target = {"arctic-480b": 480e9, "dbrx-132b": 132e9,
                  "starcoder2-7b": 7e9, "phi3-medium-14b": 14e9,
                  "chatglm3-6b": 6e9}[arch]
        assert 0.55 * target < n < 1.45 * target, (arch, n)


def test_dimenet_triplet_masking():
    """Masked triplets/edges contribute nothing."""
    from repro.data.graph import make_graph_batch
    from repro.models import dimenet as dn
    cfg = dn.DimeNetConfig(n_blocks=1, d_hidden=16, n_bilinear=2,
                           n_spherical=3, n_radial=3, d_feat=8, n_targets=3,
                           task="classification")
    b = make_graph_batch(20, 50, 8, 3, cap_per_edge=2, seed=0)
    b = {k: jnp.asarray(v) for k, v in b.items()}
    p = dn.init_params(cfg, jax.random.key(0))
    out1 = dn.forward(p, b, cfg)
    # append garbage masked triplets: output unchanged
    b2 = dict(b)
    b2["tri_edge_in"] = jnp.concatenate(
        [b["tri_edge_in"], jnp.zeros(10, jnp.int32)])
    b2["tri_edge_out"] = jnp.concatenate(
        [b["tri_edge_out"], jnp.zeros(10, jnp.int32)])
    b2["tri_mask"] = jnp.concatenate([b["tri_mask"], jnp.zeros(10, bool)])
    out2 = dn.forward(p, b2, cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-5, atol=1e-6)
