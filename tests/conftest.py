# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 CPU device.
# Only launch/dryrun.py (run as a subprocess) forces 512 virtual devices.
import numpy as np
import pytest

# Hypothesis guard: property tests degrade to *skips* (not collection errors)
# when hypothesis is absent.  Test modules import given/settings/st from here;
# with hypothesis installed (see requirements-dev.txt) they get the real API,
# without it they get stubs that mark each @given test as skipped.
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            # zero-arg stand-in: @given-provided args must not look like
            # pytest fixtures, so replace the test body with a plain skip
            def stub():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _Strategies:
        def __getattr__(self, _name):
            return lambda *_a, **_k: None

    st = _Strategies()


@pytest.fixture
def rng():
    return np.random.default_rng(0)
