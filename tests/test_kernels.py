"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("b,n,d,k,tile", [
    (1, 257, 32, 5, 64),
    (4, 1024, 64, 10, 256),
    (8, 5000, 128, 16, 512),
    (2, 100, 16, 10, 128),     # corpus smaller than tile
    (3, 4096, 64, 64, 1024),   # large k
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_search(b, n, d, k, tile, dtype):
    q = jnp.asarray(RNG.normal(size=(b, d)), dtype)
    c = jnp.asarray(RNG.normal(size=(n, d)), dtype)
    v, i = ops.topk_search(q, c, k, tile_c=tile, interpret=True)
    vr, ir = ref.topk_search_ref(q, c, k)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr),
                               rtol=tol, atol=tol)
    # ids can differ on exact ties: check score-equivalence instead
    got = np.asarray(jnp.sum(c[i] * q[:, None, :], -1), np.float32)
    np.testing.assert_allclose(got, np.asarray(vr, np.float32),
                               rtol=max(tol, 1e-4), atol=max(tol, 1e-4))


@pytest.mark.parametrize("b,h,k,tile", [
    (1, 100, 10, 64), (4, 1000, 10, 256), (8, 5000, 4, 512),
    (2, 513, 16, 512),
])
def test_homology_score(b, h, k, tile):
    draft = jnp.asarray(RNG.integers(-1, 60, (b, k)), jnp.int32)
    cache = jnp.asarray(RNG.integers(0, 60, (h, k)), jnp.int32)
    valid = jnp.asarray(RNG.random(h) > 0.3)
    s = ops.homology_score(draft, cache, valid, tile_h=tile, interpret=True)
    sr = ref.homology_score_ref(draft, cache, valid)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=1e-6)


@pytest.mark.parametrize("b,c,cap,d,p,k", [
    (2, 8, 16, 32, 3, 5), (4, 32, 64, 64, 8, 10), (1, 4, 8, 16, 2, 4),
])
def test_ivf_scan(b, c, cap, d, p, k):
    q = jnp.asarray(RNG.normal(size=(b, d)), jnp.float32)
    bv = jnp.asarray(RNG.normal(size=(c, cap, d)), jnp.float32)
    bi = jnp.asarray(RNG.integers(0, 10000, (c, cap)), jnp.int32)
    bi = jnp.where(jnp.asarray(RNG.random((c, cap)) > 0.85), -1, bi)
    probe = jnp.asarray(
        np.stack([RNG.choice(c, p, replace=False) for _ in range(b)]),
        jnp.int32)
    v, i = ops.ivf_scan(q, probe, bv, bi, k, interpret=True)
    vr, ir = ref.ivf_scan_ref(q, probe, bv, bi, k)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("v,d,b,n", [(100, 32, 8, 4), (33, 8, 2, 9),
                                     (500, 64, 16, 2)])
@pytest.mark.parametrize("mode", ["sum", "mean"])
@pytest.mark.parametrize("weighted", [False, True])
def test_embedding_bag(v, d, b, n, mode, weighted):
    t = jnp.asarray(RNG.normal(size=(v, d)), jnp.float32)
    ids = jnp.asarray(RNG.integers(0, v, (b, n)), jnp.int32)
    w = jnp.asarray(RNG.normal(size=(b, n)), jnp.float32) if weighted else None
    o = ops.embedding_bag(t, ids, w, mode, interpret=True)
    orf = ref.embedding_bag_ref(t, ids, w, mode)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               rtol=1e-4, atol=1e-5)


def test_embedding_bag_matches_segment_sum_substrate():
    """Kernel == the take+segment_sum substrate used by the models."""
    from repro.models.recsys import embedding_bag as substrate_bag
    t = jnp.asarray(RNG.normal(size=(64, 16)), jnp.float32)
    ids = jnp.asarray(RNG.integers(0, 64, (6, 5)), jnp.int32)
    seg = jnp.repeat(jnp.arange(6), 5)
    o1 = ops.embedding_bag(t, ids, mode="sum", interpret=True)
    o2 = substrate_bag(t, ids.reshape(-1), seg, 6, mode="sum")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5)


@pytest.mark.parametrize("b,h,d,s,blk,clen", [
    (2, 4, 16, 128, 32, 100), (1, 8, 32, 300, 64, 299), (3, 2, 8, 64, 64, 0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(b, h, d, s, blk, clen, dtype):
    from repro.kernels.decode_attention import decode_attention_ref
    q = jnp.asarray(RNG.normal(size=(b, h, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, s, h, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, s, h, d)), dtype)
    o = ops.decode_attention(q, k, v, jnp.int32(clen), block_s=blk,
                             interpret=True)
    orf = decode_attention_ref(q, k, v, jnp.int32(clen))
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               rtol=tol, atol=tol)
