"""HaS edge-cache snapshot/restore + warm-standby failover."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.has import (HasConfig, cache_update, cache_update_batched,
                            init_has_state, init_tenant_states)
from repro.serving.replication import (WarmStandby, gather_doc_vecs,
                                       restore, snapshot)


def _updated_state(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    state = init_has_state(cfg)
    updates = []
    for _ in range(n):
        q = rng.normal(size=(cfg.d,)).astype(np.float32)
        ids = rng.integers(0, 200, cfg.k).astype(np.int32)
        vecs = rng.normal(size=(cfg.k, cfg.d)).astype(np.float32)
        state = cache_update(cfg, state, jnp.asarray(q), jnp.asarray(ids),
                             jnp.asarray(vecs))
        updates.append((q, ids, vecs))
    return state, updates


def test_snapshot_restore_roundtrip(tmp_path):
    cfg = HasConfig(k=4, h_max=8, doc_capacity=64, d=8)
    mgr = CheckpointManager(str(tmp_path))
    state, _ = _updated_state(cfg, 5)
    snapshot(mgr, 5, state)
    step, restored = restore(mgr, cfg)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(state.query_doc_ids),
                                  np.asarray(restored.query_doc_ids))
    np.testing.assert_array_equal(np.asarray(state.doc_ids),
                                  np.asarray(restored.doc_ids))
    assert int(restored.q_ptr) == int(state.q_ptr)


def test_warm_standby_failover_replays_delta(tmp_path):
    cfg = HasConfig(k=4, h_max=16, doc_capacity=128, d=8)
    mgr = CheckpointManager(str(tmp_path))
    standby = WarmStandby(cfg, mgr, snapshot_every=4)
    state, updates = _updated_state(cfg, 10)

    # replay the primary's update stream through the standby recorder
    primary = init_has_state(cfg)
    for q, ids, vecs in updates:
        primary = cache_update(cfg, primary, jnp.asarray(q),
                               jnp.asarray(ids), jnp.asarray(vecs))
        standby.record_update(q, ids, vecs, primary)
    mgr.wait()

    recovered = standby.failover()
    # snapshot at 8 + delta of 2 -> identical to the primary
    np.testing.assert_array_equal(np.asarray(primary.query_doc_ids),
                                  np.asarray(recovered.query_doc_ids))
    assert int(recovered.q_ptr) == int(primary.q_ptr)


def test_failover_cold_start_when_no_snapshot(tmp_path):
    cfg = HasConfig(k=4, h_max=8, doc_capacity=64, d=8)
    standby = WarmStandby(cfg, CheckpointManager(str(tmp_path)))
    state = standby.failover()
    assert int(state.q_ptr) == 0


def test_failover_with_empty_delta_log_after_snapshot(tmp_path):
    """A snapshot cadence hit leaves the delta log EMPTY; failover must then
    return exactly the snapshot (no replay, no crash on the empty log)."""
    cfg = HasConfig(k=4, h_max=16, doc_capacity=128, d=8)
    standby = WarmStandby(cfg, CheckpointManager(str(tmp_path)),
                          snapshot_every=6)
    primary = init_has_state(cfg)
    rng = np.random.default_rng(4)
    for _ in range(6):                       # lands exactly on the cadence
        q = rng.normal(size=(cfg.d,)).astype(np.float32)
        ids = rng.integers(0, 200, cfg.k).astype(np.int32)
        vecs = rng.normal(size=(cfg.k, cfg.d)).astype(np.float32)
        primary = cache_update(cfg, primary, jnp.asarray(q),
                               jnp.asarray(ids), jnp.asarray(vecs))
        standby.record_update(q, ids, vecs, primary)
    standby.mgr.wait()
    assert len(standby.log) == 0             # cleared by the snapshot
    recovered = standby.failover()
    np.testing.assert_array_equal(np.asarray(primary.query_doc_ids),
                                  np.asarray(recovered.query_doc_ids))
    np.testing.assert_array_equal(np.asarray(primary.doc_ids),
                                  np.asarray(recovered.doc_ids))
    assert int(recovered.q_ptr) == int(primary.q_ptr)


def test_record_batch_cadence_boundary_at_exactly_full_batch(tmp_path):
    """One record_batch whose row count lands EXACTLY on snapshot_every:
    the cadence fires once, after the whole batch (empty log left), and a
    later partial batch replays on top of that snapshot bit-exactly."""
    cfg = HasConfig(k=4, h_max=16, doc_capacity=128, d=8)
    standby = WarmStandby(cfg, CheckpointManager(str(tmp_path)),
                          snapshot_every=8)
    rng = np.random.default_rng(7)

    def batch(n):
        return (rng.normal(size=(n, cfg.d)).astype(np.float32),
                rng.integers(0, 200, size=(n, cfg.k)).astype(np.int32),
                rng.normal(size=(n, cfg.k, cfg.d)).astype(np.float32))

    primary = init_has_state(cfg)
    qs, ids, vecs = batch(8)                 # exactly-full batch
    for i in range(8):
        primary = cache_update(cfg, primary, jnp.asarray(qs[i]),
                               jnp.asarray(ids[i]), jnp.asarray(vecs[i]))
    standby.record_batch(qs, ids, vecs, primary)
    standby.mgr.wait()
    assert len(standby.log) == 0             # snapshot AFTER the whole batch
    assert standby._since_snapshot == 0
    # partial follow-up batch: snapshot + 3-entry delta replay
    qs2, ids2, vecs2 = batch(3)
    for i in range(3):
        primary = cache_update(cfg, primary, jnp.asarray(qs2[i]),
                               jnp.asarray(ids2[i]), jnp.asarray(vecs2[i]))
    standby.record_batch(qs2, ids2, vecs2, primary)
    assert len(standby.log) == 3
    recovered = standby.failover()
    for f in ("query_emb", "query_doc_ids", "query_valid", "q_ptr",
              "doc_emb", "doc_ids", "d_ptr"):
        np.testing.assert_array_equal(np.asarray(getattr(primary, f)),
                                      np.asarray(getattr(recovered, f)),
                                      err_msg=f)


def test_record_batch_rejects_mismatched_leading_dims(tmp_path):
    """Regression: the recording loop used a bare zip over
    (q_embs, full_ids, full_vecs, tenant_ids), which silently DROPPED tail
    rows when one argument was shorter — the standby then diverged from
    the primary with no error.  Mismatches must raise instead."""
    cfg = HasConfig(k=4, h_max=8, doc_capacity=32, d=8)
    standby = WarmStandby(cfg, CheckpointManager(str(tmp_path)))
    rng = np.random.default_rng(0)
    qs = rng.normal(size=(4, cfg.d)).astype(np.float32)
    ids = rng.integers(0, 50, size=(4, cfg.k)).astype(np.int32)
    vecs = rng.normal(size=(4, cfg.k, cfg.d)).astype(np.float32)
    state = init_has_state(cfg)
    for bad in [(qs[:3], ids, vecs, None), (qs, ids[:2], vecs, None),
                (qs, ids, vecs[:1], None),
                (qs, ids, vecs, np.zeros(3, np.int32))]:
        with pytest.raises(ValueError, match="leading dimensions"):
            standby.record_batch(bad[0], bad[1], bad[2], state,
                                 tenant_ids=bad[3])
    assert len(standby.log) == 0             # nothing partially recorded
    standby.record_batch(qs, ids, vecs, state)   # matching dims still fine
    assert len(standby.log) == 4


def test_gather_doc_vecs_zeroes_padded_ids():
    """Regression: corpus[full_ids] wraps -1 pythonically and gathers the
    LAST corpus row into padded slots (corpus < k searches emit -1)."""
    corpus = np.arange(5 * 3, dtype=np.float32).reshape(5, 3)
    ids = np.array([[0, 4, -1], [-1, 2, -1]], np.int32)
    vecs = gather_doc_vecs(corpus, ids)
    np.testing.assert_array_equal(vecs[0, 0], corpus[0])
    np.testing.assert_array_equal(vecs[0, 1], corpus[4])
    np.testing.assert_array_equal(vecs[0, 2], 0.0)   # NOT corpus[-1]
    np.testing.assert_array_equal(vecs[1, 0], 0.0)
    np.testing.assert_array_equal(vecs[1, 2], 0.0)


def test_async_snapshot_immune_to_donating_ingest_churn(tmp_path):
    """Regression: snapshot(..., blocking=False) handed the checkpoint
    writer a host view that can ALIAS the device buffers on CPU; the next
    donated cache_update_batched overwrote them mid-save, corrupting the
    checkpoint (same class of bug for train.py's donated step_fn).  The
    WRITER THREAD must receive a host copy (asserted via np.shares_memory
    at the _write boundary — deterministic, unlike the race itself) and
    the restored value must match the state at call time regardless of
    immediately-following donation churn."""
    cfg = HasConfig(k=8, h_max=256, doc_capacity=4096, d=64)
    captured = {}

    class SpyMgr(CheckpointManager):
        def _write(self, step, host_tree):
            captured["tree"] = host_tree
            super()._write(step, host_tree)

    mgr = SpyMgr(str(tmp_path))
    rng = np.random.default_rng(5)

    def batch(n):
        return (jnp.asarray(rng.normal(size=(n, cfg.d)), jnp.float32),
                jnp.asarray(rng.integers(0, 5000, size=(n, cfg.k)),
                            jnp.int32),
                jnp.asarray(rng.normal(size=(n, cfg.k, cfg.d)), jnp.float32))

    state = init_has_state(cfg)
    state = cache_update_batched(cfg, state, *batch(32))   # warm + compile
    expect = {f: np.array(getattr(state, f)) for f in
              ("query_emb", "query_doc_ids", "query_valid", "q_ptr",
               "doc_emb", "doc_ids", "d_ptr")}
    snapshot(mgr, 1, state, blocking=False)
    mgr.wait()                     # writer done; captured["tree"] is set
    # the writer thread's tree must not alias the live device buffers (on
    # CPU, device_get of a jax array can be a zero-copy view — handing
    # THAT to the background thread is the bug)
    for f in ("doc_emb", "query_emb", "doc_ids"):
        assert not np.shares_memory(np.asarray(captured["tree"][f]),
                                    np.asarray(getattr(state, f))), f
    # donation churn: each call recycles the previous state's buffers in
    # place — the checkpoint on disk must still hold the pre-churn values
    for _ in range(8):
        state = cache_update_batched(cfg, state, *batch(32))
    mgr.wait()
    _, restored = restore(mgr, cfg)
    for f, v in expect.items():
        np.testing.assert_array_equal(np.asarray(getattr(restored, f)), v,
                                      err_msg=f)


def test_restore_validates_tenant_count(tmp_path):
    """Regression: snapshots recorded no tenant layout, so a wrong-T
    restore surfaced as an opaque downstream shape mismatch (or a silent
    misread between the unstacked T == 1 layout and a stacked store)."""
    cfg = HasConfig(k=4, h_max=8, doc_capacity=32, d=8)
    mgr = CheckpointManager(str(tmp_path))
    snapshot(mgr, 3, init_tenant_states(cfg, 3))
    with pytest.raises(ValueError, match="3-tenant"):
        restore(mgr, cfg, n_tenants=2)
    with pytest.raises(ValueError, match="3-tenant"):
        restore(mgr, cfg, n_tenants=1)
    step, state = restore(mgr, cfg, n_tenants=3)    # the right count loads
    assert step == 3 and state.q_ptr.shape == (3,)


def test_restore_distinguishes_stacked_one_tenant_from_unstacked(tmp_path):
    """A stacked [1, ...] store has shapes a template can silently misread
    against the unstacked layout — the layout stamp must catch it."""
    cfg = HasConfig(k=4, h_max=8, doc_capacity=32, d=8)
    mgr = CheckpointManager(str(tmp_path))
    snapshot(mgr, 1, init_tenant_states(cfg, 1))    # stacked, T == 1
    with pytest.raises(ValueError, match="stacked 1-tenant"):
        restore(mgr, cfg, n_tenants=1)              # unstacked template
    mgr2 = CheckpointManager(str(tmp_path / "unstacked"))
    snapshot(mgr2, 1, init_has_state(cfg))          # historical layout
    with pytest.raises(ValueError, match="unstacked"):
        restore(mgr2, cfg, n_tenants=2)
    step, state = restore(mgr2, cfg, n_tenants=1)
    assert step == 1 and state.q_ptr.ndim == 0


def test_multi_tenant_failover_rebuilds_each_partition(tmp_path):
    """Per-tenant delta logs: a stacked 3-tenant primary rebuilds
    bit-exactly, partition by partition — including one tenant whose log
    is empty (it saw no ingests since the snapshot)."""
    from repro.core.has import cache_update_batched, init_tenant_states
    cfg = HasConfig(k=4, h_max=8, doc_capacity=32, d=8)
    T = 3
    standby = WarmStandby(cfg, CheckpointManager(str(tmp_path)),
                          snapshot_every=10**9, n_tenants=T)
    primary = init_tenant_states(cfg, T)
    rng = np.random.default_rng(11)
    # tenants 0 and 2 ingest; tenant 1 stays quiet (empty log)
    tids = np.array([0, 2, 0, 2, 2], np.int32)
    qs = rng.normal(size=(5, cfg.d)).astype(np.float32)
    ids = rng.integers(0, 60, size=(5, cfg.k)).astype(np.int32)
    vecs = rng.normal(size=(5, cfg.k, cfg.d)).astype(np.float32)
    primary = cache_update_batched(cfg, primary, jnp.asarray(qs),
                                   jnp.asarray(ids), jnp.asarray(vecs),
                                   tenant_ids=jnp.asarray(tids))
    standby.record_batch(qs, ids, vecs, primary, tenant_ids=tids)
    assert [len(log) for log in standby.logs] == [2, 0, 3]
    recovered = standby.failover()
    for f in ("query_emb", "query_doc_ids", "query_valid", "q_ptr",
              "doc_emb", "doc_ids", "d_ptr"):
        np.testing.assert_array_equal(np.asarray(getattr(primary, f)),
                                      np.asarray(getattr(recovered, f)),
                                      err_msg=f)
