"""HaS edge-cache snapshot/restore + warm-standby failover."""
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.has import HasConfig, cache_update, init_has_state
from repro.serving.replication import WarmStandby, restore, snapshot


def _updated_state(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    state = init_has_state(cfg)
    updates = []
    for _ in range(n):
        q = rng.normal(size=(cfg.d,)).astype(np.float32)
        ids = rng.integers(0, 200, cfg.k).astype(np.int32)
        vecs = rng.normal(size=(cfg.k, cfg.d)).astype(np.float32)
        state = cache_update(cfg, state, jnp.asarray(q), jnp.asarray(ids),
                             jnp.asarray(vecs))
        updates.append((q, ids, vecs))
    return state, updates


def test_snapshot_restore_roundtrip(tmp_path):
    cfg = HasConfig(k=4, h_max=8, doc_capacity=64, d=8)
    mgr = CheckpointManager(str(tmp_path))
    state, _ = _updated_state(cfg, 5)
    snapshot(mgr, 5, state)
    step, restored = restore(mgr, cfg)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(state.query_doc_ids),
                                  np.asarray(restored.query_doc_ids))
    np.testing.assert_array_equal(np.asarray(state.doc_ids),
                                  np.asarray(restored.doc_ids))
    assert int(restored.q_ptr) == int(state.q_ptr)


def test_warm_standby_failover_replays_delta(tmp_path):
    cfg = HasConfig(k=4, h_max=16, doc_capacity=128, d=8)
    mgr = CheckpointManager(str(tmp_path))
    standby = WarmStandby(cfg, mgr, snapshot_every=4)
    state, updates = _updated_state(cfg, 10)

    # replay the primary's update stream through the standby recorder
    primary = init_has_state(cfg)
    for q, ids, vecs in updates:
        primary = cache_update(cfg, primary, jnp.asarray(q),
                               jnp.asarray(ids), jnp.asarray(vecs))
        standby.record_update(q, ids, vecs, primary)
    mgr.wait()

    recovered = standby.failover()
    # snapshot at 8 + delta of 2 -> identical to the primary
    np.testing.assert_array_equal(np.asarray(primary.query_doc_ids),
                                  np.asarray(recovered.query_doc_ids))
    assert int(recovered.q_ptr) == int(primary.q_ptr)


def test_failover_cold_start_when_no_snapshot(tmp_path):
    cfg = HasConfig(k=4, h_max=8, doc_capacity=64, d=8)
    standby = WarmStandby(cfg, CheckpointManager(str(tmp_path)))
    state = standby.failover()
    assert int(state.q_ptr) == 0
