"""HaS edge-cache snapshot/restore + warm-standby failover."""
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.has import HasConfig, cache_update, init_has_state
from repro.serving.replication import WarmStandby, restore, snapshot


def _updated_state(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    state = init_has_state(cfg)
    updates = []
    for _ in range(n):
        q = rng.normal(size=(cfg.d,)).astype(np.float32)
        ids = rng.integers(0, 200, cfg.k).astype(np.int32)
        vecs = rng.normal(size=(cfg.k, cfg.d)).astype(np.float32)
        state = cache_update(cfg, state, jnp.asarray(q), jnp.asarray(ids),
                             jnp.asarray(vecs))
        updates.append((q, ids, vecs))
    return state, updates


def test_snapshot_restore_roundtrip(tmp_path):
    cfg = HasConfig(k=4, h_max=8, doc_capacity=64, d=8)
    mgr = CheckpointManager(str(tmp_path))
    state, _ = _updated_state(cfg, 5)
    snapshot(mgr, 5, state)
    step, restored = restore(mgr, cfg)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(state.query_doc_ids),
                                  np.asarray(restored.query_doc_ids))
    np.testing.assert_array_equal(np.asarray(state.doc_ids),
                                  np.asarray(restored.doc_ids))
    assert int(restored.q_ptr) == int(state.q_ptr)


def test_warm_standby_failover_replays_delta(tmp_path):
    cfg = HasConfig(k=4, h_max=16, doc_capacity=128, d=8)
    mgr = CheckpointManager(str(tmp_path))
    standby = WarmStandby(cfg, mgr, snapshot_every=4)
    state, updates = _updated_state(cfg, 10)

    # replay the primary's update stream through the standby recorder
    primary = init_has_state(cfg)
    for q, ids, vecs in updates:
        primary = cache_update(cfg, primary, jnp.asarray(q),
                               jnp.asarray(ids), jnp.asarray(vecs))
        standby.record_update(q, ids, vecs, primary)
    mgr.wait()

    recovered = standby.failover()
    # snapshot at 8 + delta of 2 -> identical to the primary
    np.testing.assert_array_equal(np.asarray(primary.query_doc_ids),
                                  np.asarray(recovered.query_doc_ids))
    assert int(recovered.q_ptr) == int(primary.q_ptr)


def test_failover_cold_start_when_no_snapshot(tmp_path):
    cfg = HasConfig(k=4, h_max=8, doc_capacity=64, d=8)
    standby = WarmStandby(cfg, CheckpointManager(str(tmp_path)))
    state = standby.failover()
    assert int(state.q_ptr) == 0


def test_failover_with_empty_delta_log_after_snapshot(tmp_path):
    """A snapshot cadence hit leaves the delta log EMPTY; failover must then
    return exactly the snapshot (no replay, no crash on the empty log)."""
    cfg = HasConfig(k=4, h_max=16, doc_capacity=128, d=8)
    standby = WarmStandby(cfg, CheckpointManager(str(tmp_path)),
                          snapshot_every=6)
    primary = init_has_state(cfg)
    rng = np.random.default_rng(4)
    for _ in range(6):                       # lands exactly on the cadence
        q = rng.normal(size=(cfg.d,)).astype(np.float32)
        ids = rng.integers(0, 200, cfg.k).astype(np.int32)
        vecs = rng.normal(size=(cfg.k, cfg.d)).astype(np.float32)
        primary = cache_update(cfg, primary, jnp.asarray(q),
                               jnp.asarray(ids), jnp.asarray(vecs))
        standby.record_update(q, ids, vecs, primary)
    standby.mgr.wait()
    assert len(standby.log) == 0             # cleared by the snapshot
    recovered = standby.failover()
    np.testing.assert_array_equal(np.asarray(primary.query_doc_ids),
                                  np.asarray(recovered.query_doc_ids))
    np.testing.assert_array_equal(np.asarray(primary.doc_ids),
                                  np.asarray(recovered.doc_ids))
    assert int(recovered.q_ptr) == int(primary.q_ptr)


def test_record_batch_cadence_boundary_at_exactly_full_batch(tmp_path):
    """One record_batch whose row count lands EXACTLY on snapshot_every:
    the cadence fires once, after the whole batch (empty log left), and a
    later partial batch replays on top of that snapshot bit-exactly."""
    cfg = HasConfig(k=4, h_max=16, doc_capacity=128, d=8)
    standby = WarmStandby(cfg, CheckpointManager(str(tmp_path)),
                          snapshot_every=8)
    rng = np.random.default_rng(7)

    def batch(n):
        return (rng.normal(size=(n, cfg.d)).astype(np.float32),
                rng.integers(0, 200, size=(n, cfg.k)).astype(np.int32),
                rng.normal(size=(n, cfg.k, cfg.d)).astype(np.float32))

    primary = init_has_state(cfg)
    qs, ids, vecs = batch(8)                 # exactly-full batch
    for i in range(8):
        primary = cache_update(cfg, primary, jnp.asarray(qs[i]),
                               jnp.asarray(ids[i]), jnp.asarray(vecs[i]))
    standby.record_batch(qs, ids, vecs, primary)
    standby.mgr.wait()
    assert len(standby.log) == 0             # snapshot AFTER the whole batch
    assert standby._since_snapshot == 0
    # partial follow-up batch: snapshot + 3-entry delta replay
    qs2, ids2, vecs2 = batch(3)
    for i in range(3):
        primary = cache_update(cfg, primary, jnp.asarray(qs2[i]),
                               jnp.asarray(ids2[i]), jnp.asarray(vecs2[i]))
    standby.record_batch(qs2, ids2, vecs2, primary)
    assert len(standby.log) == 3
    recovered = standby.failover()
    for f in ("query_emb", "query_doc_ids", "query_valid", "q_ptr",
              "doc_emb", "doc_ids", "d_ptr"):
        np.testing.assert_array_equal(np.asarray(getattr(primary, f)),
                                      np.asarray(getattr(recovered, f)),
                                      err_msg=f)


def test_multi_tenant_failover_rebuilds_each_partition(tmp_path):
    """Per-tenant delta logs: a stacked 3-tenant primary rebuilds
    bit-exactly, partition by partition — including one tenant whose log
    is empty (it saw no ingests since the snapshot)."""
    from repro.core.has import cache_update_batched, init_tenant_states
    cfg = HasConfig(k=4, h_max=8, doc_capacity=32, d=8)
    T = 3
    standby = WarmStandby(cfg, CheckpointManager(str(tmp_path)),
                          snapshot_every=10**9, n_tenants=T)
    primary = init_tenant_states(cfg, T)
    rng = np.random.default_rng(11)
    # tenants 0 and 2 ingest; tenant 1 stays quiet (empty log)
    tids = np.array([0, 2, 0, 2, 2], np.int32)
    qs = rng.normal(size=(5, cfg.d)).astype(np.float32)
    ids = rng.integers(0, 60, size=(5, cfg.k)).astype(np.int32)
    vecs = rng.normal(size=(5, cfg.k, cfg.d)).astype(np.float32)
    primary = cache_update_batched(cfg, primary, jnp.asarray(qs),
                                   jnp.asarray(ids), jnp.asarray(vecs),
                                   tenant_ids=jnp.asarray(tids))
    standby.record_batch(qs, ids, vecs, primary, tenant_ids=tids)
    assert [len(log) for log in standby.logs] == [2, 0, 3]
    recovered = standby.failover()
    for f in ("query_emb", "query_doc_ids", "query_valid", "q_ptr",
              "doc_emb", "doc_ids", "d_ptr"):
        np.testing.assert_array_equal(np.asarray(getattr(primary, f)),
                                      np.asarray(getattr(recovered, f)),
                                      err_msg=f)
