"""Serving-engine integration: HaS vs baselines on a small world (fast)."""
import numpy as np
import pytest

from repro.core.has import HasConfig, cache_memory_bytes
from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
from repro.serving.engine import (ANNSEngine, CRAGEngine, FullRetrievalEngine,
                                  HasEngine, ReuseEngine, RetrievalService)
from repro.serving.latency import LatencyModel


@pytest.fixture(scope="module")
def service():
    world = SyntheticWorld(WorldConfig(n_entities=800, seed=0))
    return RetrievalService(world, LatencyModel(), k=10, chunk=2048)


@pytest.fixture(scope="module")
def queries(service):
    ds = DATASETS["granola"]
    return service.world.sample_queries(
        500, pattern=ds["pattern"], zipf_a=ds["zipf_a"],
        p_uncovered=ds["p_uncovered"], seed=1)


def _has(service, **kw):
    cfg = HasConfig(k=10, tau=kw.pop("tau", 0.2), h_max=kw.pop("h_max", 800),
                    nprobe=8, n_buckets=128, d=service.world.cfg.d, **kw)
    return HasEngine(service, cfg)


def test_has_reduces_latency_vs_full(service, queries):
    full = FullRetrievalEngine(service).serve(queries[:150]).summary()
    has = _has(service).serve(queries).summary()
    assert has["avg_latency_s"] < full["avg_latency_s"] * 0.95
    assert has["dar"] > 0.1
    # accuracy within a few points (paper: 1-2%)
    assert has["doc_hit_rate"] > full["doc_hit_rate"] - 0.08


def test_l_at_da_much_smaller_than_l_at_dr(service, queries):
    s = _has(service).serve(queries).summary()
    # fast path ~= edge RTT + fuzzy scan; slow path ~= cloud RTT + full scan
    assert s["l_at_da"] < 0.4 < s["l_at_dr"]


def test_higher_tau_stricter(service, queries):
    lo = _has(service, tau=0.1).serve(queries).summary()
    hi = _has(service, tau=0.5).serve(queries).summary()
    assert hi["dar"] <= lo["dar"] + 1e-9
    assert hi["avg_latency_s"] >= lo["avg_latency_s"] - 0.02


def test_larger_cache_more_acceptance(service, queries):
    small = _has(service, h_max=50).serve(queries).summary()
    large = _has(service, h_max=800).serve(queries).summary()
    assert large["dar"] >= small["dar"] - 0.02
    assert cache_memory_bytes(HasConfig(h_max=800, d=64)) > \
        cache_memory_bytes(HasConfig(h_max=50, d=64))


def test_reuse_engines_run(service, queries):
    for method, kw in [("proximity", dict(theta=0.85)),
                       ("saferadius", dict(alpha=2.0)),
                       ("mincache", dict(t_lex=0.5, t_sem=0.85))]:
        s = ReuseEngine(service, method, h_max=800, **kw).serve(
            queries[:200]).summary()
        assert np.isfinite(s["avg_latency_s"])
        # reuse-based methods never beat HaS on DAR (homology >> identity)
    prox = ReuseEngine(service, "proximity", h_max=800, theta=0.85)
    sp = prox.serve(queries).summary()
    sh = _has(service).serve(queries).summary()
    assert sh["dar"] > sp["dar"]


def test_crag_pays_evaluator_latency(service, queries):
    crag = CRAGEngine(service).serve(queries[:100]).summary()
    has = _has(service).serve(queries[:100]).summary()
    # the 0.7s LLM judge makes even accepted drafts slow
    assert crag["l_at_da"] > 0.55
    assert has["l_at_da"] < 0.2


def test_anns_engine_edge_vs_cloud(service, queries):
    edge = ANNSEngine(service, "ivf", n_buckets=128, nprobe=4,
                      on_edge=True).serve(queries[:100]).summary()
    cloud = ANNSEngine(service, "ivf", n_buckets=128, nprobe=40,
                       on_edge=False).serve(queries[:100]).summary()
    assert edge["avg_latency_s"] < cloud["avg_latency_s"]
    assert cloud["doc_hit_rate"] >= edge["doc_hit_rate"] - 0.05


def test_has_with_anns_fallback(service, queries):
    fallback = ANNSEngine(service, "ivf", n_buckets=128, nprobe=40,
                          on_edge=False)
    combo = HasEngine(service, HasConfig(k=10, tau=0.2, h_max=800, nprobe=8,
                                         n_buckets=128, d=64),
                      fallback=fallback).serve(queries).summary()
    plain = ANNSEngine(service, "ivf", n_buckets=128, nprobe=40,
                       on_edge=False).serve(queries).summary()
    assert combo["avg_latency_s"] < plain["avg_latency_s"]
