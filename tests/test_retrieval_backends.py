"""Pluggable full-retrieval backends (retrieval/service.py): sharded-mesh
parity vs the chunked oracle (incl. the shard<k edge case), worker-pool
scheduling end-to-end, replica ingest reconciliation, and the
max_inflight_full deprecation shim.

The CI `distributed-backend` job runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the mesh path
exercises real multi-shard collectives; on a 1-device tier-1 run the same
tests pass with a 1-shard mesh (the emulation path covers multi-shard
math there).
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.has import HasConfig
from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
from repro.retrieval.distributed import (distributed_flat_search,
                                         sharded_topk_reference)
from repro.retrieval.flat import chunked_flat_search
from repro.retrieval.service import (FullRetrievalBackend, LocalFlatBackend,
                                     ReplicaBackend, RetrievalService,
                                     ShardedMeshBackend)
from repro.serving.latency import LatencyModel
from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                     SchedulerConfig)


def _unit(rng, n, d):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _host_mesh():
    """Mesh over every available device (8 under the CI distributed job)."""
    n = jax.device_count()
    return jax.make_mesh((1, n), ("data", "model")), n


@pytest.mark.parametrize("n,k,shards", [
    (1024, 10, 8),        # plain multi-shard
    (32, 10, 8),          # shard rows (4) < k (10)
    (5, 7, 2),            # whole corpus < k -> -1 padded tail
    (257, 10, 4),         # ragged tail block (emulation pads)
])
def test_sharded_reference_matches_chunked(n, k, shards):
    rng = np.random.default_rng(0)
    c = jnp.asarray(_unit(rng, n, 16))
    q = jnp.asarray(_unit(rng, 5, 16))
    s_ref, i_ref = chunked_flat_search(c, q, k, chunk=64)
    s_sh, i_sh = sharded_topk_reference(c, q, k, n_shards=shards)
    assert np.array_equal(np.asarray(i_ref), np.asarray(i_sh))
    live = np.asarray(i_ref) >= 0
    assert np.array_equal(np.asarray(s_ref)[live], np.asarray(s_sh)[live])


def test_distributed_shard_smaller_than_k():
    """Regression: a corpus shard with fewer than k rows must pad its local
    candidates to k (-inf/-1) before the all-gather, so the global merge
    returns the exact chunked result (and -1 only when the corpus < k)."""
    mesh, n_dev = _host_mesh()
    search = distributed_flat_search(mesh, ("data", "model"))
    rng = np.random.default_rng(1)
    # rows per shard < k, and (on 1 device) corpus < k
    n = 4 * n_dev if n_dev > 1 else 5
    k = 10 if n_dev > 1 else 7
    c = jnp.asarray(_unit(rng, n, 16))
    q = jnp.asarray(_unit(rng, 3, 16))
    s, i = jax.jit(lambda cc, qq: search(cc, qq, k))(c, q)
    s_ref, i_ref = chunked_flat_search(c, q, k, chunk=8)
    assert np.array_equal(np.asarray(i), np.asarray(i_ref))
    live = np.asarray(i_ref) >= 0
    assert np.array_equal(np.asarray(s)[live], np.asarray(s_ref)[live])


def test_sharded_mesh_backend_bit_identical_to_local_flat():
    """Acceptance: ShardedMeshBackend == LocalFlatBackend on the parity
    suite, through the real mesh when >1 host devices are forced."""
    mesh, n_dev = _host_mesh()
    rng = np.random.default_rng(2)
    lat = LatencyModel()
    c = jnp.asarray(_unit(rng, 128 * n_dev, 16))
    q = jnp.asarray(_unit(rng, 6, 16))
    flat = LocalFlatBackend(c, 10, lat, chunk=64)
    shard = ShardedMeshBackend(c, 10, lat, mesh=mesh, n_shards=4)
    s0, i0 = flat.search(q)
    s1, i1 = shard.search(q)
    assert np.array_equal(np.asarray(i0), np.asarray(i1))
    assert np.array_equal(np.asarray(s0), np.asarray(s1))
    # shard<k through the same backend pair (>=2 rows per shard: width-1
    # matmuls may differ from the wide gemm in the last ulp)
    c2 = jnp.asarray(_unit(rng, max(8, 4 * n_dev), 16))
    flat2 = LocalFlatBackend(c2, 10, lat, chunk=8)
    shard2 = ShardedMeshBackend(c2, 10, lat, mesh=mesh, n_shards=4)
    s0, i0 = flat2.search(q)
    s1, i1 = shard2.search(q)
    assert np.array_equal(np.asarray(i0), np.asarray(i1))
    live = np.asarray(i0) >= 0
    assert np.array_equal(np.asarray(s0)[live], np.asarray(s1)[live])


def test_backend_protocol_and_latency_model():
    rng = np.random.default_rng(3)
    lat = LatencyModel()
    c = jnp.asarray(_unit(rng, 256, 16))
    flat = LocalFlatBackend(c, 10, lat, chunk=64)
    shard = ShardedMeshBackend(c, 10, lat, n_shards=8, n_workers=4)
    assert isinstance(flat, FullRetrievalBackend)
    assert isinstance(shard, FullRetrievalBackend)
    assert flat.n_workers == 1 and shard.n_workers == 4
    # shard_scale: monotone decreasing over realistic shard counts,
    # and the sharded scan is strictly faster than the flat scan
    scales = [lat.shard_scale(s) for s in (1, 2, 4, 8, 16)]
    assert all(a > b for a, b in zip(scales, scales[1:]))
    assert lat.shard_scale(1) == 1.0
    assert shard.latency(16) < flat.latency(16)


@pytest.fixture(scope="module")
def world_setup():
    world = SyntheticWorld(WorldConfig(n_entities=600, seed=0))
    ds = DATASETS["granola"]
    qs = world.sample_queries(300, pattern=ds["pattern"], zipf_a=ds["zipf_a"],
                              p_uncovered=ds["p_uncovered"], seed=1)
    cfg = HasConfig(k=10, tau=0.2, h_max=600, nprobe=4, n_buckets=256, d=64)
    return world, qs, cfg


def _sched(world, cfg, backend=None, **sched_kw):
    lat = LatencyModel()
    if callable(backend):
        backend = backend(jnp.asarray(world.doc_emb), lat)
    svc = RetrievalService(world, lat, k=10, chunk=2048, backend=backend)
    kw = dict(max_spec_batch=16, full_batch=8, full_max_wait_s=0.1)
    kw.update(sched_kw)
    return ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(**kw))


def test_scheduler_sharded_worker_pool_e2e(world_setup):
    """End-to-end: a 4-worker sharded backend overlaps full-retrieval
    batches (pool concurrency > 1), completes every request, and beats the
    serialized flat backend's saturated throughput."""
    world, qs, cfg = world_setup
    flat = _sched(world, cfg)
    r0 = flat.serve(qs, None, seed=0)
    sharded = _sched(world, cfg, backend=lambda c, lat: ShardedMeshBackend(
        c, 10, lat, n_shards=4, n_workers=4))
    assert sharded.n_full_workers == 4
    r1 = sharded.serve(qs, None, seed=0)
    assert np.all(r1.t_done >= 0) and np.all(r1.channels != "pending")
    assert r1.max_inflight_full_batches >= 2
    assert r0.max_inflight_full_batches == 1
    s0, s1 = r0.summary(), r1.summary()
    assert s1["throughput_qps"] > s0["throughput_qps"]
    # same stream, same accuracy substrate: doc-hit within a few points
    assert abs(s1["doc_hit_rate"] - s0["doc_hit_rate"]) < 0.08


def test_replica_backend_reconciles_standby_cache(world_setup):
    """Failover parity: after a served stream, a standby rebuilt from its
    reconciled delta log holds EXACTLY the cache the scheduler ended with —
    no single authoritative copy."""
    from repro.checkpoint import CheckpointManager
    from repro.serving.replication import WarmStandby
    world, qs, cfg = world_setup
    standby = WarmStandby(cfg, CheckpointManager(tempfile.mkdtemp()),
                          snapshot_every=10**9, max_lag=10**6)
    sch = _sched(world, cfg, backend=lambda c, lat: ReplicaBackend(
        LocalFlatBackend(c, 10, lat, chunk=2048), [standby], c))
    assert sch.n_full_workers == 1
    sch.serve(qs, None, seed=0)
    assert len(standby.log) > 0
    recovered = standby.failover()
    for a, b in zip(jax.tree.leaves(recovered), jax.tree.leaves(sch.state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_replica_failover_parity_across_snapshots(world_setup):
    """Regression: a snapshot cadence boundary landing inside an ingest
    batch must not double-apply the batch tail — record_batch appends the
    whole batch before the cadence check, so failover (snapshot + replayed
    log) still rebuilds the primary's cache bit-exactly."""
    from repro.checkpoint import CheckpointManager
    from repro.serving.replication import WarmStandby
    world, qs, cfg = world_setup
    standby = WarmStandby(cfg, CheckpointManager(tempfile.mkdtemp()),
                          snapshot_every=40, max_lag=10**6)
    sch = _sched(world, cfg, backend=lambda c, lat: ReplicaBackend(
        LocalFlatBackend(c, 10, lat, chunk=2048), [standby], c))
    sch.serve(qs, None, seed=0)
    standby.mgr.wait()                    # drain the async snapshot writer
    recovered = standby.failover()
    for a, b in zip(jax.tree.leaves(recovered), jax.tree.leaves(sch.state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_replica_mirrors_sequential_engine_ingest(world_setup):
    """The reconciliation contract holds outside the scheduler too: the
    sequential HasEngine's per-query cache_update also lands on the
    standby log (launch/serve.py --retrieval-backend replica --engine
    has)."""
    from repro.checkpoint import CheckpointManager
    from repro.serving.engine import HasEngine
    from repro.serving.replication import WarmStandby
    world, qs, cfg = world_setup
    standby = WarmStandby(cfg, CheckpointManager(tempfile.mkdtemp()),
                          snapshot_every=10**9, max_lag=10**6)
    lat = LatencyModel()
    corpus = jnp.asarray(world.doc_emb)
    svc = RetrievalService(world, lat, k=10, chunk=2048,
                           backend=ReplicaBackend(
                               LocalFlatBackend(corpus, 10, lat, chunk=2048),
                               [standby], corpus))
    eng = HasEngine(svc, cfg)
    eng.serve(qs[:80])
    assert len(standby.log) > 0
    recovered = standby.failover()
    for a, b in zip(jax.tree.leaves(recovered), jax.tree.leaves(eng.state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_replica_on_ingest_masks_padded_ids_corpus_smaller_than_k():
    """Regression: corpus < k searches emit -1 padded ids, and
    ReplicaBackend.on_ingest gathered corpus[-1] (the LAST corpus row)
    into every padded slot of the standby delta logs.  Padded rows must
    record ZERO vectors, and failover must still rebuild the primary's
    cache bit-exactly."""
    import tempfile

    from repro.checkpoint import CheckpointManager
    from repro.core.has import cache_update_chunked, init_has_state
    from repro.serving.replication import WarmStandby
    rng = np.random.default_rng(7)
    n, k, d = 5, 7, 16                       # whole corpus < k
    corpus = jnp.asarray(_unit(rng, n, d))
    lat = LatencyModel()
    cfg = HasConfig(k=k, tau=0.2, h_max=16, doc_capacity=64, d=d)
    standby = WarmStandby(cfg, CheckpointManager(tempfile.mkdtemp()),
                          snapshot_every=10**9, max_lag=10**6)
    backend = ReplicaBackend(
        ShardedMeshBackend(corpus, k, lat, n_shards=2), [standby], corpus)
    qs = np.asarray(_unit(rng, 6, d), np.float32)
    _, ids = backend.search(jnp.asarray(qs))
    ids = np.asarray(ids, np.int32)
    assert (ids < 0).any()                   # the padded-tail case is live
    # primary folds the same rows the way the scheduler does (device-side
    # corpus gather); the backend mirrors them onto the standby log
    primary = cache_update_chunked(cfg, init_has_state(cfg), qs, ids,
                                   corpus=corpus, chunk=4)
    backend.on_ingest(qs, ids, primary)
    last_row = np.asarray(corpus[-1])
    for q, row_ids, vecs in standby.log:
        pad = row_ids < 0
        assert pad.any()
        assert np.all(vecs[pad] == 0.0), "padded slot gathered corpus[-1]"
        assert not np.any([np.array_equal(v, last_row)
                           for v in vecs[pad]])
    recovered = standby.failover()
    for a, b in zip(jax.tree.leaves(recovered), jax.tree.leaves(primary)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_scheduler_replica_failover_bit_equal_corpus_smaller_than_k():
    """End-to-end corpus < k: the scheduler served over a ReplicaBackend
    whose every search pads with -1 — standby failover must equal the
    scheduler's final cache bit-for-bit."""
    import tempfile

    from repro.checkpoint import CheckpointManager
    from repro.data.synthetic import SyntheticWorld, WorldConfig
    from repro.serving.replication import WarmStandby
    world = SyntheticWorld(WorldConfig(n_entities=2, seed=0))
    corpus = jnp.asarray(world.doc_emb[:6])  # 6 rows < k = 10
    lat = LatencyModel()
    cfg = HasConfig(k=10, tau=0.2, h_max=32, doc_capacity=128, nprobe=2,
                    n_buckets=4, d=world.cfg.d)
    standby = WarmStandby(cfg, CheckpointManager(tempfile.mkdtemp()),
                          snapshot_every=10**9, max_lag=10**6)
    backend = ReplicaBackend(
        LocalFlatBackend(corpus, 10, lat, chunk=4), [standby], corpus)
    svc = RetrievalService(world, lat, k=10, chunk=4, backend=backend)
    qs = world.sample_queries(40, pattern="scattered", p_uncovered=0.9,
                              seed=3)
    sch = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        max_spec_batch=8, full_batch=4, full_max_wait_s=0.1))
    r = sch.serve(qs, None, seed=0)
    full = np.flatnonzero(r.channels == "full")
    assert len(full) and (r.served_ids[full] < 0).any()   # -1s were served
    recovered = standby.failover()
    for a, b in zip(jax.tree.leaves(recovered), jax.tree.leaves(sch.state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_max_inflight_full_deprecation_shim(world_setup):
    """Old configs still load: a non-None max_inflight_full warns and
    overrides the backend-sized worker pool."""
    world, qs, cfg = world_setup
    with pytest.warns(DeprecationWarning):
        sch = _sched(world, cfg,
                     backend=lambda c, lat: ShardedMeshBackend(
                         c, 10, lat, n_shards=4, n_workers=4),
                     max_inflight_full=1)
    assert sch.n_full_workers == 1
    r = sch.serve(qs[:100], None, seed=0)
    assert r.max_inflight_full_batches == 1


def test_service_routes_full_search_through_backend(world_setup):
    world, qs, cfg = world_setup
    lat = LatencyModel()
    svc = RetrievalService(world, lat, k=10, chunk=2048)
    assert isinstance(svc.backend, LocalFlatBackend)
    ids, vecs, t = svc.full_search(qs[0]["emb"])
    assert t == svc.backend.latency(1) == lat.full_scan_time()
    ids_b, t_b = svc.full_search_batch(np.stack([q["emb"] for q in qs[:4]]))
    assert np.array_equal(ids_b[0], ids) and t_b == t
    assert ids.shape == (10,) and vecs.shape == (10, 64)
