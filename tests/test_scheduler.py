"""Continuous-batching scheduler: determinism, DAR parity vs the snapshot
micro-batch engine, FIFO cache-wraparound property, early-return invariant."""
import numpy as np
import pytest

# real hypothesis when installed, skip-stubs otherwise (see conftest.py)
from conftest import given, settings, st

import jax.numpy as jnp

from repro.core.has import HasConfig, cache_update, init_has_state
from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
from repro.serving.batched import BatchedHasEngine
from repro.serving.engine import RetrievalService
from repro.serving.latency import LatencyModel
from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                     SchedulerConfig, poisson_arrivals)


@pytest.fixture(scope="module")
def setup():
    world = SyntheticWorld(WorldConfig(n_entities=600, seed=0))
    svc = RetrievalService(world, LatencyModel(), k=10, chunk=2048)
    ds = DATASETS["granola"]
    qs = world.sample_queries(400, pattern=ds["pattern"],
                              zipf_a=ds["zipf_a"],
                              p_uncovered=ds["p_uncovered"], seed=1)
    cfg = HasConfig(k=10, tau=0.2, h_max=600, nprobe=4, n_buckets=256, d=64)
    sched = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        max_spec_batch=16, full_batch=8, full_max_wait_s=0.1))
    return svc, qs, cfg, sched


@pytest.fixture(scope="module")
def saturated(setup):
    """One fully-saturated run (all requests arrive at t=0), reused."""
    _, qs, _, sched = setup
    return sched.serve(qs, arrivals=None, seed=0)


def test_deterministic_replay(setup):
    """Same seed + arrival trace -> bit-identical metrics."""
    _, qs, _, sched = setup
    arr = poisson_arrivals(len(qs), qps=20.0, seed=7)
    r1 = sched.serve(qs, arr, seed=3)
    r2 = sched.serve(qs, arr, seed=3)
    assert np.array_equal(r1.latencies, r2.latencies)
    assert np.array_equal(r1.accepts, r2.accepts)
    assert np.array_equal(r1.channels, r2.channels)
    assert np.array_equal(r1.t_done, r2.t_done)
    assert r1.full_retrievals == r2.full_retrievals


def test_dar_parity_vs_batched(setup, saturated):
    """Sharing + late re-validation can only add accepts: the scheduler's
    DAR dominates the snapshot micro-batch engine's on the same stream."""
    svc, qs, cfg, _ = setup
    bat = BatchedHasEngine(svc, cfg, batch_size=16).serve(qs).summary()
    s = saturated.summary()
    assert s["dar"] >= bat["dar"]
    # the extra accepts come from the new channels
    assert s["shared_accepts"] + s["reval_accepts"] > 0
    # and accuracy does not collapse: hit rate within a few points
    assert s["doc_hit_rate"] > bat["doc_hit_rate"] - 0.08


def test_early_return_excludes_cloud(setup):
    """Accepted-at-speculation requests never pay any cloud time."""
    _, qs, _, sched = setup
    arr = poisson_arrivals(len(qs), qps=5.0, seed=11)
    r = sched.serve(qs, arr, seed=0)
    draft = r.channels == "draft"
    reval = r.channels == "reval"
    slow = (r.channels == "full") | (r.channels == "shared")
    assert draft.any() and slow.any()
    assert np.all(r.cloud_s[draft | reval] == 0.0)
    assert np.all(r.cloud_s[slow] > 0.0)
    # at uncongested load the fast path also beats the cloud RTT floor
    min_cloud = sched.s.latency.cloud_rtt[0]
    assert np.median(r.latencies[draft]) < min_cloud


def test_sharing_reduces_full_retrievals(setup, saturated):
    """On a homology-heavy (zipf) stream, single-flight sharing measurably
    cuts the number of queries paying for a full retrieval."""
    svc, qs, cfg, _ = setup
    no_share = ContinuousBatchingScheduler(svc, cfg, SchedulerConfig(
        max_spec_batch=16, full_batch=8, full_max_wait_s=0.1, share=False))
    r0 = no_share.serve(qs, arrivals=None, seed=0)
    r1 = saturated
    assert r1.full_retrievals < r0.full_retrievals - 10
    assert r1.summary()["dar"] >= r0.summary()["dar"]


def test_throughput_beats_sequential_service_time(setup, saturated):
    """Saturated makespan is far below the sum of sequential service times
    (overlap + coalescing), i.e. the scheduler actually pipelines."""
    svc, qs, _, _ = setup
    # sequential lower bound: every rejected query pays a serialized full
    # scan; the scheduler coalesces full_batch of them into one scan
    n_full = np.sum((saturated.channels == "full"))
    seq_floor = n_full * svc.latency.full_scan_time()
    assert saturated.summary()["makespan_s"] < seq_floor


# -- hypothesis property: FIFO wraparound of the doc store -----------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 6))
def test_cache_update_wraparound_property(seed, rounds):
    """cache_update never exceeds doc_cap and never duplicates a live doc id,
    across arbitrary insert streams that wrap the FIFO ring."""
    rng = np.random.default_rng(seed)
    cfg = HasConfig(k=4, h_max=3, doc_capacity=8, d=8)
    state = init_has_state(cfg)
    for _ in range(rounds * 3):
        ids = rng.choice(40, size=4, replace=False).astype(np.int32)
        vecs = rng.normal(size=(4, 8)).astype(np.float32)
        state = cache_update(cfg, state, jnp.asarray(vecs[0]),
                             jnp.asarray(ids), jnp.asarray(vecs))
        live = np.asarray(state.doc_ids)
        live = live[live >= 0]
        assert live.size <= cfg.doc_cap
        assert live.size == np.unique(live).size, "duplicate live doc id"
