"""End-to-end fault-tolerance scenario: crash mid-training -> resume ->
elastic downscale plan, plus hypothesis property tests on HaS invariants."""
import numpy as np
import pytest
# real hypothesis when installed, skip-stubs otherwise (see conftest.py)
from conftest import given, settings, st

import jax
import jax.numpy as jnp


def test_crash_resume_identical_state(tmp_path):
    """Training resumed from a checkpoint continues from the same state."""
    from repro.checkpoint import CheckpointManager
    from repro.launch.train import train_lm
    from repro.models.transformer import TransformerConfig
    cfg = TransformerConfig(name="t", n_layers=1, d_model=32, n_heads=2,
                            n_kv_heads=1, d_ff=64, vocab_size=64, d_head=16,
                            remat=False)
    # run 1: 60 steps, checkpoints at 50
    train_lm(cfg, steps=60, batch=2, seq=16, ckpt_dir=str(tmp_path),
             log_every=1000)
    mgr = CheckpointManager(str(tmp_path))
    assert 50 in mgr.all_steps() or 60 in mgr.all_steps()
    # 'crash' and resume: restores from the latest checkpoint without error
    losses = train_lm(cfg, steps=70, batch=2, seq=16,
                      ckpt_dir=str(tmp_path), log_every=1000)
    assert len(losses) <= 20          # resumed, did not restart from 0


def test_elastic_downscale_then_upscale():
    from repro.training.fault import ElasticPlan
    down = ElasticPlan.plan(old_data=16, surviving_hosts=12)
    assert down.new_data == 12 and down.accum_steps * down.new_data >= 16
    up = ElasticPlan.plan(old_data=down.new_data, surviving_hosts=16)
    assert up.new_data == 16 and up.accum_steps == 1


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000))
def test_homology_accept_monotone_in_tau(seed):
    """Property: raising tau can only flip accept -> reject."""
    from repro.core.homology import reidentify
    rng = np.random.default_rng(seed)
    draft = jnp.asarray(rng.integers(0, 30, 6), jnp.int32)
    cache = jnp.asarray(rng.integers(0, 30, (12, 6)), jnp.int32)
    valid = jnp.asarray(rng.random(12) > 0.3)
    acc_lo, s, _ = reidentify(draft, cache, valid, jnp.float32(0.1))
    acc_hi, _, _ = reidentify(draft, cache, valid, jnp.float32(0.5))
    assert bool(acc_lo) or not bool(acc_hi)      # hi accept => lo accept


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 12))
def test_cache_ring_never_exceeds_capacity(seed, n_inserts):
    from repro.core.has import HasConfig, cache_update, init_has_state
    rng = np.random.default_rng(seed)
    cfg = HasConfig(k=3, h_max=4, doc_capacity=16, d=4)
    state = init_has_state(cfg)
    for i in range(n_inserts):
        ids = jnp.asarray(rng.integers(0, 100, 3), jnp.int32)
        state = cache_update(cfg, state, jnp.ones((4,)), ids,
                             jnp.ones((3, 4)))
    assert int(jnp.sum(state.query_valid)) <= cfg.h_max
    assert int(jnp.sum(state.doc_ids >= 0)) <= cfg.doc_cap
    assert int(state.q_ptr) == n_inserts


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_speculate_draft_ids_come_from_channels(seed):
    """Property: every returned draft id is a live cached doc or an IVF-
    indexed corpus id (never fabricated)."""
    from repro.core.has import HasConfig, cache_update, init_has_state, speculate
    from repro.retrieval.ivf import build_ivf
    rng = np.random.default_rng(seed)
    cfg = HasConfig(k=4, tau=0.3, h_max=8, doc_capacity=32, nprobe=2,
                    n_buckets=4, d=8)
    corpus = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    index = build_ivf(corpus, 4, seed=0)
    state = init_has_state(cfg)
    ids0 = jnp.asarray(rng.integers(0, 64, 4), jnp.int32)
    state = cache_update(cfg, state, jnp.ones((8,)), ids0, corpus[ids0])
    q = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    out = speculate(cfg, state, index, q)
    live = set(np.asarray(state.doc_ids)[np.asarray(state.doc_ids) >= 0])
    indexed = set(np.asarray(index.bucket_ids).reshape(-1))
    for d in np.asarray(out["draft_ids"]):
        assert d == -1 or int(d) in (live | indexed)
