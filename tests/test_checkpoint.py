"""Checkpointing: roundtrip, atomicity, corruption tolerance, elastic."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, reshard_tree


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
                       "layers": [jnp.arange(6).reshape(2, 3),
                                  jnp.ones((5,))]},
            "step": jnp.asarray(7)}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(10, t)
    restored = mgr.restore(10, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), blocking=False)
    mgr.wait()
    assert mgr.all_steps() == [1]


def test_keep_n_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [3, 4]


def test_restore_latest_skips_corrupt(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(1, t)
    mgr.save(2, t)
    # corrupt the newest checkpoint (as if killed mid-write)
    path = os.path.join(str(tmp_path), "step_000000000002")
    os.remove(os.path.join(path, "manifest.json"))
    step, restored = mgr.restore_latest(t)
    assert step == 1


def test_restore_latest_none_when_empty(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.restore_latest(_tree()) is None


def test_elastic_reshard_local_mesh(tmp_path):
    """Restore a host tree onto a mesh (1x1 here; same code path at 16x16)."""
    from repro.launch.mesh import make_local_mesh
    from repro.utils import LOCAL_RULES
    mgr = CheckpointManager(str(tmp_path))
    t = {"w": jnp.ones((8, 4))}
    mgr.save(5, t)
    _, restored = mgr.restore_latest(t)
    mesh = make_local_mesh()
    placed = reshard_tree(restored, {"w": ("fsdp", "d_ff")},
                          {"fsdp": "data", "d_ff": "model"}, mesh)
    np.testing.assert_array_equal(np.asarray(placed["w"]), np.asarray(t["w"]))
    assert placed["w"].sharding.mesh.shape == {"data": 1, "model": 1}


def test_train_resume_roundtrip(tmp_path):
    """End-to-end: train, checkpoint, resume produces identical state."""
    from repro.launch.train import make_lm100m, train_lm
    import dataclasses
    from repro.models.transformer import TransformerConfig
    cfg = TransformerConfig(name="t", n_layers=1, d_model=32, n_heads=2,
                            n_kv_heads=1, d_ff=64, vocab_size=128, d_head=16,
                            remat=False)
    losses = train_lm(cfg, steps=3, batch=2, seq=16,
                      ckpt_dir=str(tmp_path), log_every=100)
    assert len(losses) == 3 and all(np.isfinite(losses))
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.all_steps()
