"""DimeNet [arXiv:2003.03123] — assigned GNN architecture x 4 graph regimes.

Triplet tensors are capped per edge (static shapes on power-law graphs):
full_graph_sm cap=8, minibatch_lg/molecule cap=4, ogb_products cap=2 — the
cap is a system knob recorded in DESIGN.md (the dominant roofline term for
GNNs is the triplet bilinear contraction).
"""
from __future__ import annotations

import functools

from repro.configs.base import ArchSpec, ShapeSpec
from repro.configs.families import gnn_bundle
from repro.models.dimenet import DimeNetConfig

_BASE = dict(n_blocks=6, d_hidden=128, n_bilinear=8, n_spherical=7,
             n_radial=6)

# shape -> (dims, per-shape config overrides)
GNN_SHAPES = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm", "train",
        dict(n_nodes=2816, n_edges=10752, n_triplets=86016,
             d_feat=1433, n_classes=7,
             real_nodes=2708, real_edges=10556),
        note="Cora-scale full-batch (padded to 256-divisible shards)"),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg", "train",
        dict(n_nodes=169_984, n_edges=168_960, n_triplets=4 * 168_960,
             d_feat=602, n_classes=41,
             full_nodes=232_965, full_edges=114_615_892,
             batch_nodes=1024, fanout=(15, 10)),
        note="Reddit-scale sampled block: 1024 seeds x fanout 15-10 "
             "(host NeighborSampler feeds fixed-shape blocks)"),
    "ogb_products": ShapeSpec(
        "ogb_products", "train",
        dict(n_nodes=2_449_152, n_edges=61_859_840,
             n_triplets=2 * 61_859_840, d_feat=100, n_classes=47,
             real_nodes=2_449_029, real_edges=61_859_140),
        note="full-batch-large; triplet cap 2/edge; padded to 256-divisible"),
    "molecule": ShapeSpec(
        "molecule", "train",
        dict(n_nodes=30 * 128, n_edges=64 * 128, n_triplets=4 * 64 * 128,
             d_feat=32, n_graphs=128),
        note="batched small graphs, energy regression"),
}


def _cfg_for(shape_name: str) -> DimeNetConfig:
    d = GNN_SHAPES[shape_name].dims
    if shape_name == "molecule":
        return DimeNetConfig(task="regression", n_targets=1,
                             d_feat=d["d_feat"], **_BASE)
    return DimeNetConfig(task="classification", n_targets=d["n_classes"],
                         d_feat=d["d_feat"], **_BASE)


def _bundle(shape_name: str, rules, mesh=None, n_layers: int | None = None,
            unroll: bool = False):
    cfg = _cfg_for(shape_name)
    if n_layers is not None or unroll:
        import dataclasses
        nb = n_layers or cfg.n_blocks
        cfg = dataclasses.replace(cfg, n_blocks=nb,
                                  scan_unroll=nb if unroll else 1)
    return gnn_bundle(cfg, GNN_SHAPES[shape_name], rules, mesh)


def _smoke():
    import jax
    import jax.numpy as jnp
    from repro.data.graph import make_graph_batch
    from repro.models import dimenet as dn
    from repro.training.optimizer import OptConfig, opt_init
    from repro.training.train import make_train_step

    cfg = DimeNetConfig(n_blocks=2, d_hidden=32, n_bilinear=4, n_spherical=4,
                        n_radial=4, d_feat=16, n_targets=5,
                        task="classification")
    batch_np = make_graph_batch(n_nodes=40, n_edges=120, d_feat=16,
                                n_classes=5, cap_per_edge=4, seed=0)
    batch = jax.tree.map(jnp.asarray, batch_np)
    params = dn.init_params(cfg, jax.random.key(0))
    opt_cfg = OptConfig(name="adamw")
    opt_state = opt_init(opt_cfg, params)
    lossf = functools.partial(dn.loss_fn, cfg=cfg, rules=None)
    step = make_train_step(lossf, opt_cfg, compute_dtype=jnp.float32)
    return cfg, params, opt_state, step, batch


ArchSpec(
    name="dimenet", family="gnn", source="arXiv:2003.03123",
    shapes=GNN_SHAPES,
    make_bundle=_bundle,
    make_smoke=_smoke,
    config=DimeNetConfig(**_BASE),
).register()
