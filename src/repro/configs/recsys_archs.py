"""The four assigned RecSys architectures (exact public configs).

  dlrm-rm2  [arXiv:1906.00091]  Criteo embedding tables, dot interaction
  bert4rec  [arXiv:1904.06690]  bidirectional sequential recommender
  autoint   [arXiv:1810.11921]  field self-attention interaction
  deepfm    [arXiv:1703.04247]  FM + deep branch

Embedding tables are one concatenated [sum(vocab), dim] matrix, row-sharded
over the ``model`` mesh axis (classic DLRM model parallelism).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.configs.families import recsys_bundle, recsys_shapes
from repro.models.recsys import CRITEO_VOCABS, RecsysConfig

# 39-field vocabularies for autoint/deepfm: Criteo's 26 + 13 Avazu-scale
_VOCABS_39 = CRITEO_VOCABS + (100_000,) * 13

RECSYS_CONFIGS = {
    "dlrm-rm2": RecsysConfig(
        name="dlrm-rm2", kind="dlrm", vocab_sizes=CRITEO_VOCABS,
        embed_dim=64, n_dense=13, bot_mlp=(512, 256, 64),
        top_mlp=(512, 512, 256, 1)),
    "bert4rec": RecsysConfig(
        name="bert4rec", kind="bert4rec", vocab_sizes=(26744,),
        embed_dim=64, n_blocks=2, n_heads=2, seq_len=200),
    "autoint": RecsysConfig(
        name="autoint", kind="autoint", vocab_sizes=_VOCABS_39,
        embed_dim=16, n_attn_layers=3, n_heads=2, d_attn=32),
    "deepfm": RecsysConfig(
        name="deepfm", kind="deepfm", vocab_sizes=_VOCABS_39,
        embed_dim=10, mlp=(400, 400, 400)),
}


def _smoke_factory(full_cfg: RecsysConfig):
    def _smoke():
        from repro.data.recsys import ClickLog, SessionLog
        from repro.models import recsys as rs
        from repro.training.optimizer import OptConfig, opt_init
        from repro.training.train import make_train_step

        kw = dict(vocab_sizes=(64,) * min(len(full_cfg.vocab_sizes), 6),
                  embed_dim=8)
        if full_cfg.kind == "dlrm":
            cfg = RecsysConfig(name="smoke", kind="dlrm", n_dense=13,
                               bot_mlp=(32, 16, 8), top_mlp=(32, 1), **kw)
        elif full_cfg.kind == "deepfm":
            cfg = RecsysConfig(name="smoke", kind="deepfm", mlp=(32, 32), **kw)
        elif full_cfg.kind == "autoint":
            cfg = RecsysConfig(name="smoke", kind="autoint", n_attn_layers=2,
                               n_heads=2, d_attn=8, **kw)
        else:
            cfg = RecsysConfig(name="smoke", kind="bert4rec",
                               vocab_sizes=(256,), embed_dim=16, n_blocks=2,
                               n_heads=2, seq_len=16)
        params = rs.init_params(cfg, jax.random.key(0))
        opt_cfg = OptConfig(name="adamw")
        opt_state = opt_init(opt_cfg, params)
        lossf = functools.partial(rs.loss_fn, cfg=cfg, rules=None)
        step = make_train_step(lossf, opt_cfg, compute_dtype=jnp.float32)
        if cfg.kind == "bert4rec":
            batch_np = SessionLog(256, seed=0).sample(4, 16)
        else:
            batch_np = ClickLog(cfg.vocab_sizes,
                                n_dense=cfg.n_dense, seed=0).sample(8)
        batch = jax.tree.map(jnp.asarray, batch_np)
        return cfg, params, opt_state, step, batch
    return _smoke


for _name, _cfg in RECSYS_CONFIGS.items():
    ArchSpec(
        name=_name, family="recsys", source="assigned recsys pool",
        shapes=recsys_shapes(),
        make_bundle=functools.partial(recsys_bundle, _cfg),
        make_smoke=_smoke_factory(_cfg),
        config=_cfg,
    ).register()
