"""The five assigned LM transformer architectures (exact public configs).

  arctic-480b    [hf:Snowflake/snowflake-arctic-base]   MoE 128e top-2 +
                 dense residual (Arctic's dense-MoE hybrid)
  dbrx-132b      [hf:databricks/dbrx-base]              MoE 16e top-4
  starcoder2-7b  [arXiv:2402.19173]                     dense GQA kv=4, GELU
  phi3-medium-14b[arXiv:2404.14219]                     dense GQA kv=10 SwiGLU
  chatglm3-6b    [arXiv:2406.12793]                     dense GQA kv=2,
                 2D-RoPE (rotary on half the head dims)
"""
from __future__ import annotations

import functools

import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.configs.families import lm_bundle, lm_shapes, lm_smoke
from repro.models.transformer import TransformerConfig

# q-block scan bounds the attention score transient for 32k prefill
_BLOCK_Q = 512

LM_CONFIGS = {
    "arctic-480b": TransformerConfig(
        name="arctic-480b", n_layers=35, d_model=7168, n_heads=56,
        n_kv_heads=8, d_ff=4864, vocab_size=32000, d_head=128,
        moe_experts=128, moe_top_k=2, moe_dense_residual=True,
        param_dtype=jnp.bfloat16, attn_block_q=_BLOCK_Q,
        head_tp=False, head_pad_to=64),   # 56 heads: activation-pad to 64
    "dbrx-132b": TransformerConfig(
        name="dbrx-132b", n_layers=40, d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=10752, vocab_size=100352, d_head=128,
        moe_experts=16, moe_top_k=4,
        param_dtype=jnp.bfloat16, attn_block_q=_BLOCK_Q),
    "starcoder2-7b": TransformerConfig(
        name="starcoder2-7b", n_layers=32, d_model=4608, n_heads=36,
        n_kv_heads=4, d_ff=18432, vocab_size=49152, d_head=128,
        gated_mlp=False, attn_block_q=_BLOCK_Q,
        head_tp=False, head_pad_to=48),   # 36 heads: activation-pad to 48
    "phi3-medium-14b": TransformerConfig(
        name="phi3-medium-14b", n_layers=40, d_model=5120, n_heads=40,
        n_kv_heads=10, d_ff=17920, vocab_size=100352, d_head=128,
        attn_block_q=_BLOCK_Q,
        head_tp=False, head_pad_to=48),   # 40 heads: activation-pad to 48
    "chatglm3-6b": TransformerConfig(
        name="chatglm3-6b", n_layers=28, d_model=4096, n_heads=32,
        n_kv_heads=2, d_ff=13696, vocab_size=65024, d_head=128,
        rope_fraction=0.5, attn_block_q=_BLOCK_Q),
}

for _name, _cfg in LM_CONFIGS.items():
    ArchSpec(
        name=_name, family="lm", source="assigned LM pool",
        shapes=lm_shapes(),
        make_bundle=functools.partial(lm_bundle, _cfg),
        make_smoke=functools.partial(lm_smoke, _cfg),
        config=_cfg,
    ).register()
