"""Architecture registry: importing this package registers all configs.

Assigned pool (10 archs x their shape sets = 40 dry-run cells) plus the
paper's own ``has-rag`` pod-scale retrieval step.
"""
from repro.configs.base import (REGISTRY, ArchSpec, LoweringBundle,
                                ShapeSpec, all_archs, get_arch)

# registration side effects
import repro.configs.lm_archs       # noqa: F401,E402
import repro.configs.dimenet        # noqa: F401,E402
import repro.configs.recsys_archs   # noqa: F401,E402
import repro.configs.has_rag        # noqa: F401,E402
