"""Family glue: build LoweringBundles for LM / GNN / RecSys architectures."""
from __future__ import annotations

import functools
from typing import Any, Mapping

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct as SDS

from repro.configs.base import LoweringBundle, ShapeSpec
from repro.models import dimenet as dn
from repro.models import recsys as rs
from repro.models import transformer as tf
from repro.training.optimizer import OptConfig, opt_init, opt_state_logical
from repro.training.train import make_train_step

I32, F32, BF16, BOOL = jnp.int32, jnp.float32, jnp.bfloat16, jnp.bool_


def _batch_ax(b: int, mesh) -> str | None:
    """Shard the batch dim only when it divides the DP shard count."""
    if mesh is None:
        return "batch"
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp *= mesh.shape[a]
    return "batch" if b % dp == 0 and b >= dp else None


# ---------------------------------------------------------------------------
# LM transformers
# ---------------------------------------------------------------------------

def lm_opt_config(cfg: tf.TransformerConfig) -> OptConfig:
    # giant MoE: factored states (AdamW's 8 B/param would exceed pod HBM)
    return OptConfig(name="adafactor" if cfg.is_moe else "adamw")


def lm_bundle(cfg: tf.TransformerConfig, shape: ShapeSpec | str, rules,
              mesh=None, n_layers: int | None = None,
              unroll: bool = False, moe_dp_groups: int | None = None,
              remat_policy: str | None = None) -> LoweringBundle:
    if isinstance(shape, str):
        shape = lm_shapes()[shape]
    import dataclasses
    if n_layers is not None or unroll:
        nl = n_layers or cfg.n_layers
        cfg = dataclasses.replace(cfg, n_layers=nl,
                                  scan_unroll=nl if unroll else 1)
    if moe_dp_groups is None and cfg.is_moe and mesh is not None:
        # production default (§Perf): hierarchical dispatch over the DP axes
        dp = 1
        for a in ("pod", "data"):
            dp *= mesh.shape.get(a, 1)
        moe_dp_groups = dp
    if moe_dp_groups is not None:
        cfg = dataclasses.replace(cfg, moe_dp_groups=moe_dp_groups)
    if remat_policy is not None:
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)
    key = jax.random.key(0)
    aparams = jax.eval_shape(functools.partial(tf.init_params, cfg), key)
    plog = tf.params_logical(cfg)
    d = shape.dims

    if shape.kind == "train":
        b, s = d["global_batch"], d["seq_len"]
        bax = _batch_ax(b, mesh)
        batch_abs = {"tokens": SDS((b, s), I32), "labels": SDS((b, s), I32)}
        batch_log = {"tokens": (bax, None), "labels": (bax, None)}
        opt_cfg = lm_opt_config(cfg)
        aopt = jax.eval_shape(functools.partial(opt_init, opt_cfg), aparams)
        olog = opt_state_logical(opt_cfg, plog)
        lossf = functools.partial(tf.loss_fn, cfg=cfg, rules=rules)
        step = make_train_step(lossf, opt_cfg)
        return LoweringBundle(step, (aparams, aopt, batch_abs),
                              (plog, olog, batch_log), donate_argnums=(0, 1))

    if shape.kind == "prefill":
        b, s = d["global_batch"], d["seq_len"]
        bax = _batch_ax(b, mesh)
        fn = functools.partial(tf.prefill, cfg=cfg, rules=rules)
        return LoweringBundle(fn, (aparams, SDS((b, s), I32)),
                              (plog, (bax, None)))

    if shape.kind == "decode":
        b, s = d["global_batch"], d["seq_len"]
        bax = _batch_ax(b, mesh)
        if bax is None and rules is not None:
            # tiny-batch decode (long_500k B=1): free the DP axes so the
            # 500k KV-seq dim can take (data x model) without double-mapping
            rules = {**rules, "batch": None}
        acache = jax.eval_shape(
            functools.partial(tf.init_kv_cache, cfg, b, s), )
        clog = tf.kv_cache_logical(s)
        if bax is None:
            clog = jax.tree.map(
                lambda lg: (lg[0], None) + lg[2:], clog,
                is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x, dict))
        fn = functools.partial(tf.decode_step, cfg=cfg, rules=rules)
        return LoweringBundle(
            fn, (aparams, acache, SDS((b,), I32), SDS((), I32)),
            (plog, clog, (bax,), ()), donate_argnums=(1,))

    raise ValueError(shape.kind)


def lm_shapes(skip_decode: bool = False) -> dict[str, ShapeSpec]:
    """The assigned LM shape set (same for all five LM archs)."""
    shapes = {
        "train_4k": ShapeSpec("train_4k", "train",
                              dict(seq_len=4096, global_batch=256)),
        "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                                 dict(seq_len=32768, global_batch=32)),
        "decode_32k": ShapeSpec("decode_32k", "decode",
                                dict(seq_len=32768, global_batch=128)),
        "long_500k": ShapeSpec(
            "long_500k", "decode", dict(seq_len=524288, global_batch=1),
            note="decode against a 512k KV cache is O(L)/step; runs for all "
                 "five full-attention archs (see DESIGN.md §5)"),
    }
    if skip_decode:
        shapes.pop("decode_32k")
        shapes.pop("long_500k")
    return shapes


def lm_smoke(cfg_full: tf.TransformerConfig):
    """Reduced same-family config + one CPU train step."""
    cfg = tf.TransformerConfig(
        name=cfg_full.name + "-smoke", n_layers=2,
        d_model=64, n_heads=4,
        n_kv_heads=max(1, 4 * cfg_full.n_kv_heads // cfg_full.n_heads),
        d_ff=128, vocab_size=512, d_head=16,
        rope_fraction=cfg_full.rope_fraction,
        gated_mlp=cfg_full.gated_mlp,
        moe_experts=min(cfg_full.moe_experts, 4),
        moe_top_k=min(cfg_full.moe_top_k, 2),
        moe_dense_residual=cfg_full.moe_dense_residual,
        remat=False)
    params = tf.init_params(cfg, jax.random.key(0))
    opt_cfg = lm_opt_config(cfg)
    opt_state = opt_init(opt_cfg, params)
    lossf = functools.partial(tf.loss_fn, cfg=cfg, rules=None,
                              compute_dtype=jnp.float32)
    step = make_train_step(lossf, opt_cfg)
    import numpy as np
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 512, (2, 16)), I32),
             "labels": jnp.asarray(rng.integers(0, 512, (2, 16)), I32)}
    return cfg, params, opt_state, step, batch


# ---------------------------------------------------------------------------
# GNN (DimeNet)
# ---------------------------------------------------------------------------

def gnn_abstract_batch(n: int, e: int, t: int, d_feat: int,
                       task: str, n_graphs: int = 1):
    batch = {"x": SDS((n, d_feat), F32), "pos": SDS((n, 3), F32),
             "edge_src": SDS((e,), I32), "edge_dst": SDS((e,), I32),
             "edge_mask": SDS((e,), BOOL),
             "tri_edge_in": SDS((t,), I32), "tri_edge_out": SDS((t,), I32),
             "tri_mask": SDS((t,), BOOL), "node_mask": SDS((n,), BOOL)}
    log = {"x": ("nodes", None), "pos": ("nodes", None),
           "edge_src": ("edges",), "edge_dst": ("edges",),
           "edge_mask": ("edges",),
           "tri_edge_in": ("edges",), "tri_edge_out": ("edges",),
           "tri_mask": ("edges",), "node_mask": ("nodes",)}
    if task == "classification":
        batch["labels"] = SDS((n,), I32)
        log["labels"] = ("nodes",)
    else:
        batch["graph_ids"] = SDS((n,), I32)
        batch["targets"] = SDS((n_graphs,), F32)
        log["graph_ids"] = ("nodes",)
        log["targets"] = (None,)
    return batch, log


def gnn_bundle(cfg: dn.DimeNetConfig, shape: ShapeSpec, rules,
               mesh=None) -> LoweringBundle:
    d = shape.dims
    aparams = jax.eval_shape(
        functools.partial(dn.init_params, cfg), jax.random.key(0))
    plog = dn.params_logical(cfg)
    batch_abs, batch_log = gnn_abstract_batch(
        d["n_nodes"], d["n_edges"], d["n_triplets"], d["d_feat"],
        cfg.task, d.get("n_graphs", 1))
    opt_cfg = OptConfig(name="adamw")
    aopt = jax.eval_shape(functools.partial(opt_init, opt_cfg), aparams)
    olog = opt_state_logical(opt_cfg, plog)
    lossf = functools.partial(dn.loss_fn, cfg=cfg, rules=rules)
    step = make_train_step(lossf, opt_cfg, compute_dtype=F32)
    return LoweringBundle(step, (aparams, aopt, batch_abs),
                          (plog, olog, batch_log), donate_argnums=(0, 1))


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------

def recsys_abstract_batch(cfg: rs.RecsysConfig, b: int, mesh=None):
    bax = _batch_ax(b, mesh)
    if cfg.kind == "bert4rec":
        s = cfg.seq_len
        return ({"items": SDS((b, s), I32), "labels": SDS((b, s), I32),
                 "label_mask": SDS((b, s), BOOL), "mask": SDS((b, s), BOOL)},
                {"items": (bax, None), "labels": (bax, None),
                 "label_mask": (bax, None), "mask": (bax, None)})
    batch = {"sparse_ids": SDS((b, cfg.n_sparse), I32),
             "labels": SDS((b,), I32)}
    log = {"sparse_ids": (bax, None), "labels": (bax,)}
    if cfg.n_dense:
        batch["dense"] = SDS((b, cfg.n_dense), F32)
        log["dense"] = (bax, None)
    return batch, log


def recsys_bundle(cfg: rs.RecsysConfig, shape: ShapeSpec | str, rules,
                  mesh=None, **_variant) -> LoweringBundle:
    if isinstance(shape, str):
        shape = recsys_shapes()[shape]
    d = shape.dims
    aparams = jax.eval_shape(
        functools.partial(rs.init_params, cfg), jax.random.key(0))
    plog = rs.params_logical(cfg)

    if shape.kind == "retrieval":
        b, c = d["batch"], d["n_candidates"]
        dim = cfg.embed_dim
        fn = functools.partial(rs.retrieval_score, cfg=cfg, rules=rules)
        return LoweringBundle(
            fn, (aparams, {"query": SDS((b, dim), F32),
                           "candidates": SDS((c, dim), F32)}),
            (plog, {"query": (None, None), "candidates": ("corpus", None)}))

    batch_abs, batch_log = recsys_abstract_batch(cfg, d["batch"], mesh)
    if shape.kind == "train":
        opt_cfg = OptConfig(name="adamw")
        aopt = jax.eval_shape(functools.partial(opt_init, opt_cfg), aparams)
        olog = opt_state_logical(opt_cfg, plog)
        lossf = functools.partial(rs.loss_fn, cfg=cfg, rules=rules)
        step = make_train_step(lossf, opt_cfg, compute_dtype=F32)
        return LoweringBundle(step, (aparams, aopt, batch_abs),
                              (plog, olog, batch_log), donate_argnums=(0, 1))
    # serve: forward scoring
    fn = functools.partial(rs.forward, cfg=cfg, rules=rules)
    return LoweringBundle(fn, (aparams, batch_abs), (plog, batch_log))


def recsys_shapes() -> dict[str, ShapeSpec]:
    return {
        "train_batch": ShapeSpec("train_batch", "train", dict(batch=65536)),
        "serve_p99": ShapeSpec("serve_p99", "serve", dict(batch=512)),
        "serve_bulk": ShapeSpec("serve_bulk", "serve", dict(batch=262144)),
        "retrieval_cand": ShapeSpec(
            "retrieval_cand", "retrieval",
            dict(batch=1, n_candidates=1_000_448, real_candidates=1_000_000),
            note="1M candidates padded to 256-divisible shards"),
    }
