"""The paper's own system as a pod-scale lowering: HaS speculative retrieval.

Dry-run step = batched two-channel speculation + homology validation + the
full-database sharded ENNS fallback, over the paper's 49.2M-passage corpus
at contriever dim 768.  On the production mesh the corpus (fp32) and its
int8 'fuzzy' replica shard over (data x model); the cache channel, query
cache and inverted-index tables are replicated (they are the MB-scale edge
component).  This is the (e) deliverable for the paper's primary technique
itself, alongside the 10 assigned architectures.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct as SDS

from repro.configs.base import ArchSpec, LoweringBundle, ShapeSpec
from repro.core.homology import homology_scores_batched
from repro.utils import constrain

I32, F32, I8, BOOL = jnp.int32, jnp.float32, jnp.int8, jnp.bool_


def _iterative_topk(sc, k):
    """k rounds of (max, argmax, mask) over the LAST dim — reductions only,
    so GSPMD keeps them shard-local (lax.top_k lowers to sort, which XLA
    replicates when any dim is sharded: the §Perf iteration-2 finding)."""
    def body(carry, _):
        sc = carry
        cur = jnp.max(sc, axis=-1)
        arg = jnp.argmax(sc, axis=-1).astype(jnp.int32)
        col = jax.lax.broadcasted_iota(jnp.int32, sc.shape, sc.ndim - 1)
        sc = jnp.where(col == arg[..., None], -jnp.inf, sc)  # mask winner
        return sc, (cur, arg)
    _, (vals, idx) = jax.lax.scan(body, sc, None, length=k)
    # [k, B, C] -> [B, C, k]
    return jnp.moveaxis(vals, 0, -1), jnp.moveaxis(idx, 0, -1)


def _sharded_topk(scores, k, merge_chunks, rules):
    """Chunk-local top-k + tiny merge (§Perf: avoids all-gathering scores)."""
    b, n = scores.shape
    if not merge_chunks or n % merge_chunks:
        return jax.lax.top_k(scores, k)
    loc = n // merge_chunks
    sc = scores.reshape(b, merge_chunks, loc)
    sc = constrain(sc, (None, "corpus", None), rules)
    lv, li = _iterative_topk(sc, min(k, loc))            # [B, C, k] local
    lv = constrain(lv, (None, "corpus", None), rules)
    li = li + (jnp.arange(merge_chunks) * loc)[None, :, None]
    v, pos = jax.lax.top_k(lv.reshape(b, -1), k)         # tiny merge
    return v, jnp.take_along_axis(li.reshape(b, -1), pos, axis=1)


@dataclasses.dataclass(frozen=True)
class HasRagConfig:
    name: str = "has-rag"
    # 49.2M passages padded up to a 256-shard-divisible row count (pjit
    # input shardings require exact divisibility; pad rows are masked)
    corpus_size: int = 49_201_152
    d: int = 768                 # contriever embedding dim
    k: int = 10
    tau: float = 0.2
    h_max: int = 5000
    doc_cap: int = 50_000
    query_batch: int = 64


def has_retrieval_step(corpus, fuzzy_q, fuzzy_scale, cache_doc_emb,
                       cache_doc_ids, query_doc_ids, query_valid, queries,
                       *, k: int, tau: float, rules=None,
                       merge_chunks: int = 0, score_dtype=F32):
    """Batched HaS step (Algorithm 1 over a query micro-batch).

    corpus [N,d] f32 sharded('corpus'); fuzzy_q [N,d] int8 sharded (the
    compressed fuzzy channel); cache_* replicated; queries [B,d].
    Returns (ids [B,k], accept [B], homology [B]).
    """
    b = queries.shape[0]
    # cache channel: exact top-k over the replicated doc store
    sc = queries @ cache_doc_emb.T                          # [B, Dc]
    sc = jnp.where(cache_doc_ids[None, :] >= 0, sc, -jnp.inf)
    s_c, slots = jax.lax.top_k(sc, k)
    i_c = jnp.where(jnp.isfinite(s_c), cache_doc_ids[slots], -1)

    # fuzzy channel: int8 compressed scan of the sharded corpus replica
    fuzzy_q = constrain(fuzzy_q, ("corpus", None), rules)
    s_f = (queries @ fuzzy_q.T.astype(queries.dtype)) * fuzzy_scale[None, :]
    s_f = constrain(s_f, (None, "corpus"), rules)
    s_f, i_f = _sharded_topk(s_f, k, merge_chunks, rules)

    # merge/rerank -> draft
    dup = jnp.any(i_f[:, :, None] == i_c[:, None, :], axis=2)
    s_f = jnp.where(dup, -jnp.inf, s_f)
    s_all = jnp.concatenate([s_c, s_f], axis=1)
    i_all = jnp.concatenate([i_c, i_f], axis=1)
    ts, ti = jax.lax.top_k(s_all, k)
    draft = jnp.take_along_axis(i_all, ti, axis=1)          # [B, k]

    # homology validation against the replicated query cache
    scores = homology_scores_batched(draft, query_doc_ids, query_valid)
    best = jnp.max(scores, axis=1)
    accept = best > tau

    # fallback: full-database sharded ENNS (computed for the batch; the
    # serving engine only routes rejected queries here — under jit we select)
    # score_dtype=bf16 (§Perf iter 3) halves scan + score-pass bytes; exact
    # ranking is restored by fp32 re-scoring of the k winners if needed.
    corpus = constrain(corpus, ("corpus", None), rules)
    s_full = (queries.astype(score_dtype)
              @ corpus.T.astype(score_dtype)).astype(jnp.float32)
    s_full = constrain(s_full, (None, "corpus"), rules)
    _, i_full = _sharded_topk(s_full, k, merge_chunks, rules)

    ids = jnp.where(accept[:, None], draft, i_full)
    return ids, accept, best


def _bundle(shape_name: str, rules, mesh=None, merge_chunks: int | None = None,
            **_variant) -> LoweringBundle:
    cfg = HasRagConfig()
    if merge_chunks is None and mesh is not None:
        # production default (§Perf): chunk-local top-k over corpus shards
        import numpy as _np
        merge_chunks = int(_np.prod(list(mesh.shape.values())))
    merge_chunks = merge_chunks or 0
    n, d, k = cfg.corpus_size, cfg.d, cfg.k
    b = cfg.query_batch
    store_dtype = _variant.get("store_dtype", F32)
    fn = functools.partial(has_retrieval_step, k=k, tau=cfg.tau, rules=rules,
                           merge_chunks=merge_chunks,
                           score_dtype=_variant.get("score_dtype", F32))
    args = (SDS((n, d), store_dtype), SDS((n, d), I8), SDS((n,), F32),
            SDS((cfg.doc_cap, d), F32), SDS((cfg.doc_cap,), I32),
            SDS((cfg.h_max, k), I32), SDS((cfg.h_max,), BOOL),
            SDS((b, d), F32))
    logical = (("corpus", None), ("corpus", None), ("corpus",),
               (None, None), (None,), (None, None), (None,), (None, None))
    return LoweringBundle(fn, args, logical)


def _smoke():
    import numpy as np
    rng = np.random.default_rng(0)
    n, d, k, b, h, dc = 512, 16, 4, 3, 32, 64
    corpus = jnp.asarray(rng.normal(size=(n, d)), F32)
    scale = jnp.max(jnp.abs(corpus), axis=1) / 127.0
    fq = jnp.clip(jnp.round(corpus / scale[:, None]), -127, 127).astype(I8)
    args = (corpus, fq, scale,
            jnp.asarray(rng.normal(size=(dc, d)), F32),
            jnp.asarray(rng.integers(0, n, dc), I32),
            jnp.asarray(rng.integers(0, n, (h, k)), I32),
            jnp.ones((h,), BOOL),
            jnp.asarray(rng.normal(size=(b, d)), F32))
    fn = functools.partial(has_retrieval_step, k=k, tau=0.2, rules=None)
    return HasRagConfig(corpus_size=n, d=d, k=k), fn, args


ArchSpec(
    name="has-rag", family="rag", source="the paper (HaS)",
    shapes={"retrieve_batch": ShapeSpec(
        "retrieve_batch", "retrieval",
        dict(corpus=49_200_000, d=768, query_batch=64, k=10))},
    make_bundle=_bundle,
    make_smoke=_smoke,
    config=HasRagConfig(),
).register()
