"""Config registry: ArchSpec + shape specs + lowering bundles.

Every assigned architecture registers an :class:`ArchSpec` mapping each of
its input shapes to a :class:`LoweringBundle` — the (fn, abstract args,
logical shardings) triple that launch/dryrun.py jits on the production mesh
and tests smoke-run (reduced) on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax

REGISTRY: dict[str, "ArchSpec"] = {}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str               # train | prefill | decode | serve | retrieval
    dims: Mapping[str, int]
    note: str = ""


@dataclasses.dataclass
class LoweringBundle:
    """Everything dryrun needs: jit(fn, in_shardings=resolve(arg_logical))
    .lower(*abstract_args)."""
    fn: Callable
    abstract_args: tuple
    arg_logical: tuple
    donate_argnums: tuple = ()
    static_argnums: tuple = ()


@dataclasses.dataclass
class ArchSpec:
    name: str
    family: str             # lm | gnn | recsys
    source: str             # citation tag from the assignment
    shapes: dict[str, ShapeSpec]
    # full-scale lowering bundle (abstract, no allocation)
    make_bundle: Callable[[str, Any], LoweringBundle]   # (shape_name, rules)
    # reduced config smoke helpers: () -> (cfg, fn(batch)->outputs, batch)
    make_smoke: Callable[[], tuple]
    config: Any = None

    def register(self):
        REGISTRY[self.name] = self
        return self


def get_arch(name: str) -> ArchSpec:
    if name not in REGISTRY:
        # import side-effect registration
        import repro.configs  # noqa
    return REGISTRY[name]


def all_archs() -> list[str]:
    import repro.configs  # noqa
    return sorted(REGISTRY)


def abstract_init(fn, *args):
    """eval_shape wrapper: parameters as ShapeDtypeStructs, no allocation."""
    return jax.eval_shape(fn, *args)
