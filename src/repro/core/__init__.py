"""HaS core: homology-aware speculative retrieval (the paper's contribution).

Layout:
  homology.py   homology score + threshold re-identification (§III-C)
  has.py        HasState (FIFO cache, doc store), two-channel speculation (§II-B)
  baselines.py  Proximity / SafeRadius / MinCache / CRAG-evaluator / ScaNN-sub
"""
from repro.core.homology import (homology_scores, homology_scores_batched,
                                 reidentify, pairwise_homology)
