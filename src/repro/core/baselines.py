"""Baseline retrieval-acceleration methods the paper compares against (§IV-A).

Reuse-based:
  Proximity  [Bergman+ '25]  — reuse the cached result whose query embedding
      has cosine similarity > theta with the incoming query.
  SafeRadius [Frieder+ '24]  — reuse iff the incoming query lies inside the
      cached query's 'safe' hyperball; we instantiate the criterion on the
      unit sphere: reuse iff  ||q - q_h|| < alpha * margin(q_h)  where
      margin(q_h) = s_1(q_h) - s_k(q_h), the cached query's top-1/top-k score
      gap (the radius within which its top-k set provably cannot change by
      more than the margin).
  MinCache   [Haqiq+ '25]    — hierarchical: lexical resemblance via MinHash
      Jaccard over query token sets (threshold t_lex), then embedding cosine
      (threshold t_sem); reuse when either tier matches.

Validation-based:
  CRAGEvaluator [Yan+ '24]   — an LLM judges each draft document's relevance;
      simulated with the oracle golden-document labels + a configurable
      error rate and a per-call latency (0.7 s in the paper's measurement).

ANNS substitutes:
  IVF (retrieval/ivf.py) with scope presets, and a ScaNN-substitute =
  int8-quantized scoring + exact re-rank (retrieval/flat.quantized_search).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Shared reuse-cache state (query embedding -> cached result set)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ReuseState:
    query_emb: jax.Array      # [H, d]
    doc_ids: jax.Array        # [H, k]
    doc_vecs: jax.Array       # [H, k, d]
    margins: jax.Array        # [H] top1-topk score gap (SafeRadius)
    minhash: jax.Array        # [H, n_hash] int32 (MinCache)
    valid: jax.Array          # [H]
    ptr: jax.Array            # scalar

    def tree_flatten(self):
        return ((self.query_emb, self.doc_ids, self.doc_vecs, self.margins,
                 self.minhash, self.valid, self.ptr), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_reuse_state(h_max: int, k: int, d: int, n_hash: int = 64) -> ReuseState:
    return ReuseState(
        query_emb=jnp.zeros((h_max, d), jnp.float32),
        doc_ids=jnp.full((h_max, k), -1, jnp.int32),
        doc_vecs=jnp.zeros((h_max, k, d), jnp.float32),
        margins=jnp.zeros((h_max,), jnp.float32),
        minhash=jnp.full((h_max, n_hash), 2**31 - 1, jnp.int32),
        valid=jnp.zeros((h_max,), bool),
        ptr=jnp.zeros((), jnp.int32),
    )


@functools.partial(jax.jit, donate_argnames=("state",))
def reuse_insert(state: ReuseState, q_emb, doc_ids, doc_vecs, scores,
                 mh) -> ReuseState:
    slot = state.ptr % state.valid.shape[0]
    return ReuseState(
        query_emb=state.query_emb.at[slot].set(q_emb),
        doc_ids=state.doc_ids.at[slot].set(doc_ids),
        doc_vecs=state.doc_vecs.at[slot].set(doc_vecs),
        margins=state.margins.at[slot].set(scores[0] - scores[-1]),
        minhash=state.minhash.at[slot].set(mh),
        valid=state.valid.at[slot].set(True),
        ptr=state.ptr + 1,
    )


# ---------------------------------------------------------------------------
# Matching rules
# ---------------------------------------------------------------------------

@jax.jit
def proximity_match(state: ReuseState, q_emb, theta):
    """Cosine-similarity reuse (embeddings are unit-norm)."""
    sims = state.query_emb @ q_emb
    sims = jnp.where(state.valid, sims, -jnp.inf)
    h = jnp.argmax(sims)
    return sims[h] > theta, h.astype(jnp.int32), sims[h]


@jax.jit
def saferadius_match(state: ReuseState, q_emb, alpha):
    """Safe-hyperball reuse: ||q - q_h|| < alpha * margin(q_h)."""
    dist = jnp.linalg.norm(state.query_emb - q_emb[None, :], axis=-1)
    ok = (dist < alpha * state.margins) & state.valid
    score = jnp.where(ok, -dist, -jnp.inf)
    h = jnp.argmax(score)
    return ok[h], h.astype(jnp.int32), -score[h]


def minhash_signature(tokens: np.ndarray, n_hash: int = 64,
                      seed: int = 0) -> np.ndarray:
    """MinHash over a token-id set (host-side, lexical tier of MinCache)."""
    rng = np.random.default_rng(seed)
    a = rng.integers(1, 2**31 - 1, n_hash, dtype=np.int64)
    b = rng.integers(0, 2**31 - 1, n_hash, dtype=np.int64)
    p = np.int64(2**31 - 1)
    t = tokens.astype(np.int64)[:, None]
    hashes = (a[None, :] * t + b[None, :]) % p                # [T, n_hash]
    return hashes.min(axis=0).astype(np.int32)


@jax.jit
def mincache_match(state: ReuseState, q_emb, mh, t_lex, t_sem):
    """Hierarchical: MinHash-Jaccard tier OR embedding-cosine tier."""
    jac = jnp.mean((state.minhash == mh[None, :]).astype(jnp.float32), axis=1)
    sims = state.query_emb @ q_emb
    lex_ok = (jac > t_lex) & state.valid
    sem_ok = (sims > t_sem) & state.valid
    ok = lex_ok | sem_ok
    score = jnp.where(ok, jnp.maximum(jac, sims), -jnp.inf)
    h = jnp.argmax(score)
    return ok[h], h.astype(jnp.int32), score[h]


# ---------------------------------------------------------------------------
# CRAG-style LLM evaluator (simulated)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CRAGEvaluator:
    """LLM relevance judge for draft documents.

    The judgement is simulated per document from the synthetic world's
    oracle with asymmetric error rates — LLM judges are conservative
    (high false-negative on relevant docs, near-zero false-positive), and
    markedly weaker on out-of-distribution data (the paper's PopQA
    observation).  The cost model charges the paper's measured ~0.7 s
    inference latency per query.
    """
    fn_rate: float = 0.5           # misses a truly relevant doc
    fp_rate: float = 0.01          # accepts an irrelevant doc
    ood_fn_rate: float = 0.8       # weaker confidence on OOD data (PopQA)
    latency_s: float = 0.7

    def evaluate(self, rng: np.random.Generator, golden_mask: np.ndarray,
                 ood: bool = False) -> bool:
        """Accept the draft iff >=1 doc is judged relevant."""
        fn = self.ood_fn_rate if ood else self.fn_rate
        u = rng.random(golden_mask.shape)
        judged = np.where(golden_mask, u > fn, u < self.fp_rate)
        return bool(judged.any())
