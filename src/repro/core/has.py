"""HaS pipeline state + two-channel speculation (paper §II-B, Algorithm 1).

All state lives in fixed-shape JAX arrays so every step jits:
  * query cache P = (query_emb [H,d], query_doc_ids [H,k], valid [H]) — a FIFO
    ring (the paper's FIFO replacement policy) with pointer ``q_ptr``.
  * cache channel C_c = FIFO ring of *deduplicated* documents previously
    retrieved from the full database (doc_emb [Dc,d], doc_ids [Dc]).
  * fuzzy channel C_f = an aggressively configured IVFIndex (see
    retrieval/ivf.py), optionally subset-compressed (Table VII).

``speculate`` performs: two-channel top-k -> rerank/merge -> draft ->
homology validation (reidentify).  ``cache_update`` inserts the fallback
full-retrieval result.  The host-side serving loop (serving/engine.py)
sequences these per query exactly as Algorithm 1; the batched variant
processes micro-batches against a cache snapshot.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.homology import (homology_scores, homology_scores_batched,
                                 reidentify)
from repro.retrieval.ivf import IVFIndex, ivf_search


@dataclasses.dataclass(frozen=True)
class HasConfig:
    k: int = 10                    # documents per retrieval (draft size)
    tau: float = 0.2               # homology threshold
    h_max: int = 5000              # query-cache capacity (paper default)
    doc_capacity: int = 0          # doc-store slots; 0 -> h_max * k
    nprobe: int = 64               # fuzzy channel buckets probed
    n_buckets: int = 8192          # fuzzy channel total buckets
    use_fuzzy_validation: bool = True    # Table VI 'V'
    use_fuzzy_enhancement: bool = True   # Table VI 'E'
    d: int = 64                    # embedding dim

    @property
    def doc_cap(self) -> int:
        return self.doc_capacity or self.h_max * self.k


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class HasState:
    query_emb: jax.Array      # [H, d]
    query_doc_ids: jax.Array  # [H, k] int32
    query_valid: jax.Array    # [H] bool
    q_ptr: jax.Array          # scalar int32
    doc_emb: jax.Array        # [Dc, d]
    doc_ids: jax.Array        # [Dc] int32 (-1 = empty)
    d_ptr: jax.Array          # scalar int32

    def tree_flatten(self):
        return ((self.query_emb, self.query_doc_ids, self.query_valid,
                 self.q_ptr, self.doc_emb, self.doc_ids, self.d_ptr), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_has_state(cfg: HasConfig, dtype=jnp.float32) -> HasState:
    return HasState(
        query_emb=jnp.zeros((cfg.h_max, cfg.d), dtype),
        query_doc_ids=jnp.full((cfg.h_max, cfg.k), -1, jnp.int32),
        query_valid=jnp.zeros((cfg.h_max,), bool),
        q_ptr=jnp.zeros((), jnp.int32),
        doc_emb=jnp.zeros((cfg.doc_cap, cfg.d), dtype),
        doc_ids=jnp.full((cfg.doc_cap,), -1, jnp.int32),
        d_ptr=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Two-channel fast retrieval + homology validation
# ---------------------------------------------------------------------------

def _dedup_merge(s_a, i_a, s_b, i_b, k):
    """Merge two candidate lists, dropping b-entries duplicated in a."""
    dup = jnp.any(i_b[:, None] == i_a[None, :], axis=1) & (i_b >= 0)
    s_b = jnp.where(dup, -jnp.inf, s_b)
    s = jnp.concatenate([s_a, s_b])
    i = jnp.concatenate([i_a, i_b])
    ts, t = jax.lax.top_k(s, k)
    return ts, i[t]


@functools.partial(jax.jit, static_argnames=("cfg",))
def speculate(cfg: HasConfig, state: HasState, index: IVFIndex,
              q_emb: jax.Array):
    """One speculative retrieval (Algorithm 1 lines 1–14) for query q [d].

    Returns dict with draft ids/scores, accept flag, best homology score and
    matched cache slot.
    """
    q = q_emb[None, :]                                       # [1, d]

    # cache channel: flat exact top-k over the doc store
    sc = (q @ state.doc_emb.T)[0]                            # [Dc]
    sc = jnp.where(state.doc_ids >= 0, sc, -jnp.inf)
    s_c, slots = jax.lax.top_k(sc, cfg.k)
    i_c = jnp.where(jnp.isfinite(s_c), state.doc_ids[slots], -1)

    # fuzzy channel: aggressive IVF
    s_f, i_f = ivf_search(index, q, nprobe=cfg.nprobe, k=cfg.k)
    s_f, i_f = s_f[0], i_f[0]

    # draft used for validation (V flag) and for output (E flag)
    s_val, i_val = _dedup_merge(s_c, i_c, s_f, i_f, cfg.k) \
        if cfg.use_fuzzy_validation else (s_c, i_c)
    s_out, i_out = _dedup_merge(s_c, i_c, s_f, i_f, cfg.k) \
        if cfg.use_fuzzy_enhancement else (s_c, i_c)

    accept, best, slot = reidentify(
        i_val, state.query_doc_ids, state.query_valid,
        jnp.float32(cfg.tau))

    return {"draft_ids": i_out, "draft_scores": s_out,
            "val_ids": i_val, "accept": accept,
            "homology": best, "matched_slot": slot}


speculate_batched = jax.jit(
    jax.vmap(speculate, in_axes=(None, None, None, 0)),
    static_argnames=("cfg",))


# ---------------------------------------------------------------------------
# Intra-batch homology sharing (continuous-batching acceptance channel)
# ---------------------------------------------------------------------------

@jax.jit
def intra_batch_share(val_ids: jax.Array, rejected: jax.Array,
                      tau: jax.Array, pending: jax.Array | None = None):
    """Greedy leader election among the rejected drafts of a full batch.

    The snapshot semantics of micro-batched serving cannot let intra-batch
    queries re-identify each other through the cache; this scores them
    against *each other* instead: ``val_ids [B, k]`` are the validation
    drafts, ``rejected [B]`` marks queries awaiting a full retrieval.
    Scanning in admission order, each rejected query either becomes a
    *leader* (pays one full retrieval) or a *follower* of the best earlier
    leader with homology > tau, sharing that leader's full result instead
    of paying for its own (single-flight collapsing of homologous work).

    ``pending [B]`` optionally marks rows that are ALREADY leaders of
    earlier, still-unresolved full retrievals: they keep their leader role
    and serve as attach targets, letting a serving loop extend the election
    window from one batch to its whole reject queue.

    ``tau`` here may reasonably be lower than the validation threshold:
    validation scores a draft against a cached FULL result set, while
    sharing scores two k-item speculative drafts against each other, which
    systematically underestimates the queries' true homology (both sides
    are noisy subsets).

    Returns dict(is_leader [B] bool, leader [B] int32, share_score [B]):
    rows neither rejected nor pending keep leader[i] == i with is_leader
    False.
    """
    b = val_ids.shape[0]
    if pending is None:
        pending = jnp.zeros((b,), bool)
    # pairwise homology: scores[i, j] = s(q_i, q_j), 0 on invalid columns
    scores = homology_scores_batched(val_ids, val_ids, rejected | pending)
    idx = jnp.arange(b)
    tau = jnp.float32(tau)

    def body(i, carry):
        is_leader, leader, share = carry
        s = jnp.where(is_leader & (idx < i), scores[i], -1.0)
        best = jnp.argmax(s).astype(jnp.int32)
        follow = rejected[i] & ~pending[i] & (s[best] > tau)
        lead = (rejected[i] & ~follow) | pending[i]
        return (is_leader.at[i].set(lead),
                leader.at[i].set(jnp.where(follow, best, i)),
                share.at[i].set(jnp.where(follow, s[best], 0.0)))

    is_leader, leader, share = jax.lax.fori_loop(
        0, b, body, (pending, idx.astype(jnp.int32),
                     jnp.zeros((b,), jnp.float32)))
    return {"is_leader": is_leader, "leader": leader, "share_score": share}


# ---------------------------------------------------------------------------
# Cache update on rejection (Algorithm 1 line 16)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnames=("state",))
def cache_update(cfg: HasConfig, state: HasState, q_emb: jax.Array,
                 full_ids: jax.Array, full_vecs: jax.Array) -> HasState:
    """Insert (q, D_full) into P and the new docs into C_c (FIFO, dedup)."""
    h = cfg.h_max
    slot = state.q_ptr % h
    query_emb = state.query_emb.at[slot].set(q_emb)
    query_doc_ids = state.query_doc_ids.at[slot].set(full_ids)
    query_valid = state.query_valid.at[slot].set(True)

    # doc dedup: only insert ids not already present
    present = jnp.any(full_ids[:, None] == state.doc_ids[None, :], axis=1)
    new = (~present) & (full_ids >= 0)
    # ring positions for the new docs
    offs = jnp.cumsum(new.astype(jnp.int32)) - 1
    pos = (state.d_ptr + offs) % state.doc_ids.shape[0]
    pos = jnp.where(new, pos, state.doc_ids.shape[0])        # drop non-new
    doc_ids = state.doc_ids.at[pos].set(full_ids, mode="drop")
    doc_emb = state.doc_emb.at[pos].set(full_vecs, mode="drop")
    d_ptr = state.d_ptr + jnp.sum(new.astype(jnp.int32))

    return HasState(query_emb=query_emb, query_doc_ids=query_doc_ids,
                    query_valid=query_valid, q_ptr=state.q_ptr + 1,
                    doc_emb=doc_emb, doc_ids=doc_ids, d_ptr=d_ptr)


def cache_memory_bytes(cfg: HasConfig) -> int:
    """Memory footprint of the cache (Table IX 'Mem' column)."""
    d = cfg.d
    per_query = d * 4 + cfg.k * 4 + 1
    per_doc = d * 4 + 4
    return cfg.h_max * per_query + cfg.doc_cap * per_doc
