"""HaS pipeline state + two-channel speculation (paper §II-B, Algorithm 1).

All state lives in fixed-shape JAX arrays so every step jits:
  * query cache P = (query_emb [H,d], query_doc_ids [H,k], valid [H]) — a FIFO
    ring (the paper's FIFO replacement policy) with pointer ``q_ptr``.
  * cache channel C_c = FIFO ring of *deduplicated* documents previously
    retrieved from the full database (doc_emb [Dc,d], doc_ids [Dc]).
  * fuzzy channel C_f = an aggressively configured IVFIndex (see
    retrieval/ivf.py), optionally subset-compressed (Table VII).

Entry points (each records itself on :mod:`repro.core.dispatch` so the
serving layers' dispatch-count model is measurable, one record == one
host→device program launch):

``speculate``
    One speculative retrieval for a single query [d]: two-channel top-k ->
    rerank/merge -> draft -> homology validation (Algorithm 1 lines 1–14).
``speculate_batch``
    The batch-native hot path: [B, d] queries through ONE jitted program
    behind a ``backend="pallas" | "xla"`` switch.

    * ``backend="xla"`` is the reference oracle (and the CPU default): a
      dense [B, Dc] cache-channel score matrix plus the jnp IVF search,
      whose bucket gather materializes [B, nprobe, cap, d] in HBM.
    * ``backend="pallas"`` dispatches the cache channel to the streaming
      ``topk_search`` kernel (the doc store never leaves VMEM tiles), the
      fuzzy channel to the scalar-prefetch ``ivf_scan`` kernel (centroid
      top-nprobe on the MXU, buckets DMA'd per grid step with no HBM
      materialization), and validation to the ``homology_score`` kernel.
      On CPU the kernels run in interpret mode (``interpret=None`` picks
      per platform), numerically identical to the TPU path.

    Dedup-merge, rerank and validation are fused into the same jitted
    program, so a batch of B queries costs exactly one device dispatch
    instead of the O(B) launches of per-query serving.
``speculate_batched``
    Legacy ``vmap(speculate)`` lifting, kept as a second oracle for the
    batch path (same numerics as ``backend="xla"``).
``cache_update``
    Insert one fallback full-retrieval result (Algorithm 1 line 16).
``cache_update_batched``
    Fold a whole full-retrieval batch (leaders + follower attribution from
    ``intra_batch_share``) into ``HasState`` with one donated-buffer
    ``lax.scan`` — exactly equivalent to a sequential fold of
    ``cache_update`` over the unmasked rows, in one dispatch.

Multi-tenant partitioning: :func:`init_tenant_states` stacks T independent
stores into one ``[T, ...]`` pytree (per-tenant ``q_ptr``/``d_ptr``), and
every batch entry point takes an optional ``tenant_ids [B]`` that gathers
each query's slice / scatters each ingest row inside the SAME single
jitted program (per-query group masking in the Pallas kernels, a dense
tenant-compare mask in the XLA oracle).  ``intra_batch_share`` masks its
pairwise homology matrix by tenant so leaders/followers never cross
tenants.  T == 1 reduces bit-exactly to the unpartitioned path.

Fused-list speculation (``HasConfig.fusion == "rrf"``): when the cloud
stage is the hybrid lexical+dense backend, the cached result lists are
*fused* lists whose per-channel raw scores live on incompatible scales — a
cosine similarity and a hashed-term match mass cannot be compared, so the
score-domain dedup-merge would be meaningless.  In rrf mode both
speculation channels merge in RANK domain (``_rrf_merge``: mass
``1/(rrf_k + rank)``, cross-channel duplicates combined onto the first
occurrence) and homology validation weighs each draft slot by its
normalized RRF mass (``homology_scores_weighted`` /
the ``draft_weights`` operand of the ``homology_score`` kernel) instead of
the uniform 1/k overlap ratio.  Acceptance decisions therefore depend only
on the channel *rankings*: any positive monotone transform of either
channel's raw scores leaves drafts, homology scores, and accept bits
bit-identical (pinned by tests/test_hybrid_fusion.py).  Fused lists flow
through ``cache_update*`` unchanged in shape — the cache ingests whatever
the cloud stage returns, so drafts reproduce fused results for homologous
queries.  The default ``fusion="score"`` keeps the pre-hybrid program
byte-identical.

The host-side serving loop (serving/engine.py) sequences these per query
exactly as Algorithm 1; serving/batched.py and serving/scheduler.py drive
the batch-native entry points.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core.homology import (homology_scores, homology_scores_batched,
                                 homology_scores_weighted,
                                 homology_scores_weighted_batched,
                                 reidentify, rrf_draft_weights)
from repro.retrieval.ivf import IVFIndex, ivf_search


@dataclasses.dataclass(frozen=True)
class HasConfig:
    k: int = 10                    # documents per retrieval (draft size)
    tau: float = 0.2               # homology threshold
    h_max: int = 5000              # query-cache capacity (paper default)
    doc_capacity: int = 0          # doc-store slots; 0 -> h_max * k
    nprobe: int = 64               # fuzzy channel buckets probed
    n_buckets: int = 8192          # fuzzy channel total buckets
    use_fuzzy_validation: bool = True    # Table VI 'V'
    use_fuzzy_enhancement: bool = True   # Table VI 'E'
    d: int = 64                    # embedding dim
    fusion: str = "score"          # channel merge: "score" | "rrf"
    rrf_k: float = 60.0            # RRF rank constant (fusion == "rrf")

    @property
    def doc_cap(self) -> int:
        return self.doc_capacity or self.h_max * self.k


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class HasState:
    query_emb: jax.Array      # [H, d]
    query_doc_ids: jax.Array  # [H, k] int32
    query_valid: jax.Array    # [H] bool
    q_ptr: jax.Array          # scalar int32
    doc_emb: jax.Array        # [Dc, d]
    doc_ids: jax.Array        # [Dc] int32 (-1 = empty)
    d_ptr: jax.Array          # scalar int32

    def tree_flatten(self):
        return ((self.query_emb, self.query_doc_ids, self.query_valid,
                 self.q_ptr, self.doc_emb, self.doc_ids, self.d_ptr), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_has_state(cfg: HasConfig, dtype=jnp.float32) -> HasState:
    return HasState(
        query_emb=jnp.zeros((cfg.h_max, cfg.d), dtype),
        query_doc_ids=jnp.full((cfg.h_max, cfg.k), -1, jnp.int32),
        query_valid=jnp.zeros((cfg.h_max,), bool),
        q_ptr=jnp.zeros((), jnp.int32),
        doc_emb=jnp.zeros((cfg.doc_cap, cfg.d), dtype),
        doc_ids=jnp.full((cfg.doc_cap,), -1, jnp.int32),
        d_ptr=jnp.zeros((), jnp.int32),
    )


def init_tenant_states(cfg: HasConfig, n_tenants: int,
                       dtype=jnp.float32) -> HasState:
    """Tenant-partitioned store: a stacked ``[T, ...]`` :class:`HasState`.

    Every array gains a leading tenant axis (``q_ptr``/``d_ptr`` become
    ``[T]``), so each tenant owns an independent query cache + doc-store
    FIFO ring of the full per-tenant capacity (``h_max`` / ``doc_cap``
    EACH).  One tenant's churn can never evict another's homology window,
    and the tenant-batched entry points (:func:`speculate_batch` /
    :func:`cache_update_batched` with ``tenant_ids``) gather/scatter each
    query's slice inside one jitted program.  ``n_tenants == 1`` is
    bit-exactly the single-tenant path on a ``[1, ...]`` view.
    """
    if n_tenants < 1:
        raise ValueError(f"n_tenants must be >= 1, got {n_tenants}")
    return HasState(
        query_emb=jnp.zeros((n_tenants, cfg.h_max, cfg.d), dtype),
        query_doc_ids=jnp.full((n_tenants, cfg.h_max, cfg.k), -1, jnp.int32),
        query_valid=jnp.zeros((n_tenants, cfg.h_max), bool),
        q_ptr=jnp.zeros((n_tenants,), jnp.int32),
        doc_emb=jnp.zeros((n_tenants, cfg.doc_cap, cfg.d), dtype),
        doc_ids=jnp.full((n_tenants, cfg.doc_cap), -1, jnp.int32),
        d_ptr=jnp.zeros((n_tenants,), jnp.int32),
    )


def tenant_count(state: HasState) -> int:
    """Number of tenant partitions (1 for an unstacked single-tenant state)."""
    return state.q_ptr.shape[0] if state.q_ptr.ndim else 1


def tenant_slice(state: HasState, t) -> HasState:
    """View of one tenant's partition as an unstacked single-tenant state."""
    return jax.tree_util.tree_map(lambda a: a[t], state)


def default_backend() -> str:
    """Pallas kernels on TPU, the XLA oracle elsewhere (CPU containers)."""
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# ---------------------------------------------------------------------------
# Two-channel fast retrieval + homology validation
# ---------------------------------------------------------------------------

def _dedup_merge(s_a, i_a, s_b, i_b, k):
    """Merge two candidate lists, dropping b-entries duplicated in a."""
    dup = jnp.any(i_b[:, None] == i_a[None, :], axis=1) & (i_b >= 0)
    s_b = jnp.where(dup, -jnp.inf, s_b)
    s = jnp.concatenate([s_a, s_b])
    i = jnp.concatenate([i_a, i_b])
    ts, t = jax.lax.top_k(s, k)
    # a dup-masked (or bucket-starved) entry carries -inf but may retain a
    # stale positive doc id; normalize so validation never counts phantom
    # overlaps against the query cache
    return ts, jnp.where(jnp.isfinite(ts), i[t], -1)


def _rrf_merge(i_a, i_b, k, rrf_k):
    """Rank-domain RRF merge of two candidate lists (``fusion == "rrf"``).

    Every slot contributes mass ``1/(rrf_k + rank)`` within its channel;
    ids appearing in both channels sum their mass onto the FIRST occurrence
    (the duplicate slot carries 0 and can never win), and the merged top-k
    is ordered by total mass.  The output "scores" are RRF mass — a pure
    function of the channel *rankings*, so any positive monotone transform
    of either channel's raw scores leaves the fused list unchanged (the
    property that makes fused drafts comparable across channels with
    incompatible score scales).  Empty slots return (-inf, -1) like
    :func:`_dedup_merge`.
    """
    ka, kb = i_a.shape[0], i_b.shape[0]
    ids = jnp.concatenate([i_a, i_b])
    rank = jnp.concatenate(
        [jnp.arange(ka), jnp.arange(kb)]).astype(jnp.float32)
    pos = jnp.arange(ka + kb)
    valid = ids >= 0
    raw = jnp.where(valid, 1.0 / (rrf_k + rank), 0.0)
    same = (ids[:, None] == ids[None, :]) & valid[:, None] & valid[None, :]
    first = ~jnp.any(same & (pos[None, :] < pos[:, None]), axis=1)
    mass = jnp.sum(jnp.where(same, raw[None, :], 0.0), axis=1)
    mass = jnp.where(first & valid, mass, 0.0)
    ts, t = jax.lax.top_k(mass, k)
    return jnp.where(ts > 0, ts, -jnp.inf), jnp.where(ts > 0, ids[t], -1)


def _channel_merge(cfg: HasConfig):
    """The configured two-channel merge, vmapped over the batch axis."""
    if cfg.fusion == "rrf":
        return jax.vmap(
            lambda sa, ia, sb, ib: _rrf_merge(ia, ib, cfg.k, cfg.rrf_k))
    if cfg.fusion == "score":
        return jax.vmap(
            lambda sa, ia, sb, ib: _dedup_merge(sa, ia, sb, ib, cfg.k))
    raise ValueError(f"unknown fusion mode {cfg.fusion!r}")


def _speculate_impl(cfg: HasConfig, state: HasState, index: IVFIndex,
                    q_emb: jax.Array):
    q = q_emb[None, :]                                       # [1, d]

    # cache channel: flat exact top-k over the doc store
    sc = (q @ state.doc_emb.T)[0]                            # [Dc]
    sc = jnp.where(state.doc_ids >= 0, sc, -jnp.inf)
    s_c, slots = jax.lax.top_k(sc, cfg.k)
    i_c = jnp.where(jnp.isfinite(s_c), state.doc_ids[slots], -1)

    # fuzzy channel: aggressive IVF
    s_f, i_f = ivf_search(index, q, nprobe=cfg.nprobe, k=cfg.k)
    s_f, i_f = s_f[0], i_f[0]

    # draft used for validation (V flag) and for output (E flag)
    if cfg.fusion == "rrf":
        def fuse(sa, ia, sb, ib):
            return _rrf_merge(ia, ib, cfg.k, cfg.rrf_k)
    else:
        def fuse(sa, ia, sb, ib):
            return _dedup_merge(sa, ia, sb, ib, cfg.k)
    s_val, i_val = fuse(s_c, i_c, s_f, i_f) \
        if cfg.use_fuzzy_validation else (s_c, i_c)
    s_out, i_out = fuse(s_c, i_c, s_f, i_f) \
        if cfg.use_fuzzy_enhancement else (s_c, i_c)

    if cfg.fusion == "rrf":
        # fused-list validation: rank-weighted homology mass, scale-free
        s = homology_scores_weighted(
            i_val, state.query_doc_ids, state.query_valid,
            rrf_draft_weights(i_val, cfg.rrf_k))
        slot = jnp.argmax(s).astype(jnp.int32)
        best = s[slot]
        accept = best > jnp.float32(cfg.tau)
    else:
        accept, best, slot = reidentify(
            i_val, state.query_doc_ids, state.query_valid,
            jnp.float32(cfg.tau))

    return {"draft_ids": i_out, "draft_scores": s_out,
            "val_ids": i_val, "accept": accept,
            "homology": best, "matched_slot": slot}


_speculate_jit = functools.partial(jax.jit, static_argnames=("cfg",))(
    _speculate_impl)

_speculate_batched_jit = jax.jit(
    jax.vmap(_speculate_impl, in_axes=(None, None, None, 0)),
    static_argnames=("cfg",))


def speculate(cfg: HasConfig, state: HasState, index: IVFIndex,
              q_emb: jax.Array):
    """One speculative retrieval (Algorithm 1 lines 1–14) for query q [d].

    Returns dict with draft ids/scores, accept flag, best homology score and
    matched cache slot.
    """
    dispatch.record("speculate")
    return _speculate_jit(cfg, state, index, q_emb)


def speculate_batched(cfg: HasConfig, state: HasState, index: IVFIndex,
                      q_embs: jax.Array):
    """Legacy vmap lifting of :func:`speculate` over [B, d] queries."""
    dispatch.record("speculate_batched")
    return _speculate_batched_jit(cfg, state, index, q_embs)


@functools.partial(
    jax.jit, static_argnames=("cfg", "backend", "interpret", "tile_c"))
def _speculate_batch_impl(cfg: HasConfig, state: HasState, index: IVFIndex,
                          q_embs: jax.Array, backend: str, interpret: bool,
                          tile_c: int):
    nprobe = min(cfg.nprobe, index.n_buckets)

    if backend == "pallas":
        from repro.kernels.homology_score import homology_score
        from repro.kernels.ivf_scan import ivf_scan
        from repro.kernels.topk_search import topk_search

        # cache channel: streaming tiled top-k, doc store stays in VMEM
        s_c, slots = topk_search(q_embs, state.doc_emb, cfg.k,
                                 tile_c=tile_c, valid=state.doc_ids >= 0,
                                 interpret=interpret)
        i_c = jnp.where(jnp.isfinite(s_c),
                        state.doc_ids[jnp.maximum(slots, 0)], -1)

        # fuzzy channel: centroid top-nprobe on the MXU, then the
        # scalar-prefetch bucket scan (no [B, nprobe, cap, d] gather)
        cscores = q_embs @ index.centroids.T                 # [B, C]
        _, probe = jax.lax.top_k(cscores, nprobe)
        s_f, i_f = ivf_scan(q_embs, probe.astype(jnp.int32),
                            index.bucket_vecs, index.bucket_ids, cfg.k,
                            interpret=interpret)
    elif backend == "xla":
        # reference oracle: dense score matrix + materialized bucket gather
        sc = q_embs @ state.doc_emb.T                        # [B, Dc]
        sc = jnp.where(state.doc_ids[None, :] >= 0, sc, -jnp.inf)
        s_c, slots = jax.lax.top_k(sc, cfg.k)
        i_c = jnp.where(jnp.isfinite(s_c), state.doc_ids[slots], -1)
        s_f, i_f = ivf_search(index, q_embs, nprobe=cfg.nprobe, k=cfg.k)
    else:
        raise ValueError(f"unknown backend {backend!r}")

    merge = _channel_merge(cfg)
    s_val, i_val = merge(s_c, i_c, s_f, i_f) \
        if cfg.use_fuzzy_validation else (s_c, i_c)
    s_out, i_out = merge(s_c, i_c, s_f, i_f) \
        if cfg.use_fuzzy_enhancement else (s_c, i_c)

    w_val = rrf_draft_weights(i_val, cfg.rrf_k) \
        if cfg.fusion == "rrf" else None
    if backend == "pallas":
        scores = homology_score(i_val, state.query_doc_ids,
                                state.query_valid, draft_weights=w_val,
                                interpret=interpret)
    elif w_val is not None:
        scores = homology_scores_weighted_batched(
            i_val, state.query_doc_ids, state.query_valid, w_val)
    else:
        scores = homology_scores_batched(i_val, state.query_doc_ids,
                                         state.query_valid)
    slot = jnp.argmax(scores, axis=1).astype(jnp.int32)      # [B]
    best = jnp.take_along_axis(scores, slot[:, None], axis=1)[:, 0]
    accept = best > jnp.float32(cfg.tau)

    return {"draft_ids": i_out, "draft_scores": s_out,
            "val_ids": i_val, "accept": accept,
            "homology": best, "matched_slot": slot}


@functools.partial(
    jax.jit, static_argnames=("cfg", "backend", "interpret", "tile_c"))
def _speculate_batch_tenant_impl(cfg: HasConfig, state: HasState,
                                 index: IVFIndex, q_embs: jax.Array,
                                 tenant_ids: jax.Array, backend: str,
                                 interpret: bool, tile_c: int):
    """Tenant-partitioned speculation: one program, per-query cache slices.

    ``state`` is a stacked ``[T, ...]`` store (:func:`init_tenant_states`);
    ``tenant_ids [B]`` selects each query's partition.  Both channels that
    hold tenant data — the doc-store cache channel and the query-cache
    validation table — flatten to ``[T*Dc]`` / ``[T*H]`` rows tagged with
    their tenant, and the scoring masks rows whose tenant differs from the
    query's (per-query group masking in the Pallas kernels; a dense
    tenant-compare mask in the XLA oracle).  The fuzzy channel is the
    corpus-derived IVF index, shared by construction (it holds no
    per-tenant state).  T == 1 is bit-exact with the unpartitioned path.
    """
    t, dc = state.doc_ids.shape
    h = state.query_valid.shape[1]
    d = q_embs.shape[1]
    nprobe = min(cfg.nprobe, index.n_buckets)
    doc_emb = state.doc_emb.reshape(t * dc, d)
    doc_ids = state.doc_ids.reshape(t * dc)
    doc_tenant = jnp.repeat(jnp.arange(t, dtype=jnp.int32), dc)

    if backend == "pallas":
        from repro.kernels.homology_score import homology_score
        from repro.kernels.ivf_scan import ivf_scan
        from repro.kernels.topk_search import topk_search

        s_c, slots = topk_search(q_embs, doc_emb, cfg.k, tile_c=tile_c,
                                 valid=doc_ids >= 0, row_group=doc_tenant,
                                 q_group=tenant_ids, interpret=interpret)
        i_c = jnp.where(jnp.isfinite(s_c),
                        doc_ids[jnp.maximum(slots, 0)], -1)
        cscores = q_embs @ index.centroids.T                 # [B, C]
        _, probe = jax.lax.top_k(cscores, nprobe)
        s_f, i_f = ivf_scan(q_embs, probe.astype(jnp.int32),
                            index.bucket_vecs, index.bucket_ids, cfg.k,
                            interpret=interpret)
    elif backend == "xla":
        sc = q_embs @ doc_emb.T                              # [B, T*Dc]
        ok = (doc_ids[None, :] >= 0) \
            & (doc_tenant[None, :] == tenant_ids[:, None])
        sc = jnp.where(ok, sc, -jnp.inf)
        s_c, slots = jax.lax.top_k(sc, cfg.k)
        i_c = jnp.where(jnp.isfinite(s_c), doc_ids[slots], -1)
        s_f, i_f = ivf_search(index, q_embs, nprobe=cfg.nprobe, k=cfg.k)
    else:
        raise ValueError(f"unknown backend {backend!r}")

    merge = _channel_merge(cfg)
    s_val, i_val = merge(s_c, i_c, s_f, i_f) \
        if cfg.use_fuzzy_validation else (s_c, i_c)
    s_out, i_out = merge(s_c, i_c, s_f, i_f) \
        if cfg.use_fuzzy_enhancement else (s_c, i_c)

    qdi = state.query_doc_ids.reshape(t * h, cfg.k)
    qvalid = state.query_valid.reshape(t * h)
    row_tenant = jnp.repeat(jnp.arange(t, dtype=jnp.int32), h)
    w_val = rrf_draft_weights(i_val, cfg.rrf_k) \
        if cfg.fusion == "rrf" else None
    if backend == "pallas":
        scores = homology_score(i_val, qdi, qvalid, row_group=row_tenant,
                                q_group=tenant_ids, draft_weights=w_val,
                                interpret=interpret)
    else:
        valid_b = qvalid[None, :] \
            & (row_tenant[None, :] == tenant_ids[:, None])   # [B, T*H]
        if w_val is not None:
            scores = jax.vmap(
                homology_scores_weighted, in_axes=(0, None, 0, 0))(
                i_val, qdi, valid_b, w_val)
        else:
            scores = jax.vmap(homology_scores, in_axes=(0, None, 0))(
                i_val, qdi, valid_b)
    # matched_slot is flat over [T*H]: tenant t's slot s is t*h_max + s
    slot = jnp.argmax(scores, axis=1).astype(jnp.int32)      # [B]
    best = jnp.take_along_axis(scores, slot[:, None], axis=1)[:, 0]
    accept = best > jnp.float32(cfg.tau)

    return {"draft_ids": i_out, "draft_scores": s_out,
            "val_ids": i_val, "accept": accept,
            "homology": best, "matched_slot": slot}


def speculate_batch(cfg: HasConfig, state: HasState, index: IVFIndex,
                    q_embs: jax.Array, backend: str | None = None,
                    interpret: bool | None = None, tile_c: int = 1024,
                    tenant_ids: jax.Array | None = None):
    """Batch-native speculation: [B, d] queries, one device dispatch.

    ``backend=None`` auto-selects (:func:`default_backend`): the Pallas
    kernel pipeline on TPU, the XLA reference on CPU.  ``interpret=None``
    runs the kernels in interpret mode off-TPU.  Returns the same dict as
    :func:`speculate` with a leading batch axis on every entry.

    ``tenant_ids [B]`` (optional) routes each query through its tenant's
    partition of a stacked :func:`init_tenant_states` store — still one
    device dispatch per batch; ``matched_slot`` is then flat over ``[T*H]``
    (tenant t's slot s at ``t * h_max + s``).
    """
    if backend is None:
        backend = default_backend()
    if backend != "pallas":
        interpret = False                  # irrelevant: one jit cache entry
    elif interpret is None:
        interpret = jax.default_backend() != "tpu"
    dispatch.record("speculate_batch")
    if tenant_ids is None:
        if state.q_ptr.ndim != 0:
            raise ValueError(
                "stacked tenant state requires tenant_ids (or slice one "
                "tenant out with tenant_slice)")
        return _speculate_batch_impl(cfg, state, index, q_embs,
                                     backend=backend, interpret=interpret,
                                     tile_c=tile_c)
    if state.q_ptr.ndim != 1:
        raise ValueError("tenant_ids requires a stacked init_tenant_states "
                         "state")
    return _speculate_batch_tenant_impl(
        cfg, state, index, q_embs, jnp.asarray(tenant_ids, jnp.int32),
        backend=backend, interpret=interpret, tile_c=tile_c)


# ---------------------------------------------------------------------------
# Intra-batch homology sharing (continuous-batching acceptance channel)
# ---------------------------------------------------------------------------

@jax.jit
def intra_batch_share(val_ids: jax.Array, rejected: jax.Array,
                      tau: jax.Array, pending: jax.Array | None = None,
                      tenant_ids: jax.Array | None = None):
    """Greedy leader election among the rejected drafts of a full batch.

    The snapshot semantics of micro-batched serving cannot let intra-batch
    queries re-identify each other through the cache; this scores them
    against *each other* instead: ``val_ids [B, k]`` are the validation
    drafts, ``rejected [B]`` marks queries awaiting a full retrieval.
    Scanning in admission order, each rejected query either becomes a
    *leader* (pays one full retrieval) or a *follower* of the best earlier
    leader with homology > tau, sharing that leader's full result instead
    of paying for its own (single-flight collapsing of homologous work).

    ``pending [B]`` optionally marks rows that are ALREADY leaders of
    earlier, still-unresolved full retrievals: they keep their leader role
    and serve as attach targets, letting a serving loop extend the election
    window from one batch to its whole reject queue.

    ``tau`` here may reasonably be lower than the validation threshold:
    validation scores a draft against a cached FULL result set, while
    sharing scores two k-item speculative drafts against each other, which
    systematically underestimates the queries' true homology (both sides
    are noisy subsets).

    ``tenant_ids [B]`` (optional) masks the pairwise homology matrix so the
    election never crosses tenants: a rejected query can only follow a
    leader of its own tenant (isolation — one tenant's retrieved documents
    are never served to another's queries), and within each tenant the
    election is unchanged.

    Returns dict(is_leader [B] bool, leader [B] int32, share_score [B]):
    rows neither rejected nor pending keep leader[i] == i with is_leader
    False.
    """
    b = val_ids.shape[0]
    if pending is None:
        pending = jnp.zeros((b,), bool)
    # pairwise homology: scores[i, j] = s(q_i, q_j), 0 on invalid columns
    scores = homology_scores_batched(val_ids, val_ids, rejected | pending)
    if tenant_ids is not None:
        # cross-tenant pairs score -1 < any tau: never elected as leader
        # for a follower of a different tenant
        scores = jnp.where(tenant_ids[:, None] == tenant_ids[None, :],
                           scores, -1.0)
    idx = jnp.arange(b)
    tau = jnp.float32(tau)

    def body(i, carry):
        is_leader, leader, share = carry
        s = jnp.where(is_leader & (idx < i), scores[i], -1.0)
        best = jnp.argmax(s).astype(jnp.int32)
        follow = rejected[i] & ~pending[i] & (s[best] > tau)
        lead = (rejected[i] & ~follow) | pending[i]
        return (is_leader.at[i].set(lead),
                leader.at[i].set(jnp.where(follow, best, i)),
                share.at[i].set(jnp.where(follow, s[best], 0.0)))

    is_leader, leader, share = jax.lax.fori_loop(
        0, b, body, (pending, idx.astype(jnp.int32),
                     jnp.zeros((b,), jnp.float32)))
    return {"is_leader": is_leader, "leader": leader, "share_score": share}


# ---------------------------------------------------------------------------
# Cache update on rejection (Algorithm 1 line 16)
# ---------------------------------------------------------------------------

def _cache_update_impl(cfg: HasConfig, state: HasState, q_emb: jax.Array,
                       full_ids: jax.Array, full_vecs: jax.Array) -> HasState:
    h = cfg.h_max
    slot = state.q_ptr % h
    query_emb = state.query_emb.at[slot].set(q_emb)
    query_doc_ids = state.query_doc_ids.at[slot].set(full_ids)
    query_valid = state.query_valid.at[slot].set(True)

    # doc dedup: only insert ids not already present in the store AND not
    # duplicated earlier in this full result (first occurrence wins —
    # in-batch duplicates must not burn extra ring slots)
    present = jnp.any(full_ids[:, None] == state.doc_ids[None, :], axis=1)
    pos_in = jnp.arange(full_ids.shape[0])
    dup_in_batch = jnp.any(
        (full_ids[:, None] == full_ids[None, :])
        & (pos_in[None, :] < pos_in[:, None]), axis=1)
    new = (~present) & (~dup_in_batch) & (full_ids >= 0)
    # ring positions for the new docs
    offs = jnp.cumsum(new.astype(jnp.int32)) - 1
    pos = (state.d_ptr + offs) % state.doc_ids.shape[0]
    pos = jnp.where(new, pos, state.doc_ids.shape[0])        # drop non-new
    doc_ids = state.doc_ids.at[pos].set(full_ids, mode="drop")
    doc_emb = state.doc_emb.at[pos].set(full_vecs, mode="drop")
    d_ptr = state.d_ptr + jnp.sum(new.astype(jnp.int32))

    return HasState(query_emb=query_emb, query_doc_ids=query_doc_ids,
                    query_valid=query_valid, q_ptr=state.q_ptr + 1,
                    doc_emb=doc_emb, doc_ids=doc_ids, d_ptr=d_ptr)


_cache_update_jit = functools.partial(
    jax.jit, static_argnames=("cfg",), donate_argnames=("state",))(
        _cache_update_impl)


def _tenant_update(cfg: HasConfig, state: HasState, t, q_emb, full_ids,
                   full_vecs) -> HasState:
    """Apply one ``_cache_update_impl`` to tenant t's slice of a stacked
    store (gather slice -> update -> scatter back; t may be traced)."""
    sl = jax.tree_util.tree_map(lambda a: a[t], state)
    sl = _cache_update_impl(cfg, sl, q_emb, full_ids, full_vecs)
    return jax.tree_util.tree_map(lambda a, b: a.at[t].set(b), state, sl)


_cache_update_tenant_jit = functools.partial(
    jax.jit, static_argnames=("cfg",), donate_argnames=("state",))(
        _tenant_update)


def cache_update(cfg: HasConfig, state: HasState, q_emb: jax.Array,
                 full_ids: jax.Array, full_vecs: jax.Array,
                 tenant_id=None) -> HasState:
    """Insert (q, D_full) into P and the new docs into C_c (FIFO, dedup).

    ``tenant_id`` (optional) targets one partition of a stacked
    :func:`init_tenant_states` store; all other partitions are untouched.
    """
    dispatch.record("cache_update")
    if tenant_id is None:
        if state.q_ptr.ndim != 0:
            raise ValueError(
                "stacked tenant state requires tenant_id (or slice one "
                "tenant out with tenant_slice)")
        return _cache_update_jit(cfg, state, q_emb, full_ids, full_vecs)
    if state.q_ptr.ndim != 1:
        raise ValueError("tenant_id requires a stacked init_tenant_states "
                         "state")
    if not 0 <= int(tenant_id) < state.q_ptr.shape[0]:
        raise ValueError(
            f"tenant_id {int(tenant_id)} out of range for "
            f"{state.q_ptr.shape[0]} tenants")
    return _cache_update_tenant_jit(cfg, state, jnp.int32(tenant_id),
                                    q_emb, full_ids, full_vecs)


@functools.partial(jax.jit, static_argnames=("cfg",),
                   donate_argnames=("state",))
def _cache_update_batched_jit(cfg: HasConfig, state: HasState,
                              q_embs: jax.Array, full_ids: jax.Array,
                              full_vecs: jax.Array,
                              mask: jax.Array) -> HasState:
    def body(st, xs):
        q, ids, vecs, on = xs
        st = jax.lax.cond(
            on, lambda s: _cache_update_impl(cfg, s, q, ids, vecs),
            lambda s: s, st)
        return st, None

    state, _ = jax.lax.scan(body, state, (q_embs, full_ids, full_vecs, mask))
    return state


@functools.partial(jax.jit, static_argnames=("cfg",),
                   donate_argnames=("state",))
def _cache_update_batched_tenant_jit(cfg: HasConfig, state: HasState,
                                     q_embs: jax.Array, full_ids: jax.Array,
                                     full_vecs: jax.Array, mask: jax.Array,
                                     tenant_ids: jax.Array) -> HasState:
    def body(st, xs):
        q, ids, vecs, on, t = xs
        st = jax.lax.cond(
            on, lambda s: _tenant_update(cfg, s, t, q, ids, vecs),
            lambda s: s, st)
        return st, None

    state, _ = jax.lax.scan(
        body, state, (q_embs, full_ids, full_vecs, mask, tenant_ids))
    return state


def cache_update_batched(cfg: HasConfig, state: HasState, q_embs: jax.Array,
                         full_ids: jax.Array, full_vecs: jax.Array,
                         mask: jax.Array | None = None,
                         tenant_ids: jax.Array | None = None) -> HasState:
    """Fold a whole full-retrieval batch into the cache in ONE dispatch.

    q_embs [B,d], full_ids [B,k], full_vecs [B,k,d]; ``mask [B]`` (optional)
    marks real rows — padding rows (mask False) leave the state untouched,
    so serving layers can reuse one compiled shape for variable-size ingest
    batches.  Equivalent to folding :func:`cache_update` sequentially over
    the unmasked rows (a donated-buffer ``lax.scan`` of the same body), but
    costs one device dispatch instead of B.

    ``tenant_ids [B]`` (optional) scatters each row's ingest into its
    tenant's partition of a stacked :func:`init_tenant_states` store —
    equivalent to folding :func:`cache_update` with ``tenant_id`` per row,
    still in one dispatch.
    """
    if mask is None:
        mask = jnp.ones((q_embs.shape[0],), bool)
    dispatch.record("cache_update_batched")
    if tenant_ids is None:
        if state.q_ptr.ndim != 0:
            raise ValueError(
                "stacked tenant state requires tenant_ids (or slice one "
                "tenant out with tenant_slice)")
        return _cache_update_batched_jit(cfg, state, q_embs, full_ids,
                                         full_vecs, mask)
    if state.q_ptr.ndim != 1:
        raise ValueError("tenant_ids requires a stacked init_tenant_states "
                         "state")
    return _cache_update_batched_tenant_jit(
        cfg, state, q_embs, full_ids, full_vecs, mask,
        jnp.asarray(tenant_ids, jnp.int32))


def cache_update_chunked(cfg: HasConfig, state: HasState, q_embs, full_ids,
                         full_vecs=None, *, corpus=None, chunk: int,
                         tenant_ids=None) -> HasState:
    """Fold N host-side update rows through ``cache_update_batched``.

    The one pad-to-fixed-shape helper shared by every serving layer
    (scheduler ingest, batched-engine reject ingest, warm-standby delta
    replay): rows are chunked to ``chunk``, and EVERY chunk — including the
    final partial one — is zero-padded to ``[chunk, ...]`` with masked rows
    so a single compiled shape serves any N (the tail chunk never jits a
    second shape; tests assert this via the ``core/dispatch`` probe plus
    the jit cache size).  ``q_embs [N, d]`` and ``full_ids [N, k]`` are
    host arrays/lists; pass either ``full_vecs [N, k, d]`` explicitly or a
    device ``corpus`` to gather them from by id on device (one gather per
    chunk, no host round-trip).  ``tenant_ids [N]`` (optional) scatters
    each row into its tenant's partition of a stacked store.
    """
    q_embs = np.asarray(q_embs, np.float32)
    full_ids = np.asarray(full_ids, np.int32)
    n, k, d = len(q_embs), full_ids.shape[1], q_embs.shape[1]
    if full_vecs is not None:
        full_vecs = np.asarray(full_vecs, np.float32)
    if tenant_ids is not None:
        tenant_ids = np.asarray(tenant_ids, np.int32)
    for i0 in range(0, n, chunk):
        m = min(chunk, n - i0)
        embs = np.zeros((chunk, d), np.float32)
        ids = np.zeros((chunk, k), np.int32)
        mask = np.zeros((chunk,), bool)
        embs[:m] = q_embs[i0:i0 + m]
        ids[:m] = full_ids[i0:i0 + m]
        mask[:m] = True
        ids_j = jnp.asarray(ids)
        if full_vecs is None:
            vecs = corpus[ids_j]
        else:
            vecs = np.zeros((chunk, k, d), np.float32)
            vecs[:m] = full_vecs[i0:i0 + m]
            vecs = jnp.asarray(vecs)
        tids = None
        if tenant_ids is not None:
            tids = np.zeros((chunk,), np.int32)     # pad rows: tenant 0,
            tids[:m] = tenant_ids[i0:i0 + m]        # masked off anyway
            tids = jnp.asarray(tids)
        state = cache_update_batched(cfg, state, jnp.asarray(embs), ids_j,
                                     vecs, jnp.asarray(mask),
                                     tenant_ids=tids)
    return state


def cache_memory_bytes(cfg: HasConfig) -> int:
    """Memory footprint of the cache (Table IX 'Mem' column)."""
    d = cfg.d
    per_query = d * 4 + cfg.k * 4 + 1
    per_doc = d * 4 + 4
    return cfg.h_max * per_query + cfg.doc_cap * per_doc


def speculation_bytes_moved(cfg: HasConfig, n_buckets: int, bucket_cap: int,
                            b: int, backend: str) -> int:
    """Analytic HBM traffic estimate for one ``speculate_batch`` call.

    Shared terms: the centroid matmul reads [C, d] once and validation reads
    the [H, k] id table once.  The backends differ on the two channels:

    * ``xla``   — the cache channel writes+reads a dense [B, Dc] score
      matrix on top of the doc-store stream, and the fuzzy channel's bucket
      gather materializes [B, nprobe, cap, d] in HBM (write + re-read for
      scoring), tripling bucket traffic.
    * ``pallas`` — the doc store streams through VMEM tiles once regardless
      of B, and each probed bucket is DMA'd and scored in place (read once).
    """
    d, k = cfg.d, cfg.k
    nprobe = min(cfg.nprobe, n_buckets)
    common = n_buckets * d * 4 + cfg.h_max * k * 4
    doc_stream = cfg.doc_cap * d * 4
    bucket_read = b * nprobe * bucket_cap * d * 4
    if backend == "pallas":
        return common + doc_stream + bucket_read
    score_mat = 2 * b * cfg.doc_cap * 4          # write + re-read
    return common + doc_stream + score_mat + 3 * bucket_read
