"""Homology score + re-identification (paper §III-C).

Definition 5: s(q1, q2) = |D1 ∩ D2| / k — the overlap ratio between the two
queries' retrieval result sets.

The paper computes this through a document→query inverted index J (a hash
map).  Hash maps do not exist on TPU; the TPU-native equivalent is a dense
fixed-shape overlap count: the draft's k doc-ids are compared against the
cached doc-id table [H, k] with a tiled compare-reduce (Pallas kernel
``homology_score``; this module is its jnp oracle).  Complexity O(H·k²) int
comparisons — vector-unit-trivial at H=5000, k=10.  A faithful host-side
inverted index lives in serving/engine.py for the sequential reference path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def homology_scores(draft_ids: jax.Array, cache_doc_ids: jax.Array,
                    cache_valid: jax.Array) -> jax.Array:
    """Homology score of one draft against every cached query.

    draft_ids [k] int32, cache_doc_ids [H, k] int32 (-1 pad),
    cache_valid [H] bool -> scores [H] float32 in [0, 1].
    """
    k = draft_ids.shape[0]
    eq = (draft_ids[None, :, None] == cache_doc_ids[:, None, :])  # [H,k,k]
    eq &= (draft_ids[None, :, None] >= 0)
    overlap = jnp.sum(jnp.any(eq, axis=2), axis=1)                 # [H]
    s = overlap.astype(jnp.float32) / k
    return jnp.where(cache_valid, s, 0.0)


def homology_scores_batched(draft_ids: jax.Array, cache_doc_ids: jax.Array,
                            cache_valid: jax.Array) -> jax.Array:
    """draft_ids [B, k] -> scores [B, H]."""
    return jax.vmap(lambda d: homology_scores(d, cache_doc_ids, cache_valid))(
        draft_ids)


def rrf_draft_weights(ids: jax.Array, rrf_k: float) -> jax.Array:
    """Per-slot normalized RRF mass of a fused draft: ids [..., k] ->
    weights [..., k] f32 summing to 1 over the valid slots (0 if none).

    Position j of a fused list carries mass ``1/(rrf_k + j)``; invalid
    (-1) slots carry none.  Normalizing per draft makes the weighted
    homology score lie in [0, 1] like the unweighted overlap ratio, so the
    same ``tau`` threshold applies.
    """
    k = ids.shape[-1]
    w = 1.0 / (rrf_k + jnp.arange(k, dtype=jnp.float32))
    w = jnp.where(ids >= 0, w, 0.0)
    norm = jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)
    return w / norm


def homology_scores_weighted(draft_ids: jax.Array, cache_doc_ids: jax.Array,
                             cache_valid: jax.Array,
                             draft_weights: jax.Array) -> jax.Array:
    """Rank-weighted homology of one fused draft against the cache.

    draft_ids [k], draft_weights [k] (pre-normalized, e.g.
    :func:`rrf_draft_weights`), cache_doc_ids [H, k], cache_valid [H]
    -> scores [H] f32: the matched fraction of the draft's RRF mass.
    Rank-domain on both sides — invariant to any positive monotone
    transform of either channel's raw scores.
    """
    eq = (draft_ids[None, :, None] == cache_doc_ids[:, None, :])  # [H,k,k]
    eq &= (draft_ids[None, :, None] >= 0)
    hit = jnp.any(eq, axis=2).astype(jnp.float32)                 # [H, k]
    s = jnp.sum(hit * draft_weights[None, :], axis=1)
    return jnp.where(cache_valid, s, 0.0)


def homology_scores_weighted_batched(draft_ids: jax.Array,
                                     cache_doc_ids: jax.Array,
                                     cache_valid: jax.Array,
                                     draft_weights: jax.Array) -> jax.Array:
    """draft_ids/draft_weights [B, k] -> scores [B, H]."""
    return jax.vmap(lambda d, w: homology_scores_weighted(
        d, cache_doc_ids, cache_valid, w))(draft_ids, draft_weights)


@functools.partial(jax.jit, static_argnames=())
def reidentify(draft_ids: jax.Array, cache_doc_ids: jax.Array,
               cache_valid: jax.Array, tau: jax.Array):
    """Threshold-based homologous-query re-identification.

    Returns (accept: bool, best_score: float32, best_slot: int32).
    Accept iff max_h s(q, q_h) > tau  (strict >, per Algorithm 1 line 11).
    """
    s = homology_scores(draft_ids, cache_doc_ids, cache_valid)
    best_slot = jnp.argmax(s)
    best = s[best_slot]
    return best > tau, best, best_slot.astype(jnp.int32)


def pairwise_homology(ids_a: jax.Array, ids_b: jax.Array) -> jax.Array:
    """s(q1,q2) for two result sets [k] -> scalar overlap ratio."""
    k = ids_a.shape[0]
    eq = (ids_a[:, None] == ids_b[None, :]) & (ids_a[:, None] >= 0)
    return jnp.sum(jnp.any(eq, axis=1)).astype(jnp.float32) / k
