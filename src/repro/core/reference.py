"""Faithful host-side reference of Algorithm 1 (hash-map inverted index).

This is the paper's data structure verbatim: a Python dict J mapping
document id -> set of cached queries, FIFO deques for P and the doc store.
Used as the oracle for the fixed-shape jitted implementation in core/has.py
(tests/test_has_core.py asserts trace equivalence on random query streams).
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np


@dataclasses.dataclass
class RefHas:
    k: int
    tau: float
    h_max: int
    doc_cap: int

    def __post_init__(self):
        self.queries: collections.deque = collections.deque()   # (emb, ids)
        self.doc_ids: collections.OrderedDict = collections.OrderedDict()
        self.doc_embs: dict[int, np.ndarray] = {}
        self.inverted: dict[int, set[int]] = collections.defaultdict(set)
        self._qcounter = 0

    # -- cache channel -------------------------------------------------------

    def cache_channel(self, q_emb: np.ndarray):
        """Exact top-k over the live doc store."""
        if not self.doc_ids:
            return np.full(self.k, -1, np.int64), np.full(self.k, -np.inf)
        ids = np.fromiter(self.doc_ids.keys(), np.int64)
        embs = np.stack([self.doc_embs[i] for i in ids])
        scores = embs @ q_emb
        order = np.argsort(-scores)[:self.k]
        out_ids = np.full(self.k, -1, np.int64)
        out_s = np.full(self.k, -np.inf)
        out_ids[:len(order)] = ids[order]
        out_s[:len(order)] = scores[order]
        return out_ids, out_s

    # -- homology validation (Algorithm 1 lines 3-14) ------------------------

    def validate(self, draft_ids: np.ndarray):
        freq: collections.Counter = collections.Counter()
        for d in draft_ids:
            if d < 0:
                continue
            for qh in self.inverted.get(int(d), ()):
                freq[qh] += 1
        if not freq:
            return False, 0.0
        best = max(freq.values())
        return (best / self.k) > self.tau, best / self.k

    # -- cache update (line 16) ----------------------------------------------

    def update(self, q_emb: np.ndarray, full_ids: np.ndarray,
               full_embs: np.ndarray):
        qid = self._qcounter
        self._qcounter += 1
        self.queries.append((qid, set(int(i) for i in full_ids if i >= 0)))
        for d in full_ids:
            if d >= 0:
                self.inverted[int(d)].add(qid)
        if len(self.queries) > self.h_max:
            old_qid, old_ids = self.queries.popleft()
            for d in old_ids:
                self.inverted[d].discard(old_qid)
        for i, d in enumerate(full_ids):
            d = int(d)
            if d < 0 or d in self.doc_ids:
                continue
            self.doc_ids[d] = True
            self.doc_embs[d] = np.asarray(full_embs[i])
            if len(self.doc_ids) > self.doc_cap:
                evicted, _ = self.doc_ids.popitem(last=False)
                self.doc_embs.pop(evicted, None)
