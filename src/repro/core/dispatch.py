"""Host→device dispatch accounting for the serving hot path.

A *dispatch* is one host-side invocation of a jitted program (one XLA
executable launch): the unit the batch-native refactor optimizes, since a
speculation batch that costs O(B) dispatches is dominated by host↔device
round-trips long before it is bandwidth-bound.  Every public entry point in
``core/has.py`` records itself here, so benchmarks can assert the dispatch
model (e.g. "one ``speculate_batch`` call == one dispatch regardless of B")
instead of inferring it from wall-clock.

The probe is a process-global counter keyed by entry-point name; recording
is a dict increment (no device sync, no tracing interaction — wrappers
record *outside* the jitted callables, so nothing is counted at trace time).

Usage::

    from repro.core import dispatch
    with dispatch.capture() as probe:
        speculate_batch(cfg, state, index, q)     # [B, d]
    assert probe.total() == 1
"""
from __future__ import annotations

import collections
import contextlib
from typing import Iterator

_counts: collections.Counter = collections.Counter()


def record(name: str) -> None:
    """Count one device dispatch attributed to entry point ``name``."""
    _counts[name] += 1


def counts() -> dict[str, int]:
    return dict(_counts)


def reset() -> None:
    _counts.clear()


class Capture:
    """Dispatch counts scoped to a ``with dispatch.capture()`` block."""

    def __init__(self, baseline: dict[str, int]):
        self._baseline = baseline

    def counts(self) -> dict[str, int]:
        return {k: v - self._baseline.get(k, 0)
                for k, v in _counts.items()
                if v - self._baseline.get(k, 0) > 0}

    def total(self) -> int:
        return sum(self.counts().values())


@contextlib.contextmanager
def capture() -> Iterator[Capture]:
    yield Capture(dict(_counts))
