"""Model zoo: LM transformers (dense / MoE / GQA), DimeNet GNN, RecSys models."""
