"""Shared neural-net layers: norms, RoPE, GQA attention, SwiGLU MLP, MoE.

All layers are pure functions over explicit parameter pytrees.  Each
``init_*`` has a matching ``*_logical`` returning the same-structure pytree of
logical-axis tuples used for sharding (see utils.logical_to_spec).
"""
from __future__ import annotations

import functools
from typing import Any, Mapping

import jax
import jax.numpy as jnp

from repro.utils import constrain

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_logical():
    return {"scale": ("d_model",)}


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_table(positions: jax.Array, dim: int, theta: float = 10000.0):
    """[.., dim/2] cos/sin tables for the given positions."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # [..., dim/2]
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
               fraction: float = 1.0) -> jax.Array:
    """Apply rotary embedding to the first ``fraction`` of head dims.

    x: [B, S, H, D]; positions: [B, S].  ``fraction=0.5`` reproduces
    ChatGLM's 2D-RoPE convention (rotate half the dims, pass the rest).
    """
    d = x.shape[-1]
    rot_d = int(d * fraction)
    if rot_d == 0:
        return x
    rot_d -= rot_d % 2
    x_rot, x_pass = x[..., :rot_d], x[..., rot_d:]
    cos, sin = rope_table(positions, rot_d, theta)          # [B, S, rot_d/2]
    cos = cos[:, :, None, :]                                # [B, S, 1, rot_d/2]
    sin = sin[:, :, None, :]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    y = jnp.stack([y1, y2], axis=-1).reshape(x_rot.shape)
    out = jnp.concatenate([y, x_pass], axis=-1) if x_pass.shape[-1] else y
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, causal / bidirectional, TP policies)
# ---------------------------------------------------------------------------

def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   d_head: int, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d_model ** -0.5
    return {
        "wq": jax.random.normal(k1, (d_model, n_heads, d_head), dtype) * s,
        "wk": jax.random.normal(k2, (d_model, n_kv_heads, d_head), dtype) * s,
        "wv": jax.random.normal(k3, (d_model, n_kv_heads, d_head), dtype) * s,
        "wo": jax.random.normal(k4, (n_heads, d_head, d_model), dtype)
              * ((n_heads * d_head) ** -0.5),
    }


def attention_logical(head_tp: bool):
    """Logical axes for attention params.

    head_tp=True  -> classic Megatron head-sharded QKV/O.
    head_tp=False -> heads replicated; activations are sequence-sharded instead
                     (used when n_heads % tp_size != 0).
    """
    h = "heads" if head_tp else None
    return {
        "wq": ("fsdp", h, None),
        "wk": ("fsdp", "kv_heads", None),
        "wv": ("fsdp", "kv_heads", None),
        "wo": (h, None, "fsdp"),
    }


def _repeat_kv(k, n_heads):
    """GQA: repeat KV heads to match query heads (avoids sharded reshapes)."""
    group = n_heads // k.shape[2]
    return jnp.repeat(k, group, axis=2) if group > 1 else k


def _attend_block(q_blk, k, v, scale, q_pos, causal, mask, dtype):
    """q_blk [B,bq,H,D], k/v [B,T,H,D], q_pos [bq] -> out [B,bq,H,D]."""
    scores = jnp.einsum("bshd,bthd->bhst", q_blk, k) * scale
    t = k.shape[1]
    if causal:
        j = jnp.arange(t)[None, :]
        scores = jnp.where(j <= q_pos[:, None], scores, -1e30)
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def attention(params, x, positions, *, causal: bool, rope_theta: float,
              rope_fraction: float = 1.0, rules=None, head_tp: bool = True,
              kv_cache=None, cache_index=None, mask=None, block_q: int = 0,
              head_pad_to: int = 0):
    """Multi-head GQA attention (head-sharded tensor parallel).

    Sharding scheme (production mesh): the residual stream is
    sequence-sharded over 'model' (Megatron sequence parallelism); QKV
    activations are head-sharded ('heads' -> model; GSPMD pads uneven head
    counts such as arctic's 56/16).  KV heads are replicated (GQA KVs are
    small) and repeated to match Q heads so no sharded dim is reshaped.

    block_q > 0 scans the query dim in blocks of that size, bounding the
    transient score matrix to [B, H, block_q, T] — required for 32k prefill.

    With kv_cache (decode): x is [B,1,Dm]; the cache's KV-seq dim shards over
    'model' ('data'+'model' at 500k), turning softmax normalization into a
    flash-decoding-style cross-shard reduction under GSPMD.
    Returns (out, new_cache).
    """
    b, s, _ = x.shape
    n_heads = params["wq"].shape[1]
    d_head = params["wq"].shape[-1]
    scale = d_head ** -0.5

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, positions, rope_theta, rope_fraction)
    k = apply_rope(k, positions, rope_theta, rope_fraction)

    if kv_cache is not None:
        ck, cv = kv_cache
        # decode: write the new K/V at cache_index, attend over the cache.
        ck = jax.lax.dynamic_update_slice_in_dim(
            ck, k.astype(ck.dtype), cache_index, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cv, v.astype(cv.dtype), cache_index, axis=1)
        kv_seq_ax = "kv_seq_long" if ck.shape[1] >= 2 ** 18 else "kv_seq"
        ck = constrain(ck, ("batch", kv_seq_ax, "kv_heads", None), rules)
        cv = constrain(cv, ("batch", kv_seq_ax, "kv_heads", None), rules)
        kf = _repeat_kv(ck, n_heads)
        vf = _repeat_kv(cv, n_heads)
        scores = jnp.einsum("bshd,bthd->bhst", q.astype(kf.dtype), kf) * scale
        t_idx = jnp.arange(ck.shape[1])
        valid = t_idx[None, None, None, :] <= cache_index
        scores = jnp.where(valid, scores, -1e30)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
        out = jnp.einsum("bhst,bthd->bshd", probs, vf.astype(x.dtype))
        new_cache = (ck, cv)
    else:
        # zero-pad heads up to a TP-divisible count (e.g. arctic 56 -> 64):
        # padded q rows give uniform softmax but are sliced away before wo,
        # so the math is exact while the hot compute head-shards cleanly.
        h_eff = max(head_pad_to, n_heads) if (head_tp or head_pad_to) \
            else n_heads
        head_ax = "heads" if (head_tp or head_pad_to) else None
        kf = _repeat_kv(k, n_heads)
        vf = _repeat_kv(v, n_heads)
        if h_eff > n_heads:
            pad = [(0, 0), (0, 0), (0, h_eff - n_heads), (0, 0)]
            q = jnp.pad(q, pad)
            kf = jnp.pad(kf, pad)
            vf = jnp.pad(vf, pad)
        q = constrain(q, ("batch", None, head_ax, None), rules)
        kf = constrain(kf, ("batch", None, head_ax, None), rules)
        vf = constrain(vf, ("batch", None, head_ax, None), rules)
        if block_q and s % block_q == 0 and s > block_q:
            nb = s // block_q
            q_blocks = q.reshape(b, nb, block_q, h_eff, d_head)
            pos = jnp.arange(s).reshape(nb, block_q)

            def body(_, inp):
                qb, pb = inp
                ob = _attend_block(qb, kf, vf, scale, pb, causal, mask, x.dtype)
                return None, ob

            _, out = jax.lax.scan(
                body, None, (q_blocks.swapaxes(0, 1), pos))
            out = out.swapaxes(0, 1).reshape(b, s, h_eff, d_head)
        else:
            out = _attend_block(q, kf, vf, scale, jnp.arange(s), causal,
                                mask, x.dtype)
        out = constrain(out, ("batch", None, head_ax, None), rules)
        if h_eff > n_heads:
            out = out[:, :, :n_heads, :]
        new_cache = None

    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    out = constrain(out, ("batch", "seq", "d_model"), rules)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeLU)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, gated: bool = True, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    s_in, s_out = d_model ** -0.5, d_ff ** -0.5
    p = {"w_in": jax.random.normal(ks[0], (d_model, d_ff), dtype) * s_in,
         "w_out": jax.random.normal(ks[1], (d_ff, d_model), dtype) * s_out}
    if gated:
        p["w_gate"] = jax.random.normal(ks[2], (d_model, d_ff), dtype) * s_in
    return p


def mlp_logical(gated: bool = True):
    p = {"w_in": ("fsdp", "d_ff"), "w_out": ("d_ff", "fsdp")}
    if gated:
        p["w_gate"] = ("fsdp", "d_ff")
    return p


def mlp(params, x, rules=None):
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"])
    if "w_gate" in params:
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, ("batch", None, "d_ff"), rules)
    out = jnp.einsum("bsf,fd->bsd", h, params["w_out"])
    return constrain(out, ("batch", "seq", "d_model"), rules)


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k routing, sort-based capacity dispatch)
# ---------------------------------------------------------------------------

def init_moe(key, d_model: int, d_ff: int, n_experts: int, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    s_in, s_out = d_model ** -0.5, d_ff ** -0.5
    return {
        "router": jax.random.normal(ks[0], (d_model, n_experts), jnp.float32) * s_in,
        "w_in": jax.random.normal(ks[1], (n_experts, d_model, d_ff), dtype) * s_in,
        "w_gate": jax.random.normal(ks[2], (n_experts, d_model, d_ff), dtype) * s_in,
        "w_out": jax.random.normal(ks[3], (n_experts, d_ff, d_model), dtype) * s_out,
    }


def moe_logical():
    # experts own the 'model' axis (EP); the FSDP ('data') axis shards the
    # d_model dim — a second use of 'model' (e.g. on d_ff) would double-map.
    return {
        "router": ("fsdp", None),
        "w_in": ("experts", "fsdp", None),
        "w_gate": ("experts", "fsdp", None),
        "w_out": ("experts", None, "fsdp"),
    }


def _moe_dispatch(xt, router, top_k, capacity, e):
    """Sort-based capacity dispatch for one token group.

    xt [T, Dm] -> (buf [E, cap, Dm], combine info).  Tokens beyond an
    expert's capacity are dropped (standard capacity-bounded MoE).
    """
    t, dm = xt.shape
    logits = (xt.astype(jnp.float32) @ router)                    # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, top_k)                # [T, k]
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    # aux load-balancing loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], e), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux_loss = jnp.sum(density * density_proxy) * e

    flat_e = gate_idx.reshape(-1)                                  # [T*k]
    flat_t = jnp.repeat(jnp.arange(t), top_k)
    flat_w = gate_w.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    seg_start = jnp.searchsorted(se, jnp.arange(e))                # [E]
    pos = jnp.arange(t * top_k) - seg_start[se]
    keep = pos < capacity
    slot = se * capacity + jnp.where(keep, pos, 0)

    buf = jnp.zeros((e * capacity, dm), xt.dtype)
    src = jnp.where(keep, st, t)  # t == out-of-range sentinel
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, dm), xt.dtype)], axis=0)
    buf = buf.at[slot].set(jnp.where(keep[:, None], xt_pad[src], 0.0),
                           mode="drop")
    return buf.reshape(e, capacity, dm), (slot, src, sw, keep), aux_loss


def _moe_combine(out_buf, info, t, dm, dtype):
    slot, src, sw, keep = info
    flat = out_buf.reshape(-1, dm)
    gathered = (flat[slot] * (sw * keep)[:, None]).astype(dtype)
    out = jnp.zeros((t + 1, dm), dtype).at[src].add(gathered, mode="drop")
    return out[:t]


def _moe_experts(params, buf, rules):
    h_in = jnp.einsum("ecd,edf->ecf", buf, params["w_in"])
    h_gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    h = jax.nn.silu(h_gate) * h_in
    h = constrain(h, ("experts", None, None), rules)
    return jnp.einsum("ecf,efd->ecd", h, params["w_out"])


def moe(params, x, *, top_k: int, capacity_factor: float = 1.25, rules=None,
        dp_groups: int = 1):
    """Top-k MoE with sort-based, fixed-capacity dispatch (drops overflow).

    x: [B, S, Dm].  Expert weights are [E, ...] sharded over 'experts'
    (model axis).

    dp_groups=1: flat dispatch — a single global scatter into the expert
    buffer.  Correct, but under GSPMD the token->expert scatter crosses the
    (batch -> experts) sharding boundary and materializes the buffer by
    all-reduce (measured ~338 GB/device/layer on dbrx train_4k).

    dp_groups=DP: hierarchical dispatch (the §Perf hillclimb): tokens are
    dispatched *within* each data-parallel group into per-group expert
    buffers [G, E, cap/G, Dm]; the single [G, E] -> [E, G] transpose is the
    classic MoE all-to-all, moving only the routed tokens (the theoretical
    minimum payload).  Per-group capacity = global capacity / G, the
    standard local-capacity semantics of production MoE systems.
    Returns (out, aux_loss).
    """
    b, s, dm = x.shape
    e = params["router"].shape[-1]
    t = b * s

    if dp_groups <= 1:
        xt = x.reshape(t, dm)
        capacity = int(capacity_factor * t * top_k / e) + 1
        buf, info, aux = _moe_dispatch(xt, params["router"], top_k,
                                       capacity, e)
        buf = constrain(buf, ("experts", None, "d_model"), rules)
        out_buf = _moe_experts(params, buf, rules)
        out = _moe_combine(out_buf, info, t, dm, x.dtype)
        out = out.reshape(b, s, dm)
        return constrain(out, ("batch", "seq", "d_model"), rules), aux

    g = dp_groups
    t_g = t // g
    cap_g = int(capacity_factor * t_g * top_k / e) + 1
    xg = x.reshape(g, t_g, dm)
    xg = constrain(xg, ("batch", None, "d_model"), rules)

    bufs, infos, auxs = jax.vmap(
        lambda xt: _moe_dispatch(xt, params["router"], top_k, cap_g, e)
    )(xg)                                             # [G, E, cap_g, Dm]
    # 2-D parallel expert compute: groups stay data-sharded, experts take
    # the model axis — each device computes its (expert-slice x group-slice)
    # block; no buffer ever crosses the data axis.
    buf = bufs.transpose(1, 0, 2, 3)                  # [E, G, cap_g, Dm]
    buf = constrain(buf, ("experts", "batch", None, None), rules)
    h_in = jnp.einsum("egcd,edf->egcf", buf, params["w_in"])
    h_gate = jnp.einsum("egcd,edf->egcf", buf, params["w_gate"])
    h = jax.nn.silu(h_gate) * h_in
    h = constrain(h, ("experts", "batch", None, None), rules)
    out_buf = jnp.einsum("egcf,efd->egcd", h, params["w_out"])
    out_g = out_buf.transpose(1, 0, 2, 3)             # [G, E, cap_g, Dm]
    out_g = constrain(out_g, ("batch", None, None, None), rules)
    out = jax.vmap(lambda ob, info: _moe_combine(ob, info, t_g, dm,
                                                 x.dtype))(out_g, infos)
    out = out.reshape(b, s, dm)
    return constrain(out, ("batch", "seq", "d_model"), rules), jnp.mean(auxs)
