"""Decoder-only LM transformer family (dense + MoE, GQA, RoPE, SwiGLU).

Covers the five assigned LM architectures:
  arctic-480b   (MoE 128e top-2 + dense residual)
  dbrx-132b     (MoE 16e top-4)
  starcoder2-7b (dense, GQA kv=4)
  phi3-medium   (dense, GQA kv=10)
  chatglm3-6b   (dense, GQA kv=2, 2D-RoPE on half dims)

Functional API:
  init_params / params_logical            parameters + logical sharding axes
  forward(params, tokens)                 logits (train / prefill)
  loss_fn                                 next-token CE
  init_kv_cache / decode_step             single-token serving with KV cache
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Mapping

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.utils import constrain, fold_rng


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 128
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0     # chatglm3 uses 0.5 (2D RoPE)
    gated_mlp: bool = True         # SwiGLU
    moe_experts: int = 0           # 0 => dense FFN
    moe_top_k: int = 2
    moe_dense_residual: bool = False   # arctic: dense MLP in parallel w/ MoE
    moe_dp_groups: int = 1         # hierarchical dispatch groups (see §Perf)
    capacity_factor: float = 1.25
    norm_eps: float = 1e-5
    param_dtype: Any = jnp.float32
    head_tp: bool = True           # shard attention WEIGHTS by head
    head_pad_to: int = 0           # pad activation heads to a TP-divisible
                                   # count when n_heads % tp != 0
    attn_block_q: int = 0          # q-block scan size (long prefill)
    remat: bool = True
    # 'full' recomputes everything in bwd; 'dots' saves matmul/collective
    # outputs (jax checkpoint_policies) — §Perf iteration 3
    remat_policy: str = "full"
    # scan unroll factor; dryrun's roofline probes use fully-unrolled 1/2
    # layer variants (XLA cost analysis counts a while body once)
    scan_unroll: int = 1

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        attn = d * self.d_head * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.is_moe:
            ff = self.moe_experts * 3 * d * f + d * self.moe_experts
            if self.moe_dense_residual:
                ff += 3 * d * f
        else:
            ff = (3 if self.gated_mlp else 2) * d * f
        return self.n_layers * (attn + ff + 2 * d) + 2 * v * d + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        attn = d * self.d_head * (self.n_heads * 2 + self.n_kv_heads * 2)
        ff = self.moe_top_k * 3 * d * f + d * self.moe_experts
        if self.moe_dense_residual:
            ff += 3 * d * f
        return self.n_layers * (attn + ff + 2 * d) + 2 * self.vocab_size * d + d


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _init_layer(cfg: TransformerConfig, key) -> dict:
    ka, km, kd = jax.random.split(key, 3)
    p = {
        "attn_norm": L.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "mlp_norm": L.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "attn": L.init_attention(ka, cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.d_head, cfg.param_dtype),
    }
    if cfg.is_moe:
        p["moe"] = L.init_moe(km, cfg.d_model, cfg.d_ff, cfg.moe_experts,
                              cfg.param_dtype)
        if cfg.moe_dense_residual:
            p["mlp"] = L.init_mlp(kd, cfg.d_model, cfg.d_ff, cfg.gated_mlp,
                                  cfg.param_dtype)
    else:
        p["mlp"] = L.init_mlp(km, cfg.d_model, cfg.d_ff, cfg.gated_mlp,
                              cfg.param_dtype)
    return p


def init_params(cfg: TransformerConfig, key) -> dict:
    ke, ko, kl = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    # stacked per-layer params (leading dim = n_layers) for lax.scan
    stacked = jax.vmap(functools.partial(_init_layer, cfg))(layer_keys)
    return {
        "embed": jax.random.normal(
            ke, (cfg.vocab_size, cfg.d_model), cfg.param_dtype)
            * cfg.d_model ** -0.5,
        "unembed": jax.random.normal(
            ko, (cfg.d_model, cfg.vocab_size), cfg.param_dtype)
            * cfg.d_model ** -0.5,
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "layers": stacked,
    }


def params_logical(cfg: TransformerConfig) -> dict:
    layer = {
        "attn_norm": L.rmsnorm_logical(),
        "mlp_norm": L.rmsnorm_logical(),
        "attn": L.attention_logical(cfg.head_tp),
    }
    if cfg.is_moe:
        layer["moe"] = L.moe_logical()
        if cfg.moe_dense_residual:
            layer["mlp"] = L.mlp_logical(cfg.gated_mlp)
    else:
        layer["mlp"] = L.mlp_logical(cfg.gated_mlp)
    # prepend the stacked layer dim (never sharded)
    layer = jax.tree.map(
        lambda lg: (None,) + lg, layer,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, str) or e is None for e in x))
    return {
        # embed: rows replicated, d_model FSDP'd — a vocab-sharded table
        # makes the token gather all-gather the whole table (§Perf iter 2)
        "embed": (None, "fsdp"),
        "unembed": ("fsdp", "vocab"),
        "final_norm": L.rmsnorm_logical(),
        "layers": layer,
    }


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _layer_fn(cfg: TransformerConfig, rules, x, positions, lp, mask=None):
    h, _ = L.attention(lp["attn"], L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps),
                       positions, causal=True, rope_theta=cfg.rope_theta,
                       rope_fraction=cfg.rope_fraction, rules=rules,
                       head_tp=cfg.head_tp, mask=mask,
                       block_q=cfg.attn_block_q, head_pad_to=cfg.head_pad_to)
    x = x + h
    hn = L.rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe:
        h, aux = L.moe(lp["moe"], hn, top_k=cfg.moe_top_k,
                       capacity_factor=cfg.capacity_factor, rules=rules,
                       dp_groups=cfg.moe_dp_groups)
        if cfg.moe_dense_residual:
            h = h + L.mlp(lp["mlp"], hn, rules=rules)
    else:
        h = L.mlp(lp["mlp"], hn, rules=rules)
    return x + h, aux


def forward_hidden(params, tokens, cfg: TransformerConfig, rules=None,
                   compute_dtype=jnp.bfloat16):
    """tokens: [B, S] int32 -> final-norm hidden states [B, S, D]."""
    b, s = tokens.shape
    emb = params["embed"].astype(compute_dtype)
    x = emb[tokens]                                   # vocab-sharded gather
    x = constrain(x, ("batch", "seq", "d_model"), rules)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(carry, lp):
        x, aux = carry
        lp = jax.tree.map(lambda a: a.astype(compute_dtype)
                          if jnp.issubdtype(a.dtype, jnp.floating) else a, lp)
        x, a = _layer_fn(cfg, rules, x, positions, lp)
        return (x, aux + a), None

    if cfg.remat and cfg.remat_policy == "dots":
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif cfg.remat:
        body_fn = jax.checkpoint(body)
    else:
        body_fn = body
    (x, aux), _ = jax.lax.scan(body_fn, (x.astype(compute_dtype),
                                         jnp.zeros((), jnp.float32)),
                               params["layers"], unroll=cfg.scan_unroll)
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def forward(params, tokens, cfg: TransformerConfig, rules=None,
            compute_dtype=jnp.bfloat16):
    """tokens: [B, S] int32 -> logits [B, S, V] (compute dtype)."""
    x, aux = forward_hidden(params, tokens, cfg, rules, compute_dtype)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["unembed"].astype(compute_dtype))
    logits = constrain(logits, ("batch", None, "vocab"), rules)
    return logits, aux


def loss_fn(params, batch, cfg: TransformerConfig, rules=None,
            compute_dtype=jnp.bfloat16, aux_weight: float = 0.01):
    """Next-token cross-entropy. batch = {tokens [B,S], labels [B,S]}."""
    logits, aux = forward(params, batch["tokens"], cfg, rules, compute_dtype)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][..., None],
                               axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: TransformerConfig, batch: int, max_seq: int,
                  dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_logical(max_seq: int):
    kv_ax = "kv_seq_long" if max_seq >= 2 ** 18 else "kv_seq"
    return {"k": (None, "batch", kv_ax, "kv_heads", None),
            "v": (None, "batch", kv_ax, "kv_heads", None)}


def decode_step(params, cache, tokens, cache_index, cfg: TransformerConfig,
                rules=None, compute_dtype=jnp.bfloat16):
    """One serving step: tokens [B] int32, cache_index scalar int32.

    Returns (logits [B, V], new_cache).  Attention over the cache uses
    flash-decoding-style sharding: the KV seq dim is sharded over the model
    (and data, for 500k contexts) mesh axes; GSPMD turns the softmax
    normalization into a small cross-shard reduction.
    """
    b = tokens.shape[0]
    emb = params["embed"].astype(compute_dtype)
    x = emb[tokens][:, None, :]                       # [B,1,Dm]
    x = constrain(x, ("batch", None, "d_model"), rules)
    positions = jnp.broadcast_to(cache_index, (b, 1)).astype(jnp.int32)

    def body(carry, inputs):
        x = carry
        lp, ck, cv = inputs
        lp = jax.tree.map(lambda a: a.astype(compute_dtype)
                          if jnp.issubdtype(a.dtype, jnp.floating) else a, lp)
        h, (nk, nv) = L.attention(
            lp["attn"], L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps),
            positions, causal=True, rope_theta=cfg.rope_theta,
            rope_fraction=cfg.rope_fraction, rules=rules, head_tp=cfg.head_tp,
            kv_cache=(ck, cv), cache_index=cache_index)
        x = x + h
        hn = L.rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
        if cfg.is_moe:
            h, _ = L.moe(lp["moe"], hn, top_k=cfg.moe_top_k,
                         capacity_factor=cfg.capacity_factor, rules=rules)
            # (decode: tiny token counts — flat dispatch is fine)
            if cfg.moe_dense_residual:
                h = h + L.mlp(lp["mlp"], hn, rules=rules)
        else:
            h = L.mlp(lp["mlp"], hn, rules=rules)
        return x + h, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x.astype(compute_dtype),
        (params["layers"], cache["k"], cache["v"]), unroll=cfg.scan_unroll)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["unembed"].astype(compute_dtype))[:, 0]
    logits = constrain(logits, ("batch", "vocab"), rules)
    return logits, {"k": nk, "v": nv}


def prefill(params, tokens, cfg: TransformerConfig, rules=None,
            compute_dtype=jnp.bfloat16):
    """Prefill pass returning last-position logits (TTFT path).

    §Perf: the unembed matmul runs on the LAST position only — at 32k
    context the full-sequence unembed would be >half the prefill FLOPs and
    all of its output discarded."""
    x, _ = forward_hidden(params, tokens, cfg, rules, compute_dtype)
    logits = jnp.einsum("bd,dv->bv", x[:, -1],
                        params["unembed"].astype(compute_dtype))
    return constrain(logits, ("batch", "vocab"), rules)
