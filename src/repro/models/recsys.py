"""RecSys model family: DLRM-RM2, DeepFM, AutoInt, BERT4Rec.

The shared substrate is :func:`embedding_bag` — JAX has no nn.EmbeddingBag, so
lookups are built from ``jnp.take`` + ``jax.ops.segment_sum`` (multi-hot) over
one *concatenated* embedding table whose row dim shards over the ``model``
mesh axis (classic DLRM model parallelism).  Dense MLPs are data-parallel.

Shapes (per the assignment):
  train_batch    batch=65536          training (logloss)
  serve_p99      batch=512            online inference
  serve_bulk     batch=262144         offline scoring
  retrieval_cand batch=1, 1M cands    two-tower scoring via the ENNS path
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.utils import constrain

# Criteo Kaggle per-field vocabulary sizes (26 categorical fields), the
# standard DLRM benchmark tables [arXiv:1906.00091].
CRITEO_VOCABS = (1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145,
                 5683, 8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4,
                 7046547, 18, 15, 286181, 105, 142572)


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                      # dlrm | deepfm | autoint | bert4rec
    vocab_sizes: tuple[int, ...]   # per sparse field
    embed_dim: int
    n_dense: int = 0
    bot_mlp: tuple[int, ...] = ()
    top_mlp: tuple[int, ...] = ()
    mlp: tuple[int, ...] = ()
    # autoint
    n_attn_layers: int = 0
    n_heads: int = 0
    d_attn: int = 0
    # bert4rec
    n_blocks: int = 0
    seq_len: int = 0
    param_dtype: Any = jnp.float32

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    @property
    def total_vocab(self) -> int:
        return int(sum(self.vocab_sizes))

    def field_offsets(self) -> jnp.ndarray:
        import numpy as np
        return jnp.asarray(np.concatenate([[0], np.cumsum(self.vocab_sizes)[:-1]]),
                           jnp.int32)

    def param_count(self) -> int:
        n = self.total_vocab * self.embed_dim
        dims_chain = []
        if self.kind == "dlrm":
            dims_chain += [(self.n_dense,) + self.bot_mlp]
            n_inter = (self.n_sparse + 1) * self.n_sparse // 2
            dims_chain += [(n_inter + self.bot_mlp[-1],) + self.top_mlp]
        elif self.kind == "deepfm":
            dims_chain += [(self.n_sparse * self.embed_dim,) + self.mlp + (1,)]
            n += self.total_vocab  # first-order weights
        elif self.kind == "autoint":
            per = self.embed_dim * self.d_attn * self.n_heads * 3 \
                + self.d_attn * self.n_heads * self.embed_dim
            n += self.n_attn_layers * per
            n += self.n_sparse * self.embed_dim  # final logit proj
        elif self.kind == "bert4rec":
            d = self.embed_dim
            per = 4 * d * d + 8 * d * d // 1  # attn + mlp(4x)
            n += self.n_blocks * per + self.seq_len * d
        for dims in dims_chain:
            for i in range(len(dims) - 1):
                n += dims[i] * dims[i + 1] + dims[i + 1]
        return n


# ---------------------------------------------------------------------------
# Embedding bag substrate
# ---------------------------------------------------------------------------

def padded_vocab(cfg: RecsysConfig) -> int:
    """Table rows rounded up so the vocab dim shards evenly (pad rows are
    never indexed: field offsets cover only the real vocabulary)."""
    return (cfg.total_vocab + 255) // 256 * 256


def init_embedding_table(cfg: RecsysConfig, key):
    return jax.random.normal(
        key, (padded_vocab(cfg), cfg.embed_dim), cfg.param_dtype) * 0.05


def embedding_lookup(table, ids, offsets):
    """Single-valued categorical lookup.

    table [V_total, D] (vocab-sharded); ids [B, F] per-field local ids;
    offsets [F] row offsets of each field in the concatenated table.
    -> [B, F, D]
    """
    flat = ids + offsets[None, :]
    return jnp.take(table, flat, axis=0)


def embedding_bag(table, ids, segment_ids, n_bags, mode="sum", weights=None):
    """Multi-hot EmbeddingBag: gather + segment-reduce.

    ids [L] global row ids, segment_ids [L] bag assignment (sorted),
    -> [n_bags, D].
    """
    vecs = jnp.take(table, ids, axis=0)
    if weights is not None:
        vecs = vecs * weights[:, None]
    out = jax.ops.segment_sum(vecs, segment_ids, num_segments=n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(ids, jnp.float32),
                                  segment_ids, num_segments=n_bags)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def _init_mlp_chain(key, dims: Sequence[int], dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": jax.random.normal(ks[i], (dims[i], dims[i + 1]), dtype)
                  * dims[i] ** -0.5,
             "b": jnp.zeros((dims[i + 1],), dtype)}
            for i in range(len(dims) - 1)]


def _mlp_chain(layers, x, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def _mlp_chain_logical(layers):
    # CTR MLPs are KB-to-MB scale: replicate (sharding a 13x512 layer over a
    # 16-way axis is impossible and pointless; the tables carry the memory)
    return [{"w": (None, None), "b": (None,)} for _ in layers]


# ---------------------------------------------------------------------------
# DLRM
# ---------------------------------------------------------------------------

def init_params(cfg: RecsysConfig, key) -> dict:
    ke, k1, k2, k3 = jax.random.split(key, 4)
    p: dict = {"table": init_embedding_table(cfg, ke)}
    if cfg.kind == "dlrm":
        p["bot"] = _init_mlp_chain(k1, (cfg.n_dense,) + cfg.bot_mlp,
                                   cfg.param_dtype)
        n_inter = (cfg.n_sparse + 1) * cfg.n_sparse // 2
        p["top"] = _init_mlp_chain(k2, (n_inter + cfg.bot_mlp[-1],)
                                   + cfg.top_mlp, cfg.param_dtype)
    elif cfg.kind == "deepfm":
        p["w1"] = jax.random.normal(k1, (padded_vocab(cfg),), cfg.param_dtype) * 0.01
        p["deep"] = _init_mlp_chain(
            k2, (cfg.n_sparse * cfg.embed_dim,) + cfg.mlp + (1,),
            cfg.param_dtype)
    elif cfg.kind == "autoint":
        d, da, h = cfg.embed_dim, cfg.d_attn, cfg.n_heads
        ks = jax.random.split(k1, cfg.n_attn_layers)
        p["attn"] = [
            {"wq": jax.random.normal(jax.random.fold_in(ks[i], 0),
                                     (d if i == 0 else da * h, h, da),
                                     cfg.param_dtype) * 0.05,
             "wk": jax.random.normal(jax.random.fold_in(ks[i], 1),
                                     (d if i == 0 else da * h, h, da),
                                     cfg.param_dtype) * 0.05,
             "wv": jax.random.normal(jax.random.fold_in(ks[i], 2),
                                     (d if i == 0 else da * h, h, da),
                                     cfg.param_dtype) * 0.05,
             "wres": jax.random.normal(jax.random.fold_in(ks[i], 3),
                                       (d if i == 0 else da * h, h * da),
                                       cfg.param_dtype) * 0.05}
            for i in range(cfg.n_attn_layers)]
        p["out"] = jax.random.normal(
            k2, (cfg.n_sparse * cfg.d_attn * cfg.n_heads,), cfg.param_dtype) * 0.01
    elif cfg.kind == "bert4rec":
        from repro.models import layers as L
        d = cfg.embed_dim
        ks = jax.random.split(k1, cfg.n_blocks)
        p["pos_embed"] = jax.random.normal(
            k2, (cfg.seq_len, d), cfg.param_dtype) * 0.02
        p["blocks"] = [
            {"attn_norm": L.init_rmsnorm(d, cfg.param_dtype),
             "mlp_norm": L.init_rmsnorm(d, cfg.param_dtype),
             "attn": L.init_attention(jax.random.fold_in(ks[i], 0), d,
                                      cfg.n_heads, cfg.n_heads,
                                      d // cfg.n_heads, cfg.param_dtype),
             "mlp": L.init_mlp(jax.random.fold_in(ks[i], 1), d, 4 * d,
                               False, cfg.param_dtype)}
            for i in range(cfg.n_blocks)]
    return p


def params_logical(cfg: RecsysConfig) -> dict:
    from repro.models import layers as L
    p: dict = {"table": ("emb_vocab", None)}
    if cfg.kind == "dlrm":
        p["bot"] = _mlp_chain_logical(range(len(cfg.bot_mlp)))
        p["top"] = _mlp_chain_logical(range(len(cfg.top_mlp)))
    elif cfg.kind == "deepfm":
        p["w1"] = ("emb_vocab",)
        p["deep"] = _mlp_chain_logical(range(len(cfg.mlp) + 1))
    elif cfg.kind == "autoint":
        p["attn"] = [{"wq": (None, None, None), "wk": (None, None, None),
                      "wv": (None, None, None), "wres": (None, None)}
                     for _ in range(cfg.n_attn_layers)]
        p["out"] = (None,)
    elif cfg.kind == "bert4rec":
        p["pos_embed"] = (None, None)
        p["blocks"] = [
            {"attn_norm": L.rmsnorm_logical(),
             "mlp_norm": L.rmsnorm_logical(),
             "attn": L.attention_logical(False),
             "mlp": L.mlp_logical(False)}
            for _ in range(cfg.n_blocks)]
    return p


def _dot_interaction(vecs):
    """DLRM dot interaction: [B, F, D] -> strictly-upper-tri dots [B, F(F-1)/2]."""
    b, f, d = vecs.shape
    z = jnp.einsum("bfd,bgd->bfg", vecs, vecs)
    iu, ju = jnp.triu_indices(f, k=1)
    return z[:, iu, ju]


def forward(params, batch, cfg: RecsysConfig, rules=None,
            compute_dtype=jnp.float32):
    """CTR kinds -> logits [B]; bert4rec -> logits [B, S, V_items]."""
    table = params["table"].astype(compute_dtype)
    offsets = cfg.field_offsets()

    if cfg.kind == "bert4rec":
        items = batch["items"]                       # [B, S] local item ids
        x = jnp.take(table, items, axis=0) + params["pos_embed"].astype(
            compute_dtype)[None, :items.shape[1]]
        x = constrain(x, ("batch", "seq", None), rules)
        from repro.models import layers as L
        pos = jnp.broadcast_to(jnp.arange(items.shape[1])[None], items.shape)
        for blk in params["blocks"]:
            blk = jax.tree.map(lambda a: a.astype(compute_dtype), blk)
            h, _ = L.attention(blk["attn"],
                               L.rmsnorm(blk["attn_norm"], x), pos,
                               causal=False, rope_theta=10000.0,
                               rope_fraction=0.0, rules=rules, head_tp=False,
                               mask=batch.get("mask"))
            x = x + h
            x = x + L.mlp(blk["mlp"], L.rmsnorm(blk["mlp_norm"], x), rules)
        logits = jnp.einsum("bsd,vd->bsv", x, table)
        logits = constrain(logits, ("batch", None, "emb_vocab"), rules)
        if table.shape[0] > cfg.total_vocab:   # drop pad-row logits
            logits = logits[..., :cfg.total_vocab]
        return logits

    ids = batch["sparse_ids"]                        # [B, F]
    vecs = embedding_lookup(table, ids, offsets)     # [B, F, D]
    vecs = constrain(vecs, ("batch", None, None), rules)

    if cfg.kind == "dlrm":
        dense = batch["dense"].astype(compute_dtype)         # [B, 13]
        bot = _mlp_chain([jax.tree.map(lambda a: a.astype(compute_dtype), l)
                          for l in params["bot"]], dense, final_act=True)
        allv = jnp.concatenate([bot[:, None, :], vecs], axis=1)
        inter = _dot_interaction(allv)
        feat = jnp.concatenate([inter, bot], axis=-1)
        logit = _mlp_chain([jax.tree.map(lambda a: a.astype(compute_dtype), l)
                            for l in params["top"]], feat)[:, 0]
    elif cfg.kind == "deepfm":
        flat_ids = ids + offsets[None, :]
        first = jnp.sum(jnp.take(params["w1"].astype(compute_dtype),
                                 flat_ids, axis=0), axis=-1)
        sum_v = jnp.sum(vecs, axis=1)
        fm = 0.5 * jnp.sum(sum_v ** 2 - jnp.sum(vecs ** 2, axis=1), axis=-1)
        deep = _mlp_chain([jax.tree.map(lambda a: a.astype(compute_dtype), l)
                           for l in params["deep"]],
                          vecs.reshape(vecs.shape[0], -1))[:, 0]
        logit = first + fm + deep
    elif cfg.kind == "autoint":
        x = vecs                                      # [B, F, D]
        for lp in params["attn"]:
            lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp)
            q = jnp.einsum("bfd,dhk->bfhk", x, lp["wq"])
            k = jnp.einsum("bfd,dhk->bfhk", x, lp["wk"])
            v = jnp.einsum("bfd,dhk->bfhk", x, lp["wv"])
            scores = jnp.einsum("bfhk,bghk->bhfg", q, k) / jnp.sqrt(
                jnp.asarray(q.shape[-1], compute_dtype))
            probs = jax.nn.softmax(scores, axis=-1)
            o = jnp.einsum("bhfg,bghk->bfhk", probs, v)
            o = o.reshape(*o.shape[:2], -1)            # [B, F, H*Da]
            res = jnp.einsum("bfd,dk->bfk", x, lp["wres"])
            x = jax.nn.relu(o + res)
        logit = x.reshape(x.shape[0], -1) @ params["out"].astype(compute_dtype)
    else:
        raise ValueError(cfg.kind)
    return constrain(logit, ("batch",), rules)


def loss_fn(params, batch, cfg: RecsysConfig, rules=None,
            compute_dtype=jnp.float32):
    out = forward(params, batch, cfg, rules, compute_dtype)
    if cfg.kind == "bert4rec":
        logits = out.astype(jnp.float32)
        labels, lmask = batch["labels"], batch["label_mask"].astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        loss = jnp.sum((logz - gold) * lmask) / jnp.maximum(jnp.sum(lmask), 1)
    else:
        logit = out.astype(jnp.float32)
        y = batch["labels"].astype(jnp.float32)
        loss = jnp.mean(jnp.maximum(logit, 0) - logit * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logit))))
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# Retrieval scoring (retrieval_cand shape): two-tower over 1M candidates
# ---------------------------------------------------------------------------

def retrieval_score(params, batch, cfg: RecsysConfig, rules=None,
                    compute_dtype=jnp.float32, top_k: int = 100):
    """Score query vs. n_candidates item embeddings; returns top-k.

    batch = {query [B, D], candidates [C, D]} — the candidate matrix shards
    over the ``corpus`` axes, reusing the ENNS sharded top-k path.
    """
    q = batch["query"].astype(compute_dtype)
    cands = batch["candidates"].astype(compute_dtype)
    cands = constrain(cands, ("corpus", None), rules)
    scores = q @ cands.T                              # [B, C]
    # batch is 1 (one user); the candidate axis takes (data x model)
    scores = constrain(scores, (None, "corpus"), rules)
    vals, idx = jax.lax.top_k(scores, top_k)
    return vals, idx
