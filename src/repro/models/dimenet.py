"""DimeNet (Directional Message Passing, arXiv:2003.03123) in pure JAX.

TPU-native adaptation notes (see DESIGN.md):
  * Message passing is expressed with ``jax.ops.segment_sum`` over fixed-shape
    padded edge / triplet index lists (JAX has no sparse CSR; segment ops ARE
    the TPU message-passing substrate).
  * Triplets (k->j->i) are capped per edge by the data pipeline so the triplet
    tensor has a static shape even on power-law graphs (ogbn-products).
  * Spherical Bessel radial/angular bases are computed with the closed-form
    upward recurrence j_{l+1}(x) = (2l+1)/x * j_l(x) - j_{l-1}(x).
  * For non-geometric graphs (Cora / Reddit / ogbn-products) the pipeline
    synthesizes 3-D positions; the node-feature projection carries the real
    signal and DimeNet's directional blocks act as a learned graph filter.

Inputs (all fixed-shape, masked):
  x          [N, d_feat]   node features
  pos        [N, 3]        node positions
  edge_src   [E] int32     j  (message source)
  edge_dst   [E] int32     i  (message target)
  edge_mask  [E] bool
  tri_edge_in  [T] int32   index of edge (k->j)
  tri_edge_out [T] int32   index of edge (j->i)
  tri_mask   [T] bool
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.utils import constrain, fold_rng


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    d_feat: int = 128          # input node-feature dim
    n_targets: int = 1         # regression targets or classes
    cutoff: float = 5.0
    param_dtype: Any = jnp.float32
    task: str = "regression"   # or "classification"
    scan_unroll: int = 1       # roofline probes use unrolled variants

    def param_count(self) -> int:
        import math
        d, nb = self.d_hidden, self.n_bilinear
        emb = self.d_feat * d + self.n_radial * d + 3 * d * d
        per_block = (2 * d * d                       # msg in/out proj
                     + self.n_spherical * self.n_radial * nb   # sbf proj
                     + nb * d * d                    # bilinear tensor
                     + 2 * d * d                     # update MLP
                     + d * d + d * self.n_targets)   # output block
        return emb + self.n_blocks * per_block


# ---------------------------------------------------------------------------
# Basis functions
# ---------------------------------------------------------------------------

def bessel_rbf(d: jax.Array, n_radial: int, cutoff: float) -> jax.Array:
    """Radial Bessel basis: sin(n pi d/c) / d, n = 1..n_radial.  [..., R]."""
    d = jnp.maximum(d, 1e-6)
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    x = d[..., None] / cutoff
    return jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * x) / d[..., None]


def spherical_bessel(x: jax.Array, l_max: int) -> jax.Array:
    """j_l(x) for l = 0..l_max-1 via upward recurrence.  [..., L]."""
    x = jnp.maximum(x, 1e-4)
    j0 = jnp.sin(x) / x
    if l_max == 1:
        return j0[..., None]
    j1 = jnp.sin(x) / x ** 2 - jnp.cos(x) / x
    js = [j0, j1]
    for l in range(1, l_max - 1):
        js.append((2 * l + 1) / x * js[-1] - js[-2])
    return jnp.stack(js, axis=-1)


def legendre(cos_t: jax.Array, l_max: int) -> jax.Array:
    """P_l(cos) for l = 0..l_max-1 via Bonnet recurrence.  [..., L]."""
    p0 = jnp.ones_like(cos_t)
    if l_max == 1:
        return p0[..., None]
    ps = [p0, cos_t]
    for l in range(1, l_max - 1):
        ps.append(((2 * l + 1) * cos_t * ps[-1] - l * ps[-2]) / (l + 1))
    return jnp.stack(ps, axis=-1)


def sbf_basis(d_kj: jax.Array, angle_cos: jax.Array, n_spherical: int,
              n_radial: int, cutoff: float) -> jax.Array:
    """2-D spherical Fourier-Bessel basis.  [T, n_spherical * n_radial]."""
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    x = (d_kj[..., None] / cutoff) * n * jnp.pi            # [T, R]
    jl = spherical_bessel(x.reshape(-1), n_spherical)       # [T*R, L]
    jl = jl.reshape(*x.shape, n_spherical)                  # [T, R, L]
    pl = legendre(angle_cos, n_spherical)                   # [T, L]
    out = jl * pl[..., None, :]                             # [T, R, L]
    return out.reshape(*d_kj.shape, n_radial * n_spherical)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _dense(key, d_in, d_out, dtype):
    return jax.random.normal(key, (d_in, d_out), dtype) * d_in ** -0.5


def init_params(cfg: DimeNetConfig, key) -> dict:
    ks = iter(jax.random.split(key, 8 + cfg.n_blocks * 8))
    d = cfg.d_hidden
    p = {
        "feat_proj": _dense(next(ks), cfg.d_feat, d, cfg.param_dtype),
        "rbf_proj": _dense(next(ks), cfg.n_radial, d, cfg.param_dtype),
        "msg_init": _dense(next(ks), 3 * d, d, cfg.param_dtype),
        "blocks": [],
    }
    blocks = []
    for _ in range(cfg.n_blocks):
        blocks.append({
            "w_msg": _dense(next(ks), d, d, cfg.param_dtype),
            "w_sbf": _dense(next(ks), cfg.n_spherical * cfg.n_radial,
                            cfg.n_bilinear, cfg.param_dtype),
            "w_bil": jax.random.normal(
                next(ks), (cfg.n_bilinear, d, d), cfg.param_dtype) / d,
            "w_upd1": _dense(next(ks), d, d, cfg.param_dtype),
            "w_upd2": _dense(next(ks), d, d, cfg.param_dtype),
            "w_out_edge": _dense(next(ks), d, d, cfg.param_dtype),
            "w_out": _dense(next(ks), d, cfg.n_targets, cfg.param_dtype),
        })
    # stack blocks for lax.scan
    p["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    return p


def params_logical(cfg: DimeNetConfig) -> dict:
    blk = {
        "w_msg": (None, "fsdp", "d_ff"),
        "w_sbf": (None, None, None),
        "w_bil": (None, None, "fsdp", "d_ff"),
        "w_upd1": (None, "fsdp", "d_ff"),
        "w_upd2": (None, "d_ff", "fsdp"),
        "w_out_edge": (None, "fsdp", "d_ff"),
        "w_out": (None, "fsdp", None),
    }
    return {
        "feat_proj": (None, "d_ff"),   # d_feat (e.g. 1433) not shard-divisible
        "rbf_proj": (None, "d_ff"),
        "msg_init": ("fsdp", "d_ff"),
        "blocks": blk,
    }


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def forward(params, batch, cfg: DimeNetConfig, rules=None,
            compute_dtype=jnp.float32):
    """Returns per-node outputs [N, n_targets] (sum over output blocks)."""
    x = batch["x"].astype(compute_dtype)
    pos = batch["pos"].astype(jnp.float32)
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask = batch["edge_mask"].astype(compute_dtype)
    t_in, t_out = batch["tri_edge_in"], batch["tri_edge_out"]
    tmask = batch["tri_mask"].astype(compute_dtype)
    n, e = x.shape[0], src.shape[0]

    # geometry
    vec = pos[dst] - pos[src]                               # [E,3]
    dist = jnp.linalg.norm(vec + 1e-9, axis=-1)             # [E]
    rbf = bessel_rbf(dist, cfg.n_radial, cfg.cutoff).astype(compute_dtype)
    # triplet angle between edge (k->j) and (j->i)
    v_in, v_out = -vec[t_in], vec[t_out]
    cos_a = jnp.sum(v_in * v_out, -1) / (
        jnp.linalg.norm(v_in, axis=-1) * jnp.linalg.norm(v_out, axis=-1) + 1e-9)
    sbf = sbf_basis(dist[t_in], cos_a, cfg.n_spherical, cfg.n_radial,
                    cfg.cutoff).astype(compute_dtype)        # [T, SR]

    h = x @ params["feat_proj"].astype(compute_dtype)        # [N, d]
    h = constrain(h, ("nodes", None), rules)
    r = rbf @ params["rbf_proj"].astype(compute_dtype)       # [E, d]
    m = jnp.concatenate([h[src], h[dst], r], axis=-1)
    m = jax.nn.silu(m @ params["msg_init"].astype(compute_dtype))  # [E, d]
    m = m * emask[:, None]
    m = constrain(m, ("edges", None), rules)

    def block(carry, bp):
        m, acc = carry
        bp = jax.tree.map(lambda a: a.astype(compute_dtype), bp)
        # directional message: gather m over incoming triplet edges
        m_kj = m[t_in] @ bp["w_msg"]                         # [T, d]
        s = sbf @ bp["w_sbf"]                                # [T, B]
        inter = jnp.einsum("tb,td,bdf->tf", s, m_kj, bp["w_bil"])
        inter = inter * tmask[:, None]
        agg = jax.ops.segment_sum(inter, t_out, num_segments=e)  # [E, d]
        m_new = jax.nn.silu((m + agg) @ bp["w_upd1"])
        m_new = jax.nn.silu(m_new @ bp["w_upd2"]) + m        # residual
        m_new = m_new * emask[:, None]
        m_new = constrain(m_new, ("edges", None), rules)
        # output block: edges -> nodes
        eo = jax.nn.silu(m_new @ bp["w_out_edge"]) * emask[:, None]
        node = jax.ops.segment_sum(eo, dst, num_segments=n)  # [N, d]
        acc = acc + node @ bp["w_out"]
        return (m_new, acc), None

    acc0 = jnp.zeros((n, cfg.n_targets), compute_dtype)
    (m, acc), _ = jax.lax.scan(block, (m, acc0), params["blocks"],
                               unroll=cfg.scan_unroll)
    return constrain(acc, ("nodes", None), rules)


def loss_fn(params, batch, cfg: DimeNetConfig, rules=None,
            compute_dtype=jnp.float32):
    out = forward(params, batch, cfg, rules, compute_dtype).astype(jnp.float32)
    mask = batch["node_mask"].astype(jnp.float32)
    if cfg.task == "classification":
        labels = batch["labels"]
        logz = jax.nn.logsumexp(out, axis=-1)
        gold = jnp.take_along_axis(out, labels[:, None], axis=-1)[:, 0]
        loss = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1)
    else:
        # molecule energy: graph-pooled regression via graph_ids
        gid = batch["graph_ids"]
        n_graphs = batch["targets"].shape[0]
        energy = jax.ops.segment_sum(out[:, 0] * mask, gid,
                                     num_segments=n_graphs)
        loss = jnp.mean((energy - batch["targets"]) ** 2)
    return loss, {"loss": loss}
