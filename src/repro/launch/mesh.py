"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first backend init, and tests
must see 1 CPU device while the dry-run sees 512 virtual ones).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (smoke/CI)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    n = 1
    for a in names:
        n *= mesh.shape.get(a, 1)
    return n
