"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms per (arch x shape) on the single-pod 16x16 mesh (v5e constants):

  compute    = HLO_FLOPs_per_chip / 197e12            [s]
  memory     = HLO_bytes_per_chip / 819e9             [s]
  collective = collective_bytes_per_chip / 50e9       [s]

XLA's cost analysis counts a while (lax.scan) body ONCE, so scanned models
(LM layers, DimeNet blocks) are corrected exactly from the fully-unrolled
1- and 2-layer probe lowerings:  per_layer = u2 - u1;
total = u1 + (L-1) * per_layer.  MODEL_FLOPS uses the standard analytic
counts (6·N_active·tokens for training, forward-only for serving, plus the
attention S² term), giving the useful-compute ratio that catches
remat/dispatch/padding waste.

  python -m repro.launch.roofline --dryrun results/dryrun.json
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict

PEAK_FLOPS = 197e12     # bf16 / chip (v5e)
HBM_BW = 819e9          # B/s / chip
ICI_BW = 50e9           # B/s / link

CHIPS = 256             # single-pod roofline


def _model_flops(arch: str, shape: str, cfg) -> float:
    """Analytic useful FLOPs for the whole step (all chips)."""
    from repro.configs import get_arch
    spec = get_arch(arch)
    if spec.family == "lm":
        c = spec.config
        dims = spec.shapes[shape].dims
        b, s = dims["global_batch"], dims["seq_len"]
        n_act = c.active_param_count()
        attn_fwd = 4 * b * c.n_layers * c.n_heads * c.d_head * (s ** 2) / 2
        if shape == "train_4k":
            return 6 * n_act * b * s + 3 * attn_fwd
        if shape == "prefill_32k":
            return 2 * n_act * b * s + attn_fwd
        # decode: one token against an s-long cache
        return 2 * n_act * b + 4 * b * c.n_layers * c.n_heads * c.d_head * s
    if spec.family == "gnn":
        d = spec.shapes[shape].dims
        c = spec.config
        dh, nb, blocks = c.d_hidden, c.n_bilinear, c.n_blocks
        t, e = d["n_triplets"], d["n_edges"]
        per_block = 2 * t * nb * dh * dh + 2 * t * nb * dh \
            + 4 * e * dh * dh * 2
        fwd = blocks * per_block + 2 * d["n_nodes"] * d["d_feat"] * dh
        return 3 * fwd                                   # train
    if spec.family == "recsys":
        c = spec.config
        d = spec.shapes[shape].dims
        b = d.get("batch", 1)
        lookup = b * c.n_sparse * c.embed_dim * 2
        if c.kind == "dlrm":
            mlps = sum(a * bb for a, bb in zip(
                (c.n_dense,) + c.bot_mlp[:-1], c.bot_mlp))
            n_inter = (c.n_sparse + 1) * c.n_sparse // 2
            mlps += sum(a * bb for a, bb in zip(
                (n_inter + c.bot_mlp[-1],) + c.top_mlp[:-1], c.top_mlp))
            fwd = b * mlps * 2 + b * (c.n_sparse + 1) ** 2 * c.embed_dim
        elif c.kind == "deepfm":
            mlps = sum(a * bb for a, bb in zip(
                (c.n_sparse * c.embed_dim,) + c.mlp, c.mlp + (1,)))
            fwd = b * mlps * 2 + b * c.n_sparse * c.embed_dim * 4
        elif c.kind == "autoint":
            per = c.n_sparse * (3 * c.embed_dim * c.d_attn * c.n_heads * 2
                                + 2 * c.n_sparse * c.d_attn * c.n_heads * 2)
            fwd = b * c.n_attn_layers * per
        else:  # bert4rec
            dd = c.embed_dim
            s = c.seq_len
            per = s * (12 * dd * dd) + 4 * s * s * dd
            fwd = b * (c.n_blocks * per + 2 * s * dd * c.total_vocab)
        if shape == "retrieval_cand":
            return 2 * d["n_candidates"] * c.embed_dim
        fwd += lookup
        return 3 * fwd if shape == "train_batch" else fwd
    if spec.family == "rag":
        c = spec.config
        # full f32 scan + int8 fuzzy scan + cache channel, per query batch
        return 2 * c.corpus_size * c.d * 2 * c.query_batch
    return 0.0


def analyze(records: list[dict]) -> list[dict]:
    from repro.configs import get_arch
    base = {}
    probes = defaultdict(dict)
    for r in records:
        if not r.get("ok"):
            continue
        v = r.get("variant") or {}
        key = (r["arch"], r["shape"])
        if v.get("unroll"):
            probes[key][v["n_layers"]] = r
        elif r["n_devices"] == CHIPS:
            base[key] = r

    out = []
    for (arch, shape), rec in sorted(base.items()):
        spec = get_arch(arch)
        layers = None
        if spec.family == "lm":
            layers = spec.config.n_layers
        elif spec.family == "gnn":
            layers = spec.config.n_blocks

        def corrected(field):
            raw = rec.get(field, 0.0) or 0.0
            p = probes.get((arch, shape), {})
            if layers and 1 in p and 2 in p:
                u1 = p[1].get(field, 0.0) or 0.0
                u2 = p[2].get(field, 0.0) or 0.0
                return u1 + (layers - 1) * (u2 - u1)
            return raw

        flops = corrected("flops_per_device")
        mem = corrected("bytes_per_device")
        p = probes.get((arch, shape), {})
        if layers and 1 in p and 2 in p:
            c1 = p[1]["collectives"]["total"]
            c2 = p[2]["collectives"]["total"]
            coll = c1 + (layers - 1) * (c2 - c1)
        else:
            coll = rec["collectives"]["total"]

        t_comp = flops / PEAK_FLOPS
        t_mem = mem / HBM_BW
        t_coll = coll / ICI_BW
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dominant = max(terms, key=terms.get)
        step_time = max(terms.values())
        mflops = _model_flops(arch, shape, spec.config)
        ratio = mflops / (flops * CHIPS) if flops else 0.0
        mfu = (mflops / CHIPS / step_time) / PEAK_FLOPS if step_time else 0.0
        out.append({
            "arch": arch, "shape": shape,
            "flops_per_chip": flops, "bytes_per_chip": mem,
            "coll_bytes_per_chip": coll,
            "t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll,
            "dominant": dominant,
            "model_flops_total": mflops,
            "useful_ratio": ratio,
            "roofline_frac": mfu if mflops else None,
            "corrected": bool(layers and 1 in p and 2 in p),
        })
    return out


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | useful FLOP ratio | roofline frac |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for r in rows:
        rf = f"{r['roofline_frac']:.3f}" if r["roofline_frac"] else "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} "
            f"| {r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.3f} | {rf} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun.json")
    ap.add_argument("--out", default="results/roofline")
    args = ap.parse_args()
    records = json.load(open(args.dryrun))
    rows = analyze(records)
    with open(args.out + ".json", "w") as f:
        json.dump(rows, f, indent=1)
    md = to_markdown(rows)
    with open(args.out + ".md", "w") as f:
        f.write(md + "\n")
    print(md)


if __name__ == "__main__":
    main()
