"""Serving driver: HaS speculative retrieval over a synthetic query stream.

  python -m repro.launch.serve --queries 2000 --dataset granola --tau 0.2

Full-database retrieval is pluggable (``--retrieval-backend``, see
retrieval/service.py): ``flat`` is the in-process exact scan, ``sharded``
row-shards the corpus over ``--shards`` mesh workers
(``LatencyModel.shard_scale`` speedup + ``--workers`` concurrent cloud
dispatch slots for the scheduler's worker pool), ``replica`` routes through
``--workers`` warm standbys whose delta logs are reconciled on every cache
ingest.

Multi-tenant serving: ``--tenants N`` partitions the HaS cache into N
tenant slices (core/has.py::init_tenant_states; per-tenant capacity
``--h-max`` EACH) and assigns each query a tenant drawn from a Zipf
popularity law over tenants (``--tenant-zipf A``; 0 = uniform) — the
mixed-traffic shape the partitioning isolates.  Supported by the ``has``,
``crag`` and ``sched`` engines (the baselines have no per-tenant cache
state).

``--engine sched`` runs the continuous-batching scheduler
(serving/scheduler.py) over an open-loop Poisson arrival stream
(``--qps``; omit for fully saturated admission).  Its edge speculation
stage is a REPLICA POOL (serving/edge_pool.py): ``--edge-replicas R``
cache replicas each take speculation batches concurrently, kept within
``--edge-sync-every`` ingested rows of the primary by bounded-lag delta
replay.  R == 1 is the historical single-edge scheduler bit-exactly.

SLO-aware overload control (``--engine sched`` only): ``--slo-deadline S``
reports goodput against an end-to-end latency SLO, and
``--overload-policy shed|degrade`` keeps admitted-request p99 bounded past
saturation — shed rejects at admission, degrade serves speculation-only
drafts.  The result's per-stage virtual-clock breakdown (queue wait /
replay / spec / edge RTT / reval / cloud queue / cloud / ingest / lost /
retry backoff) is printed after the summary.

Agentic multi-hop serving (``--engine sched`` only): ``--agentic-frac F``
replaces a deterministic fraction F of the stream with COMPLEX multi-hop
queries (``--hops H`` chain length, serving/agentic.py) that enter
admission as their hop-1 sub-query; the scheduler resolves the hop graph
on the virtual clock — reasoning charged to the ``reason`` span, the next
hop pre-speculated from rejected drafts, mis-speculations cancelled
deterministically — and the summary grows per-complex-query aggregates
(chain e2e latency, DAR/accuracy, pre-speculation hit rates).
``--agentic-frac 0`` leaves the stream bit-identical to a build without
the hop-graph machinery.

Chaos serving (``--engine sched`` only): ``--fault-plan SPEC`` injects a
deterministic fault schedule on the virtual clock (serving/faults.py) —
``kind@t[,key=val]*`` events separated by ``;``, e.g.
``worker_crash@2.0,target=0,down=3.0;straggler@1.0,duration=5,factor=4``.
``--retry-max N`` bounds per-batch cloud retries (exponential backoff) and
``--hedge-after FACTOR`` sets the deadline multiple after which an
unfinished cloud dispatch is hedged onto a free worker.
"""
from __future__ import annotations

import argparse
import tempfile


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--dataset", default="granola",
                    choices=["granola", "popqa", "triviaqa", "squad"])
    ap.add_argument("--engine", default="has",
                    choices=["has", "full", "proximity", "saferadius",
                             "mincache", "crag", "ivf", "scann", "sched"])
    ap.add_argument("--retrieval-backend", default="flat",
                    choices=["flat", "sharded", "replica", "ann", "hybrid"],
                    help="full-retrieval backend (retrieval/service.py): "
                         "in-process flat scan, mesh-sharded concurrent "
                         "scan, warm-standby replicas, the IVF ANN "
                         "index (approximate; nprobe-calibrated), or the "
                         "hybrid lexical+dense channel pair with fused "
                         "RRF reranking (retrieval/fusion.py)")
    ap.add_argument("--hybrid-dense", default="flat",
                    choices=["flat", "sharded", "ann"],
                    help="dense channel of --retrieval-backend hybrid")
    ap.add_argument("--rrf-k", type=float, default=None,
                    help="reciprocal-rank-fusion constant for "
                         "--retrieval-backend hybrid: per-channel mass of "
                         "rank r is 1/(rrf_k + r) (default 60)")
    ap.add_argument("--diversify-sim", type=float, default=None,
                    help="near-duplicate suppression threshold for "
                         "--retrieval-backend hybrid: a fused candidate is "
                         "dropped when its cosine similarity to an already-"
                         "selected result is >= this (default 0.98; 1.0 "
                         "disables in practice)")
    ap.add_argument("--lexical-terms", type=int, default=None,
                    help="postings-row width cap (terms kept per doc) for "
                         "--retrieval-backend hybrid (default: the world's "
                         "full term width)")
    ap.add_argument("--shards", type=int, default=4,
                    help="corpus shards for --retrieval-backend sharded")
    ap.add_argument("--workers", type=int, default=None,
                    help="concurrent cloud dispatch slots (sharded/ann) / "
                         "standby replicas (replica); default 2.  Only "
                         "meaningful with a non-flat --retrieval-backend")
    ap.add_argument("--nprobe", type=int, default=32,
                    help="IVF buckets probed per query for "
                         "--retrieval-backend ann; calibrate with "
                         "benchmarks/ann_recall.py (recall feeds the HaS "
                         "cache, so too-low nprobe compounds end-to-end)")
    ap.add_argument("--ann-clusters", type=int, default=1024,
                    help="IVF centroid count for --retrieval-backend ann "
                         "(clamped to corpus_docs/8 for tiny corpora)")
    ap.add_argument("--compressed-corpus", action="store_true",
                    help="int8 centroid-residual compressed bucket residency "
                         "for --retrieval-backend ann (~3.6x smaller scan "
                         "operand; dequant fused into the kernel)")
    ap.add_argument("--tenants", type=int, default=1,
                    help="tenant partitions of the HaS cache (--h-max "
                         "capacity EACH); queries are tagged per tenant")
    ap.add_argument("--tenant-zipf", type=float, default=1.1,
                    help="Zipf exponent of the tenant popularity law "
                         "(0 = uniform traffic across tenants)")
    ap.add_argument("--edge-replicas", type=int, default=1,
                    help="edge speculation cache replicas for --engine "
                         "sched (serving/edge_pool.py); 1 == the "
                         "historical single-edge scheduler")
    ap.add_argument("--edge-sync-every", type=int, default=None,
                    help="bounded-lag replay cadence: an edge replica this "
                         "many ingested rows behind the primary replays "
                         "its missing delta rows (default 32)")
    ap.add_argument("--qps", type=float, default=None,
                    help="open-loop Poisson arrival rate for --engine "
                         "sched (omit for fully saturated admission)")
    ap.add_argument("--agentic-frac", type=float, default=0.0,
                    help="fraction of the stream served as complex "
                         "multi-hop (Auto-RAG) queries for --engine sched "
                         "(serving/agentic.py hop graphs inside the "
                         "scheduler); 0 disables agentic traffic entirely")
    ap.add_argument("--hops", type=int, default=2,
                    help="chain length of the complex queries injected by "
                         "--agentic-frac (2 == the paper's Fig-13 shape)")
    ap.add_argument("--slo-deadline", type=float, default=None,
                    help="end-to-end latency SLO in seconds for --engine "
                         "sched (reports goodput; required by "
                         "--overload-policy)")
    ap.add_argument("--overload-policy", default="none",
                    choices=["none", "shed", "degrade"],
                    help="overload control for --engine sched: shed "
                         "rejects at admission when the predicted "
                         "completion blows --slo-deadline; degrade serves "
                         "speculation-only drafts (accept=False) under "
                         "overload")
    ap.add_argument("--fault-plan", default=None,
                    help="deterministic fault schedule for --engine sched "
                         "(serving/faults.py grammar): ';'-separated "
                         "'kind@t[,key=val]*' events, kinds "
                         "worker_crash|straggler|search_fail|replica_crash"
                         "|delta_drop|delta_dup")
    ap.add_argument("--retry-max", type=int, default=None,
                    help="max cloud retries per batch after transient "
                         "failures (exponential backoff); --engine sched "
                         "with --fault-plan only (default 2)")
    ap.add_argument("--hedge-after", type=float, default=None,
                    help="hedge an unfinished cloud dispatch after this "
                         "multiple of its expected service time; must be "
                         "> 1; --engine sched with --fault-plan only "
                         "(default 2.5)")
    ap.add_argument("--tau", type=float, default=0.2)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--h-max", type=int, default=5000)
    ap.add_argument("--entities", type=int, default=20000)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    # fail fast on invalid combinations instead of a downstream shape error
    if args.shards < 1:
        ap.error(f"--shards must be >= 1 (got {args.shards})")
    if args.workers is not None and args.workers < 1:
        ap.error(f"--workers must be >= 1 (got {args.workers})")
    if args.workers is not None and args.retrieval_backend == "flat":
        ap.error("--workers only applies to --retrieval-backend "
                 "sharded|replica|ann (the flat backend is one in-process "
                 "worker by definition)")
    if args.nprobe < 1:
        ap.error(f"--nprobe must be >= 1 (got {args.nprobe})")
    if args.ann_clusters < 1:
        ap.error(f"--ann-clusters must be >= 1 (got {args.ann_clusters})")
    if args.nprobe > args.ann_clusters:
        ap.error(f"--nprobe ({args.nprobe}) must be <= --ann-clusters "
                 f"({args.ann_clusters}): a query cannot probe more "
                 "buckets than the index has")
    if args.compressed_corpus and not (
            args.retrieval_backend == "ann"
            or (args.retrieval_backend == "hybrid"
                and args.hybrid_dense == "ann")):
        ap.error("--compressed-corpus only applies to an ANN dense stage "
                 "(--retrieval-backend ann, or hybrid with --hybrid-dense "
                 "ann); the exact backends scan the f32 corpus")
    if (args.hybrid_dense != "flat"
            and args.retrieval_backend != "hybrid"):
        ap.error("--hybrid-dense only applies to --retrieval-backend "
                 "hybrid (it selects hybrid's dense channel)")
    hybrid_flags = (("--rrf-k", args.rrf_k),
                    ("--diversify-sim", args.diversify_sim),
                    ("--lexical-terms", args.lexical_terms))
    if args.retrieval_backend != "hybrid":
        for name, val in hybrid_flags:
            if val is not None:
                ap.error(f"{name} only applies to --retrieval-backend "
                         "hybrid (the single-channel backends have no "
                         "fusion stage)")
    if args.rrf_k is not None and args.rrf_k < 1:
        ap.error(f"--rrf-k must be >= 1 (got {args.rrf_k}; rank 0 mass "
                 "1/rrf_k must stay bounded)")
    if args.diversify_sim is not None and not 0 < args.diversify_sim <= 1:
        ap.error(f"--diversify-sim must be in (0, 1] "
                 f"(got {args.diversify_sim}; cosine similarity range)")
    if args.lexical_terms is not None and args.lexical_terms < 1:
        ap.error(f"--lexical-terms must be >= 1 (got {args.lexical_terms})")
    if args.tenants < 1:
        ap.error(f"--tenants must be >= 1 (got {args.tenants})")
    if args.tenant_zipf < 0:
        ap.error(f"--tenant-zipf must be >= 0 (got {args.tenant_zipf})")
    if args.tenants > 1 and args.engine not in ("has", "crag", "sched"):
        ap.error(f"--tenants requires --engine has|crag|sched (the "
                 f"'{args.engine}' engine has no per-tenant cache state)")
    if args.edge_replicas < 1:
        ap.error(f"--edge-replicas must be >= 1 (got {args.edge_replicas})")
    if args.edge_sync_every is not None and args.edge_sync_every < 1:
        ap.error(f"--edge-sync-every must be >= 1 "
                 f"(got {args.edge_sync_every})")
    if args.edge_replicas > 1 and args.engine != "sched":
        ap.error("--edge-replicas only applies to --engine sched (the "
                 "sequential engines speculate against one cache by "
                 "definition)")
    if args.edge_sync_every is not None and args.engine != "sched":
        ap.error("--edge-sync-every only applies to --engine sched "
                 "(it paces the scheduler's edge replica pool)")
    if args.qps is not None and args.qps <= 0:
        ap.error(f"--qps must be > 0 (got {args.qps})")
    if args.qps is not None and args.engine != "sched":
        ap.error("--qps only applies to --engine sched (the other engines "
                 "serve a closed loop)")
    if not 0.0 <= args.agentic_frac <= 1.0:
        ap.error(f"--agentic-frac must be in [0, 1] "
                 f"(got {args.agentic_frac})")
    if args.hops < 1:
        ap.error(f"--hops must be >= 1 (got {args.hops}; a complex query "
                 "is a chain of at least one hop)")
    if args.agentic_frac > 0 and args.engine != "sched":
        ap.error("--agentic-frac only applies to --engine sched (the "
                 "hop-graph executor lives in the continuous-batching "
                 "scheduler; use benchmarks/fig13_agentic.py for the "
                 "sequential Auto-RAG pipeline)")
    if args.slo_deadline is not None and args.slo_deadline <= 0:
        ap.error(f"--slo-deadline must be > 0 (got {args.slo_deadline})")
    if ((args.slo_deadline is not None or args.overload_policy != "none")
            and args.engine != "sched"):
        ap.error("--slo-deadline/--overload-policy only apply to --engine "
                 "sched (the sequential engines have no admission queue "
                 "to control)")
    if args.overload_policy != "none" and args.slo_deadline is None:
        ap.error(f"--overload-policy {args.overload_policy} requires "
                 "--slo-deadline (the policy triggers on the predicted "
                 "completion blowing the deadline)")
    if args.fault_plan is not None and args.engine != "sched":
        ap.error("--fault-plan only applies to --engine sched (faults are "
                 "scheduled on the scheduler's virtual clock)")
    if args.retry_max is not None and args.retry_max < 0:
        ap.error(f"--retry-max must be >= 0 (got {args.retry_max})")
    if args.hedge_after is not None and args.hedge_after <= 1.0:
        ap.error(f"--hedge-after must be > 1 (got {args.hedge_after}; it "
                 "multiplies the expected service time, so <= 1 would "
                 "hedge every dispatch immediately)")
    if ((args.retry_max is not None or args.hedge_after is not None)
            and args.fault_plan is None):
        ap.error("--retry-max/--hedge-after require --fault-plan (the "
                 "self-healing machinery only engages under a non-empty "
                 "fault plan; a fault-free run is bit-identical without "
                 "it)")
    fault_plan = None
    if args.fault_plan is not None:
        from repro.serving.faults import FaultPlan
        try:
            fault_plan = FaultPlan.parse(args.fault_plan)
        except ValueError as e:
            ap.error(f"--fault-plan: {e}")
    workers = 2 if args.workers is None else args.workers

    import jax.numpy as jnp
    import numpy as np

    from repro.core.has import HasConfig
    from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
    from repro.retrieval.service import (LocalFlatBackend, ReplicaBackend,
                                         ShardedMeshBackend)
    from repro.serving.engine import (ANNSEngine, CRAGEngine,
                                      FullRetrievalEngine, HasEngine,
                                      ReuseEngine, RetrievalService)
    from repro.serving.latency import LatencyModel

    world = SyntheticWorld(WorldConfig(n_entities=args.entities,
                                       seed=args.seed))
    latency = LatencyModel()
    corpus = jnp.asarray(world.doc_emb)
    if args.retrieval_backend == "sharded":
        backend = ShardedMeshBackend(corpus, args.k, latency,
                                     n_shards=args.shards,
                                     n_workers=workers)
    elif args.retrieval_backend == "replica":
        from repro.checkpoint import CheckpointManager
        from repro.serving.replication import WarmStandby
        cfg0 = HasConfig(k=args.k, tau=args.tau, h_max=args.h_max,
                         nprobe=16, n_buckets=2048, d=world.cfg.d)
        standbys = [
            WarmStandby(cfg0, CheckpointManager(tempfile.mkdtemp(
                prefix=f"has-standby{i}-")), snapshot_every=10_000,
                max_lag=50_000, n_tenants=args.tenants)
            for i in range(workers)]
        backend = ReplicaBackend(
            LocalFlatBackend(corpus, args.k, latency), standbys, corpus)
    elif args.retrieval_backend == "ann":
        from repro.retrieval.service import IVFBackend
        backend = IVFBackend(corpus, args.k, latency,
                             n_clusters=args.ann_clusters,
                             nprobe=args.nprobe,
                             compressed=args.compressed_corpus,
                             n_workers=workers, seed=args.seed)
    elif args.retrieval_backend == "hybrid":
        from repro.retrieval.service import HybridBackend
        backend = HybridBackend(
            corpus, args.k, latency,
            world.doc_terms, world.doc_term_weights,
            dense=args.hybrid_dense,
            rrf_k=60.0 if args.rrf_k is None else args.rrf_k,
            diversify_sim=(0.98 if args.diversify_sim is None
                           else args.diversify_sim),
            lexical_terms=args.lexical_terms,
            n_shards=args.shards, n_workers=workers,
            ann_kwargs=(dict(n_clusters=args.ann_clusters,
                             nprobe=args.nprobe,
                             compressed=args.compressed_corpus,
                             seed=args.seed)
                        if args.hybrid_dense == "ann" else None))
    else:
        backend = None                       # RetrievalService default: flat
    svc = RetrievalService(world, latency, k=args.k, backend=backend)
    ds = DATASETS[args.dataset]
    queries = world.sample_queries(
        args.queries, pattern=ds["pattern"], zipf_a=ds["zipf_a"],
        p_uncovered=ds["p_uncovered"], seed=args.seed + 1)

    if args.tenants > 1:
        # tenant popularity ~ Zipf over tenant ranks (0 -> uniform traffic)
        ranks = np.arange(1, args.tenants + 1, dtype=np.float64)
        p = ranks ** -args.tenant_zipf
        p /= p.sum()
        trng = np.random.default_rng(args.seed + 2)
        tenant_of = trng.choice(args.tenants, size=len(queries), p=p)
        for q, t in zip(queries, tenant_of):
            q["tenant"] = int(t)

    n_agentic = 0
    if args.engine == "sched" and args.agentic_frac > 0:
        # deterministic mixed trace: a seeded draw picks which arrival
        # slots become complex queries; each keeps its slot's tenant tag
        # and enters admission as its hop-1 sub-query carrying the
        # HopPlan continuation
        from repro.serving.agentic import TwoHopDataset, build_hop_trace
        n_agentic = int(round(args.agentic_frac * len(queries)))
        if n_agentic:
            ag_ds = TwoHopDataset(world, seed=args.seed)
            cqs = ag_ds.sample(n_agentic, seed=args.seed + 4,
                               hops=args.hops)
            arng = np.random.default_rng(args.seed + 5)
            slots = np.sort(arng.choice(len(queries), n_agentic,
                                        replace=False))
            hop1 = build_hop_trace(
                ag_ds, cqs, seed=args.seed,
                tenants=[int(queries[i].get("tenant", 0)) for i in slots])
            for i, q in zip(slots, hop1):
                queries[int(i)] = q

    if args.engine == "has":
        engine = HasEngine(svc, HasConfig(
            k=args.k, tau=args.tau, h_max=args.h_max,
            nprobe=16, n_buckets=2048, d=world.cfg.d),
            n_tenants=args.tenants)
    elif args.engine == "full":
        engine = FullRetrievalEngine(svc)
    elif args.engine in ("proximity", "saferadius", "mincache"):
        engine = ReuseEngine(svc, args.engine, h_max=args.h_max)
    elif args.engine == "crag":
        engine = CRAGEngine(svc, HasConfig(
            k=args.k, tau=args.tau, h_max=args.h_max,
            nprobe=16, n_buckets=2048, d=world.cfg.d),
            n_tenants=args.tenants)
    elif args.engine == "sched":
        from repro.serving.edge_pool import DEFAULT_EDGE_SYNC_EVERY
        from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                             SchedulerConfig,
                                             poisson_arrivals)
        mk = lambda: ContinuousBatchingScheduler(
            svc, HasConfig(k=args.k, tau=args.tau, h_max=args.h_max,
                           nprobe=16, n_buckets=2048, d=world.cfg.d),
            SchedulerConfig(
                n_tenants=args.tenants, edge_replicas=args.edge_replicas,
                edge_sync_every=(DEFAULT_EDGE_SYNC_EVERY
                                 if args.edge_sync_every is None
                                 else args.edge_sync_every),
                slo_deadline_s=args.slo_deadline,
                overload_policy=args.overload_policy,
                fault_plan=fault_plan,
                **({} if args.retry_max is None
                   else {"retry_max": args.retry_max}),
                **({} if args.hedge_after is None
                   else {"hedge_after": args.hedge_after})))
        try:
            engine = mk()
        except ValueError as e:
            # fault-plan vs topology mismatch (bad worker/replica target,
            # every worker crashed permanently, ...) — surface as a CLI
            # error, not a traceback
            ap.error(f"--fault-plan: {e}")
    else:
        engine = ANNSEngine(svc, method=args.engine)

    if args.engine == "sched":
        arrivals = (None if args.qps is None else poisson_arrivals(
            len(queries), qps=args.qps, seed=args.seed + 3))
        result = engine.serve(queries, arrivals, dataset=args.dataset,
                              seed=args.seed)
    else:
        result = engine.serve(queries, dataset=args.dataset, seed=args.seed)
    print(f"[serve] engine={args.engine} dataset={args.dataset} "
          f"retrieval-backend={args.retrieval_backend} "
          f"(n_workers={svc.backend.n_workers}) tenants={args.tenants}"
          + (f" edge-replicas={args.edge_replicas}"
             f" sync-every={engine.sched.edge_sync_every}"
             if args.engine == "sched" else "")
          + (f" agentic={n_agentic}/{args.queries} hops={args.hops}"
             if n_agentic else ""))
    for k, v in result.summary().items():
        print(f"  {k:20s} {v:.4f}")
    trace = getattr(result, "trace", None)
    if trace is not None and trace.n:
        print("  per-stage breakdown (virtual-clock seconds):")
        for stage, row in trace.stage_breakdown().items():
            print(f"    {stage:12s} total={row['total_s']:10.3f}  "
                  f"mean={row['mean_s']:8.4f}  frac={row['frac']:6.1%}")
    if args.tenants > 1:
        tids = np.array([q["tenant"] for q in queries])
        print(f"  tenant histogram     "
              f"{np.bincount(tids, minlength=args.tenants).tolist()}")
        # per-request slices must cover spawned hop sub-queries too (the
        # sched result's population can exceed the input trace)
        rtids = getattr(result, "tenant_ids", None)
        if rtids is not None and len(rtids) == len(result.accepts):
            tids = rtids
        for t in range(args.tenants):
            m = tids == t
            if m.any():
                print(f"  tenant[{t}] n={int(m.sum()):5d} "
                      f"dar={float(result.accepts[m].mean()):.4f} "
                      f"doc_hit={float(result.doc_hits[m].mean()):.4f}")


if __name__ == "__main__":
    main()
