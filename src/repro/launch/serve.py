"""Serving driver: HaS speculative retrieval over a synthetic query stream.

  python -m repro.launch.serve --queries 2000 --dataset granola --tau 0.2
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--dataset", default="granola",
                    choices=["granola", "popqa", "triviaqa", "squad"])
    ap.add_argument("--engine", default="has",
                    choices=["has", "full", "proximity", "saferadius",
                             "mincache", "crag", "ivf", "scann"])
    ap.add_argument("--tau", type=float, default=0.2)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--h-max", type=int, default=5000)
    ap.add_argument("--entities", type=int, default=20000)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.core.has import HasConfig
    from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
    from repro.serving.engine import (ANNSEngine, CRAGEngine,
                                      FullRetrievalEngine, HasEngine,
                                      ReuseEngine, RetrievalService)
    from repro.serving.latency import LatencyModel

    world = SyntheticWorld(WorldConfig(n_entities=args.entities,
                                       seed=args.seed))
    svc = RetrievalService(world, LatencyModel(), k=args.k)
    ds = DATASETS[args.dataset]
    queries = world.sample_queries(
        args.queries, pattern=ds["pattern"], zipf_a=ds["zipf_a"],
        p_uncovered=ds["p_uncovered"], seed=args.seed + 1)

    if args.engine == "has":
        engine = HasEngine(svc, HasConfig(
            k=args.k, tau=args.tau, h_max=args.h_max,
            nprobe=16, n_buckets=2048, d=world.cfg.d))
    elif args.engine == "full":
        engine = FullRetrievalEngine(svc)
    elif args.engine in ("proximity", "saferadius", "mincache"):
        engine = ReuseEngine(svc, args.engine, h_max=args.h_max)
    elif args.engine == "crag":
        engine = CRAGEngine(svc, HasConfig(
            k=args.k, tau=args.tau, h_max=args.h_max,
            nprobe=16, n_buckets=2048, d=world.cfg.d))
    else:
        engine = ANNSEngine(svc, method=args.engine)

    result = engine.serve(queries, dataset=args.dataset, seed=args.seed)
    print(f"[serve] engine={args.engine} dataset={args.dataset}")
    for k, v in result.summary().items():
        print(f"  {k:20s} {v:.4f}")


if __name__ == "__main__":
    main()
