"""Serving driver: HaS speculative retrieval over a synthetic query stream.

  python -m repro.launch.serve --queries 2000 --dataset granola --tau 0.2

Full-database retrieval is pluggable (``--retrieval-backend``, see
retrieval/service.py): ``flat`` is the in-process exact scan, ``sharded``
row-shards the corpus over ``--shards`` mesh workers
(``LatencyModel.shard_scale`` speedup + ``--workers`` concurrent cloud
dispatch slots for the scheduler's worker pool), ``replica`` routes through
``--workers`` warm standbys whose delta logs are reconciled on every cache
ingest.
"""
from __future__ import annotations

import argparse
import tempfile


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--dataset", default="granola",
                    choices=["granola", "popqa", "triviaqa", "squad"])
    ap.add_argument("--engine", default="has",
                    choices=["has", "full", "proximity", "saferadius",
                             "mincache", "crag", "ivf", "scann"])
    ap.add_argument("--retrieval-backend", default="flat",
                    choices=["flat", "sharded", "replica"],
                    help="full-retrieval backend (retrieval/service.py): "
                         "in-process flat scan, mesh-sharded concurrent "
                         "scan, or warm-standby replicas")
    ap.add_argument("--shards", type=int, default=4,
                    help="corpus shards for --retrieval-backend sharded")
    ap.add_argument("--workers", type=int, default=2,
                    help="concurrent cloud dispatch slots (sharded) / "
                         "standby replicas (replica)")
    ap.add_argument("--tau", type=float, default=0.2)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--h-max", type=int, default=5000)
    ap.add_argument("--entities", type=int, default=20000)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax.numpy as jnp

    from repro.core.has import HasConfig
    from repro.data.synthetic import DATASETS, SyntheticWorld, WorldConfig
    from repro.retrieval.service import (LocalFlatBackend, ReplicaBackend,
                                         ShardedMeshBackend)
    from repro.serving.engine import (ANNSEngine, CRAGEngine,
                                      FullRetrievalEngine, HasEngine,
                                      ReuseEngine, RetrievalService)
    from repro.serving.latency import LatencyModel

    world = SyntheticWorld(WorldConfig(n_entities=args.entities,
                                       seed=args.seed))
    latency = LatencyModel()
    corpus = jnp.asarray(world.doc_emb)
    if args.retrieval_backend == "sharded":
        backend = ShardedMeshBackend(corpus, args.k, latency,
                                     n_shards=args.shards,
                                     n_workers=args.workers)
    elif args.retrieval_backend == "replica":
        from repro.checkpoint import CheckpointManager
        from repro.serving.replication import WarmStandby
        cfg0 = HasConfig(k=args.k, tau=args.tau, h_max=args.h_max,
                         nprobe=16, n_buckets=2048, d=world.cfg.d)
        standbys = [
            WarmStandby(cfg0, CheckpointManager(tempfile.mkdtemp(
                prefix=f"has-standby{i}-")), snapshot_every=10_000,
                max_lag=50_000)
            for i in range(max(1, args.workers))]
        backend = ReplicaBackend(
            LocalFlatBackend(corpus, args.k, latency), standbys, corpus)
    else:
        backend = None                       # RetrievalService default: flat
    svc = RetrievalService(world, latency, k=args.k, backend=backend)
    ds = DATASETS[args.dataset]
    queries = world.sample_queries(
        args.queries, pattern=ds["pattern"], zipf_a=ds["zipf_a"],
        p_uncovered=ds["p_uncovered"], seed=args.seed + 1)

    if args.engine == "has":
        engine = HasEngine(svc, HasConfig(
            k=args.k, tau=args.tau, h_max=args.h_max,
            nprobe=16, n_buckets=2048, d=world.cfg.d))
    elif args.engine == "full":
        engine = FullRetrievalEngine(svc)
    elif args.engine in ("proximity", "saferadius", "mincache"):
        engine = ReuseEngine(svc, args.engine, h_max=args.h_max)
    elif args.engine == "crag":
        engine = CRAGEngine(svc, HasConfig(
            k=args.k, tau=args.tau, h_max=args.h_max,
            nprobe=16, n_buckets=2048, d=world.cfg.d))
    else:
        engine = ANNSEngine(svc, method=args.engine)

    result = engine.serve(queries, dataset=args.dataset, seed=args.seed)
    print(f"[serve] engine={args.engine} dataset={args.dataset} "
          f"retrieval-backend={args.retrieval_backend} "
          f"(n_workers={svc.backend.n_workers})")
    for k, v in result.summary().items():
        print(f"  {k:20s} {v:.4f}")


if __name__ == "__main__":
    main()
