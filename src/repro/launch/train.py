"""Training driver: real steps on local devices, production loop structure.

Runs any registered arch at smoke scale (CPU) or a ~100M-param preset, with
the full production loop: checkpoint/restore (atomic+async), straggler
watchdog, optional elastic-restart simulation, optional int8 gradient
compression.  On a TPU cluster the same loop runs under the production mesh
(launch/mesh.py); here it demonstrates and tests the control plane.

  python -m repro.launch.train --arch chatglm3-6b --steps 50
  python -m repro.launch.train --preset lm100m --steps 300 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np


def make_lm100m():
    """~100M-param dense transformer for the end-to-end training example."""
    from repro.models.transformer import TransformerConfig
    return TransformerConfig(
        name="lm100m", n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
        d_ff=2048, vocab_size=8192, d_head=64, remat=False)


def train_lm(cfg, steps: int, batch: int, seq: int, ckpt_dir: str | None,
             log_every: int = 10, seed: int = 0, resume: bool = True):
    from repro.checkpoint import CheckpointManager
    from repro.data.lm import MarkovLM
    from repro.models import transformer as tf
    from repro.training.fault import StragglerDetector
    from repro.training.optimizer import OptConfig, opt_init
    from repro.training.train import make_train_step

    params = tf.init_params(cfg, jax.random.key(seed))
    opt_cfg = OptConfig(name="adafactor" if cfg.is_moe else "adamw", lr=3e-4)
    opt_state = opt_init(opt_cfg, params)
    lossf = functools.partial(tf.loss_fn, cfg=cfg, rules=None,
                              compute_dtype=jnp.float32)
    step_fn = jax.jit(make_train_step(lossf, opt_cfg), donate_argnums=(0, 1))

    start = 0
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr and resume:
        restored = mgr.restore_latest({"params": params, "opt": opt_state})
        if restored is not None:
            start, tree = restored
            params, opt_state = tree["params"], tree["opt"]
            print(f"[train] resumed from step {start}")

    lm = MarkovLM(cfg.vocab_size, order=2, seed=seed)
    rng = np.random.default_rng(seed + 1)
    detector = StragglerDetector()
    losses = []
    for step in range(start, steps):
        b = jax.tree.map(jnp.asarray, lm.sample(rng, batch, seq))
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, b)
        jax.block_until_ready(metrics["loss"])
        elapsed = time.perf_counter() - t0
        if detector.observe(step, elapsed):
            print(f"[train] step {step}: straggler flagged "
                  f"({elapsed:.2f}s > {detector.deadline:.2f}s)")
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:5d} loss {losses[-1]:.4f} "
                  f"grad_norm {float(metrics['grad_norm']):.3f} "
                  f"{elapsed * 1e3:.0f} ms", flush=True)
        if mgr and (step + 1) % 50 == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state},
                     blocking=False)
    if mgr:
        mgr.save(steps, {"params": params, "opt": opt_state}, blocking=True)
    return losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="registered arch (smoke cfg)")
    ap.add_argument("--preset", default=None, choices=["lm100m"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.preset == "lm100m":
        cfg = make_lm100m()
        print(f"[train] lm100m: {cfg.param_count() / 1e6:.1f}M params")
        train_lm(cfg, args.steps, args.batch, args.seq, args.ckpt_dir)
        return

    from repro.configs import get_arch
    spec = get_arch(args.arch)
    cfg, params, opt_state, step, batch = spec.make_smoke()
    step = jax.jit(step, donate_argnums=(0, 1))
    for i in range(args.steps):
        params, opt_state, metrics = step(params, opt_state, batch)
        if i % 10 == 0:
            print(f"[train] {args.arch} step {i} loss "
                  f"{float(metrics['loss']):.4f}")
    print("[train] done")


if __name__ == "__main__":
    main()
