import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we build the production mesh (16x16 single-pod / 2x16x16
multi-pod), resolve the logical shardings, and run
``jax.jit(step).lower(*abstract_args).compile()`` over ShapeDtypeStructs —
no real allocation.  Success proves the distribution config is coherent
(shardings consistent, collectives supported, memory fits); the compiled
artifact yields the roofline terms (§Roofline in EXPERIMENTS.md):

  memory_analysis()  -> per-device HBM (args/temps/outputs)
  cost_analysis()    -> HLO FLOPs + bytes accessed (per device)
  as_text()          -> collective ops; we sum their per-device bytes

Usage:
  python -m repro.launch.dryrun --arch chatglm3-6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from repro.configs import all_archs, get_arch
from repro.launch.mesh import make_production_mesh
from repro.utils import PRODUCTION_RULES, tree_specs

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
                "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|"
                       r"f64|c64|c128)\[([0-9,]*)\]")


def rules_for_mesh(mesh) -> dict:
    """Drop mesh axes the current mesh doesn't have (e.g. 'pod')."""
    have = set(mesh.shape.keys())

    def fix(v):
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in have else None
        kept = tuple(a for a in v if a in have)
        return kept if kept else None

    return {k: fix(v) for k, v in PRODUCTION_RULES.items()}


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device payload bytes of every collective op in the HLO."""
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        for coll in _COLLECTIVES:
            # match `<result-shape> <coll>(` — op use, not a metadata mention
            m = re.search(r"=\s+(\(?[a-z0-9\[\],{}\s/#_.-]+?\)?)\s+"
                          + coll + r"(-start|-done)?\(", stripped)
            if not m:
                continue
            if m.group(2) == "-done":   # avoid double counting start/done
                continue
            result = m.group(1)
            nbytes = 0
            for dm in _SHAPE_RE.finditer(result):
                dims = dm.group(2)
                n = int(np.prod([int(x) for x in dims.split(",") if x])) \
                    if dims else 1
                nbytes += n * _DTYPE_BYTES[dm.group(1)]
            out[coll] += nbytes
            counts[coll] += 1
            break
    out_counts = {f"n_{k}": v for k, v in counts.items()}
    return {**out, **out_counts, "total": sum(out[c] for c in _COLLECTIVES)}


def run_cell(arch: str, shape: str, multi_pod: bool = False,
             keep_hlo: bool = False, **variant) -> dict:
    spec = get_arch(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for_mesh(mesh)
    rec = {"arch": arch, "shape": shape,
           "mesh": "x".join(str(s) for s in mesh.devices.shape),
           "n_devices": int(np.prod(mesh.devices.shape)), "ok": False}
    if variant:
        rec["variant"] = dict(variant)
    try:
        bundle = spec.make_bundle(shape, rules, mesh, **variant)
        from jax.sharding import NamedSharding, PartitionSpec as P
        in_specs = tuple(
            jax.tree.map(lambda s: NamedSharding(mesh, s),
                         tree_specs(lg, rules),
                         is_leaf=lambda x: isinstance(x, P))
            for lg in bundle.arg_logical)
        with mesh:
            jitted = jax.jit(bundle.fn, in_shardings=in_specs,
                             donate_argnums=bundle.donate_argnums)
            t0 = time.perf_counter()
            lowered = jitted.lower(*bundle.abstract_args)
            rec["lower_s"] = round(time.perf_counter() - t0, 2)
            t0 = time.perf_counter()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.perf_counter() - t0, 2)

        mem = compiled.memory_analysis()
        if mem is not None:
            for field in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes"):
                try:
                    rec[field] = int(getattr(mem, field))
                except (AttributeError, TypeError):
                    pass
        cost = compiled.cost_analysis()
        if cost:
            rec["flops_per_device"] = float(cost.get("flops", -1))
            rec["bytes_per_device"] = float(cost.get("bytes accessed", -1))
        txt = compiled.as_text()
        rec["collectives"] = collective_bytes(txt)
        rec["hlo_ops"] = {c: txt.count(c + "(") for c in _COLLECTIVES}
        if keep_hlo:
            rec["hlo"] = txt
        rec["ok"] = True
        print(f"[dryrun] OK  {arch:18s} {shape:14s} mesh={rec['mesh']} "
              f"lower={rec.get('lower_s')}s compile={rec.get('compile_s')}s "
              f"flops/dev={rec.get('flops_per_device', 0):.3e} "
              f"coll={rec['collectives']['total']:.3e}B", flush=True)
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] FAIL {arch} {shape} multi_pod={multi_pod}: "
              f"{rec['error']}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="also lower unrolled 1/2-layer variants (single-pod) "
                         "for exact per-layer roofline terms")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already OK in --out")
    args = ap.parse_args()

    cells = []
    meshes = [True, False] if args.both_meshes else [args.multi_pod]
    if args.all:
        for arch in all_archs():
            for shape in get_arch(arch).shapes:
                for mp in meshes:
                    cells.append((arch, shape, mp, {}))
    else:
        for mp in meshes:
            cells.append((args.arch, args.shape, mp, {}))

    if args.probe:
        for arch, shape, mp, _ in list(cells):
            if get_arch(arch).family in ("lm", "gnn") and not mp:
                cells.append((arch, shape, False,
                              dict(n_layers=1, unroll=True)))
                cells.append((arch, shape, False,
                              dict(n_layers=2, unroll=True)))

    results = []
    done = set()

    def cell_key(r):
        v = r.get("variant") or {}
        return (r["arch"], r["shape"], r["n_devices"],
                v.get("n_layers"), bool(v.get("unroll")))

    if args.out and os.path.exists(args.out):
        results = json.load(open(args.out))
        if args.skip_done:
            done = {cell_key(r) for r in results if r.get("ok")}

    for arch, shape, mp, variant in cells:
        nd = 512 if mp else 256
        key = (arch, shape, nd, variant.get("n_layers"),
               bool(variant.get("unroll")))
        if key in done:
            continue
        rec = run_cell(arch, shape, multi_pod=mp, **variant)
        results = [r for r in results if cell_key(r) != cell_key(rec)]
        results.append(rec)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    n_ok = sum(r["ok"] for r in results)
    print(f"[dryrun] {n_ok}/{len(results)} cells OK")


if __name__ == "__main__":
    main()
