"""Batched HaS serving: accept-mask compaction (throughput mode).

Algorithm 1 is sequential (each query can hit the cache updated by the
previous one).  At production load the engine instead processes micro-batches
against a cache snapshot:

  1. ``speculate_batch`` scores the whole micro-batch in ONE fused device
     dispatch (Pallas kernel pipeline on TPU, XLA oracle on CPU);
  2. rejected queries are compacted into a padded sub-batch and sent through
     ONE batched full-database search (the continuous-batching analogue);
  3. ``cache_update_batched`` folds every rejected result into the cache in
     one donated-buffer scan, then the next micro-batch runs.

Semantics vs. the sequential engine: intra-batch queries cannot re-identify
each other (the cache is a snapshot), so DAR is a lower bound that converges
to the sequential engine's as batch_size/stream_length -> 0.  Latency per
query improves by amortizing dispatch + the full-search matmul batch; the
whole step is three device dispatches (speculate / full search / ingest)
regardless of batch width.

The engine rides the shared :class:`~repro.serving.engine.ServeLoop`
substrate: it only implements ``_step_batch``; metrics recording and rng
threading live in the base class.  serving/scheduler.py lifts the same
micro-batch mechanics into an event-driven continuous-batching loop that
additionally lets intra-batch rejects share full retrievals.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.has import (HasConfig, cache_update_batched,
                            cache_update_chunked, init_has_state,
                            init_tenant_states, speculate_batch)
from repro.retrieval.ivf import build_ivf
from repro.serving.engine import RetrievalService, ServeLoop, fuzzy_scope


class BatchedHasEngine(ServeLoop):
    """``n_tenants > 1`` partitions the snapshot cache: each micro-batch row
    speculates against and ingests into its own tenant's slice (queries
    carry a ``"tenant"`` key), all still in the same three fused dispatches
    per micro-batch.  ``n_tenants == 1`` is the historical path."""

    def __init__(self, service: RetrievalService, cfg: HasConfig | None = None,
                 batch_size: int = 32, seed: int = 0,
                 backend: str | None = None, n_tenants: int = 1):
        super().__init__(service)
        self.cfg = cfg or HasConfig(k=service.k, d=service.world.cfg.d)
        self.n_tenants = max(1, int(n_tenants))
        self.state = (init_has_state(self.cfg) if self.n_tenants == 1
                      else init_tenant_states(self.cfg, self.n_tenants))
        self.index = build_ivf(service.corpus, self.cfg.n_buckets, seed=seed)
        self.batch_size = batch_size
        self.backend = backend                  # None -> auto per platform
        self.fuzzy_scope = fuzzy_scope(self.cfg, self.index)
        # warmup the fused programs at the shapes the loop uses
        z = jnp.zeros((batch_size, self.s.world.cfg.d))
        warm_tids = (None if self.n_tenants == 1
                     else jnp.zeros((batch_size,), jnp.int32))
        jax.block_until_ready(
            speculate_batch(self.cfg, self.state, self.index, z,
                            backend=backend, tenant_ids=warm_tids))
        service.backend.search(z)[0].block_until_ready()
        scratch = (init_has_state(self.cfg) if self.n_tenants == 1
                   else init_tenant_states(self.cfg, self.n_tenants))
        jax.block_until_ready(cache_update_batched(
            self.cfg, scratch, z,
            jnp.zeros((batch_size, self.cfg.k), jnp.int32),
            jnp.zeros((batch_size, self.cfg.k, self.s.world.cfg.d)),
            jnp.zeros((batch_size,), bool),
            tenant_ids=warm_tids).q_ptr)        # donated, then discarded

    def _step_batch(self, group, rng, dataset):
        lat_model = self.s.latency
        bs = self.batch_size
        embs = np.stack([q["emb"] for q in group])
        if len(group) < bs:                           # pad the tail batch
            pad = np.zeros((bs - len(group), embs.shape[1]), np.float32)
            embs = np.concatenate([embs, pad])
        if self.n_tenants == 1:
            tids, spec_tids = None, None
        else:
            tags = [int(q.get("tenant", 0)) for q in group]
            if any(not 0 <= t < self.n_tenants for t in tags):
                raise ValueError(
                    f"tenant tags {sorted(set(tags))} out of range for "
                    f"n_tenants={self.n_tenants}")
            tids = np.zeros(bs, np.int32)             # pad rows: tenant 0
            tids[:len(group)] = tags
            spec_tids = jnp.asarray(tids)
        t0 = time.perf_counter()
        out = speculate_batch(self.cfg, self.state, self.index,
                              jnp.asarray(embs), backend=self.backend,
                              tenant_ids=spec_tids)
        jax.block_until_ready(out)
        t_spec = (time.perf_counter() - t0) / max(len(group), 1)
        accepts = np.asarray(out["accept"])[:len(group)]
        drafts = np.asarray(out["draft_ids"])[:len(group)]

        # compact the rejected sub-batch -> one batched full search
        rej = np.flatnonzero(~accepts)
        ids_full, t_full = None, 0.0
        if len(rej):
            # one coalesced dispatch on the pluggable full-retrieval backend
            ids_full, t_full = self.s.full_search_batch(embs[rej])
            # fold the whole rejected batch into the cache in ONE dispatch
            # (padded to the compiled batch_size shape; mask drops the pad),
            # each row scattered into its tenant's partition
            rej_tids = None if tids is None else tids[rej]
            self.state = cache_update_chunked(
                self.cfg, self.state, embs[rej], ids_full.astype(np.int32),
                corpus=self.s.corpus, chunk=bs, tenant_ids=rej_tids)
            # replica-style backends mirror the ingest onto standby logs
            self.s.backend.on_ingest(embs[rej], ids_full.astype(np.int32),
                                     self.state, tenant_ids=rej_tids)

        fuzzy_t = lat_model.scan_time(
            lat_model.target_corpus * self.fuzzy_scope * 2.0)
        results = []
        for i in range(len(group)):
            lat = lat_model.sample_edge() + t_spec + fuzzy_t
            if accepts[i]:
                ids = drafts[i]
            else:
                j = int(np.flatnonzero(rej == i)[0])
                ids = ids_full[j]
                lat += lat_model.sample_cloud() + t_full
            results.append((ids, bool(accepts[i]), lat))
        return results
