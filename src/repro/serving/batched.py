"""Batched HaS serving: accept-mask compaction (throughput mode).

Algorithm 1 is sequential (each query can hit the cache updated by the
previous one).  At production load the engine instead processes micro-batches
against a cache snapshot:

  1. ``speculate_batched`` scores the whole micro-batch on device;
  2. rejected queries are compacted into a padded sub-batch and sent through
     ONE batched full-database search (the continuous-batching analogue);
  3. the cache ingests all rejected results, then the next micro-batch runs.

Semantics vs. the sequential engine: intra-batch queries cannot re-identify
each other (the cache is a snapshot), so DAR is a lower bound that converges
to the sequential engine's as batch_size/stream_length -> 0.  Latency per
query improves by amortizing dispatch + the full-search matmul batch.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.has import (HasConfig, cache_update, init_has_state,
                            speculate_batched)
from repro.retrieval.flat import chunked_flat_search
from repro.retrieval.ivf import build_ivf, subset_index
from repro.serving.engine import LLMS, RetrievalService, ServeResult, \
    _finish, _metrics_init, _record


class BatchedHasEngine:
    def __init__(self, service: RetrievalService, cfg: HasConfig | None = None,
                 batch_size: int = 32, seed: int = 0):
        self.s = service
        self.cfg = cfg or HasConfig(k=service.k, d=service.world.cfg.d)
        self.state = init_has_state(self.cfg)
        self.index = build_ivf(service.corpus, self.cfg.n_buckets, seed=seed)
        self.batch_size = batch_size
        self.fuzzy_scope = (min(self.cfg.nprobe, self.index.n_buckets)
                            / self.index.n_buckets)
        self._full_batch = jax.jit(lambda c, q: chunked_flat_search(
            c, q, self.cfg.k, min(32768, c.shape[0])))
        # warmup
        z = jnp.zeros((batch_size, self.s.world.cfg.d))
        jax.block_until_ready(
            speculate_batched(self.cfg, self.state, self.index, z))
        self._full_batch(self.s.corpus, z)[0].block_until_ready()

    def serve(self, queries, dataset="granola", llms=LLMS,
              seed=0) -> ServeResult:
        rng = np.random.default_rng(seed)
        m = _metrics_init(len(queries), llms)
        lat_model = self.s.latency
        bs = self.batch_size
        for start in range(0, len(queries), bs):
            group = queries[start:start + bs]
            embs = np.stack([q["emb"] for q in group])
            if len(group) < bs:                       # pad the tail batch
                pad = np.zeros((bs - len(group), embs.shape[1]), np.float32)
                embs = np.concatenate([embs, pad])
            t0 = time.perf_counter()
            out = speculate_batched(self.cfg, self.state, self.index,
                                    jnp.asarray(embs))
            jax.block_until_ready(out)
            t_spec = (time.perf_counter() - t0) / max(len(group), 1)
            accepts = np.asarray(out["accept"])[:len(group)]
            drafts = np.asarray(out["draft_ids"])[:len(group)]

            # compact the rejected sub-batch -> one batched full search
            rej = np.flatnonzero(~accepts)
            t_full = 0.0
            if len(rej):
                sub = jnp.asarray(embs[rej])
                _, ids_full = self._full_batch(self.s.corpus, sub)
                ids_full = np.asarray(ids_full)
                t_full = lat_model.full_scan_time()   # amortized batch scan
                for j, qi in enumerate(rej):
                    ids = ids_full[j].astype(np.int32)
                    self.state = cache_update(
                        self.cfg, self.state, jnp.asarray(embs[qi]),
                        jnp.asarray(ids), self.s.corpus[ids])

            fuzzy_t = lat_model.scan_time(
                lat_model.target_corpus * self.fuzzy_scope * 2.0)
            for i, q in enumerate(group):
                lat = lat_model.sample_edge() + t_spec + fuzzy_t
                if accepts[i]:
                    ids = drafts[i]
                else:
                    j = int(np.flatnonzero(rej == i)[0])
                    ids = ids_full[j]
                    lat += lat_model.sample_cloud() + t_full
                _record(m, start + i, self.s.world, q, ids, lat,
                        bool(accepts[i]), dataset, llms, rng)
        return _finish(m)
