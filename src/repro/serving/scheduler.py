"""Event-driven continuous-batching HaS serving (virtual-clock simulation).

Request lifecycle:

    arrive -> admission queue -> fused ``speculate_batch`` on the edge
              (one device dispatch per speculation batch; Pallas kernel
              pipeline on TPU, XLA oracle on CPU — see core/has.py)
           -> accepted: return early (queue wait + spec compute + edge RTT)
           -> rejected:
                -> scored against every PENDING leader (queued or in-flight
                   full retrievals) and the other rejects of its speculation
                   batch via :func:`repro.core.has.intra_batch_share`;
                   homologous peers become FOLLOWERS and share the leader's
                   single full retrieval (single-flight collapsing)
                -> leaders wait in the full-retrieval queue, are LATE
                   RE-VALIDATED against the current cache at cloud-dispatch
                   time (results ingested while they queued may re-identify
                   them; one ``reidentify`` on the already-computed
                   validation draft, no fuzzy scan), and the survivors are
                   coalesced into ONE batched cloud matmul
                -> full results (leaders + follower attribution) ingest into
                   the cache via ``cache_update_batched`` — one fused
                   donated-buffer scan per ``ingest_batch`` chunk instead of
                   a per-request dispatch loop — and everyone returns

The edge (speculation) and the cloud (full retrieval) are independent
resources, so speculation of later admissions overlaps in-flight full
retrievals — the continuous-batching win that neither the sequential
``HasEngine`` (strict Algorithm 1) nor the snapshot micro-batches of
``BatchedHasEngine`` can express.  The cloud stage itself is a WORKER POOL
over the service's pluggable full-retrieval backend
(retrieval/service.py): ``backend.n_workers`` concurrent dispatch slots,
each charged ``backend.latency(batch)`` on the virtual clock — one slot
for the in-process ``LocalFlatBackend`` (the historical serialized cloud),
several for ``ShardedMeshBackend`` mesh workers or ``ReplicaBackend`` warm
standbys, whose cache ingests the loop reconciles via
``backend.on_ingest``.  CAVEAT for approximate backends (``IVFBackend``):
the cloud stage's results are what the cache ingests, so any recall loss
COMPOUNDS — a missed document is absent from later homology validations
and from every accept served off that cache entry, not just from the one
response.  Calibrate ``nprobe`` against end-to-end doc-hit
(``benchmarks/ann_recall.py``), never against kernel recall@k alone.
Four completion channels result —
``draft`` / ``reval`` / ``shared`` / ``full`` — of which the first three
count as accepted (only ``full`` pays for its own full retrieval; only
``full`` and ``shared`` wait on the cloud).

The EDGE is a replica pool too (``SchedulerConfig.edge_replicas = R``,
serving/edge_pool.py): R speculation dispatch slots, each backed by its
own warm cache replica fed from the primary's ingest stream by
bounded-lag delta replay (``edge_sync_every``).  Admission is
staleness-aware — a batch goes to the freshest free replica — and its
acceptance decisions are validated against THAT replica's own cache
version, so an accept can only reference documents the serving replica
actually holds (no phantom accepts on a stale cache).  Ingests still land
on the primary alone; late re-validation at cloud-dispatch time checks
the primary (the authoritative cache, where those ingests live).
``R == 1`` is the historical single-edge path bit-exactly: the lone slot
IS the primary (zero lag, no pool), mirroring how ``n_tenants == 1``
keeps the unstacked store.

Multi-tenancy (``SchedulerConfig.n_tenants > 1``): the cache is a
tenant-partitioned stacked store (``core/has.py::init_tenant_states``) and
every request carries a tenant tag (``serve(tenant_ids=...)`` or a
``"tenant"`` key on the query).  Admission and the full-retrieval queue
are per-tenant FIFOs drained by weighted-fair selection
(``SchedulerConfig.tenant_weights``, optional per-batch admission quota
``tenant_quota``), speculation/ingest route each row through its tenant's
partition inside the same fused programs, and the sharing election masks
cross-tenant pairs — one tenant's churn can neither evict another's
homology window nor leak retrieved documents into another's drafts.
``SchedResult.per_tenant()`` slices every metric by tenant.  T == 1 is
the historical single-tenant path, bit-exactly.

Latency accounting: every component is *modeled* — sampled RTTs from the
scheduler's own per-serve rng plus analytic bandwidth-bound scan times
(serving/latency.py) — so a run is a pure function of
(seed, arrival trace, query stream).  tests/test_scheduler.py relies on
this bit-for-bit determinism.  Batched scans are charged bandwidth-bound:
one coalesced matmul streams the operand once, so a full-retrieval batch
costs ``full_scan_time()`` regardless of batch width, and a speculation
batch streams ``min(B * scope, 1.0)`` of the fuzzy index.  EVERY stage is
on the clock: cache ingest (the ``cache_update_chunked`` fold plus the
``on_ingest`` replication fan-out) is charged on the cloud-done path to
each request returning from that batch, and edge-replica delta replay is
charged to the dispatching edge slot before its speculation batch runs
(``LatencyModel.ingest_time`` for both — they are the same fold).
``SchedulerConfig.free_ingest_replay=True`` restores the historical
free-ingest/free-replay accounting (and
``follower_score_weighted=False`` the historical leader-ordered follower
ingest) — the compat point the pre-PR golden traces pin, and what the
zero-cost-delta verdict of ``benchmarks/sched_throughput.py
--sweep-overload`` runs to prove the tracing machinery itself never
advances the virtual clock.

Per-stage tracing (serving/tracing.py): every request records a span
breakdown — queue wait / replay / spec / edge RTT / reval wait / cloud
queue / cloud / ingest — summing EXACTLY to its end-to-end latency, and
``SchedResult.trace`` exposes ``stage_breakdown()`` and
``timeline(bucket_s)`` for benchmarks to assert on.

Overload control (``SchedulerConfig.{slo_deadline_s, overload_policy}``):
past saturation an uncontrolled open-loop queue grows without bound and
p99 is meaningless, so the scheduler can either ``shed`` — reject at
admission (new ``"shed"`` channel, zero latency, no resources consumed)
when the fluid-model predicted queue wait blows the deadline — or
``degrade`` — serve speculation-only under overload: rejected drafts
return immediately with ``accept=False`` (``"degraded"`` channel) instead
of queuing for the cloud.  The overload state machine has hysteresis
(enter above ``slo_deadline_s``, exit below ``overload_exit_frac`` of it)
and is evaluated only at event boundaries, so the policy is a
deterministic function of the virtual clock like everything else.

Fault injection + self-healing (``SchedulerConfig.fault_plan``,
serving/faults.py): a :class:`~repro.serving.faults.FaultPlan` pins fault
events to the virtual clock — cloud-worker crashes, straggler slowdowns,
transient search failures, edge-replica crashes, dropped/duplicated
replication appends — making every chaos run a pure function of
``(seed, plan, arrivals, queries)``.  Under a non-empty plan the cloud
stage self-heals: every dispatch carries a DEADLINE derived from the
calibrated latency model (``training/fault.py::StragglerDetector`` over
observed service times, ``hedge_after`` × expected before calibration);
a blown deadline HEDGES the batch onto a free worker (first result wins,
the loser is cancelled and its head start charged to the new ``lost``
span); a failed attempt RETRIES with exponential backoff (``retry_max``,
``retry_backoff_s``, charged to ``retry_backoff``); a crashed worker's
in-flight batch is requeued at the head of the line; and a crashed edge
replica's in-flight speculation reroutes to the full channel while the
slot is rebuilt in the background from the primary (rebuild time on the
clock).  Ingest is idempotent end-to-end — every completed cloud batch
carries a monotone ``ingest_key`` that ``record_batch``/``on_ingest``
dedupe, so a duplicated replication append can never fold twice.  Span
conservation stays EXACT through every recovery path, and an empty/absent
plan leaves the fault-free schedule bit-identical to the pre-PR goldens
(no extra heap events, same rng draw order) — the zero-cost verdict
``benchmarks/sched_chaos.py`` pins.

Agentic multi-hop serving (serving/agentic.py): a query carrying a
``hop_plan`` continuation is the hop-1 sub-query of a COMPLEX multi-hop
request.  When a hop resolves, the scheduler reasons out the bridge entity
(``LatencyModel.reason_time()`` on the clock — the new ``reason`` span) and
enqueues the next hop as a fresh tenant-tagged arrival; when a hop's DRAFT
is rejected, the next hop is PRE-SPECULATED from the drafted bridge
immediately (``SchedulerConfig.speculate_hops``), racing the hop's late
re-validation / full retrieval, so cross-hop latency pipelines instead of
serializing.  A mis-speculation (the validated bridge contradicts the
drafted one) cancels the in-flight child deterministically wherever it
lives — queued states settle at the cancel instant, dispatched cloud work
settles on its completion path — on the new ``cancelled`` channel
(sentinel ids, never ingested, spans conserved exactly), and the corrected
hop re-enqueues.  ``SchedResult.complex_records`` / ``summary()`` /
``per_tenant()`` report per-chain end-to-end latency, DAR/accuracy and
pre-speculation hit rates.  A trace with no ``hop_plan`` queries takes
none of these paths: zero extra rng draws, heap events and span charges —
bit-identical to the pre-hop-graph goldens (the empty-trace verdict
``benchmarks/sched_agentic.py`` pins).
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

import warnings

from repro.core.has import (HasConfig, cache_update_batched,
                            cache_update_chunked, init_has_state,
                            init_tenant_states, intra_batch_share,
                            speculate_batch)
from repro.core.homology import reidentify
from repro.retrieval.ivf import build_ivf
from repro.serving.edge_pool import DEFAULT_EDGE_SYNC_EVERY, EdgeReplicaPool
from repro.serving.engine import (LLMS, RetrievalService, ServeResult,
                                  _metrics_init, _record)
from repro.serving.faults import FaultInjector, FaultPlan
from repro.serving.replication import gather_doc_vecs
from repro.serving.engine import fuzzy_scope as _fuzzy_scope
from repro.serving.tracing import Trace, build_trace, empty_spans
from repro.training.fault import StragglerConfig, StragglerDetector

# Sharing-threshold default as a multiple of the validation threshold
# cfg.tau, calibrated by `benchmarks/sched_throughput.py --sweep-share-tau`
# on the homology-heavy granola stream at saturation: 0.5x cuts avg
# latency ~11% vs 1.0x with the follower channel's doc-hit at or above the
# full channel's (followers attach to genuinely homologous leaders), while
# 0.25x degrades follower doc-hit by 16+ points (non-homologous attachment).
DEFAULT_SHARE_TAU_MULT = 0.5


def poisson_arrivals(n: int, qps: float, seed: int = 0) -> np.ndarray:
    """Arrival times of a Poisson process at rate ``qps`` (open-loop load)."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / qps, size=n))


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_spec_batch: int = 32       # admission -> speculation coalescing cap
    full_batch: int = 16           # rejected leaders per cloud dispatch
    full_max_wait_s: float = 0.05  # dispatch a partial batch after this wait
    # DEPRECATED: the cloud stage is now a worker pool sized by the
    # retrieval backend (`service.backend.n_workers`); a non-None value
    # still loads (old configs keep working) and overrides the pool size,
    # with a DeprecationWarning at scheduler construction.
    max_inflight_full: int | None = None
    share: bool = True             # homology sharing across the reject queue
    share_tau: float | None = None  # sharing threshold; None ->
    #                                 DEFAULT_SHARE_TAU_MULT * cfg.tau
    max_pending_leaders: int = 256  # sharing registry capacity (fixed shape)
    revalidate: bool = True        # re-check leaders at cloud-dispatch time
    ingest_followers: bool = True  # followers' (q, shared D_full) also cached
    ingest_batch: int = 32         # fused cache-ingest chunk (compiled shape)
    backend: str | None = None     # speculation backend; None -> platform auto
    # -- multi-tenant partitioning (core/has.py::init_tenant_states) -------
    n_tenants: int = 1             # tenant partitions; 1 == the historical
    #                                single-tenant layout, bit-exactly
    tenant_quota: int | None = None  # admission quota: max rows one tenant
    #                                  may occupy in one speculation batch
    #                                  (None -> work-conserving fairness only)
    tenant_weights: tuple[float, ...] | None = None  # weighted-fair shares
    #                                  per tenant; None -> equal weights
    # -- edge speculation replica pool (serving/edge_pool.py) --------------
    edge_replicas: int = 1         # speculation cache replicas / dispatch
    #                                slots; 1 == the historical single-edge
    #                                path (the slot IS the primary),
    #                                bit-exactly
    edge_sync_every: int = DEFAULT_EDGE_SYNC_EVERY  # bounded-lag replay
    #                                cadence: a replica this many ingested
    #                                rows behind the primary replays its
    #                                missing delta rows
    # -- SLO-aware overload control ----------------------------------------
    slo_deadline_s: float | None = None  # end-to-end latency SLO; None ->
    #                                no deadline (goodput still unreported)
    overload_policy: str = "none"  # "none" | "shed" (reject at admission
    #                                when the predicted completion blows
    #                                the deadline) | "degrade" (serve
    #                                speculation-only under overload:
    #                                rejects return drafts, accept=False)
    overload_exit_frac: float = 0.5  # hysteresis: overload exits once the
    #                                predicted completion falls below this
    #                                fraction of the deadline
    # -- fault injection + self-healing (serving/faults.py) ----------------
    fault_plan: FaultPlan | None = None  # deterministic chaos plan pinned to
    #                                the virtual clock; None / empty plan ==
    #                                the fault-free path, BIT-EXACTLY (no
    #                                extra rng draws, no extra heap events)
    retry_max: int = 2             # transient-failure retries per cloud
    #                                batch before it fails hard ("failed"
    #                                channel)
    retry_backoff_s: float = 0.05  # exponential backoff base between a
    #                                failed cloud attempt and its retry
    #                                (doubles per attempt)
    hedge_after: float | None = 2.5  # straggler deadline factor: a cloud
    #                                dispatch outliving hedge_after x the
    #                                trailing-median attempt time (adaptive,
    #                                training/fault.py::StragglerDetector;
    #                                model-derived until warmed up) is
    #                                hedged onto a free worker — first
    #                                result wins, the loser is cancelled.
    #                                None disables hedging.  Only active
    #                                under a non-empty fault plan.
    # -- accounting / tracing ----------------------------------------------
    trace: bool = True             # per-stage span breakdown on SchedResult
    #                                (virtual-clock bookkeeping only; never
    #                                changes the schedule)
    free_ingest_replay: bool = False  # compat: the historical (pre-fix)
    #                                accounting where cache ingest and
    #                                edge-replica delta replay are FREE on
    #                                the virtual clock; the pre-PR golden
    #                                traces pin this point
    follower_score_weighted: bool = True  # followers ingest (and serve)
    #                                the shared D_full reranked by their
    #                                OWN query-doc scores; False keeps the
    #                                historical leader-ordered list
    # -- agentic hop graphs (serving/agentic.py) ---------------------------
    speculate_hops: bool = True    # cross-hop pre-speculation: launch hop
    #                                h+1 from hop h's REJECTED draft's
    #                                bridge entity, racing hop h's
    #                                validation / full retrieval; False
    #                                resolves hop graphs strictly
    #                                sequentially (the scheduler-sequential
    #                                baseline arm of benchmarks/
    #                                sched_agentic.py).  Inert on traces
    #                                with no hop_plan queries.


def _safe_mean(a) -> float:
    """``float(a.mean())`` that reports NaN instead of warning/crashing on
    an empty slice (``serve([])``, an all-shed tenant, ...)."""
    a = np.asarray(a)
    return float(a.mean()) if a.size else float("nan")


def _safe_pct(a, q: float) -> float:
    """NaN-safe ``np.percentile`` (empty slices crash it outright)."""
    a = np.asarray(a)
    return float(np.percentile(a, q)) if a.size else float("nan")


@dataclasses.dataclass
class SchedResult(ServeResult):
    """ServeResult + open-loop serving metrics."""
    t_arrive: np.ndarray
    t_done: np.ndarray
    cloud_s: np.ndarray            # cloud RTT + scan charged to each request
    channels: np.ndarray           # 'draft' | 'reval' | 'shared' | 'full'
    full_retrievals: int           # queries that PAID for a full retrieval
    spec_batches: int
    full_batches: int
    max_inflight_full_batches: int = 1  # worker-pool concurrency high-water
    tenant_ids: np.ndarray | None = None   # per-request tenant partition
    leader_idx: np.ndarray | None = None   # shared-channel leader request
    #                                        index (-1 for non-followers)
    served_ids: np.ndarray | None = None   # [n, k] doc ids actually served
    max_inflight_spec_batches: int = 1     # edge-pool concurrency high-water
    edge_replays: int = 0                  # bounded-lag delta replay events
    replica_ids: np.ndarray | None = None  # edge replica that speculated
    #                                        each request (-1: never
    #                                        speculated / R == 1 primary)
    cache_versions: np.ndarray | None = None  # serving replica's cache
    #                                        version (delta-log seq) at its
    #                                        speculation dispatch (-1: R==1)
    trace: Trace | None = None             # per-stage span breakdown
    #                                        (serving/tracing.py); None when
    #                                        SchedulerConfig.trace is False
    slo_deadline_s: float | None = None    # the SLO the stream was served
    #                                        under (goodput denominator)
    # -- fault-handling stats (serving/faults.py; all 0 fault-free) --------
    retries: int = 0               # cloud-batch re-dispatches (backoff
    #                                retries + crash requeues)
    hedges: int = 0                # straggler hedged re-dispatches
    worker_deaths: int = 0         # cloud-worker crash events handled
    replica_rebuilds: int = 0      # edge replicas rebuilt (crash recovery +
    #                                delta-gap full resyncs)
    # -- agentic hop graphs (serving/agentic.py; all None/zeros when the
    #    trace carried no hop_plan queries) -------------------------------
    hop: np.ndarray | None = None          # hop index per request (0: plain
    #                                        single-hop; spawned hop-h
    #                                        sub-queries appended after the
    #                                        input trace)
    parent_root: np.ndarray | None = None  # owning complex query's hop-1
    #                                        request index (-1: plain)
    speculative: np.ndarray | None = None  # launched from an unconfirmed
    #                                        drafted bridge AND never
    #                                        confirmed authoritative
    complex_records: list | None = None    # one record per complex query
    #                                        (root_idx, e2e_s, dar,
    #                                        accuracy, prespec[_hit],
    #                                        cancelled, hop_idx, ...)

    def per_tenant(self) -> dict[int, dict[str, float]]:
        """Per-tenant metric slices (empty when served without tenants).
        NaN-safe: an empty stream (or an all-shed tenant slice) reports
        NaN latencies instead of crashing ``np.percentile``."""
        if self.tenant_ids is None:
            return {}
        out = {}
        for t in np.unique(self.tenant_ids):
            m = self.tenant_ids == t
            lat = self.latencies[m]
            out[int(t)] = {
                "n": int(m.sum()),
                "dar": _safe_mean(self.accepts[m]),
                "doc_hit_rate": _safe_mean(self.doc_hits[m]),
                "avg_latency_s": _safe_mean(lat),
                "p95_latency_s": _safe_pct(lat, 95),
                "full_retrievals": int(np.sum((self.channels == "full") & m)),
                "shared_accepts": int(np.sum((self.channels == "shared") & m)),
            }
            if self.complex_records is not None:
                sel = [c for c in self.complex_records
                       if c["tenant"] == int(t) and c["served"]]
                out[int(t)].update({
                    "hop_requests": int(np.sum((self.hop > 0) & m)),
                    "complex_n": len(sel),
                    "complex_e2e_avg_s": _safe_mean(
                        [c["e2e_s"] for c in sel]),
                    "complex_dar": _safe_mean([c["dar"] for c in sel]),
                    "complex_accuracy": _safe_mean(
                        [c["accuracy"] for c in sel]),
                })
        return out

    def summary(self) -> dict[str, float]:
        out = super().summary()
        lat = self.latencies
        # admitted = everything the scheduler actually served (shed
        # rejections complete instantly at zero latency and would deflate
        # the percentiles the SLO verdicts assert on)
        admitted = self.channels != "shed"
        adm_lat = lat[admitted]
        makespan = (float(self.t_done.max() - self.t_arrive.min())
                    if len(lat) else float("nan"))
        out.update({
            "p50_latency_s": _safe_pct(lat, 50),
            "p95_latency_s": _safe_pct(lat, 95),
            "p99_latency_s": _safe_pct(lat, 99),
            "p99_admitted_latency_s": _safe_pct(adm_lat, 99),
            "makespan_s": makespan,
            "throughput_qps": (len(lat) / max(makespan, 1e-9)
                               if len(lat) else 0.0),
            "shared_accepts": int(np.sum(self.channels == "shared")),
            "reval_accepts": int(np.sum(self.channels == "reval")),
            "full_retrievals": int(self.full_retrievals),
            "spec_batches": int(self.spec_batches),
            "full_batches": int(self.full_batches),
            "max_inflight_full_batches": int(self.max_inflight_full_batches),
            "max_inflight_spec_batches": int(self.max_inflight_spec_batches),
            "edge_replays": int(self.edge_replays),
            "shed": int(np.sum(self.channels == "shed")),
            "degraded": int(np.sum(self.channels == "degraded")),
            "failed": int(np.sum(self.channels == "failed")),
            "retries": int(self.retries),
            "hedges": int(self.hedges),
            "worker_deaths": int(self.worker_deaths),
            "replica_rebuilds": int(self.replica_rebuilds),
        })
        if self.slo_deadline_s is not None:
            # goodput: genuinely served results (draft/reval/shared/full —
            # shed delivered nothing, degraded an unvalidated best-effort
            # draft) completing within the deadline, per second of stream
            good = (np.isin(self.channels,
                            ("draft", "reval", "shared", "full"))
                    & (lat <= self.slo_deadline_s))
            out["slo_deadline_s"] = float(self.slo_deadline_s)
            out["goodput_qps"] = (int(good.sum()) / max(makespan, 1e-9)
                                  if len(lat) else 0.0)
            out["slo_attainment"] = _safe_mean(good[admitted])
        if self.complex_records is not None:
            # per-complex-query aggregation: end-to-end latency of the hop
            # CHAIN (hop-1 arrival -> final answer, reasoning included),
            # chain-level DAR/accuracy, and cross-hop pre-speculation
            # telemetry (rate = complex queries whose next hop launched
            # from a draft bridge; hit rate = drafted bridges the
            # validated resolution confirmed)
            recs = self.complex_records
            fin = [c for c in recs if c["served"]]
            e2e = np.array([c["e2e_s"] for c in fin])
            multi = [c for c in fin if c["hops"] > 1]
            pres = [c for c in multi if c["prespec"]]
            out.update({
                "cancelled": int(np.sum(self.channels == "cancelled")),
                "complex_n": len(recs),
                "complex_served": len(fin),
                "complex_e2e_avg_s": _safe_mean(e2e),
                "complex_e2e_p95_s": _safe_pct(e2e, 95),
                "complex_retrieval_avg_s": _safe_mean(
                    e2e - np.array([c["reason_s"] for c in fin])),
                "complex_dar": _safe_mean([c["dar"] for c in fin]),
                "complex_accuracy": _safe_mean(
                    [c["accuracy"] for c in fin]),
                "hop_prespec_rate": _safe_mean(
                    [c["prespec"] for c in multi]),
                "hop_prespec_hit_rate": _safe_mean(
                    [bool(c["prespec_hit"]) for c in pres]),
                "hops_cancelled": int(sum(c["cancelled"] for c in recs)),
            })
            # per-hop aggregation over the sub-request population
            done = self.channels != "cancelled"
            for h in range(1, int(self.hop.max()) + 1):
                mh = (self.hop == h) & done
                out[f"hop{h}_n"] = int(mh.sum())
                out[f"hop{h}_avg_latency_s"] = _safe_mean(
                    self.latencies[mh])
                out[f"hop{h}_dar"] = _safe_mean(self.accepts[mh])
        return out


@dataclasses.dataclass(eq=False)      # identity semantics: requests live in
#                                       deques/registries and carry numpy
#                                       fields a field-wise __eq__ would
#                                       choke on
class _Request:
    idx: int
    q: dict
    t_arrive: float
    tenant: int = 0                        # tenant partition of this request
    edge_rtt: float = 0.0
    t_rejected: float = 0.0
    val_ids: np.ndarray | None = None
    draft_ids: np.ndarray | None = None
    ids: np.ndarray | None = None
    channel: str = "pending"
    t_done: float = -1.0
    cloud_s: float = 0.0
    slot: int = -1                         # leader-registry slot
    leader_idx: int = -1                   # leader request idx (followers)
    followers: list = dataclasses.field(default_factory=list)
    replica: int = -1                      # edge replica that speculated it
    cache_version: int = -1                # that replica's version at
    #                                        dispatch (-1: R == 1 primary)
    reroute: bool = False                  # speculation lost to a replica
    #                                        crash: straight to the full
    #                                        channel (no re-validation, no
    #                                        sharing registry — val_ids are
    #                                        the -1 sentinel)
    spans: dict = dataclasses.field(default_factory=empty_spans)
    #                                        per-stage latency breakdown
    #                                        (serving/tracing.py STAGES);
    #                                        sums to t_done - t_arrive
    # -- agentic hop graphs (serving/agentic.py) ---------------------------
    hop: int = 0                           # hop index in a complex query's
    #                                        chain (0: plain single-hop)
    cq: Any = None                         # owning _HopGraph (hop requests)
    speculative: bool = False              # launched from a DRAFT bridge,
    #                                        not yet confirmed by the
    #                                        parent hop's resolution
    cancelled: bool = False                # mis-speculation cancel landed
    t_cancel: float = -1.0                 # virtual time it landed
    stage: str = "new"                     # lifecycle position (new/admit/
    #                                        spec/cloudq/follower/cloud/
    #                                        done) — how a cancel finds the
    #                                        container holding the request
    lead: Any = None                       # leader _Request (followers)
    t_sdone: float = -1.0                  # in-flight speculation batch's
    #                                        completion time (mid-spec
    #                                        cancel claws back the tail)


class _HopGraph:
    """Serve-time state of ONE complex query's hop chain (the scheduler
    side of a :class:`~repro.serving.agentic.HopPlan` continuation).

    Tracks the authoritative per-hop results (accepts/hits), the one
    in-flight speculative next-hop child (if cross-hop pre-speculation
    launched it), and the chain's completion."""

    __slots__ = ("plan", "root_idx", "tenant", "t_start", "hits", "accepts",
                 "hop_idx", "spec_child", "prespec", "prespec_hit",
                 "cancelled", "done", "t_done", "served")

    def __init__(self, plan, root_idx: int, tenant: int, t_start: float):
        self.plan = plan
        self.root_idx = root_idx
        self.tenant = tenant
        self.t_start = t_start
        self.hits: list[bool] = []
        self.accepts: list[bool] = []
        self.hop_idx: list[int] = []
        self.spec_child = None          # in-flight speculative _Request
        self.prespec = False            # a hop was launched pre-validation
        self.prespec_hit: bool | None = None
        self.cancelled = 0              # hops cancelled on mis-speculation
        self.done = False
        self.t_done = -1.0
        self.served = False             # final hop delivered a result


# event-kind priorities at equal timestamps: full results ingest before a
# speculation batch dispatched at the same instant (cache freshness), and
# both before new arrivals join the queue.  Fault events (kind -1) fire
# FIRST at their instant — a completion scheduled for the same moment a
# crash lands is already lost work.  Kinds 4..7 exist only under a
# non-empty fault plan (the fault-free heap never sees them).
_FAULT = -1
_FULL_DONE, _SPEC_DONE, _ARRIVE, _FULL_TIMER = 0, 1, 2, 3
_DEADLINE, _RETRY, _WORKER_UP, _REBUILT = 4, 5, 6, 7


class ContinuousBatchingScheduler:
    """Continuous-batching HaS engine over an open-loop arrival process.

    Each ``serve`` call is an independent stream: the cache is re-initialised
    so that (seed, arrivals, queries) fully determine the result.
    """

    def __init__(self, service: RetrievalService, cfg: HasConfig | None = None,
                 sched: SchedulerConfig | None = None, seed: int = 0,
                 index=None):
        self.s = service
        self.cfg = cfg or HasConfig(k=service.k, d=service.world.cfg.d)
        self.sched = sched or SchedulerConfig()
        sc = self.sched
        # batching knobs: a direct SchedulerConfig(...) used to accept
        # nonsense silently (launch/serve.py validated its own flags, this
        # path did not) — a 0-wide batch livelocks the loop, a negative
        # timer fires in the past
        if sc.max_spec_batch < 1:
            raise ValueError(
                f"max_spec_batch must be >= 1, got {sc.max_spec_batch}")
        if sc.full_batch < 1:
            raise ValueError(f"full_batch must be >= 1, got {sc.full_batch}")
        if sc.full_max_wait_s < 0:
            raise ValueError(
                f"full_max_wait_s must be >= 0, got {sc.full_max_wait_s}")
        if sc.ingest_batch < 1:
            raise ValueError(
                f"ingest_batch must be >= 1, got {sc.ingest_batch}")
        # overload-control knobs
        if sc.overload_policy not in ("none", "shed", "degrade"):
            raise ValueError(
                f"overload_policy must be 'none', 'shed' or 'degrade', got "
                f"{sc.overload_policy!r}")
        if sc.slo_deadline_s is not None and sc.slo_deadline_s <= 0:
            raise ValueError(
                f"slo_deadline_s must be > 0 (or None), got "
                f"{sc.slo_deadline_s}")
        if sc.overload_policy != "none" and sc.slo_deadline_s is None:
            raise ValueError(
                f"overload_policy={sc.overload_policy!r} needs "
                "slo_deadline_s — the policy triggers on the predicted "
                "completion time blowing the deadline")
        if not (0 < sc.overload_exit_frac <= 1):
            raise ValueError(
                f"overload_exit_frac must be in (0, 1], got "
                f"{sc.overload_exit_frac}")
        # fault-handling knobs
        if sc.retry_max < 0:
            raise ValueError(f"retry_max must be >= 0, got {sc.retry_max}")
        if sc.retry_backoff_s < 0:
            raise ValueError(
                f"retry_backoff_s must be >= 0, got {sc.retry_backoff_s}")
        if sc.hedge_after is not None and not sc.hedge_after > 1:
            raise ValueError(
                f"hedge_after must be > 1 (or None to disable hedging), "
                f"got {sc.hedge_after}")
        if sc.fault_plan is not None and not isinstance(sc.fault_plan,
                                                        FaultPlan):
            raise TypeError(
                f"fault_plan must be a FaultPlan (or None), got "
                f"{type(sc.fault_plan).__name__} — parse CLI specs with "
                "FaultPlan.parse()")
        # tenant-partitioned cache: T == 1 keeps the historical unstacked
        # layout (bit-exact legacy path); T > 1 stacks [T, ...] partitions
        # with per-tenant capacity cfg.h_max / cfg.doc_cap EACH
        self.n_tenants = max(1, int(self.sched.n_tenants))
        if self.sched.tenant_weights is not None:
            if len(self.sched.tenant_weights) != self.n_tenants:
                raise ValueError(
                    f"tenant_weights needs {self.n_tenants} entries, got "
                    f"{len(self.sched.tenant_weights)}")
            if any(w <= 0 for w in self.sched.tenant_weights):
                raise ValueError("tenant_weights must be positive")
            self.tenant_weights = tuple(
                float(w) for w in self.sched.tenant_weights)
        else:
            self.tenant_weights = (1.0,) * self.n_tenants
        if self.sched.tenant_quota is not None and self.sched.tenant_quota < 1:
            # quota 0 would livelock the loop: fair_pick could never drain
            # the admission queues, yet they would keep the edge dispatching
            raise ValueError(
                f"tenant_quota must be >= 1 (or None), got "
                f"{self.sched.tenant_quota}")
        self.state = self._init_state()
        self.index = index if index is not None else build_ivf(
            service.corpus, self.cfg.n_buckets, seed=seed)
        self.fuzzy_scope = _fuzzy_scope(self.cfg, self.index)
        self._share_tau = (self.sched.share_tau if self.sched.share_tau
                           is not None
                           else DEFAULT_SHARE_TAU_MULT * self.cfg.tau)
        # cloud-stage worker pool: one slot per backend worker (mesh shard
        # group / warm-standby replica); the deprecated scalar still wins
        # when an old config sets it
        if self.sched.max_inflight_full is not None:
            warnings.warn(
                "SchedulerConfig.max_inflight_full is deprecated; the "
                "full-retrieval stage is a worker pool sized by "
                "service.backend.n_workers (see retrieval/service.py)",
                DeprecationWarning, stacklevel=2)
            self.n_full_workers = max(1, int(self.sched.max_inflight_full))
        else:
            self.n_full_workers = max(1, int(service.backend.n_workers))
        # edge speculation replica pool: R dispatch slots, each a warm cache
        # replica fed by bounded-lag delta replay (serving/edge_pool.py);
        # R == 1 keeps the historical single-edge path (the slot IS the
        # primary state — zero lag, no pool object) bit-exactly
        if self.sched.edge_replicas < 1:
            raise ValueError(
                f"edge_replicas must be >= 1, got {self.sched.edge_replicas}")
        if self.sched.edge_sync_every < 1:
            raise ValueError(
                f"edge_sync_every must be >= 1, got "
                f"{self.sched.edge_sync_every}")
        self.n_edge_replicas = int(self.sched.edge_replicas)
        self.edge_pool: EdgeReplicaPool | None = None   # built per serve()
        self._keep_edge_log = False    # audits/tests: retain the delta log
        self._inj: FaultInjector | None = None          # built per serve()
        # fault-plan topology validation: every targeted worker/replica must
        # exist, and the plan must not permanently kill the whole cloud
        # pool (queued leaders would then never complete — a silent
        # deadlock, not a chaos result)
        self._fault_mode = (self.sched.fault_plan is not None
                            and len(self.sched.fault_plan) > 0)
        if self._fault_mode:
            perm_dead = set()
            for i, ev in enumerate(self.sched.fault_plan.events):
                if ev.kind in ("worker_crash", "straggler", "search_fail"):
                    if ev.target >= self.n_full_workers:
                        raise ValueError(
                            f"fault_plan events[{i}] ({ev.kind}) targets "
                            f"worker {ev.target} but the backend has only "
                            f"{self.n_full_workers} worker(s)")
                    if ev.kind == "worker_crash" and ev.down_s == 0.0:
                        perm_dead.add(ev.target)
                elif ev.kind == "replica_crash":
                    if self.n_edge_replicas < 2:
                        raise ValueError(
                            f"fault_plan events[{i}] (replica_crash) needs "
                            "edge_replicas >= 2 — with R == 1 the lone "
                            "slot IS the primary and there is no pool to "
                            "fail over to")
                    if ev.target >= self.n_edge_replicas:
                        raise ValueError(
                            f"fault_plan events[{i}] (replica_crash) "
                            f"targets replica {ev.target} but "
                            f"edge_replicas={self.n_edge_replicas}")
                else:                          # delta_drop / delta_dup
                    if self.n_edge_replicas < 2:
                        raise ValueError(
                            f"fault_plan events[{i}] ({ev.kind}) needs "
                            "edge_replicas >= 2 — the replication delta "
                            "log only exists with an edge pool")
                    if (ev.kind == "delta_drop"
                            and self.sched.free_ingest_replay):
                        raise ValueError(
                            f"fault_plan events[{i}] (delta_drop) is "
                            "incompatible with free_ingest_replay=True — "
                            "gap detection fires at dispatch-time replay, "
                            "which the compat accounting bypasses")
            if len(perm_dead) >= self.n_full_workers:
                raise ValueError(
                    "fault_plan permanently crashes all "
                    f"{self.n_full_workers} cloud worker(s) (down_s=0) — "
                    "queued full retrievals could never complete")
        # host corpus view: pool delta vectors (R > 1) and the
        # score-weighted follower rerank both need numpy gathers
        self._corpus_np = np.asarray(service.corpus)
        # late re-validation: homology re-check of queued validation drafts
        # against the updated query cache (no fuzzy scan needed); tenant
        # mode gathers each row's partition table inside the same program
        if self.n_tenants == 1:
            self._revalidate = jax.jit(jax.vmap(
                reidentify, in_axes=(0, None, None, None)))
        else:
            self._revalidate = jax.jit(jax.vmap(
                lambda v, t, qdi, qv, tau: reidentify(v, qdi[t], qv[t], tau),
                in_axes=(0, 0, None, None, None)))
        # warmup: pre-compile the fused programs at BOTH device shapes the
        # loop uses — the [max_spec_batch, d] speculation program and the
        # [ingest_batch, ...] fused cache ingest — plus the full-search and
        # re-validation programs, so first-request latency is never billed
        # to compilation
        sc, d, k = self.sched, service.world.cfg.d, self.cfg.k
        spec_tids = (None if self.n_tenants == 1
                     else jnp.zeros((sc.max_spec_batch,), jnp.int32))
        jax.block_until_ready(speculate_batch(
            self.cfg, self.state, self.index,
            jnp.zeros((sc.max_spec_batch, d)), backend=sc.backend,
            tenant_ids=spec_tids))
        scratch = self._init_state()            # donated, then discarded
        jax.block_until_ready(cache_update_batched(
            self.cfg, scratch, jnp.zeros((sc.ingest_batch, d)),
            jnp.zeros((sc.ingest_batch, k), jnp.int32),
            jnp.zeros((sc.ingest_batch, k, d)),
            jnp.zeros((sc.ingest_batch,), bool),
            tenant_ids=(None if self.n_tenants == 1
                        else jnp.zeros((sc.ingest_batch,), jnp.int32))).q_ptr)
        service.backend.search(
            jnp.zeros((sc.full_batch, d)))[0].block_until_ready()
        reval_args = ((jnp.zeros((sc.full_batch, k), jnp.int32),)
                      if self.n_tenants == 1
                      else (jnp.zeros((sc.full_batch, k), jnp.int32),
                            jnp.zeros((sc.full_batch,), jnp.int32)))
        jax.block_until_ready(self._revalidate(
            *reval_args, self.state.query_doc_ids, self.state.query_valid,
            jnp.float32(self.cfg.tau)))
        nrows = sc.max_pending_leaders + sc.max_spec_batch
        jax.block_until_ready(intra_batch_share(
            jnp.full((nrows, k), -1, jnp.int32), jnp.zeros((nrows,), bool),
            jnp.float32(self._share_tau), jnp.zeros((nrows,), bool),
            None if self.n_tenants == 1
            else jnp.zeros((nrows,), jnp.int32)))

    def _init_state(self):
        return (init_has_state(self.cfg) if self.n_tenants == 1
                else init_tenant_states(self.cfg, self.n_tenants))

    # -- modeled service times (bandwidth-bound coalesced scans) -----------

    def _spec_time(self, b: int) -> float:
        """Edge time for one speculation batch of b queries: the cache
        channel streams the doc store once (all T tenant partitions — the
        partitioned scan is one fused program over the stacked store); the
        fuzzy channel streams the union of probed buckets (capped at the
        whole index)."""
        lat = self.s.latency
        fuzzy = lat.scan_time(min(b * self.fuzzy_scope, 1.0)
                              * lat.target_corpus * 2.0 + self.cfg.n_buckets)
        return fuzzy + lat.scan_time(self.cfg.doc_cap * self.n_tenants)

    def _full_time(self, b: int) -> float:
        """Modeled cloud compute of one coalesced backend dispatch."""
        return self.s.backend.latency(b)

    # -- fused cache ingest ------------------------------------------------

    def _ingest(self, batch, ingest_key=None):
        """Fold a completed full-retrieval batch (leaders followed by their
        followers, i.e. the attribution computed by ``intra_batch_share``)
        into the cache via ``cache_update_chunked`` — one device dispatch
        per ``ingest_batch`` chunk instead of one per request.  Row order
        matches the old per-request loop, so the final state is identical.
        The backend is then notified (``on_ingest``) so replica-style
        backends can reconcile standby caches, and the same rows are
        appended to the edge pool's delta log (bounded-lag replay keeps
        the speculation replicas within ``edge_sync_every`` rows of this
        primary).

        ``ingest_key`` stamps the batch with a stable identity so every
        replication sink (standbys, edge pool) is IDEMPOTENT on it.  Under
        a fault plan, the replication channel itself can misbehave here: a
        ``delta_dup`` event re-sends the batch (absorbed bit-exactly by
        the key), a ``delta_drop`` loses it to the edge pool (the primary
        and cloud standbys folded it; the pool's sequence numbers advance
        with no rows, so the next replica replay fails loudly on the gap
        instead of silently diverging — see ``serving/faults.py``)."""
        rows = []
        for r in batch:
            if not r.cancelled:            # a cancelled hop's row (sentinel
                rows.append(r)             # ids) never folds into the cache
            if self.sched.ingest_followers:
                rows.extend(f for f in r.followers if not f.cancelled)
        if not rows:
            return
        q_embs = np.stack([r.q["emb"] for r in rows])
        full_ids = np.stack([r.ids for r in rows])
        tids = (None if self.n_tenants == 1
                else np.array([r.tenant for r in rows], np.int32))
        self.state = cache_update_chunked(
            self.cfg, self.state, q_embs, full_ids,
            corpus=self.s.corpus, chunk=self.sched.ingest_batch,
            tenant_ids=tids)
        fault = self._inj.delta_fault() if self._inj is not None else None
        self.s.backend.on_ingest(q_embs, full_ids, self.state,
                                 tenant_ids=tids, ingest_key=ingest_key)
        if fault == "dup":
            # duplicated fan-out send — the standbys' ingest keys drop it
            self.s.backend.on_ingest(q_embs, full_ids, self.state,
                                     tenant_ids=tids, ingest_key=ingest_key)
        if self.edge_pool is not None:
            if fault == "drop":
                self.edge_pool.mark_lost(len(rows))
                return
            vecs = gather_doc_vecs(self._corpus_np, full_ids)
            self.edge_pool.record_batch(q_embs, full_ids, vecs, self.state,
                                        tenant_ids=tids,
                                        ingest_key=ingest_key)
            if fault == "dup":
                self.edge_pool.record_batch(q_embs, full_ids, vecs,
                                            self.state, tenant_ids=tids,
                                            ingest_key=ingest_key)

    # -- event loop --------------------------------------------------------

    def serve(self, queries, arrivals: np.ndarray | None = None,
              dataset: str = "granola", llms=LLMS, seed: int = 0,
              tenant_ids: np.ndarray | None = None) -> SchedResult:
        sc = self.sched
        cap = sc.max_pending_leaders
        T = self.n_tenants
        n = len(queries)
        if arrivals is None:                     # fully saturated admission
            arrivals = np.zeros(n)
        arrivals = np.asarray(arrivals, np.float64)
        assert arrivals.shape == (n,)
        # tenant resolution: explicit array wins, else the queries' own
        # "tenant" tags, else everyone in partition 0
        if tenant_ids is None:
            tids = np.array([int(q.get("tenant", 0)) for q in queries],
                            np.int32)
        else:
            tids = np.asarray(tenant_ids, np.int32)
            assert tids.shape == (n,)
        if n and (tids.min() < 0 or tids.max() >= T):
            raise ValueError(
                f"tenant ids must be in [0, {T}); got range "
                f"[{tids.min()}, {tids.max()}] — raise "
                f"SchedulerConfig.n_tenants")

        self.state = self._init_state()          # independent stream
        # edge replica pool: fresh replicas + delta log per stream (R == 1
        # keeps the historical single-slot path — the slot IS the primary)
        R = self.n_edge_replicas
        # fixed accounting replays at speculation-dispatch time (charged to
        # the slot); the compat flag restores the free record_batch cadence
        self.edge_pool = None if R == 1 else EdgeReplicaPool(
            self.cfg, R, sync_every=sc.edge_sync_every, n_tenants=T,
            replay_batch=sc.ingest_batch,       # reuse the warmed-up shape
            compact=not self._keep_edge_log,
            sync_on_record=sc.free_ingest_replay)
        pool = self.edge_pool
        rtt_rng = np.random.default_rng(seed)    # scheduler-owned RTT stream
        lat = self.s.latency

        reqs = [_Request(idx=i, q=q, t_arrive=float(arrivals[i]),
                         tenant=int(tids[i]))
                for i, q in enumerate(queries)]
        heap: list[tuple[float, int, int, Any]] = []
        seq = 0
        for r in reqs:
            heapq.heappush(heap, (r.t_arrive, _ARRIVE, seq, r))
            seq += 1

        # -- agentic hop graphs (serving/agentic.py) -----------------------
        # A query carrying a HopPlan continuation ("hop_plan") is the hop-1
        # sub-query of a complex multi-hop request: when a hop resolves, the
        # graph reasons out the bridge entity (reason_s on the clock, the
        # "reason" span) and enqueues the next hop; rejected drafts
        # PRE-SPECULATE the next hop ahead of validation (speculate_hops),
        # and mis-speculations cancel deterministically ("cancelled"
        # channel).  Everything below is gated so a trace with no hop_plan
        # queries adds zero rng draws, heap events and span charges — bit-
        # identical to the pre-hop-graph goldens.
        reason_s = lat.reason_time()
        graphs: list[_HopGraph] = []
        for r in reqs:
            plan = r.q.get("hop_plan")
            if plan is not None:
                r.hop = 1
                r.cq = _HopGraph(plan, r.idx, r.tenant, r.t_arrive)
                graphs.append(r.cq)
        agentic = bool(graphs)

        # -- fault injection + self-healing (serving/faults.py) ------------
        # Everything below is gated on fault_mode: an empty/absent plan
        # adds NO heap events, NO rng draws and NO bookkeeping, so the
        # fault-free schedule is bit-identical to pre-fault builds (the
        # golden-trace tests pin this).
        fault_mode = self._fault_mode
        inj = self._inj = FaultInjector(sc.fault_plan) if fault_mode else None
        detector = None
        cloud_free: list[int] = []     # free cloud worker ids (fault mode)
        busy: dict[int, dict] = {}     # worker id -> live dispatch/backoff
        dead_workers: set[int] = set()
        dead_replicas: set[int] = set()
        spec_epoch = [0] * R           # bumped on replica crash: stale
        #                                _SPEC_DONE events are ignored
        spec_inflight: dict[int, tuple] = {}   # replica -> in-flight batch
        ingest_seq = 0                 # stable ingest_key counter
        retries = hedges = worker_deaths = replica_rebuilds = 0
        if fault_mode:
            cloud_free = list(range(self.n_full_workers))
            detector = StragglerDetector(StragglerConfig(
                deadline_factor=(sc.hedge_after if sc.hedge_after is not None
                                 else 3.0)))
            for ev in sc.fault_plan.sorted_events():
                heapq.heappush(heap, (ev.t, _FAULT, seq, ev))
                seq += 1

        # per-tenant FIFO queues; batches are assembled by weighted-fair
        # selection across them (lowest served/weight first), so one
        # tenant's burst cannot monopolize the edge or the cloud stage.
        # T == 1 degenerates to the historical single FIFO, bit-exactly.
        admission = [collections.deque() for _ in range(T)]
        leaders = [collections.deque() for _ in range(T)]    # queued leaders
        spec_served = [0.0] * T        # weighted-fair virtual service
        full_served = [0.0] * T
        edge_free = list(range(R))     # free speculation dispatch slots
        max_inflight_spec = 0          # edge-pool concurrency high-water
        inflight_full = 0              # busy cloud-pool workers
        max_inflight = 0               # pool-concurrency high-water mark
        timer_armed = False
        spec_batches = full_batches = full_retrievals = 0

        # -- SLO-aware overload control (fluid-model predictor) ------------
        # Steady-state drain rates of the two stages from the modeled
        # service times; the predictor is the QUEUE WAIT a reject-path
        # request admitted NOW would see — everything queued or in flight
        # ahead of it at both stages, over each stage's drain rate.
        # Service time itself is load-independent (the part no admission
        # decision can avoid), so the trigger is on the waiting alone.
        # Hysteresis (enter above the deadline, exit at
        # overload_exit_frac of it) keeps the policy a deterministic step
        # function of the virtual clock.
        policy = sc.overload_policy
        overloaded = False
        if policy != "none":
            mean_cloud_rtt = 0.5 * (lat.cloud_rtt[0] + lat.cloud_rtt[1])
            spec_rate = (R * sc.max_spec_batch
                         / self._spec_time(sc.max_spec_batch))
            cloud_rate = (self.n_full_workers * sc.full_batch
                          / (self._full_time(sc.full_batch)
                             + mean_cloud_rtt))

        def predicted_wait() -> float:
            n_adm = sum(len(q) for q in admission)
            n_lead = sum(len(q) for q in leaders)
            busy_spec = R - len(edge_free)
            # pessimistic: by the time this request is rejected at the
            # edge, everything admitted ahead of it may have been rejected
            # too — the admission backlog feeds BOTH stage queues on the
            # reject path the SLO must cover
            return ((n_adm + busy_spec * sc.max_spec_batch) / spec_rate
                    + (n_adm + n_lead + inflight_full * sc.full_batch)
                    / cloud_rate)

        def update_overload():
            nonlocal overloaded
            p = predicted_wait()
            if overloaded:
                overloaded = p > sc.overload_exit_frac * sc.slo_deadline_s
            else:
                overloaded = p > sc.slo_deadline_s

        def fair_pick(queues, served, limit, quota=None):
            """Pop up to ``limit`` requests across per-tenant FIFO queues:
            repeatedly take from the non-empty tenant with the lowest
            weighted virtual service (ties -> lowest tenant id), bumping
            its counter by 1/weight.  ``quota`` caps one tenant's rows per
            call (admission quota — strict isolation knob)."""
            picked, taken = [], [0] * T
            while len(picked) < limit:
                best, best_key = -1, None
                for u in range(T):
                    if not queues[u] or (quota is not None
                                         and taken[u] >= quota):
                        continue
                    key = served[u]
                    if best_key is None or key < best_key:
                        best, best_key = u, key
                if best < 0:
                    break
                picked.append(queues[best].popleft())
                served[best] += 1.0 / self.tenant_weights[best]
                taken[best] += 1
            return picked

        # fixed-shape sharing registry over ALL pending (queued + in-flight)
        # leaders; new rejects are scored against it in one device call
        reg_vals = np.full((cap, self.cfg.k), -1, np.int32)
        reg_valid = np.zeros(cap, bool)
        reg_tenant = np.zeros(cap, np.int32)
        reg_req: list[_Request | None] = [None] * cap
        # min-heap of free slot ids: pop -> lowest, O(log cap) per
        # completion (identical lowest-slot-first allocation as the old
        # descending-sorted list, without its O(cap log cap) re-sort —
        # the golden-trace tests pin the equivalence)
        free_slots = list(range(cap))

        def registry_add(r: _Request):
            if not free_slots:
                return                      # registry full: r stays a leader
            slot = heapq.heappop(free_slots)
            reg_vals[slot] = r.val_ids
            reg_valid[slot] = True
            reg_tenant[slot] = r.tenant
            reg_req[slot] = r
            r.slot = slot

        def registry_remove(r: _Request):
            if r.slot >= 0:
                reg_valid[r.slot] = False
                reg_req[r.slot] = None
                heapq.heappush(free_slots, r.slot)
                r.slot = -1

        def _admit_chunk(group: list[_Request]):
            g = len(group)
            vals = np.concatenate([
                reg_vals,
                np.stack([r.val_ids for r in group]),
                np.full((sc.max_spec_batch - g, self.cfg.k), -1, np.int32)])
            rejected = np.zeros(cap + sc.max_spec_batch, bool)
            rejected[cap:cap + g] = True
            pending = np.concatenate(
                [reg_valid, np.zeros(sc.max_spec_batch, bool)])
            if T == 1:
                share_tids = None
            else:
                # tenant tags for registry rows + the group + inert padding:
                # the election masks cross-tenant pairs, so a follower can
                # only attach to a leader of its own partition
                share_tids = jnp.asarray(np.concatenate([
                    reg_tenant,
                    np.array([r.tenant for r in group], np.int32),
                    np.zeros(sc.max_spec_batch - g, np.int32)]))
            out = intra_batch_share(jnp.asarray(vals), jnp.asarray(rejected),
                                    jnp.float32(self._share_tau),
                                    jnp.asarray(pending), share_tids)
            leader_of = np.asarray(out["leader"])
            is_leader = np.asarray(out["is_leader"])
            for j, r in enumerate(group):
                row = cap + j
                if is_leader[row]:
                    leaders[r.tenant].append(r)
                    registry_add(r)
                    r.stage = "cloudq"
                else:
                    li = leader_of[row]
                    lead = reg_req[li] if li < cap else group[li - cap]
                    lead.followers.append(r)
                    r.lead, r.stage = lead, "follower"

        def admit_rejects(group: list[_Request]):
            """Share-or-lead election for newly rejected requests against the
            pending-leader registry + each other (admission order)."""
            if not sc.share:
                for r in group:
                    leaders[r.tenant].append(r)
                    registry_add(r)
                    r.stage = "cloudq"
                return
            for i in range(0, len(group), sc.max_spec_batch):
                _admit_chunk(group[i:i + sc.max_spec_batch])

        def dispatch_spec(t: float):
            nonlocal seq, spec_batches, max_inflight_spec, replica_rebuilds
            # staleness-aware admission: the batch goes to the freshest
            # free replica (highest cache version); R == 1 — the lone slot
            # is the primary itself (zero lag, the historical path)
            r_id = edge_free[0] if pool is None else pool.freshest(edge_free)
            edge_free.remove(r_id)
            # bounded-lag replay ON the clock: a replica edge_sync_every or
            # more rows behind catches up before its batch runs, and the
            # replay occupies the dispatching slot (compat mode keeps the
            # historical free record_batch-time cadence instead)
            replay_s = 0.0
            if (pool is not None and not sc.free_ingest_replay
                    and pool.lag(r_id) >= sc.edge_sync_every):
                try:
                    rows = pool.sync(r_id)
                    replay_s = lat.ingest_time(rows, self.cfg.doc_cap,
                                               self.cfg.k)
                except (ValueError, LookupError):
                    # delta rows lost in transit (fault plan delta_drop):
                    # replay hit a sequence gap, or the cursor fell behind
                    # the log base entirely — full resync from the primary
                    # instead of serving a diverged cache, charged to the
                    # dispatching slot like any replay
                    pool.resync_from(r_id, self.state, pool.log.head)
                    replay_s = lat.ingest_time(
                        min(pool.log.head, self.cfg.h_max),
                        self.cfg.doc_cap, self.cfg.k)
                    replica_rebuilds += 1
            spec_state = self.state if pool is None else pool.states[r_id]
            version = -1 if pool is None else pool.version(r_id)
            batch = fair_pick(admission, spec_served, sc.max_spec_batch,
                              sc.tenant_quota)
            embs = np.zeros((sc.max_spec_batch, self.s.world.cfg.d),
                            np.float32)
            for j, r in enumerate(batch):
                embs[j] = r.q["emb"]
                r.edge_rtt = rtt_rng.uniform(*lat.edge_rtt)
            if T == 1:
                spec_tids = None
            else:
                batch_tids = np.zeros(sc.max_spec_batch, np.int32)
                for j, r in enumerate(batch):
                    batch_tids[j] = r.tenant
                spec_tids = jnp.asarray(batch_tids)
            # acceptance is decided against the SERVING replica's own cache
            # version — a stale replica can only accept drafts its cache
            # actually supports (no phantom accepts)
            out = speculate_batch(self.cfg, spec_state, self.index,
                                  jnp.asarray(embs), backend=sc.backend,
                                  tenant_ids=spec_tids)
            accepts = np.asarray(out["accept"])
            drafts = np.asarray(out["draft_ids"])
            val_ids = np.asarray(out["val_ids"])
            spec_s = self._spec_time(len(batch))
            t_done = t + replay_s + spec_s
            for j, r in enumerate(batch):
                r.replica, r.cache_version = r_id, version
                # hop sub-queries pre-charge their synthesis reasoning to
                # the reason span; the wait starts when it ends (exact
                # no-op for plain requests: x - 0.0 == x)
                r.spans["queue_wait"] += t - r.t_arrive - r.spans["reason"]
                r.spans["replay"] += replay_s
                r.spans["spec"] += spec_s
                r.stage, r.t_sdone = "spec", t_done
                if accepts[j]:
                    r.ids, r.channel = drafts[j], "draft"
                else:
                    r.val_ids, r.draft_ids = val_ids[j], drafts[j]
            heapq.heappush(heap, (t_done, _SPEC_DONE, seq,
                                  (batch, r_id, spec_epoch[r_id])))
            seq += 1
            if fault_mode:
                spec_inflight[r_id] = (batch, t, replay_s, spec_s)
            max_inflight_spec = max(max_inflight_spec, R - len(edge_free))
            spec_batches += 1

        def try_spec(t: float):
            # speculation batches of later admissions overlap on DIFFERENT
            # replicas, the way full retrievals overlap on cloud workers
            while edge_free and any(admission):
                dispatch_spec(t)

        # -- fault-mode cloud dispatch machinery ---------------------------
        # A cloud "group" is one logical batch (leaders + ids) that may be
        # executed by SEVERAL dispatches over its lifetime: the original
        # attempt, backoff retries after transient failures, and hedged
        # re-dispatches racing a straggler.  The first live completion
        # wins; span attribution keeps conservation exact (cloud = the
        # winner's service, retry_backoff = accumulated backoff waits,
        # lost = everything else thrown away between first dispatch and
        # completion).  None of this exists fault-free.

        def cloud_dispatch(g, w, t):
            """Push one cloud attempt of group g on worker w."""
            nonlocal seq
            b = len(g["batch"])
            mult = inj.latency_multiplier(w, t)
            cloud = rtt_rng.uniform(*lat.cloud_rtt) + self._full_time(b) * mult
            disp = {"g": g, "w": w, "t_disp": t,
                    "fails": inj.search_fails(w, t), "live": True}
            g["dispatches"].append(disp)
            busy[w] = disp
            heapq.heappush(heap, (t + cloud, _FULL_DONE, seq, disp))
            seq += 1
            if sc.hedge_after is not None:
                # per-dispatch deadline: adaptive (trailing median of
                # completed attempts) once warmed up, model-derived before
                dl = detector.deadline
                if dl is None:
                    dl = sc.hedge_after * (self._full_time(b)
                                           + lat.cloud_rtt[1])
                disp["dl"] = dl
                heapq.heappush(heap, (t + dl, _DEADLINE, seq, disp))
                seq += 1

        def free_worker(w):
            nonlocal inflight_full
            busy.pop(w, None)
            inflight_full -= 1
            if w not in dead_workers:
                cloud_free.append(w)

        def requeue_group(g, t):
            """Worker crashed under the group's only live dispatch: charge
            the wasted attempt and put the batch back at the FRONT of the
            full-retrieval queue (it has waited longest)."""
            nonlocal retries
            g["done"] = True
            retries += 1
            for r in reversed(g["batch"]):
                if r.cancelled and r.t_done < 0:
                    # cancelled while the attempt was in flight: the crash
                    # settles it now — nothing requeues, the whole attempt
                    # was waste; live followers re-enter the election
                    r.spans["lost"] += max(0.0, r.t_cancel - g["t_first"])
                    fin_cancel(r, r.t_cancel)
                    registry_remove(r)
                    readmit, r.followers = r.followers, []
                    live = []
                    for f in readmit:
                        cq = max(0.0, g["t_first"] - f.t_rejected)
                        f.spans["cloud_queue"] += cq
                        if f.cancelled and f.t_done < 0:
                            f.spans["lost"] += max(
                                0.0, (f.t_cancel - f.t_rejected) - cq)
                            fin_cancel(f, f.t_cancel)
                            continue
                        f.spans["lost"] += max(0.0, (t - f.t_rejected) - cq)
                        f.t_rejected = t
                        live.append(f)
                    admit_rejects(live)
                    continue
                r.spans["retry_backoff"] += g["backoff_s"]
                r.spans["lost"] += max(0.0,
                                       (t - g["t_first"]) - g["backoff_s"])
                kept = []
                for f in r.followers:
                    cq = max(0.0, g["t_first"] - f.t_rejected)
                    f.spans["cloud_queue"] += cq
                    if f.cancelled and f.t_done < 0:
                        f.spans["lost"] += max(
                            0.0, (f.t_cancel - f.t_rejected) - cq)
                        fin_cancel(f, f.t_cancel)
                        continue
                    f.spans["lost"] += max(0.0, (t - f.t_rejected) - cq)
                    f.t_rejected = t
                    kept.append(f)
                r.followers = kept
                r.t_rejected = t
                r.stage = "cloudq"
                leaders[r.tenant].appendleft(r)

        def fail_group(g, t):
            """Retry budget exhausted: the batch fails hard — ``failed``
            channel, sentinel ids, accept False.  Orphaned followers
            re-enter the sharing election (their leader delivered
            nothing; they still need results)."""
            g["done"] = True
            for r in g["batch"]:
                if r.cancelled and r.t_done < 0:
                    # cancelled mid-flight: it finalizes as cancelled, not
                    # failed — the chain already moved on without it
                    r.spans["lost"] += max(0.0, r.t_cancel - g["t_first"])
                    fin_cancel(r, r.t_cancel)
                    registry_remove(r)
                else:
                    r.spans["retry_backoff"] += g["backoff_s"]
                    r.spans["lost"] += max(0.0,
                                           (t - g["t_first"])
                                           - g["backoff_s"])
                    r.ids = np.full(self.cfg.k, -1, np.int32)
                    r.channel = "failed"
                    r.t_done = t
                    r.stage = "done"
                    registry_remove(r)
                readmit, r.followers = r.followers, []
                live = []
                for f in readmit:
                    cq = max(0.0, g["t_first"] - f.t_rejected)
                    f.spans["cloud_queue"] += cq
                    if f.cancelled and f.t_done < 0:
                        f.spans["lost"] += max(
                            0.0, (f.t_cancel - f.t_rejected) - cq)
                        fin_cancel(f, f.t_cancel)
                        continue
                    f.spans["lost"] += max(0.0, (t - f.t_rejected) - cq)
                    f.t_rejected = t
                    live.append(f)
                admit_rejects(live)
                # a failed hop still resolves: the chain proceeds on the
                # guessed bridge (hit False) instead of hanging forever
                if agentic and r.cq is not None and not r.cancelled:
                    resolve(r, r.t_done)

        def complete_group(t, winner):
            """First live completion wins the group: racing dispatches are
            cancelled (their workers free NOW — the winner's result serves
            everyone) and the batch completes with fault-aware span
            attribution summing exactly to each request's latency."""
            nonlocal ingest_seq
            g = winner["g"]
            g["done"] = True
            for d in g["dispatches"]:
                if d["live"]:
                    d["live"] = False
                    free_worker(d["w"])
            detector.observe(full_batches, t - winner["t_disp"])
            batch, ids_full = g["batch"], g["ids_full"]
            n_rows = sum(not r.cancelled for r in batch)
            if sc.ingest_followers:
                n_rows += sum(sum(not f.cancelled for f in r.followers)
                              for r in batch)
            ingest_s = (0.0 if sc.free_ingest_replay else
                        lat.ingest_time(n_rows, self.cfg.doc_cap,
                                        self.cfg.k))
            winner_cloud = t - winner["t_disp"]
            for j, r in enumerate(batch):
                lead_ids = ids_full[j].astype(np.int32)
                if r.cancelled:
                    # cancelled while the group raced faults: everything
                    # it paid for past its first dispatch was waste
                    r.spans["lost"] += max(0.0, r.t_cancel - g["t_first"])
                    fin_cancel(r, r.t_cancel)
                    registry_remove(r)
                else:
                    r.ids = lead_ids
                    r.channel = "full"
                    r.cloud_s = winner_cloud
                    r.spans["cloud"] += winner_cloud
                    r.spans["retry_backoff"] += g["backoff_s"]
                    r.spans["lost"] += max(0.0,
                                           (t - g["t_first"]) - winner_cloud
                                           - g["backoff_s"])
                    r.spans["ingest"] += ingest_s
                    r.spans["edge_rtt"] += r.edge_rtt
                    r.t_done = t + ingest_s + r.edge_rtt
                    r.stage = "done"
                    registry_remove(r)
                for f in r.followers:
                    if f.cancelled:
                        cq = max(0.0, min(g["t_first"], f.t_cancel)
                                 - f.t_rejected)
                        f.spans["cloud_queue"] += cq
                        f.spans["lost"] += max(
                            0.0, (f.t_cancel - f.t_rejected) - cq)
                        fin_cancel(f, f.t_cancel)
                        f.leader_idx = r.idx
                        continue
                    f.ids = (follower_rerank(f, lead_ids)
                             if sc.follower_score_weighted else lead_ids)
                    f.channel = "shared"
                    f.cloud_s = winner_cloud
                    # the follower waited through whatever mix of queue /
                    # service / backoff / waste its leader's group saw
                    # after it attached — split its wait the same way
                    cq = max(0.0, g["t_first"] - f.t_rejected)
                    rem = (t - f.t_rejected) - cq
                    cloud_part = min(rem, winner_cloud)
                    backoff_part = min(rem - cloud_part, g["backoff_s"])
                    f.spans["cloud_queue"] += cq
                    f.spans["cloud"] += cloud_part
                    f.spans["retry_backoff"] += backoff_part
                    f.spans["lost"] += max(0.0,
                                           rem - cloud_part - backoff_part)
                    f.spans["ingest"] += ingest_s
                    f.spans["edge_rtt"] += f.edge_rtt
                    f.t_done = t + ingest_s + f.edge_rtt
                    f.stage = "done"
                    f.leader_idx = r.idx
                if agentic:
                    if r.cq is not None and not r.cancelled:
                        resolve(r, r.t_done)
                    for f in r.followers:
                        if f.cq is not None and not f.cancelled:
                            resolve(f, f.t_done)
            self._ingest(batch, ingest_key=ingest_seq)
            ingest_seq += 1

        def dispatch_full(t: float):
            nonlocal inflight_full, max_inflight, seq, full_batches, \
                full_retrievals
            batch = fair_pick(leaders, full_served, sc.full_batch)
            if agentic:
                # popped from the queues: a resolve-triggered cancel fired
                # by the re-validation below must DEFER (stage "cloud"),
                # not search the deques these rows just left
                for r in batch:
                    r.stage = "cloud"
            # late re-validation: results ingested while these leaders
            # queued may re-identify them now — no cloud work needed
            if sc.revalidate:
                vids = np.full((sc.full_batch, self.cfg.k), -1, np.int32)
                for j, r in enumerate(batch):
                    vids[j] = r.val_ids
                if T == 1:
                    reval_args = (jnp.asarray(vids),)
                else:
                    vtids = np.zeros(sc.full_batch, np.int32)
                    for j, r in enumerate(batch):
                        vtids[j] = r.tenant
                    reval_args = (jnp.asarray(vids), jnp.asarray(vtids))
                acc = np.asarray(self._revalidate(
                    *reval_args, self.state.query_doc_ids,
                    self.state.query_valid, jnp.float32(self.cfg.tau))[0])
                survivors = []
                for j, r in enumerate(batch):
                    # rerouted-after-replica-crash rows carry sentinel
                    # val_ids — they always need the real retrieval
                    if acc[j] and not r.reroute:
                        r.ids, r.channel = r.draft_ids, "reval"
                        r.spans["reval_wait"] += t - r.t_rejected
                        r.spans["edge_rtt"] += r.edge_rtt
                        r.t_done = t + r.edge_rtt
                        r.stage = "done"
                        registry_remove(r)
                        # orphaned followers re-enter the election
                        readmit_followers(r)
                        if agentic and r.cq is not None:
                            resolve(r, r.t_done)
                    else:
                        survivors.append(r)
                batch = survivors
            if agentic:
                # settle members the re-validation resolves cancelled:
                # they were never dispatched — their wait ends at the
                # cancel instant, their followers re-enter the election
                live = []
                for r in batch:
                    if r.cancelled and r.t_done < 0:
                        r.spans["cloud_queue"] += r.t_cancel - r.t_rejected
                        fin_cancel(r, r.t_cancel)
                        registry_remove(r)
                        readmit_followers(r)
                    else:
                        live.append(r)
                batch = live
            b = len(batch)
            if not b:
                return
            embs = np.zeros((sc.full_batch, self.s.world.cfg.d), np.float32)
            for j, r in enumerate(batch):
                embs[j] = r.q["emb"]
                r.spans["cloud_queue"] += t - r.t_rejected
            # one coalesced backend dispatch retrieves every leader; the
            # pool slot stays busy for the modeled service time
            term_kw = {}
            if getattr(self.s.backend, "uses_lexical", False):
                # hybrid cloud stage: thread each leader's query terms into
                # the same dispatch (fixed width keeps the jit cache warm;
                # empty slots stay -1/0 and the lexical channel ignores them)
                tw_w = self.s.backend.q_term_width
                terms = np.full((sc.full_batch, tw_w), -1, np.int32)
                tws = np.zeros((sc.full_batch, tw_w), np.float32)
                for j, r in enumerate(batch):
                    qt = np.asarray(r.q.get("terms", ()), np.int32)[:tw_w]
                    qw = np.asarray(
                        r.q.get("term_weights", ()), np.float32)[:tw_w]
                    terms[j, :qt.shape[0]] = qt
                    tws[j, :qw.shape[0]] = qw
                term_kw = dict(q_terms=jnp.asarray(terms),
                               q_term_weights=jnp.asarray(tws))
            _, ids_full = self.s.backend.search(jnp.asarray(embs), **term_kw)
            ids_full = np.asarray(ids_full)
            if not fault_mode:
                cloud = rtt_rng.uniform(*lat.cloud_rtt) + self._full_time(b)
                heapq.heappush(heap, (t + cloud, _FULL_DONE, seq,
                                      (batch, ids_full, cloud)))
                seq += 1
                inflight_full += 1
                max_inflight = max(max_inflight, inflight_full)
            else:
                w = min(cloud_free)
                cloud_free.remove(w)
                inflight_full += 1
                max_inflight = max(max_inflight, inflight_full)
                g = {"batch": batch, "ids_full": ids_full, "t_first": t,
                     "backoff_s": 0.0, "fails": 0, "done": False,
                     "dispatches": []}
                cloud_dispatch(g, w, t)
            full_batches += 1
            full_retrievals += b

        def try_full(t: float):
            nonlocal timer_armed, seq
            # fault mode tracks worker IDENTITY (crashes / stragglers are
            # per-worker); the free-list gate degenerates to the historical
            # counter gate when nobody ever dies
            while ((len(cloud_free) > 0 if fault_mode
                    else inflight_full < self.n_full_workers)
                   and any(leaders)):
                n_lead = sum(len(q) for q in leaders)
                oldest = min(q[0].t_rejected for q in leaders if q)
                deadline = oldest + sc.full_max_wait_s
                if n_lead < sc.full_batch and t < deadline:
                    if not timer_armed:
                        heapq.heappush(heap, (deadline, _FULL_TIMER, seq,
                                              None))
                        seq += 1
                        timer_armed = True
                    return
                dispatch_full(t)

        def follower_rerank(f: _Request, ids: np.ndarray) -> np.ndarray:
            """Rerank the leader's shared D_full by the FOLLOWER's own
            query-doc scores (stable descending; padded ids last) — the
            homology overlap that elected the pair is order-insensitive,
            so this changes which docs the follower serves first and its
            cache row, never the election itself."""
            scores = np.where(ids >= 0,
                              self._corpus_np[np.maximum(ids, 0)]
                              @ np.asarray(f.q["emb"], np.float32),
                              -np.inf)
            return ids[np.argsort(-scores, kind="stable")]

        # -- agentic hop-graph machinery (inert on plain traces) -----------
        # The continuation protocol: every site that finalizes a request
        # (sets t_done + channel) calls resolve(); resolution reasons out
        # the next hop's bridge entity and spawns it, confirms or cancels
        # the pre-speculated child, and closes the chain on the final hop.
        # All rng the graph consumes lives in per-(query, hop) HopPlan
        # substreams — never the scheduler's rtt_rng — so agentic traffic
        # cannot perturb the plain requests sharing the stream.

        def spawn_hop(cx, h: int, entity: int, t: float,
                      speculative: bool) -> _Request:
            """Synthesize hop ``h``'s sub-query from the (resolved or
            drafted) bridge entity: the reasoning step runs t -> t +
            reason_s on the clock (pre-charged to the new request's
            ``reason`` span), then the sub-query enters admission like any
            arrival, tenant-tagged with its chain's tenant."""
            nonlocal seq
            r = _Request(idx=len(reqs), q=cx.plan.query(h, entity),
                         t_arrive=t, tenant=cx.tenant, hop=h, cq=cx,
                         speculative=speculative, stage="reason")
            r.spans["reason"] = reason_s
            reqs.append(r)
            heapq.heappush(heap, (t + reason_s, _ARRIVE, seq, r))
            seq += 1
            return r

        def fin_cancel(r: _Request, t: float):
            """Finalize a cancelled hop: ``cancelled`` channel, sentinel
            ids (its row NEVER ingests), t_done at the settle instant —
            the caller has already balanced the spans to that instant."""
            r.channel = "cancelled"
            r.ids = np.full(self.cfg.k, -1, np.int32)
            r.t_done = t
            r.stage = "done"
            r.cq.cancelled += 1

        def cancel(r: _Request, t: float) -> bool:
            """Deterministically cancel a mis-speculated hop wherever it
            currently lives.  Queued states settle NOW (spans charged to
            ``t`` exactly — conservation stays bit-exact); in-flight cloud
            work cannot be unsent, so those flag and settle on their
            completion path at this cancel instant.  Returns False when
            the request already finalized (superseded wasted work)."""
            if r.t_done >= 0 or r.cancelled:
                return False
            r.cancelled = True
            r.t_cancel = t
            if r.stage == "reason":        # still synthesizing its query
                r.spans["reason"] = t - r.t_arrive
                fin_cancel(r, t)
            elif r.stage == "admit":
                admission[r.tenant].remove(r)
                r.spans["queue_wait"] += t - r.t_arrive - r.spans["reason"]
                fin_cancel(r, t)
            elif r.stage == "spec":        # mid-speculation: claw back the
                over = r.t_sdone - t       # not-yet-run tail of the batch
                cut = min(over, r.spans["spec"])
                r.spans["spec"] -= cut
                r.spans["replay"] -= over - cut
                fin_cancel(r, t)
            elif r.stage == "cloudq":      # queued leader
                leaders[r.tenant].remove(r)
                registry_remove(r)
                r.spans["cloud_queue"] += t - r.t_rejected
                fin_cancel(r, t)
                readmit_followers(r)       # orphans re-enter the election
            elif r.stage == "follower":
                if r.lead.stage == "cloud":
                    pass                   # leader's batch is in flight:
                    #                        its completion settles the
                    #                        follower at t_cancel
                else:
                    r.lead.followers.remove(r)
                    r.spans["cloud_queue"] += t - r.t_rejected
                    fin_cancel(r, t)
            elif r.stage == "cloud":
                # dispatched: drop the result on completion; deregister
                # NOW so no new follower attaches to a doomed leader
                registry_remove(r)
            return True

        def readmit_followers(r: _Request):
            """Detach ``r``'s followers for re-election, settling any that
            were cancelled while attached (their wait ends at t_cancel)."""
            readmit, r.followers = r.followers, []
            if agentic:
                live = []
                for f in readmit:
                    if f.cancelled and f.t_done < 0:
                        f.spans["cloud_queue"] += f.t_cancel - f.t_rejected
                        fin_cancel(f, f.t_cancel)
                    else:
                        live.append(f)
                readmit = live
            admit_rejects(readmit)

        def finish(cx, r: _Request, t: float):
            """Final hop resolved: the trailing answer-synthesis reasoning
            closes the chain.  Charged on the closing request's own clock
            when its completion IS the chain's last event; a pre-speculated
            final hop that landed before its bridge confirmed charges the
            complex query alone (the request's interval already ended)."""
            if t <= r.t_done:
                r.spans["reason"] += reason_s
                r.t_done += reason_s
                cx.t_done = r.t_done
            else:
                cx.t_done = t + reason_s
            cx.done = True
            cx.served = r.channel in ("draft", "reval", "shared", "full",
                                      "degraded")

        def resolve(r: _Request, t: float):
            """A hop request finalized at virtual time ``t`` (when its
            result reaches the agent): advance the owning hop graph."""
            cx = r.cq
            if cx is None or cx.done or r.cancelled:
                return
            if r.speculative:
                return      # parked: the parent hop's resolution decides
            h = r.hop
            if r.channel == "shed":
                # the chain lost a hop at admission: no bridge, no
                # downstream — the complex query aborts
                cx.done, cx.t_done, cx.served = True, t, False
                if cx.spec_child is not None:
                    cancel(cx.spec_child, t)
                    cx.spec_child = None
                return
            cx.accepts.append(r.channel in ("draft", "reval", "shared"))
            cx.hits.append(False if r.channel == "failed"
                           else cx.plan.hit(h, r.ids))
            cx.hop_idx.append(r.idx)
            if h == cx.plan.hops:
                finish(cx, r, t)
                return
            nxt = cx.plan.bridge(h, cx.hits[-1])
            child, cx.spec_child = cx.spec_child, None
            if child is not None:
                if not child.cancelled and child.q["entity"] == nxt:
                    # pre-speculation CONFIRMED: the drafted bridge matches
                    # the validated one — the in-flight (or finished)
                    # speculative hop becomes the authoritative
                    # continuation, keeping its head start
                    cx.prespec_hit = True
                    child.speculative = False
                    if child.t_done >= 0:
                        resolve(child, max(t, child.t_done))
                    return
                # MIS-SPECULATION: the validated bridge contradicts the
                # drafted one — cancel whatever is still cancellable and
                # re-enqueue the corrected hop (sequential timing from
                # here; a finished child is just superseded wasted work)
                cx.prespec_hit = False
                cancel(child, t)
            spawn_hop(cx, h + 1, nxt, t, speculative=False)

        while heap:
            t, kind, _, payload = heapq.heappop(heap)
            if kind == _ARRIVE:
                if payload.cancelled:
                    continue       # hop cancelled mid-reason: settled there
                if policy == "shed":
                    # admission control: reject NOW when the fluid model
                    # predicts a queue wait past the deadline — zero
                    # latency, zero resources, no rng draws
                    update_overload()
                    if overloaded:
                        payload.channel = "shed"
                        payload.ids = np.full(self.cfg.k, -1, np.int32)
                        # a shed hop still paid its synthesis reasoning
                        # (exact no-op for plain requests: x + 0.0 == x)
                        payload.t_done = (payload.t_arrive
                                          + payload.spans["reason"])
                        payload.stage = "done"
                        if agentic and payload.cq is not None:
                            resolve(payload, payload.t_done)
                            try_full(t)   # an abort-cancel may have drained
                            #               a queued leader and readmitted
                            #               its followers
                        continue
                payload.stage = "admit"
                admission[payload.tenant].append(payload)
                try_spec(t)
            elif kind == _SPEC_DONE:
                payload, r_id, epoch = payload
                if fault_mode:
                    if epoch != spec_epoch[r_id]:
                        # the replica died mid-speculation: the batch was
                        # already rerouted to the full channel and the slot
                        # is rebuilding — this completion is from a ghost
                        continue
                    spec_inflight.pop(r_id, None)
                edge_free.append(r_id)
                if policy == "degrade":
                    update_overload()
                rejected = []
                for r in payload:
                    if r.cancelled:
                        continue   # cancelled mid-spec: settled at cancel
                    if r.channel == "draft":
                        r.spans["edge_rtt"] += r.edge_rtt
                        r.t_done = t + r.edge_rtt
                        r.stage = "done"
                        if agentic and r.cq is not None:
                            resolve(r, r.t_done)
                    elif policy == "degrade" and overloaded:
                        # speculation-only under overload: the reject's
                        # draft returns immediately, unvalidated
                        # (accept=False), instead of queuing for the cloud
                        r.ids, r.channel = r.draft_ids, "degraded"
                        r.spans["edge_rtt"] += r.edge_rtt
                        r.t_done = t + r.edge_rtt
                        r.stage = "done"
                        if agentic and r.cq is not None:
                            resolve(r, r.t_done)
                    else:
                        r.t_rejected = t
                        rejected.append(r)
                        # cross-hop pre-speculation: this hop's DRAFT was
                        # rejected, but its drafted bridge entity is
                        # available NOW — launch the next hop from it,
                        # racing this hop's late re-validation / full
                        # retrieval; the authoritative resolution later
                        # confirms the child or cancels it (the plan's
                        # per-hop bridge draws are frozen, so agreeing
                        # hits imply agreeing bridges)
                        if (agentic and sc.speculate_hops
                                and r.cq is not None and not r.speculative
                                and not r.cq.done
                                and 0 < r.hop < r.cq.plan.hops
                                and r.cq.spec_child is None):
                            cx = r.cq
                            ent = cx.plan.bridge(
                                r.hop, cx.plan.hit(r.hop, r.draft_ids))
                            cx.spec_child = spawn_hop(
                                cx, r.hop + 1, ent, t, speculative=True)
                            cx.prespec = True
                admit_rejects(rejected)
                try_full(t)
                try_spec(t)
            elif kind == _FULL_DONE:
                if fault_mode:
                    disp = payload
                    g = disp["g"]
                    if not disp["live"] or g["done"]:
                        continue    # cancelled hedge loser / crashed worker
                    if disp["fails"]:
                        # transient search failure surfacing after the full
                        # service time: retry with exponential backoff on
                        # the same worker (held through the backoff), give
                        # up past the budget — unless a hedge is still
                        # racing (it IS the retry)
                        disp["live"] = False
                        g["fails"] += 1
                        if any(d["live"] for d in g["dispatches"]):
                            free_worker(disp["w"])
                        elif g["fails"] <= sc.retry_max:
                            delta = sc.retry_backoff_s * 2 ** (g["fails"] - 1)
                            g["backoff_s"] += delta
                            retries += 1
                            rec = {"g": g, "w": disp["w"], "live": True,
                                   "backoff": True}
                            busy[disp["w"]] = rec
                            heapq.heappush(heap, (t + delta, _RETRY, seq,
                                                  (g, disp["w"], rec)))
                            seq += 1
                        else:
                            free_worker(disp["w"])
                            fail_group(g, t)
                    else:
                        complete_group(t, disp)
                    try_full(t)
                    continue
                inflight_full -= 1               # ingest is EDGE work: the
                #                                  cloud worker frees at t
                batch, ids_full, cloud = payload
                n_rows = sum(not r.cancelled for r in batch)
                if sc.ingest_followers:
                    n_rows += sum(sum(not f.cancelled for f in r.followers)
                                  for r in batch)
                # the cache fold + replication fan-out of the whole batch,
                # charged to every request returning from it (the state
                # update itself lands at t: results are visible to the next
                # speculation the instant the cloud round trip ends)
                ingest_s = (0.0 if sc.free_ingest_replay else
                            lat.ingest_time(n_rows, self.cfg.doc_cap,
                                            self.cfg.k))
                t_d = t - cloud                  # this batch's dispatch time
                for j, r in enumerate(batch):
                    lead_ids = ids_full[j].astype(np.int32)
                    if r.cancelled:
                        # cancelled while in flight: the dispatch could not
                        # be unsent — service runs to the cancel instant,
                        # the result is dropped (never served, never
                        # ingested)
                        r.spans["cloud"] += max(0.0, r.t_cancel - t_d)
                        fin_cancel(r, r.t_cancel)
                        registry_remove(r)
                    else:
                        r.ids = lead_ids
                        r.channel = "full"
                        r.cloud_s = cloud
                        r.spans["cloud"] += cloud
                        r.spans["ingest"] += ingest_s
                        r.spans["edge_rtt"] += r.edge_rtt
                        r.t_done = t + ingest_s + r.edge_rtt
                        r.stage = "done"
                        registry_remove(r)
                    for f in r.followers:
                        if f.cancelled:
                            # its wait ends at ITS cancel instant
                            cq = max(0.0, min(t_d, f.t_cancel)
                                     - f.t_rejected)
                            f.spans["cloud_queue"] += cq
                            f.spans["cloud"] += max(
                                0.0, (f.t_cancel - f.t_rejected) - cq)
                            fin_cancel(f, f.t_cancel)
                            f.leader_idx = r.idx
                            continue
                        f.ids = (follower_rerank(f, lead_ids)
                                 if sc.follower_score_weighted else lead_ids)
                        f.channel = "shared"
                        f.cloud_s = cloud
                        # a follower may have attached AFTER its leader
                        # dispatched (in-flight leaders stay shareable):
                        # its cloud wait then starts at its own rejection
                        cq = max(0.0, t_d - f.t_rejected)
                        f.spans["cloud_queue"] += cq
                        f.spans["cloud"] += (t - f.t_rejected) - cq
                        f.spans["ingest"] += ingest_s
                        f.spans["edge_rtt"] += f.edge_rtt
                        f.t_done = t + ingest_s + f.edge_rtt
                        f.stage = "done"
                        f.leader_idx = r.idx
                    if agentic:
                        if r.cq is not None and not r.cancelled:
                            resolve(r, r.t_done)
                        for f in r.followers:
                            if f.cq is not None and not f.cancelled:
                                resolve(f, f.t_done)
                self._ingest(batch, ingest_key=ingest_seq)
                ingest_seq += 1
                try_full(t)
            elif kind == _FULL_TIMER:
                timer_armed = False
                try_full(t)
            elif kind == _FAULT:
                ev = payload
                if ev.kind == "worker_crash":
                    w = ev.target
                    if w in dead_workers:
                        continue                   # already down: coalesce
                    worker_deaths += 1
                    dead_workers.add(w)
                    if w in cloud_free:
                        cloud_free.remove(w)
                    rec = busy.pop(w, None)
                    if rec is not None:
                        # the crash takes the in-flight (or backing-off)
                        # dispatch with it; if that was the group's only
                        # live attempt, its queries requeue at the front
                        inflight_full -= 1
                        rec["live"] = False
                        g = rec["g"]
                        if (not g["done"]
                                and not any(d["live"]
                                            for d in g["dispatches"])):
                            requeue_group(g, t)
                    if ev.down_s > 0:
                        heapq.heappush(heap, (t + ev.down_s, _WORKER_UP,
                                              seq, w))
                        seq += 1
                    try_full(t)
                elif ev.kind == "replica_crash":
                    rho = ev.target
                    if rho in dead_replicas:
                        continue                   # already rebuilding
                    dead_replicas.add(rho)
                    spec_epoch[rho] += 1
                    if rho in edge_free:
                        edge_free.remove(rho)
                    else:
                        info = spec_inflight.pop(rho, None)
                        if info is not None:
                            # mid-speculation loss: undo the dispatch-time
                            # charges (the work never finished), reroute
                            # the batch to the full-retrieval channel —
                            # degraded latency, correct results
                            sbatch, t_disp, replay_s, spec_s = info
                            for r in sbatch:
                                if r.cancelled:
                                    continue   # settled at its cancel
                                r.spans["replay"] -= replay_s
                                r.spans["spec"] -= spec_s
                                r.spans["lost"] += t - t_disp
                                r.ids = None
                                r.channel = "pending"
                                r.val_ids = np.full(self.cfg.k, -1,
                                                    np.int32)
                                r.draft_ids = np.full(self.cfg.k, -1,
                                                      np.int32)
                                r.reroute = True
                                r.t_rejected = t
                                r.stage = "cloudq"
                            for r in reversed(sbatch):
                                if not r.cancelled:
                                    leaders[r.tenant].appendleft(r)
                    # background rebuild: install a primary snapshot (a
                    # full cache fold on the clock), then rejoin the pool
                    rb_s = lat.ingest_time(
                        min(pool.log.head, self.cfg.h_max),
                        self.cfg.doc_cap, self.cfg.k)
                    heapq.heappush(heap, (t + rb_s, _REBUILT, seq, rho))
                    seq += 1
                    try_full(t)
                else:
                    # straggler / search_fail windows, delta-channel
                    # faults: armed in the injector, consulted at
                    # dispatch / ingest time
                    inj.activate(ev)
            elif kind == _DEADLINE:
                disp = payload
                if not disp["live"] or disp["g"]["done"]:
                    continue                       # attempt already settled
                if cloud_free:
                    # hedged re-dispatch: race a fresh attempt on a free
                    # worker; first result wins, the loser is cancelled
                    w2 = min(cloud_free)
                    cloud_free.remove(w2)
                    inflight_full += 1
                    max_inflight = max(max_inflight, inflight_full)
                    hedges += 1
                    cloud_dispatch(disp["g"], w2, t)
                else:
                    heapq.heappush(heap, (t + disp["dl"], _DEADLINE, seq,
                                          disp))
                    seq += 1
            elif kind == _RETRY:
                g, w, rec = payload
                if busy.get(w) is not rec or g["done"]:
                    continue       # the worker crashed during the backoff
                # rotate AWAY from the failing worker when another is free
                # (a transient failure window is usually per-node, so a
                # same-worker retry tends to land back inside it); the held
                # slot is released to the pool either way
                if cloud_free:
                    w2 = min(cloud_free)
                    cloud_free.remove(w2)
                    del busy[w]
                    cloud_free.append(w)
                    cloud_dispatch(g, w2, t)
                    try_full(t)
                else:
                    cloud_dispatch(g, w, t)
            elif kind == _WORKER_UP:
                w = payload
                dead_workers.discard(w)
                cloud_free.append(w)
                try_full(t)
            else:                                  # _REBUILT
                rho = payload
                pool.resync_from(rho, self.state, pool.log.head)
                dead_replicas.discard(rho)
                edge_free.append(rho)
                replica_rebuilds += 1
                try_spec(t)

        # -- metrics (request-index order, shared substrate; spawned hop
        #    sub-queries appended after the input trace) -------------------
        rng = np.random.default_rng(seed)
        m = _metrics_init(len(reqs), llms)
        for r in reqs:
            accept = r.channel in ("draft", "reval", "shared")
            _record(m, r.idx, self.s.world, r.q, r.ids,
                    r.t_done - r.t_arrive, accept, dataset, llms, rng)
        t_arrive = np.array([r.t_arrive for r in reqs])
        t_done = np.array([r.t_done for r in reqs])
        channels = np.array([r.channel for r in reqs], dtype="U16")
        # -- complex-query (hop chain) records -----------------------------
        complex_records = hop_arr = parent_arr = spec_arr = None
        if agentic:
            complex_records = []
            for cx in graphs:
                H = cx.plan.hops
                full_chain = cx.done and len(cx.hits) == H
                complex_records.append({
                    "root_idx": cx.root_idx,
                    "tenant": cx.tenant,
                    "hops": H,
                    "t_start": cx.t_start,
                    "t_done": cx.t_done,
                    "e2e_s": (cx.t_done - cx.t_start if cx.done
                              else float("nan")),
                    # one reasoning step per hop: H-1 sub-query syntheses
                    # + the trailing answer synthesis
                    "reason_s": H * reason_s,
                    "served": bool(cx.served and full_chain),
                    "dar": (float(np.mean(cx.accepts)) if cx.accepts
                            else 0.0),
                    "accuracy": cx.plan.accuracy(
                        full_chain and all(cx.hits), dataset),
                    "prespec": cx.prespec,
                    "prespec_hit": cx.prespec_hit,
                    "cancelled": cx.cancelled,
                    "hop_idx": list(cx.hop_idx),
                })
            hop_arr = np.array([r.hop for r in reqs], np.int32)
            parent_arr = np.array(
                [r.cq.root_idx if r.cq is not None else -1 for r in reqs],
                np.int32)
            spec_arr = np.array([r.speculative for r in reqs], bool)
            if len(reqs) != n:
                tids = np.array([r.tenant for r in reqs], np.int32)
        return SchedResult(
            latencies=m["latencies"], accepts=m["accepts"],
            doc_hits=m["doc_hits"], correct_accepts=m["correct"], ra=m["ra"],
            t_arrive=t_arrive,
            t_done=t_done,
            cloud_s=np.array([r.cloud_s for r in reqs]),
            channels=channels,
            trace=(build_trace(reqs, t_arrive, t_done, channels)
                   if sc.trace else None),
            slo_deadline_s=sc.slo_deadline_s,
            full_retrievals=full_retrievals,
            spec_batches=spec_batches, full_batches=full_batches,
            retries=retries, hedges=hedges, worker_deaths=worker_deaths,
            replica_rebuilds=replica_rebuilds,
            max_inflight_full_batches=max_inflight,
            max_inflight_spec_batches=max(1, max_inflight_spec),
            edge_replays=0 if pool is None else pool.replays,
            replica_ids=np.array([r.replica for r in reqs], np.int32),
            cache_versions=np.array([r.cache_version for r in reqs],
                                    np.int64),
            tenant_ids=tids,
            leader_idx=np.array([r.leader_idx for r in reqs], np.int32),
            served_ids=np.stack([np.asarray(r.ids, np.int32)
                                 for r in reqs]) if reqs else None,
            hop=hop_arr, parent_root=parent_arr, speculative=spec_arr,
            complex_records=complex_records)


# canonical name for the continuous-batching HaS scheduler
HasScheduler = ContinuousBatchingScheduler
