"""Agentic RAG integration (paper §IV-E II): Auto-RAG-style 2-hop pipeline.

Complex queries reference a bridge relation: "What is A(r(e1))?" decomposes
into hop-1 "what entity is r(e1)?" (answered by a relation document of e1)
and hop-2 "what is A(e2)?".  HaS intercepts every decomposed sub-query —
no pipeline modification, exactly the paper's plug-in claim.  Decomposed
sub-queries concentrate on popular entities even harder than raw queries
(hub entities appear as many queries' bridge), which drives the paper's
69.4% retrieval-latency cut at high DAR.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.synthetic import SyntheticWorld, simulate_response_accuracy


@dataclasses.dataclass
class TwoHopDataset:
    """Synthetic complex queries over relation permutations."""
    world: SyntheticWorld
    n_relations: int = 4
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        n = self.world.cfg.n_entities
        # each relation is a mapping entity -> entity, biased toward hubs:
        # half the targets collapse onto a small popular set
        hubs = rng.choice(n, max(8, n // 100), replace=False)
        self.relations = []
        for _ in range(self.n_relations):
            perm = rng.permutation(n)
            collapse = rng.random(n) < 0.5
            perm[collapse] = rng.choice(hubs, collapse.sum())
            self.relations.append(perm)
        # relation attribute ids: reuse the first n_relations attrs
        self.rel_attr = list(range(self.n_relations))

    def sample(self, n: int, zipf_a: float = 1.12, seed: int = 1):
        rng = np.random.default_rng(seed)
        w = self.world
        out = []
        for _ in range(n):
            ranks = rng.zipf(zipf_a)
            e1 = int(min(ranks - 1, w.cfg.n_entities - 1))
            r = int(rng.integers(self.n_relations))
            e2 = int(self.relations[r][e1])
            attrs2 = np.flatnonzero(w.entity_attrs[e2])
            a2 = int(rng.choice(attrs2)) if len(attrs2) else 0
            out.append({"e1": e1, "rel": r, "e2": e2, "attr2": a2})
        return out


class AutoRagPipeline:
    """Chain-of-thought loop: decompose -> retrieve (per hop) -> answer.

    ``engine`` is any serving engine exposing the per-query step protocol
    (HasEngine) or full retrieval; the pipeline itself never changes.
    ``full_engine`` is the shared :class:`~repro.retrieval.service.
    RetrievalService`, whose ``full_search`` routes through the pluggable
    full-retrieval backend (flat / sharded-mesh / replica) — swapping the
    cloud stage under the agentic pipeline needs no pipeline changes
    either.
    """

    def __init__(self, dataset: TwoHopDataset, engine, full_engine,
                 reasoning_latency: float = 0.35):
        self.ds = dataset
        self.engine = engine          # HaS (or None -> always full)
        self.full = full_engine       # RetrievalService-backed full path
        self.reasoning_latency = reasoning_latency

    def _retrieve(self, q_emb):
        if self.engine is not None:
            ids, accept, lat, _ = self.engine.step(q_emb)
            return ids, accept, lat
        ids, _, t = self.full.full_search(q_emb)
        return ids, False, self.full.latency.sample_cloud() + t

    def run(self, complex_queries, dataset: str = "granola", seed: int = 0):
        rng = np.random.default_rng(seed)
        w = self.ds.world
        recs = []
        for cq in complex_queries:
            total_retrieval = 0.0
            accepts = []
            # hop 1: bridge sub-query (entity e1, relation attribute)
            q1 = w.encode_query(cq["e1"], self.ds.rel_attr[cq["rel"]], rng)
            ids1, acc1, lat1 = self._retrieve(q1)
            total_retrieval += lat1
            accepts.append(acc1)
            hop1_hit = bool(w.golden_mask(cq["e1"],
                                          self.ds.rel_attr[cq["rel"]],
                                          ids1).any())
            # hop 2: the pipeline reasons out e2 (correct iff hop-1 grounded,
            # else it guesses and retrieval goes off-entity)
            if hop1_hit or rng.random() < 0.15:
                e2 = cq["e2"]
            else:
                e2 = int(rng.integers(w.cfg.n_entities))
            q2 = w.encode_query(e2, cq["attr2"], rng)
            ids2, acc2, lat2 = self._retrieve(q2)
            total_retrieval += lat2
            accepts.append(acc2)
            hop2_hit = bool(w.golden_mask(cq["e2"], cq["attr2"], ids2).any())
            correct = simulate_response_accuracy(
                rng, hop1_hit and hop2_hit, dataset)
            recs.append({
                "retrieval_latency": total_retrieval,
                "e2e_latency": total_retrieval + 2 * self.reasoning_latency,
                "dar": float(np.mean(accepts)),
                "accuracy": correct,
            })
        keys = recs[0].keys()
        return {k: float(np.mean([r[k] for r in recs])) for k in keys}
