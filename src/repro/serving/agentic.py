"""Agentic RAG (paper §IV-E II): Auto-RAG multi-hop queries as hop graphs.

Complex queries reference a chain of bridge relations: "What is
A(r(e1))?" decomposes into hop-1 "what entity is r(e1)?" (answered by a
relation document of e1) and hop-2 "what is A(e2)?" — and, for ``hops``
> 2, longer chains of the same shape.  Decomposed sub-queries
concentrate on popular entities even harder than raw queries (hub
entities appear as many queries' bridge), which drives the paper's
69.4% retrieval-latency cut at high DAR.

This module is the DECOMPOSITION layer.  Execution lives in two places:

* the sequential executor here (``AutoRagPipeline`` over a per-query
  engine such as :class:`~repro.serving.engine.HasEngine`, or ``None``
  for the always-full baseline) — the paper's plug-in arm, hops strictly
  serial, reasoning charged per hop from
  :attr:`~repro.serving.latency.LatencyModel.reason_scale`;
* the continuous-batching scheduler (``serving/scheduler.py``), where a
  complex query enters admission as its hop-1 sub-query carrying a
  :class:`HopPlan` continuation (``q["hop_plan"]``).  The scheduler
  resolves the hop graph on the virtual clock: reasoning is charged via
  the ``reason`` trace stage, hop-(h+1) is *pre-speculated* from hop-h's
  accepted-or-rejected draft before validation/full retrieval lands, and
  mis-speculated hops are cancelled deterministically (the Speculative
  RAG drafting idea, applied across hops).

Every nondeterministic choice a hop graph makes (query encoding, the
lucky-guess bridge, the wrong-entity guess, answer accuracy) is drawn
from a per-(complex-query, hop) substream of ``np.random.default_rng``
— independent of scheduling order — so the sequential and scheduled
arms, and the drafted and validated bridges within one run, are
comparable at equal DAR/accuracy by construction.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.synthetic import SyntheticWorld, simulate_response_accuracy
from repro.retrieval.lexical import query_terms

#: probability the agent guesses the right bridge entity from an
#: ungrounded hop (the paper's LLM sometimes knows the relation anyway)
LUCKY_BRIDGE_P = 0.15

# substream tags keeping the per-hop rng draws disjoint (HopPlan)
_SUB_BRIDGE, _SUB_QUERY, _SUB_ACC = 101, 103, 107


@dataclasses.dataclass
class TwoHopDataset:
    """Synthetic complex queries over relation permutations.

    Deterministic in ``seed``: the relation maps are built once in
    ``__post_init__`` and ``sample`` draws from its own seeded stream, so
    the same (dataset seed, sample seed) always yields identical
    relations and samples.  Despite the name, ``sample(hops=H)`` builds
    H-hop chains for any H >= 1 (2 stays the default and the paper's
    Fig-13 shape).
    """
    world: SyntheticWorld
    n_relations: int = 4
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        n = self.world.cfg.n_entities
        # each relation is a mapping entity -> entity, biased toward hubs:
        # half the targets collapse onto a small popular set
        hubs = rng.choice(n, max(8, n // 100), replace=False)
        self.relations = []
        for _ in range(self.n_relations):
            perm = rng.permutation(n)
            collapse = rng.random(n) < 0.5
            perm[collapse] = rng.choice(hubs, collapse.sum())
            self.relations.append(perm)
        # relation attribute ids: reuse the first n_relations attrs
        self.rel_attr = list(range(self.n_relations))

    def sample(self, n: int, zipf_a: float = 1.12, seed: int = 1,
               hops: int = 2):
        """Draw ``n`` complex queries as ``hops``-long entity chains.

        Returns dicts with ``entities`` (chain, length ``hops``),
        ``rels`` (relation per bridge, length ``hops - 1``) and ``attr``
        (final-hop attribute); 2-hop samples also carry the legacy
        ``e1``/``rel``/``e2``/``attr2`` keys.  The 2-hop draw sequence is
        unchanged from the pre-hop-graph version of this module.
        """
        if hops < 1:
            raise ValueError(f"hops must be >= 1, got {hops}")
        rng = np.random.default_rng(seed)
        w = self.world
        out = []
        for _ in range(n):
            ranks = rng.zipf(zipf_a)
            e = int(min(ranks - 1, w.cfg.n_entities - 1))
            entities, rels = [e], []
            for _h in range(hops - 1):
                r = int(rng.integers(self.n_relations))
                rels.append(r)
                e = int(self.relations[r][e])
                entities.append(e)
            attrs = np.flatnonzero(w.entity_attrs[entities[-1]])
            a = int(rng.choice(attrs)) if len(attrs) else 0
            cq = {"entities": entities, "rels": rels, "attr": a}
            if hops == 2:
                cq.update(e1=entities[0], rel=rels[0], e2=entities[1],
                          attr2=a)
            out.append(cq)
        return out


class HopPlan:
    """One complex query's decomposed hop graph (the continuation).

    Owns every rng decision of the chain as per-(uid, hop) substreams so
    results are independent of WHEN a hop executes:

    * ``bridge(h, hit)`` — the entity the agent reasons out for hop h+1
      from hop h's retrieval: the true next entity iff the hop was
      grounded (``hit``) or the fixed per-hop lucky draw fires, else a
      fixed per-hop random guess.  Because the lucky/guess draws are
      frozen per hop (not per call), a bridge derived from hop-h's DRAFT
      and one derived from its final retrieval agree whenever their
      doc-hits agree — which is what makes cross-hop pre-speculation
      confirmable.
    * ``query(h, entity)`` — the encoded sub-query for hop h, keyed by
      entity so a corrected re-enqueue after mis-speculation re-encodes
      identically.
    * ``accuracy(ok, dataset)`` — the final-answer draw.
    """

    def __init__(self, world: SyntheticWorld, rel_attr, entities, rels,
                 attr: int, uid: int, seed: int = 0, tenant: int = 0):
        if len(entities) != len(rels) + 1:
            raise ValueError(
                f"chain of {len(entities)} entities needs "
                f"{len(entities) - 1} relations, got {len(rels)}")
        self.world = world
        self.rel_attr = list(rel_attr)
        self.entities = [int(e) for e in entities]
        self.rels = [int(r) for r in rels]
        self.attr = int(attr)
        self.hops = len(self.entities)
        self.uid = int(uid)
        self.seed = int(seed)
        self.tenant = int(tenant)
        self._bridges: dict[int, tuple[bool, int]] = {}

    def attr_of(self, h: int) -> int:
        """Attribute asked at hop ``h`` (1-based): the bridge relation's
        attribute for inner hops, the final attribute for the last."""
        return (self.rel_attr[self.rels[h - 1]] if h < self.hops
                else self.attr)

    def true_entity(self, h: int) -> int:
        return self.entities[h - 1]

    def hit(self, h: int, ids) -> bool:
        """Did hop ``h``'s retrieval ground the TRUE hop-h fact?  (A
        mis-bridged retrieval ran off-entity and almost surely misses.)"""
        ids = np.asarray(ids)
        if ids.size == 0:
            return False
        return bool(self.world.golden_mask(self.true_entity(h),
                                           self.attr_of(h), ids).any())

    def bridge(self, h: int, hit: bool) -> int:
        """Entity the agent reasons out for hop ``h + 1``."""
        if h not in self._bridges:
            rng = np.random.default_rng(
                [self.seed, self.uid, _SUB_BRIDGE, h])
            self._bridges[h] = (
                bool(rng.random() < LUCKY_BRIDGE_P),
                int(rng.integers(self.world.cfg.n_entities)))
        lucky, guess = self._bridges[h]
        return self.entities[h] if (hit or lucky) else guess

    def query(self, h: int, entity: int) -> dict:
        """Engine/scheduler-ready sub-query dict for hop ``h``."""
        attr = self.attr_of(h)
        rng = np.random.default_rng(
            [self.seed, self.uid, _SUB_QUERY, h, int(entity)])
        emb = self.world.encode_query(int(entity), attr, rng)
        tmpl = int(rng.integers(5))
        tokens = np.array([1000 + tmpl * 7 + t for t in range(4)]
                          + [10_000 + int(entity), 100_000 + attr],
                         np.int64)
        terms, term_weights = query_terms(int(entity), attr)
        return {"entity": int(entity), "attr": attr, "emb": emb,
                "tokens": tokens, "terms": terms,
                "term_weights": term_weights, "tenant": self.tenant}

    def root_query(self) -> dict:
        """The hop-1 sub-query that enters scheduler admission, carrying
        this plan as its continuation."""
        q = self.query(1, self.true_entity(1))
        q["hop_plan"] = self
        return q

    def accuracy(self, all_hits: bool, dataset: str) -> bool:
        rng = np.random.default_rng([self.seed, self.uid, _SUB_ACC])
        return simulate_response_accuracy(rng, all_hits, dataset)


def decompose(ds: TwoHopDataset, complex_queries, seed: int = 0,
              tenants=None) -> list[HopPlan]:
    """Build one :class:`HopPlan` per complex query (legacy 2-hop dicts
    and chain dicts both accepted)."""
    plans = []
    for i, cq in enumerate(complex_queries):
        if "entities" in cq:
            ents, rels, attr = cq["entities"], cq["rels"], cq["attr"]
        else:
            ents, rels, attr = [cq["e1"], cq["e2"]], [cq["rel"]], cq["attr2"]
        plans.append(HopPlan(ds.world, ds.rel_attr, ents, rels, attr,
                             uid=i, seed=seed,
                             tenant=0 if tenants is None else int(tenants[i])))
    return plans


def build_hop_trace(ds: TwoHopDataset, complex_queries, seed: int = 0,
                    tenants=None) -> list[dict]:
    """Scheduler-ready trace: each complex query becomes its hop-1
    sub-query with the plan continuation attached (``q["hop_plan"]``)."""
    return [p.root_query() for p in decompose(ds, complex_queries, seed,
                                              tenants)]


class AutoRagPipeline:
    """Chain-of-thought loop: decompose -> retrieve (per hop) -> answer.

    ``engine`` selects the execution substrate:

    * :class:`~repro.serving.engine.HasEngine` (or any per-query
      ``step()`` engine) — hops run strictly sequentially, the paper's
      plug-in arm;
    * ``None`` — sequential with every hop on the full (cloud) path;
    * :class:`~repro.serving.scheduler.ContinuousBatchingScheduler` —
      ``run`` becomes a thin wrapper that builds the hop-graph trace and
      serves it, returning the same summary keys aggregated from the
      scheduler's per-complex-query records (plus pre-speculation
      telemetry).

    ``full_engine`` is the shared :class:`~repro.retrieval.service.
    RetrievalService`; per-hop reasoning time comes from its
    ``LatencyModel.reason_scale`` unless ``reasoning_latency`` overrides
    it, so the sequential baseline and the scheduler path are charged
    identically.
    """

    def __init__(self, dataset: TwoHopDataset, engine, full_engine,
                 reasoning_latency: float | None = None):
        self.ds = dataset
        self.engine = engine          # HaS / scheduler (or None -> full)
        self.full = full_engine       # RetrievalService-backed full path
        self.reasoning_latency = (
            full_engine.latency.reason_time() if reasoning_latency is None
            else float(reasoning_latency))

    # -- sequential substrate ---------------------------------------------

    def _retrieve(self, q: dict):
        """One hop's retrieval, lexical terms threaded through BOTH paths
        (a HybridBackend cloud stage must never silently degrade to
        dense-only for agentic traffic)."""
        if self.engine is not None:
            ids, accept, lat, _ = self.engine.step(
                q["emb"], q_terms=q["terms"],
                q_term_weights=q["term_weights"])
            return ids, accept, lat
        ids, _, t = self.full.full_search(q["emb"], q["terms"],
                                          q["term_weights"])
        return ids, False, self.full.latency.sample_cloud() + t

    def _run_sequential(self, plans, dataset: str):
        recs = []
        for plan in plans:
            total_retrieval, accepts, hits = 0.0, [], []
            entity = plan.true_entity(1)
            for h in range(1, plan.hops + 1):
                q = plan.query(h, entity)
                ids, acc, lat = self._retrieve(q)
                total_retrieval += lat
                accepts.append(acc)
                hit = plan.hit(h, ids)
                hits.append(hit)
                if h < plan.hops:
                    entity = plan.bridge(h, hit)
            correct = plan.accuracy(all(hits), dataset)
            recs.append({
                "retrieval_latency": total_retrieval,
                "e2e_latency": (total_retrieval
                                + plan.hops * self.reasoning_latency),
                "dar": float(np.mean(accepts)),
                "accuracy": correct,
            })
        keys = recs[0].keys()
        return {k: float(np.mean([r[k] for r in recs])) for k in keys}

    # -- scheduler substrate ----------------------------------------------

    def _run_scheduled(self, plans, dataset: str, seed: int, arrivals):
        res = self.engine.serve([p.root_query() for p in plans],
                                arrivals=arrivals, dataset=dataset,
                                seed=seed)
        s = res.summary()
        out = {
            "retrieval_latency": s["complex_retrieval_avg_s"],
            "e2e_latency": s["complex_e2e_avg_s"],
            "dar": s["complex_dar"],
            "accuracy": s["complex_accuracy"],
            "hop2_prespec_rate": s["hop_prespec_rate"],
            "hop2_prespec_hit_rate": s["hop_prespec_hit_rate"],
        }
        out["sched_result"] = res
        return out

    def run(self, complex_queries, dataset: str = "granola", seed: int = 0,
            arrivals=None):
        """Execute the complex queries; returns mean retrieval/e2e
        latency, DAR and answer accuracy (same keys on every substrate).

        ``arrivals`` (scheduler substrate only) spaces the hop-1
        admissions on the virtual clock; ``None`` floods admission at
        t=0 like any saturated scheduler stream.
        """
        plans = decompose(self.ds, complex_queries, seed)
        from repro.serving.scheduler import ContinuousBatchingScheduler
        if isinstance(self.engine, ContinuousBatchingScheduler):
            return self._run_scheduled(plans, dataset, seed, arrivals)
        if arrivals is not None:
            raise ValueError("arrivals only applies to the scheduler "
                             "substrate")
        return self._run_sequential(plans, dataset)
