"""Edge speculation replica pool: R cache replicas behind one delta log.

PR 3 gave the *full-retrieval* stage a replica-backed worker pool, but the
paper's speculation stage still ran against one authoritative cache — the
edge was the serving system's hot single point (throughput capped at one
speculation batch in flight, and a failover served cold drafts).
:class:`EdgeReplicaPool` closes that gap: R warm cache replicas, each an
independent :class:`~repro.core.has.HasState` fed from ONE shared
:class:`~repro.serving.replication.DeltaLog` by bounded-lag delta-cursor
replay, so the scheduler (serving/scheduler.py) can overlap speculation
batches of later admissions on *different* replicas the way full
retrievals already overlap on cloud workers.

Consistency model (staleness-aware, no phantom accepts):

  * every cache ingest lands on the PRIMARY (the scheduler's authoritative
    state) and is appended to the pool's delta log via ``record_batch`` —
    the same sink protocol ``WarmStandby`` speaks, so
    ``retrieval/service.py::ReplicaBackend`` can fan one ``on_ingest``
    out to cloud standbys and this pool alike;
  * a replica replays its missing rows once it falls ``sync_every`` or
    more rows behind, so its lag is bounded — either at ``record_batch``
    time (``sync_on_record=True``, the standalone default) or, when the
    owning loop wants replay ON the virtual clock
    (``sync_on_record=False``), at speculation-dispatch time via ``sync``
    with the replay charged to the dispatching edge slot
    (``LatencyModel.ingest_time`` — serving/scheduler.py's
    accounting-fixed mode);
  * a speculation batch dispatched to replica r is validated against
    r's OWN cache version (``states[r]`` / ``version(r)``) — an accept
    can only reference documents that replica actually holds, never
    documents only the primary has seen (no phantom accepts on a stale
    replica);
  * ``promote(r)`` syncs replica r to the log head and hands its state
    over as the new primary, so a failover mid-stream continues the
    request trace with the cache the primary would have had.

Replay is exactly the primary's ingest fold (``cache_update_chunked``
row order), so a replica synced to sequence s is bit-identical to the
primary after its first s ingest rows — tests/test_edge_pool.py asserts
this prefix parity and audits served drafts against replica versions.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.core.has import (HasConfig, HasState, cache_update_chunked,
                            init_has_state, init_tenant_states)
from repro.serving.replication import DeltaLog, validate_ingest_batch

#: bounded-lag default: replicas replay once they fall this many ingested
#: rows behind the primary (calibrated by ``benchmarks/sched_throughput.py
#: --sweep-edge-replicas``: DAR within 2 points of the zero-lag R == 1 path
#: while replay stays off the per-batch critical path)
DEFAULT_EDGE_SYNC_EVERY = 32


@dataclasses.dataclass
class EdgeReplicaPool:
    """R speculation cache replicas over one shared delta log.

    ``n_tenants > 1`` replicates a tenant-partitioned primary: delta rows
    carry their tenant tag and replay scatters each row into its tenant's
    partition (the same ``cache_update_chunked`` contract the scheduler's
    own ingest uses).  ``compact=False`` retains the full log (audits /
    tests that fold version prefixes); the default drops rows every
    replica has replayed.
    """
    cfg: HasConfig
    n_replicas: int
    sync_every: int = DEFAULT_EDGE_SYNC_EVERY
    n_tenants: int = 1
    replay_batch: int = 64         # delta rows folded per device dispatch
    compact: bool = True
    # Who applies the bounded-lag cadence.  True (the historical default):
    # ``record_batch`` itself replays any replica that fell ``sync_every``
    # rows behind — replay is then FREE on a serving loop's virtual clock
    # (it happens "inside" the ingest event).  False: the pool only
    # appends; the caller replays at speculation-dispatch time via
    # ``sync`` and charges the replay to the dispatching edge slot
    # (serving/scheduler.py's accounting-fixed mode).
    sync_on_record: bool = True

    def __post_init__(self):
        if self.n_replicas < 1:
            raise ValueError(
                f"n_replicas must be >= 1, got {self.n_replicas}")
        if self.sync_every < 1:
            raise ValueError(
                f"sync_every must be >= 1, got {self.sync_every}")
        self.log = DeltaLog()
        self.states: list[HasState] = [self._init_state()
                                       for _ in range(self.n_replicas)]
        self.cursors = [0] * self.n_replicas
        self.replays = 0               # replay events (stat)

    def _init_state(self) -> HasState:
        return (init_has_state(self.cfg) if self.n_tenants == 1
                else init_tenant_states(self.cfg, self.n_tenants))

    # -- replica views -----------------------------------------------------

    def version(self, r: int) -> int:
        """Cache version of replica r == primary ingest rows it has
        replayed (the delta-log sequence its cursor sits at)."""
        return self.cursors[r]

    def lag(self, r: int) -> int:
        """Ingested rows replica r is behind the primary."""
        return self.log.head - self.cursors[r]

    def freshest(self, candidates) -> int:
        """Staleness-aware pick: the candidate replica with the highest
        cache version (lowest lag); ties break to the lowest replica id
        (deterministic)."""
        return max(candidates, key=lambda r: (self.cursors[r], -r))

    # -- ingest propagation (the WarmStandby record_batch sink protocol) ---

    def record_batch(self, q_embs, full_ids, full_vecs, state: Any = None,
                     tenant_ids=None) -> None:
        """Append one primary ingest batch, then apply the sync cadence.

        ``state`` (the post-batch primary) is accepted for sink-protocol
        compatibility with ``WarmStandby.record_batch`` and unused — the
        pool rebuilds replica caches from delta rows alone.  Rows with
        padded (``-1``) ids keep zeroed doc vectors (defensively re-zeroed
        here; replay drops them anyway).
        """
        q_embs = np.asarray(q_embs, np.float32)
        full_ids = np.asarray(full_ids, np.int32)
        full_vecs = np.asarray(full_vecs, np.float32)
        validate_ingest_batch(q_embs, full_ids, full_vecs, tenant_ids)
        pad = full_ids < 0
        if pad.any() and full_vecs[pad].any():
            # only copy when a padded slot actually carries data — the
            # scheduler and ReplicaBackend hand over gather_doc_vecs
            # output, already zeroed
            full_vecs = full_vecs.copy()
            full_vecs[pad] = 0.0
        if tenant_ids is None:
            if self.n_tenants > 1:
                raise ValueError(
                    f"record_batch on a {self.n_tenants}-tenant pool "
                    "requires tenant_ids — the rows' partition cannot be "
                    "inferred")
            tids = np.zeros(len(q_embs), np.int32)
        else:
            tids = np.asarray(tenant_ids, np.int32)
            if len(tids) and not (0 <= tids.min()
                                  and tids.max() < self.n_tenants):
                raise ValueError(
                    f"tenant ids [{tids.min()}, {tids.max()}] out of range "
                    f"for n_tenants={self.n_tenants}")
        for i in range(len(q_embs)):
            self.log.append((q_embs[i], full_ids[i], full_vecs[i],
                             int(tids[i])))
        if self.sync_on_record:
            for r in range(self.n_replicas):
                if self.lag(r) >= self.sync_every:
                    self.sync(r)
        if self.compact:
            self.log.compact_below(min(self.cursors))

    # -- bounded-lag delta replay ------------------------------------------

    def sync(self, r: int) -> int:
        """Replay replica r's missing delta rows (cursor -> log head).

        One fused ``cache_update_chunked`` fold per ``replay_batch`` rows,
        in primary ingest order — after the call, replica r is
        bit-identical to the primary's state after its first ``head``
        ingest rows.  Returns the number of rows replayed.
        """
        rows = self.log.since(self.cursors[r])
        if not rows:
            return 0
        self.states[r] = cache_update_chunked(
            self.cfg, self.states[r],
            np.stack([q for q, _, _, _ in rows]),
            np.stack([ids for _, ids, _, _ in rows]).astype(np.int32),
            np.stack([vecs for _, _, vecs, _ in rows]),
            chunk=self.replay_batch,
            tenant_ids=(None if self.n_tenants == 1 else
                        np.array([t for _, _, _, t in rows], np.int32)))
        self.cursors[r] = self.log.head
        self.replays += 1
        return len(rows)

    def sync_all(self) -> None:
        for r in range(self.n_replicas):
            self.sync(r)

    def promote(self, r: int) -> HasState:
        """Failover: bring replica r fully up to date and hand its state
        over as the new primary — the request trace continues on exactly
        the cache the lost primary would have had (bit-exact, because
        replay is the primary's own ingest fold)."""
        self.sync(r)
        return self.states[r]
