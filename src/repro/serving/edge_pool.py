"""Edge speculation replica pool: R cache replicas behind one delta log.

PR 3 gave the *full-retrieval* stage a replica-backed worker pool, but the
paper's speculation stage still ran against one authoritative cache — the
edge was the serving system's hot single point (throughput capped at one
speculation batch in flight, and a failover served cold drafts).
:class:`EdgeReplicaPool` closes that gap: R warm cache replicas, each an
independent :class:`~repro.core.has.HasState` fed from ONE shared
:class:`~repro.serving.replication.DeltaLog` by bounded-lag delta-cursor
replay, so the scheduler (serving/scheduler.py) can overlap speculation
batches of later admissions on *different* replicas the way full
retrievals already overlap on cloud workers.

Consistency model (staleness-aware, no phantom accepts):

  * every cache ingest lands on the PRIMARY (the scheduler's authoritative
    state) and is appended to the pool's delta log via ``record_batch`` —
    the same sink protocol ``WarmStandby`` speaks, so
    ``retrieval/service.py::ReplicaBackend`` can fan one ``on_ingest``
    out to cloud standbys and this pool alike;
  * a replica replays its missing rows once it falls ``sync_every`` or
    more rows behind, so its lag is bounded — either at ``record_batch``
    time (``sync_on_record=True``, the standalone default) or, when the
    owning loop wants replay ON the virtual clock
    (``sync_on_record=False``), at speculation-dispatch time via ``sync``
    with the replay charged to the dispatching edge slot
    (``LatencyModel.ingest_time`` — serving/scheduler.py's
    accounting-fixed mode);
  * a speculation batch dispatched to replica r is validated against
    r's OWN cache version (``states[r]`` / ``version(r)``) — an accept
    can only reference documents that replica actually holds, never
    documents only the primary has seen (no phantom accepts on a stale
    replica);
  * ``promote(r)`` syncs replica r to the log head and hands its state
    over as the new primary, so a failover mid-stream continues the
    request trace with the cache the primary would have had.

Replay is exactly the primary's ingest fold (``cache_update_chunked``
row order), so a replica synced to sequence s is bit-identical to the
primary after its first s ingest rows — tests/test_edge_pool.py asserts
this prefix parity and audits served drafts against replica versions.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.has import (HasConfig, HasState, cache_update_chunked,
                            init_has_state, init_tenant_states)
from repro.serving.replication import DeltaLog, validate_ingest_batch

#: bounded-lag default: replicas replay once they fall this many ingested
#: rows behind the primary (calibrated by ``benchmarks/sched_throughput.py
#: --sweep-edge-replicas``: DAR within 2 points of the zero-lag R == 1 path
#: while replay stays off the per-batch critical path)
DEFAULT_EDGE_SYNC_EVERY = 32


@dataclasses.dataclass
class EdgeReplicaPool:
    """R speculation cache replicas over one shared delta log.

    ``n_tenants > 1`` replicates a tenant-partitioned primary: delta rows
    carry their tenant tag and replay scatters each row into its tenant's
    partition (the same ``cache_update_chunked`` contract the scheduler's
    own ingest uses).  ``compact=False`` retains the full log (audits /
    tests that fold version prefixes); the default drops rows every
    replica has replayed.
    """
    cfg: HasConfig
    n_replicas: int
    sync_every: int = DEFAULT_EDGE_SYNC_EVERY
    n_tenants: int = 1
    replay_batch: int = 64         # delta rows folded per device dispatch
    compact: bool = True
    # Who applies the bounded-lag cadence.  True (the historical default):
    # ``record_batch`` itself replays any replica that fell ``sync_every``
    # rows behind — replay is then FREE on a serving loop's virtual clock
    # (it happens "inside" the ingest event).  False: the pool only
    # appends; the caller replays at speculation-dispatch time via
    # ``sync`` and charges the replay to the dispatching edge slot
    # (serving/scheduler.py's accounting-fixed mode).
    sync_on_record: bool = True

    def __post_init__(self):
        if self.n_replicas < 1:
            raise ValueError(
                f"n_replicas must be >= 1, got {self.n_replicas}")
        if self.sync_every < 1:
            raise ValueError(
                f"sync_every must be >= 1, got {self.sync_every}")
        self.log = DeltaLog()
        self.states: list[HasState] = [self._init_state()
                                       for _ in range(self.n_replicas)]
        self.cursors = [0] * self.n_replicas
        self.replays = 0               # replay events (stat)
        # replicas whose state was handed over by ``promote`` — their
        # cursors no longer pin compaction and replaying into them would
        # donate the new primary's buffers out from under it
        self.retired: set[int] = set()
        self._seen_keys: set = set()   # ingest_key dedup (idempotence)

    def _init_state(self) -> HasState:
        return (init_has_state(self.cfg) if self.n_tenants == 1
                else init_tenant_states(self.cfg, self.n_tenants))

    # -- replica views -----------------------------------------------------

    def version(self, r: int) -> int:
        """Cache version of replica r == primary ingest rows it has
        replayed (the delta-log sequence its cursor sits at)."""
        return self.cursors[r]

    def lag(self, r: int) -> int:
        """Ingested rows replica r is behind the primary."""
        return self.log.head - self.cursors[r]

    def freshest(self, candidates) -> int:
        """Staleness-aware pick: the candidate replica with the highest
        cache version (lowest lag); ties break to the lowest replica id
        (deterministic)."""
        return max(candidates, key=lambda r: (self.cursors[r], -r))

    # -- ingest propagation (the WarmStandby record_batch sink protocol) ---

    def record_batch(self, q_embs, full_ids, full_vecs, state: Any = None,
                     tenant_ids=None, *, ingest_key=None) -> None:
        """Append one primary ingest batch, then apply the sync cadence.

        ``state`` (the post-batch primary) is accepted for sink-protocol
        compatibility with ``WarmStandby.record_batch`` and unused — the
        pool rebuilds replica caches from delta rows alone.  Rows with
        padded (``-1``) ids keep zeroed doc vectors (defensively re-zeroed
        here; replay drops them anyway).

        ``ingest_key`` makes the append IDEMPOTENT: a batch whose key was
        already recorded is dropped whole, so a duplicated replication
        send (or a retried cloud dispatch whose first attempt landed)
        never folds twice into the replicas.  ``None`` skips dedup.
        """
        if ingest_key is not None:
            if ingest_key in self._seen_keys:
                return
            self._seen_keys.add(ingest_key)
        q_embs = np.asarray(q_embs, np.float32)
        full_ids = np.asarray(full_ids, np.int32)
        full_vecs = np.asarray(full_vecs, np.float32)
        validate_ingest_batch(q_embs, full_ids, full_vecs, tenant_ids)
        pad = full_ids < 0
        if pad.any() and full_vecs[pad].any():
            # only copy when a padded slot actually carries data — the
            # scheduler and ReplicaBackend hand over gather_doc_vecs
            # output, already zeroed
            full_vecs = full_vecs.copy()
            full_vecs[pad] = 0.0
        if tenant_ids is None:
            if self.n_tenants > 1:
                raise ValueError(
                    f"record_batch on a {self.n_tenants}-tenant pool "
                    "requires tenant_ids — the rows' partition cannot be "
                    "inferred")
            tids = np.zeros(len(q_embs), np.int32)
        else:
            tids = np.asarray(tenant_ids, np.int32)
            if len(tids) and not (0 <= tids.min()
                                  and tids.max() < self.n_tenants):
                raise ValueError(
                    f"tenant ids [{tids.min()}, {tids.max()}] out of range "
                    f"for n_tenants={self.n_tenants}")
        for i in range(len(q_embs)):
            self.log.append((q_embs[i], full_ids[i], full_vecs[i],
                             int(tids[i])))
        if self.sync_on_record:
            for r in range(self.n_replicas):
                if r not in self.retired and self.lag(r) >= self.sync_every:
                    self.sync(r)
        if self.compact:
            self.log.compact_below(self._min_live_cursor())

    def _min_live_cursor(self) -> int:
        """Lowest cursor over NON-retired replicas — the compaction bound.
        Retired (promoted-away) replicas no longer pin the log; with every
        replica retired the whole log may be trimmed."""
        live = [c for r, c in enumerate(self.cursors)
                if r not in self.retired]
        return min(live) if live else self.log.head

    def mark_lost(self, n: int = 1) -> None:
        """Model ``n`` ingest rows LOST on the replication channel: the
        primary folded them, the pool never saw them.  Sequence numbers
        advance without rows, so the next ``sync`` of a lagging replica
        fails loudly on the gap instead of silently diverging."""
        self.log.mark_lost(n)

    # -- bounded-lag delta replay ------------------------------------------

    def sync(self, r: int) -> int:
        """Replay replica r's missing delta rows (cursor -> log head).

        One fused ``cache_update_chunked`` fold per ``replay_batch`` rows,
        in primary ingest order — after the call, replica r is
        bit-identical to the primary's state after its first ``head``
        ingest rows.  Returns the number of rows replayed.

        Replay VALIDATES sequence contiguity: the delta must start at
        replica r's cursor and advance by exactly one per row.  A gap
        means ingest rows were lost in transit (``mark_lost``) — replaying
        past it would silently diverge the replica from the primary, so a
        ``ValueError`` names the replica and the expected/actual sequence;
        the owner must full-resync (``resync_from``).
        """
        if r in self.retired:
            raise ValueError(
                f"replica {r} was retired by promote() — its state now IS "
                "the primary; replaying into it would donate the primary's "
                "buffers")
        items = self.log.since_items(self.cursors[r])
        if not items:
            if self.cursors[r] < self.log.head:
                # every missing row was lost in transit
                raise ValueError(
                    f"delta replay gap for replica {r}: expected seq "
                    f"{self.cursors[r]}, next available is {self.log.head} "
                    "(rows lost in transit) — full resync required")
            return 0
        expected = self.cursors[r]
        for seq, _ in items:
            if seq != expected:
                raise ValueError(
                    f"delta replay gap for replica {r}: expected seq "
                    f"{expected}, got {seq} (rows lost in transit) — "
                    "full resync required")
            expected += 1
        if expected != self.log.head:
            # trailing rows lost after the last retained one
            raise ValueError(
                f"delta replay gap for replica {r}: expected seq "
                f"{expected}, next available is {self.log.head} "
                "(rows lost in transit) — full resync required")
        rows = [row for _, row in items]
        self.states[r] = cache_update_chunked(
            self.cfg, self.states[r],
            np.stack([q for q, _, _, _ in rows]),
            np.stack([ids for _, ids, _, _ in rows]).astype(np.int32),
            np.stack([vecs for _, _, vecs, _ in rows]),
            chunk=self.replay_batch,
            tenant_ids=(None if self.n_tenants == 1 else
                        np.array([t for _, _, _, t in rows], np.int32)))
        self.cursors[r] = self.log.head
        self.replays += 1
        return len(rows)

    def sync_all(self) -> None:
        for r in range(self.n_replicas):
            if r not in self.retired:
                self.sync(r)

    def resync_from(self, r: int, state: HasState, version: int) -> None:
        """Full resync: install a DEEP COPY of ``state`` (the primary at
        delta-log sequence ``version``, normally ``log.head``) as replica
        r's cache and move its cursor there.  The copy is load-bearing:
        later replays fold through donated-buffer updates, so sharing the
        primary's arrays would corrupt the primary the first time the
        replica syncs.  This is the recovery path after a crash or a
        ``sync`` gap error — and it un-retires a slot being rebuilt."""
        self.states[r] = jax.tree.map(jnp.copy, state)
        self.cursors[r] = version
        self.retired.discard(r)
        self.replays += 1

    def promote(self, r: int) -> HasState:
        """Failover: bring replica r fully up to date and hand its state
        over as the new primary — the request trace continues on exactly
        the cache the lost primary would have had (bit-exact, because
        replay is the primary's own ingest fold).

        The promoted replica is RETIRED: its state now is the primary, so
        its slot must not be replayed into again (donated-buffer updates
        would corrupt the new primary) and its cursor stops pinning log
        compaction — the log can be trimmed past it and stays bounded
        while serving continues.  ``resync_from`` rebuilds the slot."""
        self.sync(r)
        state = self.states[r]
        self.retired.add(r)
        if self.compact:
            self.log.compact_below(self._min_live_cursor())
        return state
