"""RAG serving engines: Full / HaS / reuse-based / CRAG / ANNS (paper §IV).

All engines run on one serve-loop substrate (:class:`ServeLoop`): the loop
owns metrics recording, record-rng threading and micro-batch iteration, and
an engine only implements ``_step`` (one query -> ids/accept/latency) or
``_step_batch`` (one micro-batch -> a list of those).  Full-database
retrieval routes through the pluggable backend layer of the shared
:class:`~repro.retrieval.service.RetrievalService` (flat / sharded-mesh /
replica — see retrieval/service.py), so every engine's cloud stage swaps
without engine changes.  ``batch_size == 1``
gives Algorithm 1's sequential semantics (the cache mutates between
queries); serving/batched.py sets ``batch_size > 1`` for snapshot
micro-batching, and serving/scheduler.py reuses the same metrics substrate
for event-driven continuous batching.

Recorded metrics (paper §IV):

  AvgL   average end-to-end retrieval latency
  DAR    draft acceptance rate
  CAR    correct acceptance rate (accepted drafts containing a golden doc)
  DocHit golden document present in the returned set
  RA     simulated response accuracy per downstream LLM
  L@DA / L@DR   latency conditioned on acceptance / rejection
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import (CRAGEvaluator, ReuseState, init_reuse_state,
                                  mincache_match, minhash_signature,
                                  proximity_match, reuse_insert,
                                  saferadius_match)
from repro.core.has import (HasConfig, cache_update, init_has_state,
                            init_tenant_states, speculate_batch)
from repro.data.synthetic import SyntheticWorld, simulate_response_accuracy
from repro.retrieval.ivf import (IVFIndex, build_ivf, ivf_search,
                                 subset_index)
# RetrievalService composes world + latency + a pluggable full-retrieval
# backend (retrieval/service.py); re-exported here for the serving layers
# and for backward compatibility of `repro.serving.engine.RetrievalService`.
from repro.retrieval.service import (FullRetrievalBackend, LocalFlatBackend,
                                     ReplicaBackend, RetrievalService,
                                     ShardedMeshBackend)
from repro.serving.latency import LatencyModel


@dataclasses.dataclass
class ServeResult:
    latencies: np.ndarray
    accepts: np.ndarray
    doc_hits: np.ndarray
    correct_accepts: np.ndarray
    ra: dict[str, np.ndarray]

    def summary(self) -> dict[str, float]:
        # NaN-safe on an empty stream (serve([])): rate/latency means
        # report NaN instead of numpy's mean-of-empty warning cascade
        def _mean(a) -> float:
            a = np.asarray(a)
            return float(a.mean()) if a.size else float("nan")

        acc = self.accepts.astype(bool)
        out = {
            "avg_latency_s": _mean(self.latencies),
            "dar": _mean(acc),
            "doc_hit_rate": _mean(self.doc_hits),
            "l_at_da": _mean(self.latencies[acc]) if acc.any() else 0.0,
            "l_at_dr": _mean(self.latencies[~acc]) if (~acc).any() else 0.0,
            "car": _mean(self.correct_accepts[acc]) if acc.any() else 0.0,
            "ra_at_da": _mean(self.ra["qwen3-8b"][acc]) if acc.any() else 0.0,
        }
        for llm, arr in self.ra.items():
            out[f"ra_{llm}"] = _mean(arr)
        return out


def _metrics_init(n, llms):
    return dict(latencies=np.zeros(n), accepts=np.zeros(n, bool),
                doc_hits=np.zeros(n, bool), correct=np.zeros(n, bool),
                ra={m: np.zeros(n, bool) for m in llms})


def _finish(m) -> ServeResult:
    return ServeResult(latencies=m["latencies"], accepts=m["accepts"],
                       doc_hits=m["doc_hits"], correct_accepts=m["correct"],
                       ra=m["ra"])


LLMS = ("qwen3-8b", "llama3-8b", "mixtral-7b")


def fuzzy_scope(cfg, index) -> float:
    """Fraction of the fuzzy IVF index streamed per probed query."""
    return min(cfg.nprobe, index.n_buckets) / index.n_buckets


def _record(m, i, world, query, ids, lat, accept, dataset, llms, rng):
    golden = world.golden_mask(query["entity"], query["attr"], ids)
    hit = bool(golden.any())
    m["latencies"][i] = lat
    m["accepts"][i] = accept
    m["doc_hits"][i] = hit
    m["correct"][i] = hit and accept
    for llm in llms:
        m["ra"][llm][i] = simulate_response_accuracy(
            rng, hit, dataset, llm, n_docs=int(np.sum(np.asarray(ids) >= 0)))


# ---------------------------------------------------------------------------
# Serve-loop substrate
# ---------------------------------------------------------------------------

class ServeLoop:
    """One serve loop for every engine (sequential or micro-batched).

    ``serve`` owns the stream mechanics every engine previously hand-rolled:
    metrics array allocation, per-query recording (DocHit/CAR/RA draws from
    the record rng), and micro-batch iteration.  Engines implement either

      * ``_step(q, rng, dataset) -> (ids, accept, latency_s)`` — sequential
        Algorithm 1 semantics (``batch_size == 1``), or
      * ``_step_batch(group, rng, dataset) -> [(ids, accept, latency_s)]`` —
        snapshot micro-batch semantics (``batch_size > 1``).

    Latency accounting convention (serving/latency.py): engines compose each
    query's latency from sampled RTTs (the latency model's own rng stream),
    measured edge compute, and analytic bandwidth-bound scan times.
    """

    batch_size: int = 1

    def __init__(self, service: RetrievalService):
        self.s = service

    def _step(self, q, rng, dataset):
        raise NotImplementedError

    def _step_batch(self, group, rng, dataset):
        return [self._step(q, rng, dataset) for q in group]

    def serve(self, queries, dataset="granola", llms=LLMS,
              seed=0) -> ServeResult:
        rng = np.random.default_rng(seed)
        m = _metrics_init(len(queries), llms)
        bs = max(int(self.batch_size), 1)
        for start in range(0, len(queries), bs):
            group = queries[start:start + bs]
            for j, (ids, accept, lat) in enumerate(
                    self._step_batch(group, rng, dataset)):
                _record(m, start + j, self.s.world, group[j], ids, lat,
                        bool(accept), dataset, llms, rng)
        return _finish(m)


# ---------------------------------------------------------------------------
# Engines
# ---------------------------------------------------------------------------

class FullRetrievalEngine(ServeLoop):
    """Baseline: always full-database retrieval on the cloud."""

    def _step(self, q, rng, dataset):
        ids, _, t = self.s.full_search(q["emb"], q.get("terms"),
                                       q.get("term_weights"))
        return ids, False, self.s.latency.sample_cloud() + t


class ANNSEngine(ServeLoop):
    """IVF / ScaNN-substitute at a configurable scope (Table II ♠/♦).

    'scann' = IVF partitioning + int8 asymmetric scoring (the TPU-native
    stand-in for ScaNN's anisotropic quantization): the bucket store keeps
    int8-degraded values (accuracy cost) and is charged 1 byte/dim on the
    latency model (bandwidth win).
    """

    def __init__(self, service: RetrievalService, method: str = "ivf",
                 n_buckets: int = 4096, nprobe: int = 64,
                 on_edge: bool = True, seed: int = 0):
        super().__init__(service)
        self.on_edge = on_edge
        self.method = method
        self.index = build_ivf(service.corpus, n_buckets, seed=seed)
        self.nprobe = min(nprobe, self.index.n_buckets)
        self.scope = self.nprobe / self.index.n_buckets
        if method == "scann":
            # bake int8 rounding into the bucket store (score degradation)
            bv = self.index.bucket_vecs
            scale = jnp.max(jnp.abs(bv), axis=-1, keepdims=True) / 127.0
            q8 = jnp.clip(jnp.round(bv / jnp.maximum(scale, 1e-8)),
                          -127, 127)
            self.index = IVFIndex(
                centroids=self.index.centroids,
                bucket_vecs=(q8 * scale).astype(jnp.float32),
                bucket_ids=self.index.bucket_ids,
                bucket_counts=self.index.bucket_counts)
        self.search(np.zeros((service.world.cfg.d,), np.float32))  # warmup

    def search(self, q_emb):
        q = jnp.asarray(q_emb)[None]
        lat = self.s.latency
        s, ids = ivf_search(self.index, q, nprobe=self.nprobe, k=self.s.k)
        # cost ~ probed fraction of the corpus (x2 bucket padding) at
        # 4 B/dim (ivf) or 1 B/dim (scann int8), + the centroid matmul
        bpd = 1 if self.method == "scann" else 4
        t = lat.scan_time(lat.target_corpus * self.scope * 2.0,
                          bytes_per_dim=bpd) + lat.scan_time(
                              self.index.n_buckets)
        return np.asarray(ids[0]), t

    def _step(self, q, rng, dataset):
        ids, t = self.search(q["emb"])
        rtt = (self.s.latency.sample_edge() if self.on_edge
               else self.s.latency.sample_cloud())
        return ids, False, rtt + t


class HasEngine(ServeLoop):
    """The paper's system (Algorithm 1) with optional ANNS fallback (♦).

    ``n_tenants > 1`` partitions the cache (``init_tenant_states``): each
    query routes through its tenant's slice (``step(..., tenant=t)``, or a
    ``"tenant"`` key on the query dict), rejects ingest only into that
    partition, and replica backends receive the tenant tag on every
    ingest.  ``n_tenants == 1`` is the historical unpartitioned path.
    """

    def __init__(self, service: RetrievalService, cfg: HasConfig | None = None,
                 fallback: ANNSEngine | None = None,
                 fuzzy_fraction: float = 1.0, seed: int = 0,
                 backend: str | None = None, n_tenants: int = 1):
        super().__init__(service)
        self.cfg = cfg or HasConfig(k=service.k, d=service.world.cfg.d)
        self.n_tenants = max(1, int(n_tenants))
        self.state = (init_has_state(self.cfg) if self.n_tenants == 1
                      else init_tenant_states(self.cfg, self.n_tenants))
        index = build_ivf(service.corpus, self.cfg.n_buckets, seed=seed)
        self.index = subset_index(index, fuzzy_fraction)
        self.fallback = fallback
        self.backend = backend                  # None -> auto per platform
        self.fuzzy_scope = (self.cfg.nprobe / self.cfg.n_buckets) * fuzzy_fraction
        # warmup the fused speculation program at the sequential shape B=1
        z = jnp.zeros((1, self.s.world.cfg.d))
        out = speculate_batch(self.cfg, self.state, self.index, z,
                              backend=backend,
                              tenant_ids=self._tids(0))
        jax.block_until_ready(out)

    def _tids(self, tenant: int):
        """tenant_ids for a B=1 speculation (None on the legacy path);
        rejects out-of-range tags up front — a silently-dropped scatter
        would otherwise leave the tenant's cache forever cold."""
        if not 0 <= tenant < self.n_tenants:
            raise ValueError(
                f"tenant {tenant} out of range for n_tenants="
                f"{self.n_tenants}")
        return (None if self.n_tenants == 1
                else jnp.full((1,), tenant, jnp.int32))

    def _fuzzy_time(self) -> float:
        """Analytic fuzzy-channel scan time at the target corpus scale."""
        lat = self.s.latency
        return lat.scan_time(lat.target_corpus * self.fuzzy_scope * 2.0
                             + self.cfg.n_buckets)

    def step(self, q_emb: np.ndarray, tenant: int = 0, q_terms=None,
             q_term_weights=None):
        """Returns (ids, accept, latency_s, homology)."""
        lat = self.s.latency.sample_edge()
        t0 = time.perf_counter()
        out = speculate_batch(self.cfg, self.state, self.index,
                              jnp.asarray(q_emb)[None], backend=self.backend,
                              tenant_ids=self._tids(tenant))
        jax.block_until_ready(out)
        # measured edge compute (cache channel + validation at true scale)
        # + analytic fuzzy scan extrapolated to the target corpus
        lat += (time.perf_counter() - t0) + self._fuzzy_time()
        accept = bool(out["accept"][0])
        if accept:
            return np.asarray(out["draft_ids"][0]), True, lat, \
                float(out["homology"][0])
        # fallback: full database (cloud) or optimized ANNS (♦)
        if self.fallback is not None:
            ids, t = self.fallback.search(q_emb)
            vecs = np.asarray(self.s.corpus[ids])
            lat += self.s.latency.sample_cloud() + t
        else:
            ids, vecs, t = self.s.full_search(q_emb, q_terms,
                                              q_term_weights)
            lat += self.s.latency.sample_cloud() + t
        t0 = time.perf_counter()
        self.state = cache_update(self.cfg, self.state, jnp.asarray(q_emb),
                                  jnp.asarray(ids.astype(np.int32)),
                                  jnp.asarray(vecs),
                                  tenant_id=(None if self.n_tenants == 1
                                             else tenant))
        jax.block_until_ready(self.state.q_ptr)
        lat += time.perf_counter() - t0
        # replica-style backends mirror the ingest onto standby delta logs
        self.s.backend.on_ingest(
            np.asarray(q_emb)[None], ids.astype(np.int32)[None], self.state,
            tenant_ids=(None if self.n_tenants == 1
                        else np.array([tenant], np.int32)))
        return ids, False, lat, float(out["homology"][0])

    def _step(self, q, rng, dataset):
        ids, accept, lat, _ = self.step(q["emb"],
                                        tenant=int(q.get("tenant", 0)),
                                        q_terms=q.get("terms"),
                                        q_term_weights=q.get("term_weights"))
        return ids, accept, lat


class ReuseEngine(ServeLoop):
    """Proximity / SafeRadius / MinCache reuse baselines (Table III)."""

    def __init__(self, service: RetrievalService, method: str,
                 h_max: int = 5000, theta: float = 0.9, alpha: float = 2.0,
                 t_lex: float = 0.6, t_sem: float = 0.9):
        super().__init__(service)
        self.method = method
        self.state = init_reuse_state(h_max, service.k, service.world.cfg.d)
        self.theta, self.alpha = theta, alpha
        self.t_lex, self.t_sem = t_lex, t_sem

    def _match(self, q):
        qe = jnp.asarray(q["emb"])
        if self.method == "proximity":
            return proximity_match(self.state, qe, jnp.float32(self.theta))
        if self.method == "saferadius":
            return saferadius_match(self.state, qe, jnp.float32(self.alpha))
        if self.method == "mincache":
            mh = jnp.asarray(minhash_signature(q["tokens"]))
            return mincache_match(self.state, qe, mh,
                                  jnp.float32(self.t_lex),
                                  jnp.float32(self.t_sem))
        raise ValueError(self.method)

    def _step(self, q, rng, dataset):
        lat = self.s.latency.sample_edge()
        t0 = time.perf_counter()
        ok, slot, _ = self._match(q)
        ok = bool(ok)
        lat += time.perf_counter() - t0
        if ok:
            ids = np.asarray(self.state.doc_ids[int(slot)])
        else:
            ids, vecs, t = self.s.full_search(q["emb"], q.get("terms"),
                                              q.get("term_weights"))
            lat += self.s.latency.sample_cloud() + t
            scores = np.asarray(self.s.corpus[ids] @ q["emb"])
            self.state = reuse_insert(
                self.state, jnp.asarray(q["emb"]),
                jnp.asarray(ids.astype(np.int32)), jnp.asarray(vecs),
                jnp.asarray(scores),
                jnp.asarray(minhash_signature(q["tokens"])))
        return ids, ok, lat


class CRAGEngine(HasEngine):
    """HaS pipeline with homology validation replaced by an LLM evaluator."""

    def __init__(self, service: RetrievalService, cfg: HasConfig | None = None,
                 evaluator: CRAGEvaluator | None = None, seed: int = 0,
                 n_tenants: int = 1):
        super().__init__(service, cfg, seed=seed, n_tenants=n_tenants)
        self.evaluator = evaluator or CRAGEvaluator()

    def _step(self, q, rng, dataset):
        tenant = int(q.get("tenant", 0))
        lat = self.s.latency.sample_edge()
        t0 = time.perf_counter()
        out = speculate_batch(self.cfg, self.state, self.index,
                              jnp.asarray(q["emb"])[None],
                              backend=self.backend,
                              tenant_ids=self._tids(tenant))
        jax.block_until_ready(out)
        lat += (time.perf_counter() - t0) + self._fuzzy_time()
        draft = np.asarray(out["draft_ids"][0])
        golden = self.s.world.golden_mask(q["entity"], q["attr"], draft)
        lat += self.evaluator.latency_s              # LLM inference cost
        accept = self.evaluator.evaluate(rng, golden, dataset == "popqa")
        if accept:
            return draft, True, lat
        ids, vecs, t = self.s.full_search(q["emb"], q.get("terms"),
                                          q.get("term_weights"))
        lat += self.s.latency.sample_cloud() + t
        self.state = cache_update(
            self.cfg, self.state, jnp.asarray(q["emb"]),
            jnp.asarray(ids.astype(np.int32)), jnp.asarray(vecs),
            tenant_id=(None if self.n_tenants == 1 else tenant))
        self.s.backend.on_ingest(
            np.asarray(q["emb"])[None], ids.astype(np.int32)[None],
            self.state,
            tenant_ids=(None if self.n_tenants == 1
                        else np.array([tenant], np.int32)))
        return ids, False, lat
