"""Per-stage virtual-clock tracing for the continuous-batching scheduler.

Every request served by ``ContinuousBatchingScheduler`` records a span
breakdown of its end-to-end latency on the virtual clock — one float per
stage, summing EXACTLY to ``t_done - t_arrive`` (the conservation property
tests/test_overload.py asserts for every channel):

  ``queue_wait``   admission-queue wait until its speculation batch
                   dispatches
  ``replay``       bounded-lag delta replay charged to the dispatching edge
                   slot (the serving replica catches up to the primary
                   before the batch runs; 0 when the replica was fresh or
                   R == 1)
  ``spec``         speculation-batch service time (fuzzy + cache-channel
                   scans)
  ``edge_rtt``     edge network round trip of the response
  ``reval_wait``   rejected-leader queue wait that ended in a late
                   re-validation accept (the ``reval`` channel's cloud-side
                   wait — no cloud work was done)
  ``cloud_queue``  full-retrieval queue wait until the cloud batch
                   dispatched (followers: until their leader's batch
                   dispatched, clipped at their own rejection time)
  ``cloud``        cloud RTT + coalesced full-scan service time
  ``ingest``       cache-ingest share: the ``cache_update_chunked`` fold +
                   ``on_ingest`` fan-out of the completed batch, charged on
                   the cloud-done path to every request returning from it
  ``lost``         virtual time thrown away by faults (serving/faults.py):
                   a crashed worker's partial service, a cancelled
                   straggler's head start over the hedge that beat it, a
                   failed search attempt, or a dead edge replica's
                   discarded speculation — work the request paid for but
                   that produced nothing
  ``retry_backoff`` exponential-backoff wait between a failed cloud
                   attempt and its retry dispatch
  ``reason``       agent reasoning time of a hop sub-query
                   (serving/agentic.py): the LLM synthesis step that turned
                   the previous hop's result into this hop's sub-query
                   (charged before the request enters admission), plus —
                   on a complex query's final hop — the trailing
                   answer-synthesis step after the last retrieval lands

Stages a request never enters stay 0 (e.g. a ``draft`` accept has only
``queue_wait``/``replay``/``spec``/``edge_rtt``; a ``shed`` rejection has
all-zero spans and ``t_done == t_arrive``; ``lost``/``retry_backoff``
stay 0 in any fault-free run; ``reason`` stays 0 for every non-agentic
request).

:class:`Trace` is the result-side container: per-request span arrays plus
``stage_breakdown()`` (aggregate seconds/fraction per stage) and
``timeline(bucket_s)`` (per-virtual-time-bucket stage mass, keyed by each
request's completion bucket) for benchmarks to assert on.  Tracing is
bookkeeping only — it never advances the virtual clock, which
benchmarks/sched_throughput.py pins with a zero-cost-delta verdict
(tracing off, legacy accounting == the pre-PR golden traces bit-exactly).
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: span keys, in pipeline order (see module docstring)
STAGES = ("queue_wait", "replay", "spec", "edge_rtt", "reval_wait",
          "cloud_queue", "cloud", "ingest", "lost", "retry_backoff",
          "reason")


def empty_spans() -> dict[str, float]:
    """One request's span accumulator (all stages, zeroed)."""
    return {s: 0.0 for s in STAGES}


@dataclasses.dataclass
class Trace:
    """Per-request span breakdown of one scheduler stream (virtual clock).

    ``spans[stage]`` is a ``[n]`` float array of seconds; for every request
    ``sum_stage spans[stage][i] == t_done[i] - t_arrive[i]`` exactly.
    """
    t_arrive: np.ndarray                 # [n]
    t_done: np.ndarray                   # [n]
    channels: np.ndarray                 # [n] completion channel per request
    spans: dict[str, np.ndarray]         # stage -> [n] seconds

    @property
    def n(self) -> int:
        return len(self.t_arrive)

    def total(self) -> np.ndarray:
        """Per-request sum of spans (== end-to-end latency)."""
        if not self.n:
            return np.zeros(0)
        return np.sum([self.spans[s] for s in STAGES], axis=0)

    def conservation_residual(self) -> np.ndarray:
        """(t_done - t_arrive) - sum(spans): ~0 for every request."""
        return (self.t_done - self.t_arrive) - self.total()

    def stage_breakdown(self, channels=None) -> dict[str, dict[str, float]]:
        """Aggregate seconds per stage: total / mean-per-request / fraction
        of the stream's total latency mass.  ``channels`` (optional)
        restricts to requests completing on those channels.  NaN-safe on an
        empty stream (or an empty channel selection)."""
        if channels is None:
            m = np.ones(self.n, bool)
        else:
            m = np.isin(self.channels, np.asarray(channels))
        nsel = int(m.sum())
        mass = float(sum(self.spans[s][m].sum() for s in STAGES))
        out = {}
        for s in STAGES:
            tot = float(self.spans[s][m].sum()) if nsel else 0.0
            out[s] = {
                "total_s": tot,
                "mean_s": tot / nsel if nsel else float("nan"),
                "frac": tot / mass if mass > 0 else float("nan"),
            }
        return out

    def timeline(self, bucket_s: float) -> dict[str, np.ndarray]:
        """Stage mass per virtual-time bucket.

        Buckets the stream by COMPLETION time (``t_done``) into windows of
        ``bucket_s`` seconds from the first arrival, attributing each
        request's full span breakdown to its completion bucket — the
        load-over-time view overload benchmarks assert on (queue-wait mass
        exploding past saturation, shed keeping it flat).  Returns
        ``{"t": bucket start times [B], "n": completions per bucket [B],
        <stage>: seconds per bucket [B]}``.
        """
        if bucket_s <= 0:
            raise ValueError(f"bucket_s must be > 0, got {bucket_s}")
        if not self.n:
            z = np.zeros(0)
            return {"t": z, "n": z.astype(np.int64),
                    **{s: z.copy() for s in STAGES}}
        t0 = float(self.t_arrive.min())
        idx = np.floor((self.t_done - t0) / bucket_s).astype(np.int64)
        idx = np.maximum(idx, 0)
        nb = int(idx.max()) + 1
        out = {"t": t0 + bucket_s * np.arange(nb),
               "n": np.bincount(idx, minlength=nb)}
        for s in STAGES:
            out[s] = np.bincount(idx, weights=self.spans[s], minlength=nb)
        return out


def build_trace(reqs, t_arrive: np.ndarray, t_done: np.ndarray,
                channels: np.ndarray) -> Trace:
    """Assemble a :class:`Trace` from the scheduler's ``_Request`` list
    (each carrying a ``spans`` dict, possibly partially filled)."""
    spans = {s: np.array([r.spans.get(s, 0.0) for r in reqs])
             for s in STAGES}
    return Trace(t_arrive=t_arrive, t_done=t_done, channels=channels,
                 spans=spans)
