"""RAG serving: engines (HaS / baselines), latency model, batched serving."""
