"""RAG serving: engines (HaS / baselines), latency model, batched serving,
and the event-driven continuous-batching scheduler (scheduler.py)."""
