"""RAG serving: engines (HaS / baselines), latency model, batched serving,
the event-driven continuous-batching scheduler (scheduler.py), and cache
replication — the delta-log substrate + cloud warm standbys
(replication.py) and the edge speculation replica pool (edge_pool.py)."""
