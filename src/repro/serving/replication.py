"""HaS edge-cache replication: snapshot, warm standby, failover.

The paper deploys HaS as an edge component; in production the edge node is
the new single point of failure for the latency win (losing the cache means
every query pays the cloud round-trip until the cache re-warms — minutes of
degraded P99).  This module gives the HaS state the same durability story
the training stack has:

  * ``snapshot`` / ``restore``: the HasState pytree (query cache, doc store,
    ring pointers) serializes through the checkpoint manager (atomic +
    validated) — the fuzzy-channel IVF index is rebuilt from the corpus, not
    checkpointed (it is derived state).
  * ``WarmStandby``: holds a delta log of cache_update inputs since the last
    snapshot and can replay them onto a restored snapshot, so a standby
    engine resumes with at most ``max_lag`` queries of acceptance-rate loss.

Serving integration: ``retrieval/service.py::ReplicaBackend`` routes the
scheduler's full-retrieval worker pool through warm standbys and mirrors
every cache ingest onto each standby's delta log (``record_update``) via
the backend's ``on_ingest`` hook — with zero lag, ``failover()`` rebuilds
EXACTLY the primary's cache (tests/test_retrieval_backends.py asserts
bit-equality), so the scheduler no longer holds the only authoritative
copy.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.has import (HasConfig, HasState, cache_update_chunked,
                            init_has_state, init_tenant_states)


def snapshot(mgr: CheckpointManager, step: int, state: HasState,
             blocking: bool = True) -> None:
    tree = {"query_emb": state.query_emb, "query_doc_ids": state.query_doc_ids,
            "query_valid": state.query_valid, "q_ptr": state.q_ptr,
            "doc_emb": state.doc_emb, "doc_ids": state.doc_ids,
            "d_ptr": state.d_ptr}
    mgr.save(step, tree, blocking=blocking)


def restore(mgr: CheckpointManager, cfg: HasConfig,
            n_tenants: int = 1) -> tuple[int, HasState] | None:
    template = (init_has_state(cfg) if n_tenants == 1
                else init_tenant_states(cfg, n_tenants))
    tree = {"query_emb": template.query_emb,
            "query_doc_ids": template.query_doc_ids,
            "query_valid": template.query_valid, "q_ptr": template.q_ptr,
            "doc_emb": template.doc_emb, "doc_ids": template.doc_ids,
            "d_ptr": template.d_ptr}
    out = mgr.restore_latest(tree)
    if out is None:
        return None
    step, t = out
    return step, HasState(
        query_emb=jnp.asarray(t["query_emb"]),
        query_doc_ids=jnp.asarray(t["query_doc_ids"]),
        query_valid=jnp.asarray(t["query_valid"]),
        q_ptr=jnp.asarray(t["q_ptr"]),
        doc_emb=jnp.asarray(t["doc_emb"]),
        doc_ids=jnp.asarray(t["doc_ids"]),
        d_ptr=jnp.asarray(t["d_ptr"]))


@dataclasses.dataclass
class WarmStandby:
    """Delta-log replication for a standby HaS engine.

    ``n_tenants > 1`` replicates a tenant-partitioned primary
    (``core/has.py::init_tenant_states``): the delta log is PER TENANT
    (one deque each, so ``max_lag`` bounds every tenant's acceptance-rate
    loss independently — a chatty tenant cannot push a quiet tenant's
    deltas out of the replay window), and ``failover`` replays each
    tenant's log into its own partition, rebuilding every partition
    bit-exactly.  ``n_tenants == 1`` is the historical single-log path
    (``self.log``).
    """
    cfg: HasConfig
    mgr: CheckpointManager
    snapshot_every: int = 500
    max_lag: int = 1000
    replay_batch: int = 64         # delta entries folded per device dispatch
    n_tenants: int = 1

    def __post_init__(self):
        self.logs: list[deque] = [deque(maxlen=self.max_lag)
                                  for _ in range(self.n_tenants)]
        self._since_snapshot = 0
        self._step = 0

    @property
    def log(self) -> deque:
        """Tenant-0 delta log (the whole log when ``n_tenants == 1``)."""
        return self.logs[0]

    def record_update(self, q_emb: np.ndarray, full_ids: np.ndarray,
                      full_vecs: np.ndarray, state: HasState,
                      tenant_id: int = 0) -> None:
        """Call after every primary cache_update."""
        self.record_batch(np.asarray(q_emb)[None], np.asarray(full_ids)[None],
                          np.asarray(full_vecs)[None], state,
                          tenant_ids=np.array([tenant_id], np.int32))

    def record_batch(self, q_embs: np.ndarray, full_ids: np.ndarray,
                     full_vecs: np.ndarray, state: HasState,
                     tenant_ids: np.ndarray | None = None) -> None:
        """Append a whole ingest batch, then apply the snapshot cadence ONCE.

        ``state`` must be the post-batch primary state.  The cadence check
        runs after ALL rows are appended: snapshotting mid-batch would
        clear the log while the batch tail still gets appended, and a
        failover would then replay rows the snapshot already contains
        (double-applying them into the FIFO rings).  An exactly-full batch
        (rows landing precisely on ``snapshot_every``) therefore snapshots
        once, after the last row, with an empty log left behind.

        ``tenant_ids [N]`` routes each row to its tenant's delta log and is
        REQUIRED when ``n_tenants > 1`` (rows must match the partition the
        primary folded them into — silently defaulting would funnel every
        delta into tenant 0 and diverge the replica from the primary).
        """
        if tenant_ids is None:
            if self.n_tenants > 1:
                raise ValueError(
                    f"record_batch on a {self.n_tenants}-tenant standby "
                    "requires tenant_ids — the rows' partition cannot be "
                    "inferred")
            tenant_ids = np.zeros(len(q_embs), np.int32)
        else:
            tenant_ids = np.asarray(tenant_ids, np.int32)
            if len(tenant_ids) and not (0 <= tenant_ids.min()
                                        and tenant_ids.max()
                                        < self.n_tenants):
                raise ValueError(
                    f"tenant ids [{tenant_ids.min()}, {tenant_ids.max()}] "
                    f"out of range for n_tenants={self.n_tenants}")
        for q, ids, vecs, t in zip(q_embs, full_ids, full_vecs, tenant_ids):
            self.logs[int(t)].append((np.asarray(q), np.asarray(ids),
                                      np.asarray(vecs)))
        self._since_snapshot += len(q_embs)
        self._step += len(q_embs)
        if self._since_snapshot >= self.snapshot_every:
            snapshot(self.mgr, self._step, state, blocking=False)
            self._since_snapshot = 0
            for log in self.logs:
                log.clear()

    def failover(self) -> HasState:
        """Rebuild the freshest possible state on the standby.

        Each tenant's delta log replays into its own partition through
        ``cache_update_chunked`` — one fused donated-buffer scan per
        ``replay_batch`` chunk (padded, masked) instead of a per-entry
        dispatch loop, so recovery time is dominated by the scan itself
        rather than host round-trips.  With no snapshot and empty logs
        this is a cold start (fresh state).
        """
        out = restore(self.mgr, self.cfg, n_tenants=self.n_tenants)
        if out is not None:
            state = out[1]
        elif self.n_tenants == 1:
            state = init_has_state(self.cfg)
        else:
            state = init_tenant_states(self.cfg, self.n_tenants)
        for t, log_t in enumerate(self.logs):
            log = list(log_t)
            if not log:
                continue
            state = cache_update_chunked(
                self.cfg, state,
                np.stack([q for q, _, _ in log]),
                np.stack([ids for _, ids, _ in log]).astype(np.int32),
                np.stack([vecs for _, _, vecs in log]),
                chunk=self.replay_batch,
                tenant_ids=(None if self.n_tenants == 1
                            else np.full(len(log), t, np.int32)))
        return state
