"""HaS edge-cache replication: delta log, snapshot, warm standby, failover.

The paper deploys HaS as an edge component; in production the edge cache
used to be the single point of failure for the latency win.  It no longer
is: every ingest fans out to warm standbys and edge replicas over the
delta log below, and the serving scheduler (serving/scheduler.py) reacts
to a mid-stream cache loss instead of dying with it — a crashed edge
replica's in-flight speculation batch is rerouted to the full-retrieval
channel (degraded but correct results), the dead slot is rebuilt in the
background from a primary snapshot plus delta replay (``sync``/
``resync_from``, rebuild time charged to the virtual clock), and a lost
PRIMARY promotes the freshest replica (``EdgeReplicaPool.promote``) so
the request trace continues on the cache the primary would have had.
Replication traffic itself is hardened: rows carry explicit sequence
numbers, lost appends surface as a replay-time gap error instead of a
silently diverged replica, and duplicated appends are deduplicated by
per-batch ingest keys (idempotent ingest — a retried cloud batch whose
first attempt landed never folds twice).  This module gives the HaS
state the same durability story the training stack has:

  * ``DeltaLog``: the ONE replication substrate — an append-only log of
    cache_update inputs with monotone global sequence numbers.  Cloud warm
    standbys (``WarmStandby``) consume it clear-on-snapshot style (failover
    replays everything currently held; a snapshot clears it), and the edge
    speculation replica pool (``serving/edge_pool.py::EdgeReplicaPool``)
    consumes it delta-cursor style: each replica keeps the sequence number
    it has replayed up to and ``since(cursor)`` hands it exactly the rows
    it is missing without mutating the log.
  * ``snapshot`` / ``restore``: the HasState pytree (query cache, doc store,
    ring pointers, tenant layout) serializes through the checkpoint manager
    (atomic + validated) — the fuzzy-channel IVF index is rebuilt from the
    corpus, not checkpointed (it is derived state).
  * ``WarmStandby``: per-tenant delta logs since the last snapshot, replayed
    onto the restored snapshot at ``failover()`` so a standby engine resumes
    with at most ``max_lag`` queries of acceptance-rate loss.

Serving integration: ``retrieval/service.py::ReplicaBackend`` routes the
scheduler's full-retrieval worker pool through warm standbys and mirrors
every cache ingest onto each member's delta log (``record_batch``) via
the backend's ``on_ingest`` hook — with zero lag, ``failover()`` rebuilds
EXACTLY the primary's cache (tests/test_retrieval_backends.py asserts
bit-equality), so the scheduler no longer holds the only authoritative
copy.  ``EdgeReplicaPool`` implements the same ``record_batch`` sink
protocol, so cloud standbys and edge speculation replicas ride one
reconciliation path.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.has import (HasConfig, HasState, cache_update_chunked,
                            init_has_state, init_tenant_states)


def validate_ingest_batch(q_embs, full_ids, full_vecs,
                          tenant_ids=None) -> None:
    """All leading dimensions of one ingest batch must agree.

    The recording loops iterate the four arrays in lockstep; a bare
    ``zip`` would silently DROP tail rows when one argument is shorter
    (diverging the replica from the primary with no error), so every
    recorder validates up front and raises instead.
    """
    lens = {"q_embs": len(q_embs), "full_ids": len(full_ids),
            "full_vecs": len(full_vecs)}
    if tenant_ids is not None:
        lens["tenant_ids"] = len(tenant_ids)
    if len(set(lens.values())) > 1:
        raise ValueError(
            "ingest batch leading dimensions disagree ("
            + ", ".join(f"{k}={v}" for k, v in lens.items())
            + ") — a zip over them would silently drop tail rows")


def gather_doc_vecs(corpus_np: np.ndarray,
                    full_ids: np.ndarray) -> np.ndarray:
    """Gather ``[..., k]`` doc ids -> ``[..., k, d]`` corpus rows, with
    padded (``-1``) ids ZEROED.

    ``distributed_flat_search`` / ``sharded_topk_reference`` emit ``-1``
    ids when the corpus holds fewer than k rows; a raw
    ``corpus_np[full_ids]`` wraps those pythonically and silently gathers
    the LAST corpus row into every padded slot, corrupting replica delta
    logs.  Zero vectors are inert on replay (``cache_update`` drops
    ``id < 0`` rows before they touch the doc store).
    """
    full_ids = np.asarray(full_ids)
    vecs = np.asarray(corpus_np)[np.maximum(full_ids, 0)]
    vecs = vecs.astype(np.float32, copy=True)
    vecs[full_ids < 0] = 0.0
    return vecs


class DeltaLog:
    """Append-only ingest log with EXPLICIT monotone sequence numbers.

    Every retained row is stored as ``(seq, payload)``: the i-th append
    (0-based since the log's creation) gets sequence number ``i`` forever,
    even after eviction/compaction — ``base`` is the sequence of the
    oldest retained row and ``head`` is one past the newest sequence ever
    PRODUCED.  Two consumption styles share it:

    * clear-on-snapshot (``WarmStandby``): ``clear()`` after a snapshot —
      ``failover`` replays whatever is currently held.
    * delta-cursor (``EdgeReplicaPool``): each replica remembers the
      sequence it has replayed up to and asks ``since(cursor)`` for the
      rows it is missing; nothing is cleared, and ``compact_below`` drops
      rows every cursor has passed.

    ``maxlen`` bounds memory the deque way: appending to a full log
    evicts the oldest row and advances ``base``, so a cursor that has
    fallen behind ``base`` detects (``LookupError``) that it must full
    resync rather than silently skipping rows.

    The sequences are explicit (not implied by position) so that LOST
    replication traffic is detectable: ``mark_lost(n)`` consumes ``n``
    sequence numbers without appending rows — the producer ingested
    them, the channel dropped them — and a consumer replaying across the
    resulting gap sees non-consecutive sequences from ``since_items``
    (``EdgeReplicaPool.sync`` raises a ``ValueError`` naming the replica
    and the expected/actual sequence instead of silently diverging).
    """

    def __init__(self, maxlen: int | None = None):
        self._rows: deque = deque(maxlen=maxlen)
        self._next = 0                     # next sequence to hand out

    @property
    def base(self) -> int:
        """Sequence of the oldest retained row (``head`` when empty)."""
        return self._rows[0][0] if self._rows else self._next

    @property
    def head(self) -> int:
        """One past the newest sequence ever produced (lost rows count)."""
        return self._next

    def append(self, row) -> None:
        self._rows.append((self._next, row))   # full deque evicts oldest
        self._next += 1

    def mark_lost(self, n: int = 1) -> None:
        """Consume ``n`` sequence numbers without retaining rows — the
        producer ingested them but the replication channel dropped them.
        Consumers replaying across the gap detect it via ``since_items``
        (non-consecutive sequences) rather than silently skipping rows."""
        if n < 0:
            raise ValueError(f"mark_lost needs n >= 0, got {n}")
        self._next += n

    def clear(self) -> None:
        self._rows.clear()

    def since_items(self, cursor: int) -> list:
        """``(seq, row)`` pairs with seq >= cursor.  Consecutive-sequence
        validation is the CONSUMER's job (a gap means rows were lost in
        transit)."""
        if cursor < self.base:
            raise LookupError(
                f"cursor {cursor} has fallen behind the log base "
                f"{self.base} (rows were evicted) — the consumer must "
                "full-resync from a snapshot")
        # rows are seq-sorted; skip the replayed prefix
        skip = 0
        for seq, _ in self._rows:
            if seq >= cursor:
                break
            skip += 1
        return list(itertools.islice(self._rows, skip, None))

    def since(self, cursor: int) -> list:
        """Rows with sequence >= cursor (the delta a consumer is missing)."""
        return [row for _, row in self.since_items(cursor)]

    def compact_below(self, cursor: int) -> None:
        """Drop rows every consumer has replayed (min cursor over them)."""
        while self._rows and self._rows[0][0] < cursor:
            self._rows.popleft()

    def __len__(self) -> int:
        return len(self._rows)

    def __iter__(self):
        return iter(row for _, row in self._rows)


def _tenant_stamp(state: HasState) -> int:
    """Layout stamp persisted with a snapshot: 0 == the historical
    unstacked single-tenant layout; T >= 1 == a stacked
    ``init_tenant_states`` store with T partitions (a stacked ``[1, ...]``
    store stamps 1, distinguishing it from the unstacked layout whose
    array shapes may otherwise be compatible)."""
    return int(state.q_ptr.shape[0]) if state.q_ptr.ndim else 0


def _stamp_name(stamp: int) -> str:
    return ("the historical unstacked single-tenant layout" if stamp == 0
            else f"a stacked {stamp}-tenant store")


def snapshot(mgr: CheckpointManager, step: int, state: HasState,
             blocking: bool = True) -> None:
    """Persist the HasState pytree (+ its tenant-layout stamp).

    Safe to call with ``blocking=False`` right before donation churn: the
    checkpoint manager COPIES the tree to host before the writer thread
    sees it (on CPU its host view could otherwise alias the device
    buffers, which the next donated ``cache_update_batched`` overwrites in
    place mid-save — see ``CheckpointManager.save``).
    """
    tree = {"query_emb": state.query_emb, "query_doc_ids": state.query_doc_ids,
            "query_valid": state.query_valid, "q_ptr": state.q_ptr,
            "doc_emb": state.doc_emb, "doc_ids": state.doc_ids,
            "d_ptr": state.d_ptr,
            "n_tenants": np.int32(_tenant_stamp(state))}
    mgr.save(step, tree, blocking=blocking)


def restore(mgr: CheckpointManager, cfg: HasConfig,
            n_tenants: int = 1) -> tuple[int, HasState] | None:
    """Restore the latest snapshot, validating its tenant layout.

    The checkpoint records the layout it was saved with
    (:func:`_tenant_stamp`); restoring with a different ``n_tenants``
    raises a clear ``ValueError`` instead of an opaque downstream shape
    mismatch — or, worse, a silent misread between the unstacked T == 1
    layout and a stacked store of compatible shapes.  Pre-stamp
    checkpoints (no ``n_tenants`` leaf) restore without validation.
    """
    template = (init_has_state(cfg) if n_tenants == 1
                else init_tenant_states(cfg, n_tenants))
    tree = {"query_emb": template.query_emb,
            "query_doc_ids": template.query_doc_ids,
            "query_valid": template.query_valid, "q_ptr": template.q_ptr,
            "doc_emb": template.doc_emb, "doc_ids": template.doc_ids,
            "d_ptr": template.d_ptr}
    try:
        out = mgr.restore_latest({**tree,
                                  "n_tenants": np.zeros((), np.int32)})
        stamp = None if out is None else int(out[1].pop("n_tenants"))
    except KeyError:                   # pre-stamp checkpoint: no layout leaf
        out = mgr.restore_latest(dict(tree))
        stamp = None
    if out is None:
        return None
    step, t = out
    expected = 0 if n_tenants == 1 else n_tenants
    if stamp is not None and stamp != expected:
        raise ValueError(
            f"checkpoint at step {step} holds {_stamp_name(stamp)} but "
            f"restore requested n_tenants={n_tenants} "
            f"({_stamp_name(expected)}) — pass the tenant count the state "
            "was snapshotted with")
    return step, HasState(
        query_emb=jnp.asarray(t["query_emb"]),
        query_doc_ids=jnp.asarray(t["query_doc_ids"]),
        query_valid=jnp.asarray(t["query_valid"]),
        q_ptr=jnp.asarray(t["q_ptr"]),
        doc_emb=jnp.asarray(t["doc_emb"]),
        doc_ids=jnp.asarray(t["doc_ids"]),
        d_ptr=jnp.asarray(t["d_ptr"]))


@dataclasses.dataclass
class WarmStandby:
    """Delta-log replication for a standby HaS engine.

    ``n_tenants > 1`` replicates a tenant-partitioned primary
    (``core/has.py::init_tenant_states``): the delta log is PER TENANT
    (one deque each, so ``max_lag`` bounds every tenant's acceptance-rate
    loss independently — a chatty tenant cannot push a quiet tenant's
    deltas out of the replay window), and ``failover`` replays each
    tenant's log into its own partition, rebuilding every partition
    bit-exactly.  ``n_tenants == 1`` is the historical single-log path
    (``self.log``).
    """
    cfg: HasConfig
    mgr: CheckpointManager
    snapshot_every: int = 500
    max_lag: int = 1000
    replay_batch: int = 64         # delta entries folded per device dispatch
    n_tenants: int = 1

    def __post_init__(self):
        self.logs: list[DeltaLog] = [DeltaLog(maxlen=self.max_lag)
                                     for _ in range(self.n_tenants)]
        self._since_snapshot = 0
        self._step = 0
        self._seen_keys: set = set()

    @property
    def log(self) -> DeltaLog:
        """Tenant-0 delta log (the whole log when ``n_tenants == 1``)."""
        return self.logs[0]

    def record_update(self, q_emb: np.ndarray, full_ids: np.ndarray,
                      full_vecs: np.ndarray, state: HasState,
                      tenant_id: int = 0) -> None:
        """Call after every primary cache_update."""
        self.record_batch(np.asarray(q_emb)[None], np.asarray(full_ids)[None],
                          np.asarray(full_vecs)[None], state,
                          tenant_ids=np.array([tenant_id], np.int32))

    def record_batch(self, q_embs: np.ndarray, full_ids: np.ndarray,
                     full_vecs: np.ndarray, state: HasState,
                     tenant_ids: np.ndarray | None = None, *,
                     ingest_key=None) -> None:
        """Append a whole ingest batch, then apply the snapshot cadence ONCE.

        ``state`` must be the post-batch primary state.  The cadence check
        runs after ALL rows are appended: snapshotting mid-batch would
        clear the log while the batch tail still gets appended, and a
        failover would then replay rows the snapshot already contains
        (double-applying them into the FIFO rings).  An exactly-full batch
        (rows landing precisely on ``snapshot_every``) therefore snapshots
        once, after the last row, with an empty log left behind.

        ``tenant_ids [N]`` routes each row to its tenant's delta log and is
        REQUIRED when ``n_tenants > 1`` (rows must match the partition the
        primary folded them into — silently defaulting would funnel every
        delta into tenant 0 and diverge the replica from the primary).

        ``ingest_key`` makes the append IDEMPOTENT: a batch whose key was
        already recorded is dropped whole (a retried cloud dispatch whose
        first attempt actually landed must not fold twice).  ``None``
        (the default) skips dedup — unkeyed callers keep at-least-once
        semantics.
        """
        if ingest_key is not None:
            if ingest_key in self._seen_keys:
                return
            self._seen_keys.add(ingest_key)
        validate_ingest_batch(q_embs, full_ids, full_vecs, tenant_ids)
        if tenant_ids is None:
            if self.n_tenants > 1:
                raise ValueError(
                    f"record_batch on a {self.n_tenants}-tenant standby "
                    "requires tenant_ids — the rows' partition cannot be "
                    "inferred")
            tenant_ids = np.zeros(len(q_embs), np.int32)
        else:
            tenant_ids = np.asarray(tenant_ids, np.int32)
            if len(tenant_ids) and not (0 <= tenant_ids.min()
                                        and tenant_ids.max()
                                        < self.n_tenants):
                raise ValueError(
                    f"tenant ids [{tenant_ids.min()}, {tenant_ids.max()}] "
                    f"out of range for n_tenants={self.n_tenants}")
        for q, ids, vecs, t in zip(q_embs, full_ids, full_vecs, tenant_ids):
            self.logs[int(t)].append((np.asarray(q), np.asarray(ids),
                                      np.asarray(vecs)))
        self._since_snapshot += len(q_embs)
        self._step += len(q_embs)
        if self._since_snapshot >= self.snapshot_every:
            snapshot(self.mgr, self._step, state, blocking=False)
            self._since_snapshot = 0
            for log in self.logs:
                log.clear()

    def failover(self) -> HasState:
        """Rebuild the freshest possible state on the standby.

        Each tenant's delta log replays into its own partition through
        ``cache_update_chunked`` — one fused donated-buffer scan per
        ``replay_batch`` chunk (padded, masked) instead of a per-entry
        dispatch loop, so recovery time is dominated by the scan itself
        rather than host round-trips.  With no snapshot and empty logs
        this is a cold start (fresh state).
        """
        out = restore(self.mgr, self.cfg, n_tenants=self.n_tenants)
        if out is not None:
            state = out[1]
        elif self.n_tenants == 1:
            state = init_has_state(self.cfg)
        else:
            state = init_tenant_states(self.cfg, self.n_tenants)
        for t, log_t in enumerate(self.logs):
            log = list(log_t)
            if not log:
                continue
            state = cache_update_chunked(
                self.cfg, state,
                np.stack([q for q, _, _ in log]),
                np.stack([ids for _, ids, _ in log]).astype(np.int32),
                np.stack([vecs for _, _, vecs in log]),
                chunk=self.replay_batch,
                tenant_ids=(None if self.n_tenants == 1
                            else np.full(len(log), t, np.int32)))
        return state
