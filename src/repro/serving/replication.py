"""HaS edge-cache replication: snapshot, warm standby, failover.

The paper deploys HaS as an edge component; in production the edge node is
the new single point of failure for the latency win (losing the cache means
every query pays the cloud round-trip until the cache re-warms — minutes of
degraded P99).  This module gives the HaS state the same durability story
the training stack has:

  * ``snapshot`` / ``restore``: the HasState pytree (query cache, doc store,
    ring pointers) serializes through the checkpoint manager (atomic +
    validated) — the fuzzy-channel IVF index is rebuilt from the corpus, not
    checkpointed (it is derived state).
  * ``WarmStandby``: holds a delta log of cache_update inputs since the last
    snapshot and can replay them onto a restored snapshot, so a standby
    engine resumes with at most ``max_lag`` queries of acceptance-rate loss.

Serving integration: ``retrieval/service.py::ReplicaBackend`` routes the
scheduler's full-retrieval worker pool through warm standbys and mirrors
every cache ingest onto each standby's delta log (``record_update``) via
the backend's ``on_ingest`` hook — with zero lag, ``failover()`` rebuilds
EXACTLY the primary's cache (tests/test_retrieval_backends.py asserts
bit-equality), so the scheduler no longer holds the only authoritative
copy.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.has import (HasConfig, HasState, cache_update_chunked,
                            init_has_state)


def snapshot(mgr: CheckpointManager, step: int, state: HasState,
             blocking: bool = True) -> None:
    tree = {"query_emb": state.query_emb, "query_doc_ids": state.query_doc_ids,
            "query_valid": state.query_valid, "q_ptr": state.q_ptr,
            "doc_emb": state.doc_emb, "doc_ids": state.doc_ids,
            "d_ptr": state.d_ptr}
    mgr.save(step, tree, blocking=blocking)


def restore(mgr: CheckpointManager, cfg: HasConfig) -> tuple[int, HasState] | None:
    template = init_has_state(cfg)
    tree = {"query_emb": template.query_emb,
            "query_doc_ids": template.query_doc_ids,
            "query_valid": template.query_valid, "q_ptr": template.q_ptr,
            "doc_emb": template.doc_emb, "doc_ids": template.doc_ids,
            "d_ptr": template.d_ptr}
    out = mgr.restore_latest(tree)
    if out is None:
        return None
    step, t = out
    return step, HasState(
        query_emb=jnp.asarray(t["query_emb"]),
        query_doc_ids=jnp.asarray(t["query_doc_ids"]),
        query_valid=jnp.asarray(t["query_valid"]),
        q_ptr=jnp.asarray(t["q_ptr"]),
        doc_emb=jnp.asarray(t["doc_emb"]),
        doc_ids=jnp.asarray(t["doc_ids"]),
        d_ptr=jnp.asarray(t["d_ptr"]))


@dataclasses.dataclass
class WarmStandby:
    """Delta-log replication for a standby HaS engine."""
    cfg: HasConfig
    mgr: CheckpointManager
    snapshot_every: int = 500
    max_lag: int = 1000
    replay_batch: int = 64         # delta entries folded per device dispatch

    def __post_init__(self):
        self.log: deque = deque(maxlen=self.max_lag)
        self._since_snapshot = 0
        self._step = 0

    def record_update(self, q_emb: np.ndarray, full_ids: np.ndarray,
                      full_vecs: np.ndarray, state: HasState) -> None:
        """Call after every primary cache_update."""
        self.record_batch(np.asarray(q_emb)[None], np.asarray(full_ids)[None],
                          np.asarray(full_vecs)[None], state)

    def record_batch(self, q_embs: np.ndarray, full_ids: np.ndarray,
                     full_vecs: np.ndarray, state: HasState) -> None:
        """Append a whole ingest batch, then apply the snapshot cadence ONCE.

        ``state`` must be the post-batch primary state.  The cadence check
        runs after ALL rows are appended: snapshotting mid-batch would
        clear the log while the batch tail still gets appended, and a
        failover would then replay rows the snapshot already contains
        (double-applying them into the FIFO rings).
        """
        for q, ids, vecs in zip(q_embs, full_ids, full_vecs):
            self.log.append((np.asarray(q), np.asarray(ids),
                             np.asarray(vecs)))
        self._since_snapshot += len(q_embs)
        self._step += len(q_embs)
        if self._since_snapshot >= self.snapshot_every:
            snapshot(self.mgr, self._step, state, blocking=False)
            self._since_snapshot = 0
            self.log.clear()

    def failover(self) -> HasState:
        """Rebuild the freshest possible state on the standby.

        The delta log replays through ``cache_update_chunked`` — one fused
        donated-buffer scan per ``replay_batch`` chunk (padded, masked)
        instead of a per-entry dispatch loop, so recovery time is dominated
        by the scan itself rather than host round-trips.
        """
        out = restore(self.mgr, self.cfg)
        state = out[1] if out is not None else init_has_state(self.cfg)
        log = list(self.log)
        if not log:
            return state
        return cache_update_chunked(
            self.cfg, state,
            np.stack([q for q, _, _ in log]),
            np.stack([ids for _, ids, _ in log]).astype(np.int32),
            np.stack([vecs for _, _, vecs in log]),
            chunk=self.replay_batch)
