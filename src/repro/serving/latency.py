"""Cloud/edge latency model (paper §IV-A deployment simulation).

The paper deploys full-database retrieval 'on the cloud' (0.1–0.2 s injected
network latency, Faiss-IndexPQ over 49.2M passages) and HaS 'on the edge'
(0.01–0.05 s).  This container is CPU-only with a smaller synthetic corpus,
so per-query latency is composed as:

    measured wall-clock of the jitted compute x corpus_scale  (for any op
    whose cost scales with corpus size: full search, fuzzy IVF scan)
  + sampled network RTT (cloud or edge)
  + measured cache/validation compute (corpus-independent, unscaled)

corpus_scale = target_corpus / actual_corpus extrapolates the measured
matmul/IVF time to the paper's 49.2M-passage scale, keeping every relative
comparison (the paper's evaluation axis) intact.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


@dataclasses.dataclass
class LatencyModel:
    cloud_rtt: tuple[float, float] = (0.1, 0.2)
    edge_rtt: tuple[float, float] = (0.01, 0.05)
    target_corpus: int = 49_200_000
    actual_corpus: int = 100_000
    d: int = 64
    # Effective scan bandwidth. The default models the paper's workstation
    # (I9-13900KF): 49.2M x 64 x 4 B / 10.3 GB/s = 1.22 s full scan, matching
    # the paper's ~1.23 s ENNS compute (AvgL 1.3845 minus cloud RTT).
    # RetrievalService(calibrate=True) replaces it with THIS machine's
    # measured bandwidth instead.
    bandwidth: float = 10.3e9
    # Per-shard overhead of the distributed scan (retrieval/distributed.py):
    # every worker all-gathers and merges O(shards·k) candidate pairs, so
    # the merge cost GROWS with the shard count — modeled as this fraction
    # of the full (unsharded) scan time per extra shard.  0.2% puts the
    # over-sharding inflection (where adding shards stops helping) at
    # s ≈ sqrt(1/0.002) ≈ 22 shards.
    shard_merge_overhead: float = 0.002
    # Agent reasoning time per hop of a multi-hop (Auto-RAG) query: the LLM
    # call that turns one hop's retrieval into the next hop's sub-query (or
    # the final answer).  The paper's Fig-13 pipeline charges one such step
    # after every hop; both the sequential AutoRagPipeline baseline and the
    # scheduler's hop-graph path draw it from HERE so the two arms are
    # charged identically (serving/agentic.py).
    reason_scale: float = 0.35
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    @property
    def corpus_scale(self) -> float:
        return self.target_corpus / max(self.actual_corpus, 1)

    def scan_time(self, n_vectors: float, bytes_per_dim: int = 4) -> float:
        """Analytic time to score n_vectors against one query."""
        return n_vectors * self.d * bytes_per_dim / self.bandwidth

    def full_scan_time(self) -> float:
        """Full-database ENNS at the paper's target corpus scale."""
        return self.scan_time(self.target_corpus)

    def ingest_time(self, rows: int, doc_cap: int, k: int) -> float:
        """Modeled edge time to fold ``rows`` (q, D_full) pairs into the
        HaS cache (``cache_update`` / its batched scan): per row, the doc
        dedup compares the k new ids against the whole doc ring
        (``doc_cap`` entries streamed once) and writes k doc vectors, and
        the replication fan-out appends the same k rows to the standby /
        edge-pool delta logs — ``scan_time(doc_cap + 2k)`` each.  The
        cache is edge-LOCAL state at its true size, so unlike the full
        scan this is NOT extrapolated to the target corpus.  Used for both
        the scheduler's cloud-done ingest charge and the edge replica
        pool's bounded-lag delta replay (the same fold)."""
        return rows * self.scan_time(doc_cap + 2 * k)

    def ann_scale(self, n_clusters: int, nprobe: int,
                  capacity_factor: float = 2.0, bytes_per_dim: int = 4,
                  residual_rows: int = 0) -> float:
        """Multiplier on ``full_scan_time()`` when the cloud stage is the
        IVF backend instead of a full-corpus scan: per query it streams the
        ``n_clusters`` f32 centroids (the probe matmul), then
        ``nprobe x capacity`` bucket rows at ``bytes_per_dim`` bytes each
        (1 for the int8 compressed residency, 4 for f32), plus the
        exact-scanned f32 residual buffer holding live-ingested spill.
        Capacity follows the build rule at target scale:
        ``target_corpus * capacity_factor / n_clusters`` padded rows per
        bucket — the padding is real streamed bytes, so it is charged."""
        c = max(1, int(n_clusters))
        p = max(1, min(int(nprobe), c))
        cap = self.target_corpus * capacity_factor / c
        scanned = (c + p * cap * (bytes_per_dim / 4.0)
                   + max(0, int(residual_rows)))
        return scanned / self.target_corpus

    def shard_scale(self, n_shards: int) -> float:
        """Multiplier on ``full_scan_time()`` when the scan is row-sharded
        over ``n_shards`` mesh workers (retrieval/distributed.py): every
        worker streams N/n_shards rows concurrently (the 1/s term), and the
        O(shards·k) all-gather candidate merge charges
        ``shard_merge_overhead`` of the full scan per extra shard — a
        linearly growing term, so over-sharding eventually costs more than
        it saves (minimum near s = sqrt(1/overhead))."""
        s = max(1, int(n_shards))
        return 1.0 / s + self.shard_merge_overhead * (s - 1)

    def hybrid_scale(self, dense_scale: float, lexical_terms: int,
                     pool: int) -> float:
        """Multiplier on ``full_scan_time()`` for the hybrid cloud stage
        (``HybridBackend``): the dense channel at its own multiplier
        (1.0 flat, ``shard_scale`` sharded, ``ann_scale`` ANN), PLUS the
        lexical postings stream — ``lexical_terms`` slots of (int32 term id
        + f32 weight) = 8 bytes per doc, charged relative to the 4·d-byte
        dense row the full scan streams — PLUS the fused rerank of the
        ``pool`` (= kd + kl) surviving candidates per query: a pool-sized
        pairwise-similarity pass and one pool x d rerank matmul, tiny next
        to either channel but charged so the fusion stage is never
        modeled as free."""
        lex = lexical_terms * 8.0 / (self.d * 4.0)
        p = max(1, int(pool))
        fuse = p * (p + self.d) / float(self.target_corpus)
        return float(dense_scale) + lex + fuse

    def calibrate(self, measured_s: float, n_vectors: int,
                  bytes_per_dim: int = 4) -> None:
        """Set effective bandwidth from one measured reference scan."""
        self.bandwidth = n_vectors * self.d * bytes_per_dim / max(measured_s, 1e-9)

    def reason_time(self) -> float:
        """Per-hop agent reasoning (sub-query / answer synthesis) time.

        Deterministic — no rng draw — so agentic traffic never perturbs the
        RTT sample stream shared with non-agentic requests."""
        return self.reason_scale

    def sample_cloud(self) -> float:
        return float(self._rng.uniform(*self.cloud_rtt))

    def sample_edge(self) -> float:
        return float(self._rng.uniform(*self.edge_rtt))


class Timer:
    """Wall-clock of a block of device work (block_until_ready outside)."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed = time.perf_counter() - self.t0
