"""Deterministic fault injection for the serving scheduler.

Chaos testing for the HaS serving stack, with the same purity contract as
everything else in the repo: a :class:`FaultPlan` is an explicit, ordered
set of fault events pinned to the scheduler's VIRTUAL clock, so a chaos
run is a pure function of ``(seed, plan, arrivals, queries)`` — the same
plan replays the same crash at the same virtual instant every time, and
an empty plan is bit-identical to not having this module at all
(tests/test_faults.py pins that against the pre-PR golden traces).

Fault model (``KINDS``):

``worker_crash``
    Cloud full-retrieval worker ``target`` dies at ``t``.  Its in-flight
    batch is lost and requeued by the scheduler; the worker rejoins the
    pool after ``down_s`` virtual seconds (``0`` = permanent).
``straggler``
    Worker ``target``'s service latency is multiplied by ``factor`` for
    dispatches STARTING in ``[t, t + duration_s)`` — the slow-node tail
    that deadlines + hedged re-dispatch are built to cut.
``search_fail``
    Dispatches to worker ``target`` starting in ``[t, t + duration_s)``
    fail transiently: the failure surfaces after the full service time
    and the scheduler retries with exponential backoff (bounded by
    ``retry_max``).
``replica_crash``
    Edge speculation replica ``target`` dies at ``t`` mid-stream: its
    in-flight speculation batch is rerouted to the full-retrieval
    channel and the slot is rebuilt in the background from the primary +
    shared delta log.
``delta_drop``
    The next ``count`` replication appends after ``t`` are LOST on the
    channel (the primary folded them, the replicas never see them) —
    surfaces as a sequence gap at the next delta replay.
``delta_dup``
    The next ``count`` replication appends after ``t`` are DUPLICATED on
    the channel — absorbed by idempotent ingest keys (a correct run is
    bit-identical to fault-free; that IS the no-duplicate-fold verdict).

:class:`FaultInjector` is the per-``serve()`` runtime view: the scheduler
pushes each event onto its heap, activates windows/counters here as they
fire, and queries the active fault set at dispatch/ingest time.  No rng
is drawn anywhere in this module.
"""
from __future__ import annotations

import dataclasses

KINDS = ("worker_crash", "straggler", "search_fail", "replica_crash",
         "delta_drop", "delta_dup")

#: kinds whose window fields (duration_s) are meaningful
_WINDOW_KINDS = ("straggler", "search_fail")
_DELTA_KINDS = ("delta_drop", "delta_dup")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One fault pinned to the virtual clock.  Field meaning varies by
    ``kind`` (see the module docstring); irrelevant fields are ignored."""
    t: float                  # virtual time the fault fires
    kind: str                 # one of KINDS
    target: int = 0           # worker id / replica id (ignored for delta_*)
    duration_s: float = 0.0   # straggler / search_fail window length
    factor: float = 4.0       # straggler service-latency multiplier
    down_s: float = 0.0       # worker_crash downtime (0 = permanent)
    count: int = 1            # delta_drop / delta_dup: appends affected


# parse() key aliases -> FaultEvent field
_PARSE_KEYS = {
    "target": ("target", int),
    "duration": ("duration_s", float),
    "duration_s": ("duration_s", float),
    "factor": ("factor", float),
    "down": ("down_s", float),
    "down_s": ("down_s", float),
    "count": ("count", int),
}


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, validated set of :class:`FaultEvent`.

    Events may be given in any order; consumers see them sorted by
    ``(t, original index)``.  An EMPTY plan is the fault-free contract:
    the scheduler must behave bit-identically to one built without a
    plan at all.
    """
    events: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        for i, ev in enumerate(self.events):
            if not isinstance(ev, FaultEvent):
                raise TypeError(
                    f"events[{i}] is {type(ev).__name__}, expected "
                    "FaultEvent")
            if ev.kind not in KINDS:
                raise ValueError(
                    f"events[{i}]: unknown fault kind {ev.kind!r} "
                    f"(choose from {', '.join(KINDS)})")
            if not ev.t >= 0.0:
                raise ValueError(
                    f"events[{i}] ({ev.kind}): t must be >= 0, got {ev.t}")
            if ev.target < 0:
                raise ValueError(
                    f"events[{i}] ({ev.kind}): target must be >= 0, "
                    f"got {ev.target}")
            if ev.kind in _WINDOW_KINDS and not ev.duration_s > 0.0:
                raise ValueError(
                    f"events[{i}] ({ev.kind}): duration_s must be > 0, "
                    f"got {ev.duration_s}")
            if ev.kind == "straggler" and not ev.factor > 1.0:
                raise ValueError(
                    f"events[{i}] (straggler): factor must be > 1, "
                    f"got {ev.factor}")
            if ev.kind == "worker_crash" and ev.down_s < 0.0:
                raise ValueError(
                    f"events[{i}] (worker_crash): down_s must be >= 0, "
                    f"got {ev.down_s}")
            if ev.kind in _DELTA_KINDS and ev.count < 1:
                raise ValueError(
                    f"events[{i}] ({ev.kind}): count must be >= 1, "
                    f"got {ev.count}")

    def __len__(self) -> int:
        return len(self.events)

    def sorted_events(self) -> list:
        """Events in firing order (stable on simultaneous faults)."""
        return sorted(self.events, key=lambda e: e.t)

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse the CLI grammar: ``;``-separated events, each
        ``kind@t[,key=val]*`` — e.g.::

            worker_crash@2.0,target=0,down=3.0;straggler@1.0,duration=5,factor=4

        Keys: ``target``, ``duration``, ``factor``, ``down``, ``count``.
        An empty/whitespace spec is the empty plan.
        """
        events = []
        for i, part in enumerate(p for p in spec.split(";") if p.strip()):
            head, *kvs = [f.strip() for f in part.split(",")]
            if "@" not in head:
                raise ValueError(
                    f"fault event {i} ({head!r}): expected 'kind@t', e.g. "
                    "'worker_crash@2.0'")
            kind, _, t_s = head.partition("@")
            kind = kind.strip()
            try:
                t = float(t_s)
            except ValueError:
                raise ValueError(
                    f"fault event {i} ({head!r}): time {t_s!r} is not a "
                    "number") from None
            fields = {}
            for kv in kvs:
                key, sep, val = kv.partition("=")
                key = key.strip()
                if not sep or key not in _PARSE_KEYS:
                    raise ValueError(
                        f"fault event {i} ({kind}): bad field {kv!r} "
                        f"(keys: {', '.join(sorted(set(_PARSE_KEYS)))})")
                name, conv = _PARSE_KEYS[key]
                try:
                    fields[name] = conv(val)
                except ValueError:
                    raise ValueError(
                        f"fault event {i} ({kind}): {key}={val!r} is not "
                        f"a valid {conv.__name__}") from None
            events.append(FaultEvent(t=t, kind=kind, **fields))
        return cls(events=tuple(events))


class FaultInjector:
    """Per-run mutable view of a :class:`FaultPlan`.

    The scheduler owns WHEN faults fire (it schedules each event on its
    heap); this object owns WHAT is currently broken: active straggler /
    search-failure windows and pending delta-channel faults.  Crash
    events (worker/replica) carry no window state — the scheduler reacts
    to them directly.  Everything here is deterministic bookkeeping.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._stragglers: list = []    # (t0, t1, worker, factor)
        self._search_fail: list = []   # (t0, t1, worker)
        self._drop_pending = 0
        self._dup_pending = 0
        # stats (mirrored into SchedResult by the scheduler)
        self.dropped_appends = 0
        self.duplicated_appends = 0

    def activate(self, ev: FaultEvent) -> None:
        """Arm windowed/counted faults when their heap event fires.
        Crash kinds are intentionally no-ops here."""
        if ev.kind == "straggler":
            self._stragglers.append(
                (ev.t, ev.t + ev.duration_s, ev.target, ev.factor))
        elif ev.kind == "search_fail":
            self._search_fail.append((ev.t, ev.t + ev.duration_s, ev.target))
        elif ev.kind == "delta_drop":
            self._drop_pending += ev.count
        elif ev.kind == "delta_dup":
            self._dup_pending += ev.count

    # -- dispatch-time queries --------------------------------------------

    def latency_multiplier(self, worker: int, t: float) -> float:
        """Service-latency multiplier for a dispatch to ``worker``
        STARTING at ``t`` (overlapping straggler windows compound)."""
        m = 1.0
        for t0, t1, w, factor in self._stragglers:
            if w == worker and t0 <= t < t1:
                m *= factor
        return m

    def search_fails(self, worker: int, t: float) -> bool:
        """True iff a dispatch to ``worker`` starting at ``t`` fails
        transiently (decided at dispatch time; surfaces at completion)."""
        return any(w == worker and t0 <= t < t1
                   for t0, t1, w in self._search_fail)

    # -- ingest-time queries ----------------------------------------------

    def delta_fault(self) -> str | None:
        """Consume one pending delta-channel fault for the next append:
        ``"drop"`` | ``"dup"`` | ``None``.  Drops take priority when both
        are pending (deterministic)."""
        if self._drop_pending > 0:
            self._drop_pending -= 1
            self.dropped_appends += 1
            return "drop"
        if self._dup_pending > 0:
            self._dup_pending -= 1
            self.duplicated_appends += 1
            return "dup"
        return None
