"""Synthetic entity–attribute RAG world (dataset substrate for all paper tables).

The paper evaluates on Wikipedia + (augmented) Granola-EQ / PopQA.  Neither
the 49.2M-passage dump nor an 8B LLM ships in this container, so we build a
*measurable* synthetic world that preserves every property the paper's
mechanisms depend on:

  1. Entity-centric encoder bias (§III-A obs. 1): document embeddings are
     dominated by their entity vector, so retrieval is entity-aligned.
  2. Multi-attribute coverage (obs. 2): each document covers several
     attributes of its entity, so homologous queries share golden docs.
  3. Popularity patterns (Fig. 4): query entities are Zipf-distributed
     ('granola'/'popqa' presets) or scattered ('triviaqa'/'squad' presets).
  4. Golden-document ground truth: G(d, q) = [E(d) = E(q)] ∧ [A(q) ∈ A(d)]
     is known exactly, giving oracle Doc-Hit / CAR metrics.
  5. Response accuracy: a calibrated generator answers correctly with
     p_hit when a golden doc is retrieved and p_miss otherwise (the paper's
     RA is the same monotone function of Doc-Hit, measured through an LLM).

Different 'encoders' (Table VIII) = different (entity-weight, attr-weight,
noise) triples, reproducing the encoder-robustness axis.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import numpy as np


@dataclasses.dataclass(frozen=True)
class WorldConfig:
    n_entities: int = 20000
    docs_per_entity: int = 5
    attrs_per_entity: int = 12     # distinct attributes an entity can have
    attrs_per_doc: int = 4         # multi-attribute coverage per document
    d: int = 64
    # encoder profile (noise scales are vector norms: noise is unit-direction
    # * scale, NOT per-component — see calibration in tests/test_world.py)
    entity_weight: float = 1.0     # entity-centric bias strength
    attr_weight_doc: float = 0.55
    attr_weight_query: float = 0.65
    noise_doc: float = 1.0         # calibrated: 2.39/5 entity-aligned top-5,
    noise_query: float = 1.1       # 73% top-1 aligned (paper: 2.35, 64.3%)
    seed: int = 0

    @property
    def n_docs(self) -> int:
        return self.n_entities * self.docs_per_entity


# encoder presets (Table VIII): robustness across encoder families
ENCODERS = {
    "contriever": dict(entity_weight=1.0, attr_weight_doc=0.55,
                       attr_weight_query=0.65, noise_doc=1.0, noise_query=1.1),
    "bge-large": dict(entity_weight=1.1, attr_weight_doc=0.60,
                      attr_weight_query=0.70, noise_doc=0.95, noise_query=1.05),
    "e5-base": dict(entity_weight=0.95, attr_weight_doc=0.50,
                    attr_weight_query=0.62, noise_doc=1.05, noise_query=1.15),
}


class SyntheticWorld:
    """Corpus + oracle + query sampler."""

    def __init__(self, cfg: WorldConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        d = cfg.d

        def unit(x):
            return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-8)

        self.entity_vecs = unit(rng.normal(size=(cfg.n_entities, d))).astype(np.float32)
        self.attr_basis = unit(rng.normal(size=(cfg.attrs_per_entity, d))).astype(np.float32)

        # documents: doc -> (entity, attr bitmask)
        n_docs = cfg.n_docs
        self.doc_entity = np.repeat(np.arange(cfg.n_entities), cfg.docs_per_entity)
        self.doc_attr_mask = np.zeros((n_docs, cfg.attrs_per_entity), bool)
        attr_mix = np.zeros((n_docs, d), np.float32)
        for i in range(cfg.docs_per_entity):
            sel = rng.random((cfg.n_entities, cfg.attrs_per_entity)).argsort(axis=1)
            sel = sel[:, :cfg.attrs_per_doc]                       # [E, apd]
            rows = np.arange(cfg.n_entities * cfg.docs_per_entity)[
                i::cfg.docs_per_entity]
            for j in range(cfg.attrs_per_doc):
                self.doc_attr_mask[rows, sel[:, j]] = True
            attr_mix[rows] = self.attr_basis[sel].sum(axis=1) \
                / np.sqrt(cfg.attrs_per_doc)

        emb = (cfg.entity_weight * self.entity_vecs[self.doc_entity]
               + cfg.attr_weight_doc * attr_mix
               + cfg.noise_doc * unit(rng.normal(size=(n_docs, d))))
        self.doc_emb = unit(emb).astype(np.float32)

        # entity -> attribute availability (a query can only ask attrs that
        # at least one doc of the entity covers)
        self.entity_attrs = np.zeros((cfg.n_entities, cfg.attrs_per_entity), bool)
        np.logical_or.at(self.entity_attrs, self.doc_entity, self.doc_attr_mask)

        # hashed-term postings for the lexical channel — pure hashing of the
        # arrays above, zero rng draws, so every embedding/query stream stays
        # bit-identical to worlds built before the hybrid backend existed
        from repro.retrieval.lexical import build_doc_terms
        self.doc_terms, self.doc_term_weights = build_doc_terms(
            self.doc_entity, self.doc_attr_mask)

    # -- query construction ------------------------------------------------

    def encode_query(self, entity: int, attr: int,
                     rng: np.random.Generator) -> np.ndarray:
        cfg = self.cfg
        noise = rng.normal(size=cfg.d)
        noise /= max(np.linalg.norm(noise), 1e-8)
        v = (cfg.entity_weight * self.entity_vecs[entity]
             + cfg.attr_weight_query * self.attr_basis[attr]
             + cfg.noise_query * noise)
        return (v / max(np.linalg.norm(v), 1e-8)).astype(np.float32)

    def golden_mask(self, entity: int, attr: int,
                    doc_ids: np.ndarray) -> np.ndarray:
        """G(d, q) for each retrieved doc id (vectorized oracle)."""
        ids = np.asarray(doc_ids)
        ok = ids >= 0
        safe = np.where(ok, ids, 0)
        g = (self.doc_entity[safe] == entity) & self.doc_attr_mask[safe, attr]
        return g & ok

    # -- query streams -----------------------------------------------------

    def sample_queries(self, n: int, pattern: str = "zipf",
                       zipf_a: float = 1.15, seed: int = 1,
                       n_templates: int = 5, p_uncovered: float = 0.0):
        """Returns list of dicts: {entity, attr, emb, tokens}.

        pattern='zipf' reproduces the popularity concentration (Fig. 4);
        'scattered' reproduces de-duplicated QA datasets (Table V).
        ``p_uncovered`` = fraction of queries asking an attribute no corpus
        document covers (the real-world knowledge gap that bounds Doc-Hit).
        """
        cfg = self.cfg
        rng = np.random.default_rng(seed)
        if pattern == "zipf":
            ranks = rng.zipf(zipf_a, size=4 * n)
            ranks = ranks[ranks <= cfg.n_entities][:n] - 1
            while len(ranks) < n:
                extra = rng.zipf(zipf_a, size=n) - 1
                ranks = np.concatenate([ranks, extra[extra < cfg.n_entities]])[:n]
            perm = rng.permutation(cfg.n_entities)
            entities = perm[ranks]
            rank_of = np.empty(cfg.n_entities, np.int64)
            rank_of[perm] = np.arange(cfg.n_entities)
        else:
            entities = rng.integers(0, cfg.n_entities, n)
            rank_of = None

        from repro.retrieval.lexical import query_terms
        out = []
        for e in entities:
            covered = np.flatnonzero(self.entity_attrs[e])
            uncovered = np.flatnonzero(~self.entity_attrs[e])
            # popular entities are better covered in real corpora: scale the
            # knowledge-gap probability down for head entities (drives the
            # paper's high CAR on accepted, i.e. re-encountered, queries)
            p_unc = p_uncovered
            if rank_of is not None:
                r = float(rank_of[e])
                p_unc = p_uncovered * (r / (r + 30.0)) * 1.35
            if len(uncovered) and rng.random() < p_unc:
                a = int(rng.choice(uncovered))
            else:
                a = int(rng.choice(covered)) if len(covered) else 0
            emb = self.encode_query(int(e), a, rng)
            tmpl = int(rng.integers(n_templates))
            # token ids: template tokens + entity token + attr token
            tokens = np.array([1000 + tmpl * 7 + t for t in range(4)]
                              + [10_000 + int(e), 100_000 + a], np.int64)
            terms, term_weights = query_terms(int(e), a)
            out.append({"entity": int(e), "attr": a, "emb": emb,
                        "tokens": tokens, "terms": terms,
                        "term_weights": term_weights})
        return out


DATASETS = {
    # query pattern + LLM answer calibration (p_hit/p_miss reproduce the
    # paper's RA levels given its Doc-Hit levels: e.g. granola Qwen3 RA
    # 0.4875 at hit 0.6457 -> p_hit*0.6457 + p_miss*0.3543 = 0.4875)
    "granola": dict(pattern="zipf", zipf_a=1.12, p_uncovered=0.42,
                    p_hit={"qwen3-8b": 0.745, "llama3-8b": 0.720,
                           "mixtral-7b": 0.735},
                    p_miss={"qwen3-8b": 0.022, "llama3-8b": 0.020,
                            "mixtral-7b": 0.021}),
    "popqa": dict(pattern="zipf", zipf_a=1.30, p_uncovered=0.68,
                  p_hit={"qwen3-8b": 0.615, "llama3-8b": 0.575,
                         "mixtral-7b": 0.560},
                  p_miss={"qwen3-8b": 0.018, "llama3-8b": 0.016,
                          "mixtral-7b": 0.015}),
    # TriviaQA/SQuAD deviate from popularity patterns but are not fully
    # entity-deduplicated: a light Zipf tail remains (Table V's premise)
    "triviaqa": dict(pattern="zipf", zipf_a=1.04, p_uncovered=0.05,
                     p_hit={"qwen3-8b": 0.80}, p_miss={"qwen3-8b": 0.30}),
    "squad": dict(pattern="zipf", zipf_a=1.01, p_uncovered=0.30,
                  p_hit={"qwen3-8b": 0.42}, p_miss={"qwen3-8b": 0.02}),
}


def simulate_response_accuracy(rng: np.random.Generator, doc_hit: bool,
                               dataset: str = "granola",
                               llm: str = "qwen3-8b",
                               n_docs: int = 10) -> bool:
    """p_hit degrades mildly beyond ~10 context docs (the lost-in-the-middle
    effect of long RAG prompts [Jin et al., ICLR'25] — Fig 11's U-shape)."""
    cal = DATASETS[dataset]
    p = cal["p_hit"].get(llm, 0.7) if doc_hit else cal["p_miss"].get(llm, 0.02)
    if doc_hit and n_docs > 10:
        p *= max(0.5, 1.0 - 0.008 * (n_docs - 10))
    return bool(rng.random() < p)
