"""Synthetic CTR / sequential-recommendation data with learnable signal."""
from __future__ import annotations

import numpy as np


class ClickLog:
    """Sparse categorical + dense features; labels from a hidden bilinear
    model so CTR training has learnable structure."""

    def __init__(self, vocab_sizes, n_dense: int = 0, seed: int = 0,
                 zipf_a: float = 1.3):
        self.vocab_sizes = tuple(int(v) for v in vocab_sizes)
        self.n_dense = n_dense
        self.zipf_a = zipf_a
        rng = np.random.default_rng(seed)
        self._field_w = [rng.normal(size=min(v, 4096)) * 0.5
                         for v in self.vocab_sizes]
        self._dense_w = rng.normal(size=n_dense) * 0.3 if n_dense else None
        self.rng = rng

    def _zipf_ids(self, n, vocab):
        z = self.rng.zipf(self.zipf_a, n)
        return np.minimum(z - 1, vocab - 1)

    def sample(self, batch: int):
        ids = np.stack([self._zipf_ids(batch, v) for v in self.vocab_sizes],
                       axis=1).astype(np.int32)
        logit = sum(w[np.minimum(ids[:, i], len(w) - 1)]
                    for i, w in enumerate(self._field_w))
        out = {"sparse_ids": ids}
        if self.n_dense:
            dense = self.rng.normal(size=(batch, self.n_dense)).astype(np.float32)
            logit = logit + dense @ self._dense_w
            out["dense"] = dense
        p = 1.0 / (1.0 + np.exp(-(logit - logit.mean())))
        out["labels"] = (self.rng.random(batch) < p).astype(np.int32)
        return out


class SessionLog:
    """Markov item sessions for BERT4Rec masked-item training."""

    def __init__(self, n_items: int, seed: int = 0, mask_frac: float = 0.15):
        self.n_items = n_items
        self.mask_frac = mask_frac
        rng = np.random.default_rng(seed)
        self._next = rng.permutation(n_items)          # item transition map
        self.rng = rng

    def sample(self, batch: int, seq: int):
        start = self.rng.integers(0, self.n_items, batch)
        items = np.zeros((batch, seq), np.int64)
        items[:, 0] = start
        for t in range(1, seq):
            jump = self.rng.random(batch) < 0.2
            items[:, t] = np.where(jump,
                                   self.rng.integers(0, self.n_items, batch),
                                   self._next[items[:, t - 1]])
        label_mask = self.rng.random((batch, seq)) < self.mask_frac
        inputs = np.where(label_mask, 0, items)        # 0 = [MASK]
        return {"items": inputs.astype(np.int32),
                "labels": items.astype(np.int32),
                "label_mask": label_mask,
                "mask": np.ones((batch, seq), bool)}
