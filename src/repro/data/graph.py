"""Graph data: synthetic graphs, triplet builder, fanout neighbor sampler.

JAX needs static shapes, so every graph batch is a fixed-size padded block:
edges [E_max], triplets [T_max] with masks.  ``build_triplets`` caps the
directional triplets (k->j->i) per edge — the TPU adaptation that bounds
DimeNet's triplet tensor on power-law graphs (see DESIGN.md).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def random_graph(n_nodes: int, n_edges: int, d_feat: int, n_classes: int,
                 seed: int = 0, radius_graph: bool = False):
    """Synthetic node-classification graph with 3-D positions."""
    rng = np.random.default_rng(seed)
    pos = rng.normal(size=(n_nodes, 3)).astype(np.float32)
    if radius_graph and n_nodes <= 5000:
        # connect k-nearest for geometric realism (molecule regime)
        d2 = ((pos[:, None] - pos[None, :]) ** 2).sum(-1)
        np.fill_diagonal(d2, np.inf)
        k = max(1, n_edges // n_nodes)
        nbr = np.argsort(d2, axis=1)[:, :k]
        src = nbr.reshape(-1)
        dst = np.repeat(np.arange(n_nodes), k)
    else:
        src = rng.integers(0, n_nodes, n_edges)
        dst = rng.integers(0, n_nodes, n_edges)
    src, dst = src[:n_edges], dst[:n_edges]
    # class-correlated features so training can learn
    labels = rng.integers(0, n_classes, n_nodes)
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    x = centers[labels] + 0.5 * rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    return {"x": x.astype(np.float32), "pos": pos,
            "edge_src": src.astype(np.int32), "edge_dst": dst.astype(np.int32),
            "labels": labels.astype(np.int32)}


def build_triplets(edge_src: np.ndarray, edge_dst: np.ndarray,
                   cap_per_edge: int, t_max: int, seed: int = 0):
    """Directional triplets: for edge e=(j->i), up to ``cap`` edges (k->j).

    Returns (tri_edge_in [T_max], tri_edge_out [T_max], tri_mask [T_max]).
    """
    rng = np.random.default_rng(seed)
    e = len(edge_src)
    # incoming edge lists per node
    order = np.argsort(edge_dst, kind="stable")
    sorted_dst = edge_dst[order]
    starts = np.searchsorted(sorted_dst, np.arange(edge_dst.max() + 2))
    t_in, t_out = [], []
    for eid in range(e):
        j = edge_src[eid]
        if j + 1 >= len(starts):
            continue
        lo, hi = starts[j], starts[j + 1]
        incoming = order[lo:hi]
        incoming = incoming[edge_src[incoming] != edge_dst[eid]]  # k != i
        if len(incoming) > cap_per_edge:
            incoming = rng.choice(incoming, cap_per_edge, replace=False)
        for kid in incoming:
            t_in.append(kid)
            t_out.append(eid)
            if len(t_in) >= t_max:
                break
        if len(t_in) >= t_max:
            break
    t = len(t_in)
    tri_in = np.zeros(t_max, np.int32)
    tri_out = np.zeros(t_max, np.int32)
    mask = np.zeros(t_max, bool)
    tri_in[:t] = t_in
    tri_out[:t] = t_out
    mask[:t] = True
    return tri_in, tri_out, mask


def make_graph_batch(n_nodes, n_edges, d_feat, n_classes, t_max=None,
                     cap_per_edge=4, seed=0, radius_graph=False):
    g = random_graph(n_nodes, n_edges, d_feat, n_classes, seed, radius_graph)
    t_max = t_max or cap_per_edge * n_edges
    ti, to, tm = build_triplets(g["edge_src"], g["edge_dst"], cap_per_edge,
                                t_max, seed)
    return {**g, "edge_mask": np.ones(n_edges, bool),
            "tri_edge_in": ti, "tri_edge_out": to, "tri_mask": tm,
            "node_mask": np.ones(n_nodes, bool)}


class NeighborSampler:
    """Uniform fanout sampling (GraphSAGE-style) producing fixed-shape blocks.

    The full graph lives on the host in CSR form; each call samples a
    ``batch_nodes``-seed subgraph with the given fanouts and emits padded
    edge/triplet arrays — the ``minibatch_lg`` training regime.
    """

    def __init__(self, edge_src: np.ndarray, edge_dst: np.ndarray,
                 n_nodes: int, seed: int = 0):
        order = np.argsort(edge_dst, kind="stable")
        self.sorted_src = edge_src[order]
        self.starts = np.zeros(n_nodes + 1, np.int64)
        counts = np.bincount(edge_dst, minlength=n_nodes)
        self.starts[1:] = np.cumsum(counts)
        self.n_nodes = n_nodes
        self.rng = np.random.default_rng(seed)

    def sample_neighbors(self, nodes: np.ndarray, fanout: int):
        """Returns (src, dst) edges: up to ``fanout`` in-neighbors per node."""
        srcs, dsts = [], []
        for v in nodes:
            lo, hi = self.starts[v], self.starts[v + 1]
            if hi <= lo:
                continue
            nbrs = self.sorted_src[lo:hi]
            if len(nbrs) > fanout:
                nbrs = self.rng.choice(nbrs, fanout, replace=False)
            srcs.append(nbrs)
            dsts.append(np.full(len(nbrs), v, np.int64))
        if not srcs:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        return np.concatenate(srcs), np.concatenate(dsts)

    def sample_block(self, seeds: np.ndarray, fanouts: tuple[int, ...],
                     e_max: int):
        """Multi-hop block: returns node set + padded local edge arrays."""
        frontier = seeds
        all_src, all_dst = [], []
        for f in fanouts:
            s, d = self.sample_neighbors(np.unique(frontier), f)
            all_src.append(s)
            all_dst.append(d)
            frontier = s
        src = np.concatenate(all_src) if all_src else np.zeros(0, np.int64)
        dst = np.concatenate(all_dst) if all_dst else np.zeros(0, np.int64)
        nodes = np.unique(np.concatenate([seeds, src, dst]))
        remap = {int(g): i for i, g in enumerate(nodes)}
        lsrc = np.array([remap[int(g)] for g in src], np.int32)
        ldst = np.array([remap[int(g)] for g in dst], np.int32)
        n_e = min(len(lsrc), e_max)
        edge_src = np.zeros(e_max, np.int32)
        edge_dst = np.zeros(e_max, np.int32)
        emask = np.zeros(e_max, bool)
        edge_src[:n_e] = lsrc[:n_e]
        edge_dst[:n_e] = ldst[:n_e]
        emask[:n_e] = True
        return nodes, edge_src, edge_dst, emask
