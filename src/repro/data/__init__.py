"""Data pipelines: synthetic RAG world, LM tokens, recsys logs, graphs."""
