"""Synthetic LM token pipeline: a learnable Markov language + batching.

A k-gram Markov source gives non-trivial structure (loss decreases visibly
within a few hundred steps for a ~100M model) without shipping a corpus.
"""
from __future__ import annotations

import numpy as np


class MarkovLM:
    def __init__(self, vocab_size: int, order: int = 2, seed: int = 0,
                 concentration: float = 0.05):
        self.vocab = vocab_size
        self.order = order
        rng = np.random.default_rng(seed)
        # hashed transition table: context hash -> categorical over vocab
        self.n_ctx = 4096
        logits = rng.gumbel(size=(self.n_ctx, vocab_size)) / concentration
        probs = np.exp(logits - logits.max(axis=1, keepdims=True))
        self.probs = probs / probs.sum(axis=1, keepdims=True)
        self._mix = rng.integers(1, 2**31 - 1, order)

    def _ctx_hash(self, ctx: np.ndarray) -> np.ndarray:
        h = np.zeros(ctx.shape[0], np.int64)
        for i in range(self.order):
            h = (h * 1000003 + ctx[:, i] * self._mix[i]) % self.n_ctx
        return h

    def sample(self, rng: np.random.Generator, batch: int, seq: int):
        toks = np.zeros((batch, seq + 1), np.int64)
        toks[:, :self.order] = rng.integers(0, self.vocab,
                                            (batch, self.order))
        cum = np.cumsum(self.probs, axis=1)
        for t in range(self.order, seq + 1):
            h = self._ctx_hash(toks[:, t - self.order:t])
            u = rng.random(batch)[:, None]
            toks[:, t] = (u < cum[h]).argmax(axis=1)
        return {"tokens": toks[:, :seq].astype(np.int32),
                "labels": toks[:, 1:seq + 1].astype(np.int32)}


def batches(vocab_size: int, batch: int, seq: int, n_steps: int,
            seed: int = 0, order: int = 2):
    lm = MarkovLM(vocab_size, order, seed)
    rng = np.random.default_rng(seed + 1)
    for _ in range(n_steps):
        yield lm.sample(rng, batch, seq)
