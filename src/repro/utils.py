"""Shared utilities: logical-axis sharding rules, tree helpers, dtype policy.

The framework uses *logical axis names* on every parameter / activation dim
(MaxText-style).  A ``ShardingRules`` table maps logical names to physical mesh
axes; :func:`logical_to_spec` resolves a tuple of logical names into a
``PartitionSpec``.  This keeps model code mesh-agnostic: the same model lowers
on a single CPU device (all rules -> None), the 16x16 single-pod mesh, and the
2x16x16 multi-pod mesh.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Version-compatible shard_map
# ---------------------------------------------------------------------------

try:                                     # newer jax exports it at top level
    from jax import shard_map as _shard_map
except ImportError:                      # older releases: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

# the replication-check kwarg was renamed check_rep -> check_vma in a
# different release than the top-level export, so probe the signature
# instead of inferring the spelling from the import location
_REP_KWARG = ("check_vma" if "check_vma" in
              inspect.signature(_shard_map).parameters else "check_rep")


def shard_map(f, *, check_vma: bool | None = None, **kw):
    """`jax.shard_map` across jax versions.

    Newer jax exports ``jax.shard_map`` and spells the replication-check
    kwarg ``check_vma``; older versions live in ``jax.experimental`` and
    spell it ``check_rep``.  Callers always use the new spelling.
    """
    if check_vma is not None:
        kw[_REP_KWARG] = check_vma
    return _shard_map(f, **kw)

# ---------------------------------------------------------------------------
# Logical axis rules
# ---------------------------------------------------------------------------

# Default production rules for the (pod, data, model) mesh.  ``fsdp`` is the
# weight-sharding axis (ZeRO-3 style); ``tensor`` is the tensor-parallel axis.
PRODUCTION_RULES: dict[str, tuple[str, ...] | str | None] = {
    "batch": ("pod", "data"),          # data-parallel batch
    "seq": "model",                    # residual-stream sequence parallelism
    "kv_seq": "model",                 # decode-time KV cache sharding
    "kv_seq_long": ("data", "model"),  # 500k-context decode KV sharding
    "d_model": None,                   # activations stay replicated on d_model
    "heads": "model",                  # attention-head tensor parallel
    "kv_heads": None,                  # GQA KV heads are few -> replicate
    "d_ff": "model",                   # FFN tensor parallel
    "vocab": "model",                  # vocab-parallel embedding / logits
    "experts": "model",                # MoE expert parallel
    "fsdp": "data",                    # ZeRO-3 weight shard axis
    "corpus": ("data", "model"),       # retrieval corpus shards
    "emb_vocab": "model",              # recsys embedding-table vocab shards
    "nodes": ("data", "model"),        # GNN node partition
    "edges": ("data", "model"),        # GNN edge partition
}

# Single-device rules (tests / smoke): everything replicated.
LOCAL_RULES: dict[str, tuple[str, ...] | str | None] = {k: None for k in PRODUCTION_RULES}


def logical_to_spec(logical: Sequence[str | None],
                    rules: Mapping[str, Any]) -> P:
    """Resolve a tuple of logical axis names into a PartitionSpec."""
    out = []
    for name in logical:
        if name is None:
            out.append(None)
        else:
            out.append(rules.get(name))
    return P(*out)


def tree_specs(logical_tree: Any, rules: Mapping[str, Any]) -> Any:
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda lg: logical_to_spec(lg, rules),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, str) or e is None for e in x),
    )


def tree_shardings(logical_tree: Any, rules: Mapping[str, Any], mesh: Mesh) -> Any:
    specs = tree_specs(logical_tree, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x: jax.Array, logical: Sequence[str | None],
              rules: Mapping[str, Any] | None) -> jax.Array:
    """with_sharding_constraint by logical names; no-op when rules is None."""
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, logical_to_spec(logical, rules))


# ---------------------------------------------------------------------------
# Tree / param helpers
# ---------------------------------------------------------------------------

def tree_size(tree: Any) -> int:
    """Total number of parameters in a pytree (works on ShapeDtypeStructs)."""
    return sum(int(jnp.prod(jnp.asarray(x.shape))) if x.shape else 1
               for x in jax.tree.leaves(tree))


def tree_bytes(tree: Any) -> int:
    return sum(
        int(jnp.prod(jnp.asarray(x.shape))) * jnp.dtype(x.dtype).itemsize
        if x.shape else jnp.dtype(x.dtype).itemsize
        for x in jax.tree.leaves(tree))


def cast_tree(tree: Any, dtype) -> Any:
    return jax.tree.map(lambda x: x.astype(dtype), tree)


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    """Mixed-precision policy: params / compute / output dtypes."""
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    output_dtype: Any = jnp.float32

    def cast_compute(self, x):
        return jax.tree.map(lambda a: a.astype(self.compute_dtype), x)


FP32 = DTypePolicy(jnp.float32, jnp.float32, jnp.float32)
BF16 = DTypePolicy(jnp.bfloat16, jnp.bfloat16, jnp.float32)
MIXED = DTypePolicy(jnp.float32, jnp.bfloat16, jnp.float32)


def pretty_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024:
            return f"{n:.2f} {unit}"
        n /= 1024
    return f"{n:.2f} PiB"


def fold_rng(key: jax.Array, *names: str) -> jax.Array:
    """Deterministically derive a sub-key from string names."""
    for name in names:
        key = jax.random.fold_in(key, abs(hash(name)) % (2**31))
    return key
