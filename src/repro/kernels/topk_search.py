"""Pallas TPU kernel: streaming similarity + running top-k (the ENNS scan).

The retrieval hot-spot of the paper: scores = q @ corpus^T with top-k
selection, streamed over corpus tiles so the score matrix never leaves VMEM.

TPU mapping:
  * grid = corpus tiles; each step loads a [TILE_C, d] corpus block into
    VMEM and issues one [B, d] x [d, TILE_C] MXU matmul.
  * the running top-k (vals/idx [B, K]) lives in the revisited output block
    (same index_map every step => stays resident in VMEM).
  * merge = K rounds of (tile argmax -> replace running argmin) — O(K·TILE)
    vector-unit compares, amortized against the O(d·TILE) MXU work; there is
    no general sort primitive in Mosaic, and for K<=128 this beats one.
  * the caller finishes with a single jnp.sort over [B, K] (K elements).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_kernel(q_ref, c_ref, valid_ref, *rest, k: int, tile_c: int,
                 n_corpus: int, grouped: bool):
    if grouped:
        row_group_ref, q_group_ref, vals_ref, idx_ref = rest
    else:
        (vals_ref, idx_ref), row_group_ref, q_group_ref = rest, None, None
    step = pl.program_id(0)
    b = q_ref.shape[0]

    @pl.when(step == 0)
    def _init():
        vals_ref[...] = jnp.full((b, k), -jnp.inf, jnp.float32)
        idx_ref[...] = jnp.full((b, k), -1, jnp.int32)

    q = q_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    valid = valid_ref[...]                                # [TILE_C]
    scores = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # [B, TILE_C]
    base = step * tile_c
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    # mask the tail tile's out-of-range columns and invalid corpus rows
    # (empty doc-store ring slots when scanning a HaS cache channel)
    ok = (base + col < n_corpus) & valid[None, :]
    if grouped:
        # partitioned scan: row i may only win for queries of its group
        # (tenant) — one extra [B, TILE_C] int compare per tile
        ok &= row_group_ref[...][None, :] == q_group_ref[...][:, None]
    scores = jnp.where(ok, scores, -jnp.inf)
    kcol = jax.lax.broadcasted_iota(jnp.int32, (b, k), 1)

    def merge(i, carry):
        scores, vals, idx = carry
        cur = jnp.max(scores, axis=1)                     # [B]
        arg = jnp.argmax(scores, axis=1).astype(jnp.int32)
        rmin = jnp.min(vals, axis=1)
        rarg = jnp.argmin(vals, axis=1).astype(jnp.int32)
        better = cur > rmin                               # [B]
        hit = (kcol == rarg[:, None]) & better[:, None]
        vals = jnp.where(hit, cur[:, None], vals)
        idx = jnp.where(hit, (base + arg)[:, None], idx)
        scores = jnp.where(col == arg[:, None], -jnp.inf, scores)
        return scores, vals, idx

    _, vals, idx = jax.lax.fori_loop(
        0, k, merge, (scores, vals_ref[...], idx_ref[...]))
    vals_ref[...] = vals
    idx_ref[...] = idx


@functools.partial(jax.jit, static_argnames=("k", "tile_c", "interpret"))
def topk_search(queries: jax.Array, corpus: jax.Array, k: int,
                tile_c: int = 1024, valid: jax.Array | None = None,
                row_group: jax.Array | None = None,
                q_group: jax.Array | None = None,
                interpret: bool = False):
    """queries [B,d], corpus [N,d] -> (vals [B,k] desc-sorted, idx [B,k]).

    ``valid`` ([N] bool, optional) masks corpus rows out of the result —
    used by the HaS cache channel, whose doc-store ring contains empty
    slots (doc_ids < 0) that must never win a top-k position.

    ``row_group`` ([N] int32) / ``q_group`` ([B] int32, both or neither)
    partition the scan: corpus row i may only win a top-k position for
    query b when ``row_group[i] == q_group[b]`` — the multi-tenant cache
    channel, where every tenant's doc-store slice scans in the SAME kernel
    launch but rows never cross tenants.  The group ids stream with the
    corpus tiles, so the partitioned scan stays one program launch with one
    extra [B, TILE_C] compare per tile.
    """
    n, d = corpus.shape
    b = queries.shape[0]
    if (row_group is None) != (q_group is None):
        raise ValueError("row_group and q_group must be passed together")
    grouped = row_group is not None
    if valid is None:
        valid = jnp.ones((n,), bool)
    n_tiles = pl.cdiv(n, tile_c)
    pad = n_tiles * tile_c - n
    if pad:
        corpus = jnp.concatenate(
            [corpus, jnp.zeros((pad, d), corpus.dtype)], axis=0)
        valid = jnp.concatenate([valid, jnp.zeros((pad,), bool)])
        if grouped:
            row_group = jnp.concatenate(
                [row_group, jnp.full((pad,), -1, jnp.int32)])

    in_specs = [
        pl.BlockSpec((b, d), lambda i: (0, 0)),        # queries resident
        pl.BlockSpec((tile_c, d), lambda i: (i, 0)),   # corpus stream
        pl.BlockSpec((tile_c,), lambda i: (i,)),       # validity stream
    ]
    operands = [queries, corpus, valid]
    if grouped:
        in_specs += [
            pl.BlockSpec((tile_c,), lambda i: (i,)),   # row groups stream
            pl.BlockSpec((b,), lambda i: (0,)),        # query groups resident
        ]
        operands += [row_group.astype(jnp.int32), q_group.astype(jnp.int32)]

    vals, idx = pl.pallas_call(
        functools.partial(_topk_kernel, k=k, tile_c=tile_c, n_corpus=n,
                          grouped=grouped),
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((b, k), lambda i: (0, 0)),        # running top-k
            pl.BlockSpec((b, k), lambda i: (0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b, k), jnp.float32),
                   jax.ShapeDtypeStruct((b, k), jnp.int32)],
        interpret=interpret,
    )(*operands)
    # final K-element sort outside the kernel
    order = jnp.argsort(-vals, axis=1)
    return jnp.take_along_axis(vals, order, axis=1), \
        jnp.take_along_axis(idx, order, axis=1)
