"""Pallas TPU kernel: flash-decoding GQA attention (one token vs long KV).

The serving hot-spot of decode_32k / long_500k: one query token attends over
an S-long KV cache.  The kernel streams KV blocks through VMEM with an
online-softmax accumulator (running max / sum / weighted value), so the
[H, S] score row never materializes in HBM — the kernel is purely
memory-bound on the KV read, which is the roofline floor for decode.

Grid = (batch, kv blocks); the accumulator lives in the revisited output
blocks (m, l, acc) and is finalized on the last block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref,
                   *, block_s: int, n_blocks: int):
    bi = pl.program_id(1)

    @pl.when(bi == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, -jnp.inf, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        o_ref[...] = jnp.zeros(o_ref.shape, jnp.float32)

    q = q_ref[...][0].astype(jnp.float32)              # [H, D]
    k = k_ref[...][0].astype(jnp.float32)              # [S_blk, H, D]
    v = v_ref[...][0].astype(jnp.float32)
    d = q.shape[-1]
    scores = jnp.einsum("hd,shd->hs", q, k) * (d ** -0.5)   # [H, S_blk]
    # mask positions beyond the current cache length
    pos = bi * block_s + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    valid = pos <= len_ref[0, 0]
    scores = jnp.where(valid, scores, -jnp.inf)

    m_prev = m_ref[...][0]                             # [H]
    l_prev = l_ref[...][0]
    acc_prev = o_ref[...][0]                           # [H, D]
    m_cur = jnp.maximum(m_prev, jnp.max(scores, axis=1))
    # guard fully-masked blocks (exp(-inf - -inf))
    safe_m = jnp.where(jnp.isfinite(m_cur), m_cur, 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)
    p = jnp.exp(jnp.where(valid, scores - safe_m[:, None], -jnp.inf))
    p = jnp.where(valid, p, 0.0)
    l_cur = l_prev * alpha + jnp.sum(p, axis=1)
    acc = acc_prev * alpha[:, None] + jnp.einsum("hs,shd->hd", p, v)

    m_ref[...] = m_cur[None]
    l_ref[...] = l_cur[None]
    o_ref[...] = acc[None]

    @pl.when(bi == n_blocks - 1)
    def _finalize():
        o_ref[...] = (acc / jnp.maximum(l_cur, 1e-30)[:, None])[None]


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, block_s: int = 512,
                     interpret: bool = False):
    """q [B, H, D]; k/v_cache [B, S, H, D] (KV already head-repeated);
    cache_len scalar int32 (attend to positions <= cache_len).
    Returns out [B, H, D] (f32)."""
    b, h, d = q.shape
    s = k_cache.shape[1]
    n_blocks = pl.cdiv(s, block_s)
    pad = n_blocks * block_s - s
    if pad:
        zk = jnp.zeros((b, pad, h, d), k_cache.dtype)
        k_cache = jnp.concatenate([k_cache, zk], axis=1)
        v_cache = jnp.concatenate([v_cache, zk], axis=1)
    lens = jnp.broadcast_to(cache_len.astype(jnp.int32), (b, 1))

    out, m, l = pl.pallas_call(
        functools.partial(_decode_kernel, block_s=block_s,
                          n_blocks=n_blocks),
        grid=(b, n_blocks),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda bi, si: (bi, 0, 0)),
            pl.BlockSpec((1, block_s, h, d), lambda bi, si: (bi, si, 0, 0)),
            pl.BlockSpec((1, block_s, h, d), lambda bi, si: (bi, si, 0, 0)),
            pl.BlockSpec((1, 1), lambda bi, si: (bi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, d), lambda bi, si: (bi, 0, 0)),
            pl.BlockSpec((1, h), lambda bi, si: (bi, 0)),
            pl.BlockSpec((1, h), lambda bi, si: (bi, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b, h, d), jnp.float32),
                   jax.ShapeDtypeStruct((b, h), jnp.float32),
                   jax.ShapeDtypeStruct((b, h), jnp.float32)],
        interpret=interpret,
    )(q, k_cache, v_cache, lens)
    return out


def decode_attention_ref(q, k_cache, v_cache, cache_len):
    """Oracle: plain masked softmax attention over the cache."""
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k_cache.astype(jnp.float32))
    scores = scores * (q.shape[-1] ** -0.5)
    pos = jnp.arange(k_cache.shape[1])
    scores = jnp.where(pos[None, None, :] <= cache_len, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", probs, v_cache.astype(jnp.float32))
