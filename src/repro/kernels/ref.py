"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_search_ref(queries: jax.Array, corpus: jax.Array, k: int):
    """Exact top-k by inner product. queries [B,d], corpus [N,d] ->
    (vals [B,k], idx [B,k])."""
    scores = (queries.astype(jnp.float32) @ corpus.astype(jnp.float32).T)
    return jax.lax.top_k(scores, k)


def homology_score_ref(draft_ids: jax.Array, cache_doc_ids: jax.Array,
                       cache_valid: jax.Array):
    """Overlap-ratio homology scores. draft [B,k], cache [H,k] -> [B,H]."""
    k = draft_ids.shape[1]
    eq = (draft_ids[:, None, :, None] == cache_doc_ids[None, :, None, :])
    eq &= (draft_ids[:, None, :, None] >= 0)
    overlap = jnp.sum(jnp.any(eq, axis=3), axis=2)       # [B, H]
    s = overlap.astype(jnp.float32) / k
    return jnp.where(cache_valid[None, :], s, 0.0)


def ivf_scan_ref(queries: jax.Array, probe: jax.Array, bucket_vecs: jax.Array,
                 bucket_ids: jax.Array, k: int,
                 bucket_scales: jax.Array | None = None,
                 probe_bias: jax.Array | None = None):
    """Gather probed buckets + exact local top-k.

    queries [B,d], probe [B,P] bucket indices, bucket_vecs [C,cap,d],
    bucket_ids [C,cap] -> (vals [B,k], global ids [B,k]).
    ``bucket_scales [C,cap,2]`` + ``probe_bias [B,P]`` (together) score the
    compressed corpus residency mode's int8 centroid-residual codes:
    ``bias + (q_lo.v8_lo)s_lo + (q_hi.v8_hi)s_hi`` per slot.
    """
    q = queries.astype(jnp.float32)
    vecs = bucket_vecs[probe]                             # [B,P,cap,d]
    ids = bucket_ids[probe]                               # [B,P,cap]
    if bucket_scales is not None:
        h = q.shape[1] // 2
        codes = vecs.astype(jnp.float32)
        sc = bucket_scales[probe]                         # [B,P,cap,2]
        s = (jnp.einsum("bd,bpcd->bpc", q[:, :h], codes[..., :h]) * sc[..., 0]
             + jnp.einsum("bd,bpcd->bpc", q[:, h:], codes[..., h:])
             * sc[..., 1]
             + probe_bias.astype(jnp.float32)[:, :, None])
    else:
        s = jnp.einsum("bd,bpcd->bpc", q, vecs.astype(jnp.float32))
    s = jnp.where(ids >= 0, s, -jnp.inf)
    b = queries.shape[0]
    s, ids = s.reshape(b, -1), ids.reshape(b, -1)
    if s.shape[1] < k:                # probed pool < k: pad like the kernel
        pad = k - s.shape[1]
        s = jnp.pad(s, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
    vals, pos = jax.lax.top_k(s, k)
    return vals, jnp.take_along_axis(ids, pos, axis=1)


def embedding_bag_ref(table: jax.Array, ids: jax.Array,
                      weights: jax.Array | None = None, mode: str = "sum"):
    """Fixed-arity EmbeddingBag. table [V,d], ids [B,n] -> [B,d]."""
    vecs = table[ids]                                     # [B,n,d]
    if weights is not None:
        vecs = vecs * weights[..., None]
    out = jnp.sum(vecs, axis=1)
    if mode == "mean":
        out = out / ids.shape[1]
    return out
