"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_search_ref(queries: jax.Array, corpus: jax.Array, k: int):
    """Exact top-k by inner product. queries [B,d], corpus [N,d] ->
    (vals [B,k], idx [B,k])."""
    scores = (queries.astype(jnp.float32) @ corpus.astype(jnp.float32).T)
    return jax.lax.top_k(scores, k)


def homology_score_ref(draft_ids: jax.Array, cache_doc_ids: jax.Array,
                       cache_valid: jax.Array):
    """Overlap-ratio homology scores. draft [B,k], cache [H,k] -> [B,H]."""
    k = draft_ids.shape[1]
    eq = (draft_ids[:, None, :, None] == cache_doc_ids[None, :, None, :])
    eq &= (draft_ids[:, None, :, None] >= 0)
    overlap = jnp.sum(jnp.any(eq, axis=3), axis=2)       # [B, H]
    s = overlap.astype(jnp.float32) / k
    return jnp.where(cache_valid[None, :], s, 0.0)


def ivf_scan_ref(queries: jax.Array, probe: jax.Array, bucket_vecs: jax.Array,
                 bucket_ids: jax.Array, k: int,
                 bucket_scales: jax.Array | None = None,
                 probe_bias: jax.Array | None = None):
    """Gather probed buckets + exact local top-k.

    queries [B,d], probe [B,P] bucket indices, bucket_vecs [C,cap,d],
    bucket_ids [C,cap] -> (vals [B,k], global ids [B,k]).
    ``bucket_scales [C,cap,2]`` + ``probe_bias [B,P]`` (together) score the
    compressed corpus residency mode's int8 centroid-residual codes:
    ``bias + (q_lo.v8_lo)s_lo + (q_hi.v8_hi)s_hi`` per slot.
    """
    q = queries.astype(jnp.float32)
    vecs = bucket_vecs[probe]                             # [B,P,cap,d]
    ids = bucket_ids[probe]                               # [B,P,cap]
    if bucket_scales is not None:
        h = q.shape[1] // 2
        codes = vecs.astype(jnp.float32)
        sc = bucket_scales[probe]                         # [B,P,cap,2]
        s = (jnp.einsum("bd,bpcd->bpc", q[:, :h], codes[..., :h]) * sc[..., 0]
             + jnp.einsum("bd,bpcd->bpc", q[:, h:], codes[..., h:])
             * sc[..., 1]
             + probe_bias.astype(jnp.float32)[:, :, None])
    else:
        s = jnp.einsum("bd,bpcd->bpc", q, vecs.astype(jnp.float32))
    s = jnp.where(ids >= 0, s, -jnp.inf)
    b = queries.shape[0]
    s, ids = s.reshape(b, -1), ids.reshape(b, -1)
    if s.shape[1] < k:                # probed pool < k: pad like the kernel
        pad = k - s.shape[1]
        s = jnp.pad(s, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
    vals, pos = jax.lax.top_k(s, k)
    return vals, jnp.take_along_axis(ids, pos, axis=1)


def lexical_score_ref(q_terms: jax.Array, q_weights: jax.Array,
                      doc_terms: jax.Array, doc_weights: jax.Array, k: int,
                      tile_n: int = 512):
    """Tiled hashed-term lexical top-k, scanning the SAME tiles through the
    SAME merge as the Pallas kernel (shared helpers), so the two backends
    agree bit-for-bit including tie order.  q_terms/q_weights [B,T],
    doc_terms/doc_weights [N,L] -> (vals [B,k], row idx [B,k])."""
    from repro.kernels.lexical_score import (
        _final_sort, _merge_topk, _pad_postings, _tile_scores)
    b = q_terms.shape[0]
    q_terms = q_terms.astype(jnp.int32)
    q_weights = q_weights.astype(jnp.float32)
    doc_terms, doc_weights, n_tiles = _pad_postings(
        doc_terms.astype(jnp.int32), doc_weights.astype(jnp.float32), tile_n)
    l_w = doc_terms.shape[1]
    dt = doc_terms.reshape(n_tiles, tile_n, l_w)
    dw = doc_weights.reshape(n_tiles, tile_n, l_w)
    bases = jnp.arange(n_tiles, dtype=jnp.int32) * tile_n

    def body(carry, tile):
        vals, idx = carry
        dt_t, dw_t, base = tile
        s = _tile_scores(q_terms, q_weights, dt_t, dw_t)
        vals, idx = _merge_topk(s, vals, idx, base, k)
        return (vals, idx), None

    init = (jnp.full((b, k), -jnp.inf, jnp.float32),
            jnp.full((b, k), -1, jnp.int32))
    (vals, idx), _ = jax.lax.scan(body, init, (dt, dw, bases))
    return _final_sort(vals, idx)


def fused_rerank_ref(queries: jax.Array, pool_ids: jax.Array,
                     pool_vecs: jax.Array, kd: int, k: int,
                     rrf_k: float = 60.0,
                     diversify_sim: float | None = None):
    """RRF fusion + diversification + rerank, running the kernel's own
    per-query ``_fuse_scores`` sequentially via ``lax.map`` — bit-identical
    to the Pallas grid by construction."""
    import functools

    from repro.kernels.fused_rerank import _final_topk, _fuse_scores
    kl = pool_ids.shape[1] - kd
    fuse = functools.partial(_fuse_scores, kd=kd, kl=kl, rrf_k=rrf_k,
                             diversify_sim=diversify_sim)
    mass, rscore = jax.lax.map(
        lambda x: fuse(x[0], x[1], x[2]),
        (queries.astype(jnp.float32), pool_ids.astype(jnp.int32),
         pool_vecs.astype(jnp.float32)))
    return _final_topk(mass, rscore, pool_ids, k)


def embedding_bag_ref(table: jax.Array, ids: jax.Array,
                      weights: jax.Array | None = None, mode: str = "sum"):
    """Fixed-arity EmbeddingBag. table [V,d], ids [B,n] -> [B,d]."""
    vecs = table[ids]                                     # [B,n,d]
    if weights is not None:
        vecs = vecs * weights[..., None]
    out = jnp.sum(vecs, axis=1)
    if mode == "mean":
        out = out / ids.shape[1]
    return out
