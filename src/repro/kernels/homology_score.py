"""Pallas TPU kernel: homology-score overlap counting (paper §III-C).

The TPU-native inverted index: draft doc-ids [B, k] are compared against the
cached doc-id table [H, k] with a tiled compare-reduce — O(H·k²) int
compares on the vector units, streamed over H tiles.  Replaces the paper's
CPU hash-map index J (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _homology_kernel(draft_ref, cache_ref, valid_ref, *rest, k: int,
                     grouped: bool, weighted: bool):
    rest = list(rest)
    w_ref = rest.pop(0) if weighted else None
    if grouped:
        row_group_ref, q_group_ref, out_ref = rest
    else:
        (out_ref,), row_group_ref, q_group_ref = rest, None, None
    draft = draft_ref[...]                                 # [B, k]
    cache = cache_ref[...]                                 # [TILE_H, k]
    valid = valid_ref[...]                                 # [TILE_H]
    # [B, TILE_H, k_draft, k_cache] compare; any over cache slots; sum draft
    eq = (draft[:, None, :, None] == cache[None, :, None, :])
    eq &= (draft[:, None, :, None] >= 0)
    hit = jnp.any(eq, axis=3).astype(jnp.float32)          # [B, TILE_H, k]
    if weighted:
        # fused-list validation: each draft slot carries its (normalized)
        # RRF mass instead of 1/k — rank-domain, score-scale free
        s = jnp.sum(hit * w_ref[...][:, None, :], axis=2)
    else:
        overlap = jnp.sum(hit, axis=2)
        s = overlap / k
    ok = valid[None, :]
    if grouped:
        # partitioned table: cached query row i only scores against drafts
        # of its own group (tenant) — cross-tenant rows read as 0 overlap
        ok &= row_group_ref[...][None, :] == q_group_ref[...][:, None]
    out_ref[...] = jnp.where(ok, s, 0.0)


@functools.partial(jax.jit, static_argnames=("tile_h", "interpret"))
def homology_score(draft_ids: jax.Array, cache_doc_ids: jax.Array,
                   cache_valid: jax.Array, tile_h: int = 512,
                   row_group: jax.Array | None = None,
                   q_group: jax.Array | None = None,
                   draft_weights: jax.Array | None = None,
                   interpret: bool = False):
    """draft [B,k] int32, cache [H,k] int32, valid [H] -> scores [B,H] f32.

    ``row_group`` ([H] int32) / ``q_group`` ([B] int32, both or neither)
    partition the cached-query table: row i contributes a non-zero score
    for draft b only when ``row_group[i] == q_group[b]`` (multi-tenant
    validation — every tenant's query-cache slice scores in the same
    kernel launch without cross-tenant re-identification).

    ``draft_weights`` ([B, k] f32, optional) switches the score from the
    uniform overlap ratio (1/k per matched slot) to per-slot weighted mass
    (the fused-list RRF validation of ``HasConfig.fusion == "rrf"``;
    weights pre-normalized by :func:`~repro.core.homology.rrf_draft_weights`).
    Absent, the program is byte-identical to the unweighted kernel.
    """
    b, k = draft_ids.shape
    h = cache_doc_ids.shape[0]
    if (row_group is None) != (q_group is None):
        raise ValueError("row_group and q_group must be passed together")
    grouped = row_group is not None
    weighted = draft_weights is not None
    n_tiles = pl.cdiv(h, tile_h)
    pad = n_tiles * tile_h - h
    if pad:
        cache_doc_ids = jnp.concatenate(
            [cache_doc_ids, jnp.full((pad, k), -2, jnp.int32)], axis=0)
        cache_valid = jnp.concatenate(
            [cache_valid, jnp.zeros((pad,), bool)], axis=0)
        if grouped:
            row_group = jnp.concatenate(
                [row_group, jnp.full((pad,), -1, jnp.int32)])

    in_specs = [
        pl.BlockSpec((b, k), lambda i: (0, 0)),
        pl.BlockSpec((tile_h, k), lambda i: (i, 0)),
        pl.BlockSpec((tile_h,), lambda i: (i,)),
    ]
    operands = [draft_ids, cache_doc_ids, cache_valid]
    if weighted:
        in_specs += [pl.BlockSpec((b, k), lambda i: (0, 0))]  # weights resident
        operands += [draft_weights.astype(jnp.float32)]
    if grouped:
        in_specs += [
            pl.BlockSpec((tile_h,), lambda i: (i,)),       # row groups
            pl.BlockSpec((b,), lambda i: (0,)),            # query groups
        ]
        operands += [row_group.astype(jnp.int32), q_group.astype(jnp.int32)]

    out = pl.pallas_call(
        functools.partial(_homology_kernel, k=k, grouped=grouped,
                          weighted=weighted),
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((b, tile_h), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, n_tiles * tile_h), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:, :h]
