"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python per grid step, numerically identical to the TPU path.
On TPU backends they compile through Mosaic.  ``auto_interpret()`` picks per
platform; every wrapper also takes an explicit override.
"""
from __future__ import annotations

import jax

from repro.kernels.decode_attention import decode_attention as _decode_attention
from repro.kernels.embedding_bag import embedding_bag as _embedding_bag
from repro.kernels.fused_rerank import fused_rerank as _fused_rerank
from repro.kernels.homology_score import homology_score as _homology_score
from repro.kernels.ivf_scan import ivf_scan as _ivf_scan
from repro.kernels.lexical_score import lexical_score as _lexical_score
from repro.kernels.topk_search import topk_search as _topk_search


def auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def topk_search(queries, corpus, k, tile_c: int = 1024, valid=None,
                interpret=None):
    if interpret is None:
        interpret = auto_interpret()
    return _topk_search(queries, corpus, k, tile_c=tile_c, valid=valid,
                        interpret=interpret)


def homology_score(draft_ids, cache_doc_ids, cache_valid, tile_h: int = 512,
                   interpret=None):
    if interpret is None:
        interpret = auto_interpret()
    return _homology_score(draft_ids, cache_doc_ids, cache_valid,
                           tile_h=tile_h, interpret=interpret)


def ivf_scan(queries, probe, bucket_vecs, bucket_ids, k, interpret=None,
             bucket_scales=None, probe_bias=None):
    if interpret is None:
        interpret = auto_interpret()
    return _ivf_scan(queries, probe, bucket_vecs, bucket_ids, k,
                     interpret=interpret, bucket_scales=bucket_scales,
                     probe_bias=probe_bias)


def lexical_score(q_terms, q_weights, doc_terms, doc_weights, k,
                  tile_n: int = 512, interpret=None):
    if interpret is None:
        interpret = auto_interpret()
    return _lexical_score(q_terms, q_weights, doc_terms, doc_weights, k,
                          tile_n=tile_n, interpret=interpret)


def fused_rerank(queries, pool_ids, pool_vecs, kd, k, rrf_k: float = 60.0,
                 diversify_sim=None, interpret=None):
    if interpret is None:
        interpret = auto_interpret()
    return _fused_rerank(queries, pool_ids, pool_vecs, kd, k, rrf_k=rrf_k,
                         diversify_sim=diversify_sim, interpret=interpret)


def embedding_bag(table, ids, weights=None, mode="sum", interpret=None):
    if interpret is None:
        interpret = auto_interpret()
    return _embedding_bag(table, ids, weights=weights, mode=mode,
                          interpret=interpret)


def decode_attention(q, k_cache, v_cache, cache_len, block_s: int = 512,
                     interpret=None):
    if interpret is None:
        interpret = auto_interpret()
    return _decode_attention(q, k_cache, v_cache, cache_len,
                             block_s=block_s, interpret=interpret)
