"""Pallas TPU kernel: fixed-arity EmbeddingBag (the recsys lookup hot path).

JAX has no nn.EmbeddingBag; the jnp substrate is take+segment_sum.  This
kernel is the fused TPU form: row ids are scalar-prefetched so the BlockSpec
index_map DMAs exactly the needed table rows from HBM — one [1, d] row per
(bag, slot) grid step, accumulated in the bag's revisited output block.
No [B, n, d] gather intermediate ever materializes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(ids_ref, table_ref, w_ref, out_ref, *, mean: bool, n: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    b = pl.program_id(0)
    row = table_ref[...].astype(jnp.float32)               # [1, d]
    w = w_ref[0, 0] if w_ref is not None else 1.0
    scale = (1.0 / n) if mean else 1.0
    out_ref[...] += (row * w * scale).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def embedding_bag(table: jax.Array, ids: jax.Array,
                  weights: jax.Array | None = None, mode: str = "sum",
                  interpret: bool = False):
    """table [V,d], ids [B,n] int32, weights [B,n]|None -> [B,d]."""
    v, d = table.shape
    b, n = ids.shape
    if weights is None:
        weights = jnp.ones((b, n), table.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, n),
        in_specs=[
            pl.BlockSpec((1, d), lambda bi, ji, ids: (ids[bi, ji], 0)),
            pl.BlockSpec((1, 1), lambda bi, ji, ids: (bi, ji)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda bi, ji, ids: (bi, 0)),
    )
    return pl.pallas_call(
        functools.partial(_bag_kernel, mean=(mode == "mean"), n=n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
    )(ids, table, weights)
