"""Pallas TPU kernel: streaming hashed-term lexical scoring + running top-k.

The sparse (lexical) retrieval channel of the hybrid cloud stage: every doc
carries a short postings row of hashed term ids and weights, and a batch of
queries (each with its own hashed terms) is scored as

    s[b, doc] = sum_t qw[b, t] * sum_l dw[doc, l] * [dt[doc, l] == qt[b, t]]

with ``-1`` term ids inert on both sides.  A doc with no positive matched
mass is *invalid* for that query (scored ``-inf``, id ``-1``) — lexical
retrieval has no notion of "closest" doc when nothing matches, unlike the
dense channel.

TPU mapping (same shape as ``topk_search``):
  * grid = postings tiles; each step streams a [TILE_N, L] block of doc
    terms + weights into VMEM while the query terms stay resident.
  * the match is L·T vector-unit integer compares per tile (T = query terms,
    L = doc postings width — both single digits), no MXU work at all: the
    channel is bandwidth-bound on the postings stream, which is the point
    (``LatencyModel.hybrid_scale`` charges exactly those bytes).
  * the running top-k lives in the revisited output block and merges with
    the same K-round argmax/argmin exchange as ``topk_search``.

``_tile_scores``/``_merge_topk`` are shared with the XLA oracle
(``kernels/ref.py::lexical_score_ref`` scans the identical tiles through the
identical merge), so the two backends agree bit-for-bit including tie order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile_scores(q_terms, q_weights, doc_terms, doc_weights):
    """Hashed-term match mass for one postings tile.

    q_terms/q_weights [B, T], doc_terms/doc_weights [C, L] -> [B, C] f32,
    with non-positive mass (no term matched) masked to ``-inf``.  Shared by
    the kernel body and the XLA oracle so the math is identical by
    construction.
    """
    t_q = q_terms.shape[1]
    dt = doc_terms[None, :, :]                             # [1, C, L]
    dw = doc_weights[None, :, :].astype(jnp.float32)
    s = jnp.zeros((q_terms.shape[0], doc_terms.shape[0]), jnp.float32)
    for t in range(t_q):                                   # static: T is tiny
        qt = q_terms[:, t][:, None, None]                  # [B, 1, 1]
        hit = (dt == qt) & (dt >= 0) & (qt >= 0)
        s = s + q_weights[:, t][:, None] * jnp.sum(
            jnp.where(hit, dw, 0.0), axis=2)
    return jnp.where(s > 0.0, s, -jnp.inf)


def _merge_topk(scores, vals, idx, base, k: int):
    """K-round merge of a [B, C] score tile into the running [B, k] top-k.

    Identical exchange to ``topk_search``: tile argmax replaces the running
    argmin when strictly better, so earlier tiles win ties and within a tile
    the lowest column wins — deterministic, and shared with the oracle.
    """
    b = scores.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    kcol = jax.lax.broadcasted_iota(jnp.int32, (b, k), 1)

    def merge(i, carry):
        scores, vals, idx = carry
        cur = jnp.max(scores, axis=1)                      # [B]
        arg = jnp.argmax(scores, axis=1).astype(jnp.int32)
        rmin = jnp.min(vals, axis=1)
        rarg = jnp.argmin(vals, axis=1).astype(jnp.int32)
        better = cur > rmin
        hit = (kcol == rarg[:, None]) & better[:, None]
        vals = jnp.where(hit, cur[:, None], vals)
        idx = jnp.where(hit, (base + arg)[:, None], idx)
        scores = jnp.where(col == arg[:, None], -jnp.inf, scores)
        return scores, vals, idx

    _, vals, idx = jax.lax.fori_loop(0, k, merge, (scores, vals, idx))
    return vals, idx


def _final_sort(vals, idx):
    """Desc-sort the [B, k] running buffer; ids of -inf slots forced to -1."""
    order = jnp.argsort(-vals, axis=1)
    vals = jnp.take_along_axis(vals, order, axis=1)
    idx = jnp.take_along_axis(idx, order, axis=1)
    return vals, jnp.where(jnp.isfinite(vals), idx, -1)


def _pad_postings(doc_terms, doc_weights, tile_n: int):
    """Pad postings rows to a tile multiple with inert (-1 / 0) rows."""
    n = doc_terms.shape[0]
    n_tiles = pl.cdiv(n, tile_n)
    pad = n_tiles * tile_n - n
    if pad:
        doc_terms = jnp.concatenate(
            [doc_terms, jnp.full((pad, doc_terms.shape[1]), -1, jnp.int32)])
        doc_weights = jnp.concatenate(
            [doc_weights, jnp.zeros((pad, doc_weights.shape[1]),
                                    doc_weights.dtype)])
    return doc_terms, doc_weights, n_tiles


def _lexical_kernel(qt_ref, qw_ref, dt_ref, dw_ref, vals_ref, idx_ref, *,
                    k: int, tile_n: int):
    step = pl.program_id(0)
    b = qt_ref.shape[0]

    @pl.when(step == 0)
    def _init():
        vals_ref[...] = jnp.full((b, k), -jnp.inf, jnp.float32)
        idx_ref[...] = jnp.full((b, k), -1, jnp.int32)

    scores = _tile_scores(qt_ref[...], qw_ref[...].astype(jnp.float32),
                          dt_ref[...], dw_ref[...])
    vals, idx = _merge_topk(scores, vals_ref[...], idx_ref[...],
                            step * tile_n, k)
    vals_ref[...] = vals
    idx_ref[...] = idx


@functools.partial(jax.jit, static_argnames=("k", "tile_n", "interpret"))
def lexical_score(q_terms: jax.Array, q_weights: jax.Array,
                  doc_terms: jax.Array, doc_weights: jax.Array, k: int,
                  tile_n: int = 512, interpret: bool = False):
    """q_terms/q_weights [B,T], doc_terms/doc_weights [N,L] ->
    (vals [B,k] desc-sorted, row idx [B,k]).

    Rows that match no query term score ``-inf`` / id ``-1`` — including
    empty postings rows (all ``-1`` terms) and the pad tail, which need no
    separate validity stream because inert terms can never accumulate
    positive mass.
    """
    b, t_q = q_terms.shape
    q_terms = q_terms.astype(jnp.int32)
    q_weights = q_weights.astype(jnp.float32)
    doc_terms, doc_weights, n_tiles = _pad_postings(
        doc_terms.astype(jnp.int32), doc_weights.astype(jnp.float32), tile_n)
    l_w = doc_terms.shape[1]

    vals, idx = pl.pallas_call(
        functools.partial(_lexical_kernel, k=k, tile_n=tile_n),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((b, t_q), lambda i: (0, 0)),      # query terms resident
            pl.BlockSpec((b, t_q), lambda i: (0, 0)),
            pl.BlockSpec((tile_n, l_w), lambda i: (i, 0)),  # postings stream
            pl.BlockSpec((tile_n, l_w), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, k), lambda i: (0, 0)),        # running top-k
            pl.BlockSpec((b, k), lambda i: (0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b, k), jnp.float32),
                   jax.ShapeDtypeStruct((b, k), jnp.int32)],
        interpret=interpret,
    )(q_terms, q_weights, doc_terms, doc_weights)
    return _final_sort(vals, idx)
