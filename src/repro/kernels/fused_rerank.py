"""Pallas TPU kernel: RRF fusion + diversification + rerank of a hybrid pool.

One grid step per query fuses the dense and lexical channels' top-k lists
entirely in rank domain:

  1. RRF mass: slot j of either channel contributes ``1 / (rrf_k + rank_j)``;
     duplicate doc ids across channels sum their mass onto the *first*
     occurrence (later occurrences get mass 0, so they can never be
     selected twice).  Rank-domain fusion is scale-free: any positive
     monotone transform of either channel's raw scores leaves the fused
     ordering unchanged.
  2. Greedy near-duplicate diversification: candidates are visited in
     descending RRF-mass order; a candidate survives only if its cosine
     similarity to every already-selected doc stays below
     ``diversify_sim`` (``None`` disables the pass — the ablation arm).
  3. Rerank: the final order is fused mass descending — the rank-domain
     fusion DECIDES — with the dense score ``pool_vec · q`` arbitrating
     exact-mass ties (slots holding the same rank in different channels
     carry identical mass; the dense model orders them instead of raw
     pool position).  Dropped slots (invalid, duplicate occurrences,
     diversity rejects) come back as ``-inf``.

The per-query pool is small (kd + kl slots), so the whole fusion state lives
in VMEM and the kernel is pure vector-unit work; the caller finishes with a
single two-key sort over [B, P] (same split as ``topk_search``'s final sort).

``_fuse_scores`` is shared with the XLA oracle
(``kernels/ref.py::fused_rerank_ref`` runs it per query via ``lax.map``), so
backends agree bit-for-bit on the fused output, invalid (-1) slots and
cross-channel duplicates included.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fuse_scores(q, ids, vecs, *, kd: int, kl: int, rrf_k: float,
                 diversify_sim: float | None):
    """Fuse one query's pool: q [d], ids [P], vecs [P,d] -> ([P], [P]) f32.

    Returns ``(mass, rscore)``: the fused RRF mass for selected docs
    (``-inf`` for dropped ones — invalid slots, duplicate occurrences,
    diversity rejects) and the dense rerank score used as the tie-break
    key.  Shared by the kernel body and the XLA oracle.
    """
    p = kd + kl
    rank = jnp.concatenate([jnp.arange(kd), jnp.arange(kl)]).astype(jnp.float32)
    pos = jnp.arange(p, dtype=jnp.int32)
    valid = ids >= 0
    raw = jnp.where(valid, 1.0 / (rrf_k + rank), 0.0)
    # combine duplicate ids: all of an id's mass lands on its first slot
    same = (ids[:, None] == ids[None, :]) & valid[:, None] & valid[None, :]
    first = ~jnp.any(same & (pos[None, :] < pos[:, None]), axis=1)
    mass = jnp.sum(jnp.where(same, raw[None, :], 0.0), axis=1)
    mass = jnp.where(first & valid, mass, 0.0)

    rscore = vecs.astype(jnp.float32) @ q.astype(jnp.float32)
    if diversify_sim is None:
        selected = mass > 0.0
    else:
        norm = jnp.sqrt(jnp.sum(vecs * vecs, axis=1))
        vn = vecs / jnp.maximum(norm, 1e-12)[:, None]
        sims = vn @ vn.T                                   # [P, P] cosine

        def body(i, carry):
            selected, rem = carry
            c = jnp.argmax(rem)                            # next-best mass
            eligible = rem[c] > 0.0
            msim = jnp.max(jnp.where(selected, sims[c], -jnp.inf))
            keep = eligible & (msim < diversify_sim)
            selected = selected | ((pos == c) & keep)
            rem = jnp.where(pos == c, 0.0, rem)
            return selected, rem

        selected, _ = jax.lax.fori_loop(
            0, p, body, (jnp.zeros((p,), bool), mass))
    return jnp.where(selected, mass, -jnp.inf), rscore


def _fused_kernel(q_ref, ids_ref, vecs_ref, mass_ref, rscore_ref, *,
                  kd: int, kl: int, rrf_k: float,
                  diversify_sim: float | None):
    mass, rscore = _fuse_scores(q_ref[0], ids_ref[0], vecs_ref[0], kd=kd,
                                kl=kl, rrf_k=rrf_k,
                                diversify_sim=diversify_sim)
    mass_ref[...] = mass[None, :]
    rscore_ref[...] = rscore[None, :]


def _final_topk(sel_mass, rscore, pool_ids, k: int):
    """Two-key desc sort of the fused pool, then slice the top-k (outside
    the kernel): primary key fused mass, secondary key dense rerank score
    (both stable argsorts, so the composition is lexicographic and
    deterministic across backends)."""
    o2 = jnp.argsort(-rscore, axis=1, stable=True)
    m2 = jnp.take_along_axis(sel_mass, o2, axis=1)
    o1 = jnp.argsort(-m2, axis=1, stable=True)
    order = jnp.take_along_axis(o2, o1, axis=1)[:, :k]
    vals = jnp.take_along_axis(sel_mass, order, axis=1)
    ids = jnp.take_along_axis(pool_ids, order, axis=1)
    return vals, jnp.where(jnp.isfinite(vals), ids, -1)


@functools.partial(jax.jit, static_argnames=(
    "kd", "k", "rrf_k", "diversify_sim", "interpret"))
def fused_rerank(queries: jax.Array, pool_ids: jax.Array,
                 pool_vecs: jax.Array, kd: int, k: int,
                 rrf_k: float = 60.0, diversify_sim: float | None = None,
                 interpret: bool = False):
    """queries [B,d], pool_ids [B,P], pool_vecs [B,P,d] ->
    (scores [B,k] desc-sorted fused RRF masses, ids [B,k]).

    ``pool_ids[:, :kd]`` is the dense channel's list, the rest the lexical
    channel's; ``-1`` marks invalid slots (their ``pool_vecs`` rows must be
    zero).  Slots dropped by fusion come back as ``-inf`` / ``-1``.
    """
    b, p = pool_ids.shape
    d = queries.shape[1]
    kl = p - kd
    mass, rscore = pl.pallas_call(
        functools.partial(_fused_kernel, kd=kd, kl=kl, rrf_k=rrf_k,
                          diversify_sim=diversify_sim),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),        # this query
            pl.BlockSpec((1, p), lambda i: (i, 0)),        # its fused pool
            pl.BlockSpec((1, p, d), lambda i: (i, 0, 0)),
        ],
        out_specs=[pl.BlockSpec((1, p), lambda i: (i, 0)),
                   pl.BlockSpec((1, p), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, p), jnp.float32),
                   jax.ShapeDtypeStruct((b, p), jnp.float32)],
        interpret=interpret,
    )(queries.astype(jnp.float32), pool_ids.astype(jnp.int32),
      pool_vecs.astype(jnp.float32))
    return _final_topk(mass, rscore, pool_ids, k)
