"""Pallas TPU kernel: IVF bucket gather-and-score (the fuzzy channel).

TPU mapping of Faiss's inverted-list probe: the probed bucket indices are
*scalar-prefetched* (PrefetchScalarGridSpec) so the BlockSpec index_map can
select which bucket block to DMA from HBM — a data-dependent gather with no
host round-trip.  Each grid step (query b, probe p) scores one bucket on
the MXU and folds it into the query's running top-k (revisited VMEM block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ivf_kernel(probe_ref, q_ref, vecs_ref, ids_ref, vals_ref, oidx_ref,
                *, k: int, scales_ref=None, bias_ref=None):
    """One (query, probed-bucket) grid step.  ``scales_ref``/``bias_ref``
    (compressed residency) carry the per-half int8 dequant scales and the
    query-centroid probe score: codes are centroid residuals, so scoring
    fuses the dequant as ``q.c + (q_lo.v8_lo)s_lo + (q_hi.v8_hi)s_hi`` —
    the int8 codes are the only per-slot HBM traffic."""
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        vals_ref[...] = jnp.full(vals_ref.shape, -jnp.inf, jnp.float32)
        oidx_ref[...] = jnp.full(oidx_ref.shape, -1, jnp.int32)

    q = q_ref[...].astype(jnp.float32)                     # [1, d]
    vecs = vecs_ref[...][0].astype(jnp.float32)            # [cap, d]
    gids = ids_ref[...][0]                                 # [cap]
    if scales_ref is None:
        scores = jax.lax.dot_general(
            q, vecs, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)[0]         # [cap]
    else:
        h = q.shape[1] // 2
        sc = scales_ref[...][0]                            # [cap, 2]
        dot = functools.partial(
            jax.lax.dot_general, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        scores = (dot(q[:, :h], vecs[:, :h])[0] * sc[:, 0]
                  + dot(q[:, h:], vecs[:, h:])[0] * sc[:, 1]
                  + bias_ref[...][0, 0])                   # fused dequant
    scores = jnp.where(gids >= 0, scores, -jnp.inf)
    kcol = jax.lax.iota(jnp.int32, k)
    cap_col = jax.lax.iota(jnp.int32, scores.shape[0])

    def merge(i, carry):
        scores, vals, idx = carry                          # [cap], [1,k], [1,k]
        cur = jnp.max(scores)
        arg = jnp.argmax(scores).astype(jnp.int32)
        rmin = jnp.min(vals)
        rarg = jnp.argmin(vals).astype(jnp.int32)
        better = cur > rmin
        hit = (kcol == rarg) & better
        vals = jnp.where(hit[None, :], cur, vals)
        idx = jnp.where(hit[None, :], gids[arg], idx)
        scores = jnp.where(cap_col == arg, -jnp.inf, scores)
        return scores, vals, idx

    _, vals, idx = jax.lax.fori_loop(
        0, k, merge, (scores, vals_ref[...], oidx_ref[...]))
    vals_ref[...] = vals
    oidx_ref[...] = idx


def _ivf_kernel_scaled(probe_ref, q_ref, vecs_ref, ids_ref, scales_ref,
                       bias_ref, vals_ref, oidx_ref, *, k: int):
    _ivf_kernel(probe_ref, q_ref, vecs_ref, ids_ref, vals_ref, oidx_ref,
                k=k, scales_ref=scales_ref, bias_ref=bias_ref)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def ivf_scan(queries: jax.Array, probe: jax.Array, bucket_vecs: jax.Array,
             bucket_ids: jax.Array, k: int, interpret: bool = False,
             bucket_scales: jax.Array | None = None,
             probe_bias: jax.Array | None = None):
    """queries [B,d], probe [B,P] int32, bucket_vecs [C,cap,d],
    bucket_ids [C,cap] -> (vals [B,k] desc, global ids [B,k]).

    ``bucket_scales [C,cap,2]`` + ``probe_bias [B,P]`` (optional, together)
    enable the compressed-residency path: ``bucket_vecs`` holds int8
    centroid-residual codes, ``probe_bias`` the query-centroid score of
    each probed bucket (the probe matmul already computed it), and each
    slot scores as ``bias + (q_lo.v8_lo)s_lo + (q_hi.v8_hi)s_hi`` inside
    the kernel (per-half scales factor out of the half inner products).
    Without them the program is byte-identical to the original f32 scan.
    """
    b, d = queries.shape
    nprobe = probe.shape[1]
    cap = bucket_vecs.shape[1]

    if bucket_scales is None:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, nprobe),
            in_specs=[
                pl.BlockSpec((1, d), lambda bi, pi, probe: (bi, 0)),
                pl.BlockSpec((1, cap, d),
                             lambda bi, pi, probe: (probe[bi, pi], 0, 0)),
                pl.BlockSpec((1, cap),
                             lambda bi, pi, probe: (probe[bi, pi], 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, k), lambda bi, pi, probe: (bi, 0)),
                pl.BlockSpec((1, k), lambda bi, pi, probe: (bi, 0)),
            ],
        )
        kernel = functools.partial(_ivf_kernel, k=k)
        operands = (probe, queries, bucket_vecs, bucket_ids)
    else:
        if probe_bias is None:
            raise ValueError(
                "bucket_scales (residual codes) requires probe_bias")
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, nprobe),
            in_specs=[
                pl.BlockSpec((1, d), lambda bi, pi, probe: (bi, 0)),
                pl.BlockSpec((1, cap, d),
                             lambda bi, pi, probe: (probe[bi, pi], 0, 0)),
                pl.BlockSpec((1, cap),
                             lambda bi, pi, probe: (probe[bi, pi], 0)),
                pl.BlockSpec((1, cap, 2),
                             lambda bi, pi, probe: (probe[bi, pi], 0, 0)),
                pl.BlockSpec((1, 1), lambda bi, pi, probe: (bi, pi)),
            ],
            out_specs=[
                pl.BlockSpec((1, k), lambda bi, pi, probe: (bi, 0)),
                pl.BlockSpec((1, k), lambda bi, pi, probe: (bi, 0)),
            ],
        )
        kernel = functools.partial(_ivf_kernel_scaled, k=k)
        operands = (probe, queries, bucket_vecs, bucket_ids, bucket_scales,
                    probe_bias.astype(jnp.float32))
    vals, idx = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, k), jnp.float32),
                   jax.ShapeDtypeStruct((b, k), jnp.int32)],
        interpret=interpret,
    )(*operands)
    order = jnp.argsort(-vals, axis=1)
    return jnp.take_along_axis(vals, order, axis=1), \
        jnp.take_along_axis(idx, order, axis=1)
