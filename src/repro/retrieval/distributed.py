"""Distributed exact top-k over a sharded corpus (shard_map + collectives).

The production path for full-database retrieval: each model-axis shard holds a
corpus slice, computes a local streaming top-k, and the k·(value,id) pairs are
merged with an all-gather tree (O(shards·k) bytes on the interconnect instead
of O(N) scores).  This is how the paper's 'slow full-database retrieval on
the cloud' lowers onto a TPU pod.

Shards smaller than k: a shard with fewer than ``k`` rows can only produce
``rows`` local candidates, so every local candidate set is padded to exactly
``k`` columns with ``-inf`` scores / ``-1`` ids before the all-gather.  The
global merge then always sees a rectangular [B, shards·k] candidate matrix
and returns ``-1`` ids only when the whole corpus holds fewer than ``k``
rows — the same contract as ``chunked_flat_search``.

:func:`sharded_topk_reference` is the mesh-free oracle: the identical
local-top-k + candidate-merge math on one device, used by
``retrieval/service.py::ShardedMeshBackend`` when no multi-device mesh is
available (and by the parity tests as the middle term between the shard_map
path and ``chunked_flat_search``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils import shard_map

from repro.retrieval.flat import chunked_flat_search


def _pad_candidates(s: jax.Array, i: jax.Array, k: int):
    """Pad local [B, kk<=k] candidates to [B, k] with -inf scores / -1 ids."""
    kk = s.shape[-1]
    if kk >= k:
        return s, i
    pad = k - kk
    s = jnp.concatenate(
        [s, jnp.full(s.shape[:-1] + (pad,), -jnp.inf, s.dtype)], axis=-1)
    i = jnp.concatenate(
        [i, jnp.full(i.shape[:-1] + (pad,), -1, i.dtype)], axis=-1)
    return s, i


def distributed_flat_search(mesh: Mesh, corpus_axes: tuple[str, ...] = ("data", "model")):
    """Returns a jit-able fn(corpus [N,d], queries [B,d]) -> (scores, ids [B,k]).

    corpus is sharded over ``corpus_axes`` (row-wise); queries replicated.
    N must divide evenly by the number of shards (the shard_map contract).
    """
    axes = corpus_axes

    def search(corpus, queries, k: int):
        n_shards = 1
        for a in axes:
            n_shards *= mesh.shape[a]
        shard_rows = corpus.shape[0] // n_shards

        def local(corpus_blk, q):
            # corpus_blk: [N/shards, d] local slice
            s, i = jax.lax.top_k(q @ corpus_blk.T, min(k, corpus_blk.shape[0]))
            # global ids: offset by this shard's row start
            idx = jax.lax.axis_index(axes)
            i = i + (idx * shard_rows).astype(i.dtype)
            # a shard smaller than k yields a ragged candidate set — pad to
            # k columns (-inf / -1) so the gathered matrix is rectangular
            s, i = _pad_candidates(s, i, k)
            # all-gather the candidate sets over the corpus axes, then merge
            s_all = jax.lax.all_gather(s, axes, axis=1, tiled=True)
            i_all = jax.lax.all_gather(i, axes, axis=1, tiled=True)
            ts, ti = jax.lax.top_k(s_all, k)
            return ts, jnp.take_along_axis(i_all, ti, axis=1)

        return shard_map(
            local, mesh=mesh,
            in_specs=(P(axes), P()),
            out_specs=(P(), P()),
            check_vma=False,   # post-all-gather results are replicated
        )(corpus, queries)

    return search


@functools.partial(jax.jit, static_argnames=("k", "n_shards", "chunk"))
def sharded_topk_reference(corpus: jax.Array, queries: jax.Array, k: int,
                           n_shards: int,
                           chunk: int = 32768) -> tuple[jax.Array, jax.Array]:
    """Single-device oracle for :func:`distributed_flat_search`.

    Splits the corpus into ``n_shards`` row blocks, runs the *streaming*
    chunked scan per shard (the transient score matrix stays [B, chunk],
    never [B, N]), offsets the local ids, pads each candidate set to ``k``
    (-inf / -1) and merges — the exact candidate layout the all-gather
    produces, so ids/scores match the mesh path and ``chunked_flat_search``
    bit-for-bit.
    """
    n, _ = corpus.shape
    b = queries.shape[0]
    rows = max(1, -(-n // n_shards))
    kk = min(k, rows)
    cand_s, cand_i = [], []
    for sh in range(n_shards):
        live = min(rows, n - sh * rows)
        if live <= 0:                   # more shards than rows: empty shard
            lv = jnp.full((b, k), -jnp.inf, queries.dtype)
            li = jnp.full((b, k), -1, jnp.int32)
        else:
            blk = jax.lax.slice_in_dim(corpus, sh * rows, sh * rows + live)
            lv, li = chunked_flat_search(blk, queries, kk,
                                         chunk=min(chunk, live))
            li = jnp.where(li >= 0, li + sh * rows, -1)   # global ids
            lv, li = _pad_candidates(lv, li, k)
        cand_s.append(lv)
        cand_i.append(li)
    v, pos = jax.lax.top_k(jnp.concatenate(cand_s, axis=1), k)  # merge
    return v, jnp.take_along_axis(jnp.concatenate(cand_i, axis=1), pos,
                                  axis=1)
