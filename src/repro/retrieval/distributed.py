"""Distributed exact top-k over a sharded corpus (shard_map + collectives).

The production path for full-database retrieval: each model-axis shard holds a
corpus slice, computes a local streaming top-k, and the k·(value,id) pairs are
merged with an all-gather tree (O(shards·k) bytes on the interconnect instead
of O(N) scores).  This is how the paper's 'slow full-database retrieval on
the cloud' lowers onto a TPU pod.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils import shard_map

from repro.retrieval.flat import chunked_flat_search


def distributed_flat_search(mesh: Mesh, corpus_axes: tuple[str, ...] = ("data", "model")):
    """Returns a jit-able fn(corpus [N,d], queries [B,d]) -> (scores, ids [B,k]).

    corpus is sharded over ``corpus_axes`` (row-wise); queries replicated.
    """
    axes = corpus_axes

    def search(corpus, queries, k: int):
        n_shards = 1
        for a in axes:
            n_shards *= mesh.shape[a]
        shard_rows = corpus.shape[0] // n_shards

        def local(corpus_blk, q):
            # corpus_blk: [N/shards, d] local slice
            s, i = jax.lax.top_k(q @ corpus_blk.T, min(k, corpus_blk.shape[0]))
            # global ids: offset by this shard's row start
            idx = jax.lax.axis_index(axes)
            i = i + (idx * shard_rows).astype(i.dtype)
            # all-gather the candidate sets over the corpus axes, then merge
            s_all = jax.lax.all_gather(s, axes, axis=1, tiled=True)
            i_all = jax.lax.all_gather(i, axes, axis=1, tiled=True)
            ts, ti = jax.lax.top_k(s_all, k)
            return ts, jnp.take_along_axis(i_all, ti, axis=1)

        return shard_map(
            local, mesh=mesh,
            in_specs=(P(axes), P()),
            out_specs=(P(), P()),
            check_vma=False,   # post-all-gather results are replicated
        )(corpus, queries)

    return search
