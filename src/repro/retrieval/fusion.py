"""Single-dispatch hybrid retrieval programs (dense + lexical + fused rerank).

One jitted program per ``[B, d]`` batch runs the whole hybrid cloud stage:

    dense channel scan (flat | sharded | IVF-ANN)      -> top-kd ids
    lexical channel scan (hashed postings)             -> top-kl ids
    RRF fusion + near-dup diversification + rerank     -> top-k ids

The channel scans and the fusion kernel are all traceable (Pallas kernels or
their XLA oracles behind the shared ``scan_backend`` switch), so XLA fuses
the stage into ONE host->device dispatch regardless of batch width — the
same dispatch-count discipline as ``speculate_batch`` and ``IVFBackend``,
probed through ``core/dispatch.py`` by the benchmarks.

Id contract: the hybrid doc store keeps postings row == global doc id
(``HybridBackend`` rejects non-sequential ids at ingest), so the lexical
channel's row indices are already ids and the fused pool gathers rerank
vectors straight from the corpus; ``-1`` invalid slots gather zeros and can
never be selected.

``ivf_ann_body`` is the (un-jitted) ANN program body shared with
``retrieval/service.py::_ivf_ann_search`` — the hybrid ANN mode inlines the
exact same centroid -> probe -> bucket-scan -> residual-merge math as its
dense channel, keeping the whole thing one program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fused_rerank import fused_rerank
from repro.kernels.ref import fused_rerank_ref
from repro.retrieval.distributed import sharded_topk_reference
from repro.retrieval.flat import chunked_flat_search
from repro.retrieval.ivf import CompressedIVFIndex, ivf_probe_scan
from repro.retrieval.lexical import lexical_topk


def ivf_ann_body(index, res_vecs, res_ids, queries, *, nprobe: int, k: int,
                 scan_backend: str, interpret: bool):
    """ONE program per [B,d] batch: centroid matmul -> top-nprobe probe ->
    bucket scan (Pallas kernel or XLA oracle) -> exact residual-buffer scan
    -> merged top-k.  Everything fuses into a single host dispatch."""
    from repro.kernels import ops
    queries = queries.astype(jnp.float32)
    nprobe = min(nprobe, index.n_buckets)
    cscores = queries @ index.centroids.T                    # [B, C]
    cvals, probe = jax.lax.top_k(cscores, nprobe)            # [B, nprobe]
    if scan_backend == "pallas":
        if isinstance(index, CompressedIVFIndex):
            # residual codes: the probe scores double as the centroid bias
            scales, bias = index.bucket_scales, cvals
        else:
            scales = bias = None
        s, ids = ops.ivf_scan(queries, probe.astype(jnp.int32),
                              index.bucket_vecs, index.bucket_ids, k,
                              interpret=interpret, bucket_scales=scales,
                              probe_bias=bias)
    else:
        s, ids = ivf_probe_scan(index, queries, probe, k)
    # exact scan of the residual flat buffer (live-ingested bucket spill)
    rs = queries @ res_vecs.T                                # [B, R]
    rs = jnp.where(res_ids[None, :] >= 0, rs, -jnp.inf)
    rk = min(k, res_vecs.shape[0])
    r_s, r_pos = jax.lax.top_k(rs, rk)
    r_ids = res_ids[r_pos]
    s = jnp.concatenate([s, r_s], axis=1)
    ids = jnp.concatenate([ids, r_ids], axis=1)
    top_s, top_i = jax.lax.top_k(s, k)
    return top_s, jnp.take_along_axis(ids, top_i, axis=1)


def _fuse_tail(corpus, queries, i_d, q_terms, q_weights, doc_terms,
               doc_weights, *, k: int, kl: int, rrf_k: float,
               diversify_sim: float | None, scan_backend: str,
               interpret: bool, tile_n: int):
    """Lexical scan + RRF/diversify/rerank over the two channels' lists."""
    _, i_l = lexical_topk(q_terms, q_weights, doc_terms, doc_weights, kl,
                          backend=scan_backend, tile_n=tile_n,
                          interpret=interpret)
    pool_ids = jnp.concatenate([i_d, i_l], axis=1)           # [B, kd+kl]
    pool_vecs = (corpus[jnp.maximum(pool_ids, 0)]
                 * (pool_ids >= 0)[..., None].astype(corpus.dtype))
    kd = i_d.shape[1]
    if scan_backend == "pallas":
        return fused_rerank(queries, pool_ids, pool_vecs, kd, k,
                            rrf_k=rrf_k, diversify_sim=diversify_sim,
                            interpret=interpret)
    return fused_rerank_ref(queries, pool_ids, pool_vecs, kd, k,
                            rrf_k=rrf_k, diversify_sim=diversify_sim)


_HYBRID_STATIC = ("k", "kd", "kl", "rrf_k", "diversify_sim", "scan_backend",
                  "interpret", "tile_n")


@functools.partial(jax.jit, static_argnames=_HYBRID_STATIC + ("chunk",))
def hybrid_flat_search(corpus, doc_terms, doc_weights, queries, q_terms,
                       q_weights, *, k, kd, kl, rrf_k, diversify_sim,
                       scan_backend, interpret, tile_n, chunk):
    queries = queries.astype(jnp.float32)
    _, i_d = chunked_flat_search(corpus, queries, kd, chunk=chunk)
    return _fuse_tail(corpus, queries, i_d, q_terms, q_weights, doc_terms,
                      doc_weights, k=k, kl=kl, rrf_k=rrf_k,
                      diversify_sim=diversify_sim, scan_backend=scan_backend,
                      interpret=interpret, tile_n=tile_n)


@functools.partial(jax.jit, static_argnames=_HYBRID_STATIC + ("n_shards",
                                                              "chunk"))
def hybrid_sharded_search(corpus, doc_terms, doc_weights, queries, q_terms,
                          q_weights, *, k, kd, kl, rrf_k, diversify_sim,
                          scan_backend, interpret, tile_n, n_shards, chunk):
    queries = queries.astype(jnp.float32)
    _, i_d = sharded_topk_reference(corpus, queries, kd, n_shards=n_shards,
                                    chunk=chunk)
    return _fuse_tail(corpus, queries, i_d, q_terms, q_weights, doc_terms,
                      doc_weights, k=k, kl=kl, rrf_k=rrf_k,
                      diversify_sim=diversify_sim, scan_backend=scan_backend,
                      interpret=interpret, tile_n=tile_n)


@functools.partial(jax.jit, static_argnames=_HYBRID_STATIC + ("nprobe",))
def hybrid_ann_search(index, res_vecs, res_ids, corpus, doc_terms,
                      doc_weights, queries, q_terms, q_weights, *, k, kd, kl,
                      rrf_k, diversify_sim, scan_backend, interpret, tile_n,
                      nprobe):
    queries = queries.astype(jnp.float32)
    _, i_d = ivf_ann_body(index, res_vecs, res_ids, queries, nprobe=nprobe,
                          k=kd, scan_backend=scan_backend,
                          interpret=interpret)
    return _fuse_tail(corpus, queries, i_d, q_terms, q_weights, doc_terms,
                      doc_weights, k=k, kl=kl, rrf_k=rrf_k,
                      diversify_sim=diversify_sim, scan_backend=scan_backend,
                      interpret=interpret, tile_n=tile_n)
