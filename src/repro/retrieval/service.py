"""Pluggable full-retrieval backend layer + the shared RetrievalService.

The paper's speedup comes from *bypassing* slow full-database retrieval, but
every rejected draft still pays for it — so the cloud stage is the serving
system's scaling bottleneck.  This module makes that stage pluggable: the
:class:`FullRetrievalBackend` protocol is what every serving layer (the
``ServeLoop`` engines, ``BatchedHasEngine``, the continuous-batching
scheduler, ``AutoRagPipeline``) sees, and three implementations cover the
deployment spectrum:

``LocalFlatBackend``
    One in-process exact scan (``chunked_flat_search``) — the historical
    behavior of ``RetrievalService.full_search``.  One worker: full
    retrievals serialize behind each other.
``ShardedMeshBackend``
    The corpus row-sharded over a CPU/TPU mesh
    (``retrieval/distributed.py``): each shard streams N/shards rows and the
    O(shards·k) candidate sets merge with an all-gather.  Latency is scaled
    by ``LatencyModel.shard_scale(n_shards)`` and the backend exposes
    ``n_workers`` concurrent dispatch slots, so the scheduler's cloud stage
    becomes a worker *pool* whose throughput scales with corpus shards.
    Off-mesh (one local device) the identical merge math runs through
    :func:`~repro.retrieval.distributed.sharded_topk_reference`, keeping
    results bit-identical to the mesh path and to ``LocalFlatBackend``.
``ReplicaBackend``
    Routes full retrievals through warm-standby replicas
    (``serving/replication.py``): ``n_workers`` = number of standbys, and
    every cache ingest is reconciled into each standby's delta log
    (``on_ingest``), so any replica can fail over with the cache it would
    have had — the scheduler no longer assumes one authoritative cache.

Latency protocol: ``latency(batch)`` returns the *modeled* service time of
one coalesced dispatch (bandwidth-bound: a batch streams the operand once,
so the time is batch-width independent); ``n_workers`` is how many such
dispatches the virtual clock may overlap.
"""
from __future__ import annotations

import functools
import time
from typing import Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.retrieval.distributed import (distributed_flat_search,
                                         sharded_topk_reference)
from repro.retrieval.flat import chunked_flat_search


@runtime_checkable
class FullRetrievalBackend(Protocol):
    """What a serving layer needs from the full-database retrieval stage."""

    #: concurrent dispatch slots the virtual clock may overlap
    n_workers: int

    def search(self, q_embs: jax.Array) -> tuple[jax.Array, jax.Array]:
        """Exact top-k for a query batch [B, d] -> (scores [B,k], ids [B,k])."""
        ...

    def latency(self, batch: int) -> float:
        """Modeled service time (s) of ONE coalesced dispatch of ``batch``."""
        ...

    def on_ingest(self, q_embs: np.ndarray, full_ids: np.ndarray,
                  state, tenant_ids: np.ndarray | None = None, *,
                  ingest_key=None) -> None:
        """Cache-ingest notification (rows just folded into the HaS cache).

        ``tenant_ids [N]`` (optional) tags each row with its tenant
        partition so replica-style backends keep per-tenant delta logs
        (None == the single-tenant path).  ``ingest_key`` (optional,
        keyword-only) is a stable batch identity for IDEMPOTENT ingest:
        a backend that replicates must drop a batch whose key it has
        already recorded — a retried cloud dispatch whose first attempt
        landed must not fold twice downstream.
        """
        ...


class _BackendBase:
    """Shared no-op ingest hook; concrete backends set search/latency."""

    n_workers: int = 1

    def on_ingest(self, q_embs, full_ids, state, tenant_ids=None, *,
                  ingest_key=None) -> None:
        return None


class LocalFlatBackend(_BackendBase):
    """Today's behavior: one in-process chunked exact scan, one worker."""

    def __init__(self, corpus: jax.Array, k: int, lat, chunk: int = 32768):
        self.corpus = corpus
        self.k = k
        self.lat = lat
        self.chunk = min(chunk, corpus.shape[0])
        self._search = jax.jit(functools.partial(
            chunked_flat_search, k=k, chunk=self.chunk))
        self.n_workers = 1

    def search(self, q_embs):
        return self._search(self.corpus, q_embs)

    def latency(self, batch: int) -> float:
        # bandwidth-bound coalesced matmul: the batch streams the corpus once
        return self.lat.full_scan_time()


class ShardedMeshBackend(_BackendBase):
    """Row-sharded mesh scan with a concurrent-dispatch worker pool.

    ``mesh`` (multi-device) lowers through ``distributed_flat_search``
    (shard_map + all-gather merge over ``corpus_axes``); without a mesh —
    or on a 1-device mesh — the same candidate-merge math runs through
    ``sharded_topk_reference`` so the virtual clock can model an
    ``n_shards``-way deployment from a single-device container.  Either
    path returns scores/ids bit-identical to ``LocalFlatBackend``.
    """

    def __init__(self, corpus: jax.Array, k: int, lat, n_shards: int = 4,
                 n_workers: int = 1, mesh=None,
                 corpus_axes: tuple[str, ...] = ("data", "model")):
        self.corpus = corpus
        self.k = k
        self.lat = lat
        self.mesh = mesh
        mesh_shards = 1
        if mesh is not None:
            for a in corpus_axes:
                mesh_shards *= mesh.shape.get(a, 1)
        if mesh is not None and mesh_shards > 1:
            # the mesh decides the physical shard count
            self.n_shards = mesh_shards
            if corpus.shape[0] % mesh_shards:
                raise ValueError(
                    f"corpus rows {corpus.shape[0]} must divide evenly over "
                    f"{mesh_shards} mesh shards")
            dist = distributed_flat_search(mesh, corpus_axes)
            self._search = jax.jit(lambda c, q: dist(c, q, k))
        else:
            self.n_shards = max(1, int(n_shards))
            self._search = functools.partial(
                sharded_topk_reference, k=k, n_shards=self.n_shards)
        self.n_workers = max(1, int(n_workers))

    def search(self, q_embs):
        return self._search(self.corpus, q_embs)

    def latency(self, batch: int) -> float:
        # every shard streams N/n_shards rows concurrently + merge overhead
        return self.lat.full_scan_time() * self.lat.shard_scale(self.n_shards)


class ReplicaBackend(_BackendBase):
    """Warm-standby replica routing + cache-ingest reconciliation.

    Wraps an inner backend for the actual scan and models one concurrent
    dispatch slot per standby replica.  ``on_ingest`` mirrors every row the
    serving loop folds into the authoritative cache onto each member's
    delta log via the shared ``record_batch`` sink protocol
    (serving/replication.py) — members are cloud ``WarmStandby`` replicas
    and/or an edge ``EdgeReplicaPool`` (serving/edge_pool.py), so both
    replication tiers reconcile off ONE ingest notification.  A standby
    failover then resumes with exactly the cache the primary had — the
    serving loop no longer owns the only authoritative copy.

    Padded (``-1``) doc ids — emitted by the sharded search paths when the
    corpus holds fewer than k rows — gather ZERO vectors into the delta
    logs (:func:`~repro.serving.replication.gather_doc_vecs`); a raw
    ``corpus[full_ids]`` would wrap them to the LAST corpus row and
    silently corrupt every member's log.
    """

    def __init__(self, inner: FullRetrievalBackend, standbys: Sequence,
                 corpus: jax.Array):
        self.inner = inner
        self.standbys = list(standbys)
        self.corpus = corpus
        self._corpus_np = np.asarray(corpus)    # one host copy, reused
        self.n_workers = max(1, len(self.standbys))

    def search(self, q_embs):
        return self.inner.search(q_embs)

    def latency(self, batch: int) -> float:
        return self.inner.latency(batch)

    def on_ingest(self, q_embs, full_ids, state, tenant_ids=None, *,
                  ingest_key=None) -> None:
        from repro.serving.replication import gather_doc_vecs
        q_embs = np.asarray(q_embs, np.float32)
        full_ids = np.asarray(full_ids, np.int32)
        vecs = gather_doc_vecs(self._corpus_np, full_ids)  # [N, k, d]
        for sb in self.standbys:
            sb.record_batch(q_embs, full_ids, vecs, state,
                            tenant_ids=tenant_ids, ingest_key=ingest_key)


class RetrievalService:
    """Shared substrate: corpus + latency calibration + retrieval backend.

    Composition only — the world supplies the corpus, the
    :class:`LatencyModel` supplies analytic scan times, and the
    :class:`FullRetrievalBackend` supplies the actual full-database search
    (``backend=None`` -> :class:`LocalFlatBackend`, the historical
    behavior).

    Latency accounting (see serving/latency.py): edge-local compute (cache
    channel, homology validation, cache updates) is charged at *measured*
    wall-clock — those structures run at their true paper-scale sizes here.
    Corpus-proportional compute (full ENNS scan, fuzzy IVF scan) is charged
    analytically as bytes/bandwidth at the paper's 49.2M-passage target
    scale, with the bandwidth calibrated from a measured reference scan.
    """

    def __init__(self, world, latency, k: int = 10, chunk: int = 32768,
                 calibrate: bool = False,
                 backend: FullRetrievalBackend | None = None):
        self.world = world
        self.latency = latency
        self.latency.d = world.cfg.d
        self.latency.actual_corpus = world.cfg.n_docs
        self.k = k
        self.chunk = min(chunk, world.cfg.n_docs)
        # one device-resident corpus: reuse the backend's copy when one was
        # injected (every backend holds the same world.doc_emb by contract)
        bc = getattr(backend, "corpus", None) if backend is not None else None
        self.corpus = bc if bc is not None else jnp.asarray(world.doc_emb)
        self.backend = backend if backend is not None else LocalFlatBackend(
            self.corpus, k, latency, chunk=self.chunk)
        # warmup (+ optional bandwidth calibration from a measured scan)
        z = jnp.zeros((1, world.cfg.d))
        self.backend.search(z)[0].block_until_ready()
        if calibrate:
            # bandwidth is defined against the UNSHARDED reference scan
            # (shard_scale etc. apply on top of it) — always time the flat
            # chunked scan, not backend.search, or a sharded backend would
            # count its speedup twice
            ref = (self.backend._search
                   if isinstance(self.backend, LocalFlatBackend)
                   else jax.jit(functools.partial(
                       chunked_flat_search, k=k, chunk=self.chunk)))
            ref(self.corpus, z)[0].block_until_ready()
            t0 = time.perf_counter()
            for _ in range(3):
                ref(self.corpus, z)[0].block_until_ready()
            self.latency.calibrate((time.perf_counter() - t0) / 3,
                                   world.cfg.n_docs)

    def full_search(self, q_emb: np.ndarray):
        """Exact full-database search; returns (ids [k], vecs [k,d], t_comp)."""
        s, ids = self.backend.search(jnp.asarray(q_emb)[None])
        ids = np.asarray(ids[0])
        t = self.backend.latency(1)
        return ids, np.asarray(self.corpus[ids]), t

    def full_search_batch(self, q_embs) -> tuple[np.ndarray, float]:
        """Coalesced exact search for [B, d]; returns (ids [B,k], t_comp)."""
        _, ids = self.backend.search(jnp.asarray(q_embs))
        return np.asarray(ids), self.backend.latency(len(q_embs))
