"""Pluggable full-retrieval backend layer + the shared RetrievalService.

The paper's speedup comes from *bypassing* slow full-database retrieval, but
every rejected draft still pays for it — so the cloud stage is the serving
system's scaling bottleneck.  This module makes that stage pluggable: the
:class:`FullRetrievalBackend` protocol is what every serving layer (the
``ServeLoop`` engines, ``BatchedHasEngine``, the continuous-batching
scheduler, ``AutoRagPipeline``) sees, and three implementations cover the
deployment spectrum:

``LocalFlatBackend``
    One in-process exact scan (``chunked_flat_search``) — the historical
    behavior of ``RetrievalService.full_search``.  One worker: full
    retrievals serialize behind each other.
``ShardedMeshBackend``
    The corpus row-sharded over a CPU/TPU mesh
    (``retrieval/distributed.py``): each shard streams N/shards rows and the
    O(shards·k) candidate sets merge with an all-gather.  Latency is scaled
    by ``LatencyModel.shard_scale(n_shards)`` and the backend exposes
    ``n_workers`` concurrent dispatch slots, so the scheduler's cloud stage
    becomes a worker *pool* whose throughput scales with corpus shards.
    Off-mesh (one local device) the identical merge math runs through
    :func:`~repro.retrieval.distributed.sharded_topk_reference`, keeping
    results bit-identical to the mesh path and to ``LocalFlatBackend``.
``IVFBackend``
    ANN cloud stage (``--retrieval-backend ann``): an IVF index
    (``retrieval/ivf.py``) scored through the Pallas ``ivf_scan`` kernel or
    its XLA oracle — the same ``backend="pallas"|"xla"`` switch the
    speculation path uses — in ONE dispatch per query batch (centroid
    matmul -> top-nprobe -> scalar-prefetched bucket scan -> residual
    merge).  Optional int8 compressed corpus residency
    (``compressed=True``) quantizes bucket storage per vector with the
    dequant fused into the scan.  ``latency`` is
    ``LatencyModel.ann_scale`` — centroid + nprobe·capacity bucket cost
    instead of the full corpus.  NOTE the result is *approximate*:
    recall@k is calibrated by ``benchmarks/ann_recall.py``, end-to-end,
    because approximate results feed the HaS cache.
``ReplicaBackend``
    Routes full retrievals through warm-standby replicas
    (``serving/replication.py``): ``n_workers`` = number of standbys, and
    every cache ingest is reconciled into each standby's delta log
    (``on_ingest``), so any replica can fail over with the cache it would
    have had — the scheduler no longer assumes one authoritative cache.

Latency protocol: ``latency(batch)`` returns the *modeled* service time of
one coalesced dispatch (bandwidth-bound: a batch streams the operand once,
so the time is batch-width independent); ``n_workers`` is how many such
dispatches the virtual clock may overlap.
"""
from __future__ import annotations

import functools
import time
from typing import Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.retrieval.distributed import (distributed_flat_search,
                                         sharded_topk_reference)
from repro.retrieval.flat import chunked_flat_search
from repro.retrieval.fusion import (hybrid_ann_search, hybrid_flat_search,
                                    hybrid_sharded_search, ivf_ann_body)
from repro.retrieval.ivf import (CompressedIVFIndex, IVFIndex, _assign_fn,
                                 _build_ivf_arrays, _quant_residual_halves,
                                 ivf_probe_scan)


@runtime_checkable
class FullRetrievalBackend(Protocol):
    """What a serving layer needs from the full-database retrieval stage."""

    #: concurrent dispatch slots the virtual clock may overlap
    n_workers: int

    def search(self, q_embs: jax.Array) -> tuple[jax.Array, jax.Array]:
        """Exact top-k for a query batch [B, d] -> (scores [B,k], ids [B,k])."""
        ...

    def latency(self, batch: int) -> float:
        """Modeled service time (s) of ONE coalesced dispatch of ``batch``."""
        ...

    def on_ingest(self, q_embs: np.ndarray, full_ids: np.ndarray,
                  state, tenant_ids: np.ndarray | None = None, *,
                  ingest_key=None) -> None:
        """Cache-ingest notification (rows just folded into the HaS cache).

        ``tenant_ids [N]`` (optional) tags each row with its tenant
        partition so replica-style backends keep per-tenant delta logs
        (None == the single-tenant path).  ``ingest_key`` (optional,
        keyword-only) is a stable batch identity for IDEMPOTENT ingest:
        a backend that replicates must drop a batch whose key it has
        already recorded — a retried cloud dispatch whose first attempt
        landed must not fold twice downstream.
        """
        ...


class _BackendBase:
    """Shared no-op ingest hook; concrete backends set search/latency."""

    n_workers: int = 1

    def on_ingest(self, q_embs, full_ids, state, tenant_ids=None, *,
                  ingest_key=None) -> None:
        return None


class LocalFlatBackend(_BackendBase):
    """Today's behavior: one in-process chunked exact scan, one worker."""

    def __init__(self, corpus: jax.Array, k: int, lat, chunk: int = 32768):
        self.corpus = corpus
        self.k = k
        self.lat = lat
        self.chunk = min(chunk, corpus.shape[0])
        self._search = jax.jit(functools.partial(
            chunked_flat_search, k=k, chunk=self.chunk))
        self.n_workers = 1

    def search(self, q_embs):
        return self._search(self.corpus, q_embs)

    def latency(self, batch: int) -> float:
        # bandwidth-bound coalesced matmul: the batch streams the corpus once
        return self.lat.full_scan_time()


class ShardedMeshBackend(_BackendBase):
    """Row-sharded mesh scan with a concurrent-dispatch worker pool.

    ``mesh`` (multi-device) lowers through ``distributed_flat_search``
    (shard_map + all-gather merge over ``corpus_axes``); without a mesh —
    or on a 1-device mesh — the same candidate-merge math runs through
    ``sharded_topk_reference`` so the virtual clock can model an
    ``n_shards``-way deployment from a single-device container.  Either
    path returns scores/ids bit-identical to ``LocalFlatBackend``.
    """

    def __init__(self, corpus: jax.Array, k: int, lat, n_shards: int = 4,
                 n_workers: int = 1, mesh=None,
                 corpus_axes: tuple[str, ...] = ("data", "model")):
        self.corpus = corpus
        self.k = k
        self.lat = lat
        self.mesh = mesh
        mesh_shards = 1
        if mesh is not None:
            for a in corpus_axes:
                mesh_shards *= mesh.shape.get(a, 1)
        if mesh is not None and mesh_shards > 1:
            # the mesh decides the physical shard count
            self.n_shards = mesh_shards
            if corpus.shape[0] % mesh_shards:
                raise ValueError(
                    f"corpus rows {corpus.shape[0]} must divide evenly over "
                    f"{mesh_shards} mesh shards")
            dist = distributed_flat_search(mesh, corpus_axes)
            self._search = jax.jit(lambda c, q: dist(c, q, k))
        else:
            self.n_shards = max(1, int(n_shards))
            self._search = functools.partial(
                sharded_topk_reference, k=k, n_shards=self.n_shards)
        self.n_workers = max(1, int(n_workers))

    def search(self, q_embs):
        return self._search(self.corpus, q_embs)

    def latency(self, batch: int) -> float:
        # every shard streams N/n_shards rows concurrently + merge overhead
        return self.lat.full_scan_time() * self.lat.shard_scale(self.n_shards)


# the ANN program body lives in retrieval/fusion.py so the hybrid backend
# can inline the identical math as its dense channel inside ONE fused program
_ivf_ann_search = functools.partial(jax.jit, static_argnames=(
    "nprobe", "k", "scan_backend", "interpret"))(ivf_ann_body)


class IVFBackend(_BackendBase):
    """ANN cloud stage: IVF index + Pallas/XLA bucket scan + live-ingest
    reconciliation.

    The index is built by streaming the corpus through k-means assignment
    in ``build_chunk``-row slices (never materializing f32 buckets in
    compressed mode); host-side mirrors of the bucket arrays stay canonical
    so live ingest mutates numpy and re-uploads lazily on the next search.
    ``compressed=True`` stores int8 centroid-residual codes with two
    per-half dequant scales (``retrieval/ivf.py::_quant_residual_halves``,
    built on ``training/compression.py::quantize_int8``); the dequant fuses
    into scoring on both scan backends and the centroid term reuses the
    probe matmul — the bucket store shrinks ~3.6x (d bytes + two f32
    scales per vector vs 4d bytes), with a smaller recall drop than plain
    per-vector int8 because the int8 grid codes only the residual.

    Live ingest (``ingest_docs``) assigns each new doc to its nearest
    centroid; a full bucket spills into a small exact-scanned residual
    flat buffer (capacity ``residual_cap``), and residual overflow
    triggers a full re-bucketing flush (k-means + rebuild over the grown
    corpus).  Search correctness never depends on WHERE a doc landed —
    the residual is merged into every top-k.  ``on_ingest`` (cache-ingest
    notification) stays the no-op base hook, so ``ReplicaBackend`` can
    wrap an ``IVFBackend`` unchanged.

    Results are APPROXIMATE (recall < 1 at nprobe < n_buckets) and feed
    the HaS cache downstream; calibrate nprobe with
    ``benchmarks/ann_recall.py``, which measures end-to-end doc-hit, not
    just kernel recall@k.
    """

    def __init__(self, corpus: jax.Array, k: int, lat,
                 n_clusters: int = 1024, nprobe: int = 32,
                 capacity_factor: float = 2.0, compressed: bool = False,
                 backend: str | None = None, n_workers: int = 1,
                 seed: int = 0, residual_cap: int = 1024,
                 build_chunk: int = 65536, kmeans_iters: int = 10,
                 interpret: bool | None = None):
        from repro.core.has import default_backend
        from repro.kernels.ops import auto_interpret
        self.corpus = corpus
        self.k = k
        self.lat = lat
        self.n_clusters = int(n_clusters)
        self.nprobe = max(1, int(nprobe))
        self.capacity_factor = float(capacity_factor)
        self.compressed = bool(compressed)
        self.scan_backend = backend if backend is not None else default_backend()
        self.n_workers = max(1, int(n_workers))
        self.seed = int(seed)
        self.residual_cap = max(1, int(residual_cap))
        self.build_chunk = int(build_chunk)
        self.kmeans_iters = int(kmeans_iters)
        self._interpret = auto_interpret() if interpret is None else interpret
        self._corpus_np = np.asarray(corpus, np.float32)
        self._ids_np = np.arange(self._corpus_np.shape[0], dtype=np.int32)
        self._next_id = int(self._corpus_np.shape[0])
        self._ingest_seen: dict = {}
        self.rebuilds = 0
        self._res_vecs_np = np.zeros(
            (self.residual_cap, self._corpus_np.shape[1]), np.float32)
        self._res_ids_np = np.full(self.residual_cap, -1, np.int32)
        self._res_count = 0
        self._build()

    # -- index build / upload -------------------------------------------
    def _build(self) -> None:
        (self._cents_np, self._bvecs_np, self._bscales_np, self._bids_np,
         self._counts_np) = _build_ivf_arrays(
            self._corpus_np, self.n_clusters,
            capacity_factor=self.capacity_factor,
            kmeans_iters=self.kmeans_iters, seed=self.seed,
            chunk=self.build_chunk, compressed=self.compressed,
            ids=self._ids_np)
        self._dirty = True
        self._upload()

    def _upload(self) -> None:
        if self.compressed:
            self.index = CompressedIVFIndex(
                centroids=jnp.asarray(self._cents_np),
                bucket_vecs=jnp.asarray(self._bvecs_np),
                bucket_scales=jnp.asarray(self._bscales_np),
                bucket_ids=jnp.asarray(self._bids_np),
                bucket_counts=jnp.asarray(self._counts_np))
        else:
            self.index = IVFIndex(
                centroids=jnp.asarray(self._cents_np),
                bucket_vecs=jnp.asarray(self._bvecs_np),
                bucket_ids=jnp.asarray(self._bids_np),
                bucket_counts=jnp.asarray(self._counts_np))
        self._res_vecs = jnp.asarray(self._res_vecs_np)
        self._res_ids = jnp.asarray(self._res_ids_np)
        self._dirty = False

    # -- FullRetrievalBackend protocol ----------------------------------
    def search(self, q_embs):
        dispatch.record("ivf_backend_search")
        if self._dirty:
            self._upload()
        return _ivf_ann_search(self.index, self._res_vecs, self._res_ids,
                               q_embs, nprobe=self.nprobe, k=self.k,
                               scan_backend=self.scan_backend,
                               interpret=self._interpret)

    def latency(self, batch: int) -> float:
        return self.lat.full_scan_time() * self.lat.ann_scale(
            self.index.n_buckets, self.nprobe,
            capacity_factor=self.capacity_factor,
            bytes_per_dim=1 if self.compressed else 4,
            residual_rows=self._res_count)

    # -- live-ingest reconciliation -------------------------------------
    @property
    def residual_count(self) -> int:
        return self._res_count

    def _rebucket(self) -> None:
        """Flush: rebuild the whole index (incl. residual docs, which are
        already rows of the host corpus) and empty the residual buffer."""
        self._build()
        self._res_vecs_np[:] = 0.0
        self._res_ids_np[:] = -1
        self._res_count = 0
        self.rebuilds += 1
        self._dirty = True

    def ingest_docs(self, vecs, ids=None, *, ingest_key=None) -> np.ndarray:
        """Reconcile live-ingested docs: nearest-centroid assignment with
        bounded bucket spill into the residual buffer; residual overflow
        triggers a re-bucketing flush.  Idempotent on ``ingest_key``.
        Returns the global ids assigned to the new docs."""
        if ingest_key is not None and ingest_key in self._ingest_seen:
            return self._ingest_seen[ingest_key]
        vecs = np.asarray(vecs, np.float32)
        if vecs.ndim == 1:
            vecs = vecs[None]
        n_new = vecs.shape[0]
        if ids is None:
            ids = self._next_id + np.arange(n_new, dtype=np.int32)
        ids = np.asarray(ids, np.int32)
        self._next_id = max(self._next_id, int(ids.max(initial=-1)) + 1)
        # the host corpus grows FIRST: a re-bucketing flush rebuilds from
        # it, so every doc (placed or not) survives the flush
        self._corpus_np = np.concatenate([self._corpus_np, vecs])
        self._ids_np = np.concatenate([self._ids_np, ids])
        assign = np.asarray(_assign_fn(jnp.asarray(vecs),
                                       jnp.asarray(self._cents_np)))
        if self.compressed:
            q_all, s_all = _quant_residual_halves(
                jnp.asarray(vecs), jnp.asarray(self._cents_np[assign]))
            q_all = np.asarray(q_all)
            s_all = np.asarray(s_all)
        cap = self._bids_np.shape[1]
        for i in range(n_new):
            b = int(assign[i])
            c = int(self._counts_np[b])
            if c < cap:
                self._bids_np[b, c] = ids[i]
                if self.compressed:
                    self._bvecs_np[b, c] = q_all[i]
                    self._bscales_np[b, c] = s_all[i]
                else:
                    self._bvecs_np[b, c] = vecs[i]
                self._counts_np[b] = c + 1
            elif self._res_count < self.residual_cap:
                self._res_vecs_np[self._res_count] = vecs[i]
                self._res_ids_np[self._res_count] = ids[i]
                self._res_count += 1
            else:
                # overflow: the rebuild already covers every remaining doc
                self._rebucket()
                break
        self._dirty = True
        if ingest_key is not None:
            self._ingest_seen[ingest_key] = ids
        return ids


class HybridBackend(_BackendBase):
    """Hybrid lexical+dense cloud stage with single-dispatch fused reranking.

    Composes a dense channel (``dense="flat" | "sharded" | "ann"``) with the
    hashed-term lexical channel (``retrieval/lexical.py``) and fuses both
    into ONE jitted program per ``[B, d]`` batch (``retrieval/fusion.py``):
    channel scans -> rank-domain RRF (``1/(rrf_k + rank)``, cross-channel
    duplicate mass combined onto the first occurrence) -> greedy
    near-duplicate diversification (cosine >= ``diversify_sim`` against
    already-selected docs is dropped; ``None`` disables) -> dense rerank of
    the surviving pool.  ``search`` therefore costs exactly one host
    dispatch regardless of batch width (``dispatch.record``-probed).

    Queries without term arrays (warmup, engines that only carry
    embeddings) run the same program with an all-invalid term batch: the
    lexical channel contributes nothing and the result degrades gracefully
    to diversified+reranked dense retrieval.

    Id contract: postings row == global doc id, so ``ingest_docs`` REJECTS
    non-sequential ids — both channels grow in lockstep (dense vectors via
    the inner ``IVFBackend`` in ANN mode, plain corpus append otherwise;
    postings rows always appended here, ``-1``-padded when the new doc has
    no terms).  ``on_ingest`` stays the base no-op so ``ReplicaBackend``
    and the fault-plan retry/hedge paths compose unchanged.
    """

    uses_lexical = True

    def __init__(self, corpus: jax.Array, k: int, lat,
                 doc_terms, doc_term_weights, dense: str = "flat",
                 dense_k: int | None = None, lexical_k: int | None = None,
                 rrf_k: float = 60.0, diversify_sim: float | None = 0.98,
                 lexical_terms: int | None = None,
                 backend: str | None = None, interpret: bool | None = None,
                 chunk: int = 32768, n_shards: int = 4, n_workers: int = 1,
                 tile_n: int = 512, q_term_width: int = 2,
                 ann_kwargs: dict | None = None):
        from repro.core.has import default_backend
        from repro.kernels.ops import auto_interpret
        if dense not in ("flat", "sharded", "ann"):
            raise ValueError(f"unknown hybrid dense mode: {dense!r}")
        if rrf_k < 1:
            raise ValueError("rrf_k must be >= 1")
        if diversify_sim is not None and not 0.0 < diversify_sim <= 1.0:
            raise ValueError("diversify_sim must be in (0, 1]")
        self.k = k
        self.lat = lat
        self.dense = dense
        self.dense_k = int(dense_k) if dense_k else k
        self.lexical_k = int(lexical_k) if lexical_k else k
        self.rrf_k = float(rrf_k)
        self.diversify_sim = (None if diversify_sim is None
                              else float(diversify_sim))
        self.scan_backend = backend if backend is not None else default_backend()
        self._interpret = auto_interpret() if interpret is None else interpret
        self.tile_n = int(tile_n)
        self.q_term_width = max(1, int(q_term_width))
        self.n_workers = max(1, int(n_workers))
        self.n_shards = max(1, int(n_shards))
        self._corpus_np = np.asarray(corpus, np.float32)
        self.chunk = min(chunk, max(1, self._corpus_np.shape[0]))
        terms = np.asarray(doc_terms, np.int32)
        tw = np.asarray(doc_term_weights, np.float32)
        if terms.shape != tw.shape or terms.shape[0] != self._corpus_np.shape[0]:
            raise ValueError("postings arrays must be [n_docs, L] and match "
                             "the corpus row count")
        if lexical_terms is not None:
            lw = max(1, int(lexical_terms))
            terms, tw = terms[:, :lw], tw[:, :lw]
        self.lexical_terms = terms.shape[1]
        self._terms_np, self._tw_np = terms, tw
        self._ivf = None
        if dense == "ann":
            kw = dict(backend=self.scan_backend, interpret=self._interpret)
            kw.update(ann_kwargs or {})
            self._ivf = IVFBackend(jnp.asarray(self._corpus_np),
                                   self.dense_k, lat, **kw)
        self._ingest_seen: dict = {}
        self._dirty = True
        self._upload()

    def _upload(self) -> None:
        if self._ivf is not None and self._ivf._dirty:
            self._ivf._upload()
        self.corpus = jnp.asarray(self._corpus_np)
        self._terms = jnp.asarray(self._terms_np)
        self._tw = jnp.asarray(self._tw_np)
        self._dirty = False

    # -- FullRetrievalBackend protocol ----------------------------------
    def search(self, q_embs, q_terms=None, q_term_weights=None):
        dispatch.record("hybrid_backend_search")
        b = q_embs.shape[0]
        if q_terms is None:
            # term-less callers: inert terms, lexical channel matches nothing
            q_terms = jnp.full((b, self.q_term_width), -1, jnp.int32)
            q_term_weights = jnp.zeros((b, self.q_term_width), jnp.float32)
        else:
            q_terms = jnp.asarray(q_terms).astype(jnp.int32)
            if q_term_weights is None:
                q_term_weights = jnp.where(q_terms >= 0, 1.0, 0.0)
            q_term_weights = jnp.asarray(q_term_weights).astype(jnp.float32)
        if self._dirty or (self._ivf is not None and self._ivf._dirty):
            self._upload()
        common = dict(k=self.k, kd=self.dense_k, kl=self.lexical_k,
                      rrf_k=self.rrf_k, diversify_sim=self.diversify_sim,
                      scan_backend=self.scan_backend,
                      interpret=self._interpret, tile_n=self.tile_n)
        if self.dense == "flat":
            return hybrid_flat_search(self.corpus, self._terms, self._tw,
                                      q_embs, q_terms, q_term_weights,
                                      chunk=self.chunk, **common)
        if self.dense == "sharded":
            return hybrid_sharded_search(self.corpus, self._terms, self._tw,
                                         q_embs, q_terms, q_term_weights,
                                         n_shards=self.n_shards,
                                         chunk=self.chunk, **common)
        return hybrid_ann_search(self._ivf.index, self._ivf._res_vecs,
                                 self._ivf._res_ids, self.corpus,
                                 self._terms, self._tw, q_embs, q_terms,
                                 q_term_weights, nprobe=self._ivf.nprobe,
                                 **common)

    def _dense_scale(self) -> float:
        if self.dense == "flat":
            return 1.0
        if self.dense == "sharded":
            return self.lat.shard_scale(self.n_shards)
        return self.lat.ann_scale(
            self._ivf.index.n_buckets, self._ivf.nprobe,
            capacity_factor=self._ivf.capacity_factor,
            bytes_per_dim=1 if self._ivf.compressed else 4,
            residual_rows=self._ivf._res_count)

    def latency(self, batch: int) -> float:
        return self.lat.full_scan_time() * self.lat.hybrid_scale(
            self._dense_scale(), self.lexical_terms,
            self.dense_k + self.lexical_k)

    # -- live-corpus ingest (both channels in lockstep) ------------------
    def ingest_docs(self, vecs, ids=None, *, terms=None, term_weights=None,
                    ingest_key=None) -> np.ndarray:
        if ingest_key is not None and ingest_key in self._ingest_seen:
            return self._ingest_seen[ingest_key]
        vecs = np.asarray(vecs, np.float32)
        if vecs.ndim == 1:
            vecs = vecs[None]
        n_new = vecs.shape[0]
        start = self._corpus_np.shape[0]
        want = (start + np.arange(n_new)).astype(np.int32)
        if ids is not None and not np.array_equal(
                np.asarray(ids, np.int32), want):
            raise ValueError(
                "HybridBackend requires sequential doc ids (postings row == "
                f"global id): expected {start}..{start + n_new - 1}")
        t_rows = np.full((n_new, self.lexical_terms), -1, np.int32)
        w_rows = np.zeros((n_new, self.lexical_terms), np.float32)
        if terms is not None:
            terms = np.asarray(terms, np.int32)
            if terms.ndim == 1:
                terms = terms[None]
            if term_weights is None:
                tw = np.where(terms >= 0, 1.0, 0.0).astype(np.float32)
            else:
                tw = np.asarray(term_weights, np.float32)
                if tw.ndim == 1:
                    tw = tw[None]
            m = min(self.lexical_terms, terms.shape[1])
            t_rows[:, :m] = terms[:, :m]
            w_rows[:, :m] = np.where(terms[:, :m] >= 0, tw[:, :m], 0.0)
        if self._ivf is not None:
            got = np.asarray(
                self._ivf.ingest_docs(vecs, want, ingest_key=ingest_key),
                np.int32)
            self._corpus_np = self._ivf._corpus_np
        else:
            got = want
            self._corpus_np = np.concatenate([self._corpus_np, vecs])
        self._terms_np = np.concatenate([self._terms_np, t_rows])
        self._tw_np = np.concatenate([self._tw_np, w_rows])
        self._dirty = True
        if ingest_key is not None:
            self._ingest_seen[ingest_key] = got
        return got


class ReplicaBackend(_BackendBase):
    """Warm-standby replica routing + cache-ingest reconciliation.

    Wraps an inner backend for the actual scan and models one concurrent
    dispatch slot per standby replica.  ``on_ingest`` mirrors every row the
    serving loop folds into the authoritative cache onto each member's
    delta log via the shared ``record_batch`` sink protocol
    (serving/replication.py) — members are cloud ``WarmStandby`` replicas
    and/or an edge ``EdgeReplicaPool`` (serving/edge_pool.py), so both
    replication tiers reconcile off ONE ingest notification.  A standby
    failover then resumes with exactly the cache the primary had — the
    serving loop no longer owns the only authoritative copy.

    Padded (``-1``) doc ids — emitted by the sharded search paths when the
    corpus holds fewer than k rows — gather ZERO vectors into the delta
    logs (:func:`~repro.serving.replication.gather_doc_vecs`); a raw
    ``corpus[full_ids]`` would wrap them to the LAST corpus row and
    silently corrupt every member's log.
    """

    def __init__(self, inner: FullRetrievalBackend, standbys: Sequence,
                 corpus: jax.Array):
        self.inner = inner
        self.standbys = list(standbys)
        self.corpus = corpus
        self._corpus_np = np.asarray(corpus)    # one host copy, reused
        self.n_workers = max(1, len(self.standbys))

    def search(self, q_embs, **kw):
        # kwargs pass through untouched (e.g. a HybridBackend inner's
        # q_terms/q_term_weights)
        return self.inner.search(q_embs, **kw)

    @property
    def uses_lexical(self) -> bool:
        return bool(getattr(self.inner, "uses_lexical", False))

    @property
    def q_term_width(self) -> int:
        return int(getattr(self.inner, "q_term_width", 0))

    def latency(self, batch: int) -> float:
        return self.inner.latency(batch)

    def on_ingest(self, q_embs, full_ids, state, tenant_ids=None, *,
                  ingest_key=None) -> None:
        from repro.serving.replication import gather_doc_vecs
        q_embs = np.asarray(q_embs, np.float32)
        full_ids = np.asarray(full_ids, np.int32)
        vecs = gather_doc_vecs(self._corpus_np, full_ids)  # [N, k, d]
        for sb in self.standbys:
            sb.record_batch(q_embs, full_ids, vecs, state,
                            tenant_ids=tenant_ids, ingest_key=ingest_key)

    def ingest_docs(self, vecs, ids=None, *, ingest_key=None, **kw):
        """Live-corpus ingest passthrough (an ``IVFBackend`` or
        ``HybridBackend`` inner): the inner index reconciles, and this
        wrapper refreshes its host corpus mirror so later ``on_ingest``
        gathers see the new rows.  Extra kwargs (e.g. the hybrid backend's
        ``terms``/``term_weights``) pass through untouched."""
        inner_ingest = getattr(self.inner, "ingest_docs", None)
        if inner_ingest is None:
            raise AttributeError(
                f"{type(self.inner).__name__} has no ingest_docs")
        out = inner_ingest(vecs, ids, ingest_key=ingest_key, **kw)
        inner_np = getattr(self.inner, "_corpus_np", None)
        if inner_np is not None:
            self._corpus_np = inner_np
        return out


class RetrievalService:
    """Shared substrate: corpus + latency calibration + retrieval backend.

    Composition only — the world supplies the corpus, the
    :class:`LatencyModel` supplies analytic scan times, and the
    :class:`FullRetrievalBackend` supplies the actual full-database search
    (``backend=None`` -> :class:`LocalFlatBackend`, the historical
    behavior).

    Latency accounting (see serving/latency.py): edge-local compute (cache
    channel, homology validation, cache updates) is charged at *measured*
    wall-clock — those structures run at their true paper-scale sizes here.
    Corpus-proportional compute (full ENNS scan, fuzzy IVF scan) is charged
    analytically as bytes/bandwidth at the paper's 49.2M-passage target
    scale, with the bandwidth calibrated from a measured reference scan.
    """

    def __init__(self, world, latency, k: int = 10, chunk: int = 32768,
                 calibrate: bool = False,
                 backend: FullRetrievalBackend | None = None):
        self.world = world
        self.latency = latency
        self.latency.d = world.cfg.d
        self.latency.actual_corpus = world.cfg.n_docs
        self.k = k
        self.chunk = min(chunk, world.cfg.n_docs)
        # one device-resident corpus: reuse the backend's copy when one was
        # injected (every backend holds the same world.doc_emb by contract)
        bc = getattr(backend, "corpus", None) if backend is not None else None
        self.corpus = bc if bc is not None else jnp.asarray(world.doc_emb)
        self.backend = backend if backend is not None else LocalFlatBackend(
            self.corpus, k, latency, chunk=self.chunk)
        # warmup (+ optional bandwidth calibration from a measured scan)
        z = jnp.zeros((1, world.cfg.d))
        self.backend.search(z)[0].block_until_ready()
        if calibrate:
            # bandwidth is defined against the UNSHARDED reference scan
            # (shard_scale etc. apply on top of it) — always time the flat
            # chunked scan, not backend.search, or a sharded backend would
            # count its speedup twice
            ref = (self.backend._search
                   if isinstance(self.backend, LocalFlatBackend)
                   else jax.jit(functools.partial(
                       chunked_flat_search, k=k, chunk=self.chunk)))
            ref(self.corpus, z)[0].block_until_ready()
            t0 = time.perf_counter()
            for _ in range(3):
                ref(self.corpus, z)[0].block_until_ready()
            self.latency.calibrate((time.perf_counter() - t0) / 3,
                                   world.cfg.n_docs)

    def _term_kw(self, q_terms, q_term_weights) -> dict:
        """Forward query terms only to backends that score them."""
        if q_terms is None or not getattr(self.backend, "uses_lexical", False):
            return {}
        return dict(q_terms=jnp.asarray(q_terms),
                    q_term_weights=(None if q_term_weights is None
                                    else jnp.asarray(q_term_weights)))

    def full_search(self, q_emb: np.ndarray, q_terms=None,
                    q_term_weights=None):
        """Exact full-database search; returns (ids [k], vecs [k,d], t_comp)."""
        kw = self._term_kw(None if q_terms is None else
                           np.asarray(q_terms)[None],
                           None if q_term_weights is None else
                           np.asarray(q_term_weights)[None])
        s, ids = self.backend.search(jnp.asarray(q_emb)[None], **kw)
        ids = np.asarray(ids[0])
        t = self.backend.latency(1)
        return ids, np.asarray(self.corpus[ids]), t

    def full_search_batch(self, q_embs, q_terms=None,
                          q_term_weights=None) -> tuple[np.ndarray, float]:
        """Coalesced exact search for [B, d]; returns (ids [B,k], t_comp)."""
        kw = self._term_kw(q_terms, q_term_weights)
        _, ids = self.backend.search(jnp.asarray(q_embs), **kw)
        return np.asarray(ids), self.backend.latency(len(q_embs))
