"""IVF (inverted-file) approximate search in pure JAX.

Build: k-means over the corpus -> centroids; vectors re-ordered into
fixed-capacity buckets (power-law bucket sizes are padded/truncated so every
shape is static — the TPU adaptation of Faiss's variable-length inverted
lists; truncation loss is the deliberate 'fuzzy' accuracy trade of HaS).

Search: centroid matmul -> top-nprobe buckets -> bucket gather -> scoring ->
local top-k.  The gather+score inner loop is the Pallas ``ivf_scan`` kernel's
oracle.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


from repro.training.compression import quantize_int8


@dataclasses.dataclass
class IVFIndex:
    centroids: jax.Array     # [C, d]
    bucket_vecs: jax.Array   # [C, cap, d]
    bucket_ids: jax.Array    # [C, cap] int32 global ids (-1 = pad)
    bucket_counts: jax.Array  # [C] int32

    @property
    def n_buckets(self) -> int:
        return self.centroids.shape[0]

    @property
    def capacity(self) -> int:
        return self.bucket_ids.shape[1]

    def tree_flatten(self):
        return ((self.centroids, self.bucket_vecs, self.bucket_ids,
                 self.bucket_counts), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    IVFIndex, IVFIndex.tree_flatten, IVFIndex.tree_unflatten)


@dataclasses.dataclass
class CompressedIVFIndex:
    """IVF index with int8 residual-coded bucket storage.

    The compressed-residency mode of the ANN cloud backend: ``bucket_vecs``
    holds symmetric-int8 codes of the RESIDUAL ``v - centroid[bucket]``
    (residuals are much smaller than the vectors, so the int8 grid spends
    its 8 bits where the information is), with one dequant scale per
    d/2-dim half of each slot.  The scan operand is ~3.6x smaller than f32
    and the dequant fuses into scoring:

        ``q . v  =  q . c  +  (q_lo . v8_lo) s_lo  +  (q_hi . v8_hi) s_hi``

    — the centroid term is the probe score the search already computed, and
    the per-half scales factor out of the half inner products, so no f32
    vectors are ever materialized.
    """
    centroids: jax.Array      # [C, d] f32
    bucket_vecs: jax.Array    # [C, cap, d] int8 residual codes
    bucket_scales: jax.Array  # [C, cap, 2] f32 per-half dequant scales
    bucket_ids: jax.Array     # [C, cap] int32 global ids (-1 = pad)
    bucket_counts: jax.Array  # [C] int32

    @property
    def n_buckets(self) -> int:
        return self.centroids.shape[0]

    @property
    def capacity(self) -> int:
        return self.bucket_ids.shape[1]

    def tree_flatten(self):
        return ((self.centroids, self.bucket_vecs, self.bucket_scales,
                 self.bucket_ids, self.bucket_counts), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    CompressedIVFIndex, CompressedIVFIndex.tree_flatten,
    CompressedIVFIndex.tree_unflatten)


@functools.partial(jax.jit, static_argnames=("n_clusters",), donate_argnums=(1,))
def _kmeans_step(train, cents, n_clusters: int):
    assign = jnp.argmax(train @ cents.T, axis=1)          # [S]
    sums = jax.ops.segment_sum(train, assign, num_segments=n_clusters)
    cnts = jax.ops.segment_sum(jnp.ones((train.shape[0],)), assign,
                               num_segments=n_clusters)
    new = sums / jnp.maximum(cnts, 1.0)[:, None]
    # re-seed empty clusters from the previous centroids
    new = jnp.where((cnts > 0)[:, None], new, cents)
    return new / jnp.maximum(
        jnp.linalg.norm(new, axis=-1, keepdims=True), 1e-8)


def kmeans(vecs: jax.Array, n_clusters: int, iters: int = 10,
           seed: int = 0, sample: int = 131072) -> jax.Array:
    """Mini-batch-free Lloyd's k-means on (a sample of) the corpus."""
    key = jax.random.key(seed)
    n = vecs.shape[0]
    if n > sample:
        idx = jax.random.choice(key, n, (sample,), replace=False)
        train = vecs[idx]
    else:
        train = vecs
    init_idx = jax.random.choice(jax.random.fold_in(key, 1),
                                 train.shape[0], (n_clusters,), replace=False)
    cents = train[init_idx]
    for _ in range(iters):
        cents = _kmeans_step(train, cents, n_clusters)
    return cents


_assign_fn = jax.jit(lambda corpus, cents: jnp.argmax(corpus @ cents.T, axis=1))


def build_ivf(corpus: jax.Array, n_buckets: int, capacity_factor: float = 2.0,
              kmeans_iters: int = 10, seed: int = 0) -> IVFIndex:
    """Assign every corpus vector to its nearest centroid bucket."""
    n, d = corpus.shape
    n_buckets = max(1, min(n_buckets, n // 8))   # clamp for tiny corpora
    cents = kmeans(corpus, n_buckets, kmeans_iters, seed)
    assign = np.asarray(_assign_fn(corpus, cents))
    cap = int(np.ceil(n / n_buckets * capacity_factor))
    # vectorized bucket fill: sort by bucket, position-in-bucket via offsets
    order = np.argsort(assign, kind="stable")
    sorted_b = assign[order]
    starts = np.searchsorted(sorted_b, np.arange(n_buckets))
    pos = np.arange(n) - starts[sorted_b]
    keep = pos < cap
    bucket_ids = np.full((n_buckets, cap), -1, np.int32)
    bucket_ids[sorted_b[keep], pos[keep]] = order[keep]
    counts = np.bincount(sorted_b[keep], minlength=n_buckets).astype(np.int32)
    corpus_np = np.asarray(corpus)
    safe = np.where(bucket_ids >= 0, bucket_ids, 0)
    bucket_vecs = corpus_np[safe]
    bucket_vecs[bucket_ids < 0] = 0.0
    return IVFIndex(centroids=cents,
                    bucket_vecs=jnp.asarray(bucket_vecs),
                    bucket_ids=jnp.asarray(bucket_ids),
                    bucket_counts=jnp.asarray(counts))


@jax.jit
def _quant_residual_halves(rows, cents_rows):
    """int8-code the residual ``rows - centroid`` with one symmetric scale
    per d/2-dim half.  Returns ``(codes [n, d] int8, scales [n, 2] f32)``."""
    r = rows - cents_rows
    h = r.shape[1] // 2
    q0, s0 = quantize_int8(r[:, :h], axis=-1)
    q1, s1 = quantize_int8(r[:, h:], axis=-1)
    return jnp.concatenate([q0, q1], axis=1), jnp.concatenate([s0, s1], axis=1)


def _build_ivf_arrays(corpus, n_buckets: int, capacity_factor: float = 2.0,
                      kmeans_iters: int = 10, seed: int = 0,
                      chunk: int = 65536, compressed: bool = False,
                      ids=None):
    """Streaming bucket build on HOST arrays (the backend keeps them as
    mutable mirrors for live ingest).  The corpus flows through k-means
    assignment ``chunk`` rows at a time; per-bucket fill cursors reproduce
    ``build_ivf``'s stable bucket order without ever materializing the
    [B, C] score matrix or (in compressed mode) f32 buckets.  Returns
    ``(centroids, bucket_vecs, bucket_scales | None, bucket_ids, counts)``
    as numpy arrays.
    """
    corpus_np = np.asarray(corpus)
    n, d = corpus_np.shape
    n_buckets = max(1, min(n_buckets, n // 8))   # clamp for tiny corpora
    cents = kmeans(jnp.asarray(corpus_np), n_buckets, kmeans_iters, seed)
    cap = int(np.ceil(n / n_buckets * capacity_factor))
    gids = (np.arange(n, dtype=np.int32) if ids is None
            else np.asarray(ids, np.int32))
    bucket_ids = np.full((n_buckets, cap), -1, np.int32)
    counts = np.zeros(n_buckets, np.int64)
    if compressed:
        bucket_vecs = np.zeros((n_buckets, cap, d), np.int8)
        bucket_scales = np.zeros((n_buckets, cap, 2), np.float32)
        cents_np = np.asarray(cents)
    else:
        bucket_vecs = np.zeros((n_buckets, cap, d), np.float32)
        bucket_scales = None
    for lo in range(0, n, chunk):
        rows = corpus_np[lo:lo + chunk]
        assign = np.asarray(_assign_fn(jnp.asarray(rows), cents))
        order = np.argsort(assign, kind="stable")
        sb = assign[order]
        starts = np.searchsorted(sb, np.arange(n_buckets))
        pos = counts[sb] + (np.arange(len(sb)) - starts[sb])
        keep = pos < cap
        rb, rp, ro = sb[keep], pos[keep].astype(np.int64), order[keep]
        bucket_ids[rb, rp] = gids[lo + ro]
        if compressed:
            q, scale = _quant_residual_halves(
                jnp.asarray(rows[ro]), jnp.asarray(cents_np[rb]))
            bucket_vecs[rb, rp] = np.asarray(q)
            bucket_scales[rb, rp] = np.asarray(scale)
        else:
            bucket_vecs[rb, rp] = rows[ro]
        counts = np.minimum(
            counts + np.bincount(sb, minlength=n_buckets), cap)
    return (np.asarray(cents), bucket_vecs, bucket_scales, bucket_ids,
            counts.astype(np.int32))


def build_ivf_streaming(corpus, n_buckets: int, capacity_factor: float = 2.0,
                        kmeans_iters: int = 10, seed: int = 0,
                        chunk: int = 65536, compressed: bool = False,
                        ids=None) -> IVFIndex | CompressedIVFIndex:
    """Chunked-assignment build; bucket contents identical to ``build_ivf``
    for the same (corpus, seed).  ``compressed=True`` returns a
    :class:`CompressedIVFIndex` with int8 bucket storage — the f32 buckets
    are never materialized, only one ``chunk``-row slice at a time."""
    cents, bvecs, bscales, bids, counts = _build_ivf_arrays(
        corpus, n_buckets, capacity_factor, kmeans_iters, seed, chunk,
        compressed, ids)
    if compressed:
        return CompressedIVFIndex(centroids=jnp.asarray(cents),
                                  bucket_vecs=jnp.asarray(bvecs),
                                  bucket_scales=jnp.asarray(bscales),
                                  bucket_ids=jnp.asarray(bids),
                                  bucket_counts=jnp.asarray(counts))
    return IVFIndex(centroids=jnp.asarray(cents),
                    bucket_vecs=jnp.asarray(bvecs),
                    bucket_ids=jnp.asarray(bids),
                    bucket_counts=jnp.asarray(counts))


def subset_index(index: IVFIndex, fraction: float, seed: int = 0) -> IVFIndex:
    """Keep only a fraction of each bucket (Table VII compression mode)."""
    if fraction >= 1.0:
        return index
    cap = index.capacity
    new_cap = max(1, int(cap * fraction))
    return IVFIndex(centroids=index.centroids,
                    bucket_vecs=index.bucket_vecs[:, :new_cap],
                    bucket_ids=index.bucket_ids[:, :new_cap],
                    bucket_counts=jnp.minimum(index.bucket_counts, new_cap))


def ivf_probe_scan(index: IVFIndex | CompressedIVFIndex, queries: jax.Array,
                   probe: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Gather + score the probed buckets (traceable; the XLA oracle of the
    Pallas ``ivf_scan`` kernel).  For a :class:`CompressedIVFIndex` the
    int8 dequant fuses into scoring: codes are centroid residuals with
    per-half scales, so scores are ``q.c + (q_lo.v8_lo)s_lo +
    (q_hi.v8_hi)s_hi`` — no f32 gather."""
    vecs = index.bucket_vecs[probe]                          # [B, np, cap, d]
    ids = index.bucket_ids[probe]                            # [B, np, cap]
    if isinstance(index, CompressedIVFIndex):
        h = queries.shape[1] // 2
        codes = vecs.astype(jnp.float32)
        scales = index.bucket_scales[probe]                  # [B, np, cap, 2]
        bias = jnp.einsum("bd,bpd->bp", queries, index.centroids[probe])
        s = (jnp.einsum("bd,bpcd->bpc", queries[:, :h], codes[..., :h])
             * scales[..., 0]
             + jnp.einsum("bd,bpcd->bpc", queries[:, h:], codes[..., h:])
             * scales[..., 1]
             + bias[:, :, None])
    else:
        s = jnp.einsum("bd,bpcd->bpc", queries, vecs)
    s = jnp.where(ids >= 0, s, -jnp.inf)
    b = queries.shape[0]
    s = s.reshape(b, -1)
    ids = ids.reshape(b, -1)
    if s.shape[1] < k:       # tiny probe pools (compressed fuzzy channel)
        pad = k - s.shape[1]
        s = jnp.concatenate([s, jnp.full((b, pad), -jnp.inf, s.dtype)], 1)
        ids = jnp.concatenate([ids, jnp.full((b, pad), -1, ids.dtype)], 1)
    top_s, top_i = jax.lax.top_k(s, k)
    return top_s, jnp.take_along_axis(ids, top_i, axis=1)


@functools.partial(jax.jit, static_argnames=("nprobe", "k"))
def ivf_search(index: IVFIndex | CompressedIVFIndex, queries: jax.Array, *,
               nprobe: int, k: int) -> tuple[jax.Array, jax.Array]:
    """queries [B, d] -> (scores [B, k], global ids [B, k])."""
    nprobe = min(nprobe, index.n_buckets)
    cscores = queries @ index.centroids.T                    # [B, C]
    _, probe = jax.lax.top_k(cscores, nprobe)                # [B, nprobe]
    return ivf_probe_scan(index, queries, probe, k)
