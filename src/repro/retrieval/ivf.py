"""IVF (inverted-file) approximate search in pure JAX.

Build: k-means over the corpus -> centroids; vectors re-ordered into
fixed-capacity buckets (power-law bucket sizes are padded/truncated so every
shape is static — the TPU adaptation of Faiss's variable-length inverted
lists; truncation loss is the deliberate 'fuzzy' accuracy trade of HaS).

Search: centroid matmul -> top-nprobe buckets -> bucket gather -> scoring ->
local top-k.  The gather+score inner loop is the Pallas ``ivf_scan`` kernel's
oracle.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class IVFIndex:
    centroids: jax.Array     # [C, d]
    bucket_vecs: jax.Array   # [C, cap, d]
    bucket_ids: jax.Array    # [C, cap] int32 global ids (-1 = pad)
    bucket_counts: jax.Array  # [C] int32

    @property
    def n_buckets(self) -> int:
        return self.centroids.shape[0]

    @property
    def capacity(self) -> int:
        return self.bucket_ids.shape[1]

    def tree_flatten(self):
        return ((self.centroids, self.bucket_vecs, self.bucket_ids,
                 self.bucket_counts), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    IVFIndex, IVFIndex.tree_flatten, IVFIndex.tree_unflatten)


@functools.partial(jax.jit, static_argnames=("n_clusters",), donate_argnums=(1,))
def _kmeans_step(train, cents, n_clusters: int):
    assign = jnp.argmax(train @ cents.T, axis=1)          # [S]
    sums = jax.ops.segment_sum(train, assign, num_segments=n_clusters)
    cnts = jax.ops.segment_sum(jnp.ones((train.shape[0],)), assign,
                               num_segments=n_clusters)
    new = sums / jnp.maximum(cnts, 1.0)[:, None]
    # re-seed empty clusters from the previous centroids
    new = jnp.where((cnts > 0)[:, None], new, cents)
    return new / jnp.maximum(
        jnp.linalg.norm(new, axis=-1, keepdims=True), 1e-8)


def kmeans(vecs: jax.Array, n_clusters: int, iters: int = 10,
           seed: int = 0, sample: int = 131072) -> jax.Array:
    """Mini-batch-free Lloyd's k-means on (a sample of) the corpus."""
    key = jax.random.key(seed)
    n = vecs.shape[0]
    if n > sample:
        idx = jax.random.choice(key, n, (sample,), replace=False)
        train = vecs[idx]
    else:
        train = vecs
    init_idx = jax.random.choice(jax.random.fold_in(key, 1),
                                 train.shape[0], (n_clusters,), replace=False)
    cents = train[init_idx]
    for _ in range(iters):
        cents = _kmeans_step(train, cents, n_clusters)
    return cents


_assign_fn = jax.jit(lambda corpus, cents: jnp.argmax(corpus @ cents.T, axis=1))


def build_ivf(corpus: jax.Array, n_buckets: int, capacity_factor: float = 2.0,
              kmeans_iters: int = 10, seed: int = 0) -> IVFIndex:
    """Assign every corpus vector to its nearest centroid bucket."""
    n, d = corpus.shape
    n_buckets = max(1, min(n_buckets, n // 8))   # clamp for tiny corpora
    cents = kmeans(corpus, n_buckets, kmeans_iters, seed)
    assign = np.asarray(_assign_fn(corpus, cents))
    cap = int(np.ceil(n / n_buckets * capacity_factor))
    # vectorized bucket fill: sort by bucket, position-in-bucket via offsets
    order = np.argsort(assign, kind="stable")
    sorted_b = assign[order]
    starts = np.searchsorted(sorted_b, np.arange(n_buckets))
    pos = np.arange(n) - starts[sorted_b]
    keep = pos < cap
    bucket_ids = np.full((n_buckets, cap), -1, np.int32)
    bucket_ids[sorted_b[keep], pos[keep]] = order[keep]
    counts = np.bincount(sorted_b[keep], minlength=n_buckets).astype(np.int32)
    corpus_np = np.asarray(corpus)
    safe = np.where(bucket_ids >= 0, bucket_ids, 0)
    bucket_vecs = corpus_np[safe]
    bucket_vecs[bucket_ids < 0] = 0.0
    return IVFIndex(centroids=cents,
                    bucket_vecs=jnp.asarray(bucket_vecs),
                    bucket_ids=jnp.asarray(bucket_ids),
                    bucket_counts=jnp.asarray(counts))


def subset_index(index: IVFIndex, fraction: float, seed: int = 0) -> IVFIndex:
    """Keep only a fraction of each bucket (Table VII compression mode)."""
    if fraction >= 1.0:
        return index
    cap = index.capacity
    new_cap = max(1, int(cap * fraction))
    return IVFIndex(centroids=index.centroids,
                    bucket_vecs=index.bucket_vecs[:, :new_cap],
                    bucket_ids=index.bucket_ids[:, :new_cap],
                    bucket_counts=jnp.minimum(index.bucket_counts, new_cap))


@functools.partial(jax.jit, static_argnames=("nprobe", "k"))
def ivf_search(index: IVFIndex, queries: jax.Array, *, nprobe: int,
               k: int) -> tuple[jax.Array, jax.Array]:
    """queries [B, d] -> (scores [B, k], global ids [B, k])."""
    nprobe = min(nprobe, index.n_buckets)
    cscores = queries @ index.centroids.T                    # [B, C]
    _, probe = jax.lax.top_k(cscores, nprobe)                # [B, nprobe]
    vecs = index.bucket_vecs[probe]                          # [B, np, cap, d]
    ids = index.bucket_ids[probe]                            # [B, np, cap]
    s = jnp.einsum("bd,bpcd->bpc", queries, vecs)
    s = jnp.where(ids >= 0, s, -jnp.inf)
    b = queries.shape[0]
    s = s.reshape(b, -1)
    ids = ids.reshape(b, -1)
    if s.shape[1] < k:       # tiny probe pools (compressed fuzzy channel)
        pad = k - s.shape[1]
        s = jnp.concatenate([s, jnp.full((b, pad), -jnp.inf, s.dtype)], 1)
        ids = jnp.concatenate([ids, jnp.full((b, pad), -1, ids.dtype)], 1)
    top_s, top_i = jax.lax.top_k(s, k)
    return top_s, jnp.take_along_axis(ids, top_i, axis=1)
