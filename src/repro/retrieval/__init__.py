"""Retrieval substrate: flat ENNS, IVF ANNS, int8 stores, distributed top-k,
and the pluggable full-retrieval backend layer (service.py): the
FullRetrievalBackend protocol, LocalFlatBackend / ShardedMeshBackend /
ReplicaBackend, and the RetrievalService every serving layer composes."""
