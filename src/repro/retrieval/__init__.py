"""Retrieval substrate: flat ENNS, IVF ANNS, int8 stores, distributed top-k."""
