"""Hashed-term lexical (sparse) retrieval channel.

The synthetic world has no real text, but its entity/attribute structure is
exactly what a lexical index would key on: the entity name and the queried
attribute.  We hash both into a flat term vocabulary at world-gen time —
pure integer hashing of arrays the world already has, consuming **zero**
rng draws, so dense embeddings and query streams stay bit-identical to the
pre-hybrid goldens:

  * every doc posts its entity term (weight 1.0) plus one term per covered
    attribute (weight 0.7);
  * every query carries its entity term (weight 1.0) plus the queried
    (entity, attribute) term (weight 0.7).

A golden doc therefore scores 1.0 + 0.49 while a same-entity/wrong-attr doc
scores 1.0 — the channel finds answers the dense encoder can miss (the
fused-retrieval bench corrupts dense embeddings while leaving these postings
intact), which is the reason hybrid retrieval exists.

Scoring runs through ``kernels/lexical_score.py`` (Pallas) or its tiled XLA
oracle behind the usual ``backend="pallas"|"xla"`` switch; both are
traceable, so the hybrid cloud stage fuses the channel into the same jitted
program as the dense scan (``retrieval/fusion.py``).
"""
from __future__ import annotations

import numpy as np

from repro.kernels.lexical_score import lexical_score
from repro.kernels.ref import lexical_score_ref

LEXICAL_VOCAB = 1 << 20          # hashed term-id space
ENTITY_TERM_WEIGHT = 1.0
ATTR_TERM_WEIGHT = 0.7
_P_ENTITY = 2654435761           # Knuth multiplicative hash constants
_P_ATTR = 40503


def entity_term(entity) -> np.ndarray:
    """Hashed term id for an entity name (vectorized)."""
    return ((np.asarray(entity, np.int64) * _P_ENTITY)
            % LEXICAL_VOCAB).astype(np.int32)


def attr_term(entity, attr) -> np.ndarray:
    """Hashed term id for an (entity, attribute) pair (vectorized)."""
    e = np.asarray(entity, np.int64) * _P_ENTITY
    a = (np.asarray(attr, np.int64) + 1) * _P_ATTR
    return ((e ^ a) % LEXICAL_VOCAB).astype(np.int32)


def build_doc_terms(doc_entity: np.ndarray, doc_attr_mask: np.ndarray,
                    width: int | None = None):
    """Postings arrays for a corpus: -> (terms [N,L] int32 -1-padded,
    weights [N,L] f32).

    Slot 0 is the entity term; the remaining slots are the covered
    attributes' pair terms in ascending attribute order.  ``width`` caps L
    (the ``--lexical-terms`` knob): narrower postings drop the
    highest-numbered attributes and cost proportionally less bandwidth.
    Deterministic in the inputs — no rng.
    """
    n, _ = doc_attr_mask.shape
    max_attrs = int(doc_attr_mask.sum(axis=1).max()) if n else 0
    l_w = (1 + max_attrs) if width is None else max(1, int(width))
    terms = np.full((n, l_w), -1, np.int32)
    weights = np.zeros((n, l_w), np.float32)
    terms[:, 0] = entity_term(doc_entity)
    weights[:, 0] = ENTITY_TERM_WEIGHT
    # covered attrs first (ascending attr id) per row, without a python loop
    order = np.argsort(~doc_attr_mask, axis=1, kind="stable")
    counts = doc_attr_mask.sum(axis=1)
    for j in range(l_w - 1):
        has = counts > j
        t = attr_term(doc_entity, order[:, j])
        terms[has, 1 + j] = t[has]
        weights[has, 1 + j] = ATTR_TERM_WEIGHT
    return terms, weights


def query_terms(entity: int, attr: int):
    """Hashed query terms -> (terms [2] int32, weights [2] f32)."""
    return (np.array([entity_term(entity), attr_term(entity, attr)],
                     np.int32),
            np.array([ENTITY_TERM_WEIGHT, ATTR_TERM_WEIGHT], np.float32))


def lexical_topk(q_terms, q_weights, doc_terms, doc_weights, k: int,
                 backend: str = "pallas", tile_n: int = 512,
                 interpret: bool = False):
    """Channel top-k behind the pallas|xla switch (both traceable).

    -> (vals [B,k] desc, postings-row idx [B,k]); rows with no matched term
    come back as ``-inf`` / ``-1``.
    """
    if backend == "pallas":
        return lexical_score(q_terms, q_weights, doc_terms, doc_weights, k,
                             tile_n=tile_n, interpret=interpret)
    if backend == "xla":
        return lexical_score_ref(q_terms, q_weights, doc_terms, doc_weights,
                                 k, tile_n=tile_n)
    raise ValueError(f"unknown lexical backend: {backend!r}")
