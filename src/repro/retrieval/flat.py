"""Exact nearest-neighbour search (ENNS) as sharded matmul + top-k.

On TPU, flat search over an embedding store IS a matmul: scores = q @ E^T.
The corpus shards over the ``corpus`` logical axes (data x model); the top-k
runs per shard and merges with a tree reduction (see distributed.py).  On a
single device the chunked variant bounds the transient score matrix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.utils import constrain


def flat_search(corpus: jax.Array, queries: jax.Array, k: int,
                rules=None, merge_chunks: int = 0) -> tuple[jax.Array, jax.Array]:
    """Exact top-k by inner product.

    corpus [N, d] (sharded over 'corpus'), queries [B, d] -> (scores [B,k],
    ids [B,k]).

    merge_chunks > 0 (set it to the corpus shard count) computes the top-k
    *per chunk locally* and merges the [B, chunks, k] candidates — §Perf
    iteration for has-rag: a plain top_k over the sharded N dim makes GSPMD
    all-gather the full [B, N] score matrix (~25 GB/device at 49.2M);
    chunk-local selection reduces the interconnect payload to B·chunks·k
    pairs (~MBs), the same tree-merge the shard_map path uses.
    """
    corpus = constrain(corpus, ("corpus", None), rules)
    scores = queries @ corpus.T                      # [B, N]
    scores = constrain(scores, (None, "corpus"), rules)
    b, n = scores.shape
    if merge_chunks and n % merge_chunks == 0:
        loc = n // merge_chunks
        sc = scores.reshape(b, merge_chunks, loc)
        sc = constrain(sc, (None, "corpus", None), rules)
        lv, li = jax.lax.top_k(sc, min(k, loc))      # [B, C, k] local
        li = li + (jnp.arange(merge_chunks) * loc)[None, :, None]
        lv = lv.reshape(b, -1)
        li = li.reshape(b, -1)
        v, pos = jax.lax.top_k(lv, k)                # tiny merge
        return v, jnp.take_along_axis(li, pos, axis=1)
    return jax.lax.top_k(scores, k)


def chunked_flat_search(corpus: jax.Array, queries: jax.Array, k: int,
                        chunk: int = 65536) -> tuple[jax.Array, jax.Array]:
    """Streaming exact top-k: scans corpus chunks with a running top-k merge.

    Bounds the transient score matrix to [B, chunk]; this is the pure-jnp
    oracle for the Pallas ``topk_search`` kernel.
    """
    n, d = corpus.shape
    b = queries.shape[0]
    n_chunks = max(1, (n + chunk - 1) // chunk)
    pad = n_chunks * chunk - n
    if pad:
        corpus = jnp.concatenate(
            [corpus, jnp.zeros((pad, d), corpus.dtype)], axis=0)
    blocks = corpus.reshape(n_chunks, chunk, d)

    def body(carry, inputs):
        best_s, best_i = carry
        block, base = inputs
        s = queries @ block.T                         # [B, chunk]
        ids = base + jnp.arange(chunk, dtype=jnp.int32)[None, :]
        s = jnp.where(ids < n, s, -jnp.inf)
        cs = jnp.concatenate([best_s, s], axis=1)
        ci = jnp.concatenate([best_i, jnp.broadcast_to(ids, (b, chunk))], axis=1)
        ts, ti = jax.lax.top_k(cs, k)
        return (ts, jnp.take_along_axis(ci, ti, axis=1)), None

    init = (jnp.full((b, k), -jnp.inf, queries.dtype),
            jnp.full((b, k), -1, jnp.int32))
    bases = (jnp.arange(n_chunks) * chunk).astype(jnp.int32)
    (scores, ids), _ = jax.lax.scan(body, init, (blocks, bases))
    return scores, ids


# ---------------------------------------------------------------------------
# int8 quantized store (TPU-native replacement for Faiss PQ)
# ---------------------------------------------------------------------------

def quantize_store(corpus: jax.Array) -> dict:
    """Per-vector symmetric int8 quantization: ~4x HBM compression."""
    scale = jnp.max(jnp.abs(corpus), axis=-1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(corpus / jnp.maximum(scale, 1e-8)), -127, 127)
    return {"q": q.astype(jnp.int8), "scale": scale[:, 0].astype(jnp.float32)}


def quantized_search(store: dict, queries: jax.Array, k: int,
                     rescore: jax.Array | None = None,
                     rescore_factor: int = 4) -> tuple[jax.Array, jax.Array]:
    """ADC-style scoring on the int8 store + optional exact re-rank.

    This is the ScaNN-substitute: approximate scores from the compressed
    store select ``rescore_factor * k`` candidates which are exactly
    re-scored against the fp corpus (if given).
    """
    approx = (queries @ store["q"].T.astype(queries.dtype)) \
        * store["scale"][None, :]
    if rescore is None:
        return jax.lax.top_k(approx, k)
    m = min(rescore_factor * k, approx.shape[1])
    _, cand = jax.lax.top_k(approx, m)                 # [B, m]
    cvecs = rescore[cand]                              # [B, m, d]
    exact = jnp.einsum("bd,bmd->bm", queries, cvecs)
    s, local = jax.lax.top_k(exact, k)
    return s, jnp.take_along_axis(cand, local, axis=1)
