"""Training substrate: optimizers, train step, grad compression, loops."""
