"""Optimizers: AdamW (dense archs) and Adafactor (giant MoE archs).

Adafactor's factored second moment keeps optimizer state ~O(params/row) so a
480B-param MoE fits a v5e pod (AdamW's 2x fp32 state would not: 480B x 8 B =
3.8 TB > the pod's 4 TB HBM).  Optimizer states inherit the parameter
sharding (ZeRO-style: FSDP'd params imply FSDP'd states).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    # adafactor
    decay: float = 0.8
    min_dim_factored: int = 2      # factor 2D+ tensors


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - cfg.lr * u).astype(p.dtype)

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    m = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": m, "v": v, "step": step}


# ---------------------------------------------------------------------------
# Adafactor (factored second moments, no momentum)
# ---------------------------------------------------------------------------

def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params):
    def init_v(p):
        if _factored(p):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"v": jax.tree.map(init_v, params,
                              is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - t ** (-cfg.decay)

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if _factored(p):
            vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(jnp.mean(vr, axis=-1,
                                            keepdims=True)[..., None], 1e-30))
            u = g / (jnp.sqrt(denom) + cfg.eps)
            nv = {"vr": vr, "vc": vc}
        else:
            vv = beta * v["v"] + (1 - beta) * g2
            u = g / (jnp.sqrt(vv) + cfg.eps)
            nv = {"v": vv}
        # update clipping (Adafactor RMS-1 rule)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return nv, (p.astype(jnp.float32) - cfg.lr * u).astype(p.dtype)

    leaves_is = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    out = jax.tree.map(upd, grads, state["v"], params, is_leaf=None)
    nv = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"v": nv, "step": step}


# ---------------------------------------------------------------------------
# Unified interface
# ---------------------------------------------------------------------------

def opt_init(cfg: OptConfig, params):
    return adamw_init(params) if cfg.name == "adamw" else adafactor_init(params)


def opt_update(cfg: OptConfig, grads, state, params):
    if cfg.grad_clip > 0:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    if cfg.name == "adamw":
        return adamw_update(cfg, grads, state, params)
    return adafactor_update(cfg, grads, state, params)


def opt_state_logical(cfg: OptConfig, params_logical):
    """Optimizer-state sharding mirrors the parameter sharding."""
    is_lg = lambda x: isinstance(x, tuple) and all(
        isinstance(e, str) or e is None for e in x)
    if cfg.name == "adamw":
        return {"m": params_logical, "v": params_logical, "step": ()}

    def v_logical(lg):
        # vr drops the last dim's axis, vc drops the second-to-last's
        return {"vr": lg[:-1], "vc": lg[:-2] + lg[-1:]} if len(lg) >= 2 \
            else {"v": lg}
    return {"v": jax.tree.map(v_logical, params_logical, is_leaf=is_lg),
            "step": ()}
